//! The sweep service: `hindsight serve` as a library.
//!
//! Turns the batch sweep stack (grid expansion, the deterministic
//! executor's cache discipline, the resumable run store) into a
//! long-running, sharded serving layer:
//!
//! * [`protocol`] — hand-rolled HTTP/1.1 request/response framing in
//!   the crate's no-deps style, hardened for untrusted input.
//! * [`queue`] — the shared cost-prioritized work queue (scheme
//!   datapath bits × model MACs × steps, heaviest first).
//! * [`shard`] — deterministic `index % N` cell ownership, so N
//!   processes over one store split a grid with zero coordination.
//! * [`server`] — the service itself: job registration and
//!   persistence, worker threads with store write-through, status /
//!   results / cache-inspection endpoints, graceful drain.

pub mod protocol;
pub mod queue;
pub mod server;
pub mod shard;

pub use queue::{cell_cost, PushError, QueueItem, WorkQueue};
pub use server::{synthetic_cell_record, CellRunner, JobSpec, ServeOptions, Server};
pub use shard::ShardSpec;

//! Minimal HTTP/1.1 wire protocol, hand-rolled in the crate's no-deps
//! style (the server speaks exactly as much HTTP as `curl` needs).
//!
//! Supported: request line + headers + `Content-Length` bodies, close
//! semantics (`Connection: close` on every response — one request per
//! connection keeps the state machine trivial), JSON and plain-text
//! response bodies.  Deliberately absent: keep-alive, chunked encoding,
//! TLS, multipart.  Inputs are untrusted: header and body sizes are
//! capped ([`MAX_HEADER_BYTES`], [`MAX_BODY_BYTES`]) and JSON bodies go
//! through the hardened [`crate::util::json::parse`].

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// Cap on request line + headers (a `curl` submit is well under 1 KiB).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// Cap on request bodies (a grid submission is tens of bytes).
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// decoded path without the query string, e.g. `/jobs/abc`
    pub path: String,
    /// decoded query parameters in order of appearance
    pub query: Vec<(String, String)>,
    /// header `(name, value)` pairs, names lowercased
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON (the hardened parser: depth + size caps).
    pub fn json(&self) -> Result<Value> {
        let text = std::str::from_utf8(&self.body).context("request body is not UTF-8")?;
        json::parse(text).map_err(|e| anyhow::anyhow!("bad JSON body: {e}"))
    }
}

/// Read one request off `r`.  Byte-at-a-time up to the blank line (the
/// header section is tiny and this keeps the reader dependency-free and
/// un-overreadable), then an exact `Content-Length` body read.
pub fn read_request<R: Read>(r: &mut R) -> Result<Request> {
    let head = read_until_blank_line(r)?;
    let head = std::str::from_utf8(&head).context("request head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or_default();
    let mut parts = request_line.split_ascii_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let target = parts.next().unwrap_or_default();
    let version = parts.next().unwrap_or_default();
    if method.is_empty() || target.is_empty() || !version.starts_with("HTTP/1.") {
        bail!("malformed request line '{request_line}'");
    }
    let (raw_path, raw_query) = match target.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (target, None),
    };
    let path = percent_decode(raw_path);
    let mut query = Vec::new();
    if let Some(q) = raw_query {
        for pair in q.split('&').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            query.push((percent_decode(k), percent_decode(v)));
        }
    }
    let mut headers = Vec::new();
    for line in lines.filter(|l| !l.is_empty()) {
        let Some((name, value)) = line.split_once(':') else {
            bail!("malformed header line '{line}'");
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| v.parse::<usize>().context("bad Content-Length"))
        .transpose()?
        .unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        bail!("body of {content_length} bytes exceeds cap of {MAX_BODY_BYTES}");
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).context("reading request body")?;
    Ok(Request { method, path, query, headers, body })
}

/// Read bytes until the `\r\n\r\n` header terminator (exclusive),
/// erroring past [`MAX_HEADER_BYTES`] or on EOF mid-head.
fn read_until_blank_line<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut buf = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    loop {
        let n = r.read(&mut byte).context("reading request head")?;
        if n == 0 {
            bail!("connection closed mid-request");
        }
        buf.push(byte[0]);
        if buf.ends_with(b"\r\n\r\n") {
            buf.truncate(buf.len() - 4);
            return Ok(buf);
        }
        if buf.len() > MAX_HEADER_BYTES {
            bail!("request head exceeds cap of {MAX_HEADER_BYTES} bytes");
        }
    }
}

/// Minimal percent-decoding (`%41` → `A`, `+` → space); invalid
/// escapes pass through literally.
fn percent_decode(s: &str) -> String {
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'%' if i + 2 < b.len() => {
                let hex = std::str::from_utf8(&b[i + 1..i + 3]).unwrap_or("");
                if let Ok(v) = u8::from_str_radix(hex, 16) {
                    out.push(v);
                    i += 3;
                } else {
                    out.push(b'%');
                    i += 1;
                }
            }
            b'+' => {
                out.push(b' ');
                i += 1;
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    String::from_utf8_lossy(&out).into_owned()
}

/// A response body: owned bytes, or a shared slice (the results cache
/// hands the same `Arc` to every warm GET — zero copies, zero
/// serializations on the write path).
#[derive(Debug, Clone)]
pub enum Body {
    Owned(Vec<u8>),
    Shared(std::sync::Arc<[u8]>),
}

impl Body {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Body::Owned(v) => v,
            Body::Shared(a) => a,
        }
    }

    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }
}

/// One response, written with `Connection: close`.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Body,
    /// additional headers (e.g. `Retry-After` on 429), written verbatim
    pub extra_headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, v: &Value) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: Body::Owned(format!("{v}\n").into_bytes()),
            extra_headers: Vec::new(),
        }
    }

    /// A JSON response over pre-assembled shared bytes (the caller owns
    /// the framing contract: the slice must already end in `\n` like
    /// [`Response::json`] output).
    pub fn json_shared(status: u16, body: std::sync::Arc<[u8]>) -> Self {
        Self {
            status,
            content_type: "application/json",
            body: Body::Shared(body),
            extra_headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: &str) -> Self {
        Self {
            status,
            content_type: "text/plain; charset=utf-8",
            body: Body::Owned(body.as_bytes().to_vec()),
            extra_headers: Vec::new(),
        }
    }

    /// A JSON error envelope: `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Self {
        Self::json(status, &Value::object(vec![("error", Value::from(msg))]))
    }

    /// Attach an extra header (builder-style).
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Self {
        self.extra_headers.push((name, value.into()));
        self
    }

    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        let reason = reason_phrase(self.status);
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
            self.status,
            reason,
            self.content_type,
            self.body.len()
        )?;
        for (name, value) in &self.extra_headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        write!(w, "Connection: close\r\n\r\n")?;
        w.write_all(self.body.as_slice())?;
        w.flush()?;
        Ok(())
    }
}

fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

/// Client-side helper (tests, smoke tools): read one full response,
/// returning `(status, body)`.
pub fn read_response<R: Read>(r: &mut R) -> Result<(u16, Vec<u8>)> {
    let (status, _headers, body) = read_response_full(r)?;
    Ok((status, body))
}

/// Like [`read_response`], but also returns the header `(name, value)`
/// pairs (names lowercased) — the flood e2e inspects `Retry-After`.
pub fn read_response_full<R: Read>(r: &mut R) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let head = read_until_blank_line(r)?;
    let head = std::str::from_utf8(&head).context("response head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or_default();
    let status: u16 = status_line
        .split_ascii_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("malformed status line '{status_line}'"))?;
    let mut headers = Vec::new();
    for line in lines.filter(|l| !l.is_empty()) {
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    let content_length = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse::<usize>().ok());
    let mut body = Vec::new();
    match content_length {
        Some(n) => {
            body.resize(n, 0);
            r.read_exact(&mut body).context("reading response body")?;
        }
        None => {
            r.read_to_end(&mut body).context("reading response body")?;
        }
    }
    Ok((status, headers, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_a_get_with_query() {
        let raw = b"GET /jobs/abc?verbose=1&tag=a%20b HTTP/1.1\r\nHost: x\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/jobs/abc");
        assert_eq!(req.query_param("verbose"), Some("1"));
        assert_eq!(req.query_param("tag"), Some("a b"));
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_a_post_with_json_body() {
        let body = r#"{"grid":"g:hindsight:8","seeds":[1,2]}"#;
        let raw = format!(
            "POST /jobs HTTP/1.1\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        );
        let req = read_request(&mut Cursor::new(raw.as_bytes())).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/jobs");
        let v = req.json().unwrap();
        assert_eq!(v.get("grid").and_then(|g| g.as_str()), Some("g:hindsight:8"));
        assert_eq!(v.get("seeds").unwrap().as_usize_vec(), Some(vec![1, 2]));
    }

    #[test]
    fn rejects_malformed_oversized_and_truncated() {
        assert!(read_request(&mut Cursor::new(&b"NOPE\r\n\r\n"[..])).is_err());
        assert!(read_request(&mut Cursor::new(&b"GET / FTP/9\r\n\r\n"[..])).is_err());
        // truncated: head never terminates
        assert!(read_request(&mut Cursor::new(&b"GET / HTTP/1.1\r\n"[..])).is_err());
        // oversized head
        let huge = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n", "a".repeat(MAX_HEADER_BYTES));
        assert!(read_request(&mut Cursor::new(huge.as_bytes())).is_err());
        // oversized declared body
        let raw = format!(
            "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        assert!(read_request(&mut Cursor::new(raw.as_bytes())).is_err());
        // body shorter than declared
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc";
        assert!(read_request(&mut Cursor::new(&raw[..])).is_err());
    }

    /// Satellite coverage: percent-decoding at its edges — truncated
    /// escapes at end-of-input, invalid hex, `+`, and `%2B`.
    #[test]
    fn percent_decode_adversarial_edges() {
        // truncated escape at end-of-input passes through literally
        // (the `i + 2 < len` guard; a fuzz target must never see an
        // out-of-bounds slice here)
        assert_eq!(percent_decode("%4"), "%4");
        assert_eq!(percent_decode("%"), "%");
        assert_eq!(percent_decode("abc%"), "abc%");
        assert_eq!(percent_decode("abc%F"), "abc%F");
        // invalid hex: the '%' passes through, the rest re-scans
        assert_eq!(percent_decode("%GG"), "%GG");
        assert_eq!(percent_decode("%zz41"), "%zz41");
        // '%' then a valid escape right behind it
        assert_eq!(percent_decode("%%41"), "%A");
        // '+' is a space, '%2B' is a literal plus
        assert_eq!(percent_decode("a+b"), "a b");
        assert_eq!(percent_decode("a%2Bb"), "a+b");
        assert_eq!(percent_decode("%2b%2B"), "++");
        // NUL and high bytes decode; invalid UTF-8 is replacement-lossy
        assert_eq!(percent_decode("%00"), "\0");
        assert_eq!(percent_decode("%ff"), "\u{fffd}");
        // multi-byte UTF-8 sequences reassemble
        assert_eq!(percent_decode("%E7%B1%B3"), "米");
        // and the request path exercises the same code
        let raw = b"GET /jobs/a%2Bb?q=%4 HTTP/1.1\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..])).unwrap();
        assert_eq!(req.path, "/jobs/a+b");
        assert_eq!(req.query_param("q"), Some("%4"));
    }

    #[test]
    fn overflowing_content_length_is_a_clean_error() {
        // usize overflow in the Content-Length parse must error, not
        // panic or wrap into a tiny allocation
        let raw =
            b"POST / HTTP/1.1\r\nContent-Length: 99999999999999999999999999\r\n\r\n";
        let err = read_request(&mut Cursor::new(&raw[..])).unwrap_err();
        assert!(format!("{err:#}").contains("Content-Length"), "{err:#}");
        let raw = b"POST / HTTP/1.1\r\nContent-Length: -1\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&raw[..])).is_err());
    }

    #[test]
    fn response_round_trips_through_the_client_reader() {
        let v = Value::object(vec![("job", Value::from("abc")), ("total", Value::from(4usize))]);
        let resp = Response::json(202, &v);
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 202 Accepted\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"));
        let (status, body) = read_response(&mut Cursor::new(&wire[..])).unwrap();
        assert_eq!(status, 202);
        let parsed = crate::util::json::parse(std::str::from_utf8(&body).unwrap().trim()).unwrap();
        assert_eq!(parsed.get("job").and_then(|j| j.as_str()), Some("abc"));
        assert_eq!(parsed.get("total").and_then(|t| t.as_usize()), Some(4));
    }

    #[test]
    fn shared_bodies_and_extra_headers_round_trip() {
        let bytes: std::sync::Arc<[u8]> = std::sync::Arc::from(&b"{\"x\":1}\n"[..]);
        let resp = Response::json_shared(200, bytes.clone()).with_header("Retry-After", "1");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"));
        // extra headers sit inside the head, before the blank line
        assert!(text.find("Retry-After").unwrap() < text.find("\r\n\r\n").unwrap());
        let (status, headers, body) = read_response_full(&mut Cursor::new(&wire[..])).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body.as_slice(), &bytes[..]);
        assert!(headers.iter().any(|(k, v)| k == "retry-after" && v == "1"));
        // 429 carries a real reason phrase
        let resp = Response::error(429, "queue full").with_header("Retry-After", "2");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
    }

    #[test]
    fn error_envelope_and_reason_phrases() {
        let resp = Response::error(404, "no such job");
        let mut wire = Vec::new();
        resp.write_to(&mut wire).unwrap();
        let text = String::from_utf8(wire).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.contains(r#"{"error":"no such job"}"#));
        assert_eq!(reason_phrase(503), "Service Unavailable");
        assert_eq!(reason_phrase(999), "Status");
    }
}

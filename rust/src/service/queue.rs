//! Shared, cost-prioritized work queue for the sweep service.
//!
//! Cells are ordered heaviest-first so the most expensive runs start
//! earliest and stragglers don't tail the sweep (the classic LPT
//! heuristic).  The cost estimate multiplies the scheme's datapath
//! bits (wider datapaths cost proportionally more in the simulated
//! fixed-point pipeline) by the model's MAC count from the
//! architecture-geometry zoo ([`crate::models`]) and the step count —
//! a deliberate *ranking* proxy, not a clock model.
//!
//! Persistence is job-level, not item-level: submitted jobs land as
//! files under `<store>/jobs/` and completed cells in the run store,
//! so a restarted service re-registers every job and re-queues exactly
//! the cells the store can't serve.  The in-memory queue itself is a
//! `Mutex<Vec>` + `Condvar` — workers block in [`WorkQueue::pop`];
//! [`WorkQueue::close`] drains (pops continue until empty), while
//! [`WorkQueue::clear_and_close`] aborts pending work immediately.

use std::sync::{Condvar, Mutex};

use crate::coordinator::GridCell;
use crate::scheme::QuantScheme;

/// Estimated relative cost of one cell: `steps × datapath bits ×
/// model MMACs`, saturating.  Unknown models (e.g. the reduced
/// trainable manifest variants, which have no zoo geometry) count as
/// 1 MMAC, so their cells still order by bits × steps.
pub fn cell_cost(model: &str, scheme: &QuantScheme, steps: u64) -> u64 {
    let bits = scheme.weights.datapath_bits()
        + scheme.activations.datapath_bits()
        + scheme.gradients.datapath_bits();
    let mmacs = crate::models::by_name(model)
        .map(|layers| {
            layers
                .iter()
                .map(|l| l.macs())
                .fold(0u64, |acc, m| acc.saturating_add(m))
                / 1_000_000
        })
        .unwrap_or(0)
        .max(1);
    steps.max(1).saturating_mul(bits).saturating_mul(mmacs)
}

/// One queued unit of work: a grid cell owned by a job.
#[derive(Debug, Clone)]
pub struct QueueItem {
    /// id of the job this cell belongs to
    pub job: String,
    pub cell: GridCell,
    /// precomputed [`cell_cost`] priority (higher pops first)
    pub cost: u64,
}

#[derive(Debug, Default)]
struct QueueState {
    items: Vec<QueueItem>,
    /// false once closed: pushes are refused and (after the drain)
    /// pops return `None` instead of blocking
    open: bool,
}

/// Why [`WorkQueue::try_push`] refused a batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PushError {
    /// Admitting the batch would exceed the pending-cell capacity; the
    /// batch was dropped whole (jobs are all-or-nothing).  Carries the
    /// observed backlog so the service can shape its 429 answer.
    Full { capacity: usize, pending: usize },
    /// The queue is closed (shutdown).
    Closed,
}

/// A blocking, cost-prioritized multi-producer multi-consumer queue,
/// optionally bounded (backpressure: a full queue refuses whole
/// batches instead of growing without limit under submission floods).
#[derive(Debug)]
pub struct WorkQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
    /// maximum pending items ([`usize::MAX`] = unbounded)
    capacity: usize,
}

impl Default for WorkQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl WorkQueue {
    pub fn new() -> Self {
        Self::bounded(usize::MAX)
    }

    /// A queue refusing batches that would push the pending count past
    /// `capacity` (0 is clamped to 1 so a lone job can always queue).
    pub fn bounded(capacity: usize) -> Self {
        Self {
            state: Mutex::new(QueueState { items: Vec::new(), open: true }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Pending-cell capacity ([`usize::MAX`] = unbounded).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue items; returns `false` (dropping them) once closed.
    /// Unbounded compatibility wrapper over [`WorkQueue::try_push`] —
    /// capacity overflows are still refused, but indistinguishable
    /// from a closed queue here.
    pub fn push(&self, items: Vec<QueueItem>) -> bool {
        self.try_push(items).is_ok()
    }

    /// Enqueue a batch all-or-nothing: refused with
    /// [`PushError::Full`] when it would exceed capacity, or
    /// [`PushError::Closed`] after shutdown.
    pub fn try_push(&self, items: Vec<QueueItem>) -> Result<(), PushError> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if !st.open {
            return Err(PushError::Closed);
        }
        let pending = st.items.len();
        if pending + items.len() > self.capacity {
            return Err(PushError::Full { capacity: self.capacity, pending });
        }
        st.items.extend(items);
        drop(st);
        self.ready.notify_all();
        Ok(())
    }

    /// Remove every still-queued item of `job` (cancellation: running
    /// cells are unaffected) and return them.
    pub fn remove_job(&self, job: &str) -> Vec<QueueItem> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        let mut dropped = Vec::new();
        let mut i = 0;
        while i < st.items.len() {
            if st.items[i].job == job {
                dropped.push(st.items.swap_remove(i));
            } else {
                i += 1;
            }
        }
        drop(st);
        // capacity may have freed up; nothing blocks on that today,
        // but waking poppers keeps close() semantics prompt
        self.ready.notify_all();
        dropped
    }

    /// Block until an item is available (heaviest first; ties break by
    /// `(job, cell index)` so the order is deterministic), or return
    /// `None` once the queue is closed and drained.
    pub fn pop(&self) -> Option<QueueItem> {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(best) = Self::best_index(&st.items) {
                return Some(st.items.swap_remove(best));
            }
            if !st.open {
                return None;
            }
            st = self.ready.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn best_index(items: &[QueueItem]) -> Option<usize> {
        items
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| {
                a.cost
                    .cmp(&b.cost)
                    // reversed: *lower* (job, index) wins a cost tie
                    .then_with(|| b.job.cmp(&a.job))
                    .then_with(|| b.cell.index.cmp(&a.cell.index))
            })
            .map(|(i, _)| i)
    }

    /// Stop accepting work but let workers drain what's queued.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.open = false;
        drop(st);
        self.ready.notify_all();
    }

    /// Abort: discard queued items and close.
    pub fn clear_and_close(&self) {
        let mut st = self.state.lock().unwrap_or_else(|e| e.into_inner());
        st.items.clear();
        st.open = false;
        drop(st);
        self.ready.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap_or_else(|e| e.into_inner()).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once `close`/`clear_and_close` has been called.
    pub fn is_closed(&self) -> bool {
        !self.state.lock().unwrap_or_else(|e| e.into_inner()).open
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{GridSpec, TrainConfig};

    fn items(job: &str, template: &str, steps: u64) -> Vec<QueueItem> {
        let mut base = TrainConfig::new("mlp");
        base.steps = steps;
        let spec = GridSpec::new(template, &[1]).unwrap();
        spec.expand(&base)
            .into_iter()
            .map(|cell| {
                let cost = cell_cost(&cell.cfg.model, &cell.cfg.scheme, cell.cfg.steps);
                QueueItem { job: job.into(), cell, cost }
            })
            .collect()
    }

    #[test]
    fn cost_scales_with_bits_model_and_steps() {
        let spec = GridSpec::new("g:{hindsight}:{4,8}", &[1]).unwrap();
        let narrow = &spec.schemes()[0];
        let wide = &spec.schemes()[1];
        assert!(
            cell_cost("mlp", wide, 100) > cell_cost("mlp", narrow, 100),
            "wider gradient datapath must cost more"
        );
        assert!(cell_cost("mlp", wide, 200) > cell_cost("mlp", wide, 100));
        // a zoo model with real GMACs dominates the unknown-model floor
        assert!(cell_cost("resnet18", wide, 100) > cell_cost("mlp", wide, 100));
        // vgg16 is the heaviest zoo entry; ordering must reflect it
        assert!(cell_cost("vgg16", wide, 100) > cell_cost("mobilenet_v2", wide, 100));
    }

    #[test]
    fn pop_orders_heaviest_first_with_deterministic_ties() {
        let q = WorkQueue::new();
        // 4-bit and 8-bit gradient cells: 8-bit must pop first
        assert!(q.push(items("job-a", "g:{hindsight,current}:{4,8}", 10)));
        let first = q.pop().unwrap();
        let second = q.pop().unwrap();
        assert!(first.cost >= second.cost);
        assert!(first.cell.label.contains(":8"), "heaviest (8-bit) first: {}", first.cell.label);
        // ties (same cost) break by lowest (job, cell index)
        let q = WorkQueue::new();
        let mut batch = items("job-b", "g:{hindsight,current}:8", 10);
        batch.extend(items("job-a", "g:{hindsight,current}:8", 10));
        q.push(batch);
        let order: Vec<(String, usize)> =
            std::iter::from_fn(|| {
                if q.is_empty() {
                    None
                } else {
                    q.pop().map(|it| (it.job, it.cell.index))
                }
            })
            .collect();
        assert_eq!(
            order,
            vec![
                ("job-a".to_string(), 0),
                ("job-a".to_string(), 1),
                ("job-b".to_string(), 0),
                ("job-b".to_string(), 1),
            ]
        );
    }

    #[test]
    fn pop_blocks_until_push_and_close_drains() {
        let q = std::sync::Arc::new(WorkQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(it) = q2.pop() {
                got.push(it.cell.label.clone());
            }
            got
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        q.push(items("j", "g:{hindsight,current}:8", 10));
        std::thread::sleep(std::time::Duration::from_millis(30));
        q.close();
        let got = h.join().unwrap();
        assert_eq!(got.len(), 2, "close must drain queued items first");
        assert!(!q.push(items("j", "g:tqt:8", 10)), "closed queue refuses pushes");
    }

    #[test]
    fn bounded_queue_refuses_whole_batches_past_capacity() {
        let q = WorkQueue::bounded(3);
        assert_eq!(q.capacity(), 3);
        // 2 cells fit
        assert!(q.try_push(items("a", "g:{hindsight,current}:8", 10)).is_ok());
        // 2 more would make 4 > 3: refused whole, nothing partial
        let err = q.try_push(items("b", "g:{hindsight,current}:8", 10)).unwrap_err();
        assert_eq!(err, PushError::Full { capacity: 3, pending: 2 });
        assert_eq!(q.len(), 2, "refused batch must not partially enqueue");
        // a 1-cell batch still fits
        assert!(q.try_push(items("c", "g:tqt:8", 10)).is_ok());
        assert_eq!(q.len(), 3);
        // drained capacity admits new work again
        let _ = q.pop().unwrap();
        assert!(q.try_push(items("d", "g:tqt:8", 10)).is_ok());
        q.close();
        assert_eq!(q.try_push(items("e", "g:tqt:8", 10)).unwrap_err(), PushError::Closed);
    }

    #[test]
    fn remove_job_drops_only_that_jobs_queued_cells() {
        let q = WorkQueue::new();
        q.push(items("keep", "g:{hindsight,current}:8", 10));
        q.push(items("cancel", "g:{hindsight,current,tqt}:8", 10));
        assert_eq!(q.len(), 5);
        let dropped = q.remove_job("cancel");
        assert_eq!(dropped.len(), 3);
        assert!(dropped.iter().all(|it| it.job == "cancel"));
        assert_eq!(q.len(), 2);
        while !q.is_empty() {
            assert_eq!(q.pop().unwrap().job, "keep");
        }
        assert_eq!(q.remove_job("cancel").len(), 0, "idempotent on empty");
    }

    #[test]
    fn clear_and_close_aborts_pending_work() {
        let q = WorkQueue::new();
        q.push(items("j", "g:{hindsight,current,tqt}:8", 10));
        assert_eq!(q.len(), 3);
        q.clear_and_close();
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        assert!(q.is_closed());
    }
}

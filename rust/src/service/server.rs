//! The sweep service: a long-running HTTP front end over the grid
//! executor and the resumable run store.
//!
//! One process = one shard ([`ShardSpec`]).  Submitted jobs (a grid
//! template + model + seeds + steps) are expanded through
//! [`GridSpec`], the shard's claimed cells are queued heaviest-first
//! on the [`WorkQueue`], and worker threads execute them with
//! write-through to the shared [`RunStore`] — exactly the executor's
//! cache discipline, so service results are bit-identical to a serial
//! `run_grid` of the same grid.  Jobs persist as `job-<id>.json` files
//! under `<store>/jobs/`; sibling shards discover them by polling that
//! directory, so N processes pointed at one store split a grid with no
//! coordinator.  Completion of *foreign* cells (owned by another
//! shard) is observed through the store via [`RunStore::refresh`].
//!
//! Endpoints (all JSON; see rust/README.md for curl examples):
//!
//! * `POST /jobs` — submit `{"grid", "model", "seeds", "steps"}`;
//!   202 on first submission, 200 (same id) on resubmission, 429 with
//!   a `Retry-After` hint when the bounded pending-cell queue is full.
//! * `GET /jobs` — all known jobs with progress counts.
//! * `GET /jobs/<id>` — one job's progress.
//! * `GET /jobs/<id>/results` — per-scheme `grid_rows` aggregation
//!   plus per-cell records; 409 until every cell is in the store.
//!   Served through the parse-once/serve-many path: cell documents
//!   come from the store's doc cache and the assembled body is cached
//!   per job, so a repeat GET over an unchanged store re-sends the
//!   same shared bytes — zero JSON parses, zero tree serializations.
//! * `POST /jobs/<id>/cancel` — drop the job's still-queued cells
//!   (running cells finish; `cancelled` counts in the status doc).
//! * `GET /cells` — the store's cell index (cache inspection).
//! * `GET /healthz` — liveness + shard identity + read-path counters.
//! * `POST /shutdown` — `{"drain": true}` finishes queued work first;
//!   `{"drain": false}` aborts queued cells.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::executor::panic_message;
use crate::coordinator::store::fnv1a64;
use crate::coordinator::{
    format_seeds, grid_rows, parse_seeds, CellKey, CellOutcome, CellRun, GridCell, GridSpec,
    RunStore, TrainConfig, Trainer,
};
use crate::metrics::RunRecord;
use crate::runtime::engine::Engine;
use crate::service::protocol::{read_request, Request, Response};
use crate::service::queue::{cell_cost, PushError, QueueItem, WorkQueue};
use crate::service::shard::ShardSpec;
use crate::util::json::{self, Value};

/// How a worker turns a claimed cell into a [`RunRecord`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellRunner {
    /// real training through the PJRT engine (needs artifacts)
    Engine,
    /// deterministic synthetic records (tests, CI smoke, benches)
    Synthetic,
}

/// The synthetic cell record: shared by the service, its tests and the
/// benches so "bit-identical to a serial run" is checkable without
/// artifacts.  Must stay in lockstep with the grid benches' runner.
pub fn synthetic_cell_record(cell: &GridCell) -> RunRecord {
    RunRecord::synthetic(&cell.label, cell.cfg.steps)
}

/// A submitted sweep: the JSON body of `POST /jobs`.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// grid template, e.g. `g:{hindsight,current,tqt,banner}@{pt,pc}:{4,8}`
    pub grid: String,
    /// model name (default `mlp`)
    pub model: String,
    /// seed axis (default `[1]`)
    pub seeds: Vec<u64>,
    /// training steps per cell (default: the model config's default)
    pub steps: Option<u64>,
}

/// 2^53, the first integer whose f64 neighborhood is ambiguous: the
/// JSON number 2^53+1 rounds to exactly 2^53 in the f64 parse, so a
/// numeric seed at or above this may already have lost precision and
/// is rejected toward the exact string form (strictly below it, every
/// integer is uniquely representable).
const MAX_EXACT_SEED: f64 = 9_007_199_254_740_992.0;

/// One seed or step count out of a submission body: a checked integral
/// number (integral, non-negative, below 2^53) or an exact decimal
/// string.
fn job_u64(x: &Value, what: &str) -> Result<u64> {
    if let Some(s) = x.as_str() {
        return s
            .trim()
            .parse::<u64>()
            .with_context(|| format!("bad {what} '{s}'"));
    }
    let f = x
        .as_f64()
        .with_context(|| format!("{what} must be an integer or a decimal string"))?;
    let n = json::f64_to_u64(f)
        .with_context(|| format!("{what} {f} is not a non-negative integer"))?;
    if f >= MAX_EXACT_SEED {
        bail!(
            "numeric {what} {n} exceeds 2^53 and may have lost precision as a \
             JSON number — pass it as a decimal string (\"{n}\") instead"
        );
    }
    Ok(n)
}

impl JobSpec {
    /// Parse a submission body.  `seeds` accepts a JSON array (numbers
    /// below 2^53 or exact decimal strings) and the CLI string form
    /// (`"1..5"`); non-integral or precision-losing numbers are
    /// rejected rather than truncated.
    pub fn from_json(v: &Value) -> Result<Self> {
        let grid = v
            .get("grid")
            .and_then(|g| g.as_str())
            .context("submission needs a string 'grid' template")?
            .to_string();
        let model = v
            .get("model")
            .and_then(|m| m.as_str())
            .unwrap_or("mlp")
            .to_string();
        let seeds = match v.get("seeds") {
            None => vec![1],
            Some(Value::Str(s)) => parse_seeds(s)?,
            Some(Value::Array(a)) => {
                if a.is_empty() {
                    bail!("'seeds' array is empty — pass at least one seed");
                }
                a.iter()
                    .map(|x| job_u64(x, "seed"))
                    .collect::<Result<Vec<u64>>>()?
            }
            Some(_) => bail!("'seeds' must be an array or a range string"),
        };
        let steps = match v.get("steps") {
            None => None,
            Some(x) => Some(job_u64(x, "steps")?),
        };
        Ok(Self { grid, model, seeds, steps })
    }

    /// The persisted `job-<id>.json` form.  Seeds serialize as the CLI
    /// range string ([`format_seeds`]) — exact for all of `u64`, where
    /// the old `Num(s as f64)` array rounded seeds ≥ 2^53 and sibling
    /// shards re-expanded different cell keys.
    pub fn to_json(&self) -> Value {
        let mut kv = vec![
            ("grid", Value::from(self.grid.clone())),
            ("model", Value::from(self.model.clone())),
            ("seeds", Value::Str(format_seeds(&self.seeds))),
        ];
        if let Some(steps) = self.steps {
            kv.push(("steps", json::u64_value(steps)));
        }
        Value::object(kv)
    }

    /// Content-derived job id (16 hex chars): identical submissions
    /// map to the same job, so `POST /jobs` is idempotent.
    pub fn id(&self) -> String {
        let seeds: Vec<String> = self.seeds.iter().map(|s| s.to_string()).collect();
        let flat = format!(
            "{}|{}|{}|{}",
            self.grid,
            self.model,
            seeds.join(","),
            self.steps.map(|s| s.to_string()).unwrap_or_default()
        );
        format!("{:016x}", fnv1a64(flat.as_bytes()))
    }

    /// The base config the grid expands over.
    pub fn base_config(&self) -> TrainConfig {
        let mut cfg = TrainConfig::new(&self.model);
        if let Some(steps) = self.steps {
            cfg.steps = steps;
        }
        cfg
    }

    /// Expand into grid cells (validates the template and seeds).
    pub fn expand(&self) -> Result<Vec<GridCell>> {
        let spec = GridSpec::new(&self.grid, &self.seeds)?;
        Ok(spec.expand(&self.base_config()))
    }
}

/// Where this process stands on one cell of a job.
#[derive(Debug, Clone, PartialEq)]
enum LocalState {
    /// another shard owns this cell; we watch the store for it
    Foreign,
    Queued,
    Running,
    /// executed here this session
    Ran,
    /// served from the store (registration pre-pass or late check)
    Cached,
    /// dropped from the queue by `POST /jobs/<id>/cancel` before running
    Cancelled,
    Failed(String),
}

#[derive(Debug)]
struct JobState {
    spec: JobSpec,
    cells: Vec<GridCell>,
    /// indexed by dense grid index, parallel to `cells`
    local: Vec<LocalState>,
}

/// One job's cached `GET /jobs/<id>/results` body.  `sig` fingerprints
/// the exact store state the bytes were assembled from (job id + every
/// cell document's stat fingerprint): any rewrite of a cell file
/// changes its fingerprint, so a stale body can never be replayed.
struct CachedResults {
    sig: u64,
    body: Arc<[u8]>,
}

/// State shared between the accept loop, workers, poller and handlers.
struct Shared {
    store: RunStore,
    jobs_dir: PathBuf,
    shard: ShardSpec,
    runner: CellRunner,
    queue: WorkQueue,
    jobs: Mutex<HashMap<String, JobState>>,
    /// per-job results bodies for the serve-many path
    results: Mutex<HashMap<String, CachedResults>>,
    /// results GETs that assembled a fresh body / re-sent cached bytes
    results_cold: AtomicU64,
    results_warm: AtomicU64,
    /// artificial per-cell latency for the synthetic runner (tests)
    synthetic_delay_ms: u64,
    /// cells executed (not cache-served) by this process
    executed: AtomicUsize,
    /// workers currently inside a cell
    active: AtomicUsize,
    draining: AtomicBool,
    stop: AtomicBool,
}

/// What `POST /jobs` resolved to.
enum SubmitOutcome {
    /// first registration of this id
    Created(String),
    /// idempotent re-submission of a known id
    Known(String),
    /// the bounded queue could not take the job's cells — nothing was
    /// registered or persisted; the client should retry later
    Busy { pending: usize, capacity: usize },
}

impl Shared {
    /// Register a job: expand, cache pre-pass over claimed cells,
    /// queue the rest, persist the job file.  Re-registration of a
    /// known id is a no-op; a full queue rejects the whole job
    /// ([`SubmitOutcome::Busy`]) without registering or persisting it.
    fn register_job(&self, spec: JobSpec) -> Result<SubmitOutcome> {
        let id = spec.id();
        {
            let jobs = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
            if jobs.contains_key(&id) {
                return Ok(SubmitOutcome::Known(id));
            }
        }
        let cells = spec.expand()?;
        let mut local = Vec::with_capacity(cells.len());
        let mut items = Vec::new();
        for cell in &cells {
            if !self.shard.claims(cell.index) {
                local.push(LocalState::Foreign);
            } else if self.store.get(&CellKey::of(&cell.cfg)).is_some() {
                local.push(LocalState::Cached);
            } else {
                local.push(LocalState::Queued);
                items.push(QueueItem {
                    job: id.clone(),
                    cell: cell.clone(),
                    cost: cell_cost(&cell.cfg.model, &cell.cfg.scheme, cell.cfg.steps),
                });
            }
        }
        {
            let mut jobs = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
            // a concurrent submit of the same spec may have won the race
            if jobs.contains_key(&id) {
                return Ok(SubmitOutcome::Known(id));
            }
            jobs.insert(id.clone(), JobState { spec: spec.clone(), cells, local });
        }
        if !items.is_empty() {
            match self.queue.try_push(items) {
                Ok(()) => {}
                Err(PushError::Full { capacity, pending }) => {
                    // all-or-nothing: none of the job's cells entered
                    // the queue, so dropping the entry fully undoes the
                    // registration (no worker can be holding a cell)
                    self.jobs.lock().unwrap_or_else(|e| e.into_inner()).remove(&id);
                    return Ok(SubmitOutcome::Busy { pending, capacity });
                }
                Err(PushError::Closed) => {
                    let mut jobs = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
                    if let Some(job) = jobs.get_mut(&id) {
                        for st in job.local.iter_mut() {
                            if *st == LocalState::Queued {
                                *st = LocalState::Failed("queue closed".into());
                            }
                        }
                    }
                }
            }
        }
        self.persist_job_file(&id, &spec);
        Ok(SubmitOutcome::Created(id))
    }

    /// Write `job-<id>.json` (atomic tmp + rename) unless present.
    fn persist_job_file(&self, id: &str, spec: &JobSpec) {
        let path = self.jobs_dir.join(format!("job-{id}.json"));
        if path.exists() {
            return;
        }
        let tmp = self
            .jobs_dir
            .join(format!(".tmp-{}-job-{id}.json", std::process::id()));
        let write = std::fs::write(&tmp, format!("{}\n", spec.to_json()))
            .and_then(|_| std::fs::rename(&tmp, &path));
        if let Err(e) = write {
            log::warn!("serve: could not persist job file {}: {e:#}", path.display());
        }
    }

    /// Scan `<store>/jobs/` and register any job this process doesn't
    /// know yet (startup recovery + cross-shard job discovery).
    fn register_jobs_from_dir(&self) {
        let Ok(rd) = std::fs::read_dir(&self.jobs_dir) else {
            return;
        };
        for e in rd.filter_map(|e| e.ok()) {
            let name = e.file_name().to_string_lossy().into_owned();
            let Some(id) = name.strip_prefix("job-").and_then(|n| n.strip_suffix(".json"))
            else {
                continue;
            };
            let known = self
                .jobs
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .contains_key(id);
            if known {
                continue;
            }
            let spec = std::fs::read_to_string(e.path())
                .map_err(anyhow::Error::from)
                .and_then(|text| {
                    crate::util::json::parse(&text)
                        .map_err(anyhow::Error::from)
                        .and_then(|v| JobSpec::from_json(&v))
                });
            match spec.and_then(|spec| self.register_job(spec)) {
                // Busy: the job file stays put; the next poll retries
                // once the queue has drained below its capacity
                Ok(SubmitOutcome::Busy { pending, capacity }) => {
                    log::debug!(
                        "serve: job file {name} deferred: queue full ({pending}/{capacity})"
                    );
                }
                Ok(_) => {}
                Err(err) => log::warn!("serve: job file {name} failed to register: {err:#}"),
            }
        }
    }

    fn set_state(&self, job: &str, grid_index: usize, st: LocalState) {
        let mut jobs = self.jobs.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(j) = jobs.get_mut(job) {
            if let Some(slot) = j.local.get_mut(grid_index) {
                *slot = st;
            }
        }
    }

    /// One worker: pop-execute-store until the queue closes and drains.
    fn worker_loop(&self) {
        let mut engine: Option<Engine> = None;
        while let Some(item) = self.queue.pop() {
            self.active.fetch_add(1, Ordering::SeqCst);
            let key = CellKey::of(&item.cell.cfg);
            // late cache check: another shard (or an earlier failure's
            // retry) may have stored this cell since registration
            if self.store.get(&key).is_some() {
                self.set_state(&item.job, item.cell.index, LocalState::Cached);
                self.active.fetch_sub(1, Ordering::SeqCst);
                continue;
            }
            self.set_state(&item.job, item.cell.index, LocalState::Running);
            let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                run_one_cell(self.runner, &mut engine, &item.cell, self.synthetic_delay_ms)
            }));
            let state = match outcome {
                Ok(Ok(record)) => {
                    if let Err(e) = self.store.put(&key, &record) {
                        log::warn!("serve: store write for '{}' failed: {e:#}", item.cell.label);
                    }
                    self.executed.fetch_add(1, Ordering::SeqCst);
                    LocalState::Ran
                }
                Ok(Err(e)) => LocalState::Failed(format!("{e:#}")),
                Err(p) => LocalState::Failed(format!("panicked: {}", panic_message(&*p))),
            };
            self.set_state(&item.job, item.cell.index, state);
            self.active.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Execute one claimed cell under the configured runner.
fn run_one_cell(
    runner: CellRunner,
    engine: &mut Option<Engine>,
    cell: &GridCell,
    synthetic_delay_ms: u64,
) -> Result<RunRecord> {
    match runner {
        CellRunner::Synthetic => {
            // lets the cancellation/backpressure tests hold cells
            // in-flight deterministically; 0 (the default) is free
            if synthetic_delay_ms > 0 {
                std::thread::sleep(Duration::from_millis(synthetic_delay_ms));
            }
            Ok(synthetic_cell_record(cell))
        }
        CellRunner::Engine => {
            if engine.is_none() {
                *engine = Some(Engine::new().context("creating worker engine")?);
            }
            Trainer::new(engine.as_ref().expect("just created"), cell.cfg.clone())?.run()
        }
    }
}

/// Configuration of one service process.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// bind address, e.g. `127.0.0.1:8080` (`:0` = ephemeral port)
    pub addr: String,
    /// worker threads executing cells
    pub workers: usize,
    /// shared run-store directory (job files land in `<dir>/jobs/`)
    pub store_dir: PathBuf,
    pub shard: ShardSpec,
    pub runner: CellRunner,
    /// job-directory poll cadence for cross-shard discovery
    pub poll_ms: u64,
    /// pending-cell bound: a submission that would push past this many
    /// queued cells gets 429 (`usize::MAX` = unbounded)
    pub queue_cap: usize,
    /// artificial synthetic-runner latency per cell (tests only)
    pub synthetic_delay_ms: u64,
}

/// A bound (not yet running) service.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    workers: usize,
    poll_ms: u64,
}

impl Server {
    /// Bind the listener and open the store; `run` starts serving.
    pub fn bind(opts: ServeOptions) -> Result<Self> {
        let store = RunStore::open(&opts.store_dir)?;
        let jobs_dir = opts.store_dir.join("jobs");
        std::fs::create_dir_all(&jobs_dir)
            .with_context(|| format!("creating jobs dir {}", jobs_dir.display()))?;
        let listener = TcpListener::bind(&opts.addr)
            .with_context(|| format!("binding {}", opts.addr))?;
        // nonblocking accept so the loop can watch the shutdown flags
        listener.set_nonblocking(true).context("setting nonblocking accept")?;
        Ok(Self {
            listener,
            shared: Arc::new(Shared {
                store,
                jobs_dir,
                shard: opts.shard,
                runner: opts.runner,
                queue: WorkQueue::bounded(opts.queue_cap),
                jobs: Mutex::new(HashMap::new()),
                results: Mutex::new(HashMap::new()),
                results_cold: AtomicU64::new(0),
                results_warm: AtomicU64::new(0),
                synthetic_delay_ms: opts.synthetic_delay_ms,
                executed: AtomicUsize::new(0),
                active: AtomicUsize::new(0),
                draining: AtomicBool::new(false),
                stop: AtomicBool::new(false),
            }),
            workers: opts.workers.max(1),
            poll_ms: opts.poll_ms.max(10),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until drained (`POST /shutdown`): accept loop + workers +
    /// the job-directory poller.  Returns once all in-flight work has
    /// finished and every thread has joined.
    pub fn run(self) -> Result<()> {
        if self.shared.runner == CellRunner::Engine {
            crate::runtime::engine::ensure_default_xla_flags();
        }
        // the fused kernels' chunked-parallel backend splits threads
        // with the executor; tell it how many workers surround it
        let _guard = crate::quant::kernel::parallel::external_parallelism_guard(self.workers);
        self.shared.register_jobs_from_dir();
        let workers: Vec<_> = (0..self.workers)
            .map(|_| {
                let shared = self.shared.clone();
                std::thread::spawn(move || shared.worker_loop())
            })
            .collect();
        let poller = {
            let shared = self.shared.clone();
            let poll_ms = self.poll_ms;
            std::thread::spawn(move || {
                while !shared.stop.load(Ordering::SeqCst)
                    && !shared.draining.load(Ordering::SeqCst)
                    && !shared.queue.is_closed()
                {
                    std::thread::sleep(Duration::from_millis(poll_ms));
                    shared.register_jobs_from_dir();
                }
            })
        };
        loop {
            if self.shared.stop.load(Ordering::SeqCst) {
                break;
            }
            if self.shared.draining.load(Ordering::SeqCst)
                && self.shared.queue.is_empty()
                && self.shared.active.load(Ordering::SeqCst) == 0
            {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let shared = self.shared.clone();
                    std::thread::spawn(move || handle_conn(stream, &shared));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => {
                    log::warn!("serve: accept failed: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }
        // release any still-blocked workers, then wait for in-flight
        // cells: run() returning means the store is fully written
        self.shared.queue.close();
        for w in workers {
            let _ = w.join();
        }
        let _ = poller.join();
        Ok(())
    }
}

/// Serve one connection (one request: `Connection: close` semantics).
fn handle_conn(mut stream: TcpStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
    let response = match read_request(&mut stream) {
        Ok(req) => route(&req, shared),
        Err(e) => Response::error(400, &format!("{e:#}")),
    };
    if let Err(e) = response.write_to(&mut stream) {
        log::debug!("serve: response write failed: {e:#}");
    }
}

fn route(req: &Request, shared: &Shared) -> Response {
    let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => healthz(shared),
        ("POST", ["jobs"]) => submit(req, shared),
        ("GET", ["jobs"]) => list_jobs(shared),
        ("GET", ["jobs", id]) => job_status(shared, id),
        ("GET", ["jobs", id, "results"]) => job_results(shared, id),
        ("POST", ["jobs", id, "cancel"]) => cancel_job(shared, id),
        ("GET", ["cells"]) => cells(shared),
        ("POST", ["shutdown"]) => shutdown(req, shared),
        ("GET", _) | ("POST", _) => Response::error(404, &format!("no route for {}", req.path)),
        _ => Response::error(405, &format!("method {} not allowed", req.method)),
    }
}

fn healthz(shared: &Shared) -> Response {
    let jobs = shared.jobs.lock().unwrap_or_else(|e| e.into_inner()).len();
    let cap = shared.queue.capacity();
    let queue_cap = if cap == usize::MAX { Value::Null } else { Value::from(cap) };
    Response::json(
        200,
        &Value::object(vec![
            ("status", Value::from("ok")),
            ("shard", Value::from(shared.shard.to_string())),
            ("jobs", Value::from(jobs)),
            ("queue", Value::from(shared.queue.len())),
            ("queue_cap", queue_cap),
            ("active", Value::from(shared.active.load(Ordering::SeqCst))),
            ("executed", Value::from(shared.executed.load(Ordering::SeqCst))),
            ("draining", Value::from(shared.draining.load(Ordering::SeqCst))),
            // read-path instrumentation: the parse-once/serve-many
            // proof the e2e tests and serve_http bench assert against
            ("doc_parses", Value::Num(shared.store.doc_parses() as f64)),
            ("doc_hits", Value::Num(shared.store.doc_hits() as f64)),
            ("results_cold", Value::Num(shared.results_cold.load(Ordering::SeqCst) as f64)),
            ("results_warm", Value::Num(shared.results_warm.load(Ordering::SeqCst) as f64)),
        ]),
    )
}

fn submit(req: &Request, shared: &Shared) -> Response {
    if shared.draining.load(Ordering::SeqCst) || shared.stop.load(Ordering::SeqCst) {
        return Response::error(503, "shutting down: not accepting submissions");
    }
    let spec = match req.json().and_then(|v| JobSpec::from_json(&v)) {
        Ok(spec) => spec,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    match shared.register_job(spec) {
        Ok(SubmitOutcome::Created(id)) => match status_doc(shared, &id) {
            Some(doc) => Response::json(202, &doc),
            None => Response::error(500, "job vanished during registration"),
        },
        Ok(SubmitOutcome::Known(id)) => match status_doc(shared, &id) {
            Some(doc) => Response::json(200, &doc),
            None => Response::error(500, "job vanished during registration"),
        },
        Ok(SubmitOutcome::Busy { pending, capacity }) => Response::error(
            429,
            &format!("queue full ({pending}/{capacity} cells pending): retry later"),
        )
        .with_header("Retry-After", "1"),
        Err(e) => Response::error(400, &format!("{e:#}")),
    }
}

fn list_jobs(shared: &Shared) -> Response {
    let ids: Vec<String> = {
        let jobs = shared.jobs.lock().unwrap_or_else(|e| e.into_inner());
        let mut ids: Vec<String> = jobs.keys().cloned().collect();
        ids.sort();
        ids
    };
    shared.store.refresh();
    let docs: Vec<Value> = ids.iter().filter_map(|id| status_doc(shared, id)).collect();
    Response::json(
        200,
        &Value::object(vec![
            ("count", Value::from(docs.len())),
            ("jobs", Value::Array(docs)),
        ]),
    )
}

fn job_status(shared: &Shared, id: &str) -> Response {
    // foreign cells complete through the store: pick up sibling writes
    shared.store.refresh();
    match status_doc(shared, id) {
        Some(doc) => Response::json(200, &doc),
        None => Response::error(404, &format!("no job '{id}'")),
    }
}

/// Build one job's status document (None = unknown id).
fn status_doc(shared: &Shared, id: &str) -> Option<Value> {
    let jobs = shared.jobs.lock().unwrap_or_else(|e| e.into_inner());
    let job = jobs.get(id)?;
    let total = job.cells.len();
    let (mut queued, mut running, mut ran, mut cached, mut failed) = (0, 0, 0, 0, 0);
    let (mut stored, mut pending, mut cancelled) = (0, 0, 0);
    for (cell, st) in job.cells.iter().zip(&job.local) {
        match st {
            LocalState::Queued => queued += 1,
            LocalState::Running => running += 1,
            LocalState::Ran => ran += 1,
            LocalState::Cached => cached += 1,
            LocalState::Cancelled => cancelled += 1,
            LocalState::Failed(_) => failed += 1,
            LocalState::Foreign => {
                if shared.store.get(&CellKey::of(&cell.cfg)).is_some() {
                    stored += 1;
                } else {
                    pending += 1;
                }
            }
        }
    }
    let done = ran + cached + stored;
    let failures: Vec<Value> = job
        .cells
        .iter()
        .zip(&job.local)
        .filter_map(|(cell, st)| match st {
            LocalState::Failed(e) => Some(Value::object(vec![
                ("label", Value::from(cell.label.clone())),
                ("error", Value::from(e.clone())),
            ])),
            _ => None,
        })
        .collect();
    Some(Value::object(vec![
        ("job", Value::from(id)),
        ("grid", Value::from(job.spec.grid.clone())),
        ("model", Value::from(job.spec.model.clone())),
        ("shard", Value::from(shared.shard.to_string())),
        ("total", Value::from(total)),
        ("claimed", Value::from(total - (stored + pending))),
        ("queued", Value::from(queued)),
        ("running", Value::from(running)),
        ("ran", Value::from(ran)),
        ("cached", Value::from(cached)),
        ("stored", Value::from(stored)),
        ("pending", Value::from(pending)),
        ("cancelled", Value::from(cancelled)),
        ("failed", Value::from(failed)),
        ("done", Value::from(done)),
        ("complete", Value::from(done == total)),
        ("executed", Value::from(shared.executed.load(Ordering::SeqCst))),
        ("failures", Value::Array(failures)),
    ]))
}

/// `GET /jobs/<id>/results` — the parse-once/serve-many hot path.
///
/// Every cell document comes from the store's doc cache
/// ([`RunStore::get_doc`]): a cell file is parsed at most once per
/// process lifetime, and its canonical `record` serialization rides
/// along as pre-rendered bytes.  The response body is assembled by
/// concatenating those slices — byte-identical to serializing the
/// equivalent `Value` tree, because the canonical serializer is
/// compositional (no whitespace, insertion-order keys) — and cached
/// per job under a signature of every document's stat fingerprint.
/// A repeat GET over an unchanged store re-sends the same `Arc`'d
/// bytes: zero JSON parses, zero tree serializations.
fn job_results(shared: &Shared, id: &str) -> Response {
    shared.store.refresh();
    let cells: Vec<GridCell> = {
        let jobs = shared.jobs.lock().unwrap_or_else(|e| e.into_inner());
        match jobs.get(id) {
            Some(job) => job.cells.clone(),
            None => return Response::error(404, &format!("no job '{id}'")),
        }
    };
    // every cell must be servable from the shared store — the *merged*
    // result across shards, never just this process's slice
    let mut docs = Vec::with_capacity(cells.len());
    for cell in &cells {
        match shared.store.get_doc(&CellKey::of(&cell.cfg)) {
            Some(doc) => docs.push(doc),
            None => {
                return Response::error(409, &format!("cell '{}' not complete yet", cell.label))
            }
        }
    }
    let mut sig_src = String::from(id);
    for doc in &docs {
        sig_src.push_str(&format!("|{:016x}", doc.fingerprint));
    }
    let sig = fnv1a64(sig_src.as_bytes());
    {
        let cache = shared.results.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(cached) = cache.get(id) {
            if cached.sig == sig {
                shared.results_warm.fetch_add(1, Ordering::SeqCst);
                return Response::json_shared(200, cached.body.clone());
            }
        }
    }
    shared.results_cold.fetch_add(1, Ordering::SeqCst);
    let runs: Vec<CellRun> = cells
        .iter()
        .zip(&docs)
        .map(|(cell, doc)| CellRun {
            index: cell.index,
            label: cell.label.clone(),
            key: doc.key.clone(),
            outcome: CellOutcome::Cached(doc.record.clone()),
        })
        .collect();
    let rows: Vec<Value> = grid_rows(&runs).iter().map(|row| row.to_json()).collect();
    let mut body = String::new();
    body.push_str("{\"job\":");
    json::escape_into(id, &mut body).expect("write to String");
    body.push_str(",\"rows\":");
    // one tree serialization on a cold assembly; the per-cell record
    // bytes below are spliced from the doc cache, never re-rendered
    body.push_str(&Value::Array(rows).to_string());
    body.push_str(",\"cells\":[");
    for (i, (cell, doc)) in cells.iter().zip(&docs).enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str("{\"label\":");
        json::escape_into(&cell.label, &mut body).expect("write to String");
        body.push_str(",\"record\":");
        body.push_str(&doc.record_json);
        body.push('}');
    }
    body.push_str("]}\n");
    let body: Arc<[u8]> = Arc::from(body.into_bytes());
    shared
        .results
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .insert(id.to_string(), CachedResults { sig, body: body.clone() });
    Response::json_shared(200, body)
}

/// `POST /jobs/<id>/cancel` — drop the job's still-queued cells.
/// Running cells finish (their store writes stay valid for siblings);
/// dropped cells report as `cancelled` in the status document, so a
/// cancelled job never reaches `complete` and `/results` stays 409.
/// The job file is removed so restarts and sibling shards don't
/// resurrect the queued work.
fn cancel_job(shared: &Shared, id: &str) -> Response {
    let known = shared.jobs.lock().unwrap_or_else(|e| e.into_inner()).contains_key(id);
    if !known {
        return Response::error(404, &format!("no job '{id}'"));
    }
    let dropped = shared.queue.remove_job(id);
    for item in &dropped {
        shared.set_state(id, item.cell.index, LocalState::Cancelled);
    }
    let path = shared.jobs_dir.join(format!("job-{id}.json"));
    if let Err(e) = std::fs::remove_file(&path) {
        if e.kind() != std::io::ErrorKind::NotFound {
            log::warn!("serve: could not remove job file {}: {e}", path.display());
        }
    }
    match status_doc(shared, id) {
        Some(doc) => Response::json(200, &doc),
        None => Response::error(404, &format!("no job '{id}'")),
    }
}

fn cells(shared: &Shared) -> Response {
    shared.store.refresh();
    let entries: Vec<Value> = shared
        .store
        .entries()
        .into_iter()
        .map(|(file, key_id)| {
            Value::object(vec![
                ("file", Value::from(file)),
                ("id", Value::from(key_id)),
            ])
        })
        .collect();
    Response::json(
        200,
        &Value::object(vec![
            ("count", Value::from(entries.len())),
            ("cells", Value::Array(entries)),
        ]),
    )
}

fn shutdown(req: &Request, shared: &Shared) -> Response {
    // default: drain (finish queued work); {"drain": false} aborts
    let drain = req
        .json()
        .ok()
        .and_then(|v| v.get("drain").and_then(|d| d.as_bool()))
        .unwrap_or(true);
    shared.draining.store(true, Ordering::SeqCst);
    if drain {
        shared.queue.close();
    } else {
        shared.queue.clear_and_close();
        shared.stop.store(true, Ordering::SeqCst);
    }
    Response::json(
        200,
        &Value::object(vec![
            ("shutting_down", Value::from(true)),
            ("drain", Value::from(drain)),
        ]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_spec_parses_defaults_ranges_and_rejects_bad_bodies() {
        let v = crate::util::json::parse(r#"{"grid":"g:hindsight:8"}"#).unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.model, "mlp");
        assert_eq!(spec.seeds, vec![1]);
        assert_eq!(spec.steps, None);
        let v = crate::util::json::parse(
            r#"{"grid":"g:{hindsight,current}:8","model":"cnn","seeds":"1..3","steps":12}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.model, "cnn");
        assert_eq!(spec.seeds, vec![1, 2, 3]);
        assert_eq!(spec.steps, Some(12));
        let v = crate::util::json::parse(r#"{"grid":"g:hindsight:8","seeds":[4,5]}"#).unwrap();
        assert_eq!(JobSpec::from_json(&v).unwrap().seeds, vec![4, 5]);
        for bad in [
            r#"{}"#,
            r#"{"grid":12}"#,
            r#"{"grid":"g:hindsight:8","seeds":{"a":1}}"#,
            r#"{"grid":"g:hindsight:8","seeds":["x"]}"#,
        ] {
            let v = crate::util::json::parse(bad).unwrap();
            assert!(JobSpec::from_json(&v).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn job_ids_are_content_derived_and_stable() {
        let v = crate::util::json::parse(
            r#"{"grid":"g:hindsight:8","model":"mlp","seeds":[1,2],"steps":6}"#,
        )
        .unwrap();
        let a = JobSpec::from_json(&v).unwrap();
        let b = JobSpec::from_json(&v).unwrap();
        assert_eq!(a.id(), b.id());
        assert_eq!(a.id().len(), 16);
        let mut c = a.clone();
        c.seeds = vec![1, 3];
        assert_ne!(a.id(), c.id());
        // round-trips through the job-file form
        let back = JobSpec::from_json(&a.to_json()).unwrap();
        assert_eq!(back, a);
        assert_eq!(back.id(), a.id());
    }

    /// Regression (satellite bugfix): seeds ≥ 2^53 must survive the
    /// job-file round-trip exactly — the old `Num(s as f64)` form
    /// rounded them and sibling shards expanded different cell keys.
    #[test]
    fn huge_seeds_round_trip_the_job_file_exactly() {
        let p53 = 1_u64 << 53;
        for seeds in [
            vec![p53 - 1, p53 + 1, u64::MAX],
            vec![u64::MAX],
            vec![1, 2, 3, p53],
        ] {
            let spec = JobSpec {
                grid: "g:hindsight:8".into(),
                model: "mlp".into(),
                seeds: seeds.clone(),
                steps: Some(4),
            };
            let text = spec.to_json().to_string();
            let v = crate::util::json::parse(&text).unwrap();
            let back = JobSpec::from_json(&v).unwrap();
            assert_eq!(back, spec, "file text: {text}");
            assert_eq!(back.id(), spec.id());
            // and the cells expand to the exact seeds
            let cells = back.expand().unwrap();
            let got: Vec<u64> = cells.iter().map(|c| c.cfg.seed).collect();
            assert_eq!(got, seeds);
        }
    }

    #[test]
    fn lossy_or_bogus_numeric_seeds_are_rejected_not_truncated() {
        for (body, needle) in [
            // above 2^53 as a JSON number: precision is unprovable
            (r#"{"grid":"g:hindsight:8","seeds":[9007199254740994]}"#, "2^53"),
            (r#"{"grid":"g:hindsight:8","seeds":[1.5]}"#, "not a non-negative integer"),
            (r#"{"grid":"g:hindsight:8","seeds":[-1]}"#, "not a non-negative integer"),
            (r#"{"grid":"g:hindsight:8","seeds":[]}"#, "at least one seed"),
            (r#"{"grid":"g:hindsight:8","steps":1.5}"#, "not a non-negative integer"),
            (r#"{"grid":"g:hindsight:8","steps":[3]}"#, "integer or a decimal string"),
            (r#"{"grid":"g:hindsight:8","seeds":["18446744073709551616"]}"#, "bad seed"),
        ] {
            let v = crate::util::json::parse(body).unwrap();
            let err = format!("{:#}", JobSpec::from_json(&v).unwrap_err());
            assert!(err.contains(needle), "{body} -> {err}");
        }
        // the exact string form accepts the full u64 range
        let v = crate::util::json::parse(
            r#"{"grid":"g:hindsight:8","seeds":["18446744073709551615",7],"steps":"12"}"#,
        )
        .unwrap();
        let spec = JobSpec::from_json(&v).unwrap();
        assert_eq!(spec.seeds, vec![u64::MAX, 7]);
        assert_eq!(spec.steps, Some(12));
        // the largest unambiguous numeric seed is 2^53 - 1 ...
        let v = crate::util::json::parse(
            r#"{"grid":"g:hindsight:8","seeds":[9007199254740991]}"#,
        )
        .unwrap();
        assert_eq!(JobSpec::from_json(&v).unwrap().seeds, vec![(1u64 << 53) - 1]);
        // ... while 2^53 itself is ambiguous (the JSON number 2^53+1
        // rounds onto it in the f64 parse) and is rejected
        let v = crate::util::json::parse(
            r#"{"grid":"g:hindsight:8","seeds":[9007199254740992]}"#,
        )
        .unwrap();
        let err = format!("{:#}", JobSpec::from_json(&v).unwrap_err());
        assert!(err.contains("2^53"), "{err}");
    }

    #[test]
    fn job_spec_expands_through_the_grid_engine() {
        let spec = JobSpec {
            grid: "g:{hindsight,current,tqt}:8".into(),
            model: "mlp".into(),
            seeds: vec![1, 2],
            steps: Some(6),
        };
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 6);
        assert!(cells.iter().all(|c| c.cfg.steps == 6));
        assert!(cells.iter().all(|c| c.cfg.model == "mlp"));
        // dense, stable indices — the shard contract
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        let bad = JobSpec { grid: "g:{unclosed".into(), ..spec };
        assert!(bad.expand().is_err());
    }

    #[test]
    fn synthetic_records_match_the_executor_convention() {
        let spec = JobSpec {
            grid: "g:hindsight:8".into(),
            model: "mlp".into(),
            seeds: vec![1],
            steps: Some(4),
        };
        let cells = spec.expand().unwrap();
        let rec = synthetic_cell_record(&cells[0]);
        assert_eq!(rec, RunRecord::synthetic(&cells[0].label, 4));
    }
}

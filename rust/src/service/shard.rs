//! Deterministic grid sharding: `--shard i/N` claims every cell whose
//! dense grid index is `i (mod N)`.
//!
//! [`GridSpec::expand`](crate::coordinator::GridSpec::expand) assigns
//! each cell a dense, stable index (scheme-major, seed-minor), so N
//! service processes pointed at the *same* grid and the *same* store
//! directory split the work with zero coordination: the claimed sets
//! are disjoint by construction and their union is the whole grid, and
//! the shared run store merges the results.  A cell another shard owns
//! is "foreign" to this process — it never executes it, but status and
//! result endpoints observe its completion through the store.

use anyhow::{bail, Result};

use crate::coordinator::GridCell;

/// Which slice of a grid this process executes: `index` of `count`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// this process's shard number, `0 <= index < count`
    pub index: usize,
    /// total number of shards splitting the grid
    pub count: usize,
}

impl ShardSpec {
    /// The un-sharded singleton: claims every cell.
    pub fn solo() -> Self {
        Self { index: 0, count: 1 }
    }

    /// Parse the CLI form `i/N` (e.g. `0/2`, `3/4`).
    pub fn parse(s: &str) -> Result<Self> {
        let Some((i, n)) = s.split_once('/') else {
            bail!("shard spec '{s}' is not of the form i/N");
        };
        let index: usize = i.trim().parse().map_err(|_| {
            anyhow::anyhow!("shard index '{i}' in '{s}' is not an integer")
        })?;
        let count: usize = n.trim().parse().map_err(|_| {
            anyhow::anyhow!("shard count '{n}' in '{s}' is not an integer")
        })?;
        if count == 0 {
            bail!("shard count must be >= 1 in '{s}'");
        }
        if index >= count {
            bail!("shard index {index} out of range for {count} shards in '{s}'");
        }
        Ok(Self { index, count })
    }

    /// Does this shard own the cell at dense grid index `grid_index`?
    pub fn claims(&self, grid_index: usize) -> bool {
        grid_index % self.count == self.index
    }

    /// The subset of `cells` this shard owns (order preserved).
    pub fn filter(&self, cells: &[GridCell]) -> Vec<GridCell> {
        cells
            .iter()
            .filter(|c| self.claims(c.index))
            .cloned()
            .collect()
    }
}

impl std::fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::GridSpec;

    #[test]
    fn parse_accepts_valid_and_rejects_invalid() {
        assert_eq!(ShardSpec::parse("0/1").unwrap(), ShardSpec::solo());
        assert_eq!(ShardSpec::parse("1/3").unwrap(), ShardSpec { index: 1, count: 3 });
        assert_eq!(ShardSpec::parse(" 2 / 4 ").unwrap(), ShardSpec { index: 2, count: 4 });
        for bad in ["", "1", "a/2", "1/b", "1/0", "2/2", "5/3", "-1/2"] {
            assert!(ShardSpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        assert_eq!(ShardSpec { index: 1, count: 3 }.to_string(), "1/3");
    }

    /// Satellite coverage: whitespace forms and `usize` overflow — a
    /// shard spec past the platform word errors cleanly, never panics
    /// or wraps.
    #[test]
    fn parse_overflow_and_whitespace_edges() {
        assert_eq!(ShardSpec::parse("0/1\n").unwrap(), ShardSpec::solo());
        assert_eq!(ShardSpec::parse("\t1/2").unwrap(), ShardSpec { index: 1, count: 2 });
        for bad in [
            "99999999999999999999999999/2",
            "0/99999999999999999999999999",
            "18446744073709551616/18446744073709551617",
            "1/ 2 3",
            "1//2",
            "/",
            " / ",
        ] {
            assert!(ShardSpec::parse(bad).is_err(), "'{bad}' must be rejected");
        }
        // Rust's usize parse accepts a leading '+': pinned here as
        // accepted rather than silently depended upon
        assert_eq!(ShardSpec::parse("+1/+2").unwrap(), ShardSpec { index: 1, count: 2 });
    }

    #[test]
    fn shards_partition_the_grid_exactly() {
        let spec = GridSpec::new("g:{hindsight,current,tqt,banner}:{4,8}", &[1, 2, 3]).unwrap();
        let cells = spec.expand(&crate::coordinator::TrainConfig::new("mlp"));
        assert!(cells.len() >= 8, "grid must be non-trivial");
        for count in 1..=4 {
            let shards: Vec<ShardSpec> =
                (0..count).map(|index| ShardSpec { index, count }).collect();
            let mut seen = vec![0usize; cells.len()];
            for shard in &shards {
                for cell in shard.filter(&cells) {
                    seen[cell.index] += 1;
                }
            }
            // every cell claimed by exactly one shard: disjoint + total
            assert!(
                seen.iter().all(|&n| n == 1),
                "N={count}: claim counts {seen:?} must all be 1"
            );
        }
    }

    #[test]
    fn solo_claims_everything() {
        let solo = ShardSpec::solo();
        for i in 0..64 {
            assert!(solo.claims(i));
        }
    }
}

//! Chunked-parallel backend: `std::thread`-scoped workers over
//! cache-sized spans, rayon-free like the sweep executor.
//!
//! The tensor is split into at most `threads` contiguous spans, each a
//! multiple of `CHUNK` (so every worker's inner loops keep the
//! cache-resident blocking of the serial backends).  Each worker runs
//! the [`super::simd`] kernel over its span and reduces a per-span
//! `(min, max)` pair; the caller merges span pairs **in span order**.
//! That merge only reassociates the NaN-dropping min/max fold, and the
//! fake-quant side is element-wise, so the result is bit-identical to
//! the scalar reference — pinned by `tests/kernel_conformance.rs`
//! across span counts {1, 2, 7, 16}.
//!
//! `fq_cosine` is the one kernel that does *not* fan out: its f64
//! reduction is order-sensitive (float addition does not reassociate),
//! so per-span partial sums would break the bit-parity guarantee every
//! backend carries.  It delegates to the SIMD backend, which keeps the
//! reference accumulation order.
//!
//! The auto path guarantees every worker at least [`PAR_MIN_LEN`]
//! elements of work — a spawn costs more than it saves below that — so
//! tensors shorter than *twice* `PAR_MIN_LEN` run on the SIMD path
//! with zero threads spawned.  Tests pin chunk-count determinism
//! through the `*_with` entry points, which take an explicit span
//! count.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::{simd, CHUNK};

/// Minimum elements of work per spawned worker: thread spawn + join is
/// tens of microseconds, a full fused pass over 64Ki floats is
/// comparable.  The auto path therefore stays serial until a tensor
/// has two spans' worth (`2 * PAR_MIN_LEN` elements).
pub const PAR_MIN_LEN: usize = 1 << 16;

/// Worker count the auto path uses for `len` elements: one worker per
/// full `PAR_MIN_LEN` of work, capped at the hardware parallelism
/// share this process is hinted to use (see
/// [`external_parallelism_guard`]).
pub fn auto_threads(len: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let budget = (hw / EXTERNAL_WORKERS.load(Ordering::Relaxed).max(1)).max(1);
    budget.min(len / PAR_MIN_LEN).max(1)
}

/// Concurrently running coordinator workers (the sweep executor's
/// threads), used to divide the hardware budget so kernel fan-out and
/// worker fan-out don't multiply: an 8-worker sweep on an 8-core box
/// must not explode into 64 kernel threads.  1 = no external
/// parallelism (the default).
static EXTERNAL_WORKERS: AtomicUsize = AtomicUsize::new(1);

/// RAII hint that `n` coordinator worker threads will be running
/// kernels concurrently until the guard drops; [`auto_threads`]
/// divides the hardware budget by it.  A hint, not a lock: concurrent
/// guards are last-writer-wins, and explicit `*_with` span counts
/// ignore it entirely.  Bit-parity is unaffected either way — span
/// counts never change results.
pub fn external_parallelism_guard(n: usize) -> ExternalParallelism {
    ExternalParallelism(EXTERNAL_WORKERS.swap(n.max(1), Ordering::Relaxed))
}

/// Guard returned by [`external_parallelism_guard`]; restores the
/// previous hint on drop.
pub struct ExternalParallelism(usize);

impl Drop for ExternalParallelism {
    fn drop(&mut self) {
        EXTERNAL_WORKERS.store(self.0, Ordering::Relaxed);
    }
}

/// Span length that divides `len` elements over at most `threads`
/// workers in `align`-multiples (the last span keeps the remainder).
fn span_len(len: usize, threads: usize, align: usize) -> usize {
    let per = len.div_ceil(threads.max(1));
    per.div_ceil(align).max(1) * align
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a
}

pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    minmax_fq_with(auto_threads(xs.len()), xs, qmin, qmax, bits)
}

/// [`minmax_fq`] over an explicit number of parallel spans (never more
/// spans than exist); `threads <= 1` runs serially on the calling
/// thread.  Empty slices follow the dispatcher's `(0.0, 0.0)`
/// convention, so the `_with` surface is safe to call directly.
pub fn minmax_fq_with(
    threads: usize,
    xs: &mut [f32],
    qmin: f32,
    qmax: f32,
    bits: u32,
) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::minmax_fq(xs, qmin, qmax, bits);
    }
    let span = span_len(xs.len(), threads, CHUNK);
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); xs.len().div_ceil(span)];
    std::thread::scope(|scope| {
        for (chunk, slot) in xs.chunks_mut(span).zip(stats.iter_mut()) {
            scope.spawn(move || {
                *slot = simd::minmax_fq(chunk, qmin, qmax, bits);
            });
        }
    });
    stats.iter().fold(
        (f32::INFINITY, f32::NEG_INFINITY),
        |(lo, hi), &(l, h)| (lo.min(l), hi.max(h)),
    )
}

pub fn minmax_fq_axis(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    minmax_fq_axis_with(auto_threads(xs.len()), xs, ranges, bits)
}

/// [`minmax_fq_axis`] over an explicit number of parallel spans.  Span
/// boundaries stay channel-aligned (multiples of `ranges.len()`), so
/// every span sees the same channels-last phase and per-span stats
/// merge channel-wise in span order.  Empty slices follow the
/// dispatcher's `(0.0, 0.0)`-rows convention.
pub fn minmax_fq_axis_with(
    threads: usize,
    xs: &mut [f32],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    if xs.is_empty() {
        return vec![(0.0, 0.0); c];
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::minmax_fq_axis(xs, ranges, bits);
    }
    // align spans to lcm(CHUNK, c): CHUNK keeps the inner blocking
    // cache-aligned, c keeps every span channel-phase 0
    let align = CHUNK / gcd(CHUNK, c) * c;
    let span = span_len(xs.len(), threads, align);
    let n_spans = xs.len().div_ceil(span);
    let mut stats: Vec<Vec<(f32, f32)>> = vec![Vec::new(); n_spans];
    std::thread::scope(|scope| {
        for (chunk, slot) in xs.chunks_mut(span).zip(stats.iter_mut()) {
            scope.spawn(move || {
                *slot = simd::minmax_fq_axis(chunk, ranges, bits);
            });
        }
    });
    (0..c)
        .map(|ch| {
            stats.iter().fold(
                (f32::INFINITY, f32::NEG_INFINITY),
                |(lo, hi), span_stats| {
                    let (l, h) = span_stats[ch];
                    (lo.min(l), hi.max(h))
                },
            )
        })
        .collect()
}

pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    fq_into_with(auto_threads(src.len()), src, dst, qmin, qmax, bits)
}

/// [`fq_into`] over an explicit number of parallel spans.  Element-wise
/// work: spans cannot interact, parity is structural.
pub fn fq_into_with(threads: usize, src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    if threads <= 1 || src.len() <= CHUNK {
        return simd::fq_into(src, dst, qmin, qmax, bits);
    }
    let span = span_len(src.len(), threads, CHUNK);
    std::thread::scope(|scope| {
        for (s, d) in src.chunks(span).zip(dst.chunks_mut(span)) {
            scope.spawn(move || {
                simd::fq_into(s, d, qmin, qmax, bits);
            });
        }
    });
}

/// Sequential by design: see the module doc — fanning out the f64
/// reduction would reassociate an order-sensitive sum and break the
/// backend bit-parity contract.
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    simd::fq_cosine(xs, qmin, qmax, bits)
}

//! Chunked-parallel backend: `std::thread`-scoped workers over
//! cache-sized spans, rayon-free like the sweep executor.
//!
//! The tensor is split into at most `threads` contiguous spans, each a
//! multiple of `CHUNK` (so every worker's inner loops keep the
//! cache-resident blocking of the serial backends).  Each worker runs
//! the [`super::simd`] kernel over its span and reduces a per-span
//! `(min, max)` pair; the caller merges span pairs **in span order**.
//! That merge only reassociates the NaN-dropping min/max fold, and the
//! fake-quant side is element-wise, so the result is bit-identical to
//! the scalar reference — pinned by `tests/kernel_conformance.rs`
//! across span counts {1, 2, 7, 16}.
//!
//! `fq_cosine` is the one kernel that does *not* fan out: its f64
//! reduction is order-sensitive (float addition does not reassociate),
//! so per-span partial sums would break the bit-parity guarantee every
//! backend carries.  It delegates to the SIMD backend, which keeps the
//! reference accumulation order.
//!
//! The auto path guarantees every worker at least [`PAR_MIN_LEN`]
//! elements of work — a spawn costs more than it saves below that — so
//! tensors shorter than *twice* `PAR_MIN_LEN` run on the SIMD path
//! with zero threads spawned.  Tests pin chunk-count determinism
//! through the `*_with` entry points, which take an explicit span
//! count.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::{simd, CHUNK};

/// Minimum elements of work per spawned worker: thread spawn + join is
/// tens of microseconds, a full fused pass over 64Ki floats is
/// comparable.  The auto path therefore stays serial until a tensor
/// has two spans' worth (`2 * PAR_MIN_LEN` elements).
pub const PAR_MIN_LEN: usize = 1 << 16;

/// Worker count the auto path uses for `len` elements: one worker per
/// full `PAR_MIN_LEN` of work, capped at the hardware parallelism
/// share this process is hinted to use (see
/// [`external_parallelism_guard`]).
pub fn auto_threads(len: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let budget = (hw / EXTERNAL_WORKERS.load(Ordering::Relaxed).max(1)).max(1);
    budget.min(len / PAR_MIN_LEN).max(1)
}

/// Concurrently running coordinator workers (the sweep executor's
/// threads), used to divide the hardware budget so kernel fan-out and
/// worker fan-out don't multiply: an 8-worker sweep on an 8-core box
/// must not explode into 64 kernel threads.  1 = no external
/// parallelism (the default).
static EXTERNAL_WORKERS: AtomicUsize = AtomicUsize::new(1);

/// RAII hint that `n` coordinator worker threads will be running
/// kernels concurrently until the guard drops; [`auto_threads`]
/// divides the hardware budget by it.  A hint, not a lock: concurrent
/// guards are last-writer-wins, and explicit `*_with` span counts
/// ignore it entirely.  Bit-parity is unaffected either way — span
/// counts never change results.
pub fn external_parallelism_guard(n: usize) -> ExternalParallelism {
    ExternalParallelism(EXTERNAL_WORKERS.swap(n.max(1), Ordering::Relaxed))
}

/// Guard returned by [`external_parallelism_guard`]; restores the
/// previous hint on drop.
pub struct ExternalParallelism(usize);

impl Drop for ExternalParallelism {
    fn drop(&mut self) {
        EXTERNAL_WORKERS.store(self.0, Ordering::Relaxed);
    }
}

/// Span length that divides `len` elements over at most `threads`
/// workers in `align`-multiples (the last span keeps the remainder).
fn span_len(len: usize, threads: usize, align: usize) -> usize {
    let per = len.div_ceil(threads.max(1));
    per.div_ceil(align).max(1) * align
}

fn gcd(mut a: usize, mut b: usize) -> usize {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a
}

pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    minmax_fq_with(auto_threads(xs.len()), xs, qmin, qmax, bits)
}

/// [`minmax_fq`] over an explicit number of parallel spans (never more
/// spans than exist); `threads <= 1` runs serially on the calling
/// thread.  Empty slices follow the dispatcher's `(0.0, 0.0)`
/// convention, so the `_with` surface is safe to call directly.
pub fn minmax_fq_with(
    threads: usize,
    xs: &mut [f32],
    qmin: f32,
    qmax: f32,
    bits: u32,
) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::minmax_fq(xs, qmin, qmax, bits);
    }
    let span = span_len(xs.len(), threads, CHUNK);
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); xs.len().div_ceil(span)];
    std::thread::scope(|scope| {
        for (chunk, slot) in xs.chunks_mut(span).zip(stats.iter_mut()) {
            scope.spawn(move || {
                *slot = simd::minmax_fq(chunk, qmin, qmax, bits);
            });
        }
    });
    stats.iter().fold(
        (f32::INFINITY, f32::NEG_INFINITY),
        |(lo, hi), &(l, h)| (lo.min(l), hi.max(h)),
    )
}

pub fn minmax_fq_axis(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    minmax_fq_axis_with(auto_threads(xs.len()), xs, ranges, bits)
}

/// [`minmax_fq_axis`] over an explicit number of parallel spans.  Span
/// boundaries stay channel-aligned (multiples of `ranges.len()`), so
/// every span sees the same channels-last phase and per-span stats
/// merge channel-wise in span order.  Empty slices follow the
/// dispatcher's `(0.0, 0.0)`-rows convention.
pub fn minmax_fq_axis_with(
    threads: usize,
    xs: &mut [f32],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    if xs.is_empty() {
        return vec![(0.0, 0.0); c];
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::minmax_fq_axis(xs, ranges, bits);
    }
    // align spans to lcm(CHUNK, c): CHUNK keeps the inner blocking
    // cache-aligned, c keeps every span channel-phase 0
    let align = CHUNK / gcd(CHUNK, c) * c;
    let span = span_len(xs.len(), threads, align);
    let n_spans = xs.len().div_ceil(span);
    let mut stats: Vec<Vec<(f32, f32)>> = vec![Vec::new(); n_spans];
    std::thread::scope(|scope| {
        for (chunk, slot) in xs.chunks_mut(span).zip(stats.iter_mut()) {
            scope.spawn(move || {
                *slot = simd::minmax_fq_axis(chunk, ranges, bits);
            });
        }
    });
    (0..c)
        .map(|ch| {
            stats.iter().fold(
                (f32::INFINITY, f32::NEG_INFINITY),
                |(lo, hi), span_stats| {
                    let (l, h) = span_stats[ch];
                    (lo.min(l), hi.max(h))
                },
            )
        })
        .collect()
}

pub fn fq_store_i8(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    fq_store_i8_with(auto_threads(xs.len()), xs, dst, qmin, qmax, bits)
}

/// [`fq_store_i8`] over an explicit number of parallel spans: one code
/// byte per element, so payload spans mirror the element spans exactly;
/// per-span stats merge in span order like [`minmax_fq_with`].
pub fn fq_store_i8_with(
    threads: usize,
    xs: &[f32],
    dst: &mut [u8],
    qmin: f32,
    qmax: f32,
    bits: u32,
) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::fq_store_i8(xs, dst, qmin, qmax, bits);
    }
    let span = span_len(xs.len(), threads, CHUNK);
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); xs.len().div_ceil(span)];
    std::thread::scope(|scope| {
        for ((chunk, codes), slot) in xs
            .chunks(span)
            .zip(dst.chunks_mut(span))
            .zip(stats.iter_mut())
        {
            scope.spawn(move || {
                *slot = simd::fq_store_i8(chunk, codes, qmin, qmax, bits);
            });
        }
    });
    stats.iter().fold(
        (f32::INFINITY, f32::NEG_INFINITY),
        |(lo, hi), &(l, h)| (lo.min(l), hi.max(h)),
    )
}

pub fn fq_store_i4(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    fq_store_i4_with(auto_threads(xs.len()), xs, dst, qmin, qmax, bits)
}

/// [`fq_store_i4`] over an explicit number of parallel spans.  Spans
/// align to `CHUNK` (even), so every span boundary lands on a byte
/// boundary of the packed stream: worker k owns exactly `span / 2`
/// payload bytes and no two workers share a byte.
pub fn fq_store_i4_with(
    threads: usize,
    xs: &[f32],
    dst: &mut [u8],
    qmin: f32,
    qmax: f32,
    bits: u32,
) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::fq_store_i4(xs, dst, qmin, qmax, bits);
    }
    let span = span_len(xs.len(), threads, CHUNK);
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); xs.len().div_ceil(span)];
    std::thread::scope(|scope| {
        for ((chunk, codes), slot) in xs
            .chunks(span)
            .zip(dst.chunks_mut(span / 2))
            .zip(stats.iter_mut())
        {
            scope.spawn(move || {
                *slot = simd::fq_store_i4(chunk, codes, qmin, qmax, bits);
            });
        }
    });
    stats.iter().fold(
        (f32::INFINITY, f32::NEG_INFINITY),
        |(lo, hi), &(l, h)| (lo.min(l), hi.max(h)),
    )
}

pub fn fq_store_i8_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    fq_store_i8_axis_with(auto_threads(xs.len()), xs, dst, ranges, bits)
}

/// [`fq_store_i8_axis`] over an explicit span count; span boundaries
/// stay channel-aligned like [`minmax_fq_axis_with`]'s.
pub fn fq_store_i8_axis_with(
    threads: usize,
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    if xs.is_empty() {
        return vec![(0.0, 0.0); c];
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::fq_store_i8_axis(xs, dst, ranges, bits);
    }
    let align = CHUNK / gcd(CHUNK, c) * c;
    let span = span_len(xs.len(), threads, align);
    let n_spans = xs.len().div_ceil(span);
    let mut stats: Vec<Vec<(f32, f32)>> = vec![Vec::new(); n_spans];
    std::thread::scope(|scope| {
        for ((chunk, codes), slot) in xs
            .chunks(span)
            .zip(dst.chunks_mut(span))
            .zip(stats.iter_mut())
        {
            scope.spawn(move || {
                *slot = simd::fq_store_i8_axis(chunk, codes, ranges, bits);
            });
        }
    });
    merge_axis_stats(c, &stats)
}

pub fn fq_store_i4_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    fq_store_i4_axis_with(auto_threads(xs.len()), xs, dst, ranges, bits)
}

/// [`fq_store_i4_axis`] over an explicit span count.  Spans align to
/// `lcm(CHUNK, c)` — a multiple of `CHUNK`, hence even — so every span
/// starts at channel phase 0 *and* on a packed-byte boundary.
pub fn fq_store_i4_axis_with(
    threads: usize,
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    if xs.is_empty() {
        return vec![(0.0, 0.0); c];
    }
    if threads <= 1 || xs.len() <= CHUNK {
        return simd::fq_store_i4_axis(xs, dst, ranges, bits);
    }
    let align = CHUNK / gcd(CHUNK, c) * c;
    let span = span_len(xs.len(), threads, align);
    let n_spans = xs.len().div_ceil(span);
    let mut stats: Vec<Vec<(f32, f32)>> = vec![Vec::new(); n_spans];
    std::thread::scope(|scope| {
        for ((chunk, codes), slot) in xs
            .chunks(span)
            .zip(dst.chunks_mut(span / 2))
            .zip(stats.iter_mut())
        {
            scope.spawn(move || {
                *slot = simd::fq_store_i4_axis(chunk, codes, ranges, bits);
            });
        }
    });
    merge_axis_stats(c, &stats)
}

/// Channel-wise merge of per-span axis stats, in span order.
fn merge_axis_stats(c: usize, stats: &[Vec<(f32, f32)>]) -> Vec<(f32, f32)> {
    (0..c)
        .map(|ch| {
            stats.iter().fold(
                (f32::INFINITY, f32::NEG_INFINITY),
                |(lo, hi), span_stats| {
                    let (l, h) = span_stats[ch];
                    (lo.min(l), hi.max(h))
                },
            )
        })
        .collect()
}

pub fn dequant_i8(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    dequant_i8_with(auto_threads(dst.len()), codes, dst, qmin, qmax, bits)
}

/// [`dequant_i8`] over an explicit span count (element-wise decode:
/// spans cannot interact, parity is structural).
pub fn dequant_i8_with(
    threads: usize,
    codes: &[u8],
    dst: &mut [f32],
    qmin: f32,
    qmax: f32,
    bits: u32,
) {
    if threads <= 1 || dst.len() <= CHUNK {
        return simd::dequant_i8(codes, dst, qmin, qmax, bits);
    }
    let span = span_len(dst.len(), threads, CHUNK);
    std::thread::scope(|scope| {
        for (c, d) in codes.chunks(span).zip(dst.chunks_mut(span)) {
            scope.spawn(move || {
                simd::dequant_i8(c, d, qmin, qmax, bits);
            });
        }
    });
}

pub fn dequant_i4(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    dequant_i4_with(auto_threads(dst.len()), codes, dst, qmin, qmax, bits)
}

/// [`dequant_i4`] over an explicit span count; `CHUNK`-aligned element
/// spans keep every worker on whole payload bytes.
pub fn dequant_i4_with(
    threads: usize,
    codes: &[u8],
    dst: &mut [f32],
    qmin: f32,
    qmax: f32,
    bits: u32,
) {
    if threads <= 1 || dst.len() <= CHUNK {
        return simd::dequant_i4(codes, dst, qmin, qmax, bits);
    }
    let span = span_len(dst.len(), threads, CHUNK);
    std::thread::scope(|scope| {
        for (c, d) in codes.chunks(span / 2).zip(dst.chunks_mut(span)) {
            scope.spawn(move || {
                simd::dequant_i4(c, d, qmin, qmax, bits);
            });
        }
    });
}

/// Channel-strided readback over channel-aligned spans.
pub fn dequant_i8_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    let c = ranges.len();
    debug_assert!(c > 0 && dst.len() % c == 0, "validated by the dispatcher");
    let threads = auto_threads(dst.len());
    if threads <= 1 || dst.len() <= CHUNK {
        return simd::dequant_i8_axis(codes, dst, ranges, bits);
    }
    let align = CHUNK / gcd(CHUNK, c) * c;
    let span = span_len(dst.len(), threads, align);
    std::thread::scope(|scope| {
        for (cs, d) in codes.chunks(span).zip(dst.chunks_mut(span)) {
            scope.spawn(move || {
                simd::dequant_i8_axis(cs, d, ranges, bits);
            });
        }
    });
}

/// Channel-strided bit-packed readback over `lcm(CHUNK, c)`-aligned
/// spans (channel phase 0 and byte-aligned at every span start).
pub fn dequant_i4_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    let c = ranges.len();
    debug_assert!(c > 0 && dst.len() % c == 0, "validated by the dispatcher");
    let threads = auto_threads(dst.len());
    if threads <= 1 || dst.len() <= CHUNK {
        return simd::dequant_i4_axis(codes, dst, ranges, bits);
    }
    let align = CHUNK / gcd(CHUNK, c) * c;
    let span = span_len(dst.len(), threads, align);
    std::thread::scope(|scope| {
        for (cs, d) in codes.chunks(span / 2).zip(dst.chunks_mut(span)) {
            scope.spawn(move || {
                simd::dequant_i4_axis(cs, d, ranges, bits);
            });
        }
    });
}

pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    fq_into_with(auto_threads(src.len()), src, dst, qmin, qmax, bits)
}

/// [`fq_into`] over an explicit number of parallel spans.  Element-wise
/// work: spans cannot interact, parity is structural.
pub fn fq_into_with(threads: usize, src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    if threads <= 1 || src.len() <= CHUNK {
        return simd::fq_into(src, dst, qmin, qmax, bits);
    }
    let span = span_len(src.len(), threads, CHUNK);
    std::thread::scope(|scope| {
        for (s, d) in src.chunks(span).zip(dst.chunks_mut(span)) {
            scope.spawn(move || {
                simd::fq_into(s, d, qmin, qmax, bits);
            });
        }
    });
}

/// Sequential by design: see the module doc — fanning out the f64
/// reduction would reassociate an order-sensitive sum and break the
/// backend bit-parity contract.
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    simd::fq_cosine(xs, qmin, qmax, bits)
}

//! Scalar reference backend — the pinned semantics of every kernel.
//!
//! This is the code the original fused kernels shipped as: plain
//! sequential loops, chunked only for cache residency.  Every other
//! backend is required (and tested, by `tests/kernel_conformance.rs`)
//! to be bit-identical to these functions; when the conformance harness
//! disagrees, *this* file is the one that is right by definition.
//!
//! Inputs arrive pre-validated by the dispatch layer in the parent
//! module: slices are non-empty, axis tensors divide evenly into
//! channels.  The loops here therefore carry no error paths of their
//! own.

use super::CHUNK;
use crate::quant::QuantParams;

/// Fused min/max + fake-quantize in place: returns the (min, max) of
/// the *original* values while rewriting `xs` onto the `[qmin, qmax]`
/// grid, folding extrema and rounding chunk by chunk so each block is
/// cache-resident for both passes.
pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for chunk in xs.chunks_mut(CHUNK) {
        for &x in chunk.iter() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        for x in chunk.iter_mut() {
            *x = qp.fq(*x);
        }
    }
    (lo, hi)
}

/// Channel-strided fused min/max + fake-quantize (channels-last: the
/// channel of flat element `i` is `i % ranges.len()`).  One traversal
/// folds each channel's pre-quantization extrema *and* rewrites the
/// tensor onto its channel's grid; returns one `(min, max)` per
/// channel.
pub fn minmax_fq_axis(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
    // channel-aligned blocks (block % c == 0, and the trailing chunk is
    // too since the total length divides by c) let a wrapping counter
    // replace a per-element `j % c` division, while preserving the
    // cache-resident reduce-then-round structure
    let block = (CHUNK / c).max(1) * c;
    for chunk in xs.chunks_mut(block) {
        let mut ch = 0usize;
        for &x in chunk.iter() {
            let s = &mut stats[ch];
            s.0 = s.0.min(x);
            s.1 = s.1.max(x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
        ch = 0;
        for x in chunk.iter_mut() {
            *x = qps[ch].fq(*x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
    }
    stats
}

/// Fused min/max + integer store: the payload form of [`minmax_fq`].
/// Instead of rewriting `xs` onto the grid, the `bits`-bit grid *index*
/// of each element is written to `dst` (one code byte per element —
/// `bits <= 8`, so every index fits), while the pre-quantization
/// extrema fold exactly like [`minmax_fq`]'s.  `dequant_i8` of the
/// payload reproduces `fq(x)` bit-for-bit, because both sides round
/// through the same [`QuantParams::index_of`]/`value_of` pair.
pub fn fq_store_i8(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for (chunk, codes) in xs.chunks(CHUNK).zip(dst.chunks_mut(CHUNK)) {
        for &x in chunk.iter() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        for (d, &x) in codes.iter_mut().zip(chunk) {
            *d = qp.index_of(x) as u8;
        }
    }
    (lo, hi)
}

/// Bit-packed 4-bit payload store: two codes per byte (`bits <= 4`).
/// Flat element `2k` lands in the low nibble of byte `k`, element
/// `2k + 1` in the high nibble; on an odd-length tensor the final
/// byte's high nibble stays zero.  `dst` holds `xs.len().div_ceil(2)`
/// bytes (validated by the dispatcher).
pub fn fq_store_i4(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    // CHUNK is even, so chunk boundaries always land on byte boundaries
    // of the packed stream; only the final chunk can end mid-byte.
    for (chunk, codes) in xs.chunks(CHUNK).zip(dst.chunks_mut(CHUNK / 2)) {
        for &x in chunk.iter() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let rem = chunk.chunks_exact(2).remainder();
        for (d, p) in codes.iter_mut().zip(chunk.chunks_exact(2)) {
            *d = qp.index_of(p[0]) as u8 | ((qp.index_of(p[1]) as u8) << 4);
        }
        if let [x] = rem {
            codes[chunk.len() / 2] = qp.index_of(*x) as u8;
        }
    }
    (lo, hi)
}

/// Channel-strided payload store (channels-last, like
/// [`minmax_fq_axis`]): per-channel extrema plus one code byte per
/// element, each element encoded on its channel's grid.
pub fn fq_store_i8_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
    let block = (CHUNK / c).max(1) * c;
    for (chunk, codes) in xs.chunks(block).zip(dst.chunks_mut(block)) {
        let mut ch = 0usize;
        for &x in chunk.iter() {
            let s = &mut stats[ch];
            s.0 = s.0.min(x);
            s.1 = s.1.max(x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
        ch = 0;
        for (d, &x) in codes.iter_mut().zip(chunk) {
            *d = qps[ch].index_of(x) as u8;
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
    }
    stats
}

/// Channel-strided bit-packed store.  Packing is flat-index based: with
/// an odd channel count the byte boundary drifts across channels, which
/// is fine — the channel of flat element `i` is `i % c` regardless of
/// which nibble holds its code.
pub fn fq_store_i4_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
    let mut ch = 0usize;
    for &x in xs.iter() {
        let s = &mut stats[ch];
        s.0 = s.0.min(x);
        s.1 = s.1.max(x);
        ch += 1;
        if ch == c {
            ch = 0;
        }
    }
    ch = 0;
    let rem = xs.chunks_exact(2).remainder();
    for (d, p) in dst.iter_mut().zip(xs.chunks_exact(2)) {
        let lo_n = qps[ch].index_of(p[0]) as u8;
        ch += 1;
        if ch == c {
            ch = 0;
        }
        let hi_n = qps[ch].index_of(p[1]) as u8;
        ch += 1;
        if ch == c {
            ch = 0;
        }
        *d = lo_n | (hi_n << 4);
    }
    if let [x] = rem {
        dst[xs.len() / 2] = qps[ch].index_of(*x) as u8;
    }
    stats
}

/// Payload readback: decode one code byte per element back to the grid
/// values `fq` would have produced.
pub fn dequant_i8(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    for (x, &code) in dst.iter_mut().zip(codes) {
        *x = qp.value_of(code as u32);
    }
}

/// Bit-packed readback: low nibble first, matching [`fq_store_i4`]'s
/// packing; `dst.len()` is the element count (the final high nibble is
/// ignored on odd lengths).
pub fn dequant_i4(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    for (pair, &byte) in dst.chunks_mut(2).zip(codes) {
        pair[0] = qp.value_of((byte & 0x0F) as u32);
        if let Some(x) = pair.get_mut(1) {
            *x = qp.value_of((byte >> 4) as u32);
        }
    }
}

/// Channel-strided readback of [`fq_store_i8_axis`] payloads.
pub fn dequant_i8_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    let c = ranges.len();
    debug_assert!(c > 0 && dst.len() % c == 0, "validated by the dispatcher");
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut ch = 0usize;
    for (x, &code) in dst.iter_mut().zip(codes) {
        *x = qps[ch].value_of(code as u32);
        ch += 1;
        if ch == c {
            ch = 0;
        }
    }
}

/// Channel-strided readback of [`fq_store_i4_axis`] payloads.
pub fn dequant_i4_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    let c = ranges.len();
    debug_assert!(c > 0 && dst.len() % c == 0, "validated by the dispatcher");
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut ch = 0usize;
    for (pair, &byte) in dst.chunks_mut(2).zip(codes) {
        pair[0] = qps[ch].value_of((byte & 0x0F) as u32);
        ch += 1;
        if ch == c {
            ch = 0;
        }
        if let Some(x) = pair.get_mut(1) {
            *x = qps[ch].value_of((byte >> 4) as u32);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
    }
}

/// Fake-quantize `src` into a caller-owned buffer of the same length.
pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = qp.fq(x);
    }
}

/// Fused DSGC objective: `cosine(x, fake_quant(x))` in one traversal,
/// never materializing the quantized tensor.  The f64 accumulation
/// order (flat element order) is part of the pinned contract — floating
/// addition does not reassociate, so every backend keeps this exact
/// order.
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for &x in xs {
        let q = qp.fq(x);
        dot += x as f64 * q as f64;
        na += x as f64 * x as f64;
        nb += q as f64 * q as f64;
    }
    if na == 0.0 && nb == 0.0 {
        return 1.0;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

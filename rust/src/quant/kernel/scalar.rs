//! Scalar reference backend — the pinned semantics of every kernel.
//!
//! This is the code the original fused kernels shipped as: plain
//! sequential loops, chunked only for cache residency.  Every other
//! backend is required (and tested, by `tests/kernel_conformance.rs`)
//! to be bit-identical to these functions; when the conformance harness
//! disagrees, *this* file is the one that is right by definition.
//!
//! Inputs arrive pre-validated by the dispatch layer in the parent
//! module: slices are non-empty, axis tensors divide evenly into
//! channels.  The loops here therefore carry no error paths of their
//! own.

use super::CHUNK;
use crate::quant::QuantParams;

/// Fused min/max + fake-quantize in place: returns the (min, max) of
/// the *original* values while rewriting `xs` onto the `[qmin, qmax]`
/// grid, folding extrema and rounding chunk by chunk so each block is
/// cache-resident for both passes.
pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for chunk in xs.chunks_mut(CHUNK) {
        for &x in chunk.iter() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        for x in chunk.iter_mut() {
            *x = qp.fq(*x);
        }
    }
    (lo, hi)
}

/// Channel-strided fused min/max + fake-quantize (channels-last: the
/// channel of flat element `i` is `i % ranges.len()`).  One traversal
/// folds each channel's pre-quantization extrema *and* rewrites the
/// tensor onto its channel's grid; returns one `(min, max)` per
/// channel.
pub fn minmax_fq_axis(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
    // channel-aligned blocks (block % c == 0, and the trailing chunk is
    // too since the total length divides by c) let a wrapping counter
    // replace a per-element `j % c` division, while preserving the
    // cache-resident reduce-then-round structure
    let block = (CHUNK / c).max(1) * c;
    for chunk in xs.chunks_mut(block) {
        let mut ch = 0usize;
        for &x in chunk.iter() {
            let s = &mut stats[ch];
            s.0 = s.0.min(x);
            s.1 = s.1.max(x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
        ch = 0;
        for x in chunk.iter_mut() {
            *x = qps[ch].fq(*x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
    }
    stats
}

/// Fake-quantize `src` into a caller-owned buffer of the same length.
pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = qp.fq(x);
    }
}

/// Fused DSGC objective: `cosine(x, fake_quant(x))` in one traversal,
/// never materializing the quantized tensor.  The f64 accumulation
/// order (flat element order) is part of the pinned contract — floating
/// addition does not reassociate, so every backend keeps this exact
/// order.
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for &x in xs {
        let q = qp.fq(x);
        dot += x as f64 * q as f64;
        na += x as f64 * x as f64;
        nb += q as f64 * q as f64;
    }
    if na == 0.0 && nb == 0.0 {
        return 1.0;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

//! Explicit-SIMD backend: lane-chunked inner loops with a scalar tail.
//!
//! Stable Rust has no `std::simd`, so this backend is written the way
//! portable-SIMD code lowers: fixed-width lane blocks (`LANES`
//! elements) with no cross-lane dependency inside the hot loop, so the
//! autovectorizer emits one vector op per lane statement, plus a scalar
//! tail for the ragged end.  The structure — per-lane min/max
//! accumulators folded once at the end, element-wise rounding through
//! the exact same [`QuantParams::fq`] scalar sequence — keeps every
//! result bit-identical to the [`super::scalar`] reference:
//!
//! * the fake-quant side is element-wise, so lane blocking cannot
//!   change a single output bit;
//! * the min/max fold only *reassociates* a reduction whose operator is
//!   commutative, associative, and NaN-dropping (`f32::min`/`max`
//!   return the non-NaN operand), so the folded extrema are the same
//!   values the sequential fold produces;
//! * the `fq_cosine` f64 accumulation does **not** reassociate (float
//!   addition is order-sensitive): lanes compute the quantized values,
//!   the sums run in flat element order, exactly like the reference.
//!
//! Cache behaviour matches the scalar backend: lane loops run inside
//! the same `CHUNK`-sized blocks, reducing then rounding each block
//! while it is resident.

use super::CHUNK;
use crate::quant::QuantParams;

/// Lane width of the blocked inner loops — eight f32 lanes (one AVX2
/// register, two NEON registers); `CHUNK` is a multiple of it, so only
/// the final chunk ever has a scalar tail.
pub const LANES: usize = 8;

/// Lane-blocked fused min/max + fake-quantize in place.
pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut vlo = [f32::INFINITY; LANES];
    let mut vhi = [f32::NEG_INFINITY; LANES];
    let (mut slo, mut shi) = (f32::INFINITY, f32::NEG_INFINITY);
    for chunk in xs.chunks_mut(CHUNK) {
        let split = chunk.len() - chunk.len() % LANES;
        let (blocks, tail) = chunk.split_at_mut(split);
        for block in blocks.chunks_exact(LANES) {
            for l in 0..LANES {
                vlo[l] = vlo[l].min(block[l]);
                vhi[l] = vhi[l].max(block[l]);
            }
        }
        for &x in tail.iter() {
            slo = slo.min(x);
            shi = shi.max(x);
        }
        for block in blocks.chunks_exact_mut(LANES) {
            for x in block.iter_mut() {
                *x = qp.fq(*x);
            }
        }
        for x in tail.iter_mut() {
            *x = qp.fq(*x);
        }
    }
    let lo = vlo.iter().fold(slo, |a, &b| a.min(b));
    let hi = vhi.iter().fold(shi, |a, &b| a.max(b));
    (lo, hi)
}

/// Lane-blocked channel-strided fused kernel.  Two gather-free lane
/// layouts cover the cases that matter:
///
/// * `LANES % c == 0` (c in {2, 4, 8}) — each lane position maps to a
///   *fixed* channel (`l % c` is block-invariant), so per-lane
///   accumulators and a per-lane `QuantParams` table vectorize the
///   strided fold (`axis_lane_mapped`);
/// * `c % LANES == 0` (the common wide case: 16, 64, ... feature
///   channels) — every LANES-block of consecutive elements sits inside
///   one contiguous window of channels, so lanes fold straight into a
///   sliding window of per-channel accumulators (`axis_row_blocked`).
///
/// Channel counts fitting neither (non-multiples like 3, 5, 6, 12)
/// fall back to the scalar wrapped-counter loop — same bits, no lane
/// win.
pub fn minmax_fq_axis(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    if c == 1 {
        let (lo, hi) = minmax_fq(xs, ranges[0][0], ranges[0][1], bits);
        return vec![(lo, hi)];
    }
    if LANES % c == 0 {
        return axis_lane_mapped(xs, ranges, bits);
    }
    if c % LANES == 0 {
        return axis_row_blocked(xs, ranges, bits);
    }
    super::scalar::minmax_fq_axis(xs, ranges, bits)
}

/// `LANES % c == 0`: lane l always sees channel `l % c` — `CHUNK` and
/// `LANES` are multiples of `c`, so block starts are channel-aligned
/// everywhere.
fn axis_lane_mapped(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    let c = ranges.len();
    let lane_qp: Vec<QuantParams> = (0..LANES)
        .map(|l| QuantParams::from_range(ranges[l % c][0], ranges[l % c][1], bits))
        .collect();
    let mut vlo = [f32::INFINITY; LANES];
    let mut vhi = [f32::NEG_INFINITY; LANES];
    let mut tail_stats = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
    for chunk in xs.chunks_mut(CHUNK) {
        let split = chunk.len() - chunk.len() % LANES;
        let (blocks, tail) = chunk.split_at_mut(split);
        for block in blocks.chunks_exact(LANES) {
            for l in 0..LANES {
                vlo[l] = vlo[l].min(block[l]);
                vhi[l] = vhi[l].max(block[l]);
            }
        }
        for block in blocks.chunks_exact_mut(LANES) {
            for l in 0..LANES {
                block[l] = lane_qp[l].fq(block[l]);
            }
        }
        // the tail starts channel-aligned (everything before it is a
        // multiple of LANES, hence of c)
        let mut ch = 0usize;
        for x in tail.iter_mut() {
            let s = &mut tail_stats[ch];
            s.0 = s.0.min(*x);
            s.1 = s.1.max(*x);
            *x = lane_qp[ch].fq(*x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
    }
    // fold lanes into channels in increasing lane order, then the tail
    (0..c)
        .map(|ch| {
            let mut s = tail_stats[ch];
            for l in (ch..LANES).step_by(c) {
                s.0 = s.0.min(vlo[l]);
                s.1 = s.1.max(vhi[l]);
            }
            s
        })
        .collect()
}

/// `c % LANES == 0`: a LANES-block of consecutive elements never wraps
/// a channel boundary (block starts are multiples of LANES, and LANES
/// divides c), so lanes fold into a contiguous window of per-channel
/// accumulators and round through the matching window of the
/// per-channel `QuantParams` table — no gathers, no per-element
/// modulo.  Each channel's single accumulator folds its elements in
/// increasing index order, exactly like the scalar reference.
fn axis_row_blocked(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    let c = ranges.len();
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut lo = vec![f32::INFINITY; c];
    let mut hi = vec![f32::NEG_INFINITY; c];
    // xs.len() is a multiple of c and LANES | c, so there is no tail:
    // every element lives in a full LANES-block
    debug_assert_eq!(xs.len() % LANES, 0);
    let mut base = 0usize;
    for block in xs.chunks_exact_mut(LANES) {
        let lo_w = &mut lo[base..base + LANES];
        let hi_w = &mut hi[base..base + LANES];
        let qp_w = &qps[base..base + LANES];
        for l in 0..LANES {
            lo_w[l] = lo_w[l].min(block[l]);
            hi_w[l] = hi_w[l].max(block[l]);
        }
        for l in 0..LANES {
            block[l] = qp_w[l].fq(block[l]);
        }
        base += LANES;
        if base == c {
            base = 0;
        }
    }
    lo.into_iter().zip(hi).collect()
}

/// Lane-blocked fused min/max + integer store.  The stats fold is the
/// same per-lane accumulator structure as [`minmax_fq`]; the encode
/// side is element-wise (`index_of` then a `u8` narrow), so lane
/// blocking cannot change a payload bit.
pub fn fq_store_i8(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut vlo = [f32::INFINITY; LANES];
    let mut vhi = [f32::NEG_INFINITY; LANES];
    let (mut slo, mut shi) = (f32::INFINITY, f32::NEG_INFINITY);
    for (chunk, codes) in xs.chunks(CHUNK).zip(dst.chunks_mut(CHUNK)) {
        let split = chunk.len() - chunk.len() % LANES;
        let (blocks, tail) = chunk.split_at(split);
        let (cb, ct) = codes.split_at_mut(split);
        for block in blocks.chunks_exact(LANES) {
            for l in 0..LANES {
                vlo[l] = vlo[l].min(block[l]);
                vhi[l] = vhi[l].max(block[l]);
            }
        }
        for &x in tail.iter() {
            slo = slo.min(x);
            shi = shi.max(x);
        }
        for (d, block) in cb.chunks_exact_mut(LANES).zip(blocks.chunks_exact(LANES)) {
            for l in 0..LANES {
                d[l] = qp.index_of(block[l]) as u8;
            }
        }
        for (d, &x) in ct.iter_mut().zip(tail) {
            *d = qp.index_of(x) as u8;
        }
    }
    let lo = vlo.iter().fold(slo, |a, &b| a.min(b));
    let hi = vhi.iter().fold(shi, |a, &b| a.max(b));
    (lo, hi)
}

/// Lane-blocked bit-packed store: each LANES-block of elements encodes
/// into `LANES / 2` packed bytes (the lane split is a multiple of
/// `LANES`, hence even, so the packed stream stays byte-aligned at
/// every block and chunk boundary — only the tensor's final tail can
/// end mid-byte).
pub fn fq_store_i4(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut vlo = [f32::INFINITY; LANES];
    let mut vhi = [f32::NEG_INFINITY; LANES];
    let (mut slo, mut shi) = (f32::INFINITY, f32::NEG_INFINITY);
    for (chunk, codes) in xs.chunks(CHUNK).zip(dst.chunks_mut(CHUNK / 2)) {
        let split = chunk.len() - chunk.len() % LANES;
        let (blocks, tail) = chunk.split_at(split);
        let (cb, ct) = codes.split_at_mut(split / 2);
        for block in blocks.chunks_exact(LANES) {
            for l in 0..LANES {
                vlo[l] = vlo[l].min(block[l]);
                vhi[l] = vhi[l].max(block[l]);
            }
        }
        for &x in tail.iter() {
            slo = slo.min(x);
            shi = shi.max(x);
        }
        for (d, block) in cb.chunks_exact_mut(LANES / 2).zip(blocks.chunks_exact(LANES)) {
            for l in 0..LANES / 2 {
                d[l] = qp.index_of(block[2 * l]) as u8
                    | ((qp.index_of(block[2 * l + 1]) as u8) << 4);
            }
        }
        let rem = tail.chunks_exact(2).remainder();
        for (d, p) in ct.iter_mut().zip(tail.chunks_exact(2)) {
            *d = qp.index_of(p[0]) as u8 | ((qp.index_of(p[1]) as u8) << 4);
        }
        if let [x] = rem {
            ct[tail.len() / 2] = qp.index_of(*x) as u8;
        }
    }
    let lo = vlo.iter().fold(slo, |a, &b| a.min(b));
    let hi = vhi.iter().fold(shi, |a, &b| a.max(b));
    (lo, hi)
}

/// Channel-strided payload store.  `LANES % c == 0` layouts get the
/// lane-mapped fast path (per-lane `QuantParams` table, like
/// [`minmax_fq_axis`]); everything else falls back to the scalar
/// wrapped-counter loop — the encode side is store-bound, so gathered
/// layouts have no lane win.  Same bits either way.
pub fn fq_store_i8_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    if c == 1 {
        let (lo, hi) = fq_store_i8(xs, dst, ranges[0][0], ranges[0][1], bits);
        return vec![(lo, hi)];
    }
    if LANES % c != 0 {
        return super::scalar::fq_store_i8_axis(xs, dst, ranges, bits);
    }
    // lane l always sees channel l % c (CHUNK and LANES are multiples
    // of c, so block starts are channel-aligned everywhere)
    let lane_qp: Vec<QuantParams> = (0..LANES)
        .map(|l| QuantParams::from_range(ranges[l % c][0], ranges[l % c][1], bits))
        .collect();
    let mut vlo = [f32::INFINITY; LANES];
    let mut vhi = [f32::NEG_INFINITY; LANES];
    let mut tail_stats = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
    for (chunk, codes) in xs.chunks(CHUNK).zip(dst.chunks_mut(CHUNK)) {
        let split = chunk.len() - chunk.len() % LANES;
        let (blocks, tail) = chunk.split_at(split);
        let (cb, ct) = codes.split_at_mut(split);
        for block in blocks.chunks_exact(LANES) {
            for l in 0..LANES {
                vlo[l] = vlo[l].min(block[l]);
                vhi[l] = vhi[l].max(block[l]);
            }
        }
        for (d, block) in cb.chunks_exact_mut(LANES).zip(blocks.chunks_exact(LANES)) {
            for l in 0..LANES {
                d[l] = lane_qp[l].index_of(block[l]) as u8;
            }
        }
        let mut ch = 0usize;
        for (d, &x) in ct.iter_mut().zip(tail) {
            let s = &mut tail_stats[ch];
            s.0 = s.0.min(x);
            s.1 = s.1.max(x);
            *d = lane_qp[ch].index_of(x) as u8;
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
    }
    (0..c)
        .map(|ch| {
            let mut s = tail_stats[ch];
            for l in (ch..LANES).step_by(c) {
                s.0 = s.0.min(vlo[l]);
                s.1 = s.1.max(vhi[l]);
            }
            s
        })
        .collect()
}

/// Channel-strided bit-packed store: one channel runs the per-tensor
/// packed kernel; multi-channel layouts delegate to the scalar
/// reference — nibble packing across a channel stride leaves no lane
/// structure worth blocking for.
pub fn fq_store_i4_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    let c = ranges.len();
    debug_assert!(c > 0 && xs.len() % c == 0, "validated by the dispatcher");
    if c == 1 {
        let (lo, hi) = fq_store_i4(xs, dst, ranges[0][0], ranges[0][1], bits);
        return vec![(lo, hi)];
    }
    super::scalar::fq_store_i4_axis(xs, dst, ranges, bits)
}

/// Lane-blocked payload readback (element-wise decode — parity is
/// structural).
pub fn dequant_i8(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let split = dst.len() - dst.len() % LANES;
    let (db, dt) = dst.split_at_mut(split);
    let (cb, ct) = codes.split_at(split);
    for (d, c) in db.chunks_exact_mut(LANES).zip(cb.chunks_exact(LANES)) {
        for l in 0..LANES {
            d[l] = qp.value_of(c[l] as u32);
        }
    }
    for (x, &code) in dt.iter_mut().zip(ct) {
        *x = qp.value_of(code as u32);
    }
}

/// Lane-blocked bit-packed readback: `LANES / 2` bytes unpack to one
/// LANES-block of values, scalar tail for the ragged end.
pub fn dequant_i4(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let split = dst.len() - dst.len() % LANES;
    let (db, dt) = dst.split_at_mut(split);
    let (cb, ct) = codes.split_at(split / 2);
    for (d, c) in db.chunks_exact_mut(LANES).zip(cb.chunks_exact(LANES / 2)) {
        for l in 0..LANES / 2 {
            d[2 * l] = qp.value_of((c[l] & 0x0F) as u32);
            d[2 * l + 1] = qp.value_of((c[l] >> 4) as u32);
        }
    }
    for (pair, &byte) in dt.chunks_mut(2).zip(ct) {
        pair[0] = qp.value_of((byte & 0x0F) as u32);
        if let Some(x) = pair.get_mut(1) {
            *x = qp.value_of((byte >> 4) as u32);
        }
    }
}

/// Channel-strided readback: decode is load-bound, so multi-channel
/// layouts delegate to the scalar reference (same bits).
pub fn dequant_i8_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    if ranges.len() == 1 {
        return dequant_i8(codes, dst, ranges[0][0], ranges[0][1], bits);
    }
    super::scalar::dequant_i8_axis(codes, dst, ranges, bits)
}

/// Channel-strided bit-packed readback (scalar delegate past c == 1).
pub fn dequant_i4_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    if ranges.len() == 1 {
        return dequant_i4(codes, dst, ranges[0][0], ranges[0][1], bits);
    }
    super::scalar::dequant_i4_axis(codes, dst, ranges, bits)
}

/// Lane-blocked fake-quantize into a caller-owned buffer.
pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let split = src.len() - src.len() % LANES;
    let (sb, st) = src.split_at(split);
    let (db, dt) = dst.split_at_mut(split);
    for (d, s) in db.chunks_exact_mut(LANES).zip(sb.chunks_exact(LANES)) {
        for l in 0..LANES {
            d[l] = qp.fq(s[l]);
        }
    }
    for (d, &x) in dt.iter_mut().zip(st) {
        *d = qp.fq(x);
    }
}

/// Fused DSGC objective with lane-blocked quantization and the
/// reference's sequential f64 accumulation (the reduction order is
/// pinned — see the module doc).
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    let split = xs.len() - xs.len() % LANES;
    let (blocks, tail) = xs.split_at(split);
    let mut q = [0f32; LANES];
    for block in blocks.chunks_exact(LANES) {
        for l in 0..LANES {
            q[l] = qp.fq(block[l]);
        }
        for l in 0..LANES {
            let x = block[l];
            dot += x as f64 * q[l] as f64;
            na += x as f64 * x as f64;
            nb += q[l] as f64 * q[l] as f64;
        }
    }
    for &x in tail {
        let qx = qp.fq(x);
        dot += x as f64 * qx as f64;
        na += x as f64 * x as f64;
        nb += qx as f64 * qx as f64;
    }
    if na == 0.0 && nb == 0.0 {
        return 1.0;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

//! Fused single-pass quantization kernels — the paper's Fig. 3
//! accelerator contract as coordinator-side code, behind a
//! backend-dispatched hot path.
//!
//! The in-hindsight argument for hardware is that a *static* quantizer
//! can requantize the accumulator output on the way to memory while
//! folding the pre-quantization extrema into online statistics
//! registers: one traversal, no 32-bit round trip.  These kernels do
//! that work in one pass; the scalar `quant::minmax` +
//! `quant::fake_quant_slice` pair they replaced walks the tensor twice.
//!
//! Three backends implement the fused entry points ([`minmax_fq`],
//! [`minmax_fq_axis`], [`fq_into`], [`fq_cosine`]) and the
//! integer-payload family ([`fq_store_i8`], [`fq_store_i4`], their
//! `_axis` forms and the `dequant_*` readbacks — see "Integer
//! payloads" below):
//!
//! * [`scalar`] — the sequential reference; its bits are the contract.
//! * [`simd`] — lane-chunked inner loops (`simd::LANES` f32 lanes,
//!   scalar tail) shaped for the autovectorizer.
//! * [`parallel`] — rayon-free `std::thread` spans of cache-sized
//!   chunks; per-span min/max pairs merge in span order.
//!
//! Every backend is **bit-identical** to the scalar reference — the
//! differential harness in `tests/kernel_conformance.rs` pins it across
//! adversarial tensors (NaN/±inf payloads, subnormals, lane/chunk
//! boundary lengths, ragged channel layouts).  Callers therefore never
//! choose: the process-wide backend is resolved exactly once by
//! [`backend`], from `--kernel-backend` (the CLI calls
//! [`select_backend`] before any kernel runs), else the
//! `HINDSIGHT_KERNEL_BACKEND` env var, else [`auto_backend`] — and
//! every call site (`dsgc`, the simulator's store paths, the estimator
//! searches, the sweep executor's workers) picks up the fast path
//! through the same four functions.
//!
//! Numerics are bit-exact with the scalar two-pass path: every kernel
//! rounds through [`QuantParams::fq`](super::QuantParams::fq) and the min/max folds only
//! reassociate a commutative, NaN-dropping reduction, so the property
//! tests require equality, not tolerance.
//!
//! # Integer payloads
//!
//! The fake-quant kernels model a low-bit store by rewriting f32
//! values onto the grid; the payload kernels *materialize* it: the
//! `bits`-bit grid index of each element is written to a `u8` buffer —
//! one code byte per element for 5..=8 bits ([`fq_store_i8`]), two
//! codes per byte for 1..=4 bits ([`fq_store_i4`]; low nibble = even
//! flat index, final high nibble zero on odd lengths) — while the same
//! pre-quantization extrema fold into the Fig. 3 statistics.
//! [`payload_bytes`] gives the buffer size, and `dequant_*` of a
//! payload reproduces `fq(x)` bit-for-bit (both sides round through
//! [`QuantParams::index_of`](super::QuantParams::index_of) /
//! [`value_of`](super::QuantParams::value_of)), so the simulator's
//! store paths can emit real buffers whose *sizes* are the traffic
//! numbers, without changing a single output bit.

pub mod parallel;
pub mod scalar;
pub mod simd;

use std::sync::OnceLock;

/// Block size for the chunked traversal: small enough to stay
/// cache-resident, large enough that the reduction loop and the rounding
/// loop each vectorize over a full block.  A multiple of
/// [`simd::LANES`], so only a tensor's final block has a scalar tail.
pub const CHUNK: usize = 1024;

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// One of the kernel implementations behind the dispatched entry
/// points.  All backends are bit-identical; they differ only in speed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// sequential reference loops (the pinned semantics)
    Scalar,
    /// lane-chunked loops with a scalar tail (autovectorizer-shaped)
    Simd,
    /// `std::thread` chunked-parallel spans over the SIMD inner loops
    Parallel,
}

impl KernelBackend {
    /// Every backend, scalar first (the conformance reference).
    pub const ALL: [KernelBackend; 3] = [Self::Scalar, Self::Simd, Self::Parallel];

    /// The CLI/env spelling (`scalar` | `simd` | `parallel`).
    pub fn key(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Simd => "simd",
            Self::Parallel => "parallel",
        }
    }

    /// Parse a CLI/env spelling; `auto` resolves to [`auto_backend`].
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(Self::Scalar),
            "simd" => Ok(Self::Simd),
            "parallel" => Ok(Self::Parallel),
            "auto" | "" => Ok(auto_backend()),
            other => Err(format!(
                "unknown kernel backend '{other}' (scalar|simd|parallel|auto)"
            )),
        }
    }
}

impl std::fmt::Display for KernelBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// The best backend this machine supports: chunked-parallel when more
/// than one hardware thread exists (it guarantees each worker
/// [`parallel::PAR_MIN_LEN`] elements of work, so tensors under twice
/// that run the SIMD path, spawning nothing), SIMD otherwise.
pub fn auto_backend() -> KernelBackend {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if hw > 1 {
        KernelBackend::Parallel
    } else {
        KernelBackend::Simd
    }
}

/// Resolve an env-var value (`None` = unset) the way [`backend`] does,
/// as a pure function so the precedence is unit-testable.
pub fn backend_from_env(value: Option<&str>) -> Result<KernelBackend, String> {
    match value {
        None => Ok(auto_backend()),
        Some(v) => KernelBackend::parse(v),
    }
}

static BACKEND: OnceLock<KernelBackend> = OnceLock::new();

/// The process-wide backend, resolved exactly once: an earlier
/// [`select_backend`] call (CLI) wins, else `HINDSIGHT_KERNEL_BACKEND`,
/// else [`auto_backend`].  An unparseable env value logs a warning and
/// falls back to auto rather than poisoning every kernel call.
pub fn backend() -> KernelBackend {
    *BACKEND.get_or_init(|| {
        let env = std::env::var("HINDSIGHT_KERNEL_BACKEND").ok();
        backend_from_env(env.as_deref()).unwrap_or_else(|e| {
            log::warn!("HINDSIGHT_KERNEL_BACKEND: {e}; using auto");
            auto_backend()
        })
    })
}

/// Pin the process-wide backend (the `--kernel-backend` path; CLI
/// beats env because the CLI calls this before any kernel runs).
/// Re-selecting the already-resolved backend is a no-op; conflicting
/// with an earlier resolution is an error — a half-switched process
/// would make perf numbers unattributable.
pub fn select_backend(kind: KernelBackend) -> Result<(), String> {
    match BACKEND.set(kind) {
        Ok(()) => Ok(()),
        Err(_) => {
            let current = *BACKEND.get().expect("set failed, so the cell is full");
            if current == kind {
                Ok(())
            } else {
                Err(format!(
                    "kernel backend already resolved to '{current}' — select \
                     '{kind}' before the first kernel call"
                ))
            }
        }
    }
}

/// The already-resolved process-wide backend, if any — `None` while the
/// choice is still open (no CLI selection, no kernel call yet).  Lets
/// calibration-time autotuning pin a *measured* winner without racing
/// the lazy env/heuristic resolution in [`backend`].
pub fn resolved_backend() -> Option<KernelBackend> {
    BACKEND.get().copied()
}

static MEASURED_AUTO: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Ask for the *measured* auto policy: the CLI calls this for
/// `--kernel-backend auto` instead of pinning the core-count heuristic,
/// leaving the process backend unresolved so that calibration can
/// autotune the candidate backends on real site shapes and
/// [`select_backend`] the winner.  Subcommands that never calibrate
/// still resolve lazily through [`backend`]'s heuristic.
pub fn request_measured_auto() {
    MEASURED_AUTO.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// Whether [`request_measured_auto`] was called (the trainer's
/// calibration hook checks this before pinning its measured winner).
pub fn measured_auto_requested() -> bool {
    MEASURED_AUTO.load(std::sync::atomic::Ordering::Relaxed)
}

// ---------------------------------------------------------------------------
// Checked contracts
// ---------------------------------------------------------------------------

/// Contract violations of the axis kernel, surfaced as values so
/// callers assembling ranges from external input (schemes, manifests,
/// stores) can reject them instead of panicking a worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq, thiserror::Error)]
pub enum KernelError {
    /// `ranges` was empty — no channel grid to quantize onto.
    #[error("minmax_fq_axis needs at least one channel (empty ranges)")]
    NoChannels,
    /// The tensor does not divide into `channels` channels-last groups:
    /// quantizing anyway would silently misassign every element after
    /// the first wrap to a neighbouring channel's grid.
    #[error(
        "tensor length {len} not divisible by {channels} channels — ragged \
         channels-last layout; refusing to misquantize"
    )]
    RaggedAxis { len: usize, channels: usize },
}

// ---------------------------------------------------------------------------
// Dispatched entry points
// ---------------------------------------------------------------------------

/// Fused min/max + fake-quantize in place (the Fig. 3 static-store
/// path): returns the (min, max) of the *original* values while
/// rewriting `xs` to the `[qmin, qmax]` grid.  `(0.0, 0.0)` on an empty
/// slice, matching [`super::minmax`].  Runs on the process-wide
/// [`backend`].
pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    minmax_fq_on(backend(), xs, qmin, qmax, bits)
}

/// [`minmax_fq`] on an explicit backend (benches and the conformance
/// harness; call sites use the dispatched form).
pub fn minmax_fq_on(
    b: KernelBackend,
    xs: &mut [f32],
    qmin: f32,
    qmax: f32,
    bits: u32,
) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    match b {
        KernelBackend::Scalar => scalar::minmax_fq(xs, qmin, qmax, bits),
        KernelBackend::Simd => simd::minmax_fq(xs, qmin, qmax, bits),
        KernelBackend::Parallel => parallel::minmax_fq(xs, qmin, qmax, bits),
    }
}

/// Channel-strided fused min/max + fake-quantize in place — the
/// per-channel counterpart of [`minmax_fq`].  Channels-last layout: the
/// channel of flat element `i` is `i % ranges.len()` (the convention the
/// per-channel estimator adapter and the simulator share).  Returns one
/// `(min, max)` per channel, `(0.0, 0.0)` rows on an empty slice.
///
/// Panics on a ragged layout; [`try_minmax_fq_axis`] is the checked
/// form for callers whose ranges come from external input.
pub fn minmax_fq_axis(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    try_minmax_fq_axis(xs, ranges, bits).unwrap_or_else(|e| panic!("{e}"))
}

/// Checked [`minmax_fq_axis`]: rejects an empty channel set and tensors
/// whose length is not a multiple of the channel count, the two caller
/// mistakes that would otherwise misquantize silently (or panic a
/// sweep worker).  Validation happens once here, before dispatch, so
/// every backend shares the same contract.
pub fn try_minmax_fq_axis(
    xs: &mut [f32],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Result<Vec<(f32, f32)>, KernelError> {
    try_minmax_fq_axis_on(backend(), xs, ranges, bits)
}

/// [`try_minmax_fq_axis`] on an explicit backend.
pub fn try_minmax_fq_axis_on(
    b: KernelBackend,
    xs: &mut [f32],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Result<Vec<(f32, f32)>, KernelError> {
    let c = ranges.len();
    if c == 0 {
        return Err(KernelError::NoChannels);
    }
    if xs.len() % c != 0 {
        return Err(KernelError::RaggedAxis {
            len: xs.len(),
            channels: c,
        });
    }
    if xs.is_empty() {
        return Ok(vec![(0.0, 0.0); c]);
    }
    Ok(match b {
        KernelBackend::Scalar => scalar::minmax_fq_axis(xs, ranges, bits),
        KernelBackend::Simd => simd::minmax_fq_axis(xs, ranges, bits),
        KernelBackend::Parallel => parallel::minmax_fq_axis(xs, ranges, bits),
    })
}

/// [`minmax_fq_axis`] on an explicit backend, panicking form.
pub fn minmax_fq_axis_on(
    b: KernelBackend,
    xs: &mut [f32],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    try_minmax_fq_axis_on(b, xs, ranges, bits).unwrap_or_else(|e| panic!("{e}"))
}

/// Fake-quantize `src` into a caller-owned buffer (the no-alloc variant
/// of [`super::fake_quant`]).  Panics if the lengths differ.
pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    fq_into_on(backend(), src, dst, qmin, qmax, bits)
}

/// [`fq_into`] on an explicit backend.
pub fn fq_into_on(b: KernelBackend, src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    assert_eq!(src.len(), dst.len(), "fq_into buffer length mismatch");
    match b {
        KernelBackend::Scalar => scalar::fq_into(src, dst, qmin, qmax, bits),
        KernelBackend::Simd => simd::fq_into(src, dst, qmin, qmax, bits),
        KernelBackend::Parallel => parallel::fq_into(src, dst, qmin, qmax, bits),
    }
}

/// Fused DSGC objective: `cosine(x, fake_quant(x))` in one traversal,
/// never materializing the quantized tensor.  Identical accumulation
/// order to `cosine_similarity(x, &fake_quant(x, ..))` on every backend
/// (the f64 reduction never reassociates), so results are bit-equal to
/// the scalar two-pass form (including the zero-vector conventions).
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    fq_cosine_on(backend(), xs, qmin, qmax, bits)
}

/// [`fq_cosine`] on an explicit backend.
pub fn fq_cosine_on(b: KernelBackend, xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    match b {
        KernelBackend::Scalar => scalar::fq_cosine(xs, qmin, qmax, bits),
        KernelBackend::Simd => simd::fq_cosine(xs, qmin, qmax, bits),
        KernelBackend::Parallel => parallel::fq_cosine(xs, qmin, qmax, bits),
    }
}

// ---------------------------------------------------------------------------
// Integer-payload stores
// ---------------------------------------------------------------------------

/// Payload buffer size in bytes for `elems` codes at `bits` bits: two
/// codes per byte up to 4 bits (the [`fq_store_i4`] packing), one code
/// byte each for 5..=8 bits ([`fq_store_i8`]).
pub fn payload_bytes(elems: usize, bits: u32) -> usize {
    assert!(
        (1..=8).contains(&bits),
        "integer payloads cover 1..=8 bits (got {bits})"
    );
    if bits <= 4 {
        elems.div_ceil(2)
    } else {
        elems
    }
}

/// Fused min/max + integer store: quantize `xs` onto the `[qmin, qmax]`
/// grid, writing one `bits`-bit code byte per element into `dst`
/// (`bits <= 8`; `dst.len() == xs.len()`), and return the
/// pre-quantization `(min, max)` exactly like [`minmax_fq`] —
/// `(0.0, 0.0)` on an empty slice.  `xs` is untouched; the grid values
/// come back via [`dequant_i8`], bit-identical to `fq`.
pub fn fq_store_i8(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    fq_store_i8_on(backend(), xs, dst, qmin, qmax, bits)
}

/// [`fq_store_i8`] on an explicit backend.
pub fn fq_store_i8_on(
    b: KernelBackend,
    xs: &[f32],
    dst: &mut [u8],
    qmin: f32,
    qmax: f32,
    bits: u32,
) -> (f32, f32) {
    assert!(
        (1..=8).contains(&bits),
        "fq_store_i8 encodes 1..=8-bit codes (got {bits})"
    );
    assert_eq!(xs.len(), dst.len(), "fq_store_i8 payload length mismatch");
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    match b {
        KernelBackend::Scalar => scalar::fq_store_i8(xs, dst, qmin, qmax, bits),
        KernelBackend::Simd => simd::fq_store_i8(xs, dst, qmin, qmax, bits),
        KernelBackend::Parallel => parallel::fq_store_i8(xs, dst, qmin, qmax, bits),
    }
}

/// Bit-packed 4-bit payload store: two codes per byte (`bits <= 4`,
/// `dst.len() == xs.len().div_ceil(2)`; low nibble = even flat index,
/// the final byte's high nibble stays zero on odd lengths).  Stats and
/// empty-slice conventions as in [`fq_store_i8`].
pub fn fq_store_i4(xs: &[f32], dst: &mut [u8], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    fq_store_i4_on(backend(), xs, dst, qmin, qmax, bits)
}

/// [`fq_store_i4`] on an explicit backend.
pub fn fq_store_i4_on(
    b: KernelBackend,
    xs: &[f32],
    dst: &mut [u8],
    qmin: f32,
    qmax: f32,
    bits: u32,
) -> (f32, f32) {
    assert!(
        (1..=4).contains(&bits),
        "fq_store_i4 packs 1..=4-bit codes (got {bits})"
    );
    assert_eq!(
        xs.len().div_ceil(2),
        dst.len(),
        "fq_store_i4 payload length mismatch"
    );
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    match b {
        KernelBackend::Scalar => scalar::fq_store_i4(xs, dst, qmin, qmax, bits),
        KernelBackend::Simd => simd::fq_store_i4(xs, dst, qmin, qmax, bits),
        KernelBackend::Parallel => parallel::fq_store_i4(xs, dst, qmin, qmax, bits),
    }
}

/// Channel-strided payload store (channels-last, one code byte per
/// element).  Returns per-channel pre-quantization stats; `(0.0, 0.0)`
/// rows on an empty slice.  Panicking form of
/// [`try_fq_store_i8_axis`].
pub fn fq_store_i8_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    try_fq_store_i8_axis(xs, dst, ranges, bits).unwrap_or_else(|e| panic!("{e}"))
}

/// Checked [`fq_store_i8_axis`]: same channel-layout contract as
/// [`try_minmax_fq_axis`], plus the payload length check.
pub fn try_fq_store_i8_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Result<Vec<(f32, f32)>, KernelError> {
    try_fq_store_i8_axis_on(backend(), xs, dst, ranges, bits)
}

/// [`try_fq_store_i8_axis`] on an explicit backend.
pub fn try_fq_store_i8_axis_on(
    b: KernelBackend,
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Result<Vec<(f32, f32)>, KernelError> {
    assert!(
        (1..=8).contains(&bits),
        "fq_store_i8_axis encodes 1..=8-bit codes (got {bits})"
    );
    assert_eq!(
        xs.len(),
        dst.len(),
        "fq_store_i8_axis payload length mismatch"
    );
    let c = ranges.len();
    if c == 0 {
        return Err(KernelError::NoChannels);
    }
    if xs.len() % c != 0 {
        return Err(KernelError::RaggedAxis {
            len: xs.len(),
            channels: c,
        });
    }
    if xs.is_empty() {
        return Ok(vec![(0.0, 0.0); c]);
    }
    Ok(match b {
        KernelBackend::Scalar => scalar::fq_store_i8_axis(xs, dst, ranges, bits),
        KernelBackend::Simd => simd::fq_store_i8_axis(xs, dst, ranges, bits),
        KernelBackend::Parallel => parallel::fq_store_i8_axis(xs, dst, ranges, bits),
    })
}

/// Channel-strided bit-packed store; packing is flat-index based, so an
/// odd channel count simply drifts the byte boundary across channels.
/// Panicking form of [`try_fq_store_i4_axis`].
pub fn fq_store_i4_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Vec<(f32, f32)> {
    try_fq_store_i4_axis(xs, dst, ranges, bits).unwrap_or_else(|e| panic!("{e}"))
}

/// Checked [`fq_store_i4_axis`].
pub fn try_fq_store_i4_axis(
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Result<Vec<(f32, f32)>, KernelError> {
    try_fq_store_i4_axis_on(backend(), xs, dst, ranges, bits)
}

/// [`try_fq_store_i4_axis`] on an explicit backend.
pub fn try_fq_store_i4_axis_on(
    b: KernelBackend,
    xs: &[f32],
    dst: &mut [u8],
    ranges: &[[f32; 2]],
    bits: u32,
) -> Result<Vec<(f32, f32)>, KernelError> {
    assert!(
        (1..=4).contains(&bits),
        "fq_store_i4_axis packs 1..=4-bit codes (got {bits})"
    );
    assert_eq!(
        xs.len().div_ceil(2),
        dst.len(),
        "fq_store_i4_axis payload length mismatch"
    );
    let c = ranges.len();
    if c == 0 {
        return Err(KernelError::NoChannels);
    }
    if xs.len() % c != 0 {
        return Err(KernelError::RaggedAxis {
            len: xs.len(),
            channels: c,
        });
    }
    if xs.is_empty() {
        return Ok(vec![(0.0, 0.0); c]);
    }
    Ok(match b {
        KernelBackend::Scalar => scalar::fq_store_i4_axis(xs, dst, ranges, bits),
        KernelBackend::Simd => simd::fq_store_i4_axis(xs, dst, ranges, bits),
        KernelBackend::Parallel => parallel::fq_store_i4_axis(xs, dst, ranges, bits),
    })
}

/// Payload readback: decode an [`fq_store_i8`] buffer into grid values
/// (`dst.len() == codes.len()`), bit-identical to what `fq` would have
/// produced from the original tensor.
pub fn dequant_i8(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    dequant_i8_on(backend(), codes, dst, qmin, qmax, bits)
}

/// [`dequant_i8`] on an explicit backend.
pub fn dequant_i8_on(
    b: KernelBackend,
    codes: &[u8],
    dst: &mut [f32],
    qmin: f32,
    qmax: f32,
    bits: u32,
) {
    assert!(
        (1..=8).contains(&bits),
        "dequant_i8 decodes 1..=8-bit codes (got {bits})"
    );
    assert_eq!(codes.len(), dst.len(), "dequant_i8 payload length mismatch");
    match b {
        KernelBackend::Scalar => scalar::dequant_i8(codes, dst, qmin, qmax, bits),
        KernelBackend::Simd => simd::dequant_i8(codes, dst, qmin, qmax, bits),
        KernelBackend::Parallel => parallel::dequant_i8(codes, dst, qmin, qmax, bits),
    }
}

/// Bit-packed readback: decode an [`fq_store_i4`] buffer; `dst.len()`
/// is the element count (`codes.len() == dst.len().div_ceil(2)`).
pub fn dequant_i4(codes: &[u8], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    dequant_i4_on(backend(), codes, dst, qmin, qmax, bits)
}

/// [`dequant_i4`] on an explicit backend.
pub fn dequant_i4_on(
    b: KernelBackend,
    codes: &[u8],
    dst: &mut [f32],
    qmin: f32,
    qmax: f32,
    bits: u32,
) {
    assert!(
        (1..=4).contains(&bits),
        "dequant_i4 decodes 1..=4-bit codes (got {bits})"
    );
    assert_eq!(
        codes.len(),
        dst.len().div_ceil(2),
        "dequant_i4 payload length mismatch"
    );
    match b {
        KernelBackend::Scalar => scalar::dequant_i4(codes, dst, qmin, qmax, bits),
        KernelBackend::Simd => simd::dequant_i4(codes, dst, qmin, qmax, bits),
        KernelBackend::Parallel => parallel::dequant_i4(codes, dst, qmin, qmax, bits),
    }
}

/// Channel-strided readback of an [`fq_store_i8_axis`] payload.  The
/// layout was validated by the paired store, so this form panics on a
/// mismatch rather than returning a `Result`.
pub fn dequant_i8_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    dequant_i8_axis_on(backend(), codes, dst, ranges, bits)
}

/// [`dequant_i8_axis`] on an explicit backend.
pub fn dequant_i8_axis_on(
    b: KernelBackend,
    codes: &[u8],
    dst: &mut [f32],
    ranges: &[[f32; 2]],
    bits: u32,
) {
    assert_eq!(
        codes.len(),
        dst.len(),
        "dequant_i8_axis payload length mismatch"
    );
    let c = ranges.len();
    assert!(c > 0 && dst.len() % c == 0, "dequant_i8_axis channel layout");
    if dst.is_empty() {
        return;
    }
    match b {
        KernelBackend::Scalar => scalar::dequant_i8_axis(codes, dst, ranges, bits),
        KernelBackend::Simd => simd::dequant_i8_axis(codes, dst, ranges, bits),
        KernelBackend::Parallel => parallel::dequant_i8_axis(codes, dst, ranges, bits),
    }
}

/// Channel-strided readback of an [`fq_store_i4_axis`] payload.
pub fn dequant_i4_axis(codes: &[u8], dst: &mut [f32], ranges: &[[f32; 2]], bits: u32) {
    dequant_i4_axis_on(backend(), codes, dst, ranges, bits)
}

/// [`dequant_i4_axis`] on an explicit backend.
pub fn dequant_i4_axis_on(
    b: KernelBackend,
    codes: &[u8],
    dst: &mut [f32],
    ranges: &[[f32; 2]],
    bits: u32,
) {
    assert_eq!(
        codes.len(),
        dst.len().div_ceil(2),
        "dequant_i4_axis payload length mismatch"
    );
    let c = ranges.len();
    assert!(c > 0 && dst.len() % c == 0, "dequant_i4_axis channel layout");
    if dst.is_empty() {
        return;
    }
    match b {
        KernelBackend::Scalar => scalar::dequant_i4_axis(codes, dst, ranges, bits),
        KernelBackend::Simd => simd::dequant_i4_axis(codes, dst, ranges, bits),
        KernelBackend::Parallel => parallel::dequant_i4_axis(codes, dst, ranges, bits),
    }
}

// ---------------------------------------------------------------------------
// Per-site autotuning
// ---------------------------------------------------------------------------

/// One measured backend pick for a tensor shape: which backend won a
/// timed fused-store shootout on `elems` elements at `bits` bits, and
/// the timings that prove it.  Cached per site by the range manager at
/// calibration; surfaced as the `autotune` field of bench records.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Autotune {
    /// the measured winner
    pub backend: KernelBackend,
    pub elems: usize,
    pub bits: u32,
    /// mean seconds per fused pass for the winner
    pub best_s: f64,
    /// mean seconds per fused pass for the scalar reference
    pub scalar_s: f64,
}

impl Autotune {
    /// Measured speedup of the winner over the scalar reference.
    pub fn speedup(&self) -> f64 {
        if self.best_s > 0.0 {
            self.scalar_s / self.best_s
        } else {
            1.0
        }
    }
}

/// Time every backend's fused `minmax_fq` pass on a synthetic tensor of
/// `elems` elements and return the measured winner.  Bit-parity makes
/// the choice purely a speed question, so the pick is safe whatever the
/// timings say; the input is deterministic (seeded), only the timings —
/// and on a loaded machine possibly the winner — vary run to run.
/// Iteration count scales inversely with `elems` to keep calibration
/// cheap on large sites without starving small ones of samples.
pub fn autotune_minmax_fq(elems: usize, bits: u32) -> Autotune {
    let mut rng = crate::util::rng::Pcg32::new(0x7A_0E, elems as u64);
    let mut xs: Vec<f32> = (0..elems).map(|_| rng.normal()).collect();
    let iters = ((1usize << 21) / elems.max(1)).clamp(2, 16);
    let mut scalar_s = f64::INFINITY;
    let mut best = (KernelBackend::Scalar, f64::INFINITY);
    for b in KernelBackend::ALL {
        // warmup pass, then timed passes; re-quantizing an already
        // on-grid tensor costs the same traversal, so no reset needed
        let _ = minmax_fq_on(b, &mut xs, -3.0, 3.0, bits);
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            let _ = minmax_fq_on(b, &mut xs, -3.0, 3.0, bits);
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        if b == KernelBackend::Scalar {
            scalar_s = dt;
        }
        // strict < keeps the earlier (ALL-order) backend on a tie, so
        // the pick is deterministic given the timings
        if dt < best.1 {
            best = (b, dt);
        }
    }
    Autotune {
        backend: best.0,
        elems,
        bits,
        best_s: best.1,
        scalar_s,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{cosine_similarity, fake_quant, fake_quant_slice, minmax};
    use crate::util::testkit::{forall, gens};

    fn case(rng: &mut crate::util::rng::Pcg32) -> (f32, f32, u32, Vec<f32>) {
        let (lo, hi) = gens::range(rng);
        let bits = gens::bits(rng);
        // span several chunks sometimes so the chunked path is exercised
        let xs = gens::tensor(rng, 3 * CHUNK);
        (lo, hi, bits, xs)
    }

    #[test]
    fn minmax_fq_equals_scalar_two_pass() {
        forall(96, "minmax_fq-parity", case, |(lo, hi, bits, xs)| {
            let mut fused = xs.clone();
            let stats = minmax_fq(&mut fused, *lo, *hi, *bits);
            let mut scalar = xs.clone();
            let expect_stats = minmax(&scalar);
            fake_quant_slice(&mut scalar, *lo, *hi, *bits);
            stats == expect_stats && fused == scalar
        });
    }

    #[test]
    fn every_backend_equals_the_scalar_two_pass() {
        // the deep differential coverage lives in
        // tests/kernel_conformance.rs; this pins the `_on` plumbing
        forall(32, "backend-parity", case, |(lo, hi, bits, xs)| {
            KernelBackend::ALL.iter().all(|&b| {
                let mut fused = xs.clone();
                let stats = minmax_fq_on(b, &mut fused, *lo, *hi, *bits);
                let mut scalar = xs.clone();
                let expect_stats = minmax(&scalar);
                fake_quant_slice(&mut scalar, *lo, *hi, *bits);
                stats == expect_stats && fused == scalar
            })
        });
    }

    #[test]
    fn fq_into_equals_fake_quant() {
        forall(96, "fq_into-parity", case, |(lo, hi, bits, xs)| {
            let mut dst = vec![0.0f32; xs.len()];
            fq_into(xs, &mut dst, *lo, *hi, *bits);
            dst == fake_quant(xs, *lo, *hi, *bits)
        });
    }

    #[test]
    fn fq_cosine_equals_two_pass_cosine() {
        forall(96, "fq_cosine-parity", case, |(lo, hi, bits, xs)| {
            let fused = fq_cosine(xs, *lo, *hi, *bits);
            let q = fake_quant(xs, *lo, *hi, *bits);
            fused == cosine_similarity(xs, &q)
        });
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        for b in KernelBackend::ALL {
            assert_eq!(minmax_fq_on(b, &mut [], -1.0, 1.0, 8), (0.0, 0.0));
            fq_into_on(b, &[], &mut [], -1.0, 1.0, 8);
            // all-zero tensor quantizes to itself: cosine convention is 1
            assert_eq!(fq_cosine_on(b, &[0.0; 8], -1.0, 1.0, 8), 1.0);
            // degenerate range: outputs collapse to the guarded near-zero grid
            let mut xs = [0.5f32, -0.5];
            let (lo, hi) = minmax_fq_on(b, &mut xs, 0.0, 0.0, 8);
            assert_eq!((lo, hi), (-0.5, 0.5));
            assert!(xs.iter().all(|&x| x.is_finite() && x.abs() < 1e-9));
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn fq_into_rejects_mismatched_buffers() {
        let mut dst = [0.0f32; 2];
        fq_into(&[1.0], &mut dst, -1.0, 1.0, 8);
    }

    // ------------------------------------------------------------------
    // Integer payloads
    // ------------------------------------------------------------------

    #[test]
    fn payload_bytes_policy() {
        assert_eq!(payload_bytes(10, 8), 10);
        assert_eq!(payload_bytes(10, 6), 10); // unpacked: a byte per code
        assert_eq!(payload_bytes(10, 4), 5);
        assert_eq!(payload_bytes(11, 4), 6); // odd length rounds up
        assert_eq!(payload_bytes(11, 2), 6);
        assert_eq!(payload_bytes(0, 4), 0);
    }

    #[test]
    #[should_panic(expected = "1..=8 bits")]
    fn payload_bytes_rejects_wide_codes() {
        payload_bytes(4, 16);
    }

    #[test]
    fn i8_payload_round_trip_equals_fake_quant() {
        forall(96, "i8-roundtrip", case, |(lo, hi, bits, xs)| {
            let mut codes = vec![0u8; xs.len()];
            let stats = fq_store_i8(xs, &mut codes, *lo, *hi, *bits);
            let mut back = vec![0.0f32; xs.len()];
            dequant_i8(&codes, &mut back, *lo, *hi, *bits);
            stats == minmax(xs) && back == fake_quant(xs, *lo, *hi, *bits)
        });
    }

    #[test]
    fn i4_payload_round_trip_equals_fake_quant() {
        forall(96, "i4-roundtrip", case, |(lo, hi, bits, xs)| {
            let bits = (*bits).min(4);
            let mut codes = vec![0u8; xs.len().div_ceil(2)];
            let stats = fq_store_i4(xs, &mut codes, *lo, *hi, bits);
            let mut back = vec![0.0f32; xs.len()];
            dequant_i4(&codes, &mut back, *lo, *hi, bits);
            stats == minmax(xs) && back == fake_quant(xs, *lo, *hi, bits)
        });
    }

    #[test]
    fn i4_odd_length_parks_the_last_high_nibble_at_zero() {
        let xs = [0.5f32, -0.5, 0.25];
        let mut codes = vec![0xFFu8; 2];
        fq_store_i4(&xs, &mut codes, -1.0, 1.0, 4);
        assert_eq!(codes[1] >> 4, 0, "odd tail must zero the spare nibble");
    }

    #[test]
    fn axis_payload_round_trips_on_every_backend() {
        let ranges = [[-1.0f32, 1.0], [-2.0, 2.0], [0.0, 4.0]];
        let xs: Vec<f32> = (0..3 * 7).map(|i| (i as f32) * 0.17 - 1.5).collect();
        for b in KernelBackend::ALL {
            let mut c8 = vec![0u8; xs.len()];
            let s8 = try_fq_store_i8_axis_on(b, &xs, &mut c8, &ranges, 8).unwrap();
            let mut back8 = vec![0.0f32; xs.len()];
            dequant_i8_axis_on(b, &c8, &mut back8, &ranges, 8);
            let mut expect = xs.clone();
            let expect_stats = minmax_fq_axis(&mut expect, &ranges, 8);
            assert_eq!(s8, expect_stats);
            assert_eq!(back8, expect);

            let mut c4 = vec![0u8; xs.len().div_ceil(2)];
            let s4 = try_fq_store_i4_axis_on(b, &xs, &mut c4, &ranges, 4).unwrap();
            let mut back4 = vec![0.0f32; xs.len()];
            dequant_i4_axis_on(b, &c4, &mut back4, &ranges, 4);
            let mut expect4 = xs.clone();
            let expect4_stats = minmax_fq_axis(&mut expect4, &ranges, 4);
            assert_eq!(s4, expect4_stats);
            assert_eq!(back4, expect4);
        }
    }

    #[test]
    fn payload_axis_contracts_match_the_fq_axis_ones() {
        let xs = [1.0f32, 2.0, 3.0];
        let mut dst = vec![0u8; 3];
        assert_eq!(
            try_fq_store_i8_axis(&xs, &mut dst, &[[-1.0, 1.0]; 2], 8).unwrap_err(),
            KernelError::RaggedAxis { len: 3, channels: 2 }
        );
        assert_eq!(
            try_fq_store_i8_axis(&xs, &mut dst, &[], 8).unwrap_err(),
            KernelError::NoChannels
        );
        let mut dst4 = vec![0u8; 2];
        assert_eq!(
            try_fq_store_i4_axis(&xs, &mut dst4, &[[-1.0, 1.0]; 2], 4).unwrap_err(),
            KernelError::RaggedAxis { len: 3, channels: 2 }
        );
        // empty slices: stats rows by convention, payloads untouched
        assert_eq!(
            try_fq_store_i4_axis(&[], &mut [], &[[-1.0, 1.0]; 5], 4).unwrap(),
            vec![(0.0, 0.0); 5]
        );
    }

    #[test]
    #[should_panic(expected = "payload length mismatch")]
    fn fq_store_i8_rejects_short_payload_buffers() {
        let mut dst = [0u8; 1];
        fq_store_i8(&[1.0, 2.0], &mut dst, -1.0, 1.0, 8);
    }

    #[test]
    #[should_panic(expected = "payload length mismatch")]
    fn fq_store_i4_rejects_unpacked_buffers() {
        // an i8-sized buffer for a packed store is the classic caller bug
        let mut dst = [0u8; 4];
        fq_store_i4(&[1.0, 2.0, 3.0, 4.0], &mut dst, -1.0, 1.0, 4);
    }

    #[test]
    #[should_panic(expected = "1..=4-bit")]
    fn fq_store_i4_rejects_wide_codes() {
        let mut dst = [0u8; 1];
        fq_store_i4(&[1.0, 2.0], &mut dst, -1.0, 1.0, 8);
    }

    // ------------------------------------------------------------------
    // Autotune
    // ------------------------------------------------------------------

    #[test]
    fn autotune_measures_every_backend_and_picks_one() {
        let at = autotune_minmax_fq(4 * CHUNK, 8);
        assert_eq!(at.elems, 4 * CHUNK);
        assert_eq!(at.bits, 8);
        assert!(at.best_s > 0.0 && at.scalar_s > 0.0);
        // the winner can never be slower than the scalar sample
        assert!(at.best_s <= at.scalar_s);
        assert!(at.speedup() >= 1.0);
        assert!(KernelBackend::ALL.contains(&at.backend));
    }

    // ------------------------------------------------------------------
    // Backend selection
    // ------------------------------------------------------------------

    #[test]
    fn backend_keys_round_trip() {
        for b in KernelBackend::ALL {
            assert_eq!(KernelBackend::parse(b.key()), Ok(b));
            assert_eq!(format!("{b}"), b.key());
        }
        assert_eq!(KernelBackend::parse("SIMD"), Ok(KernelBackend::Simd));
        assert!(KernelBackend::parse("avx512").is_err());
    }

    #[test]
    fn env_resolution_precedence() {
        // unset -> auto; `auto` -> auto; explicit key -> that backend
        assert_eq!(backend_from_env(None), Ok(auto_backend()));
        assert_eq!(backend_from_env(Some("auto")), Ok(auto_backend()));
        assert_eq!(backend_from_env(Some("scalar")), Ok(KernelBackend::Scalar));
        assert_eq!(
            backend_from_env(Some("parallel")),
            Ok(KernelBackend::Parallel)
        );
        assert!(backend_from_env(Some("gpu")).is_err());
        // auto never picks the reference loops: scalar exists to pin
        // semantics, not to be the default
        assert_ne!(auto_backend(), KernelBackend::Scalar);
    }

    // ------------------------------------------------------------------
    // Per-channel axis kernel
    // ------------------------------------------------------------------

    /// The scalar per-channel reference: gather each channel's strided
    /// slice, two-pass `minmax` + `fake_quant_slice`, scatter back.
    fn axis_scalar_reference(
        xs: &[f32],
        ranges: &[[f32; 2]],
        bits: u32,
    ) -> (Vec<f32>, Vec<(f32, f32)>) {
        let c = ranges.len();
        let mut out = xs.to_vec();
        let mut stats = vec![(0.0f32, 0.0f32); c];
        for ch in 0..c {
            let mut chan: Vec<f32> = xs.iter().skip(ch).step_by(c).copied().collect();
            stats[ch] = minmax(&chan);
            fake_quant_slice(&mut chan, ranges[ch][0], ranges[ch][1], bits);
            for (k, v) in chan.iter().enumerate() {
                out[ch + k * c] = *v;
            }
        }
        (out, stats)
    }

    fn axis_case(rng: &mut crate::util::rng::Pcg32) -> (u32, Vec<[f32; 2]>, Vec<f32>) {
        let bits = gens::bits(rng);
        let c = 1 + rng.below(8);
        let ranges: Vec<[f32; 2]> = (0..c)
            .map(|_| {
                let (lo, hi) = gens::range(rng);
                [lo, hi]
            })
            .collect();
        // sometimes span several channel-aligned blocks
        let per_chan = rng.below(2 * CHUNK / c + 2);
        let scale = 10f32.powf(rng.range(-3.0, 3.0));
        let xs: Vec<f32> = (0..per_chan * c).map(|_| rng.normal() * scale).collect();
        (bits, ranges, xs)
    }

    #[test]
    fn minmax_fq_axis_equals_scalar_per_channel_reference() {
        forall(96, "minmax_fq_axis-parity", axis_case, |(bits, ranges, xs)| {
            let mut fused = xs.clone();
            let stats = minmax_fq_axis(&mut fused, ranges, *bits);
            let (expect, expect_stats) = axis_scalar_reference(xs, ranges, *bits);
            stats == expect_stats && fused == expect
        });
    }

    #[test]
    fn minmax_fq_axis_with_one_channel_equals_minmax_fq() {
        forall(64, "axis-1ch-parity", case, |(lo, hi, bits, xs)| {
            let mut a = xs.clone();
            let sa = minmax_fq_axis(&mut a, &[[*lo, *hi]], *bits);
            let mut b = xs.clone();
            let sb = minmax_fq(&mut b, *lo, *hi, *bits);
            sa == vec![sb] && a == b
        });
    }

    #[test]
    fn minmax_fq_axis_empty_and_degenerate() {
        assert_eq!(minmax_fq_axis(&mut [], &[[-1.0, 1.0]; 3], 8), vec![(0.0, 0.0); 3]);
        // degenerate per-channel ranges collapse to the guarded grid
        let mut xs = [0.5f32, -0.5, 0.25, -0.25];
        let stats = minmax_fq_axis(&mut xs, &[[0.0, 0.0], [0.0, 0.0]], 8);
        assert_eq!(stats, vec![(0.25, 0.5), (-0.5, -0.25)]);
        assert!(xs.iter().all(|&x| x.is_finite() && x.abs() < 1e-9));
    }

    /// Regression (satellite): length-vs-`ranges` mismatches are a
    /// checked contract on every backend — the dispatcher validates
    /// before any kernel sees the tensor — not a caller-trusted layout
    /// that silently misquantizes.
    #[test]
    fn ragged_axis_layouts_are_a_checked_error() {
        for b in KernelBackend::ALL {
            let mut xs = [1.0f32, 2.0, 3.0];
            let err = try_minmax_fq_axis_on(b, &mut xs, &[[-1.0, 1.0]; 2], 8).unwrap_err();
            assert_eq!(err, KernelError::RaggedAxis { len: 3, channels: 2 });
            assert!(err.to_string().contains("not divisible"), "{err}");
            assert_eq!(xs, [1.0, 2.0, 3.0], "tensor untouched on rejection");

            let err = try_minmax_fq_axis_on(b, &mut xs, &[], 8).unwrap_err();
            assert_eq!(err, KernelError::NoChannels);
            assert!(err.to_string().contains("at least one channel"), "{err}");
        }
        // empty tensors are fine with any channel count (0 % c == 0)
        assert_eq!(
            try_minmax_fq_axis(&mut [], &[[-1.0, 1.0]; 5], 8).unwrap(),
            vec![(0.0, 0.0); 5]
        );
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn minmax_fq_axis_rejects_misaligned_tensors() {
        minmax_fq_axis(&mut [1.0, 2.0, 3.0], &[[-1.0, 1.0], [-1.0, 1.0]], 8);
    }

    #[test]
    #[should_panic(expected = "at least one channel")]
    fn minmax_fq_axis_rejects_empty_ranges() {
        minmax_fq_axis(&mut [1.0, 2.0], &[], 8);
    }

    /// NaN policy (pinned): the `f32::min`/`f32::max` fold returns the
    /// non-NaN operand, so NaN elements are silently *dropped* from the
    /// statistics — a NaN never reaches the range state (where one EMA
    /// step would poison it permanently).  The fake-quant side instead
    /// *saturates*: `fq(NaN)` lands on the grid's lower edge via the
    /// NaN-to-0 `as u32` cast.  See also `quant::minmax`'s doc.
    #[test]
    fn nan_stats_are_dropped_by_the_fused_folds() {
        for b in KernelBackend::ALL {
            let mut xs = [1.0f32, f32::NAN, -2.0, 0.5];
            let (lo, hi) = minmax_fq_on(b, &mut xs, -4.0, 4.0, 8);
            assert_eq!((lo, hi), (-2.0, 1.0), "NaN must not surface in stats");
            assert!(xs.iter().all(|x| x.is_finite()), "fq saturates NaN onto the grid");

            let mut xs = [f32::NAN, 1.0, f32::NAN, -3.0];
            let stats = minmax_fq_axis_on(b, &mut xs, &[[-4.0, 4.0], [-4.0, 4.0]], 8);
            // channel 0 = {NaN, NaN} -> untouched inf fold (documented
            // degenerate); channel 1 = {1.0, -3.0} -> NaN-free hull
            assert_eq!(stats[0], (f32::INFINITY, f32::NEG_INFINITY));
            assert_eq!(stats[1], (-3.0, 1.0));
            assert!(xs.iter().all(|x| x.is_finite()));
        }
    }
}

//! Fused single-pass quantization kernels — the paper's Fig. 3
//! accelerator contract as coordinator-side code.
//!
//! The in-hindsight argument for hardware is that a *static* quantizer
//! can requantize the accumulator output on the way to memory while
//! folding the pre-quantization extrema into online statistics
//! registers: one traversal, no 32-bit round trip.  The scalar
//! `quant::minmax` + `quant::fake_quant_slice` pair walks the tensor
//! twice (three times when the output must not alias the input); these
//! kernels do the same work in one traversal, chunked so each
//! cache-resident block is reduced and rounded before the next block
//! streams in.
//!
//! Numerics are bit-exact with the scalar path: every kernel rounds
//! through [`QuantParams::fq`] and folds min/max in the same sequential
//! order, so the property tests can require equality, not tolerance.

use super::QuantParams;

/// Block size for the chunked traversal: small enough to stay
/// cache-resident, large enough that the reduction loop and the rounding
/// loop each vectorize over a full block.
const CHUNK: usize = 1024;

/// Fused min/max + fake-quantize in place (the Fig. 3 static-store
/// path): returns the (min, max) of the *original* values while
/// rewriting `xs` to the `[qmin, qmax]` grid.  `(0.0, 0.0)` on an empty
/// slice, matching [`super::minmax`].
pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for chunk in xs.chunks_mut(CHUNK) {
        for &x in chunk.iter() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        for x in chunk.iter_mut() {
            *x = qp.fq(*x);
        }
    }
    (lo, hi)
}

/// Channel-strided fused min/max + fake-quantize in place — the
/// per-channel counterpart of [`minmax_fq`].  Channels-last layout: the
/// channel of flat element `i` is `i % ranges.len()` (the convention the
/// per-channel estimator adapter and the simulator share).  One single
/// traversal folds each channel's pre-quantization extrema *and*
/// rewrites the tensor onto its channel's `[qmin, qmax]` grid; returns
/// one `(min, max)` per channel, `(0.0, 0.0)` on an empty slice
/// (matching [`super::minmax`]).
///
/// Bit-exact with the scalar per-channel reference (gather each
/// channel's strided slice, `minmax` + `fake_quant_slice` per channel):
/// the fold visits each channel's elements in the same increasing-index
/// order and rounds through the same [`QuantParams::fq`].
pub fn minmax_fq_axis(xs: &mut [f32], ranges: &[[f32; 2]], bits: u32) -> Vec<(f32, f32)> {
    let c = ranges.len();
    assert!(c > 0, "minmax_fq_axis needs at least one channel");
    assert_eq!(
        xs.len() % c,
        0,
        "tensor length {} not divisible by {c} channels",
        xs.len()
    );
    if xs.is_empty() {
        return vec![(0.0, 0.0); c];
    }
    let qps: Vec<QuantParams> = ranges
        .iter()
        .map(|r| QuantParams::from_range(r[0], r[1], bits))
        .collect();
    let mut stats = vec![(f32::INFINITY, f32::NEG_INFINITY); c];
    // channel-aligned blocks (block % c == 0, and the trailing chunk is
    // too since the total length divides by c) let a wrapping counter
    // replace a per-element `j % c` division, while preserving the
    // cache-resident reduce-then-round structure
    let block = (CHUNK / c).max(1) * c;
    for chunk in xs.chunks_mut(block) {
        let mut ch = 0usize;
        for &x in chunk.iter() {
            let s = &mut stats[ch];
            s.0 = s.0.min(x);
            s.1 = s.1.max(x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
        ch = 0;
        for x in chunk.iter_mut() {
            *x = qps[ch].fq(*x);
            ch += 1;
            if ch == c {
                ch = 0;
            }
        }
    }
    stats
}

/// Fake-quantize `src` into a caller-owned buffer (the no-alloc variant
/// of [`super::fake_quant`]).  Panics if the lengths differ.
pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    assert_eq!(src.len(), dst.len(), "fq_into buffer length mismatch");
    let qp = QuantParams::from_range(qmin, qmax, bits);
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = qp.fq(x);
    }
}

/// Fused DSGC objective: `cosine(x, fake_quant(x))` in one traversal,
/// never materializing the quantized tensor.  Identical accumulation
/// order to `cosine_similarity(x, &fake_quant(x, ..))`, so results are
/// bit-equal to the scalar two-pass form (including the zero-vector
/// conventions).
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for &x in xs {
        let q = qp.fq(x);
        dot += x as f64 * q as f64;
        na += x as f64 * x as f64;
        nb += q as f64 * q as f64;
    }
    if na == 0.0 && nb == 0.0 {
        return 1.0;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{cosine_similarity, fake_quant, fake_quant_slice, minmax};
    use crate::util::testkit::{forall, gens};

    fn case(rng: &mut crate::util::rng::Pcg32) -> (f32, f32, u32, Vec<f32>) {
        let (lo, hi) = gens::range(rng);
        let bits = gens::bits(rng);
        // span several chunks sometimes so the chunked path is exercised
        let xs = gens::tensor(rng, 3 * CHUNK);
        (lo, hi, bits, xs)
    }

    #[test]
    fn minmax_fq_equals_scalar_two_pass() {
        forall(96, "minmax_fq-parity", case, |(lo, hi, bits, xs)| {
            let mut fused = xs.clone();
            let stats = minmax_fq(&mut fused, *lo, *hi, *bits);
            let mut scalar = xs.clone();
            let expect_stats = minmax(&scalar);
            fake_quant_slice(&mut scalar, *lo, *hi, *bits);
            stats == expect_stats && fused == scalar
        });
    }

    #[test]
    fn fq_into_equals_fake_quant() {
        forall(96, "fq_into-parity", case, |(lo, hi, bits, xs)| {
            let mut dst = vec![0.0f32; xs.len()];
            fq_into(xs, &mut dst, *lo, *hi, *bits);
            dst == fake_quant(xs, *lo, *hi, *bits)
        });
    }

    #[test]
    fn fq_cosine_equals_two_pass_cosine() {
        forall(96, "fq_cosine-parity", case, |(lo, hi, bits, xs)| {
            let fused = fq_cosine(xs, *lo, *hi, *bits);
            let q = fake_quant(xs, *lo, *hi, *bits);
            fused == cosine_similarity(xs, &q)
        });
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        assert_eq!(minmax_fq(&mut [], -1.0, 1.0, 8), (0.0, 0.0));
        fq_into(&[], &mut [], -1.0, 1.0, 8);
        // all-zero tensor quantizes to itself: cosine convention is 1
        assert_eq!(fq_cosine(&[0.0; 8], -1.0, 1.0, 8), 1.0);
        // degenerate range: outputs collapse to the guarded near-zero grid
        let mut xs = [0.5f32, -0.5];
        let (lo, hi) = minmax_fq(&mut xs, 0.0, 0.0, 8);
        assert_eq!((lo, hi), (-0.5, 0.5));
        assert!(xs.iter().all(|&x| x.is_finite() && x.abs() < 1e-9));
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn fq_into_rejects_mismatched_buffers() {
        let mut dst = [0.0f32; 2];
        fq_into(&[1.0], &mut dst, -1.0, 1.0, 8);
    }

    // ------------------------------------------------------------------
    // Per-channel axis kernel
    // ------------------------------------------------------------------

    /// The scalar per-channel reference: gather each channel's strided
    /// slice, two-pass `minmax` + `fake_quant_slice`, scatter back.
    fn axis_scalar_reference(
        xs: &[f32],
        ranges: &[[f32; 2]],
        bits: u32,
    ) -> (Vec<f32>, Vec<(f32, f32)>) {
        let c = ranges.len();
        let mut out = xs.to_vec();
        let mut stats = vec![(0.0f32, 0.0f32); c];
        for ch in 0..c {
            let mut chan: Vec<f32> = xs.iter().skip(ch).step_by(c).copied().collect();
            stats[ch] = minmax(&chan);
            fake_quant_slice(&mut chan, ranges[ch][0], ranges[ch][1], bits);
            for (k, v) in chan.iter().enumerate() {
                out[ch + k * c] = *v;
            }
        }
        (out, stats)
    }

    fn axis_case(rng: &mut crate::util::rng::Pcg32) -> (u32, Vec<[f32; 2]>, Vec<f32>) {
        let bits = gens::bits(rng);
        let c = 1 + rng.below(8);
        let ranges: Vec<[f32; 2]> = (0..c)
            .map(|_| {
                let (lo, hi) = gens::range(rng);
                [lo, hi]
            })
            .collect();
        // sometimes span several channel-aligned blocks
        let per_chan = rng.below(2 * CHUNK / c + 2);
        let scale = 10f32.powf(rng.range(-3.0, 3.0));
        let xs: Vec<f32> = (0..per_chan * c).map(|_| rng.normal() * scale).collect();
        (bits, ranges, xs)
    }

    #[test]
    fn minmax_fq_axis_equals_scalar_per_channel_reference() {
        forall(96, "minmax_fq_axis-parity", axis_case, |(bits, ranges, xs)| {
            let mut fused = xs.clone();
            let stats = minmax_fq_axis(&mut fused, ranges, *bits);
            let (expect, expect_stats) = axis_scalar_reference(xs, ranges, *bits);
            stats == expect_stats && fused == expect
        });
    }

    #[test]
    fn minmax_fq_axis_with_one_channel_equals_minmax_fq() {
        forall(64, "axis-1ch-parity", case, |(lo, hi, bits, xs)| {
            let mut a = xs.clone();
            let sa = minmax_fq_axis(&mut a, &[[*lo, *hi]], *bits);
            let mut b = xs.clone();
            let sb = minmax_fq(&mut b, *lo, *hi, *bits);
            sa == vec![sb] && a == b
        });
    }

    #[test]
    fn minmax_fq_axis_empty_and_degenerate() {
        assert_eq!(minmax_fq_axis(&mut [], &[[-1.0, 1.0]; 3], 8), vec![(0.0, 0.0); 3]);
        // degenerate per-channel ranges collapse to the guarded grid
        let mut xs = [0.5f32, -0.5, 0.25, -0.25];
        let stats = minmax_fq_axis(&mut xs, &[[0.0, 0.0], [0.0, 0.0]], 8);
        assert_eq!(stats, vec![(0.25, 0.5), (-0.5, -0.25)]);
        assert!(xs.iter().all(|&x| x.is_finite() && x.abs() < 1e-9));
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn minmax_fq_axis_rejects_misaligned_tensors() {
        minmax_fq_axis(&mut [1.0, 2.0, 3.0], &[[-1.0, 1.0], [-1.0, 1.0]], 8);
    }

    /// NaN policy (pinned): the `f32::min`/`f32::max` fold returns the
    /// non-NaN operand, so NaN elements are silently *dropped* from the
    /// statistics — a NaN never reaches the range state (where one EMA
    /// step would poison it permanently).  The fake-quant side instead
    /// *saturates*: `fq(NaN)` lands on the grid's lower edge via the
    /// NaN-to-0 `as u32` cast.  See also `quant::minmax`'s doc.
    #[test]
    fn nan_stats_are_dropped_by_the_fused_folds() {
        let mut xs = [1.0f32, f32::NAN, -2.0, 0.5];
        let (lo, hi) = minmax_fq(&mut xs, -4.0, 4.0, 8);
        assert_eq!((lo, hi), (-2.0, 1.0), "NaN must not surface in stats");
        assert!(xs.iter().all(|x| x.is_finite()), "fq saturates NaN onto the grid");

        let mut xs = [f32::NAN, 1.0, f32::NAN, -3.0];
        let stats = minmax_fq_axis(&mut xs, &[[-4.0, 4.0], [-4.0, 4.0]], 8);
        // channel 0 = {NaN, NaN} -> untouched inf fold (documented
        // degenerate); channel 1 = {1.0, -3.0} -> NaN-free hull
        assert_eq!(stats[0], (f32::INFINITY, f32::NEG_INFINITY));
        assert_eq!(stats[1], (-3.0, 1.0));
        assert!(xs.iter().all(|x| x.is_finite()));
    }
}

//! Fused single-pass quantization kernels — the paper's Fig. 3
//! accelerator contract as coordinator-side code.
//!
//! The in-hindsight argument for hardware is that a *static* quantizer
//! can requantize the accumulator output on the way to memory while
//! folding the pre-quantization extrema into online statistics
//! registers: one traversal, no 32-bit round trip.  The scalar
//! `quant::minmax` + `quant::fake_quant_slice` pair walks the tensor
//! twice (three times when the output must not alias the input); these
//! kernels do the same work in one traversal, chunked so each
//! cache-resident block is reduced and rounded before the next block
//! streams in.
//!
//! Numerics are bit-exact with the scalar path: every kernel rounds
//! through [`QuantParams::fq`] and folds min/max in the same sequential
//! order, so the property tests can require equality, not tolerance.

use super::QuantParams;

/// Block size for the chunked traversal: small enough to stay
/// cache-resident, large enough that the reduction loop and the rounding
/// loop each vectorize over a full block.
const CHUNK: usize = 1024;

/// Fused min/max + fake-quantize in place (the Fig. 3 static-store
/// path): returns the (min, max) of the *original* values while
/// rewriting `xs` to the `[qmin, qmax]` grid.  `(0.0, 0.0)` on an empty
/// slice, matching [`super::minmax`].
pub fn minmax_fq(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for chunk in xs.chunks_mut(CHUNK) {
        for &x in chunk.iter() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        for x in chunk.iter_mut() {
            *x = qp.fq(*x);
        }
    }
    (lo, hi)
}

/// Fake-quantize `src` into a caller-owned buffer (the no-alloc variant
/// of [`super::fake_quant`]).  Panics if the lengths differ.
pub fn fq_into(src: &[f32], dst: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    assert_eq!(src.len(), dst.len(), "fq_into buffer length mismatch");
    let qp = QuantParams::from_range(qmin, qmax, bits);
    for (d, &x) in dst.iter_mut().zip(src) {
        *d = qp.fq(x);
    }
}

/// Fused DSGC objective: `cosine(x, fake_quant(x))` in one traversal,
/// never materializing the quantized tensor.  Identical accumulation
/// order to `cosine_similarity(x, &fake_quant(x, ..))`, so results are
/// bit-equal to the scalar two-pass form (including the zero-vector
/// conventions).
pub fn fq_cosine(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f32 {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for &x in xs {
        let q = qp.fq(x);
        dot += x as f64 * q as f64;
        na += x as f64 * x as f64;
        nb += q as f64 * q as f64;
    }
    if na == 0.0 && nb == 0.0 {
        return 1.0;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{cosine_similarity, fake_quant, fake_quant_slice, minmax};
    use crate::util::testkit::{forall, gens};

    fn case(rng: &mut crate::util::rng::Pcg32) -> (f32, f32, u32, Vec<f32>) {
        let (lo, hi) = gens::range(rng);
        let bits = gens::bits(rng);
        // span several chunks sometimes so the chunked path is exercised
        let xs = gens::tensor(rng, 3 * CHUNK);
        (lo, hi, bits, xs)
    }

    #[test]
    fn minmax_fq_equals_scalar_two_pass() {
        forall(96, "minmax_fq-parity", case, |(lo, hi, bits, xs)| {
            let mut fused = xs.clone();
            let stats = minmax_fq(&mut fused, *lo, *hi, *bits);
            let mut scalar = xs.clone();
            let expect_stats = minmax(&scalar);
            fake_quant_slice(&mut scalar, *lo, *hi, *bits);
            stats == expect_stats && fused == scalar
        });
    }

    #[test]
    fn fq_into_equals_fake_quant() {
        forall(96, "fq_into-parity", case, |(lo, hi, bits, xs)| {
            let mut dst = vec![0.0f32; xs.len()];
            fq_into(xs, &mut dst, *lo, *hi, *bits);
            dst == fake_quant(xs, *lo, *hi, *bits)
        });
    }

    #[test]
    fn fq_cosine_equals_two_pass_cosine() {
        forall(96, "fq_cosine-parity", case, |(lo, hi, bits, xs)| {
            let fused = fq_cosine(xs, *lo, *hi, *bits);
            let q = fake_quant(xs, *lo, *hi, *bits);
            fused == cosine_similarity(xs, &q)
        });
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        assert_eq!(minmax_fq(&mut [], -1.0, 1.0, 8), (0.0, 0.0));
        fq_into(&[], &mut [], -1.0, 1.0, 8);
        // all-zero tensor quantizes to itself: cosine convention is 1
        assert_eq!(fq_cosine(&[0.0; 8], -1.0, 1.0, 8), 1.0);
        // degenerate range: outputs collapse to the guarded near-zero grid
        let mut xs = [0.5f32, -0.5];
        let (lo, hi) = minmax_fq(&mut xs, 0.0, 0.0, 8);
        assert_eq!((lo, hi), (-0.5, 0.5));
        assert!(xs.iter().all(|&x| x.is_finite() && x.abs() < 1e-9));
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn fq_into_rejects_mismatched_buffers() {
        let mut dst = [0.0f32; 2];
        fq_into(&[1.0], &mut dst, -1.0, 1.0, 8);
    }
}

//! Direction-Sensitive Gradient Clipping (DSGC) range search
//! [Zhu et al. 2019, "Towards Unified INT8 Training", paper Sec. 5.1].
//!
//! DSGC periodically searches for the clipping range that maximizes the
//! cosine similarity between the FP32 gradient tensor and its quantized
//! version, then uses that range *statically* until the next update — a
//! hybrid of static and dynamic quantization.  The original paper gives
//! no implementation details; following the reproduction target paper we
//! use **golden-section search** over a scalar `alpha ∈ (0, 1]` that
//! scales the tensor's min-max range: `range(alpha) = alpha * minmax(G)`.
//!
//! The search evaluates the objective (fake-quantization + cosine) at
//! every probe — inherently expensive, which is exactly the overhead the
//! target paper charges DSGC with ("the update step can be very
//! expensive"); `perf_estimator_overhead` measures it.  Each probe is
//! one fused [`kernel::fq_cosine`] pass (no allocation, no materialized
//! quantized tensor), so the measured cost is the O(n · evals) floor of
//! the method, not implementation overhead.

use super::kernel;
use super::minmax;

/// Result of one DSGC range update.
#[derive(Debug, Clone, Copy)]
pub struct DsgcResult {
    pub qmin: f32,
    pub qmax: f32,
    pub alpha: f32,
    pub cosine: f32,
    /// number of objective evaluations performed (cost accounting)
    pub evals: u32,
}

const INV_PHI: f64 = 0.618_033_988_749_894_8; // 1/φ

/// Golden-section maximization of `f` on `[lo, hi]` with `iters` probes.
/// Returns (argmax, max, evals).
pub fn golden_section_max(
    mut lo: f64,
    mut hi: f64,
    iters: u32,
    mut f: impl FnMut(f64) -> f64,
) -> (f64, f64, u32) {
    let mut evals = 0;
    let mut c = hi - (hi - lo) * INV_PHI;
    let mut d = lo + (hi - lo) * INV_PHI;
    let mut fc = f(c);
    let mut fd = f(d);
    evals += 2;
    for _ in 0..iters {
        if fc >= fd {
            hi = d;
            d = c;
            fd = fc;
            c = hi - (hi - lo) * INV_PHI;
            fc = f(c);
        } else {
            lo = c;
            c = d;
            fc = fd;
            d = lo + (hi - lo) * INV_PHI;
            fd = f(d);
        }
        evals += 1;
    }
    let x = 0.5 * (lo + hi);
    let fx = f(x);
    evals += 1;
    (x, fx, evals)
}

/// Search the clipping range for gradient tensor `g` (paper's DSGC).
///
/// `bits` — quantizer bit-width; `iters` — golden-section refinement
/// steps (the objective is evaluated `iters + 3` times, each costing a
/// full fake-quant + cosine pass over `g`).
pub fn search_range(g: &[f32], bits: u32, iters: u32) -> DsgcResult {
    search_range_on(kernel::backend(), g, bits, iters)
}

/// [`search_range`] with the objective pinned to an explicit kernel
/// backend — the bench surface; results are backend-invariant (the
/// objective is bit-identical on every backend), so this is a speed
/// knob only.
pub fn search_range_on(b: kernel::KernelBackend, g: &[f32], bits: u32, iters: u32) -> DsgcResult {
    let (gmin, gmax) = minmax(g);
    if g.is_empty() || (gmin == 0.0 && gmax == 0.0) {
        return DsgcResult {
            qmin: 0.0,
            qmax: 0.0,
            alpha: 1.0,
            cosine: 1.0,
            evals: 0,
        };
    }
    let objective = |alpha: f64| -> f64 {
        let a = alpha as f32;
        kernel::fq_cosine_on(b, g, a * gmin, a * gmax, bits) as f64
    };
    // alpha in (0, 1]: clipping tighter than min-max can *increase* cosine
    // because it shrinks the grid step over the bulk of the distribution.
    let (alpha, cosine, evals) = golden_section_max(0.05, 1.0, iters, objective);
    let a = alpha as f32;
    DsgcResult {
        qmin: a * gmin,
        qmax: a * gmax,
        alpha: a,
        cosine: cosine as f32,
        evals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{cosine_similarity, fake_quant};
    use crate::util::rng::Pcg32;

    fn heavy_tailed(n: usize, seed: u64) -> Vec<f32> {
        // gradient-like: gaussian bulk + rare large outliers
        let mut rng = Pcg32::new(seed, 1);
        (0..n)
            .map(|i| {
                let x = rng.normal() * 0.01;
                if i % 997 == 0 {
                    x + rng.normal() * 2.0
                } else {
                    x
                }
            })
            .collect()
    }

    #[test]
    fn golden_section_finds_parabola_max() {
        let (x, fx, _) = golden_section_max(0.0, 4.0, 40, |x| -(x - 1.3) * (x - 1.3));
        assert!((x - 1.3).abs() < 1e-4, "x={x}");
        assert!(fx.abs() < 1e-6);
    }

    #[test]
    fn dsgc_clips_heavy_tails() {
        // At 8 bits the grid is fine enough that cosine favours keeping
        // outliers; the clipping benefit the paper exploits shows at the
        // coarse end, so exercise a 4-bit grid on a heavy-tailed tensor.
        let g = heavy_tailed(20_000, 7);
        let r = search_range(&g, 4, 25);
        // the searched range must beat plain min-max on the objective
        let (lo, hi) = minmax(&g);
        let q_mm = fake_quant(&g, lo, hi, 4);
        let cos_mm = cosine_similarity(&g, &q_mm);
        assert!(r.cosine >= cos_mm, "{} vs {}", r.cosine, cos_mm);
        // and the optimum is strictly inside (0, 1): real clipping happened
        assert!(r.alpha < 0.999, "alpha={}", r.alpha);
    }

    #[test]
    fn dsgc_keeps_full_range_for_uniform_tensor() {
        // no outliers: clipping only hurts, alpha should stay high
        let mut rng = Pcg32::new(3, 2);
        let g: Vec<f32> = (0..4096).map(|_| rng.range(-1.0, 1.0)).collect();
        let r = search_range(&g, 8, 20);
        assert!(r.alpha > 0.6, "alpha={}", r.alpha);
        assert!(r.cosine > 0.999);
    }

    #[test]
    fn dsgc_degenerate_inputs() {
        let r = search_range(&[], 8, 10);
        assert_eq!(r.evals, 0);
        let r = search_range(&[0.0; 16], 8, 10);
        assert_eq!(r.qmin, 0.0);
        assert_eq!(r.qmax, 0.0);
    }

    #[test]
    fn eval_count_matches_iters() {
        let g = heavy_tailed(1000, 1);
        let r = search_range(&g, 8, 15);
        assert_eq!(r.evals, 15 + 3);
    }
}

//! Quantization math — the Rust mirror of the L1 kernels' semantics.
//!
//! Shares the exact conventions of `python/compile/kernels/ref.py`
//! (asymmetric uniform grid containing zero, f32 arithmetic, 1e-12 scale
//! guard) so the coordinator-side computations (DSGC search, calibration
//! checks, the accelerator simulator's requantization) agree with what
//! the compiled graphs do.  Property tests enforce the invariants; the
//! integration suite cross-checks against artifact outputs.

//!
//! `kernel` holds the fused single-pass variants of the hot paths
//! (stats + fake-quant in one traversal, the no-alloc DSGC objective)
//! behind a backend dispatch (scalar reference / lane-chunked SIMD /
//! `std::thread` chunked-parallel, selected once per process via
//! `--kernel-backend` / `HINDSIGHT_KERNEL_BACKEND`); the scalar entry
//! points below stay as the reference semantics, and every backend is
//! bit-identical to them (`tests/kernel_conformance.rs`).

pub mod dsgc;
pub mod kernel;

/// Asymmetric uniform quantizer parameters for a `[qmin, qmax]` range.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    pub scale: f32,
    pub zero_point: f32,
    pub n_levels: u32,
    pub bits: u32,
}

impl QuantParams {
    /// Mirrors `ref.quant_params`: widen the range to contain 0, guard the
    /// scale, round the zero-point to an integer grid index.
    pub fn from_range(qmin: f32, qmax: f32, bits: u32) -> Self {
        let qmin = qmin.min(0.0);
        let qmax = qmax.max(0.0);
        let n_levels = (1u32 << bits) - 1;
        let scale = ((qmax - qmin) / n_levels as f32).max(1e-12);
        let zero_point = (-qmin / scale).round();
        Self {
            scale,
            zero_point,
            n_levels,
            bits,
        }
    }

    /// Real-value edges of the representable grid.
    pub fn grid_edges(&self) -> (f32, f32) {
        (
            (0.0 - self.zero_point) * self.scale,
            (self.n_levels as f32 - self.zero_point) * self.scale,
        )
    }

    /// Quantize one value to its integer grid index (nearest rounding).
    #[inline]
    pub fn index_of(&self, x: f32) -> u32 {
        let t = (x / self.scale + self.zero_point).round();
        t.clamp(0.0, self.n_levels as f32) as u32
    }

    /// Dequantize a grid index.
    #[inline]
    pub fn value_of(&self, idx: u32) -> f32 {
        (idx as f32 - self.zero_point) * self.scale
    }

    /// Fake-quantize one value (nearest rounding).
    #[inline]
    pub fn fq(&self, x: f32) -> f32 {
        self.value_of(self.index_of(x))
    }

    /// Fake-quantize with stochastic rounding given uniform noise in [0,1).
    #[inline]
    pub fn fq_stochastic(&self, x: f32, u: f32) -> f32 {
        let t = (x / self.scale + self.zero_point + u).floor();
        let idx = t.clamp(0.0, self.n_levels as f32);
        (idx - self.zero_point) * self.scale
    }
}

/// Per-tensor (min, max) — the accumulator statistics of paper Fig. 3.
/// An empty slice yields `(0.0, 0.0)`: the naive `(+inf, -inf)` fold
/// poisons every downstream consumer (`ema_update` smears the infinities
/// into the range state permanently).
///
/// NaN policy (intentional, pinned by tests here and in `kernel`):
/// `f32::min`/`f32::max` return the non-NaN operand, so NaN elements
/// are silently *dropped* from the fold — a NaN gradient never surfaces
/// in the range state (one EMA step would otherwise poison it forever).
/// This is the IEEE-754 minNum/maxNum convention, matching what XLA's
/// reduce-min/max emit on real accelerators.  The degenerate all-NaN
/// slice folds to `(+inf, -inf)` and is the caller's responsibility
/// (loss-scale overflow checks fire long before that in practice).
pub fn minmax(xs: &[f32]) -> (f32, f32) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    (lo, hi)
}

/// Fake-quantize a tensor in place (nearest rounding).
pub fn fake_quant_slice(xs: &mut [f32], qmin: f32, qmax: f32, bits: u32) {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    for x in xs.iter_mut() {
        *x = qp.fq(*x);
    }
}

/// Fake-quantize into a new buffer.
pub fn fake_quant(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> Vec<f32> {
    let mut out = vec![0.0; xs.len()];
    kernel::fq_into(xs, &mut out, qmin, qmax, bits);
    out
}

/// Cosine similarity between two tensors (DSGC's objective; paper Sec. 5.1:
/// maximize cos(FP32 grad, quantized grad)).  Returns 1.0 for two zero
/// vectors and 0.0 when exactly one is zero.
pub fn cosine_similarity(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 && nb == 0.0 {
        return 1.0;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

/// Fraction of values outside `[qmin, qmax]` (paper footnote 1).
pub fn saturation_ratio(xs: &[f32], qmin: f32, qmax: f32) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let out = xs.iter().filter(|&&x| x < qmin || x > qmax).count();
    out as f32 / xs.len() as f32
}

/// EMA range update (paper eqs. 2-3):
/// `new = (1 - eta) * stats + eta * prev` per component.
pub fn ema_update(prev: [f32; 2], stats: [f32; 2], eta: f32) -> [f32; 2] {
    [
        (1.0 - eta) * stats[0] + eta * prev[0],
        (1.0 - eta) * stats[1] + eta * prev[1],
    ]
}

/// Mean squared quantization error for a range candidate (diagnostics).
pub fn mse(xs: &[f32], qmin: f32, qmax: f32, bits: u32) -> f64 {
    let qp = QuantParams::from_range(qmin, qmax, bits);
    let mut acc = 0f64;
    for &x in xs {
        let e = (qp.fq(x) - x) as f64;
        acc += e * e;
    }
    acc / xs.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testkit::{forall, gens};

    #[test]
    fn zero_always_representable() {
        forall(
            128,
            "zero-representable",
            |rng| (gens::range(rng), gens::bits(rng)),
            |((lo, hi), bits)| {
                let qp = QuantParams::from_range(*lo, *hi, *bits);
                qp.fq(0.0) == 0.0
            },
        );
    }

    #[test]
    fn output_on_grid_and_clipped() {
        forall(
            128,
            "on-grid",
            |rng| {
                let (lo, hi) = gens::range(rng);
                let bits = gens::bits(rng);
                let xs = gens::tensor(rng, 256);
                (lo, hi, bits, xs)
            },
            |(lo, hi, bits, xs)| {
                let qp = QuantParams::from_range(*lo, *hi, *bits);
                let (glo, ghi) = qp.grid_edges();
                xs.iter().all(|&x| {
                    let q = qp.fq(x);
                    let idx = q / qp.scale + qp.zero_point;
                    (idx - idx.round()).abs() < 1e-3 && q >= glo - 1e-6 && q <= ghi + 1e-6
                })
            },
        );
    }

    #[test]
    fn quantization_error_bounded_by_step() {
        // inside the grid the error is <= scale/2 (nearest rounding)
        forall(
            128,
            "error-bound",
            |rng| {
                let (lo, hi) = gens::range(rng);
                let bits = gens::bits(rng);
                let xs = gens::tensor(rng, 128);
                (lo, hi, bits, xs)
            },
            |(lo, hi, bits, xs)| {
                let qp = QuantParams::from_range(*lo, *hi, *bits);
                let (glo, ghi) = qp.grid_edges();
                xs.iter()
                    .filter(|&&x| x >= glo && x <= ghi)
                    .all(|&x| (qp.fq(x) - x).abs() <= qp.scale * 0.5001 + 1e-6)
            },
        );
    }

    #[test]
    fn stochastic_rounding_unbiased() {
        let qp = QuantParams::from_range(0.0, 1.0, 2);
        let mut rng = crate::util::rng::Pcg32::new(3, 1);
        let x = 0.3f32;
        let n = 40_000;
        let mean: f64 = (0..n)
            .map(|_| qp.fq_stochastic(x, rng.uniform()) as f64)
            .sum::<f64>()
            / n as f64;
        assert!((mean - x as f64).abs() < 5e-3, "mean {mean}");
    }

    #[test]
    fn degenerate_range_is_finite_zero() {
        let qp = QuantParams::from_range(0.0, 0.0, 8);
        assert!(qp.fq(123.0).is_finite());
        assert_eq!(qp.fq(0.0), 0.0);
    }

    #[test]
    fn cosine_similarity_cases() {
        assert!((cosine_similarity(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((cosine_similarity(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine_similarity(&[1.0, 2.0], &[-1.0, -2.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine_similarity(&[0.0], &[0.0]), 1.0);
        assert_eq!(cosine_similarity(&[0.0], &[1.0]), 0.0);
    }

    #[test]
    fn quantized_tensor_has_high_cosine_with_original() {
        forall(
            64,
            "cosine-after-quant",
            |rng| gens::tensor(rng, 512),
            |xs| {
                if xs.iter().all(|&x| x == 0.0) {
                    return true;
                }
                let (lo, hi) = minmax(xs);
                let q = fake_quant(xs, lo, hi, 8);
                cosine_similarity(xs, &q) > 0.995
            },
        );
    }

    #[test]
    fn ema_update_matches_paper() {
        let out = ema_update([-1.0, 2.0], [-3.0, 1.0], 0.9);
        assert!((out[0] - (0.9 * -1.0 + 0.1 * -3.0)).abs() < 1e-6);
        assert!((out[1] - (0.9 * 2.0 + 0.1 * 1.0)).abs() < 1e-6);
    }

    #[test]
    fn saturation_ratio_cases() {
        let xs = [-2.0, -0.5, 0.5, 3.0];
        assert!((saturation_ratio(&xs, -1.0, 1.0) - 0.5).abs() < 1e-6);
        assert_eq!(saturation_ratio(&[], -1.0, 1.0), 0.0);
    }

    #[test]
    fn minmax_of_empty_slice_is_zero_not_inf() {
        assert_eq!(minmax(&[]), (0.0, 0.0));
        // the regression this guards: an (+inf, -inf) fold would poison
        // the EMA'd range state forever
        let (lo, hi) = minmax(&[]);
        let r = ema_update([-1.0, 1.0], [lo, hi], 0.9);
        assert!(r[0].is_finite() && r[1].is_finite());
        assert_eq!(minmax(&[2.0]), (2.0, 2.0));
    }

    #[test]
    fn nan_stats_never_reach_the_range_state() {
        // NaN policy: dropped from the fold wherever finite values exist
        forall(
            64,
            "minmax-drops-nan",
            |rng| {
                let mut xs = gens::tensor(rng, 128);
                let n = xs.len();
                for _ in 0..1 + rng.below(4) {
                    let at = rng.below(n);
                    xs[at] = f32::NAN;
                }
                xs.push(1.0); // guarantee at least one finite value
                xs
            },
            |xs| {
                let (lo, hi) = minmax(xs);
                let finite_hull = xs.iter().filter(|x| !x.is_nan()).fold(
                    (f32::INFINITY, f32::NEG_INFINITY),
                    |(l, h), &x| (l.min(x), h.max(x)),
                );
                lo.is_finite() && hi.is_finite() && (lo, hi) == finite_hull
            },
        );
        // the documented all-NaN degenerate
        assert_eq!(
            minmax(&[f32::NAN, f32::NAN]),
            (f32::INFINITY, f32::NEG_INFINITY)
        );
    }

    #[test]
    fn minmax_range_quantization_never_saturates() {
        forall(
            64,
            "minmax-no-saturation",
            |rng| gens::tensor(rng, 256),
            |xs| {
                let (lo, hi) = minmax(xs);
                let q = fake_quant(xs, lo, hi, 8);
                // max error within half step of an 8-bit grid over [lo,hi]
                let qp = QuantParams::from_range(lo, hi, 8);
                xs.iter()
                    .zip(&q)
                    .all(|(&x, &qx)| (x - qx).abs() <= qp.scale * 0.5001 + 1e-6)
            },
        );
    }
}

//! Fuzz-harness bodies for the four public parser surfaces.
//!
//! Each `check_*` function takes arbitrary bytes and panics only when a
//! guarded property is violated — never on malformed input.  The
//! `fuzz/` cargo-fuzz targets are one-line wrappers around these, and
//! `tests/fuzz_regression.rs` replays the checked-in corpus through the
//! same bodies on the stable toolchain, so every crash cargo-fuzz
//! shrinks becomes a plain `cargo test` regression by dropping the
//! input file into `fuzz/corpus/<target>/`.
//!
//! The properties, per surface:
//!
//! * **scheme** — `QuantScheme::parse` never panics; an accepted string
//!   canonicalizes to a fixpoint (`parse(canon).to_string() == canon`)
//!   and the reparsed scheme equals the original.
//! * **grid** — `expand_braces` / `parse_seeds` / `GridSpec::new` never
//!   panic and never return results over their caps
//!   ([`MAX_EXPANSIONS`](crate::coordinator::grid::MAX_EXPANSIONS),
//!   [`MAX_SEEDS`](crate::coordinator::grid::MAX_SEEDS),
//!   [`MAX_GRID_CELLS`](crate::coordinator::grid::MAX_GRID_CELLS)) —
//!   the DoS guards hold for *every* input, not just the known bombs.
//! * **json** — the owned parser and the bytes-backed [`RawDoc`] agree:
//!   same accept/reject decision, equal trees, equal error position and
//!   message, and an accepted document survives serialize → reparse.
//! * **service** — `read_request` over arbitrary bytes never panics and
//!   never hands back a body over [`MAX_BODY_BYTES`]; a request that
//!   parses all the way into a [`JobSpec`] expands to at most
//!   `MAX_GRID_CELLS` cells.

use std::io::Cursor;
use std::sync::Arc;

use crate::coordinator::grid::{
    expand_braces, parse_seeds, GridSpec, MAX_EXPANSIONS, MAX_GRID_CELLS, MAX_SEEDS,
};
use crate::scheme::QuantScheme;
use crate::service::protocol::{read_request, MAX_BODY_BYTES};
use crate::service::server::JobSpec;
use crate::util::json::{self, RawDoc};

/// Scheme grammar: parse → canonicalize → reparse is a fixpoint.
pub fn check_scheme_roundtrip(data: &[u8]) {
    let Ok(text) = std::str::from_utf8(data) else {
        return;
    };
    let Ok(scheme) = QuantScheme::parse(text) else {
        return; // rejection is fine; panicking is not
    };
    let canon = scheme.to_string();
    let reparsed = QuantScheme::parse(&canon).unwrap_or_else(|e| {
        panic!("canonical form '{canon}' of '{text}' failed to reparse: {e:#}")
    });
    assert_eq!(
        reparsed, scheme,
        "reparsing canonical '{canon}' changed the scheme"
    );
    assert_eq!(
        reparsed.to_string(),
        canon,
        "canonicalization of '{text}' is not a fixpoint"
    );
}

/// Grid surface: templates and seed strings never panic and never
/// produce results over the caps.  Input is `template[\n seeds]`.
pub fn check_grid_expansion(data: &[u8]) {
    let Ok(text) = std::str::from_utf8(data) else {
        return;
    };
    let (template, seed_str) = match text.split_once('\n') {
        Some((t, s)) => (t, s),
        None => (text, "1..3"),
    };
    if let Ok(expansions) = expand_braces(template) {
        assert!(
            expansions.len() <= MAX_EXPANSIONS,
            "expand_braces returned {} results, over the {MAX_EXPANSIONS} cap",
            expansions.len()
        );
    }
    let seeds = match parse_seeds(seed_str) {
        Ok(seeds) => {
            assert!(
                seeds.len() <= MAX_SEEDS && !seeds.is_empty(),
                "parse_seeds returned {} seeds (cap {MAX_SEEDS})",
                seeds.len()
            );
            seeds
        }
        Err(_) => vec![1, 2, 3],
    };
    if let Ok(grid) = GridSpec::new(template, &seeds) {
        assert!(
            grid.n_cells() <= MAX_GRID_CELLS,
            "grid expanded to {} cells, over the {MAX_GRID_CELLS} cap",
            grid.n_cells()
        );
    }
}

/// JSON differential: the owned parser and the bytes-backed raw parser
/// must agree on everything a caller can observe.
pub fn check_json_differential(data: &[u8]) {
    // the Arc entry point takes raw bytes (UTF-8 validation is part of
    // the surface under test) — it must never panic
    let _ = RawDoc::parse_arc(Arc::from(data));
    let Ok(text) = std::str::from_utf8(data) else {
        return;
    };
    let owned = json::parse(text);
    let raw = RawDoc::parse(text);
    match (owned, raw) {
        (Ok(v), Ok(doc)) => {
            assert_eq!(
                doc.to_value(),
                v,
                "owned and raw parsers built different trees for {text:?}"
            );
            // serialize → reparse survives (Display is the serializer)
            let ser = v.to_string();
            let back = json::parse(&ser).unwrap_or_else(|e| {
                panic!("serialized form {ser:?} of accepted {text:?} failed to reparse: {e}")
            });
            assert_eq!(back, v, "serialize -> reparse changed the tree for {text:?}");
        }
        (Err(a), Err(b)) => {
            assert_eq!(
                (a.pos, &a.msg),
                (b.pos, &b.msg),
                "parsers rejected {text:?} with different errors"
            );
        }
        (Ok(_), Err(e)) => panic!("raw parser rejected {text:?} the owned parser accepts: {e}"),
        (Err(e), Ok(_)) => panic!("owned parser rejected {text:?} the raw parser accepts: {e}"),
    }
}

/// Service request path: framing → JSON body → job spec → expansion,
/// end to end, on arbitrary bytes.
pub fn check_service_request(data: &[u8]) {
    let Ok(req) = read_request(&mut Cursor::new(data)) else {
        return;
    };
    assert!(
        req.body.len() <= MAX_BODY_BYTES,
        "read_request returned a {}-byte body, over the {MAX_BODY_BYTES} cap",
        req.body.len()
    );
    let Ok(body) = req.json() else {
        return;
    };
    let Ok(spec) = JobSpec::from_json(&body) else {
        return;
    };
    if let Ok(cells) = spec.expand() {
        assert!(
            cells.len() <= MAX_GRID_CELLS,
            "job expanded to {} cells, over the {MAX_GRID_CELLS} cap",
            cells.len()
        );
        // the persisted job file must round-trip to the same spec (the
        // cross-shard contract: sibling shards re-expand from this)
        let persisted = spec.to_json().to_string();
        let reread = json::parse(&persisted).unwrap_or_else(|e| {
            panic!("persisted job file {persisted:?} failed to reparse: {e}")
        });
        let respec = JobSpec::from_json(&reread).unwrap_or_else(|e| {
            panic!("persisted job file {persisted:?} failed to re-spec: {e:#}")
        });
        assert_eq!(respec, spec, "job file round-trip changed the spec");
    }
}

/// Structured-random generators over the same four surfaces, for the
/// stable-toolchain property loops in `tests/fuzz_regression.rs`.
/// libFuzzer explores byte-level mutations; these explore the
/// grammar-shaped neighborhood (valid-ish inputs with adversarial
/// edges) that random bytes rarely reach.
pub mod gen {
    use crate::util::rng::Pcg32;

    const EST_KEYS: [&str; 7] =
        ["hindsight", "current", "tqt", "banner", "sampled", "dsgc", "fp32"];

    /// A scheme-grammar-shaped string: mostly valid clauses with
    /// occasional junk (bad keys, out-of-range bits, stray separators).
    pub fn scheme_string(rng: &mut Pcg32) -> String {
        let mut out = String::new();
        let clauses = 1 + rng.below(4);
        for i in 0..clauses {
            if i > 0 {
                out.push(if rng.below(8) == 0 { ':' } else { ' ' });
            }
            let class = ["w", "a", "g", "q", ""][rng.below(5)];
            let key = if rng.below(10) == 0 {
                "bogus"
            } else {
                EST_KEYS[rng.below(EST_KEYS.len())]
            };
            let gran = ["", "@pt", "@pc", "@"][rng.below(4)];
            out.push_str(class);
            if !class.is_empty() {
                out.push(':');
            }
            out.push_str(key);
            out.push_str(gran);
            match rng.below(4) {
                0 => {}
                1 => out.push_str(&format!(":{}", 2 + rng.below(20))),
                2 => out.push_str(&format!(":{}:eta=0.{}", 2 + rng.below(15), rng.below(100))),
                _ => out.push_str(&format!(":{}:sym", 2 + rng.below(15))),
            }
        }
        out
    }

    /// A grid input (`template\nseeds`) with brace groups, ranges and
    /// near-cap magnitudes.
    pub fn grid_input(rng: &mut Pcg32) -> String {
        let mut template = String::from("g:");
        let groups = 1 + rng.below(3);
        for _ in 0..groups {
            match rng.below(5) {
                0 => template.push_str("{hindsight,current,tqt}"),
                1 => template.push_str("@{pt,pc}"),
                2 => template.push_str(":{4,8}"),
                3 => template.push_str("{a,"), // unterminated on purpose
                _ => template.push_str(EST_KEYS[rng.below(EST_KEYS.len())]),
            }
        }
        let seeds = match rng.below(5) {
            0 => format!("{}..{}", rng.below(10), rng.below(100_000)),
            1 => "0..4000000000".to_string(),
            2 => format!("{}", u64::MAX),
            3 => "1,2,3".to_string(),
            _ => format!("{0}..{0}", rng.below(50)),
        };
        format!("{template}\n{seeds}")
    }

    /// A JSON-shaped document: nesting, escapes, big numbers, and the
    /// job-file / store-cell vocabulary.
    pub fn json_text(rng: &mut Pcg32) -> String {
        fn val(rng: &mut Pcg32, depth: usize) -> String {
            if depth == 0 {
                return leaf(rng);
            }
            match rng.below(4) {
                0 => {
                    let n = rng.below(4);
                    let items: Vec<String> = (0..n).map(|_| val(rng, depth - 1)).collect();
                    format!("[{}]", items.join(","))
                }
                1 => {
                    let keys = ["seed", "steps", "grid", "seeds", "x\\n", "米"];
                    let n = rng.below(4);
                    let items: Vec<String> = (0..n)
                        .map(|_| {
                            format!("\"{}\":{}", keys[rng.below(keys.len())], val(rng, depth - 1))
                        })
                        .collect();
                    format!("{{{}}}", items.join(","))
                }
                _ => leaf(rng),
            }
        }
        fn leaf(rng: &mut Pcg32) -> String {
            match rng.below(8) {
                0 => "null".into(),
                1 => "true".into(),
                2 => format!("{}", rng.below(1_000_000)),
                3 => format!("{}.{}e{}", rng.below(10), rng.below(1000), rng.below(400)),
                4 => "1e999".into(),
                5 => format!("{}", u64::MAX),
                6 => "\"a\\u00e9b\"".into(),
                _ => "\"9007199254740993\"".into(),
            }
        }
        val(rng, 1 + rng.below(3))
    }

    /// Raw HTTP request bytes around the `POST /jobs` shape: valid
    /// submissions, truncations, header bombs and length lies.
    pub fn http_request(rng: &mut Pcg32) -> Vec<u8> {
        let body = match rng.below(5) {
            0 => r#"{"grid":"g:hindsight:8","seeds":"1..3"}"#.to_string(),
            1 => r#"{"grid":"g:hindsight:8","seeds":"0..4000000000"}"#.to_string(),
            2 => format!(r#"{{"grid":"g:{}:8"}}"#, "{a,b}".repeat(rng.below(20))),
            3 => r#"{"grid":"g:hindsight:8","seeds":[18446744073709551615]}"#.to_string(),
            _ => "{not json".to_string(),
        };
        let declared = match rng.below(4) {
            0 => body.len().to_string(),
            1 => (body.len() + 1 + rng.below(50)).to_string(),
            2 => "99999999999999999999999999".to_string(),
            _ => body.len().to_string(),
        };
        let mut req = format!(
            "POST /jobs{} HTTP/1.1\r\nContent-Length: {declared}\r\n\r\n{body}",
            ["", "?q=%4", "/a%2Bb"][rng.below(3)]
        )
        .into_bytes();
        // random truncation keeps the framing reader honest
        if rng.below(4) == 0 {
            let keep = rng.below(req.len().max(1));
            req.truncate(keep);
        }
        req
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::{default_cases, forall};

    // The check functions are themselves exercised hard by
    // tests/fuzz_regression.rs (corpus replay + property loops); here
    // each one gets a smoke pass over its generator so `cargo test`
    // on the library alone still covers every harness body.

    #[test]
    fn harness_bodies_never_panic_on_generated_input() {
        forall(
            default_cases(),
            "fuzz-harness-smoke",
            |rng| {
                (
                    gen::scheme_string(rng),
                    gen::grid_input(rng),
                    gen::json_text(rng),
                    gen::http_request(rng),
                )
            },
            |(scheme, grid, json, req)| {
                check_scheme_roundtrip(scheme.as_bytes());
                check_grid_expansion(grid.as_bytes());
                check_json_differential(json.as_bytes());
                check_service_request(req);
                true
            },
        );
    }

    #[test]
    fn harness_bodies_accept_arbitrary_bytes() {
        // non-UTF-8, empty, and control bytes flow through every body
        for data in [
            &b""[..],
            &[0xff, 0xfe, 0x00][..],
            &[b'{', 0x80][..],
            &b"\r\n\r\n"[..],
        ] {
            check_scheme_roundtrip(data);
            check_grid_expansion(data);
            check_json_differential(data);
            check_service_request(data);
        }
    }

    #[test]
    fn generators_reach_both_accept_and_reject() {
        // the grammar-shaped generators must produce inputs on both
        // sides of each parser, or the property loops test nothing
        let mut scheme_ok = false;
        let mut scheme_err = false;
        let mut grid_ok = false;
        let mut grid_err = false;
        for i in 0..512 {
            let mut rng = Pcg32::fold(11, "gen-cover", i);
            let s = gen::scheme_string(&mut rng);
            match crate::scheme::QuantScheme::parse(&s) {
                Ok(_) => scheme_ok = true,
                Err(_) => scheme_err = true,
            }
            let g = gen::grid_input(&mut rng);
            let template = g.split('\n').next().unwrap();
            match crate::coordinator::grid::expand_braces(template) {
                Ok(_) => grid_ok = true,
                Err(_) => grid_err = true,
            }
        }
        assert!(
            scheme_ok && scheme_err && grid_ok && grid_err,
            "{scheme_ok} {scheme_err} {grid_ok} {grid_err}"
        );
    }
}

//! Minimal CLI argument parser (clap stand-in).
//!
//! Grammar: `prog <subcommand> [--key value | --key=value | --flag] ...`
//! Unknown keys are collected and reported by `finish()` so typos fail
//! loudly instead of silently using defaults.

use std::collections::BTreeMap;

/// The one boolean-token rule every flag shares (`--flag`,
/// `--flag true|1|yes`); anything else is false.
pub fn parse_bool(v: &str) -> bool {
    matches!(v, "true" | "1" | "yes")
}

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    consumed: BTreeMap<String, bool>,
}

impl Args {
    /// Parse from process args (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn from_iter<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut subcommand = None;
        let mut positional = Vec::new();
        let mut opts = BTreeMap::new();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    opts.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    opts.insert(rest.to_string(), iter.next().unwrap());
                } else {
                    opts.insert(rest.to_string(), "true".to_string());
                }
            } else if subcommand.is_none() {
                subcommand = Some(tok);
            } else {
                positional.push(tok);
            }
        }
        let consumed = opts.keys().map(|k| (k.clone(), false)).collect();
        Self {
            subcommand,
            positional,
            opts,
            consumed,
        }
    }

    pub fn get(&mut self, key: &str) -> Option<String> {
        if let Some(v) = self.opts.get(key) {
            self.consumed.insert(key.to_string(), true);
            Some(v.clone())
        } else {
            None
        }
    }

    pub fn str_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&mut self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: expected integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64_or(&mut self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: expected integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f32_or(&mut self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: expected float, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn bool_or(&mut self, key: &str, default: bool) -> bool {
        self.get(key).map(|v| parse_bool(&v)).unwrap_or(default)
    }

    /// Comma-separated list.
    pub fn list_or(&mut self, key: &str, default: &[&str]) -> Vec<String> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
            .unwrap_or_else(|| default.iter().map(|s| s.to_string()).collect())
    }

    /// Error out on unconsumed options (call after all gets).
    pub fn finish(&self) -> Result<(), String> {
        let unknown: Vec<_> = self
            .consumed
            .iter()
            .filter(|(_, used)| !**used)
            .map(|(k, _)| format!("--{k}"))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown option(s): {}", unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_and_options() {
        let mut a = args("train --model resnet_tiny --steps=200 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("model", "x"), "resnet_tiny");
        assert_eq!(a.usize_or("steps", 0), 200);
        assert!(a.bool_or("verbose", false));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn unknown_options_rejected() {
        let mut a = args("train --oops 1");
        let _ = a.str_or("model", "x");
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults_apply() {
        let mut a = args("eval");
        assert_eq!(a.f32_or("lr", 0.1), 0.1);
        assert_eq!(a.list_or("seeds", &["1", "2"]), vec!["1", "2"]);
    }

    #[test]
    fn comma_lists() {
        let mut a = args("sweep --estimators hindsight,current");
        assert_eq!(
            a.list_or("estimators", &[]),
            vec!["hindsight".to_string(), "current".to_string()]
        );
    }
}

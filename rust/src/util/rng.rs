//! Deterministic PRNGs (rand stand-in): SplitMix64 for seeding, PCG32 for
//! streams.  Every consumer (dataset generation, stochastic tests,
//! shuffles) derives a named substream so runs are bitwise reproducible.

/// SplitMix64 — used to expand a single u64 seed into substream seeds.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32) — the workhorse stream generator.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a named substream deterministically (fold the label in).
    pub fn fold(seed: u64, label: &str, index: u64) -> Self {
        let mut h = SplitMix64::new(seed);
        let mut acc = h.next_u64();
        for b in label.bytes() {
            acc = acc.wrapping_mul(0x100000001B3) ^ (b as u64);
        }
        acc = acc.wrapping_add(index.wrapping_mul(0x9E3779B97F4A7C15));
        Self::new(acc, acc >> 17)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free approximation is fine here
        (self.next_u32() as u64 * n as u64 >> 32) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Pcg32::new(7, 3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(9, 4);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(1, 1);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_in_bounds() {
        let mut rng = Pcg32::new(5, 5);
        for _ in 0..1000 {
            assert!(rng.below(7) < 7);
        }
    }

    #[test]
    fn fold_label_sensitivity() {
        let mut a = Pcg32::fold(1, "data", 0);
        let mut b = Pcg32::fold(1, "init", 0);
        assert_ne!(a.next_u32(), b.next_u32());
    }
}

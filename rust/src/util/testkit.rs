//! Mini property-testing harness (proptest stand-in).
//!
//! `forall(N, seed, gen, prop)` draws `N` cases from `gen(&mut rng)` and
//! asserts `prop(case)`; `forall_shrink` additionally takes a shrinker
//! (candidate simpler cases) and greedily minimizes the first failing
//! case before reporting it, so a 3000-element adversarial tensor
//! failure comes back as the 4-element core that actually trips the
//! property.  Failures report the reproduction seed
//! (`HINDSIGHT_PT_SEED`).

use crate::util::rng::Pcg32;

/// Number of cases per property; override with HINDSIGHT_PT_CASES.
pub fn default_cases() -> usize {
    std::env::var("HINDSIGHT_PT_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("HINDSIGHT_PT_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `n` generated cases; panics with the failing case's
/// debug repr and reproduction seed on the first violation.
pub fn forall<T: std::fmt::Debug>(
    n: usize,
    label: &str,
    gen: impl Fn(&mut Pcg32) -> T,
    prop: impl Fn(&T) -> bool,
) {
    forall_shrink(n, label, gen, |_| Vec::new(), prop)
}

/// Maximum shrink steps before giving up and reporting the current
/// smallest failure (a safety valve, not a tuning knob).
const MAX_SHRINK_STEPS: usize = 256;

/// [`forall`] with shrinking: when a case falsifies `prop`, `shrink`
/// proposes simpler candidates; any candidate that still fails becomes
/// the new case, greedily, until no candidate fails (a local minimum)
/// or `MAX_SHRINK_STEPS` is hit.  The panic reports the *minimized*
/// case plus how many shrink steps it took.
pub fn forall_shrink<T: std::fmt::Debug>(
    n: usize,
    label: &str,
    gen: impl Fn(&mut Pcg32) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> bool,
) {
    let seed = base_seed();
    for i in 0..n {
        let mut rng = Pcg32::fold(seed, label, i as u64);
        let case = gen(&mut rng);
        if prop(&case) {
            continue;
        }
        let mut smallest = case;
        let mut steps = 0usize;
        'minimize: while steps < MAX_SHRINK_STEPS {
            for cand in shrink(&smallest) {
                steps += 1;
                if !prop(&cand) {
                    smallest = cand;
                    continue 'minimize;
                }
                if steps >= MAX_SHRINK_STEPS {
                    break;
                }
            }
            break;
        }
        panic!(
            "property '{label}' falsified on case #{i} \
             (HINDSIGHT_PT_SEED={seed}, shrunk in {steps} step(s)):\n{smallest:#?}"
        );
    }
}

/// Generators for common shapes.
pub mod gens {
    use super::Pcg32;

    /// Random f32 vector with magnitudes spanning several decades.
    pub fn tensor(rng: &mut Pcg32, max_len: usize) -> Vec<f32> {
        let len = 1 + rng.below(max_len);
        let scale = 10f32.powf(rng.range(-3.0, 3.0));
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    /// A plausible quantization range (possibly degenerate/one-sided).
    pub fn range(rng: &mut Pcg32) -> (f32, f32) {
        match rng.below(4) {
            0 => (0.0, 0.0),                              // degenerate
            1 => (0.0, rng.range(0.01, 50.0)),            // one-sided (ReLU)
            2 => (-rng.range(0.01, 50.0), 0.0),           // one-sided neg
            _ => {
                let lo = rng.range(-50.0, 0.0);
                (lo, lo + rng.range(0.01, 100.0))
            }
        }
    }

    pub fn bits(rng: &mut Pcg32) -> u32 {
        [2, 3, 4, 6, 8][rng.below(5)]
    }

    /// A tensor length biased onto the edges kernel backends care
    /// about: empty, tiny, one below / exactly at / one past each of
    /// the given `boundaries` (SIMD lane width, cache-chunk size,
    /// parallel span...), or an arbitrary in-between value.
    pub fn boundary_len(rng: &mut Pcg32, boundaries: &[usize]) -> usize {
        match rng.below(3) {
            0 => rng.below(4), // 0..=3: empty and sub-lane tails
            1 => {
                let b = boundaries[rng.below(boundaries.len())];
                // b-1 | b | b+1 | a few lanes past
                match rng.below(4) {
                    0 => b.saturating_sub(1),
                    1 => b,
                    2 => b + 1,
                    _ => b + 1 + rng.below(2 * b.max(1)),
                }
            }
            _ => rng.below(boundaries.iter().copied().max().unwrap_or(64) * 3 + 2),
        }
    }

    /// Adversarial tensor for kernel-conformance testing: a base shape
    /// (normal noise / all-negative / all-constant / subnormal-scale /
    /// zeros) of a boundary-biased length, with NaN and ±inf payloads
    /// sprinkled in — everything the NaN-dropping fold, the saturating
    /// fake-quant and the lane/chunk tails must survive.
    pub fn adversarial(rng: &mut Pcg32, boundaries: &[usize]) -> Vec<f32> {
        let len = boundary_len(rng, boundaries);
        let mut xs: Vec<f32> = match rng.below(5) {
            // gaussian across several decades
            0 => {
                let scale = 10f32.powf(rng.range(-3.0, 3.0));
                (0..len).map(|_| rng.normal() * scale).collect()
            }
            // all-negative (one-sided hull, asymmetric grids)
            1 => {
                let scale = 10f32.powf(rng.range(-2.0, 2.0));
                (0..len).map(|_| -rng.uniform().abs() * scale - 1e-3).collect()
            }
            // all-constant (zero-width hull; min == max)
            2 => {
                let v = rng.normal();
                vec![v; len]
            }
            // subnormal magnitudes (scale guard + flush behaviour)
            3 => (0..len)
                .map(|_| rng.normal() * f32::MIN_POSITIVE * 0.5)
                .collect(),
            // exact zeros with mixed signs
            _ => (0..len)
                .map(|_| if rng.below(2) == 0 { 0.0 } else { -0.0 })
                .collect(),
        };
        // payload injection: NaN / +inf / -inf at random positions
        if !xs.is_empty() && rng.below(2) == 0 {
            for _ in 0..1 + rng.below(1 + xs.len() / 8) {
                let at = rng.below(xs.len());
                xs[at] = match rng.below(3) {
                    0 => f32::NAN,
                    1 => f32::INFINITY,
                    _ => f32::NEG_INFINITY,
                };
            }
        }
        xs
    }

    /// Shrink a tensor: drop halves, then neutralize elements to 0.0 —
    /// enough to reduce most kernel failures to a handful of elements.
    pub fn shrink_tensor(xs: &[f32]) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if xs.is_empty() {
            return out;
        }
        let mid = xs.len() / 2;
        if mid > 0 {
            out.push(xs[..mid].to_vec());
            out.push(xs[mid..].to_vec());
        }
        // neutralize the first non-zero element (kills payloads one by
        // one without changing the length/layout)
        if let Some(i) = xs.iter().position(|&x| x != 0.0 || x.is_nan()) {
            let mut ys = xs.to_vec();
            ys[i] = 0.0;
            out.push(ys);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(32, "trivial", |rng| rng.uniform(), |x| (0.0..1.0).contains(x));
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn forall_reports_failures() {
        forall(32, "fails", |rng| rng.uniform(), |x| *x < 0.5);
    }

    #[test]
    fn generators_cover_degenerate_ranges() {
        let mut seen_degenerate = false;
        for i in 0..64 {
            let mut rng = Pcg32::fold(1, "cover", i);
            let (lo, hi) = gens::range(&mut rng);
            assert!(lo <= hi);
            if lo == hi {
                seen_degenerate = true;
            }
        }
        assert!(seen_degenerate);
    }

    #[test]
    fn shrinking_minimizes_the_failing_case() {
        // property: no element is NaN.  The generator plants one NaN in
        // a large tensor; shrinking must reduce it to a small core that
        // still contains the NaN.
        let caught = std::panic::catch_unwind(|| {
            forall_shrink(
                8,
                "shrinks",
                |rng| {
                    let mut xs: Vec<f32> = (0..512).map(|_| rng.normal()).collect();
                    let at = rng.below(xs.len());
                    xs[at] = f32::NAN;
                    xs
                },
                |xs| gens::shrink_tensor(xs),
                |xs| !xs.iter().any(|x| x.is_nan()),
            )
        });
        let msg = match caught {
            Ok(()) => panic!("property must fail"),
            Err(p) => *p.downcast::<String>().expect("string panic"),
        };
        assert!(msg.contains("falsified"), "{msg}");
        assert!(msg.contains("shrunk in"), "{msg}");
        // the reported case is the minimized one: halving 512 down to
        // the NaN core keeps it under a handful of lines
        let elements = msg.matches(',').count() + 1;
        assert!(elements < 64, "shrunk case still large: {msg}");
    }

    #[test]
    fn boundary_lengths_hit_the_edges() {
        let boundaries = [8usize, 1024];
        let (mut at, mut below, mut above, mut empty) = (false, false, false, false);
        for i in 0..512 {
            let mut rng = Pcg32::fold(2, "bounds", i);
            let len = gens::boundary_len(&mut rng, &boundaries);
            empty |= len == 0;
            for b in boundaries {
                at |= len == b;
                below |= len == b - 1;
                above |= len == b + 1;
            }
        }
        assert!(empty && at && below && above, "{empty} {at} {below} {above}");
    }

    #[test]
    fn adversarial_tensors_cover_payload_classes() {
        let boundaries = [8usize, 1024];
        let (mut nan, mut inf, mut allneg, mut constant, mut subnormal) =
            (false, false, false, false, false);
        for i in 0..512 {
            let mut rng = Pcg32::fold(3, "adv", i);
            let xs = gens::adversarial(&mut rng, &boundaries);
            nan |= xs.iter().any(|x| x.is_nan());
            inf |= xs.iter().any(|x| x.is_infinite());
            allneg |= !xs.is_empty() && xs.iter().all(|&x| x < 0.0);
            constant |= xs.len() > 1 && xs.windows(2).all(|w| w[0].to_bits() == w[1].to_bits());
            subnormal |= xs.iter().any(|x| x.is_subnormal());
        }
        assert!(
            nan && inf && allneg && constant && subnormal,
            "{nan} {inf} {allneg} {constant} {subnormal}"
        );
    }
}

//! Mini property-testing harness (proptest stand-in).
//!
//! `forall(N, seed, gen, prop)` draws `N` cases from `gen(&mut rng)` and
//! asserts `prop(case)`; on failure it retries with simpler cases drawn
//! from `gen_simpler` if provided (a shrinking-lite pass) and reports the
//! failing seed so the case is reproducible with `HINDSIGHT_PT_SEED`.

use crate::util::rng::Pcg32;

/// Number of cases per property; override with HINDSIGHT_PT_CASES.
pub fn default_cases() -> usize {
    std::env::var("HINDSIGHT_PT_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("HINDSIGHT_PT_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `n` generated cases; panics with the failing case's
/// debug repr and reproduction seed on the first violation.
pub fn forall<T: std::fmt::Debug>(
    n: usize,
    label: &str,
    gen: impl Fn(&mut Pcg32) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let seed = base_seed();
    for i in 0..n {
        let mut rng = Pcg32::fold(seed, label, i as u64);
        let case = gen(&mut rng);
        if !prop(&case) {
            panic!(
                "property '{label}' falsified on case #{i} \
                 (HINDSIGHT_PT_SEED={seed}):\n{case:#?}"
            );
        }
    }
}

/// Generators for common shapes.
pub mod gens {
    use super::Pcg32;

    /// Random f32 vector with magnitudes spanning several decades.
    pub fn tensor(rng: &mut Pcg32, max_len: usize) -> Vec<f32> {
        let len = 1 + rng.below(max_len);
        let scale = 10f32.powf(rng.range(-3.0, 3.0));
        (0..len).map(|_| rng.normal() * scale).collect()
    }

    /// A plausible quantization range (possibly degenerate/one-sided).
    pub fn range(rng: &mut Pcg32) -> (f32, f32) {
        match rng.below(4) {
            0 => (0.0, 0.0),                              // degenerate
            1 => (0.0, rng.range(0.01, 50.0)),            // one-sided (ReLU)
            2 => (-rng.range(0.01, 50.0), 0.0),           // one-sided neg
            _ => {
                let lo = rng.range(-50.0, 0.0);
                (lo, lo + rng.range(0.01, 100.0))
            }
        }
    }

    pub fn bits(rng: &mut Pcg32) -> u32 {
        [2, 3, 4, 6, 8][rng.below(5)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall(32, "trivial", |rng| rng.uniform(), |x| (0.0..1.0).contains(x));
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn forall_reports_failures() {
        forall(32, "fails", |rng| rng.uniform(), |x| *x < 0.5);
    }

    #[test]
    fn generators_cover_degenerate_ranges() {
        let mut seen_degenerate = false;
        for i in 0..64 {
            let mut rng = Pcg32::fold(1, "cover", i);
            let (lo, hi) = gens::range(&mut rng);
            assert!(lo <= hi);
            if lo == hi {
                seen_degenerate = true;
            }
        }
        assert!(seen_degenerate);
    }
}

//! Bench harness (criterion stand-in) used by every `cargo bench` target.
//!
//! Provides (a) `time_it` — warmup + timed iterations with mean/p50/p99,
//! and (b) `Table` — aligned table rendering matching the paper's layout
//! so each bench prints the rows of the table it regenerates.
//!
//! Env knobs: `HINDSIGHT_BENCH_STEPS`, `HINDSIGHT_BENCH_SEEDS`,
//! `HINDSIGHT_BENCH_QUICK=1` (CI-scale run).

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::{self, Value};
use crate::util::stats;

/// Timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
}

impl Timing {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Measure `f` — `warmup` untimed calls then `iters` timed calls.
pub fn time_it(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        iters,
        mean_s: stats::mean(&samples),
        p50_s: stats::median(&samples),
        p99_s: stats::percentile(&samples, 99.0),
    }
}

/// Scale knob for table benches: full runs by default, small for CI.
pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

pub fn quick() -> bool {
    std::env::var("HINDSIGHT_BENCH_QUICK").as_deref() == Ok("1")
}

/// Aligned plain-text table writer (paper-style rows).
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("| {:<w$} ", c, w = widths[i]));
            }
            line.push('|');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        use std::io::Write;
        print!("{}", self.render());
        let _ = std::io::stdout().flush();
    }

    /// Render as GitHub-flavoured markdown (for EXPERIMENTS.md).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }
}

/// Format "mean ± std" the way the paper's tables do.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.2} ± {std:.2}")
}

/// Append one record to the kernel-perf trajectory file so successive
/// bench runs accumulate (`BENCH_kernels.json` in the bench's working
/// directory — the crate root under `cargo bench` — or the path in
/// `HINDSIGHT_BENCH_JSON`).  The file is `{"runs": [...]}`; a missing or
/// malformed file is re-seeded.
pub fn append_bench_record(record: Value) -> std::io::Result<PathBuf> {
    let path = PathBuf::from(
        std::env::var("HINDSIGHT_BENCH_JSON").unwrap_or_else(|_| "BENCH_kernels.json".into()),
    );
    append_bench_record_at(&path, record)?;
    Ok(path)
}

/// Path-explicit form of [`append_bench_record`] (testable).
pub fn append_bench_record_at(path: &Path, record: Value) -> std::io::Result<()> {
    let mut doc = std::fs::read_to_string(path)
        .ok()
        .and_then(|s| json::parse(&s).ok())
        .unwrap_or(Value::Null);
    if !matches!(doc, Value::Object(_)) {
        doc = Value::object(vec![("runs", Value::Array(Vec::new()))]);
    }
    if let Value::Object(kv) = &mut doc {
        match kv.iter_mut().find(|(k, _)| k == "runs") {
            Some((_, Value::Array(runs))) => runs.push(record),
            Some((_, other)) => *other = Value::Array(vec![record]),
            None => kv.push(("runs".to_string(), Value::Array(vec![record]))),
        }
    }
    std::fs::write(path, format!("{doc}\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts_iters() {
        let mut n = 0;
        let t = time_it("noop", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(t.iters, 5);
        assert!(t.mean_s >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "Acc"]);
        t.row(&["hindsight".into(), "59.46".into()]);
        t.row(&["fp32".into(), "58.97".into()]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("| hindsight "));
        let md = t.markdown();
        assert!(md.starts_with("| Method | Acc |"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn bench_records_accumulate_in_json() {
        let path = std::env::temp_dir().join(format!(
            "hindsight_bench_test_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let rec = |n: usize| {
            Value::object(vec![
                ("bench", Value::from("unit-test")),
                ("n", Value::from(n)),
            ])
        };
        append_bench_record_at(&path, rec(1)).unwrap();
        append_bench_record_at(&path, rec(2)).unwrap();
        let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let runs = doc.get("runs").unwrap().as_array().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[1].get("n").unwrap().as_usize(), Some(2));
        // a malformed file is re-seeded, not crashed on
        std::fs::write(&path, "not json").unwrap();
        append_bench_record_at(&path, rec(3)).unwrap();
        let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("runs").unwrap().as_array().unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}

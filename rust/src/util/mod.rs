//! Hand-rolled substrates (offline build: no serde/clap/rand/criterion).

pub mod bench;
pub mod cli;
pub mod fuzzing;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod testkit;

//! Minimal JSON parser/serializer (serde_json stand-in; offline build).
//!
//! Supports the full JSON grammar minus exotic number forms; preserves
//! object key order (the manifest relies on positional marshalling, and
//! ordered keys make diffs and round-trips deterministic).
//!
//! Hardened for untrusted input (the sweep service feeds network bytes
//! straight in): nesting is bounded by [`MAX_DEPTH`] and input size by
//! [`MAX_INPUT_BYTES`], both returning a clean [`ParseError`] instead
//! of a stack overflow or an unbounded allocation.
//!
//! Two representations share the grammar:
//!
//! * [`Value`] — the owned tree ([`parse`] / `Display`), used everywhere
//!   a document is built or mutated.
//! * [`raw::RawDoc`] — a bytes-backed lazy view over a shared
//!   `Arc<[u8]>` buffer for the parse-once/serve-many read path.
//!   Strings without escapes borrow straight from the buffer
//!   (copy-on-escape); every node remembers its source span so
//!   already-canonical subtrees can be spliced into responses without
//!   re-serialization.  [`raw::RawRef`] and `&Value` expose the same
//!   accessor surface through [`JsonView`].

use std::collections::BTreeMap;
use std::fmt;

pub mod raw;

pub use raw::{RawDoc, RawRef};

/// Process-wide instrumentation for the parse-once/serve-many claim.
///
/// Every document parse ([`parse`] and [`raw::RawDoc`] construction)
/// and every top-level tree serialization (`Value as Display`) bumps a
/// counter.  The serve e2e tests and `benches/serve_http.rs` snapshot
/// these around a warm results GET to prove the hot path does zero
/// JSON work — instrumentation, not vibes.
pub mod count {
    use std::sync::atomic::{AtomicU64, Ordering};

    static PARSES: AtomicU64 = AtomicU64::new(0);
    static SERIALIZES: AtomicU64 = AtomicU64::new(0);

    pub(super) fn record_parse() {
        PARSES.fetch_add(1, Ordering::Relaxed);
    }

    pub(super) fn record_serialize() {
        SERIALIZES.fetch_add(1, Ordering::Relaxed);
    }

    /// Documents parsed since process start (owned + raw).
    pub fn parses() -> u64 {
        PARSES.load(Ordering::Relaxed)
    }

    /// Top-level `Value` tree serializations since process start.
    pub fn serializes() -> u64 {
        SERIALIZES.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Checked f64 -> integer conversion
// ---------------------------------------------------------------------------

/// `f64` -> `i64` only when the value is finite, integral, and in
/// range.  `2^63` itself is exactly representable but one past
/// `i64::MAX`, so the upper bound is exclusive; `-2^63` is `i64::MIN`
/// exactly and allowed.
pub fn f64_to_i64(f: f64) -> Option<i64> {
    const LO: f64 = -9_223_372_036_854_775_808.0; // -2^63 == i64::MIN
    const HI: f64 = 9_223_372_036_854_775_808.0; // 2^63 == i64::MAX + 1
    if !f.is_finite() || f.fract() != 0.0 || f < LO || f >= HI {
        return None;
    }
    Some(f as i64)
}

/// `f64` -> `u64` only when the value is finite, integral,
/// non-negative, and below `2^64`.
pub fn f64_to_u64(f: f64) -> Option<u64> {
    const HI: f64 = 18_446_744_073_709_551_616.0; // 2^64 == u64::MAX + 1
    if !f.is_finite() || f.fract() != 0.0 || f < 0.0 || f >= HI {
        return None;
    }
    Some(f as u64)
}

/// `f64` -> `usize` only when the value is finite, integral,
/// non-negative, and fits the platform word.
pub fn f64_to_usize(f: f64) -> Option<usize> {
    f64_to_u64(f).and_then(|n| usize::try_from(n).ok())
}

/// Lossless JSON encoding of a `u64`: values that survive the f64 hop
/// exactly stay JSON numbers (byte-identical to every document written
/// before this helper existed), anything that would round — odd values
/// above 2^53, `u64::MAX` — is emitted as a decimal string, which
/// [`lossless_u64`] reads back exactly.  This is how the run store and
/// job files persist seeds without the `Num(s as f64)` precision bug.
pub fn u64_value(n: u64) -> Value {
    if f64_to_u64(n as f64) == Some(n) {
        Value::Num(n as f64)
    } else {
        Value::Str(n.to_string())
    }
}

/// Reader for [`u64_value`]'s dual encoding: a checked integral number
/// or a canonical decimal string (leading zeros, signs, and whitespace
/// are rejected — a seed either round-trips exactly or fails loud).
pub fn lossless_u64<'a, V: JsonView<'a>>(v: V) -> Option<u64> {
    if let Some(f) = v.as_f64() {
        return f64_to_u64(f);
    }
    v.as_str()
        .and_then(|s| s.parse::<u64>().ok().filter(|n| n.to_string() == s))
}

// ---------------------------------------------------------------------------
// Uniform accessor surface over both representations
// ---------------------------------------------------------------------------

/// Read-only JSON accessors implemented by both `&Value` and
/// [`raw::RawRef`], so decoders (e.g. `RunRecord::from_json`) can be
/// written once and run against either the owned tree or the
/// zero-copy view.
pub trait JsonView<'a>: Sized + Copy {
    fn get(self, key: &str) -> Option<Self>;
    fn as_str(self) -> Option<&'a str>;
    fn as_f64(self) -> Option<f64>;
    fn as_bool(self) -> Option<bool>;
    fn items(self) -> Option<Vec<Self>>;
    fn entries(self) -> Option<Vec<(&'a str, Self)>>;

    fn as_i64(self) -> Option<i64> {
        self.as_f64().and_then(f64_to_i64)
    }

    fn as_usize(self) -> Option<usize> {
        self.as_f64().and_then(f64_to_usize)
    }
}

impl<'a> JsonView<'a> for &'a Value {
    fn get(self, key: &str) -> Option<Self> {
        Value::get(self, key)
    }

    fn as_str(self) -> Option<&'a str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_f64(self) -> Option<f64> {
        Value::as_f64(self)
    }

    fn as_bool(self) -> Option<bool> {
        Value::as_bool(self)
    }

    fn items(self) -> Option<Vec<Self>> {
        match self {
            Value::Array(a) => Some(a.iter().collect()),
            _ => None,
        }
    }

    fn entries(self) -> Option<Vec<(&'a str, Self)>> {
        match self {
            Value::Object(kv) => Some(kv.iter().map(|(k, v)| (k.as_str(), v)).collect()),
            _ => None,
        }
    }
}

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    /// Key-ordered object (insertion order preserved).
    Object(Vec<(String, Value)>),
}

/// Parse error with byte offset context.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl Value {
    // ---------------- accessors ----------------

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with a path-ish message (manifest loading).
    pub fn req(&self, key: &str) -> Result<&Value, ParseError> {
        self.get(key).ok_or_else(|| ParseError {
            pos: 0,
            msg: format!("missing key '{key}'"),
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integral numbers only: non-integral, non-finite, or
    /// out-of-range values return `None` (they used to silently
    /// truncate through an `as` cast).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(f64_to_i64)
    }

    /// Integral non-negative numbers only; see [`Value::as_i64`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(f64_to_usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Convenience: `[1,2,3]` -> `vec![1usize,2,3]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_array()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
    }

    // ---------------- constructors ----------------

    pub fn object(kv: Vec<(&str, Value)>) -> Value {
        Value::Object(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_map(map: &BTreeMap<String, f64>) -> Value {
        Value::Object(
            map.iter()
                .map(|(k, v)| (k.clone(), Value::Num(*v)))
                .collect(),
        )
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}
impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Maximum container nesting depth `parse` accepts.  The recursive
/// descent uses one stack frame per level, so this bounds stack use on
/// adversarial input like `"[".repeat(1 << 20)`; 128 levels is far
/// beyond any document the crate produces or consumes.
pub const MAX_DEPTH: usize = 128;

/// Maximum input size `parse` accepts (64 MiB).  The parser is O(n) in
/// time but can allocate a multiple of the input size for pathological
/// documents; capping the input bounds both.
pub const MAX_INPUT_BYTES: usize = 64 * 1024 * 1024;

pub fn parse(text: &str) -> Result<Value, ParseError> {
    count::record_parse();
    if text.len() > MAX_INPUT_BYTES {
        return Err(ParseError {
            pos: 0,
            msg: format!("input of {} bytes exceeds cap of {MAX_INPUT_BYTES}", text.len()),
        });
    }
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
        depth: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// current container nesting level (bounded by [`MAX_DEPTH`])
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => {
                self.descend()?;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.descend()?;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn descend(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Object(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Object(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Array(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Array(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match txt.parse::<f64>() {
            // overflow to ±inf ("1e999") would serialize as "inf",
            // which no JSON parser reads back: reject at the source so
            // every accepted number survives a serialize -> parse trip
            Ok(n) if n.is_finite() => Ok(Value::Num(n)),
            Ok(_) => Err(self.err("number out of range")),
            Err(_) => Err(self.err("invalid number")),
        }
    }
}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // one count per tree (nested nodes go through `write` directly)
        count::record_serialize();
        write(self, f)
    }
}

fn write(v: &Value, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    match v {
        Value::Null => f.write_str("null"),
        Value::Bool(b) => write!(f, "{b}"),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                write!(f, "{}", *n as i64)
            } else {
                write!(f, "{n}")
            }
        }
        Value::Str(s) => write_str(s, f),
        Value::Array(a) => {
            f.write_str("[")?;
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                write(v, f)?;
            }
            f.write_str("]")
        }
        Value::Object(kv) => {
            f.write_str("{")?;
            for (i, (k, v)) in kv.iter().enumerate() {
                if i > 0 {
                    f.write_str(",")?;
                }
                write_str(k, f)?;
                f.write_str(":")?;
                write(v, f)?;
            }
            f.write_str("}")
        }
    }
}

fn write_str(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    escape_into(s, f)
}

/// Write `s` as a quoted JSON string literal, byte-identical to how the
/// `Value` serializer emits it.  Public so response assembly can escape
/// individual strings without building a `Value` tree.
pub fn escape_into<W: fmt::Write>(s: &str, f: &mut W) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => f.write_char(c)?,
        }
    }
    f.write_str("\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,true,null,"s\"q"],"y":{"z":[]}}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01abc").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A米""#).unwrap(), Value::Str("A米".into()));
    }

    #[test]
    fn key_order_preserved() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_object().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    fn nested(depth: usize) -> String {
        let mut s = "[".repeat(depth);
        s.push('0');
        s.push_str(&"]".repeat(depth));
        s
    }

    #[test]
    fn depth_limit_boundary() {
        assert!(parse(&nested(MAX_DEPTH)).is_ok(), "exactly MAX_DEPTH must parse");
        let err = parse(&nested(MAX_DEPTH + 1)).unwrap_err();
        assert!(err.msg.contains("nesting"), "{}", err.msg);
        // mixed object/array nesting counts the same budget
        let mut s = String::new();
        for _ in 0..MAX_DEPTH / 2 {
            s.push_str("{\"k\":[");
        }
        s.push('0');
        for _ in 0..MAX_DEPTH / 2 {
            s.push_str("]}");
        }
        assert!(parse(&s).is_ok());
    }

    #[test]
    fn deep_nesting_errors_instead_of_overflowing_the_stack() {
        // pre-hardening this recursed ~100k frames and crashed the
        // process; now it must return a clean error
        let bomb = "[".repeat(100_000);
        let err = parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting"), "{}", err.msg);
        let obj_bomb = "{\"a\":".repeat(100_000);
        assert!(parse(&obj_bomb).is_err());
    }

    #[test]
    fn oversized_input_is_rejected_up_front() {
        let big = format!("\"{}\"", "a".repeat(MAX_INPUT_BYTES));
        let err = parse(&big).unwrap_err();
        assert!(err.msg.contains("exceeds cap"), "{}", err.msg);
    }

    #[test]
    fn prop_nesting_parses_iff_within_depth_budget() {
        use crate::util::testkit::forall;
        forall(
            crate::util::testkit::default_cases(),
            "json_depth_budget",
            |rng| 1 + rng.below(2 * MAX_DEPTH),
            |&d| parse(&nested(d)).is_ok() == (d <= MAX_DEPTH),
        );
    }

    #[test]
    fn prop_finite_tensors_round_trip_through_display() {
        use crate::util::testkit::{forall, gens};
        forall(
            crate::util::testkit::default_cases(),
            "json_tensor_roundtrip",
            |rng| gens::tensor(rng, 64),
            |xs| {
                let v = Value::Array(
                    xs.iter()
                        .map(|&x| Value::Num(if x.is_finite() { x as f64 } else { 0.0 }))
                        .collect(),
                );
                parse(&v.to_string()).map(|back| back == v).unwrap_or(false)
            },
        );
    }

    #[test]
    fn integer_accessors_reject_non_integral_and_out_of_range() {
        // integral values in range pass, including 2^53 (the last
        // contiguous f64 integer) and the exact i64::MIN
        let p53 = 9_007_199_254_740_992.0_f64; // 2^53
        assert_eq!(Value::Num(p53).as_i64(), Some(1_i64 << 53));
        assert_eq!(Value::Num(p53).as_usize(), Some(1_usize << 53));
        assert_eq!(Value::Num(-p53).as_i64(), Some(-(1_i64 << 53)));
        assert_eq!(
            Value::Num(-9_223_372_036_854_775_808.0).as_i64(),
            Some(i64::MIN)
        );
        assert_eq!(Value::Num(0.0).as_usize(), Some(0));
        assert_eq!(Value::Num(-0.0).as_usize(), Some(0));

        // non-integral: used to truncate (1.9 -> 1), now None
        assert_eq!(Value::Num(1.9).as_i64(), None);
        assert_eq!(Value::Num(1.9).as_usize(), None);
        assert_eq!(Value::Num(-0.5).as_i64(), None);

        // negatives never fit usize
        assert_eq!(Value::Num(-1.0).as_usize(), None);

        // out of range: 2^63 is one past i64::MAX, 2^64 one past u64::MAX
        assert_eq!(Value::Num(9_223_372_036_854_775_808.0).as_i64(), None);
        assert_eq!(Value::Num(18_446_744_073_709_551_616.0).as_usize(), None);
        assert_eq!(Value::Num(1e300).as_i64(), None);

        // non-finite
        assert_eq!(Value::Num(f64::NAN).as_i64(), None);
        assert_eq!(Value::Num(f64::NAN).as_usize(), None);
        assert_eq!(Value::Num(f64::INFINITY).as_i64(), None);
        assert_eq!(Value::Num(f64::NEG_INFINITY).as_usize(), None);

        // non-numbers unchanged
        assert_eq!(Value::Str("3".into()).as_i64(), None);
    }

    /// Regression (fuzz finding): `1e999` used to parse to `inf`,
    /// whose serialization ("inf") no parser reads back.
    #[test]
    fn overflowing_numbers_are_rejected_not_infinity() {
        for s in ["1e999", "-1e999", "1e308e", "123456789e400"] {
            assert!(parse(s).is_err(), "'{s}' must not parse");
        }
        let err = parse("1e999").unwrap_err();
        assert!(err.msg.contains("out of range"), "{}", err.msg);
        // large-but-finite still parses; subnormal underflow is fine
        assert_eq!(parse("1e308").unwrap(), Value::Num(1e308));
        assert_eq!(parse("1e-999").unwrap(), Value::Num(0.0));
    }

    #[test]
    fn u64_value_round_trips_every_magnitude() {
        let p53 = 1_u64 << 53;
        for n in [
            0,
            1,
            p53 - 1,
            p53,
            p53 + 1,
            p53 + 2,
            1_u64 << 63,
            u64::MAX - 1,
            u64::MAX,
        ] {
            let v = u64_value(n);
            assert_eq!(lossless_u64(&v), Some(n), "direct trip for {n}");
            let text = Value::object(vec![("n", v)]).to_string();
            let back = parse(&text).unwrap();
            assert_eq!(
                lossless_u64(back.get("n").unwrap()),
                Some(n),
                "serialized trip for {n}: {text}"
            );
        }
        // values ≤ 2^53 keep the plain number form (back-compat with
        // documents written before the dual encoding)
        assert_eq!(u64_value(42), Value::Num(42.0));
        assert_eq!(u64_value(p53), Value::Num(p53 as f64));
        // u64::MAX rounds to 2^64 as f64 and must take the string form
        assert!(matches!(u64_value(u64::MAX), Value::Str(_)));
        // the reader rejects non-canonical strings
        for s in ["+5", "05", " 5", "5 ", "-1", "1.0", ""] {
            assert_eq!(lossless_u64(&Value::Str(s.into())), None, "'{s}'");
        }
        assert_eq!(lossless_u64(&Value::Num(1.5)), None);
        assert_eq!(lossless_u64(&Value::Num(-1.0)), None);
    }

    #[test]
    fn prop_garbage_never_panics() {
        use crate::util::testkit::forall;
        const CHARSET: &[u8] = b"{}[]\",:0123456789.eE+-\\ truefalsn\n\tu00\x7f";
        forall(
            crate::util::testkit::default_cases(),
            "json_garbage_fuzz",
            |rng| {
                let len = rng.below(256);
                (0..len)
                    .map(|_| CHARSET[rng.below(CHARSET.len())] as char)
                    .collect::<String>()
            },
            // the property is simply "parse returns" — ok or clean err
            |s| {
                let _ = parse(s);
                true
            },
        );
    }
}

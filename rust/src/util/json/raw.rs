//! Bytes-backed lazy JSON: the parse-once/serve-many read path.
//!
//! [`RawDoc`] parses a document once into a skeleton of spans over a
//! shared `Arc<[u8]>` buffer.  Strings without escape sequences stay
//! borrowed slices of the input (copy-on-escape: only strings
//! containing `\` materialize an owned `String`); numbers are decoded
//! eagerly (an `f64` is smaller than a span) but remember their source
//! span like every other node, so any subtree's exact source bytes can
//! be spliced into an outgoing response without re-serialization.
//!
//! The grammar, nesting/size caps, and every accepted/rejected input
//! are identical to the owned [`parse`](super::parse) — pinned by the
//! differential property tests in `tests/json_raw_conformance.rs`.

use std::sync::Arc;

use super::{
    count, f64_to_i64, f64_to_usize, JsonView, ParseError, Value, MAX_DEPTH, MAX_INPUT_BYTES,
};

/// Byte range into a [`RawDoc`] buffer (`start..end`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub start: usize,
    pub end: usize,
}

/// A string inside a [`RawDoc`]: borrowed from the buffer when the
/// source literal had no escapes, owned (materialized once, at parse
/// time) when it did.
#[derive(Debug, Clone, PartialEq)]
pub enum RawStr {
    /// Span of the string *contents* (between the quotes); escape-free.
    Borrowed(Span),
    /// The literal contained `\`-escapes; decoded at parse time.
    Owned(String),
}

impl RawStr {
    fn as_str<'a>(&'a self, buf: &'a [u8]) -> &'a str {
        match self {
            // the whole buffer is validated UTF-8 before parsing and
            // span edges sit on ASCII quotes, so the slice is valid
            RawStr::Borrowed(sp) => std::str::from_utf8(&buf[sp.start..sp.end])
                .expect("RawDoc buffer validated as UTF-8 at parse"),
            RawStr::Owned(s) => s,
        }
    }
}

/// One node of the parsed skeleton.  Every variant records the span of
/// its source text so `raw_bytes` can splice canonical subtrees.
#[derive(Debug, Clone, PartialEq)]
pub enum RawNode {
    Null { span: Span },
    Bool { value: bool, span: Span },
    Num { value: f64, span: Span },
    Str { value: RawStr, span: Span },
    Array { items: Vec<RawNode>, span: Span },
    Object { members: Vec<(RawStr, RawNode)>, span: Span },
}

impl RawNode {
    fn span(&self) -> Span {
        match self {
            RawNode::Null { span }
            | RawNode::Bool { span, .. }
            | RawNode::Num { span, .. }
            | RawNode::Str { span, .. }
            | RawNode::Array { span, .. }
            | RawNode::Object { span, .. } => *span,
        }
    }
}

/// A parsed document holding its input alive in a shared buffer.
///
/// Cheap to clone behind an `Arc`; the store's document cache hands out
/// `Arc<RawDoc>`-backed views so one parse serves every subsequent
/// request for the same cell file.
#[derive(Debug, Clone)]
pub struct RawDoc {
    buf: Arc<[u8]>,
    root: RawNode,
}

impl RawDoc {
    /// Parse from a `&str` (copies the text into a fresh shared buffer).
    pub fn parse(text: &str) -> Result<RawDoc, ParseError> {
        Self::parse_arc(Arc::from(text.as_bytes()))
    }

    /// Parse from an already-shared buffer without copying it.  The
    /// buffer must be UTF-8 (network/disk bytes are validated here).
    pub fn parse_arc(buf: Arc<[u8]>) -> Result<RawDoc, ParseError> {
        count::record_parse();
        if buf.len() > MAX_INPUT_BYTES {
            return Err(ParseError {
                pos: 0,
                msg: format!("input of {} bytes exceeds cap of {MAX_INPUT_BYTES}", buf.len()),
            });
        }
        if let Err(e) = std::str::from_utf8(&buf) {
            return Err(ParseError {
                pos: e.valid_up_to(),
                msg: "invalid utf8".to_string(),
            });
        }
        let mut p = RawParser { b: &buf, i: 0, depth: 0 };
        p.ws();
        let root = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(RawDoc { buf, root })
    }

    /// Root node view.
    pub fn root(&self) -> RawRef<'_> {
        RawRef { buf: &self.buf, node: &self.root }
    }

    /// The shared input buffer.
    pub fn buf(&self) -> &Arc<[u8]> {
        &self.buf
    }

    /// Deep-convert to the owned representation (differential tests,
    /// escape hatch for mutation).
    pub fn to_value(&self) -> Value {
        self.root().to_value()
    }
}

/// Copyable view of one node plus the buffer it points into — the
/// zero-copy analog of `&Value`, sharing its accessor names (and the
/// [`JsonView`] trait) so decoders work against either.
#[derive(Debug, Clone, Copy)]
pub struct RawRef<'a> {
    buf: &'a [u8],
    node: &'a RawNode,
}

impl<'a> RawRef<'a> {
    fn at(&self, node: &'a RawNode) -> RawRef<'a> {
        RawRef { buf: self.buf, node }
    }

    /// Source span of this node in the document buffer.
    pub fn span(&self) -> Span {
        self.node.span()
    }

    /// The exact source bytes of this node — already serialized JSON,
    /// spliceable into a response when the source is canonical.
    pub fn raw_bytes(&self) -> &'a [u8] {
        let sp = self.node.span();
        &self.buf[sp.start..sp.end]
    }

    pub fn get(&self, key: &str) -> Option<RawRef<'a>> {
        match self.node {
            RawNode::Object { members, .. } => members
                .iter()
                .find(|(k, _)| k.as_str(self.buf) == key)
                .map(|(_, v)| self.at(v)),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&'a str> {
        match self.node {
            RawNode::Str { value, .. } => Some(value.as_str(self.buf)),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self.node {
            RawNode::Num { value, .. } => Some(*value),
            _ => None,
        }
    }

    /// Checked like [`Value::as_i64`]: integral in-range numbers only.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(f64_to_i64)
    }

    /// Checked like [`Value::as_usize`].
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(f64_to_usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self.node {
            RawNode::Bool { value, .. } => Some(*value),
            _ => None,
        }
    }

    /// Array element views, in order.
    pub fn items(&self) -> Option<Vec<RawRef<'a>>> {
        match self.node {
            RawNode::Array { items, .. } => Some(items.iter().map(|n| self.at(n)).collect()),
            _ => None,
        }
    }

    /// Object member views, in key order.
    pub fn entries(&self) -> Option<Vec<(&'a str, RawRef<'a>)>> {
        match self.node {
            RawNode::Object { members, .. } => Some(
                members
                    .iter()
                    .map(|(k, v)| (k.as_str(self.buf), self.at(v)))
                    .collect(),
            ),
            _ => None,
        }
    }

    /// True when this node is a string borrowed straight from the
    /// buffer (i.e. the copy-on-escape fast path applied).
    pub fn is_borrowed_str(&self) -> bool {
        matches!(
            self.node,
            RawNode::Str {
                value: RawStr::Borrowed(_),
                ..
            }
        )
    }

    /// Deep-convert this subtree to an owned [`Value`].
    pub fn to_value(&self) -> Value {
        match self.node {
            RawNode::Null { .. } => Value::Null,
            RawNode::Bool { value, .. } => Value::Bool(*value),
            RawNode::Num { value, .. } => Value::Num(*value),
            RawNode::Str { value, .. } => Value::Str(value.as_str(self.buf).to_string()),
            RawNode::Array { items, .. } => {
                Value::Array(items.iter().map(|n| self.at(n).to_value()).collect())
            }
            RawNode::Object { members, .. } => Value::Object(
                members
                    .iter()
                    .map(|(k, v)| (k.as_str(self.buf).to_string(), self.at(v).to_value()))
                    .collect(),
            ),
        }
    }
}

impl<'a> JsonView<'a> for RawRef<'a> {
    fn get(self, key: &str) -> Option<Self> {
        RawRef::get(&self, key)
    }

    fn as_str(self) -> Option<&'a str> {
        RawRef::as_str(&self)
    }

    fn as_f64(self) -> Option<f64> {
        RawRef::as_f64(&self)
    }

    fn as_bool(self) -> Option<bool> {
        RawRef::as_bool(&self)
    }

    fn items(self) -> Option<Vec<Self>> {
        RawRef::items(&self)
    }

    fn entries(self) -> Option<Vec<(&'a str, Self)>> {
        RawRef::entries(&self)
    }
}

// ---------------------------------------------------------------------------
// Parser — mirrors super::Parser exactly (grammar, caps, error points)
// ---------------------------------------------------------------------------

struct RawParser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> RawParser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str) -> Result<Span, ParseError> {
        let start = self.i;
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(Span { start, end: self.i })
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<RawNode, ParseError> {
        match self.peek() {
            Some(b'{') => {
                self.descend()?;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.descend()?;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => {
                let start = self.i;
                let value = self.string()?;
                Ok(RawNode::Str {
                    value,
                    span: Span { start, end: self.i },
                })
            }
            Some(b't') => {
                let span = self.lit("true")?;
                Ok(RawNode::Bool { value: true, span })
            }
            Some(b'f') => {
                let span = self.lit("false")?;
                Ok(RawNode::Bool { value: false, span })
            }
            Some(b'n') => {
                let span = self.lit("null")?;
                Ok(RawNode::Null { span })
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn descend(&mut self) -> Result<(), ParseError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        Ok(())
    }

    fn object(&mut self) -> Result<RawNode, ParseError> {
        let start = self.i;
        self.eat(b'{')?;
        let mut members = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(RawNode::Object {
                members,
                span: Span { start, end: self.i },
            });
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            members.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(RawNode::Object {
                        members,
                        span: Span { start, end: self.i },
                    });
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<RawNode, ParseError> {
        let start = self.i;
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(RawNode::Array {
                items,
                span: Span { start, end: self.i },
            });
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(RawNode::Array {
                        items,
                        span: Span { start, end: self.i },
                    });
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<RawStr, ParseError> {
        self.eat(b'"')?;
        let content_start = self.i;
        // fast path: no escapes -> borrow the contents span verbatim.
        // UTF-8 validity of the whole buffer was checked up front, so
        // skipping bytes until '"' or '\\' cannot split a scalar.
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let span = Span { start: content_start, end: self.i };
                    self.i += 1;
                    return Ok(RawStr::Borrowed(span));
                }
                Some(b'\\') => break,
                Some(_) => self.i += 1,
            }
        }
        // copy-on-escape: rewind and materialize with the exact escape
        // loop of the owned parser (same errors at the same offsets)
        self.i = content_start;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(RawStr::Owned(s));
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<RawNode, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let span = Span { start, end: self.i };
        let txt = std::str::from_utf8(&self.b[span.start..span.end]).unwrap();
        match txt.parse::<f64>() {
            // mirror the owned parser exactly: overflow to ±inf is a
            // parse error, not a Num that cannot round-trip
            Ok(value) if value.is_finite() => Ok(RawNode::Num { value, span }),
            Ok(_) => Err(self.err("number out of range")),
            Err(_) => Err(self.err("invalid number")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    /// Regression (fuzz finding): both parsers must reject `1e999`
    /// identically — same error position, same message.
    #[test]
    fn overflowing_numbers_rejected_in_lockstep_with_owned_parser() {
        for s in ["1e999", "[-1e999]", "{\"n\":2e400}"] {
            let owned = parse(s).unwrap_err();
            let raw = RawDoc::parse(s).unwrap_err();
            assert_eq!(owned.pos, raw.pos, "pos for '{s}'");
            assert_eq!(owned.msg, raw.msg, "msg for '{s}'");
            assert!(raw.msg.contains("out of range"), "{}", raw.msg);
        }
    }

    #[test]
    fn borrows_plain_strings_and_materializes_escaped_ones() {
        let src = r#"{"plain":"abc米","esc":"a\nb"}"#;
        let doc = RawDoc::parse(src).unwrap();
        let plain = doc.root().get("plain").unwrap();
        assert!(plain.is_borrowed_str());
        assert_eq!(plain.as_str(), Some("abc米"));
        // the borrowed &str points into the doc's own buffer
        let s = plain.as_str().unwrap();
        let base = doc.buf().as_ptr() as usize;
        assert!((base..base + doc.buf().len()).contains(&(s.as_ptr() as usize)));
        let esc = doc.root().get("esc").unwrap();
        assert!(!esc.is_borrowed_str());
        assert_eq!(esc.as_str(), Some("a\nb"));
    }

    #[test]
    fn spans_cover_exact_source_bytes() {
        let src = r#"  {"a": [1, 2.5], "b": "x"}  "#;
        let doc = RawDoc::parse(src).unwrap();
        assert_eq!(doc.root().raw_bytes(), br#"{"a": [1, 2.5], "b": "x"}"#);
        let arr = doc.root().get("a").unwrap();
        assert_eq!(arr.raw_bytes(), b"[1, 2.5]");
        assert_eq!(arr.items().unwrap()[1].raw_bytes(), b"2.5");
        assert_eq!(doc.root().get("b").unwrap().raw_bytes(), br#""x""#);
    }

    #[test]
    fn matches_owned_parser_on_basics() {
        for src in [
            "null",
            "true",
            "-1.5e3",
            r#""aAb""#,
            r#"{"z":1,"a":[true,null,"s\"q"],"m":{"x":[]}}"#,
        ] {
            let owned = parse(src).unwrap();
            let raw = RawDoc::parse(src).unwrap();
            assert_eq!(raw.to_value(), owned, "src={src}");
        }
        for src in ["{", "[1,]", "01abc", "\"unterminated", "{\"a\":1} extra"] {
            assert!(RawDoc::parse(src).is_err(), "src={src}");
        }
    }

    #[test]
    fn invalid_utf8_bytes_rejected() {
        let buf: Arc<[u8]> = Arc::from(&b"\"ab\xff\""[..]);
        assert!(RawDoc::parse_arc(buf).is_err());
    }
}

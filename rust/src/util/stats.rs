//! Small statistics toolkit: mean/std aggregation for multi-seed sweeps
//! (the paper reports "average of N seeds ± standard deviation") and
//! Welford online accumulation for streaming metrics.

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator, as in the paper's tables;
/// 0.0 for a single sample).
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorted copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0, 100] via nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.138089935).abs() < 1e-6);
        assert_eq!(median(&xs), 4.5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.5, -3.0, 4.0, 0.5];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std(&[1.0]), 0.0);
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
    }
}

//! Run records and aggregation: loss curves, eval accuracy, per-seed
//! aggregation into the paper's "mean ± std" rows, CSV export.

use std::collections::BTreeMap;
use std::io::Write;

use crate::util::stats;

/// Metrics of a single training run (one seed, one configuration).
#[derive(Debug, Clone, Default)]
pub struct RunRecord {
    pub name: String,
    pub steps: Vec<u64>,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    /// (step, val_loss, val_acc) from periodic evaluations
    pub evals: Vec<(u64, f32, f32)>,
    /// wall-clock seconds of the step loop (excl. compilation)
    pub train_seconds: f64,
    /// extra scalar outcomes (e.g. final ranges, dsgc evals)
    pub extra: BTreeMap<String, f64>,
}

impl RunRecord {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn log_step(&mut self, step: u64, loss: f32, acc: f32) {
        self.steps.push(step);
        self.losses.push(loss);
        self.accs.push(acc);
    }

    pub fn log_eval(&mut self, step: u64, loss: f32, acc: f32) {
        self.evals.push((step, loss, acc));
    }

    /// Final validation accuracy (%, the paper's headline number).
    pub fn final_val_acc(&self) -> f64 {
        self.evals.last().map(|e| e.2 as f64 * 100.0).unwrap_or(f64::NAN)
    }

    /// Best validation accuracy over the run (%).
    pub fn best_val_acc(&self) -> f64 {
        self.evals
            .iter()
            .map(|e| e.2 as f64 * 100.0)
            .fold(f64::NAN, f64::max)
    }

    /// Mean training loss over the last `k` logged steps.
    pub fn tail_loss(&self, k: usize) -> f64 {
        let n = self.losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let s = n.saturating_sub(k);
        stats::mean(&self.losses[s..].iter().map(|&x| x as f64).collect::<Vec<_>>())
    }

    /// True if the loss curve actually went down (e2e sanity check).
    pub fn loss_decreased(&self) -> bool {
        if self.losses.len() < 10 {
            return false;
        }
        let head = stats::mean(
            &self.losses[..5].iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        self.tail_loss(5) < head
    }

    /// Write the loss curve as CSV.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,acc")?;
        for i in 0..self.steps.len() {
            writeln!(f, "{},{},{}", self.steps[i], self.losses[i], self.accs[i])?;
        }
        Ok(())
    }
}

/// Aggregate of several seeds of the same configuration.
#[derive(Debug, Clone)]
pub struct SeedAggregate {
    pub name: String,
    pub accs: Vec<f64>,
}

impl SeedAggregate {
    pub fn from_runs(name: &str, runs: &[RunRecord]) -> Self {
        Self {
            name: name.to_string(),
            accs: runs.iter().map(|r| r.final_val_acc()).collect(),
        }
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.accs)
    }

    pub fn std(&self) -> f64 {
        stats::std(&self.accs)
    }

    /// "59.46 ± 0.71"-style cell.
    pub fn cell(&self) -> String {
        format!("{:.2} ± {:.2}", self.mean(), self.std())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_with(evals: &[(u64, f32, f32)], losses: &[f32]) -> RunRecord {
        let mut r = RunRecord::new("t");
        for (i, &l) in losses.iter().enumerate() {
            r.log_step(i as u64, l, 0.5);
        }
        for &(s, l, a) in evals {
            r.log_eval(s, l, a);
        }
        r
    }

    #[test]
    fn final_and_best_acc() {
        let r = run_with(&[(10, 1.0, 0.50), (20, 0.9, 0.62), (30, 1.1, 0.58)], &[]);
        assert!((r.final_val_acc() - 58.0).abs() < 1e-4);
        assert!((r.best_val_acc() - 62.0).abs() < 1e-4);
    }

    #[test]
    fn loss_decrease_detection() {
        let down: Vec<f32> = (0..50).map(|i| 3.0 - 0.05 * i as f32).collect();
        let flat: Vec<f32> = (0..50).map(|_| 3.0).collect();
        assert!(run_with(&[], &down).loss_decreased());
        assert!(!run_with(&[], &flat).loss_decreased());
    }

    #[test]
    fn aggregate_cells() {
        let runs: Vec<RunRecord> = [0.59f32, 0.60, 0.58]
            .iter()
            .map(|&a| run_with(&[(1, 1.0, a)], &[]))
            .collect();
        let agg = SeedAggregate::from_runs("hindsight", &runs);
        assert!((agg.mean() - 59.0).abs() < 1e-3);
        assert!(agg.cell().contains("±"));
    }

    #[test]
    fn csv_roundtrip() {
        let r = run_with(&[], &[1.0, 0.5]);
        let p = std::env::temp_dir().join("hindsight_metrics_test.csv");
        r.write_csv(p.to_str().unwrap()).unwrap();
        let txt = std::fs::read_to_string(&p).unwrap();
        assert!(txt.starts_with("step,loss,acc"));
        assert_eq!(txt.lines().count(), 3);
        let _ = std::fs::remove_file(p);
    }
}

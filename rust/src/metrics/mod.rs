//! Run records and aggregation: loss curves, eval accuracy, per-seed
//! aggregation into the paper's "mean ± std" rows, CSV export, and the
//! JSON form the resumable run store (`coordinator::store`) persists.

use std::collections::BTreeMap;
use std::io::Write;

use anyhow::{Context, Result};

use crate::util::json::{JsonView, RawRef, Value};
use crate::util::stats;

/// Metrics of a single training run (one seed, one configuration).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunRecord {
    pub name: String,
    pub steps: Vec<u64>,
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
    /// (step, val_loss, val_acc) from periodic evaluations
    pub evals: Vec<(u64, f32, f32)>,
    /// wall-clock seconds of the step loop (excl. compilation)
    pub train_seconds: f64,
    /// extra scalar outcomes (e.g. final ranges, dsgc evals)
    pub extra: BTreeMap<String, f64>,
}

impl RunRecord {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            ..Default::default()
        }
    }

    /// Deterministic pseudo-run derived only from `name` — a stand-in
    /// trainer for the grid-executor tests and the `grid_sweep` smoke
    /// bench, which exercise expansion/executor/store logic without
    /// compiled artifacts.  Same name → bit-identical record, like a
    /// real run's dependence on its configuration.
    pub fn synthetic(name: &str, steps: u64) -> Self {
        let mut rng = crate::util::rng::Pcg32::fold(0x5EED_CE11, name, steps);
        let mut r = Self::new(name);
        for step in 0..steps {
            let x = rng.uniform();
            r.log_step(step, 2.0 - x, x);
        }
        r.log_eval(steps, 1.0, rng.uniform());
        r.train_seconds = 0.01;
        r
    }

    pub fn log_step(&mut self, step: u64, loss: f32, acc: f32) {
        self.steps.push(step);
        self.losses.push(loss);
        self.accs.push(acc);
    }

    pub fn log_eval(&mut self, step: u64, loss: f32, acc: f32) {
        self.evals.push((step, loss, acc));
    }

    /// Final validation accuracy (%, the paper's headline number).
    pub fn final_val_acc(&self) -> f64 {
        self.evals.last().map(|e| e.2 as f64 * 100.0).unwrap_or(f64::NAN)
    }

    /// Best validation accuracy over the run (%).
    pub fn best_val_acc(&self) -> f64 {
        self.evals
            .iter()
            .map(|e| e.2 as f64 * 100.0)
            .fold(f64::NAN, f64::max)
    }

    /// Mean training loss over the last `k` logged steps.
    pub fn tail_loss(&self, k: usize) -> f64 {
        let n = self.losses.len();
        if n == 0 {
            return f64::NAN;
        }
        let s = n.saturating_sub(k);
        stats::mean(&self.losses[s..].iter().map(|&x| x as f64).collect::<Vec<_>>())
    }

    /// True if the loss curve actually went down (e2e sanity check).
    pub fn loss_decreased(&self) -> bool {
        if self.losses.len() < 10 {
            return false;
        }
        let head = stats::mean(
            &self.losses[..5].iter().map(|&x| x as f64).collect::<Vec<_>>(),
        );
        self.tail_loss(5) < head
    }

    /// Write the loss curve as CSV.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,acc")?;
        for i in 0..self.steps.len() {
            writeln!(f, "{},{},{}", self.steps[i], self.losses[i], self.accs[i])?;
        }
        Ok(())
    }

    /// JSON form persisted by the run store.  Round-trips bit-exactly
    /// through [`RunRecord::from_json`] for finite values (f32 scalars
    /// widen to f64, and the serializer prints the shortest decimal that
    /// re-parses to the same f64); a record holding NaN/Inf — a diverged
    /// run — does not re-parse, so such cells simply never cache-hit.
    pub fn to_json(&self) -> Value {
        let nums =
            |v: &[f32]| Value::Array(v.iter().map(|&x| Value::Num(x as f64)).collect());
        Value::object(vec![
            ("name", Value::from(self.name.clone())),
            (
                "steps",
                Value::Array(self.steps.iter().map(|&s| Value::Num(s as f64)).collect()),
            ),
            ("losses", nums(&self.losses)),
            ("accs", nums(&self.accs)),
            (
                "evals",
                Value::Array(
                    self.evals
                        .iter()
                        .map(|&(s, l, a)| {
                            Value::Array(vec![
                                Value::Num(s as f64),
                                Value::Num(l as f64),
                                Value::Num(a as f64),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("train_seconds", Value::Num(self.train_seconds)),
            ("extra", Value::from_map(&self.extra)),
        ])
    }

    /// Parse the [`RunRecord::to_json`] form back.
    pub fn from_json(v: &Value) -> Result<Self> {
        Self::from_view(v)
    }

    /// Decode straight from the zero-copy view — no owned `Value` tree
    /// is built on the store's parse-once read path.
    pub fn from_raw(v: RawRef<'_>) -> Result<Self> {
        Self::from_view(v)
    }

    /// Decode the [`RunRecord::to_json`] form from either
    /// representation (`&Value` or `RawRef`) via [`JsonView`].
    pub fn from_view<'a, V: JsonView<'a>>(v: V) -> Result<Self> {
        let req = |key: &str| -> Result<V> {
            v.get(key).with_context(|| format!("missing key '{key}'"))
        };
        let f32s = |key: &str| -> Result<Vec<f32>> {
            req(key)?
                .items()
                .with_context(|| format!("record '{key}' is not an array"))?
                .into_iter()
                .map(|x| x.as_f64().map(|f| f as f32))
                .collect::<Option<Vec<f32>>>()
                .with_context(|| format!("record '{key}' holds a non-number"))
        };
        let evals = req("evals")?
            .items()
            .context("record 'evals' is not an array")?
            .into_iter()
            .map(|e| {
                let t = e.items()?;
                if t.len() != 3 {
                    return None;
                }
                Some((
                    t[0].as_f64()? as u64,
                    t[1].as_f64()? as f32,
                    t[2].as_f64()? as f32,
                ))
            })
            .collect::<Option<Vec<_>>>()
            .context("record 'evals' holds a malformed triple")?;
        let extra = req("extra")?
            .entries()
            .context("record 'extra' is not an object")?
            .into_iter()
            .map(|(k, x)| x.as_f64().map(|f| (k.to_string(), f)))
            .collect::<Option<BTreeMap<String, f64>>>()
            .context("record 'extra' holds a non-number")?;
        Ok(Self {
            name: req("name")?
                .as_str()
                .context("record 'name' is not a string")?
                .to_string(),
            steps: req("steps")?
                .items()
                .context("record 'steps' is not an array")?
                .into_iter()
                .map(|x| x.as_f64().map(|f| f as u64))
                .collect::<Option<Vec<u64>>>()
                .context("record 'steps' holds a non-number")?,
            losses: f32s("losses")?,
            accs: f32s("accs")?,
            evals,
            train_seconds: req("train_seconds")?
                .as_f64()
                .context("record 'train_seconds' is not a number")?,
            extra,
        })
    }
}

/// Aggregate of several seeds of the same configuration.
#[derive(Debug, Clone)]
pub struct SeedAggregate {
    pub name: String,
    pub accs: Vec<f64>,
    /// grid-cell provenance: the run tag of each contributing record,
    /// in aggregation order — a table cell can always be traced back to
    /// the exact cells (and store entries) it was computed from
    pub cells: Vec<String>,
}

impl SeedAggregate {
    pub fn from_runs(name: &str, runs: &[RunRecord]) -> Self {
        Self {
            name: name.to_string(),
            accs: runs.iter().map(|r| r.final_val_acc()).collect(),
            cells: runs.iter().map(|r| r.name.clone()).collect(),
        }
    }

    pub fn mean(&self) -> f64 {
        stats::mean(&self.accs)
    }

    pub fn std(&self) -> f64 {
        stats::std(&self.accs)
    }

    /// "59.46 ± 0.71"-style cell.
    pub fn cell(&self) -> String {
        format!("{:.2} ± {:.2}", self.mean(), self.std())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_with(evals: &[(u64, f32, f32)], losses: &[f32]) -> RunRecord {
        let mut r = RunRecord::new("t");
        for (i, &l) in losses.iter().enumerate() {
            r.log_step(i as u64, l, 0.5);
        }
        for &(s, l, a) in evals {
            r.log_eval(s, l, a);
        }
        r
    }

    #[test]
    fn final_and_best_acc() {
        let r = run_with(&[(10, 1.0, 0.50), (20, 0.9, 0.62), (30, 1.1, 0.58)], &[]);
        assert!((r.final_val_acc() - 58.0).abs() < 1e-4);
        assert!((r.best_val_acc() - 62.0).abs() < 1e-4);
    }

    #[test]
    fn loss_decrease_detection() {
        let down: Vec<f32> = (0..50).map(|i| 3.0 - 0.05 * i as f32).collect();
        let flat: Vec<f32> = (0..50).map(|_| 3.0).collect();
        assert!(run_with(&[], &down).loss_decreased());
        assert!(!run_with(&[], &flat).loss_decreased());
    }

    #[test]
    fn aggregate_cells() {
        let runs: Vec<RunRecord> = [0.59f32, 0.60, 0.58]
            .iter()
            .map(|&a| run_with(&[(1, 1.0, a)], &[]))
            .collect();
        let agg = SeedAggregate::from_runs("hindsight", &runs);
        assert!((agg.mean() - 59.0).abs() < 1e-3);
        assert!(agg.cell().contains("±"));
        // grid-cell provenance: one entry per contributing record
        assert_eq!(agg.cells, vec!["t", "t", "t"]);
    }

    #[test]
    fn json_round_trip_is_exact() {
        let mut r = run_with(
            &[(10, 1.25, 0.5), (20, 0.1, 0.62)],
            &[2.5, 1.0 / 3.0, 0.1], // 1/3 and 0.1 are not exact binary
        );
        r.train_seconds = 12.3456789;
        r.extra.insert("search_evals".into(), 42.0);
        r.extra.insert("coverage".into(), 0.875);
        let doc = r.to_json().to_string();
        let back = RunRecord::from_json(&crate::util::json::parse(&doc).unwrap()).unwrap();
        assert_eq!(back, r, "round trip must be bit-exact");
        // malformed documents error instead of panicking
        let bad = crate::util::json::parse(r#"{"name":"x"}"#).unwrap();
        assert!(RunRecord::from_json(&bad).is_err());
        let bad = crate::util::json::parse(r#"{"name":1}"#).unwrap();
        assert!(RunRecord::from_json(&bad).is_err());
    }

    #[test]
    fn csv_roundtrip() {
        let r = run_with(&[], &[1.0, 0.5]);
        let p = std::env::temp_dir().join("hindsight_metrics_test.csv");
        r.write_csv(p.to_str().unwrap()).unwrap();
        let txt = std::fs::read_to_string(&p).unwrap();
        assert!(txt.starts_with("step,loss,acc"));
        assert_eq!(txt.lines().count(), 3);
        let _ = std::fs::remove_file(p);
    }
}

//! Layer-graph abstraction over workload geometry.
//!
//! The paper pitches in-hindsight estimation as a drop-in range
//! estimator for *any* quantized-training workload, but the original
//! traffic stack was hardwired to [`Conv2dGeom`].  [`LayerGeom`] is the
//! interface the rest of the stack actually consumes — MAC counts,
//! per-tensor-class traffic volumes, quantizer-site plans, and trailing
//! channel/head counts for `@pc` granularity — with three variants:
//!
//! * [`LayerGeom::Conv2d`] — the original conv geometry, unchanged.
//!   Every cost formula consumes only `weight_bits` / `input_bits` /
//!   `output_elems`, so the conv path is bit-for-bit identical to the
//!   pre-refactor accounting (pinned by the golden parity tests below).
//! * [`LayerGeom::Linear`] — a token-batched fully connected layer
//!   (transformer MLP halves, classifier heads, patch embeddings when
//!   expressed as matmul).
//! * [`LayerGeom::Attention`] — one multi-head self-attention block:
//!   the QKV projections, the softmax-scaled score matmul `Q K^T`, the
//!   value matmul `P V`, and the output projection, accounted as four
//!   GEMM stages.  `n_heads` is the channel-group axis: per-head range
//!   rows are exactly the per-channel machinery with heads as the
//!   trailing axis.
//!
//! [`workload_spec`] turns a layer list into a synthetic [`ModelSpec`]
//! whose quantizer sites carry head-last feature shapes, so
//! `RangeManager` discovers per-head row groups with zero new code.

use crate::runtime::manifest::{ModelSpec, SiteKind, SiteSpec};

pub use super::traffic::Conv2dGeom;

/// Token-batched fully connected layer: `tokens x d_in  @  d_in x d_out`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearGeom {
    pub name: &'static str,
    pub d_in: u64,
    pub d_out: u64,
    /// rows of the input matrix (sequence length x batch; 1 for a head)
    pub tokens: u64,
}

/// One multi-head self-attention block (pre-norm ViT convention).
///
/// Four GEMM stages per block:
///
/// ```text
///   QKV:    tokens x d_model  @  d_model x 3*inner      (inner = heads * head_dim)
///   scores: per head, tokens x head_dim @ head_dim x tokens   (softmax fused)
///   ctx:    per head, tokens x tokens   @ tokens x head_dim
///   out:    tokens x inner    @  inner x d_model
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttentionGeom {
    pub name: &'static str,
    pub tokens: u64,
    pub d_model: u64,
    pub n_heads: u64,
    pub head_dim: u64,
}

impl AttentionGeom {
    /// The projected inner width, `n_heads * head_dim` (== `d_model` in
    /// the standard ViT configs, but not required to be).
    pub const fn inner(&self) -> u64 {
        self.n_heads * self.head_dim
    }
}

/// Geometry of one layer of a workload graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerGeom {
    Conv2d(Conv2dGeom),
    Linear(LinearGeom),
    Attention(AttentionGeom),
}

impl LayerGeom {
    /// Conv constructor (same argument order as [`Conv2dGeom::new`]).
    pub const fn conv(
        name: &'static str,
        cin: u64,
        cout: u64,
        k: u64,
        w: u64,
        h: u64,
        depthwise: bool,
    ) -> Self {
        Self::Conv2d(Conv2dGeom::new(name, cin, cout, k, w, h, depthwise))
    }

    pub const fn linear(name: &'static str, d_in: u64, d_out: u64, tokens: u64) -> Self {
        Self::Linear(LinearGeom {
            name,
            d_in,
            d_out,
            tokens,
        })
    }

    pub const fn attention(
        name: &'static str,
        tokens: u64,
        d_model: u64,
        n_heads: u64,
        head_dim: u64,
    ) -> Self {
        Self::Attention(AttentionGeom {
            name,
            tokens,
            d_model,
            n_heads,
            head_dim,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Conv2d(g) => g.name,
            Self::Linear(g) => g.name,
            Self::Attention(g) => g.name,
        }
    }

    /// Short layer-kind tag for reports and bench records.
    pub fn kind_str(&self) -> &'static str {
        match self {
            Self::Conv2d(g) if g.depthwise => "dw-conv",
            Self::Conv2d(_) => "conv",
            Self::Linear(_) => "linear",
            Self::Attention(_) => "attn",
        }
    }

    /// The conv geometry, when this layer is one.
    pub fn as_conv(&self) -> Option<&Conv2dGeom> {
        match self {
            Self::Conv2d(g) => Some(g),
            _ => None,
        }
    }

    /// Weight tensor footprint in *bits* at width `b_w`.  Attention
    /// counts the QKV and output projection matrices (the score/value
    /// matmuls are activation-activation, no weights).
    pub fn weight_bits(&self, b_w: u64) -> u64 {
        match self {
            Self::Conv2d(g) => g.weight_bits(b_w),
            Self::Linear(g) => g.d_in * g.d_out * b_w,
            Self::Attention(g) => {
                (g.d_model * 3 * g.inner() + g.inner() * g.d_model) * b_w
            }
        }
    }

    /// Elements streamed *into* the layer's GEMM stages at activation
    /// width.  For attention that is the block input plus the Q/K/V/P
    /// operands the score and value matmuls re-read (Q, K for scores;
    /// P, V for context; ctx for the output projection):
    /// `t*d + 4*t*inner + heads*t^2`.
    pub fn input_elems(&self) -> u64 {
        match self {
            Self::Conv2d(g) => g.cin * g.w * g.h,
            Self::Linear(g) => g.tokens * g.d_in,
            Self::Attention(g) => {
                let (t, h) = (g.tokens, g.n_heads);
                t * g.d_model + 4 * t * g.inner() + h * t * t
            }
        }
    }

    pub fn input_bits(&self, b_a: u64) -> u64 {
        self.input_elems() * b_a
    }

    /// Elements each GEMM stage writes through the output quantizer.
    /// For attention: QKV out (`3*t*inner`), softmaxed scores
    /// (`heads*t^2`, the softmax is fused into the score store), context
    /// (`t*inner`), and the output projection (`t*d`).
    pub fn output_elems(&self) -> u64 {
        match self {
            Self::Conv2d(g) => g.output_elems(),
            Self::Linear(g) => g.tokens * g.d_out,
            Self::Attention(g) => {
                let (t, h) = (g.tokens, g.n_heads);
                3 * t * g.inner() + h * t * t + t * g.inner() + t * g.d_model
            }
        }
    }

    /// MAC count of the layer (roofline-style reporting).
    pub fn macs(&self) -> u64 {
        match self {
            Self::Conv2d(g) => g.macs(),
            Self::Linear(g) => g.tokens * g.d_in * g.d_out,
            Self::Attention(g) => {
                let t = g.tokens;
                // QKV (3) + out projection (1) = 4 weight GEMMs, plus the
                // score and context matmuls (t^2 * head_dim each, per head)
                4 * t * g.d_model * g.inner() + 2 * t * t * g.inner()
            }
        }
    }

    /// Channel-group count for `@pc` granularity: output channels for
    /// convs, output features for linears, **heads** for attention.
    pub fn channels(&self) -> u64 {
        match self {
            Self::Conv2d(g) => g.cout,
            Self::Linear(g) => g.d_out,
            Self::Attention(g) => g.n_heads,
        }
    }

    /// Input-side width (report column).
    pub fn fan_in(&self) -> u64 {
        match self {
            Self::Conv2d(g) => g.cin,
            Self::Linear(g) => g.d_in,
            Self::Attention(g) => g.d_model,
        }
    }

    /// Output-side width (report column).
    pub fn fan_out(&self) -> u64 {
        match self {
            Self::Conv2d(g) => g.cout,
            Self::Linear(g) => g.d_out,
            Self::Attention(g) => g.d_model,
        }
    }

    /// Spatial/sequence extent for reports: `WxH` for convs, token and
    /// head counts otherwise.
    pub fn spatial(&self) -> String {
        match self {
            Self::Conv2d(g) => format!("{}x{}", g.w, g.h),
            Self::Linear(g) => format!("t={}", g.tokens),
            Self::Attention(g) => format!("t={}/h={}", g.tokens, g.n_heads),
        }
    }

    /// Quantizer-site plan: `(suffix, kind, feature_shape)` per site,
    /// channels-last (the trailing axis is the `@pc` group axis — heads
    /// for the attention score/context sites).  Site suffixes contain no
    /// whitespace so `@<site>:<spec>` overrides can always address them.
    pub fn sites(&self) -> Vec<(&'static str, SiteKind, Vec<usize>)> {
        match self {
            Self::Conv2d(g) => vec![
                (
                    "out",
                    SiteKind::Act,
                    vec![g.h as usize, g.w as usize, g.cout as usize],
                ),
                (
                    "gx",
                    SiteKind::Grad,
                    vec![g.h as usize, g.w as usize, g.cin as usize],
                ),
            ],
            Self::Linear(g) => vec![
                (
                    "out",
                    SiteKind::Act,
                    vec![g.tokens as usize, g.d_out as usize],
                ),
                (
                    "gx",
                    SiteKind::Grad,
                    vec![g.tokens as usize, g.d_in as usize],
                ),
            ],
            Self::Attention(g) => {
                let (t, h, hd) = (g.tokens as usize, g.n_heads as usize, g.head_dim as usize);
                vec![
                    // softmaxed attention probabilities, head-last
                    ("probs", SiteKind::Act, vec![t, t, h]),
                    // per-head context output of the value matmul
                    ("ctx", SiteKind::Act, vec![t, hd, h]),
                    // score gradients — the per-head gradient quantizer
                    ("scores.gx", SiteKind::Grad, vec![t, t, h]),
                    // block-input gradient, per-feature
                    ("gx", SiteKind::Grad, vec![t, g.d_model as usize]),
                ]
            }
        }
    }
}

/// Build a synthetic [`ModelSpec`] whose quantizer sites are the layer
/// graph's site plans — enough manifest for `RangeManager` (and the
/// trainer's scheme-site validation) to run on an analytic workload with
/// no compiled artifacts.  Site names are `L<idx>.<suffix>` (`L03.gx`),
/// whitespace-free so the scheme grammar's `@<site>:<spec>` overrides
/// address them.
pub fn workload_spec(name: &str, layers: &[LayerGeom]) -> ModelSpec {
    let mut sites = Vec::new();
    let mut index = 0usize;
    for (li, layer) in layers.iter().enumerate() {
        for (suffix, kind, feature_shape) in layer.sites() {
            sites.push(SiteSpec {
                index,
                name: format!("L{li:02}.{suffix}"),
                kind,
                feature_shape,
            });
            index += 1;
        }
    }
    ModelSpec {
        name: name.to_string(),
        batch_size: 1,
        input_shape: vec![],
        n_classes: 0,
        n_params: layers.iter().map(|l| l.weight_bits(1) as usize).sum(),
        pallas: "analytic".to_string(),
        params: vec![],
        state: vec![],
        sites,
        graphs: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::traffic::{self, BitWidths};

    /// Golden conv parity: the generalized accessors reproduce the
    /// legacy `Conv2dGeom` formulas verbatim — same u64 expressions, so
    /// bit-identical, not merely close.
    #[test]
    fn conv_parity_is_bit_exact() {
        let mut convs: Vec<Conv2dGeom> = traffic::table5_layers()
            .iter()
            .filter_map(|l| l.as_conv().copied())
            .collect();
        for net in ["resnet18", "vgg16", "mobilenet_v2"] {
            convs.extend(
                crate::models::by_name(net)
                    .unwrap()
                    .iter()
                    .filter_map(|l| l.as_conv().copied()),
            );
        }
        assert!(convs.len() > 80);
        for g in convs {
            let l = LayerGeom::Conv2d(g);
            for bits in [1u64, 4, 8, 16, 32] {
                // legacy formulas, inlined verbatim
                let legacy_w = if g.depthwise {
                    g.cin * g.k * g.k * bits
                } else {
                    g.cin * g.cout * g.k * g.k * bits
                };
                assert_eq!(l.weight_bits(bits), legacy_w);
                assert_eq!(l.input_bits(bits), g.cin * g.w * g.h * bits);
            }
            assert_eq!(l.input_elems(), g.cin * g.w * g.h);
            assert_eq!(l.output_elems(), g.cout * g.w * g.h);
            assert_eq!(l.macs(), g.macs());
            assert_eq!(l.channels(), g.cout);
            assert_eq!(l.name(), g.name);
            // full forward cost identity at the Table 5 bit-widths
            let c = traffic::compare(&l, BitWidths::default());
            let b = BitWidths::default();
            assert_eq!(
                c.static_bits,
                legacy_static(&g, b),
                "{}: static cost drifted",
                g.name
            );
            assert_eq!(c.dynamic_bits, legacy_dynamic(&g, b));
        }
    }

    fn legacy_static(g: &Conv2dGeom, b: BitWidths) -> u64 {
        g.weight_bits(b.b_w) + g.input_bits(b.b_a) + g.output_elems() * b.b_a
    }

    fn legacy_dynamic(g: &Conv2dGeom, b: BitWidths) -> u64 {
        g.weight_bits(b.b_w)
            + g.input_bits(b.b_a)
            + g.output_elems() * b.b_acc * 2
            + g.output_elems() * b.b_a
    }

    #[test]
    fn attention_accounting_identities() {
        // ViT-S/16 block: t=197, d=384, 6 heads x 64
        let a = LayerGeom::attention("attn", 197, 384, 6, 64);
        let (t, d, h, inner) = (197u64, 384u64, 6u64, 384u64);
        assert_eq!(a.macs(), 4 * t * d * inner + 2 * t * t * inner);
        assert_eq!(a.input_elems(), t * d + 4 * t * inner + h * t * t);
        assert_eq!(a.output_elems(), 3 * t * inner + h * t * t + t * inner + t * d);
        assert_eq!(a.weight_bits(8), (d * 3 * inner + inner * d) * 8);
        // heads are the channel-group axis
        assert_eq!(a.channels(), 6);
        assert_eq!(a.kind_str(), "attn");
        assert_eq!(a.spatial(), "t=197/h=6");
        // the score matmuls dominate neither MACs nor traffic at t=197
        assert!(4 * t * d * inner > 2 * t * t * inner);
    }

    #[test]
    fn linear_accounting() {
        let l = LayerGeom::linear("fc", 384, 1536, 197);
        assert_eq!(l.macs(), 197 * 384 * 1536);
        assert_eq!(l.weight_bits(4), 384 * 1536 * 4);
        assert_eq!(l.input_elems(), 197 * 384);
        assert_eq!(l.output_elems(), 197 * 1536);
        assert_eq!(l.channels(), 1536);
        assert_eq!(l.kind_str(), "linear");
    }

    #[test]
    fn workload_spec_sites_and_head_groups() {
        let layers = [
            LayerGeom::conv("stem", 3, 64, 7, 112, 112, false),
            LayerGeom::attention("attn", 16, 32, 4, 8),
            LayerGeom::linear("head", 32, 10, 1),
        ];
        let spec = workload_spec("toy", &layers);
        assert_eq!(spec.name, "toy");
        // 2 conv sites + 4 attention sites + 2 linear sites
        assert_eq!(spec.sites.len(), 8);
        let names: Vec<&str> = spec.sites.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            [
                "L00.out",
                "L00.gx",
                "L01.probs",
                "L01.ctx",
                "L01.scores.gx",
                "L01.gx",
                "L02.out",
                "L02.gx"
            ]
        );
        // indices dense, names whitespace-free (override-addressable)
        for (i, s) in spec.sites.iter().enumerate() {
            assert_eq!(s.index, i);
            assert!(!s.name.contains(' '));
        }
        // the attention score/probs sites group by *head* under @pc
        let probs = &spec.sites[2];
        assert_eq!(probs.kind, SiteKind::Act);
        assert_eq!(probs.channels(), 4);
        let sgx = &spec.sites[4];
        assert_eq!(sgx.kind, SiteKind::Grad);
        assert_eq!(sgx.channels(), 4);
        // the block-input gradient groups per feature
        assert_eq!(spec.sites[5].channels(), 32);
        // conv sites keep the channels-last conv convention
        assert_eq!(spec.sites[0].channels(), 64);
        assert_eq!(spec.sites[1].channels(), 3);
    }
}

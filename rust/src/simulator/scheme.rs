//! Scheme-driven accelerator simulation: the bridge between the typed
//! [`QuantScheme`] API and the fixed-point machine/traffic models.
//!
//! The compiled engine artifacts are fixed-bit (W8/A8/G8), so
//! mixed-precision schemes — `w:current:8 a:hindsight:8 g:hindsight@pc:4`
//! — execute end-to-end *here*: per-class bit-widths resolve from the
//! scheme into the forward ([`BitWidths::from_scheme`]) and backward
//! ([`BwdBits::from_scheme`]) datapaths, the activation spec picks the
//! accumulator [`Policy`] (static single-store vs dynamic round trip,
//! per-tensor vs per-channel), and the gradient spec drives the fused
//! `G_X` store.  [`QuantScheme::w8a8g8`] reproduces the legacy default
//! simulator configuration bit-for-bit (pinned below).

use crate::quant::QuantParams;
use crate::scheme::QuantScheme;
use crate::simulator::backward::{bwd_compare, store_gx_static, store_gx_static_axis, BwdBits};
use crate::simulator::layer::LayerGeom;
use crate::simulator::machine::{MacArray, Policy, RunResult};
use crate::simulator::traffic::{compare, BitWidths, TrafficCost};

/// Traffic accounting of one layer under one scheme: forward eq. (4)/(5)
/// at the scheme's W/A bits, backward analogue at its G bits.
#[derive(Debug, Clone)]
pub struct LayerTraffic {
    pub fwd: TrafficCost,
    pub bwd: TrafficCost,
    /// the bit-widths the scheme resolved to (reported so callers can
    /// verify per-class bits end-to-end)
    pub fwd_bits: BitWidths,
    pub bwd_bits: BwdBits,
}

impl LayerTraffic {
    /// Whole-training-step ratio (dynamic / static), the Sec. 6 number.
    pub fn step_ratio(&self) -> f64 {
        (self.fwd.dynamic_bits + self.bwd.dynamic_bits) as f64
            / (self.fwd.static_bits + self.bwd.static_bits) as f64
    }
}

/// Closed-form eq. (4)/(5) traffic of `geom` under `scheme` — any
/// [`LayerGeom`] variant; attention blocks pay the asymmetry on every
/// GEMM-stage store.
pub fn layer_traffic(scheme: &QuantScheme, geom: &LayerGeom) -> LayerTraffic {
    let fwd_bits = BitWidths::from_scheme(scheme);
    let bwd_bits = BwdBits::from_scheme(scheme);
    LayerTraffic {
        fwd: compare(geom, fwd_bits),
        bwd: bwd_compare(geom, bwd_bits),
        fwd_bits,
        bwd_bits,
    }
}

/// Execute one forward GEMM on the MAC-array machine under `scheme`:
/// datapath widths from the weight/activation specs, output requantized
/// at the activation bits under the activation spec's policy
/// (`act_rows` are the coordinator-held range rows of the output site —
/// one row per channel group for `@pc` specs).  The activation spec
/// must quantize (`enabled`); an fp32 class has no machine-level store
/// policy.
#[allow(clippy::too_many_arguments)]
pub fn forward_gemm(
    scheme: &QuantScheme,
    a: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    qp_a: QuantParams,
    qp_w: QuantParams,
    act_rows: &[[f32; 2]],
) -> RunResult {
    assert!(
        scheme.activations.enabled(),
        "forward_gemm needs a quantizing activation spec (got '{}')",
        scheme.activations.estimator.key()
    );
    let mac = MacArray::from_scheme(scheme);
    let policy = Policy::for_spec(&scheme.activations, act_rows);
    mac.gemm(a, w, m, k, n, qp_a, qp_w, scheme.activations.bits, policy)
}

/// Quantize-and-store one backward `G_X` tensor under `scheme`: the
/// gradient spec picks the bit-width and granularity of the fused store
/// (`rows` as in [`forward_gemm`]).  Returns the per-row Fig. 3
/// statistics and the bits moved — `8 *` the integer payload buffer the
/// store emitted (`gx.len() * g_bits` for byte-aligned widths), which
/// is how a mixed-precision `g:4` scheme is verified end-to-end.
pub fn store_gradient(
    scheme: &QuantScheme,
    gx: &mut [f32],
    rows: &[[f32; 2]],
) -> (Vec<(f32, f32)>, u64) {
    let b = BwdBits::from_scheme(scheme);
    if scheme.gradients.is_per_channel() {
        store_gx_static_axis(gx, rows, b)
    } else {
        let (stats, bits) = store_gx_static(gx, rows[0][0], rows[0][1], b);
        (vec![stats], bits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::minmax;
    use crate::simulator::traffic::table5_layers;
    use crate::util::rng::Pcg32;

    fn inputs(m: usize, k: usize, n: usize) -> (Vec<f32>, Vec<f32>, QuantParams, QuantParams) {
        let mut rng = Pcg32::new(41, 1);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
        let (alo, ahi) = minmax(&a);
        let (wlo, whi) = minmax(&w);
        (
            a,
            w,
            QuantParams::from_range(alo, ahi, 8),
            QuantParams::from_range(wlo, whi, 8),
        )
    }

    /// Satellite acceptance: `QuantScheme::w8a8g8()` reproduces the
    /// legacy default simulator path bit-for-bit.
    #[test]
    fn w8a8g8_matches_the_legacy_defaults_bit_for_bit() {
        let scheme = QuantScheme::w8a8g8();
        assert_eq!(BitWidths::from_scheme(&scheme), BitWidths::default());
        assert_eq!(BwdBits::from_scheme(&scheme), BwdBits::default());
        let (m, k, n) = (16, 32, 16);
        let (a, w, qpa, qpw) = inputs(m, k, n);
        // legacy path: default machine, per-tensor static policy
        let legacy = MacArray::default().gemm(
            &a,
            &w,
            m,
            k,
            n,
            qpa,
            qpw,
            8,
            Policy::Static { qmin: -25.0, qmax: 25.0 },
        );
        let ours = forward_gemm(&scheme, &a, &w, m, k, n, qpa, qpw, &[[-25.0, 25.0]]);
        assert_eq!(ours.output, legacy.output); // bit-for-bit
        assert_eq!(ours.phases, legacy.phases);
        assert_eq!(ours.acc_stats, legacy.acc_stats);
        assert_eq!(ours.cycles, legacy.cycles);
        // and the closed-form traffic equals the default-bits closed form
        for g in table5_layers() {
            let t = layer_traffic(&scheme, &g);
            let legacy = compare(&g, BitWidths::default());
            assert_eq!(t.fwd.static_bits, legacy.static_bits);
            assert_eq!(t.fwd.dynamic_bits, legacy.dynamic_bits);
            let legacy_bwd = bwd_compare(&g, BwdBits::default());
            assert_eq!(t.bwd.static_bits, legacy_bwd.static_bits);
            assert_eq!(t.bwd.dynamic_bits, legacy_bwd.dynamic_bits);
        }
    }

    /// Tentpole acceptance: the mixed-precision scheme of the issue
    /// executes end-to-end on the simulator with per-class bits visible
    /// in the traffic/stats output.
    #[test]
    fn mixed_precision_scheme_runs_end_to_end() {
        let scheme = QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight@pc:4").unwrap();
        let g = table5_layers()[0];
        let t = layer_traffic(&scheme, &g);
        // per-class bits surface in the resolved widths ...
        assert_eq!(t.fwd_bits, BitWidths { b_w: 8, b_a: 8, b_acc: 32 });
        assert_eq!(t.bwd_bits.b_g, 4);
        // ... and in the backward accounting: the G_X store term is
        // 4-bit, so static backward traffic drops vs the 8-bit scheme
        let t8 = layer_traffic(&QuantScheme::w8a8g8(), &g);
        let gx_elems = g.input_elems();
        assert_eq!(
            t8.bwd.static_bits - t.bwd.static_bits,
            gx_elems * 4 + g.output_elems() * 4, // G_X store + G_Y load at 4 bits less
        );

        // forward executes on the machine (a:hindsight:8 => static store)
        let (m, k, n) = (8, 16, 4);
        let (a, w, qpa, qpw) = inputs(m, k, n);
        let run = forward_gemm(&scheme, &a, &w, m, k, n, qpa, qpw, &[[-30.0, 30.0]]);
        assert_eq!(run.phases.acc_store, 0); // static single store
        assert_eq!(run.phases.output_store, (m * n) as u64); // 8 bits/elem

        // gradient store: per-channel (2 groups), 4-bit traffic
        let c = 2usize;
        let mut rng = Pcg32::new(7, 1);
        let gx: Vec<f32> = (0..c * 256)
            .map(|i| rng.normal() * 0.01 * ((i % c) + 1) as f32)
            .collect();
        let rows: Vec<[f32; 2]> = (0..c).map(|i| {
            let w = 0.05 * (i + 1) as f32;
            [-w, w]
        }).collect();
        let mut stored = gx.clone();
        let (stats, bits_moved) = store_gradient(&scheme, &mut stored, &rows);
        assert_eq!(bits_moved, gx.len() as u64 * 4, "G_X moves at 4 bits/elem");
        assert_eq!(stats.len(), c, "one statistics register pair per channel");
        // per-channel stats match each channel's strided hull
        for (ch, s) in stats.iter().enumerate() {
            let chan: Vec<f32> = gx.iter().skip(ch).step_by(c).copied().collect();
            assert_eq!(*s, minmax(&chan));
        }
        // the stored tensor sits on each channel's 4-bit grid
        for (i, (&orig, &q)) in gx.iter().zip(&stored).enumerate() {
            let qp = QuantParams::from_range(rows[i % c][0], rows[i % c][1], 4);
            assert_eq!(q, qp.fq(orig));
        }
    }

    #[test]
    fn attention_layer_traffic_resolves_per_class_bits() {
        // the ViT-S/16 attention block through the same closed form the
        // conv rows use: 4-bit gradients shrink only the static G_X/G_Y
        // terms, so the step ratio widens exactly like a conv layer's
        let scheme = QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight@pc:4").unwrap();
        let g = LayerGeom::attention("attn", 197, 384, 6, 64);
        let t = layer_traffic(&scheme, &g);
        assert_eq!(t.bwd_bits.b_g, 4);
        let t8 = layer_traffic(&QuantScheme::w8a8g8(), &g);
        assert_eq!(
            t8.bwd.static_bits - t.bwd.static_bits,
            g.input_elems() * 4 + g.output_elems() * 4,
        );
        assert!(t.step_ratio() > t8.step_ratio());
    }

    #[test]
    fn fp32_classes_bill_full_precision_traffic() {
        // an unmentioned (fp32) class moves 32-bit data, not its inert
        // spec bits — a grad-only scheme must not look like W8/A8
        let s = QuantScheme::parse("g:hindsight:4").unwrap();
        assert_eq!(
            BitWidths::from_scheme(&s),
            BitWidths { b_w: 32, b_a: 32, b_acc: 32 }
        );
        let b = BwdBits::from_scheme(&s);
        assert_eq!((b.b_g, b.b_a, b.b_w), (4, 32, 32));
        // fp32 gradients round-trip at full precision too
        let f = BwdBits::from_scheme(&QuantScheme::fp32());
        assert_eq!(f.b_g, 32);
    }

    #[test]
    fn dynamic_act_specs_pick_the_two_pass_policy() {
        let scheme = QuantScheme::parse("w:current:8 a:current:8 g:hindsight:8").unwrap();
        let (m, k, n) = (8, 8, 8);
        let (a, w, qpa, qpw) = inputs(m, k, n);
        let run = forward_gemm(&scheme, &a, &w, m, k, n, qpa, qpw, &[[-30.0, 30.0]]);
        // dynamic: accumulator round trip through memory
        assert!(run.phases.acc_store > 0);
        assert_eq!(run.phases.acc_store, run.phases.acc_reload);
    }

    #[test]
    fn per_channel_act_specs_pick_the_axis_policy() {
        let scheme = QuantScheme::parse("w:current:8 a:hindsight@pc:8 g:hindsight:8").unwrap();
        let (m, k, n) = (8, 16, 4);
        let (a, w, qpa, qpw) = inputs(m, k, n);
        let rows: Vec<[f32; 2]> = (0..n).map(|_| [-30.0, 30.0]).collect();
        let run = forward_gemm(&scheme, &a, &w, m, k, n, qpa, qpw, &rows);
        assert_eq!(run.acc_stats_axis.len(), n); // one register pair per column
        assert_eq!(run.phases.acc_store, 0); // still a single-store path
    }

    #[test]
    fn lower_gradient_bits_widen_the_step_ratio() {
        // shrinking only the static-path G_X store makes dynamic's fixed
        // 32-bit round trip relatively more expensive
        let g = table5_layers()[0];
        let r8 = layer_traffic(&QuantScheme::w8a8g8(), &g).step_ratio();
        let mixed = QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight:4").unwrap();
        let r4 = layer_traffic(&mixed, &g).step_ratio();
        assert!(r4 > r8, "g:4 ratio {r4} vs g:8 ratio {r8}");
    }

    #[test]
    #[should_panic(expected = "quantizing activation spec")]
    fn fp32_activations_have_no_machine_policy() {
        let scheme = QuantScheme::grad_only(crate::estimator::Estimator::HINDSIGHT);
        let (a, w, qpa, qpw) = inputs(4, 4, 4);
        let _ = forward_gemm(&scheme, &a, &w, 4, 4, 4, qpa, qpw, &[[-1.0, 1.0]]);
    }
}

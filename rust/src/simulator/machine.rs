//! Cycle-approximate MAC-array machine (paper Fig. 2, realized).
//!
//! Executes an int8 GEMM the way the paper's accelerator diagram does:
//! the output is produced in `PxP` slices by a fixed-size MAC array; each
//! slice accumulates into 32-bit registers; what happens *after* the
//! accumulator is where static and dynamic quantization part ways:
//!
//! * **static** — ranges are known up front: each completed accumulator
//!   slice is requantized immediately and written to memory as a real
//!   integer payload (one code byte per element at 5..=8 bits, packed
//!   two-per-byte at <= 4 — `quant::kernel::fq_store_i8`/`fq_store_i4`),
//!   so the store counter is the payload buffer's measured size;
//!   in-hindsight additionally folds the slice min/max into the online
//!   statistics registers (paper Fig. 3) at zero extra traffic — one
//!   fused pass either way;
//! * **dynamic** — every slice is written at `b_acc` bits; once the full
//!   tensor is out, min/max are computed, the tensor is read *back*,
//!   quantized, and written again at `b_a` bits — two passes by
//!   construction, which is the whole Sec. 6 argument.
//!
//! The machine is bit-exact: its integer path must agree with the
//! `quant` module's fake-quant (asserted in tests), which is in turn the
//! mirror of the L1 kernels — so the simulator validates the whole
//! numeric chain, not just byte counts.

use crate::quant::{fake_quant_slice, kernel, minmax, QuantParams};

/// DMA byte counters, one per dataflow phase (paper Fig. 4's arrows).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Phases {
    pub weight_load: u64,
    pub input_load: u64,
    pub acc_store: u64,
    pub acc_reload: u64,
    pub output_store: u64,
}

impl Phases {
    pub fn total(&self) -> u64 {
        self.weight_load + self.input_load + self.acc_store + self.acc_reload + self.output_store
    }
}

/// Result of one simulated layer execution.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// dequantized output values (for numeric cross-checks)
    pub output: Vec<f32>,
    /// min/max of the accumulator output *before* requantization —
    /// the Fig. 3 statistics the in-hindsight estimator consumes
    pub acc_stats: (f32, f32),
    /// per-channel-group accumulator stats (populated only under
    /// [`Policy::StaticPerChannel`]; the online statistics registers
    /// hold one (min, max) pair per channel group there)
    pub acc_stats_axis: Vec<(f32, f32)>,
    pub phases: Phases,
    /// MAC-array busy cycles (one cycle per PxP MAC wavefront)
    pub cycles: u64,
    /// fraction of issued MAC lanes doing useful work
    pub mac_utilization: f64,
}

/// Quantization-at-the-accumulator policy.
#[derive(Debug, Clone, PartialEq)]
pub enum Policy {
    /// pre-computed per-tensor range (in-hindsight / any static scheme)
    Static { qmin: f32, qmax: f32 },
    /// pre-computed per-channel-group ranges: row `c` quantizes output
    /// elements with flat index ≡ c (mod ranges.len()) — for a
    /// row-major (m, n) output with `ranges.len()` dividing `n`, that is
    /// column group `j % ranges.len()` (channels-last).  Same
    /// single-traversal store as [`Policy::Static`], just with one
    /// statistics register pair per channel group.
    StaticPerChannel { ranges: Vec<[f32; 2]> },
    /// current min-max: ranges depend on the full output (dynamic)
    Dynamic,
}

/// Fixed-size MAC array machine.
#[derive(Debug, Clone)]
pub struct MacArray {
    /// array dimension P (PxP processing elements)
    pub p: usize,
    pub b_w: u64,
    pub b_a: u64,
    pub b_acc: u64,
}

impl Default for MacArray {
    fn default() -> Self {
        Self {
            p: 16,
            b_w: 8,
            b_a: 8,
            b_acc: 32,
        }
    }
}

impl MacArray {
    /// Machine with the datapath widths of a quantization scheme
    /// (weight/activation bits from the class specs, 32 for disabled
    /// fp32 classes; default array size and 32-bit accumulator).
    pub fn from_scheme(scheme: &crate::scheme::QuantScheme) -> Self {
        Self {
            b_w: scheme.weights.datapath_bits(),
            b_a: scheme.activations.datapath_bits(),
            ..Default::default()
        }
    }
}

impl Policy {
    /// The accumulator policy a [`QuantSpec`](crate::scheme::QuantSpec)
    /// implies for the tensor it quantizes, given the coordinator-held
    /// range rows of the site: static estimators requantize at the
    /// accumulator with the pre-computed row(s) — one per channel group
    /// for `@pc` specs — while dynamic estimators pay the two-pass
    /// round trip.
    pub fn for_spec(spec: &crate::scheme::QuantSpec, rows: &[[f32; 2]]) -> Policy {
        assert!(!rows.is_empty(), "policy needs at least one range row");
        if !spec.estimator.is_static() {
            Policy::Dynamic
        } else if spec.is_per_channel() {
            Policy::StaticPerChannel { ranges: rows.to_vec() }
        } else {
            Policy::Static { qmin: rows[0][0], qmax: rows[0][1] }
        }
    }
}

impl MacArray {
    /// Run `Y[m,n] = A[m,k] @ W[k,n]` where A/W are *real-valued* tensors
    /// pre-quantized to (qp_a, qp_w) grids; the machine operates on their
    /// integer indices exactly like silicon would.
    ///
    /// Returns the dequantized, requantized-output values plus the
    /// traffic/cycle accounting under `policy`.
    pub fn gemm(
        &self,
        a: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        qp_a: QuantParams,
        qp_w: QuantParams,
        out_bits: u32,
        policy: Policy,
    ) -> RunResult {
        assert_eq!(a.len(), m * k);
        assert_eq!(w.len(), k * n);

        // Integer views (what actually sits in SRAM): index - zero_point.
        let ai: Vec<i32> = a.iter().map(|&x| qp_a.index_of(x) as i32 - qp_a.zero_point as i32).collect();
        let wi: Vec<i32> = w.iter().map(|&x| qp_w.index_of(x) as i32 - qp_w.zero_point as i32).collect();

        // Accumulate in i64 (b_acc-bit accumulator; 32 suffices for the
        // sizes here, i64 guards the simulation itself).
        let mut acc = vec![0i64; m * n];
        let mut cycles = 0u64;
        let tiles_m = m.div_ceil(self.p);
        let tiles_n = n.div_ceil(self.p);
        let tiles_k = k.div_ceil(self.p);
        for tm in 0..tiles_m {
            for tn in 0..tiles_n {
                for tk in 0..tiles_k {
                    // one wavefront through the PxP array per k-slice
                    cycles += self.p as u64;
                    for i in tm * self.p..((tm + 1) * self.p).min(m) {
                        for j in tn * self.p..((tn + 1) * self.p).min(n) {
                            let mut s = 0i64;
                            for kk in tk * self.p..((tk + 1) * self.p).min(k) {
                                s += ai[i * k + kk] as i64 * wi[kk * n + j] as i64;
                            }
                            acc[i * n + j] += s;
                        }
                    }
                }
            }
        }
        let issued = (tiles_m * tiles_n * tiles_k) as u64
            * (self.p as u64 * self.p as u64 * self.p as u64);
        let useful = (m * n * k) as u64;

        // Dequantize the accumulator: real = acc * scale_a * scale_w.
        let s = qp_a.scale * qp_w.scale;
        let mut real: Vec<f32> = acc.iter().map(|&v| v as f32 * s).collect();

        let mut phases = Phases {
            weight_load: k as u64 * n as u64 * self.b_w / 8,
            input_load: m as u64 * k as u64 * self.b_a / 8,
            ..Default::default()
        };

        let out_elems = (m * n) as u64;
        let mut acc_stats_axis = Vec::new();
        let acc_stats = match policy {
            Policy::Static { qmin, qmax } => {
                // requantize at the accumulator; only the integer payload
                // leaves.  One fused pass emits the out_bits-bit codes
                // (packed two-per-byte at <= 4 bits) *and* folds the
                // pre-quantization extrema into the Fig. 3 statistics
                // registers — the single-traversal contract the paper's
                // accelerator sketch relies on.  The store counter is the
                // payload buffer's real size; `real` continues as the
                // readback, bit-identical to the fake-quant grid.
                if out_bits <= 8 {
                    let mut payload =
                        vec![0u8; kernel::payload_bytes(real.len(), out_bits)];
                    let stats = if out_bits <= 4 {
                        let s = kernel::fq_store_i4(&real, &mut payload, qmin, qmax, out_bits);
                        kernel::dequant_i4(&payload, &mut real, qmin, qmax, out_bits);
                        s
                    } else {
                        let s = kernel::fq_store_i8(&real, &mut payload, qmin, qmax, out_bits);
                        kernel::dequant_i8(&payload, &mut real, qmin, qmax, out_bits);
                        s
                    };
                    phases.output_store = payload.len() as u64;
                    stats
                } else {
                    phases.output_store = out_elems * self.b_a / 8;
                    kernel::minmax_fq(&mut real, qmin, qmax, out_bits)
                }
            }
            Policy::StaticPerChannel { ranges } => {
                // identical traffic to Static — the payload buffer has the
                // same size; per-channel granularity only widens the
                // statistics register file, the store is still one fused
                // traversal (now channel-strided).
                if out_bits <= 8 {
                    let mut payload =
                        vec![0u8; kernel::payload_bytes(real.len(), out_bits)];
                    acc_stats_axis = if out_bits <= 4 {
                        let s = kernel::fq_store_i4_axis(&real, &mut payload, &ranges, out_bits);
                        kernel::dequant_i4_axis(&payload, &mut real, &ranges, out_bits);
                        s
                    } else {
                        let s = kernel::fq_store_i8_axis(&real, &mut payload, &ranges, out_bits);
                        kernel::dequant_i8_axis(&payload, &mut real, &ranges, out_bits);
                        s
                    };
                    phases.output_store = payload.len() as u64;
                } else {
                    phases.output_store = out_elems * self.b_a / 8;
                    acc_stats_axis = kernel::minmax_fq_axis(&mut real, &ranges, out_bits);
                }
                acc_stats_axis.iter().fold(
                    (f32::INFINITY, f32::NEG_INFINITY),
                    |(lo, hi), &(l, h)| (lo.min(l), hi.max(h)),
                )
            }
            Policy::Dynamic => {
                // full-precision round trip through memory first: the
                // ranges are unknown until the whole tensor exists, so the
                // stats pass and the quantize pass cannot fuse.
                phases.acc_store = out_elems * self.b_acc / 8;
                phases.acc_reload = out_elems * self.b_acc / 8;
                phases.output_store = out_elems * self.b_a / 8;
                let (lo, hi) = minmax(&real);
                fake_quant_slice(&mut real, lo, hi, out_bits);
                (lo, hi)
            }
        };

        RunResult {
            output: real,
            acc_stats,
            acc_stats_axis,
            phases,
            cycles,
            mac_utilization: useful as f64 / issued as f64,
        }
    }

    /// Per-phase traffic of one layer run as (a sequence of) GEMMs —
    /// convs via im2col, linears directly, attention as its four GEMM
    /// stages (geometry-level; used to bridge machine-level accounting
    /// to the closed-form eqs. 4/5 over any [`LayerGeom`](super::LayerGeom)
    /// variant).
    pub fn layer_phases(
        &self,
        g: &super::LayerGeom,
        policy_static: bool,
    ) -> Phases {
        let out_elems = g.output_elems();
        let mut ph = Phases {
            weight_load: g.weight_bits(self.b_w) / 8,
            input_load: g.input_bits(self.b_a) / 8,
            ..Default::default()
        };
        if policy_static {
            ph.output_store = out_elems * self.b_a / 8;
        } else {
            ph.acc_store = out_elems * self.b_acc / 8;
            ph.acc_reload = out_elems * self.b_acc / 8;
            ph.output_store = out_elems * self.b_a / 8;
        }
        ph
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{fake_quant, minmax, QuantParams};
    use crate::simulator::traffic::{self, BitWidths};
    use crate::util::rng::Pcg32;

    fn rand_tensor(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Pcg32::new(seed, 1);
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    fn machine_inputs(m: usize, k: usize, n: usize) -> (Vec<f32>, Vec<f32>, QuantParams, QuantParams) {
        let a = rand_tensor(m * k, 11, 1.0);
        let w = rand_tensor(k * n, 12, 0.5);
        let (alo, ahi) = minmax(&a);
        let (wlo, whi) = minmax(&w);
        (a, w, QuantParams::from_range(alo, ahi, 8), QuantParams::from_range(wlo, whi, 8))
    }

    /// The integer MAC path must equal fake-quant matmul exactly.
    #[test]
    fn integer_path_matches_fake_quant_reference() {
        let (m, k, n) = (9, 17, 5);
        let (a, w, qpa, qpw) = machine_inputs(m, k, n);
        let mac = MacArray::default();
        let run = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8, Policy::Dynamic);

        // reference: fake-quant a and w, real matmul, quantize output with
        // the same (dynamic) range
        let aq = fake_quant(&a, qpa.grid_edges().0, qpa.grid_edges().1, 8);
        let wq = fake_quant(&w, qpw.grid_edges().0, qpw.grid_edges().1, 8);
        let mut y = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = 0f64;
                for kk in 0..k {
                    s += aq[i * k + kk] as f64 * wq[kk * n + j] as f64;
                }
                y[i * n + j] = s as f32;
            }
        }
        let (lo, hi) = minmax(&run_output_real(&run, &y));
        let _ = (lo, hi);
        let (ylo, yhi) = minmax(&y);
        let yq = fake_quant(&y, ylo, yhi, 8);
        for (ours, theirs) in run.output.iter().zip(&yq) {
            assert!(
                (ours - theirs).abs() < 2e-4 * (1.0 + theirs.abs()),
                "{ours} vs {theirs}"
            );
        }
        // accumulator stats equal the pre-quantization extrema
        assert!((run.acc_stats.0 - ylo).abs() < 2e-4 * (1.0 + ylo.abs()));
        assert!((run.acc_stats.1 - yhi).abs() < 2e-4 * (1.0 + yhi.abs()));
    }

    fn run_output_real(run: &RunResult, _y: &[f32]) -> Vec<f32> {
        run.output.clone()
    }

    /// Machine-level accounting must agree with the closed form (4)/(5).
    #[test]
    fn machine_traffic_matches_closed_form() {
        let mac = MacArray::default();
        for g in traffic::table5_layers() {
            let st = mac.layer_phases(&g, true);
            let dy = mac.layer_phases(&g, false);
            let closed = traffic::compare(&g, BitWidths::default());
            assert_eq!(st.total() * 8, closed.static_bits, "{}", g.name());
            assert_eq!(dy.total() * 8, closed.dynamic_bits, "{}", g.name());
        }
        // the bridge holds for the transformer variants too
        for g in [
            crate::simulator::LayerGeom::attention("attn", 197, 384, 6, 64),
            crate::simulator::LayerGeom::linear("fc1", 384, 1536, 197),
        ] {
            let st = mac.layer_phases(&g, true);
            let dy = mac.layer_phases(&g, false);
            let closed = traffic::compare(&g, BitWidths::default());
            assert_eq!(st.total() * 8, closed.static_bits, "{}", g.name());
            assert_eq!(dy.total() * 8, closed.dynamic_bits, "{}", g.name());
        }
    }

    #[test]
    fn static_policy_moves_less_data() {
        let (m, k, n) = (32, 64, 48);
        let (a, w, qpa, qpw) = machine_inputs(m, k, n);
        let mac = MacArray::default();
        let st = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8,
                          Policy::Static { qmin: -30.0, qmax: 30.0 });
        let dy = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8, Policy::Dynamic);
        assert!(st.phases.total() < dy.phases.total());
        assert_eq!(st.phases.acc_store, 0);
        assert_eq!(dy.phases.acc_store, dy.phases.acc_reload);
        // both executed the same MACs
        assert_eq!(st.cycles, dy.cycles);
    }

    #[test]
    fn static_with_stale_range_still_close_when_range_covers() {
        // in-hindsight premise: yesterday's range quantizes today's tensor
        // almost as well, as long as the distribution moved slowly.
        let (m, k, n) = (16, 32, 16);
        let (a, w, qpa, qpw) = machine_inputs(m, k, n);
        let mac = MacArray::default();
        let dy = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8, Policy::Dynamic);
        let (lo, hi) = dy.acc_stats;
        // "hindsight" range: 10% wider than the true one (EMA lag)
        let st = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8,
                          Policy::Static { qmin: lo * 1.1, qmax: hi * 1.1 });
        let cos = crate::quant::cosine_similarity(&st.output, &dy.output);
        assert!(cos > 0.999, "cos {cos}");
    }

    #[test]
    fn static_per_channel_one_group_equals_static() {
        let (m, k, n) = (16, 32, 16);
        let (a, w, qpa, qpw) = machine_inputs(m, k, n);
        let mac = MacArray::default();
        let st = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8,
                          Policy::Static { qmin: -25.0, qmax: 25.0 });
        let pc = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8,
                          Policy::StaticPerChannel { ranges: vec![[-25.0, 25.0]] });
        assert_eq!(pc.output, st.output); // bit-for-bit
        assert_eq!(pc.acc_stats, st.acc_stats);
        assert_eq!(pc.acc_stats_axis, vec![st.acc_stats]);
        assert_eq!(pc.phases, st.phases);
    }

    #[test]
    fn static_per_channel_moves_static_traffic_and_tracks_columns() {
        let (m, k, n) = (8, 16, 4);
        let (a, w, qpa, qpw) = machine_inputs(m, k, n);
        let mac = MacArray::default();
        // one range row per output column (channels-last, C = n)
        let ranges: Vec<[f32; 2]> = (0..n).map(|_| [-30.0, 30.0]).collect();
        let pc = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8,
                          Policy::StaticPerChannel { ranges });
        let dy = mac.gemm(&a, &w, m, k, n, qpa, qpw, 8, Policy::Dynamic);
        // per-channel static is the same single-traversal store as static
        assert_eq!(pc.phases.acc_store, 0);
        assert!(pc.phases.total() < dy.phases.total());
        // channel stats hull over columns == the per-tensor stats
        assert_eq!(pc.acc_stats_axis.len(), n);
        assert_eq!(pc.acc_stats, dy.acc_stats);
        // per-tensor policies leave the axis registers empty
        assert!(dy.acc_stats_axis.is_empty());
    }

    #[test]
    fn utilization_and_cycles() {
        let mac = MacArray { p: 16, ..Default::default() };
        let (a, w, qpa, qpw) = machine_inputs(16, 16, 16);
        let run = mac.gemm(&a, &w, 16, 16, 16, qpa, qpw, 8, Policy::Dynamic);
        assert_eq!(run.cycles, 16); // single tile, one wavefront
        assert!((run.mac_utilization - 1.0).abs() < 1e-9);
        let run2 = mac.gemm(&a, &w, 16, 16, 16, qpa, qpw, 8, Policy::Dynamic);
        assert_eq!(run.output, run2.output); // deterministic
    }
}

//! Memory-movement accounting for static vs dynamic quantization
//! (paper Sec. 6, eqs. 4 & 5, Table 5).
//!
//! Static quantization: weights and inputs stream in at low bit-width,
//! the accumulator output is quantized on the fly and written once:
//!
//! ```text
//!   cost_static = Cin*Cout*k^2*b_w + Cin*W*H*b_a + Cout*W*H*b_a      (4)
//! ```
//!
//! Dynamic quantization must round-trip the 32-bit accumulator output
//! through memory before the ranges are known:
//!
//! ```text
//!   cost_dynamic = Cin*Cout*k^2*b_w + Cin*W*H*b_a
//!                + Cout*W*H*b_acc   (save acc output)
//!                + Cout*W*H*b_acc   (load acc output)
//!                + Cout*W*H*b_a     (save quantized output)          (5)
//! ```
//!
//! `W x H` is the *output* feature-map size; depthwise convolutions use
//! `Cin * k^2 * b_w` weights (one filter per channel).
//!
//! The cost formulas are written over the [`LayerGeom`] abstraction —
//! any layer kind exposing weight/input/output volumes pays the same
//! static-vs-dynamic asymmetry; the conv variant reproduces eqs. (4)/(5)
//! bit-for-bit (golden parity test in `simulator::layer`).

use super::layer::LayerGeom;

/// Geometry of one conv layer (paper Table 5 columns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Conv2dGeom {
    pub name: &'static str,
    pub cin: u64,
    pub cout: u64,
    pub k: u64,
    /// output feature map width/height
    pub w: u64,
    pub h: u64,
    pub depthwise: bool,
}

impl Conv2dGeom {
    pub const fn new(
        name: &'static str,
        cin: u64,
        cout: u64,
        k: u64,
        w: u64,
        h: u64,
        depthwise: bool,
    ) -> Self {
        Self {
            name,
            cin,
            cout,
            k,
            w,
            h,
            depthwise,
        }
    }

    /// Weight tensor footprint in *bits* at width `b_w`.
    pub fn weight_bits(&self, b_w: u64) -> u64 {
        if self.depthwise {
            self.cin * self.k * self.k * b_w
        } else {
            self.cin * self.cout * self.k * self.k * b_w
        }
    }

    pub fn input_bits(&self, b_a: u64) -> u64 {
        self.cin * self.w * self.h * b_a
    }

    pub fn output_elems(&self) -> u64 {
        self.cout * self.w * self.h
    }

    /// MAC count of the layer (for roofline-style reporting).
    pub fn macs(&self) -> u64 {
        let per_out = if self.depthwise {
            self.k * self.k
        } else {
            self.cin * self.k * self.k
        };
        self.output_elems() * per_out
    }
}

/// Bit-widths of the datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitWidths {
    pub b_w: u64,
    pub b_a: u64,
    pub b_acc: u64,
}

impl Default for BitWidths {
    fn default() -> Self {
        // the paper's Table 5 setting
        Self {
            b_w: 8,
            b_a: 8,
            b_acc: 32,
        }
    }
}

impl BitWidths {
    /// Forward-path bit-widths of a quantization scheme: per-class bits
    /// from the weight/activation specs (32 for disabled/fp32 classes),
    /// 32-bit accumulator.
    pub fn from_scheme(scheme: &crate::scheme::QuantScheme) -> Self {
        Self {
            b_w: scheme.weights.datapath_bits(),
            b_a: scheme.activations.datapath_bits(),
            b_acc: 32,
        }
    }
}

/// Byte costs of running one layer each way.
#[derive(Debug, Clone, Copy)]
pub struct TrafficCost {
    pub static_bits: u64,
    pub dynamic_bits: u64,
}

impl TrafficCost {
    pub fn static_kb(&self) -> f64 {
        self.static_bits as f64 / 8.0 / 1024.0
    }

    pub fn dynamic_kb(&self) -> f64 {
        self.dynamic_bits as f64 / 8.0 / 1024.0
    }

    /// Paper's "Delta" column: extra traffic of dynamic vs static, in %.
    pub fn delta_percent(&self) -> f64 {
        (self.dynamic_bits as f64 / self.static_bits as f64 - 1.0) * 100.0
    }

    /// Multiplier form (the paper quotes "up to 8x").
    pub fn ratio(&self) -> f64 {
        self.dynamic_bits as f64 / self.static_bits as f64
    }
}

/// Eq. (4): static quantization memory movement in bits.
pub fn static_cost(g: &LayerGeom, b: BitWidths) -> u64 {
    g.weight_bits(b.b_w) + g.input_bits(b.b_a) + g.output_elems() * b.b_a
}

/// Eq. (5): dynamic quantization memory movement in bits.
pub fn dynamic_cost(g: &LayerGeom, b: BitWidths) -> u64 {
    g.weight_bits(b.b_w)
        + g.input_bits(b.b_a)
        + g.output_elems() * b.b_acc // save accumulator output
        + g.output_elems() * b.b_acc // load accumulator output
        + g.output_elems() * b.b_a // save quantized output
}

pub fn compare(g: &LayerGeom, b: BitWidths) -> TrafficCost {
    TrafficCost {
        static_bits: static_cost(g, b),
        dynamic_bits: dynamic_cost(g, b),
    }
}

/// The five rows of paper Table 5 (ImageNet-size layers).
pub fn table5_layers() -> Vec<LayerGeom> {
    vec![
        LayerGeom::conv("ResNet18 3x3", 64, 64, 3, 56, 56, false),
        LayerGeom::conv("ResNet18 3x3", 256, 256, 3, 14, 14, false),
        LayerGeom::conv("MobileNetV2 1x1", 16, 96, 1, 112, 112, false),
        LayerGeom::conv("MobileNetV2 3x3 (DW)", 96, 96, 3, 112, 112, true),
        LayerGeom::conv("MobileNetV2 3x3 (DW)", 960, 960, 3, 7, 7, true),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The exact KB numbers and deltas of paper Table 5.
    ///
    /// NOTE on row 4 (MobileNetV2 3x3 DW, 96ch, 112x112): the paper prints
    /// 882 / 4410 KB, but eq. (4) applied to that geometry gives
    /// 2353 / 11761 KB — the paper's absolute numbers for this single row
    /// are inconsistent with its own formula by an unexplained 3/8 factor
    /// (every other row matches the formula to the KB).  The row's *Delta*
    /// (+400%) is scale-invariant and matches exactly, so we pin the
    /// formula-derived absolutes and the paper's delta.  Recorded in
    /// EXPERIMENTS.md.
    #[test]
    fn reproduces_paper_table5() {
        let expect = [
            (428.0, 1996.0, 366.0),
            (674.0, 1066.0, 58.0),
            (1374.0, 10782.0, 685.0),
            (2352.8, 11761.3, 400.0), // paper prints 882/4410; see note
            (100.0, 468.0, 366.0),
        ];
        for (g, (s_kb, d_kb, delta)) in table5_layers().iter().zip(expect) {
            let c = compare(g, BitWidths::default());
            assert!(
                (c.static_kb() - s_kb).abs() < 1.0,
                "{}: static {} vs paper {}",
                g.name(),
                c.static_kb(),
                s_kb
            );
            assert!(
                (c.dynamic_kb() - d_kb).abs() < 1.0,
                "{}: dynamic {} vs paper {}",
                g.name(),
                c.dynamic_kb(),
                d_kb
            );
            assert!(
                (c.delta_percent() - delta).abs() < 1.5,
                "{}: delta {} vs paper {}",
                g.name(),
                c.delta_percent(),
                delta
            );
        }
    }

    #[test]
    fn paper_headline_up_to_8x() {
        // "in the extreme case of certain point-wise convolutions in
        // MobileNetV2, the memory movement of dynamic quantization can be
        // 8x higher" — the 1x1 16->96 layer.
        let g = &table5_layers()[2];
        let c = compare(g, BitWidths::default());
        assert!(c.ratio() > 7.5 && c.ratio() < 8.1, "ratio {}", c.ratio());
    }

    #[test]
    fn dynamic_always_exceeds_static() {
        for g in table5_layers() {
            let c = compare(&g, BitWidths::default());
            assert!(c.dynamic_bits > c.static_bits);
        }
    }

    #[test]
    fn weight_heavy_layers_have_lower_overhead() {
        // paper: "Only in later layers in ResNet18, where the weight tensor
        // is significantly larger than the input feature map, is the
        // overhead lower."
        let rows = table5_layers();
        let early = compare(&rows[0], BitWidths::default());
        let late = compare(&rows[1], BitWidths::default());
        assert!(late.delta_percent() < early.delta_percent());
    }

    #[test]
    fn depthwise_weight_accounting() {
        let g = Conv2dGeom::new("dw", 96, 96, 3, 112, 112, true);
        assert_eq!(g.weight_bits(8), 96 * 9 * 8);
        let g2 = Conv2dGeom::new("pw", 96, 96, 3, 112, 112, false);
        assert_eq!(g2.weight_bits(8), 96 * 96 * 9 * 8);
    }

    #[test]
    fn wider_accumulator_widens_gap() {
        let g = table5_layers()[0];
        let base = compare(&g, BitWidths::default());
        let wide = compare(
            &g,
            BitWidths {
                b_w: 8,
                b_a: 8,
                b_acc: 48,
            },
        );
        assert!(wide.delta_percent() > base.delta_percent());
    }
}

//! Fixed-point neural-network accelerator model (paper Secs. 3.2 & 6).
//!
//! Two levels of fidelity:
//!
//! * [`traffic`] — the closed-form memory-movement accounting of paper
//!   eqs. (4) and (5); regenerates Table 5 exactly (it is an analytic
//!   property of the dataflow, not a silicon measurement).
//! * [`machine`] — a cycle-approximate MAC-array machine that actually
//!   executes int8 GEMMs slice by slice through a 32-bit accumulator,
//!   tracking per-phase DMA bytes; it realizes Figs. 2 and 4 in numbers
//!   and cross-validates the closed form (integration tests assert the
//!   two agree).
//! * [`scheme`] — the bridge from the typed `QuantScheme` API: per-class
//!   bit-widths and policies resolve from a scheme, so mixed-precision
//!   settings (`g:hindsight@pc:4`) execute end-to-end here.
//! * [`layer`] — the layer-graph abstraction the traffic stack is
//!   written over: conv / linear / attention variants of [`LayerGeom`]
//!   expose MAC counts, traffic volumes and quantizer-site plans
//!   (heads are the `@pc` channel-group axis for attention).

pub mod backward;
pub mod layer;
pub mod machine;
pub mod scheme;
pub mod traffic;

pub use layer::{workload_spec, AttentionGeom, LayerGeom, LinearGeom};
pub use traffic::{Conv2dGeom, TrafficCost};

//! Backward-pass memory-traffic model (paper Sec. 6: "We show it here for
//! the forward pass, the backwards pass follows analogously (see figure
//! 1)").  The paper leaves the backward accounting implicit; this module
//! makes it explicit so the *training-step* traffic ratio — the number a
//! deployment actually cares about — can be reported.
//!
//! Per Fig. 1, the backward pass of a conv layer computes, from the
//! quantized output-gradient `G_Y` (Cout x W x H at b_g bits):
//!
//! * the **input gradient** `G_X = G_Y ⊛ rot180(W)` — a conv with the
//!   same MAC volume as the forward pass, whose Cin x W x H output goes
//!   through `Q_G`: *this* is the quantizer whose range estimation the
//!   paper studies, and the static/dynamic asymmetry is identical to the
//!   forward one (eqs. 4/5 with gradient bit-widths);
//! * the **weight gradient** `G_W = X^T ⊛ G_Y`, kept FP32 (paper Sec.
//!   3.1), so its store is always full-precision — static and dynamic
//!   pay it equally.

use super::layer::LayerGeom;
use super::traffic::{BitWidths, TrafficCost};
use crate::quant::kernel;

/// Bit-widths of the backward datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BwdBits {
    /// activation-gradient bit-width (G8 in the paper)
    pub b_g: u64,
    /// stored activations (inputs re-read for G_W), b_a
    pub b_a: u64,
    /// weights re-read for G_X
    pub b_w: u64,
    /// accumulator / FP32 weight-gradient width
    pub b_acc: u64,
}

impl Default for BwdBits {
    fn default() -> Self {
        Self {
            b_g: 8,
            b_a: 8,
            b_w: 8,
            b_acc: 32,
        }
    }
}

impl BwdBits {
    /// Backward-path bit-widths of a quantization scheme: per-class bits
    /// from the gradient/activation/weight specs (32 for disabled/fp32
    /// classes), 32-bit accumulator.
    pub fn from_scheme(scheme: &crate::scheme::QuantScheme) -> Self {
        Self {
            b_g: scheme.gradients.datapath_bits(),
            b_a: scheme.activations.datapath_bits(),
            b_w: scheme.weights.datapath_bits(),
            b_acc: 32,
        }
    }
}

/// Eq. (4)-analogue for the backward pass, static `Q_G`:
/// weights + incoming G_Y + store quantized G_X + (G_W path: re-read X,
/// store FP32 G_W).
pub fn bwd_static_cost(g: &LayerGeom, b: BwdBits) -> u64 {
    let gy = g.output_elems() * b.b_g; // load quantized output-gradient
    let gx_store = g.input_elems() * b.b_g; // store quantized G_X
    let x_reload = g.input_bits(b.b_a); // re-read saved activations
    let gw_store = g.weight_bits(b.b_acc); // FP32 weight gradient out
    g.weight_bits(b.b_w) + gy + gx_store + x_reload + gw_store
}

/// Eq. (5)-analogue: dynamic `Q_G` must round-trip the G_X accumulator
/// output at `b_acc` before it can be quantized.
pub fn bwd_dynamic_cost(g: &LayerGeom, b: BwdBits) -> u64 {
    let gx_elems = g.input_elems();
    bwd_static_cost(g, b)
        - gx_elems * b.b_g                 // replace the direct store...
        + gx_elems * b.b_acc               // ...with acc store
        + gx_elems * b.b_acc               // acc reload
        + gx_elems * b.b_g // quantized store
}

pub fn bwd_compare(g: &LayerGeom, b: BwdBits) -> TrafficCost {
    TrafficCost {
        static_bits: bwd_static_cost(g, b),
        dynamic_bits: bwd_dynamic_cost(g, b),
    }
}

/// Numeric counterpart of the `G_X` term in [`bwd_static_cost`]: quantize
/// and store an input-gradient tensor the way the static (in-hindsight)
/// accelerator does — one fused pass emits the `b_g`-bit **integer
/// payload** (packed two-per-byte at ≤ 4 bits) *and* the Fig. 3
/// statistics the next range update consumes, then the payload is read
/// back in place of `gx` — bit-identical to the fake-quant grid, because
/// `dequant(store(x)) == fq(x)` by construction.  Returns
/// `((lo, hi), bits_moved)` where `bits_moved` is `8 *` the payload
/// buffer's real size: a measured quantity, not accounting.  For 8-bit
/// (and even-length ≤ 4-bit) tensors it coincides with the closed-form
/// `len * b_g` term; 5..=7-bit codes occupy a whole byte each, and a
/// `b_g > 8` (fp16/fp32) class keeps the fake-quant path and the
/// closed-form count — there is no integer payload to measure.
pub fn store_gx_static(gx: &mut [f32], qmin: f32, qmax: f32, b: BwdBits) -> ((f32, f32), u64) {
    let bits = b.b_g as u32;
    if b.b_g > 8 {
        let stats = kernel::minmax_fq(gx, qmin, qmax, bits);
        return (stats, gx.len() as u64 * b.b_g);
    }
    let mut payload = vec![0u8; kernel::payload_bytes(gx.len(), bits)];
    let stats = if bits <= 4 {
        let s = kernel::fq_store_i4(gx, &mut payload, qmin, qmax, bits);
        kernel::dequant_i4(&payload, gx, qmin, qmax, bits);
        s
    } else {
        let s = kernel::fq_store_i8(gx, &mut payload, qmin, qmax, bits);
        kernel::dequant_i8(&payload, gx, qmin, qmax, bits);
        s
    };
    (stats, payload.len() as u64 * 8)
}

/// Per-channel-group variant of [`store_gx_static`]: `ranges[c]` covers
/// the gradient elements with flat index ≡ c (mod `ranges.len()`)
/// (channels-last, the layout the per-channel estimator adapter feeds).
/// Traffic is identical to the per-tensor store — the payload buffer has
/// the same size; per-channel granularity only widens the statistics
/// register file, the store is still a single fused traversal.
pub fn store_gx_static_axis(
    gx: &mut [f32],
    ranges: &[[f32; 2]],
    b: BwdBits,
) -> (Vec<(f32, f32)>, u64) {
    let bits = b.b_g as u32;
    if b.b_g > 8 {
        let stats = kernel::minmax_fq_axis(gx, ranges, bits);
        return (stats, gx.len() as u64 * b.b_g);
    }
    let mut payload = vec![0u8; kernel::payload_bytes(gx.len(), bits)];
    let stats = if bits <= 4 {
        let s = kernel::fq_store_i4_axis(gx, &mut payload, ranges, bits);
        kernel::dequant_i4_axis(&payload, gx, ranges, bits);
        s
    } else {
        let s = kernel::fq_store_i8_axis(gx, &mut payload, ranges, bits);
        kernel::dequant_i8_axis(&payload, gx, ranges, bits);
        s
    };
    (stats, payload.len() as u64 * 8)
}

/// Full training-step (fwd + bwd) traffic for a network under each
/// policy; the deployment-level number the paper's Sec. 6 argument
/// implies.  Returns (static_bits, dynamic_bits).
pub fn training_step_cost(
    layers: &[LayerGeom],
    fwd: BitWidths,
    bwd: BwdBits,
) -> (u64, u64) {
    let mut s = 0u64;
    let mut d = 0u64;
    for g in layers {
        s += super::traffic::static_cost(g, fwd) + bwd_static_cost(g, bwd);
        d += super::traffic::dynamic_cost(g, fwd) + bwd_dynamic_cost(g, bwd);
    }
    (s, d)
}

/// Network-level summary row.
#[derive(Debug, Clone)]
pub struct NetworkTraffic {
    pub name: String,
    pub fwd: TrafficCost,
    pub bwd: TrafficCost,
    pub step_static_mb: f64,
    pub step_dynamic_mb: f64,
}

impl NetworkTraffic {
    pub fn analyze(name: &str, layers: &[LayerGeom]) -> Self {
        let fwd_b = BitWidths::default();
        let bwd_b = BwdBits::default();
        let fwd = TrafficCost {
            static_bits: layers.iter().map(|g| super::traffic::static_cost(g, fwd_b)).sum(),
            dynamic_bits: layers.iter().map(|g| super::traffic::dynamic_cost(g, fwd_b)).sum(),
        };
        let bwd = TrafficCost {
            static_bits: layers.iter().map(|g| bwd_static_cost(g, bwd_b)).sum(),
            dynamic_bits: layers.iter().map(|g| bwd_dynamic_cost(g, bwd_b)).sum(),
        };
        let (s, d) = training_step_cost(layers, fwd_b, bwd_b);
        Self {
            name: name.to_string(),
            fwd,
            bwd,
            step_static_mb: s as f64 / 8e6,
            step_dynamic_mb: d as f64 / 8e6,
        }
    }

    pub fn step_ratio(&self) -> f64 {
        self.step_dynamic_mb / self.step_static_mb
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models;
    use crate::simulator::traffic;

    #[test]
    fn backward_dynamic_exceeds_static_by_acc_roundtrip() {
        for g in traffic::table5_layers() {
            let b = BwdBits::default();
            let st = bwd_static_cost(&g, b);
            let dy = bwd_dynamic_cost(&g, b);
            // the gap is exactly two b_acc round trips of G_X
            assert_eq!(dy - st, 2 * g.input_elems() * b.b_acc);
        }
    }

    #[test]
    fn gx_asymmetry_mirrors_forward_shape() {
        // for a stride-1 square layer the *extra* dynamic traffic in bwd
        // (over G_X elements) equals the fwd extra (over Y elements) when
        // cin == cout
        let g = traffic::table5_layers()[0]; // 64 -> 64
        let fwd = traffic::compare(&g, traffic::BitWidths::default());
        let bwd = bwd_compare(&g, BwdBits::default());
        assert_eq!(
            fwd.dynamic_bits - fwd.static_bits,
            bwd.dynamic_bits - bwd.static_bits
        );
    }

    #[test]
    fn weight_gradient_paid_equally() {
        // FP32 G_W store appears in both policies: removing it from both
        // leaves the delta unchanged
        let g = traffic::table5_layers()[2];
        let b = BwdBits::default();
        let delta = bwd_dynamic_cost(&g, b) - bwd_static_cost(&g, b);
        let mut b2 = b;
        b2.b_acc = 32; // same acc, G_W unchanged
        assert_eq!(delta, bwd_dynamic_cost(&g, b2) - bwd_static_cost(&g, b2));
    }

    #[test]
    fn fused_gx_store_matches_the_closed_form_term() {
        use crate::quant::{minmax, QuantParams};
        use crate::util::rng::Pcg32;
        let g = traffic::table5_layers()[0];
        let b = BwdBits::default();
        let n = g.input_elems() as usize;
        let mut rng = Pcg32::new(17, 1);
        let mut gx: Vec<f32> = (0..n).map(|_| rng.normal() * 0.01).collect();
        let expect_stats = minmax(&gx);
        let (stats, bits_moved) = store_gx_static(&mut gx, -0.05, 0.05, b);
        // the single pass reports the pre-quantization extrema ...
        assert_eq!(stats, expect_stats);
        // ... moves exactly the closed-form G_X store term ...
        assert_eq!(bits_moved, g.input_elems() * b.b_g);
        // ... and leaves the tensor on the b_g grid
        let qp = QuantParams::from_range(-0.05, 0.05, b.b_g as u32);
        assert!(gx.iter().all(|&x| (qp.fq(x) - x).abs() < 1e-7));
    }

    #[test]
    fn per_channel_gx_store_same_traffic_finer_stats() {
        use crate::quant::minmax;
        use crate::util::rng::Pcg32;
        let b = BwdBits::default();
        let c = 8usize;
        let n = c * 512;
        let mut rng = Pcg32::new(23, 1);
        // channel-dependent spread: channel i scaled by (i + 1)
        let gx: Vec<f32> = (0..n)
            .map(|i| rng.normal() * 0.01 * ((i % c) + 1) as f32)
            .collect();
        let ranges: Vec<[f32; 2]> = (0..c).map(|i| {
            let w = 0.05 * (i + 1) as f32;
            [-w, w]
        }).collect();
        let mut per_tensor = gx.clone();
        let (_, bits_pt) = store_gx_static(&mut per_tensor, -0.4, 0.4, b);
        let mut per_chan = gx.clone();
        let (stats, bits_pc) = store_gx_static_axis(&mut per_chan, &ranges, b);
        // identical closed-form traffic term
        assert_eq!(bits_pc, bits_pt);
        // per-channel stats match each channel's strided hull
        for (ch, s) in stats.iter().enumerate() {
            let chan: Vec<f32> = gx.iter().skip(ch).step_by(c).copied().collect();
            assert_eq!(*s, minmax(&chan));
        }
        // one group reduces to the per-tensor store bit-for-bit
        let mut a = gx.clone();
        let (s1, _) = store_gx_static(&mut a, -0.4, 0.4, b);
        let mut bb = gx.clone();
        let (s2, _) = store_gx_static_axis(&mut bb, &[[-0.4, 0.4]], b);
        assert_eq!(vec![s1], s2);
        assert_eq!(a, bb);
    }

    #[test]
    fn training_step_network_totals() {
        for net in ["resnet18", "vgg16", "mobilenet_v2"] {
            let layers = models::by_name(net).unwrap();
            let t = NetworkTraffic::analyze(net, &layers);
            // network-level training-step overhead is diluted by the FP32
            // weight-gradient stores both policies pay (ResNet18 ~1.4x,
            // MobileNetV2 ~3x) — still a material tax everywhere
            assert!(t.step_ratio() > 1.2, "{net}: ratio {}", t.step_ratio());
            assert!(t.step_static_mb > 1.0);
            // fwd + bwd decompose the step totals
            let total_s = (t.fwd.static_bits + t.bwd.static_bits) as f64 / 8e6;
            assert!((total_s - t.step_static_mb).abs() < 1e-9);
        }
    }

    #[test]
    fn training_step_transformer_totals() {
        // the layer-graph refactor's new workloads go through the same
        // closed-form accounting: attention blocks pay the static/dynamic
        // asymmetry on every GEMM-stage store
        for net in ["vit_s16", "deit_t16"] {
            let layers = models::by_name(net).unwrap();
            let t = NetworkTraffic::analyze(net, &layers);
            assert!(t.step_ratio() > 1.2, "{net}: ratio {}", t.step_ratio());
            assert!(t.step_static_mb > 1.0, "{net}: {} MB", t.step_static_mb);
            let total_s = (t.fwd.static_bits + t.bwd.static_bits) as f64 / 8e6;
            assert!((total_s - t.step_static_mb).abs() < 1e-9);
        }
    }

    #[test]
    fn mobilenet_is_the_worst_case_network() {
        // the paper's 8x layers push MobileNetV2's network-level ratio
        // above ResNet18's
        let r = NetworkTraffic::analyze(
            "resnet18",
            &models::by_name("resnet18").unwrap(),
        );
        let m = NetworkTraffic::analyze(
            "mobilenet_v2",
            &models::by_name("mobilenet_v2").unwrap(),
        );
        assert!(m.step_ratio() > r.step_ratio());
    }
}

//! The canonical string form of a [`QuantScheme`] and its parser.
//!
//! Grammar (whitespace-separated clauses, each class at most once;
//! unmentioned classes default to `fp32`):
//!
//! ```text
//!   scheme  := clause (ws clause)*
//!   clause  := ('w' | 'a' | 'g') ':' spec      per-class spec
//!            | '@' site-name ':' spec          per-site override
//!   spec    := est-key ['@pc'] (':' attr)*
//!   attr    := <bits>                          integer in 2..=16
//!            | 'eta=' <float>                  EMA momentum in [0, 1]
//!            | 'sym'                           zero-symmetric grid
//! ```
//!
//! Examples: `w:current:8 a:hindsight:8 g:hindsight@pc:4`,
//! `g:tqt:8:eta=0.95`, `w:fp32:8 a:fp32:8 g:dsgc:8 @fc1_g:sampled:8`.
//!
//! `Display` emits the canonical form (every class, explicit bits,
//! non-default `eta`/`sym` attrs, overrides in site-name order) and
//! round-trips: `QuantScheme::parse(&s.to_string()) == s` for every
//! valid scheme — pinned by property tests below across all registry
//! keys × granularities × bit-widths.
//!
//! Errors enumerate the valid registry keys and the `@pc` / `:bits`
//! suffix syntax instead of just echoing the bad token.

use std::fmt;

use anyhow::{bail, Context, Result};

use super::{QuantScheme, QuantSpec, TensorClass, BITS_RANGE, DEFAULT_ETA};
use crate::estimator::Estimator;

/// One-paragraph grammar reminder appended to parse errors and printed
/// by `hindsight estimators`.
pub fn syntax_help() -> String {
    format!(
        "scheme syntax: whitespace-separated clauses `<class>:<est>[@pc][:<bits>][:eta=<f>][:sym]` \
         with class one of w|a|g (or `@<site>` for a per-site override); \
         estimator keys: {}; bits in {}..={}; e.g. \
         'w:current:8 a:hindsight:8 g:hindsight@pc:4'",
        Estimator::keys().join("|"),
        BITS_RANGE.start(),
        BITS_RANGE.end()
    )
}

/// Parse an EMA momentum, enforcing the one range rule every surface
/// shares (`eta=` attrs, the CLI `--eta` flag).
pub fn parse_eta(v: &str) -> Result<f32> {
    v.parse()
        .ok()
        .filter(|e: &f32| (0.0..=1.0).contains(e))
        .with_context(|| format!("bad eta '{v}' — expected a float in [0, 1]"))
}

/// Reject site names the string form cannot represent.
pub(super) fn validate_site_name(site: &str) -> Result<()> {
    if site.is_empty()
        || site
            .chars()
            .any(|c| c.is_whitespace() || c == ':' || c == '@')
    {
        bail!(
            "invalid site name '{site}': overrides are keyed by single-token \
             site names (no whitespace, ':' or '@')"
        );
    }
    Ok(())
}

/// Parse one clause body (`hindsight@pc:4:eta=0.5:sym`).
pub(super) fn parse_spec(body: &str) -> Result<QuantSpec> {
    let mut parts = body.split(':');
    let key = parts.next().unwrap_or("");
    if key.is_empty() {
        bail!("empty estimator key in '{body}' — {}", syntax_help());
    }
    let estimator =
        Estimator::parse(key).with_context(|| format!("in spec '{body}' — {}", syntax_help()))?;
    let mut spec = QuantSpec::new(estimator);
    let mut saw_bits = false;
    for attr in parts {
        if let Some(v) = attr.strip_prefix("eta=") {
            spec.eta = parse_eta(v).with_context(|| format!("in '{body}'"))?;
        } else if attr == "sym" {
            spec.symmetric = true;
        } else if !attr.is_empty() && attr.chars().all(|c| c.is_ascii_digit()) {
            if saw_bits {
                bail!("duplicate bit-width attr '{attr}' in '{body}'");
            }
            let bits: u32 = attr.parse().with_context(|| format!("bad bits '{attr}'"))?;
            if !BITS_RANGE.contains(&bits) {
                bail!(
                    "bits {bits} in '{body}' outside the supported {}..={} range",
                    BITS_RANGE.start(),
                    BITS_RANGE.end()
                );
            }
            spec.bits = bits;
            saw_bits = true;
        } else {
            bail!(
                "unknown attribute '{attr}' in '{body}' — expected a bit-width \
                 ({}..={}), 'eta=<f>' or 'sym'; {}",
                BITS_RANGE.start(),
                BITS_RANGE.end(),
                syntax_help()
            );
        }
    }
    Ok(spec)
}

/// Parse the whole scheme string; see the module docs for the grammar.
pub(super) fn parse_scheme(s: &str) -> Result<QuantScheme> {
    let mut scheme = QuantScheme::fp32();
    let mut seen = [false; 3];
    let mut any = false;
    for tok in s.split_whitespace() {
        any = true;
        let Some((head, body)) = tok.split_once(':') else {
            bail!("clause '{tok}' has no ':' — {}", syntax_help());
        };
        if let Some(site) = head.strip_prefix('@') {
            validate_site_name(site)?;
            let spec = parse_spec(body)?;
            if scheme.overrides.insert(site.to_string(), spec).is_some() {
                bail!("duplicate override for site '{site}'");
            }
        } else {
            let class = match head {
                "w" => TensorClass::Weights,
                "a" => TensorClass::Activations,
                "g" => TensorClass::Gradients,
                other => bail!(
                    "unknown tensor class '{other}' in clause '{tok}' — {}",
                    syntax_help()
                ),
            };
            let idx = TensorClass::all().iter().position(|c| *c == class).unwrap();
            if seen[idx] {
                bail!("duplicate clause for tensor class '{head}'");
            }
            seen[idx] = true;
            *scheme.spec_mut(class) = parse_spec(body)?;
        }
    }
    if !any {
        bail!("empty scheme string — {}", syntax_help());
    }
    Ok(scheme)
}

impl fmt::Display for QuantSpec {
    /// Canonical clause body: `est[@pc]:bits[:eta=<f>][:sym]` (bits
    /// always explicit, `eta` only when non-default).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.estimator.spec(), self.bits)?;
        if self.eta != DEFAULT_ETA {
            write!(f, ":eta={}", self.eta)?;
        }
        if self.symmetric {
            write!(f, ":sym")?;
        }
        Ok(())
    }
}

impl fmt::Display for QuantScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "w:{} a:{} g:{}",
            self.weights, self.activations, self.gradients
        )?;
        for (site, spec) in &self.overrides {
            write!(f, " @{site}:{spec}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::Granularity;
    use crate::util::testkit::forall;

    #[test]
    fn the_issue_example_parses_and_round_trips() {
        let s = QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight@pc:4").unwrap();
        assert_eq!(s.weights.estimator, Estimator::CURRENT);
        assert_eq!(s.activations.estimator, Estimator::HINDSIGHT);
        assert_eq!(s.gradients.estimator.key(), "hindsight");
        assert!(s.gradients.is_per_channel());
        assert_eq!(s.gradients.bits, 4);
        assert_eq!(s.to_string(), "w:current:8 a:hindsight:8 g:hindsight@pc:4");
        assert_eq!(QuantScheme::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn unmentioned_classes_default_to_fp32() {
        let s = QuantScheme::parse("g:dsgc:8").unwrap();
        assert!(!s.weights.enabled());
        assert!(!s.activations.enabled());
        assert_eq!(s.gradients.estimator, Estimator::DSGC);
        assert_eq!(s, QuantScheme::grad_only(Estimator::DSGC));
    }

    #[test]
    fn attrs_parse_in_any_order() {
        let a = QuantScheme::parse("g:hindsight:4:eta=0.5:sym").unwrap();
        let b = QuantScheme::parse("g:hindsight:sym:eta=0.5:4").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.gradients.bits, 4);
        assert_eq!(a.gradients.eta, 0.5);
        assert!(a.gradients.symmetric);
        // bits default to 8 when omitted
        let c = QuantScheme::parse("g:hindsight").unwrap();
        assert_eq!(c.gradients.bits, 8);
    }

    #[test]
    fn overrides_parse_and_round_trip_in_name_order() {
        let s = QuantScheme::parse("g:dsgc:8 @b_site:tqt:6 @a_site:sampled:8").unwrap();
        assert_eq!(s.overrides().count(), 2);
        assert_eq!(
            s.to_string(),
            "w:fp32:8 a:fp32:8 g:dsgc:8 @a_site:sampled:8 @b_site:tqt:6"
        );
        assert_eq!(QuantScheme::parse(&s.to_string()).unwrap(), s);
    }

    #[test]
    fn errors_enumerate_keys_and_suffix_syntax() {
        let err = QuantScheme::parse("g:bogus:8").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown estimator 'bogus'"), "{msg}");
        for key in Estimator::keys() {
            assert!(msg.contains(key), "error must list '{key}': {msg}");
        }
        assert!(msg.contains("@pc"), "{msg}");
        assert!(msg.contains(":<bits>"), "{msg}");

        let err = format!("{:#}", QuantScheme::parse("x:hindsight:8").unwrap_err());
        assert!(err.contains("unknown tensor class 'x'"), "{err}");
        assert!(err.contains("w|a|g"), "{err}");

        let err = format!("{:#}", QuantScheme::parse("g:hindsight:wat").unwrap_err());
        assert!(err.contains("unknown attribute 'wat'"), "{err}");
        assert!(err.contains("eta=<f>"), "{err}");
    }

    #[test]
    fn malformed_schemes_are_rejected() {
        assert!(QuantScheme::parse("").is_err());
        assert!(QuantScheme::parse("   ").is_err());
        assert!(QuantScheme::parse("g").is_err()); // no ':'
        assert!(QuantScheme::parse("g:").is_err()); // empty key
        assert!(QuantScheme::parse("g:hindsight:8 g:current:8").is_err()); // dup class
        assert!(QuantScheme::parse("@s:tqt:8 @s:tqt:8").is_err()); // dup site
        assert!(QuantScheme::parse("g:hindsight:1").is_err()); // bits too low
        assert!(QuantScheme::parse("g:hindsight:99").is_err()); // bits too high
        assert!(QuantScheme::parse("g:hindsight:4:4").is_err()); // dup bits
        assert!(QuantScheme::parse("g:hindsight:eta=2.0").is_err()); // eta range
        assert!(QuantScheme::parse("g:hindsight@bogus:8").is_err()); // bad gran
        assert!(QuantScheme::parse("@:tqt:8").is_err()); // empty site
    }

    /// Satellite acceptance: the string form round-trips for every
    /// registry key × granularity × bit-width 2..=8, exhaustively, in
    /// every class slot.
    #[test]
    fn round_trip_exhaustive_over_keys_granularities_and_bits() {
        for est in Estimator::all() {
            for pc in [false, true] {
                let est = if pc { est.per_channel() } else { est };
                for bits in 2u32..=8 {
                    for class in TensorClass::all() {
                        let mut s = QuantScheme::w8a8g8();
                        s.spec_mut(class).estimator = est;
                        let s = s.bits(class, bits);
                        let rendered = s.to_string();
                        let parsed = QuantScheme::parse(&rendered)
                            .unwrap_or_else(|e| panic!("'{rendered}' failed: {e:#}"));
                        assert_eq!(parsed, s, "round trip of '{rendered}'");
                    }
                }
            }
        }
    }

    /// Randomized round trip over full schemes: random estimators,
    /// granularities, bits, eta, sym and overrides per case.
    #[test]
    fn round_trip_random_schemes() {
        let keys = Estimator::keys();
        forall(
            128,
            "scheme-round-trip",
            |rng| {
                let spec = |rng: &mut crate::util::rng::Pcg32| {
                    let mut est = Estimator::parse(keys[rng.below(keys.len())]).unwrap();
                    if rng.below(2) == 1 {
                        est = est.per_channel();
                    }
                    let mut q = QuantSpec::new(est).with_bits(2 + rng.below(7) as u32);
                    if rng.below(2) == 1 {
                        // quarter-steps land on exact f32 values
                        q = q.with_eta(rng.below(5) as f32 * 0.25);
                    }
                    q.symmetric = rng.below(2) == 1;
                    q
                };
                let mut s = QuantScheme::fp32();
                s.weights = spec(rng);
                s.activations = spec(rng);
                s.gradients = spec(rng);
                for i in 0..rng.below(3) {
                    s = s.override_site(&format!("site{i}"), spec(rng)).unwrap();
                }
                s
            },
            |s| QuantScheme::parse(&s.to_string()).unwrap() == *s,
        );
    }

    #[test]
    fn granularity_survives_the_string_form() {
        let s = QuantScheme::parse("a:running@pc:8 g:tqt@pc:4").unwrap();
        assert_eq!(s.activations.granularity(), Granularity::PerChannel);
        assert_eq!(s.gradients.granularity(), Granularity::PerChannel);
        assert_eq!(s.weights.granularity(), Granularity::PerTensor);
    }
}

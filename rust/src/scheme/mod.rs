//! Typed per-tensor-class quantization schemes.
//!
//! The paper's experiments are inherently per-tensor-class: gradients use
//! in-hindsight estimation while activations may use running min-max and
//! weights current min-max, at independently chosen bit-widths (Tables
//! 1-3, W8/A8/G8 vs W4/A4/G8).  This module replaces the old flat
//! two-knob configuration (one gradient estimator, one activation
//! estimator, a global `eta`, an implicit global bit-width) with a
//! composable policy object:
//!
//! * [`QuantSpec`] — how one tensor class is quantized: the range
//!   [`Estimator`], the bit-width, the EMA momentum `eta` and an optional
//!   symmetric-grid constraint.  Granularity (per-tensor vs per-channel)
//!   is part of the estimator's identity (the `@pc` key suffix) and is
//!   exposed through [`QuantSpec::granularity`].
//! * [`TensorClass`] — the three classes the training graph quantizes:
//!   weights, activations, gradients.
//! * [`QuantScheme`] — one spec per class plus per-site overrides keyed
//!   by quantizer-site name, with a builder
//!   (`QuantScheme::w8a8g8().grad("hindsight@pc")?.bits(TensorClass::Gradients, 4)`)
//!   and a canonical string form
//!   (`w:current:8 a:hindsight:8 g:hindsight@pc:4`) that parses and
//!   round-trips (see [`parse`]).
//!
//! Consumers: `TrainConfig` carries a scheme instead of loose knobs,
//! `RangeManager` resolves each site's spec at construction (per-site
//! bits/eta flow into search and calibration), the accelerator simulator
//! derives its per-class bit-widths from a scheme
//! (`simulator::scheme`), and the CLI/sweeps/benches construct schemes
//! via the builder or the string form.

pub mod parse;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::estimator::{Estimator, Granularity, RangeEstimator, SiteParams};

/// Default bit-width of every tensor class (the paper's W8/A8/G8).
pub const DEFAULT_BITS: u32 = 8;
/// Default EMA momentum (paper Sec. 5: eta = 0.9).
pub const DEFAULT_ETA: f32 = 0.9;
/// Valid bit-width range for a scheme spec (2-bit grids up to the
/// 16-bit headroom ablations probe; the accumulator stays 32-bit).
pub const BITS_RANGE: std::ops::RangeInclusive<u32> = 2..=16;

/// The three tensor classes the training graph quantizes (paper Fig. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorClass {
    /// layer weights (the paper quantizes them with current min-max)
    Weights,
    /// forward activations
    Activations,
    /// backward activation gradients (the paper's focus)
    Gradients,
}

impl TensorClass {
    /// All classes, in canonical (`w a g`) order.
    pub fn all() -> [TensorClass; 3] {
        [Self::Weights, Self::Activations, Self::Gradients]
    }

    /// The one-letter clause prefix of the string form.
    pub fn token(self) -> &'static str {
        match self {
            Self::Weights => "w",
            Self::Activations => "a",
            Self::Gradients => "g",
        }
    }
}

/// How one tensor class (or one overridden site) is quantized.
///
/// `granularity` lives inside the estimator handle (`@pc` registry
/// suffix) so it cannot drift out of sync; [`QuantSpec::granularity`]
/// exposes it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantSpec {
    /// range estimator (registry key, possibly `@pc`)
    pub estimator: Estimator,
    /// quantization bit-width (validated against [`BITS_RANGE`])
    pub bits: u32,
    /// EMA momentum for running/in-hindsight-style updates; also the
    /// adaptation-rate knob stateful estimators may consume (TQT derives
    /// its threshold step from it)
    pub eta: f32,
    /// force a zero-symmetric grid: the coordinator symmetrizes every
    /// range row it adopts to `[-m, m]`, `m = max(|lo|, |hi|)`
    pub symmetric: bool,
}

impl QuantSpec {
    /// Spec with the paper's defaults (8 bits, eta 0.9, asymmetric).
    pub fn new(estimator: Estimator) -> Self {
        Self {
            estimator,
            bits: DEFAULT_BITS,
            eta: DEFAULT_ETA,
            symmetric: false,
        }
    }

    /// Parse one clause body of the string form (`hindsight@pc:4`,
    /// `current:8:eta=0.5:sym`); see [`parse`] for the grammar.
    pub fn parse(clause: &str) -> Result<Self> {
        parse::parse_spec(clause)
    }

    /// Quantizer granularity (delegates to the estimator handle).
    pub fn granularity(&self) -> Granularity {
        self.estimator.granularity()
    }

    pub fn is_per_channel(&self) -> bool {
        self.estimator.is_per_channel()
    }

    /// Whether this spec quantizes its tensor class at all.
    pub fn enabled(&self) -> bool {
        self.estimator.enabled()
    }

    /// The per-site knobs handed to the estimator registry's factories.
    pub fn params(&self) -> SiteParams {
        SiteParams {
            bits: self.bits,
            eta: self.eta,
        }
    }

    /// Bits this class actually moves on the accelerator datapath: the
    /// spec's bit-width when it quantizes, full precision (32) when the
    /// class is `fp32` — so traffic models never bill an unquantized
    /// tensor at its (inert) spec bits.
    pub fn datapath_bits(&self) -> u64 {
        if self.enabled() {
            self.bits as u64
        } else {
            32
        }
    }

    /// Build the per-site estimator instance for a site with
    /// `n_channels` channel groups, honoring granularity and handing the
    /// spec's bits/eta to the registry factory.
    pub fn instantiate_site(&self, n_channels: usize) -> Box<dyn RangeEstimator> {
        self.estimator.instantiate_site_with(self.params(), n_channels)
    }

    pub fn with_bits(mut self, bits: u32) -> Self {
        assert!(
            BITS_RANGE.contains(&bits),
            "bits {bits} outside the supported {}..={} range",
            BITS_RANGE.start(),
            BITS_RANGE.end()
        );
        self.bits = bits;
        self
    }

    pub fn with_eta(mut self, eta: f32) -> Self {
        assert!((0.0..=1.0).contains(&eta), "eta {eta} outside [0, 1]");
        self.eta = eta;
        self
    }

    pub fn with_symmetric(mut self, on: bool) -> Self {
        self.symmetric = on;
        self
    }
}

/// One [`QuantSpec`] per tensor class plus per-site overrides, keyed by
/// quantizer-site name.  This is the whole quantization policy of a run:
/// `TrainConfig` carries one, `RangeManager` resolves it per site, the
/// simulator derives per-class bit-widths from it.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantScheme {
    pub weights: QuantSpec,
    pub activations: QuantSpec,
    pub gradients: QuantSpec,
    /// site-name → spec; wins over the class spec for that site only
    overrides: BTreeMap<String, QuantSpec>,
}

impl QuantScheme {
    /// No quantization anywhere (every class `fp32`).
    pub fn fp32() -> Self {
        Self {
            weights: QuantSpec::new(Estimator::FP32),
            activations: QuantSpec::new(Estimator::FP32),
            gradients: QuantSpec::new(Estimator::FP32),
            overrides: BTreeMap::new(),
        }
    }

    /// The paper's fully quantized W8/A8/G8 setting with in-hindsight
    /// ranges — identical to the legacy `fully_quantized(HINDSIGHT)`
    /// configuration (weights current min-max, acts/grads in-hindsight,
    /// 8 bits everywhere; parity is pinned bit-for-bit on the simulator
    /// path in `simulator::scheme`).
    pub fn w8a8g8() -> Self {
        Self::fully_quantized(Estimator::HINDSIGHT)
    }

    /// Fully quantized setting for `est`: gradients use `est`,
    /// activations fall back to current min-max for search-based
    /// (`needs_search`) estimators (paper Table 3's DSGC row), weights
    /// are quantized (current min-max) iff `est` quantizes at all.
    pub fn fully_quantized(est: Estimator) -> Self {
        Self::fp32().with_fully_quantized(est)
    }

    /// Gradient-quantization-only study (paper Table 1).
    pub fn grad_only(est: Estimator) -> Self {
        Self::fp32().with_grad_only(est)
    }

    /// Activation-quantization-only study (paper Table 2).
    pub fn act_only(est: Estimator) -> Self {
        Self::fp32().with_act_only(est)
    }

    // The `with_*` variants re-point the class *estimators* of an
    // existing scheme while preserving everything else (per-class
    // bits/eta/symmetry and site overrides) — what a sweep wants when
    // the base scheme came from user flags.

    /// [`QuantScheme::fully_quantized`] applied to this scheme's
    /// estimators, keeping its bits/eta/sym attrs and overrides.
    pub fn with_fully_quantized(mut self, est: Estimator) -> Self {
        self.gradients.estimator = est;
        self.activations.estimator =
            if est.needs_search() { Estimator::CURRENT } else { est };
        self.weights.estimator =
            if est.enabled() { Estimator::CURRENT } else { Estimator::FP32 };
        self
    }

    /// [`QuantScheme::grad_only`] applied to this scheme's estimators.
    pub fn with_grad_only(mut self, est: Estimator) -> Self {
        self.gradients.estimator = est;
        self.activations.estimator = Estimator::FP32;
        self.weights.estimator = Estimator::FP32;
        self
    }

    /// [`QuantScheme::act_only`] applied to this scheme's estimators.
    pub fn with_act_only(mut self, est: Estimator) -> Self {
        self.activations.estimator = est;
        self.gradients.estimator = Estimator::FP32;
        self.weights.estimator = Estimator::FP32;
        self
    }

    /// Parse the canonical string form; see [`parse`] for the grammar.
    pub fn parse(s: &str) -> Result<Self> {
        parse::parse_scheme(s)
    }

    /// The spec of one tensor class.
    pub fn spec(&self, class: TensorClass) -> &QuantSpec {
        match class {
            TensorClass::Weights => &self.weights,
            TensorClass::Activations => &self.activations,
            TensorClass::Gradients => &self.gradients,
        }
    }

    pub fn spec_mut(&mut self, class: TensorClass) -> &mut QuantSpec {
        match class {
            TensorClass::Weights => &mut self.weights,
            TensorClass::Activations => &mut self.activations,
            TensorClass::Gradients => &mut self.gradients,
        }
    }

    /// Resolve the spec governing one quantizer site: a per-site
    /// override if present, else the class spec.
    pub fn site_spec(&self, class: TensorClass, site: &str) -> QuantSpec {
        self.overrides.get(site).copied().unwrap_or(*self.spec(class))
    }

    /// The per-site overrides, in site-name order.
    pub fn overrides(&self) -> impl Iterator<Item = (&str, &QuantSpec)> {
        self.overrides.iter().map(|(k, v)| (k.as_str(), v))
    }

    // ---- builder --------------------------------------------------------

    /// Set the gradient estimator from a registry key (`"hindsight@pc"`).
    pub fn grad(self, key: &str) -> Result<Self> {
        Ok(self.grad_est(Estimator::parse(key)?))
    }

    /// Set the activation estimator from a registry key.
    pub fn act(self, key: &str) -> Result<Self> {
        Ok(self.act_est(Estimator::parse(key)?))
    }

    /// Set the weight estimator from a registry key (`"current"` to
    /// quantize weights, `"fp32"` to disable).
    pub fn weights(self, key: &str) -> Result<Self> {
        Ok(self.weights_est(Estimator::parse(key)?))
    }

    pub fn grad_est(mut self, est: Estimator) -> Self {
        self.gradients.estimator = est;
        self
    }

    pub fn act_est(mut self, est: Estimator) -> Self {
        self.activations.estimator = est;
        self
    }

    pub fn weights_est(mut self, est: Estimator) -> Self {
        self.weights.estimator = est;
        self
    }

    /// Set one class's bit-width (panics outside [`BITS_RANGE`]; the
    /// string-form parser reports the same constraint as an error).
    pub fn bits(mut self, class: TensorClass, bits: u32) -> Self {
        let spec = self.spec(class).with_bits(bits);
        *self.spec_mut(class) = spec;
        self
    }

    /// Set one class's EMA momentum.
    pub fn eta(mut self, class: TensorClass, eta: f32) -> Self {
        let spec = self.spec(class).with_eta(eta);
        *self.spec_mut(class) = spec;
        self
    }

    /// Set every class's EMA momentum (the legacy global `--eta` knob).
    pub fn eta_all(mut self, eta: f32) -> Self {
        for class in TensorClass::all() {
            let spec = self.spec(class).with_eta(eta);
            *self.spec_mut(class) = spec;
        }
        self
    }

    /// Force a zero-symmetric grid for one class.
    pub fn symmetric(mut self, class: TensorClass, on: bool) -> Self {
        self.spec_mut(class).symmetric = on;
        self
    }

    /// Override one site's spec by quantizer-site name (wins over the
    /// class spec for that site only).  Site names must be single
    /// tokens: no whitespace, `:` or `@`.
    pub fn override_site(mut self, site: &str, spec: QuantSpec) -> Result<Self> {
        parse::validate_site_name(site)?;
        self.overrides.insert(site.to_string(), spec);
        Ok(self)
    }

    /// Override one site's spec from a clause body (`"tqt:8"`).
    pub fn override_site_str(self, site: &str, clause: &str) -> Result<Self> {
        let spec = QuantSpec::parse(clause)?;
        self.override_site(site, spec)
    }

    // ---- derived views --------------------------------------------------

    /// The single `eta` scalar fed to the compiled train graph.  The
    /// graph ABI has one EMA momentum for all in-graph range updates, so
    /// it follows the gradient class (the paper's estimation target);
    /// per-class `eta` differences still apply to all coordinator-side
    /// math (calibration, stateful estimators).
    pub fn graph_eta(&self) -> f32 {
        self.gradients.eta
    }

    /// Filesystem-friendly one-token form of the canonical string
    /// (spaces replaced by `_`), for run tags and sweep labels.
    pub fn tag(&self) -> String {
        self.to_string().replace(' ', "_")
    }
}

impl Default for QuantScheme {
    /// The paper's headline setting ([`QuantScheme::w8a8g8`]).
    fn default() -> Self {
        Self::w8a8g8()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn w8a8g8_matches_the_legacy_fully_quantized_defaults() {
        let s = QuantScheme::w8a8g8();
        assert_eq!(s.gradients.estimator, Estimator::HINDSIGHT);
        assert_eq!(s.activations.estimator, Estimator::HINDSIGHT);
        assert_eq!(s.weights.estimator, Estimator::CURRENT);
        assert!(s.weights.enabled());
        for class in TensorClass::all() {
            assert_eq!(s.spec(class).bits, 8);
            assert_eq!(s.spec(class).eta, DEFAULT_ETA);
            assert!(!s.spec(class).symmetric);
        }
        assert_eq!(s, QuantScheme::default());
        assert_eq!(s, QuantScheme::fully_quantized(Estimator::HINDSIGHT));
    }

    #[test]
    fn fully_quantized_applies_the_search_and_fp32_fallbacks() {
        // search estimators quantize gradients; acts fall back to current
        let d = QuantScheme::fully_quantized(Estimator::DSGC);
        assert_eq!(d.gradients.estimator, Estimator::DSGC);
        assert_eq!(d.activations.estimator, Estimator::CURRENT);
        assert!(d.weights.enabled());
        // fp32 disables weight quantization too
        let f = QuantScheme::fully_quantized(Estimator::FP32);
        assert!(!f.weights.enabled());
        assert!(!f.activations.enabled());
    }

    #[test]
    fn grad_and_act_only_studies() {
        let g = QuantScheme::grad_only(Estimator::DSGC);
        assert_eq!(g.gradients.estimator, Estimator::DSGC);
        assert!(!g.activations.enabled());
        assert!(!g.weights.enabled());
        let a = QuantScheme::act_only(Estimator::RUNNING);
        assert_eq!(a.activations.estimator, Estimator::RUNNING);
        assert!(!a.gradients.enabled());
    }

    #[test]
    fn builder_chain_from_the_issue() {
        let s = QuantScheme::w8a8g8()
            .grad("hindsight@pc")
            .unwrap()
            .bits(TensorClass::Gradients, 4);
        assert!(s.gradients.is_per_channel());
        assert_eq!(s.gradients.bits, 4);
        assert_eq!(s.activations.bits, 8);
        assert_eq!(s.to_string(), "w:current:8 a:hindsight:8 g:hindsight@pc:4");
    }

    #[test]
    fn site_overrides_win_for_their_site_only() {
        let s = QuantScheme::w8a8g8()
            .override_site_str("fc1_g", "tqt:6")
            .unwrap();
        let o = s.site_spec(TensorClass::Gradients, "fc1_g");
        assert_eq!(o.estimator.key(), "tqt");
        assert_eq!(o.bits, 6);
        let base = s.site_spec(TensorClass::Gradients, "fc0_g");
        assert_eq!(base.estimator, Estimator::HINDSIGHT);
        assert_eq!(s.overrides().count(), 1);
    }

    #[test]
    fn bad_site_names_are_rejected() {
        let spec = QuantSpec::new(Estimator::HINDSIGHT);
        assert!(QuantScheme::w8a8g8().override_site("has space", spec).is_err());
        assert!(QuantScheme::w8a8g8().override_site("has:colon", spec).is_err());
        assert!(QuantScheme::w8a8g8().override_site("", spec).is_err());
    }

    #[test]
    #[should_panic(expected = "outside the supported")]
    fn builder_rejects_out_of_range_bits() {
        let _ = QuantScheme::w8a8g8().bits(TensorClass::Gradients, 1);
    }

    #[test]
    fn eta_flows_per_class_and_graph_eta_follows_gradients() {
        let s = QuantScheme::w8a8g8()
            .eta(TensorClass::Activations, 0.5)
            .eta(TensorClass::Gradients, 0.75);
        assert_eq!(s.activations.eta, 0.5);
        assert_eq!(s.graph_eta(), 0.75);
        let all = QuantScheme::w8a8g8().eta_all(0.25);
        for class in TensorClass::all() {
            assert_eq!(all.spec(class).eta, 0.25);
        }
    }

    #[test]
    fn spec_instantiation_honors_granularity_and_params() {
        let pc = QuantSpec::new(Estimator::parse("hindsight@pc").unwrap());
        assert_eq!(pc.instantiate_site(3).n_rows(), 3);
        let pt = QuantSpec::new(Estimator::HINDSIGHT);
        assert_eq!(pt.instantiate_site(3).n_rows(), 1);
        assert_eq!(pt.params(), SiteParams { bits: 8, eta: DEFAULT_ETA });
    }

    #[test]
    fn tag_is_single_token() {
        let tag = QuantScheme::w8a8g8().tag();
        assert!(!tag.contains(' '), "{tag}");
        assert!(tag.contains("g:hindsight:8"), "{tag}");
    }
}

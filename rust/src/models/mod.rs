//! Architecture geometry zoo.
//!
//! Full-size ImageNet-scale layer graphs for ResNet18, VGG16,
//! MobileNetV2 and the ViT-S/16 / DeiT-T/16 transformers — used by the
//! traffic simulator (Table 5, the memory_report example and the
//! fig4/table5 benches).  The *training* variants are defined on the
//! Python side and described by the artifact manifest; this module is
//! about the memory-movement analysis, which the paper performs at full
//! ImageNet scale.  Everything is a [`LayerGeom`] graph: the conv nets
//! are pure `Conv2d` chains, the transformers mix a conv patch embed
//! with `Attention` and `Linear` layers (heads are the `@pc`
//! channel-group axis).

use crate::simulator::LayerGeom;

/// All conv layers of ResNet18 at 224x224 input (output-map sizes).
pub fn resnet18() -> Vec<LayerGeom> {
    let mut v = vec![LayerGeom::conv("conv1 7x7/2", 3, 64, 7, 112, 112, false)];
    // layer1: 2 basic blocks @ 64ch, 56x56
    for i in 0..4 {
        v.push(LayerGeom::conv(
            match i {
                0 => "layer1 3x3 a",
                1 => "layer1 3x3 b",
                2 => "layer1 3x3 c",
                _ => "layer1 3x3 d",
            },
            64,
            64,
            3,
            56,
            56,
            false,
        ));
    }
    // layer2: downsample to 128ch, 28x28
    v.push(LayerGeom::conv("layer2 3x3/2", 64, 128, 3, 28, 28, false));
    v.push(LayerGeom::conv("layer2 1x1/2 (sc)", 64, 128, 1, 28, 28, false));
    for _ in 0..3 {
        v.push(LayerGeom::conv("layer2 3x3", 128, 128, 3, 28, 28, false));
    }
    // layer3: 256ch, 14x14
    v.push(LayerGeom::conv("layer3 3x3/2", 128, 256, 3, 14, 14, false));
    v.push(LayerGeom::conv("layer3 1x1/2 (sc)", 128, 256, 1, 14, 14, false));
    for _ in 0..3 {
        v.push(LayerGeom::conv("layer3 3x3", 256, 256, 3, 14, 14, false));
    }
    // layer4: 512ch, 7x7
    v.push(LayerGeom::conv("layer4 3x3/2", 256, 512, 3, 7, 7, false));
    v.push(LayerGeom::conv("layer4 1x1/2 (sc)", 256, 512, 1, 7, 7, false));
    for _ in 0..3 {
        v.push(LayerGeom::conv("layer4 3x3", 512, 512, 3, 7, 7, false));
    }
    v
}

/// All conv layers of VGG16 at 224x224 input.
pub fn vgg16() -> Vec<LayerGeom> {
    let plan: &[(&'static str, u64, u64, u64)] = &[
        ("block1 conv1", 3, 64, 224),
        ("block1 conv2", 64, 64, 224),
        ("block2 conv1", 64, 128, 112),
        ("block2 conv2", 128, 128, 112),
        ("block3 conv1", 128, 256, 56),
        ("block3 conv2", 256, 256, 56),
        ("block3 conv3", 256, 256, 56),
        ("block4 conv1", 256, 512, 28),
        ("block4 conv2", 512, 512, 28),
        ("block4 conv3", 512, 512, 28),
        ("block5 conv1", 512, 512, 14),
        ("block5 conv2", 512, 512, 14),
        ("block5 conv3", 512, 512, 14),
    ];
    plan.iter()
        .map(|&(name, cin, cout, hw)| LayerGeom::conv(name, cin, cout, 3, hw, hw, false))
        .collect()
}

/// All conv layers of MobileNetV2 at 224x224 input (expand/depthwise/
/// project per inverted-residual block, t=6).
pub fn mobilenet_v2() -> Vec<LayerGeom> {
    let mut v = vec![LayerGeom::conv("conv 3x3/2", 3, 32, 3, 112, 112, false)];
    // (t, cin, cout, n, first-stride, in_hw)
    let blocks: &[(u64, u64, u64, u64, u64, u64)] = &[
        (1, 32, 16, 1, 1, 112),
        (6, 16, 24, 2, 2, 112),
        (6, 24, 32, 3, 2, 56),
        (6, 32, 64, 4, 2, 28),
        (6, 64, 96, 3, 1, 14),
        (6, 96, 160, 3, 2, 14),
        (6, 160, 320, 1, 1, 7),
    ];
    for &(t, cin0, cout, n, s0, hw_in) in blocks {
        let mut cin = cin0;
        let mut hw = hw_in;
        for i in 0..n {
            let stride = if i == 0 { s0 } else { 1 };
            let hw_out = hw / stride;
            let mid = cin * t;
            if t != 1 {
                v.push(LayerGeom::conv("expand 1x1", cin, mid, 1, hw, hw, false));
            }
            // depthwise geometry recorded at its *input* resolution, the
            // convention of the paper's Table 5 (96ch DW at 112x112)
            v.push(LayerGeom::conv("dw 3x3", mid, mid, 3, hw, hw, true));
            v.push(LayerGeom::conv("project 1x1", mid, cout, 1, hw_out, hw_out, false));
            cin = cout;
            hw = hw_out;
        }
    }
    v.push(LayerGeom::conv("conv 1x1", 320, 1280, 1, 7, 7, false));
    v
}

/// ViT-style encoder at 224x224 / patch 16: a conv patch embed
/// (16x16/16 -> 14x14 = 196 patches, +1 cls token => t=197), 12 pre-norm
/// blocks of multi-head self-attention + 4x MLP, and a classifier head.
/// `d_model` and `n_heads` select the variant; `head_dim` is 64 in both.
fn vit_like(d_model: u64, n_heads: u64) -> Vec<LayerGeom> {
    const TOKENS: u64 = 197;
    let mut v = vec![LayerGeom::conv(
        "patch-embed 16x16/16",
        3,
        d_model,
        16,
        14,
        14,
        false,
    )];
    for _ in 0..12 {
        v.push(LayerGeom::attention("attn (mhsa)", TOKENS, d_model, n_heads, 64));
        v.push(LayerGeom::linear("mlp fc1", d_model, 4 * d_model, TOKENS));
        v.push(LayerGeom::linear("mlp fc2", 4 * d_model, d_model, TOKENS));
    }
    v.push(LayerGeom::linear("head fc", d_model, 1000, 1));
    v
}

/// ViT-S/16: d=384, 6 heads x 64, 12 blocks (~4.6 GMACs at t=197).
pub fn vit_s16() -> Vec<LayerGeom> {
    vit_like(384, 6)
}

/// DeiT-T/16: d=192, 3 heads x 64, 12 blocks (~1.3 GMACs at t=197).
pub fn deit_t16() -> Vec<LayerGeom> {
    vit_like(192, 3)
}

/// Every workload name [`by_name`] resolves — the single source of
/// truth the CLI error paths and docs enumerate.
pub fn names() -> &'static [&'static str] {
    &["resnet18", "vgg16", "mobilenet_v2", "vit_s16", "deit_t16"]
}

/// Named lookup used by the CLI / memory_report example.
pub fn by_name(name: &str) -> Option<Vec<LayerGeom>> {
    match name {
        "resnet18" => Some(resnet18()),
        "vgg16" => Some(vgg16()),
        "mobilenet_v2" => Some(mobilenet_v2()),
        "vit_s16" => Some(vit_s16()),
        "deit_t16" => Some(deit_t16()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_has_expected_structure() {
        let layers = resnet18();
        // 1 stem + 4*4 basic-block convs + 3 downsample 1x1 = 20
        assert_eq!(layers.len(), 20);
        // paper Table 5 rows exist in the zoo
        assert!(layers
            .iter()
            .filter_map(|l| l.as_conv())
            .any(|g| g.cin == 64 && g.cout == 64 && g.w == 56 && g.k == 3));
        assert!(layers
            .iter()
            .filter_map(|l| l.as_conv())
            .any(|g| g.cin == 256 && g.cout == 256 && g.w == 14 && g.k == 3));
    }

    #[test]
    fn vgg16_has_13_convs() {
        let layers = vgg16();
        assert_eq!(layers.len(), 13);
        assert!(layers.iter().all(|l| l.as_conv().is_some()));
    }

    #[test]
    fn mobilenet_structure() {
        let layers = mobilenet_v2();
        // 17 inverted residual blocks: 16 with expand (3 convs) + 1 without
        // (2 convs) + stem + head = 1 + 16*3 + 2 + 1 = 52
        assert_eq!(layers.len(), 52);
        // paper Table 5's 96-channel 112x112 depthwise exists
        assert!(layers
            .iter()
            .filter_map(|l| l.as_conv())
            .any(|g| g.depthwise && g.cin == 96 && g.w == 112));
        // depthwise layers never mix channels
        for g in layers.iter().filter_map(|l| l.as_conv()) {
            if g.depthwise {
                assert_eq!(g.cin, g.cout);
            }
        }
    }

    #[test]
    fn macs_are_imagenet_scale() {
        let total: u64 = resnet18().iter().map(|g| g.macs()).sum();
        // ResNet18 is ~1.8 GMACs; conv-only accounting lands close
        assert!(total > 1_500_000_000 && total < 2_200_000_000, "{total}");
    }

    #[test]
    fn vit_s16_structure_and_macs() {
        let layers = vit_s16();
        // patch embed + 12 * (attn, fc1, fc2) + head
        assert_eq!(layers.len(), 38);
        let attn: Vec<_> = layers
            .iter()
            .filter(|l| matches!(l, LayerGeom::Attention(_)))
            .collect();
        assert_eq!(attn.len(), 12);
        // every attention block groups ranges by head under @pc
        for a in &attn {
            assert_eq!(a.channels(), 6);
            assert_eq!(a.kind_str(), "attn");
        }
        // ViT-S/16 is ~4.6 GMACs at 224x224 (t=197)
        let total: u64 = layers.iter().map(|g| g.macs()).sum();
        assert!(total > 4_300_000_000 && total < 4_900_000_000, "{total}");
    }

    #[test]
    fn deit_t16_is_the_tiny_variant() {
        let layers = deit_t16();
        assert_eq!(layers.len(), 38);
        // DeiT-T/16 is ~1.3 GMACs
        let total: u64 = layers.iter().map(|g| g.macs()).sum();
        assert!(total > 1_100_000_000 && total < 1_400_000_000, "{total}");
        // 3 heads of 64 at d=192
        assert!(layers
            .iter()
            .any(|l| matches!(l, LayerGeom::Attention(a) if a.n_heads == 3 && a.d_model == 192)));
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("resnet18").is_some());
        assert!(by_name("vit_s16").is_some());
        assert!(by_name("nope").is_none());
        // names() is the source of truth: every listed workload resolves
        for name in names() {
            assert!(by_name(name).is_some(), "{name} listed but unresolvable");
        }
    }
}

//! Synthetic vision datasets — the Tiny ImageNet / ImageNet stand-in
//! (DESIGN.md §3 documents the substitution).
//!
//! `SynthVision` draws, per class, a smooth random "prototype" field
//! (sum of low-frequency 2-D sinusoids per channel) and renders samples
//! as affine-jittered, noise-perturbed views of their class prototype.
//! The task is learnable but non-trivial (classes overlap through jitter
//! and shared frequency bands), produces activation/gradient
//! distributions that drift as training sharpens features, and is fully
//! deterministic from a seed — which is what the paper's range-estimator
//! comparison actually needs from the data.

use crate::util::rng::Pcg32;

/// Dataset configuration.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub n_classes: usize,
    pub hw: usize,
    pub channels: usize,
    pub n_train: usize,
    pub n_val: usize,
    pub seed: u64,
    /// per-sample additive noise amplitude
    pub noise: f32,
    /// max translation jitter in pixels
    pub jitter: usize,
}

impl SynthSpec {
    /// Defaults matched to the table-bench artifacts (32x32x3, 16-way).
    pub fn tiny(n_classes: usize, hw: usize, seed: u64) -> Self {
        Self {
            n_classes,
            hw,
            channels: 3,
            n_train: 4096,
            n_val: 1024,
            seed,
            noise: 0.30,
            jitter: 2,
        }
    }
}

/// One class's prototype: per-channel sinusoid mixture coefficients.
#[derive(Debug, Clone)]
struct Prototype {
    // per channel: (ax, ay, phase, amplitude) x n_waves
    waves: Vec<Vec<(f32, f32, f32, f32)>>,
    // per-channel DC bias (class colour signature; anchors same-class
    // correlation under affine jitter)
    bias: Vec<f32>,
}

/// Deterministic synthetic dataset (images in NHWC, labels in i32).
#[derive(Debug, Clone)]
pub struct SynthVision {
    pub spec: SynthSpec,
    protos: Vec<Prototype>,
}

impl SynthVision {
    pub fn new(spec: SynthSpec) -> Self {
        let n_waves = 4;
        let protos = (0..spec.n_classes)
            .map(|c| {
                let mut rng = Pcg32::fold(spec.seed, "proto", c as u64);
                let waves = (0..spec.channels)
                    .map(|_| {
                        (0..n_waves)
                            .map(|_| {
                                (
                                    rng.range(0.5, 2.2), // x frequency
                                    rng.range(0.5, 2.2), // y frequency
                                    rng.range(0.0, std::f32::consts::TAU),
                                    rng.range(0.4, 1.0), // amplitude
                                )
                            })
                            .collect()
                    })
                    .collect();
                let bias = (0..spec.channels).map(|_| rng.range(-0.9, 0.9)).collect();
                Prototype { waves, bias }
            })
            .collect();
        Self { spec, protos }
    }

    /// Total samples in the split.
    pub fn len(&self, val: bool) -> usize {
        if val {
            self.spec.n_val
        } else {
            self.spec.n_train
        }
    }

    pub fn is_empty(&self) -> bool {
        self.spec.n_train == 0
    }

    /// Label of sample `idx` (stratified round-robin).
    pub fn label(&self, idx: usize) -> i32 {
        (idx % self.spec.n_classes) as i32
    }

    /// Render sample `idx` of the split into `out` (len hw*hw*c, NHWC).
    pub fn render(&self, idx: usize, val: bool, out: &mut [f32]) {
        let s = &self.spec;
        assert_eq!(out.len(), s.hw * s.hw * s.channels);
        let split = if val { 1u64 << 40 } else { 0 };
        let mut rng = Pcg32::fold(s.seed, "sample", split + idx as u64);
        let class = self.label(idx) as usize;
        let proto = &self.protos[class];

        // affine jitter: translation + small scale
        let dx = rng.range(-(s.jitter as f32), s.jitter as f32);
        let dy = rng.range(-(s.jitter as f32), s.jitter as f32);
        let zoom = rng.range(0.93, 1.07);
        let gain = rng.range(0.8, 1.2);

        let inv = 1.0 / s.hw as f32;
        for y in 0..s.hw {
            for x in 0..s.hw {
                let u = ((x as f32 + dx) * zoom) * inv * std::f32::consts::TAU;
                let v = ((y as f32 + dy) * zoom) * inv * std::f32::consts::TAU;
                for c in 0..s.channels {
                    let mut val = 0.0;
                    for &(fx, fy, ph, amp) in &proto.waves[c] {
                        val += amp * (fx * u + fy * v + ph).sin();
                    }
                    let noise = rng.normal() * s.noise;
                    out[(y * s.hw + x) * s.channels + c] =
                        gain * (val + proto.bias[c]) + noise;
                }
            }
        }
    }

    /// Fill a whole batch; returns labels. `epoch_perm` supplies the
    /// shuffled order (see [`Batcher`]).
    pub fn fill_batch(
        &self,
        indices: &[usize],
        val: bool,
        x_out: &mut [f32],
        y_out: &mut [i32],
    ) {
        let s = &self.spec;
        let img = s.hw * s.hw * s.channels;
        assert_eq!(x_out.len(), indices.len() * img);
        assert_eq!(y_out.len(), indices.len());
        for (bi, &idx) in indices.iter().enumerate() {
            self.render(idx, val, &mut x_out[bi * img..(bi + 1) * img]);
            y_out[bi] = self.label(idx);
        }
    }
}

/// Epoch-shuffled batch index iterator.
#[derive(Debug)]
pub struct Batcher {
    n: usize,
    batch: usize,
    perm: Vec<usize>,
    cursor: usize,
    epoch: u64,
    seed: u64,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        let mut b = Self {
            n,
            batch,
            perm: (0..n).collect(),
            cursor: 0,
            epoch: 0,
            seed,
        };
        b.reshuffle();
        b
    }

    fn reshuffle(&mut self) {
        let mut rng = Pcg32::fold(self.seed, "batcher", self.epoch);
        rng.shuffle(&mut self.perm);
    }

    /// Next batch of indices (wraps across epochs, reshuffling).
    pub fn next_batch(&mut self) -> &[usize] {
        if self.cursor + self.batch > self.n {
            self.epoch += 1;
            self.cursor = 0;
            self.reshuffle();
        }
        let s = &self.perm[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        s
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> SynthVision {
        SynthVision::new(SynthSpec::tiny(8, 16, 42))
    }

    #[test]
    fn deterministic_rendering() {
        let d = ds();
        let mut a = vec![0f32; 16 * 16 * 3];
        let mut b = vec![0f32; 16 * 16 * 3];
        d.render(5, false, &mut a);
        d.render(5, false, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn train_val_differ_and_classes_differ() {
        let d = ds();
        let mut a = vec![0f32; 16 * 16 * 3];
        let mut b = vec![0f32; 16 * 16 * 3];
        d.render(5, false, &mut a);
        d.render(5, true, &mut b);
        assert_ne!(a, b);
        // same class, different sample index: similar but not equal
        d.render(5, false, &mut a);
        d.render(13, false, &mut b); // 13 % 8 == 5
        assert_eq!(d.label(5), d.label(13));
        assert_ne!(a, b);
    }

    #[test]
    fn class_signal_exceeds_noise() {
        // same-class samples must correlate more than cross-class ones
        let d = ds();
        let img = 16 * 16 * 3;
        let n_per = 8;
        let sample = |idx: usize| {
            let mut v = vec![0f32; img];
            d.render(idx, false, &mut v);
            v
        };
        let cos = crate::quant::cosine_similarity;
        let mut same = 0.0;
        let mut diff = 0.0;
        let mut cnt = 0;
        for i in 0..n_per {
            let a = sample(i * 8); // class 0
            let b = sample((i + 1) * 8); // class 0
            let c = sample(i * 8 + 1); // class 1
            same += cos(&a, &b);
            diff += cos(&a, &c);
            cnt += 1;
        }
        assert!(
            same / cnt as f32 > diff / cnt as f32 + 0.2,
            "same {} diff {}",
            same / cnt as f32,
            diff / cnt as f32
        );
    }

    #[test]
    fn batcher_covers_all_indices_each_epoch() {
        let mut b = Batcher::new(100, 10, 1);
        let mut seen = vec![0; 100];
        for _ in 0..10 {
            for &i in b.next_batch().to_vec().iter() {
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
        let _ = b.next_batch();
        assert_eq!(b.epoch(), 1);
    }

    #[test]
    fn fill_batch_layout() {
        let d = ds();
        let img = 16 * 16 * 3;
        let idx = [0usize, 1, 2];
        let mut x = vec![0f32; 3 * img];
        let mut y = vec![0i32; 3];
        d.fill_batch(&idx, false, &mut x, &mut y);
        assert_eq!(y, vec![0, 1, 2]);
        let mut single = vec![0f32; img];
        d.render(1, false, &mut single);
        assert_eq!(&x[img..2 * img], &single[..]);
    }

    #[test]
    fn values_are_bounded() {
        let d = ds();
        let mut v = vec![0f32; 16 * 16 * 3];
        for i in 0..16 {
            d.render(i, false, &mut v);
            assert!(v.iter().all(|x| x.is_finite() && x.abs() < 10.0));
        }
    }
}

//! `hindsight` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train       train one model/estimator configuration end to end
//!   sweep       multi-seed, multi-estimator table rows (paper Tables 1-4)
//!               and parallel scheme grids (--grid/--workers/--resume)
//!   estimators  list the range-estimator registry
//!   mem-report  static-vs-dynamic memory traffic (paper Table 5 / Sec. 6)
//!   inspect     print a model's manifest ABI and quantizer sites
//!   bench-step  time the train-step hot path for one model
//!   bench-report  render the kernel-perf trajectory (BENCH_kernels.json)
//!               as Markdown speedup tables and gate on a speedup floor
//!   serve       long-running sweep service: HTTP job submission over the
//!               grid executor + run store, sharded via --shard i/N
//!   runs        inspect the run store: list cached cells, --gc prunes
//!               skewed/mismatched files, --verify re-reads every cell
//!
//! Quantization policy is a typed scheme: one clause per tensor class
//! (`w:` weights, `a:` activations, `g:` gradients), each naming a
//! registry estimator (append `@pc` for per-channel granularity), a
//! bit-width, and optional `eta=`/`sym` attrs — `--scheme
//! "w:current:8 a:hindsight:8 g:hindsight@pc:4"`.  The legacy flags
//! (`--grad-est`, `--act-est`, `--quant-weights`, `--eta`) still work
//! and rewrite the scheme.  `hindsight estimators` prints the registry
//! and the full scheme grammar.
//!
//! Kernel backends: every fused quantization kernel (the simulator's
//! static stores, DSGC probes, estimator searches, sweep workers)
//! dispatches through one process-wide backend — `--kernel-backend
//! scalar|simd|parallel|auto` beats the `HINDSIGHT_KERNEL_BACKEND` env
//! var, which beats auto-detection.  `auto` is *measured*: the trainer's
//! calibration pass times every backend on each quantizer site's actual
//! tensor shape and pins the largest site's winner; paths that never
//! calibrate fall back to the core-count heuristic on first kernel use.
//! All backends are bit-identical; the choice is purely about speed.
//!
//! Scheme grids: `sweep --grid` takes a scheme template with shell-style
//! alternations, crossed with `--seeds` (ranges are inclusive), run on
//! `--workers` threads with deterministic (grid-index) output ordering.
//! Completed cells persist in the run store (`--store`, default `runs/`)
//! so an interrupted grid resumes where it stopped; `--no-cache` forces
//! every cell to re-run.
//!
//! Examples:
//!   hindsight train --model cnn --steps 300 --grad-est hindsight
//!   hindsight train --model cnn --scheme "w:current:8 a:hindsight:8 g:hindsight:8"
//!   hindsight train --model cnn --grad-est hindsight@pc
//!   hindsight sweep --model resnet_tiny --mode grad --seeds 1,2,3
//!   hindsight sweep --model cnn --estimators hindsight,hindsight@pc,tqt
//!   hindsight sweep --model cnn --grid "g:{hindsight,current,tqt}@{pt,pc}:8" \
//!       --seeds 1..5 --workers 4
//!   hindsight mem-report --network mobilenet_v2
//!   hindsight mem-report --network vit_s16 --scheme "w:current:8 a:hindsight:8 g:hindsight@pc:4"

use anyhow::{bail, Result};

use hindsight::coordinator::{
    grid_rows, parse_seeds, run_grid, sweep_row, CellOutcome, Estimator, GridOptions, GridSpec,
    QuantScheme, RunStore, Schedule, TrainConfig, Trainer,
};
use hindsight::models;
use hindsight::runtime::Engine;
use hindsight::scheme::parse::syntax_help;
use hindsight::simulator::backward::{self, BwdBits};
use hindsight::simulator::traffic::{self, BitWidths};
use hindsight::util::bench::Table;
use hindsight::util::cli::Args;
use hindsight::util::logging;

fn main() {
    logging::init();
    let args = Args::from_env();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(mut args: Args) -> Result<()> {
    // resolve the kernel backend before any kernel can run: the CLI
    // flag beats HINDSIGHT_KERNEL_BACKEND, which beats auto-detection
    if let Some(v) = args.get("kernel-backend") {
        if v.trim().eq_ignore_ascii_case("auto") {
            // don't pin anything yet: the trainer's calibration pass
            // autotunes each site's actual shape and adopts the measured
            // winner; paths that never calibrate resolve lazily (env var,
            // then the core-count heuristic) on first kernel use
            hindsight::quant::kernel::request_measured_auto();
        } else {
            let kind = hindsight::quant::kernel::KernelBackend::parse(&v)
                .map_err(|e| anyhow::anyhow!("--kernel-backend: {e}"))?;
            hindsight::quant::kernel::select_backend(kind)
                .map_err(|e| anyhow::anyhow!("--kernel-backend: {e}"))?;
        }
    }
    match args.subcommand.clone().as_deref() {
        Some("train") => cmd_train(&mut args),
        Some("sweep") => cmd_sweep(&mut args),
        Some("estimators") => cmd_estimators(&mut args),
        Some("mem-report") => cmd_mem_report(&mut args),
        Some("inspect") => cmd_inspect(&mut args),
        Some("bench-step") => cmd_bench_step(&mut args),
        Some("bench-report") => cmd_bench_report(&mut args),
        Some("serve") => cmd_serve(&mut args),
        Some("runs") => cmd_runs(&mut args),
        Some(other) => bail!("unknown subcommand '{other}'"),
        None => {
            eprintln!(
                "usage: hindsight <train|sweep|estimators|mem-report|inspect|bench-step|bench-report|serve|runs> [--flags]\n\
                 quantization policy: --scheme \"w:current:8 a:hindsight:8 g:hindsight@pc:4\"\n\
                 scheme grids: sweep --grid \"g:{{hindsight,current}}@{{pt,pc}}:8\" --seeds 1..5 \
                 --workers 4 [--store runs] [--no-cache]\n\
                 kernel backend: --kernel-backend scalar|simd|parallel|auto \
                 (default: auto; env HINDSIGHT_KERNEL_BACKEND; auto = measured per-site pick)\n\
                 bench gate: bench-report [--json BENCH_kernels.json] [--floor 1.0] [--kernel NAME]\n\
                 sweep service: serve [--addr 127.0.0.1:8080] [--workers 2] [--store runs] \
                 [--shard i/N] [--synthetic] [--poll-ms 500] [--queue-cap N]\n\
                 store inspection: runs [--store runs] [--gc] [--verify]\n\
                 {}",
                syntax_help()
            );
            Ok(())
        }
    }
}

fn parse_cfg(args: &mut Args) -> Result<TrainConfig> {
    let model = args.str_or("model", "cnn");
    // the geometry zoo (mem-report workloads) and the trainable manifest
    // models are different namespaces — catch the mixup early with a
    // pointer to the right subcommand instead of a manifest-lookup error
    if models::names().contains(&model.as_str()) {
        bail!(
            "'{model}' is a memory-analysis workload, not a trainable model — \
             use `hindsight mem-report --network {model}`; trainable models come \
             from the artifact manifest (see `hindsight inspect`)"
        );
    }
    let mut cfg = TrainConfig::new(&model);
    cfg.steps = args.u64_or("steps", cfg.steps);
    // the typed scheme is the source of truth; the legacy flags rewrite
    // it field by field so existing invocations keep working
    let mut scheme = match args.get("scheme") {
        Some(s) => QuantScheme::parse(&s)?,
        None => QuantScheme::w8a8g8(),
    };
    if let Some(g) = args.get("grad-est") {
        scheme = scheme.grad(&g)?;
    }
    if let Some(a) = args.get("act-est") {
        scheme = scheme.act(&a)?;
    }
    if let Some(w) = args.get("quant-weights") {
        let on = hindsight::util::cli::parse_bool(&w);
        scheme = scheme.weights_est(if on { Estimator::CURRENT } else { Estimator::FP32 });
    }
    if let Some(e) = args.get("eta") {
        let eta = hindsight::scheme::parse::parse_eta(&e)
            .map_err(|err| anyhow::anyhow!("--eta: {err:#}"))?;
        scheme = scheme.eta_all(eta);
    }
    cfg.scheme = scheme;
    cfg.lr = args.f32_or("lr", cfg.lr);
    cfg.schedule = Schedule::parse(&args.str_or("schedule", "step"))?;
    cfg.weight_decay = args.f32_or("weight-decay", cfg.weight_decay);
    cfg.calib_batches = args.usize_or("calib-batches", cfg.calib_batches);
    cfg.dsgc_period = args.u64_or("dsgc-period", cfg.dsgc_period);
    cfg.dsgc_iters = args.usize_or("dsgc-iters", cfg.dsgc_iters as usize) as u32;
    cfg.seed = args.u64_or("seed", cfg.seed);
    cfg.n_train = args.usize_or("n-train", cfg.n_train);
    cfg.n_val = args.usize_or("n-val", cfg.n_val);
    cfg.eval_every = args.u64_or("eval-every", cfg.eval_every);
    cfg.log_every = args.u64_or("log-every", cfg.log_every);
    Ok(cfg)
}

fn cmd_train(args: &mut Args) -> Result<()> {
    let cfg = parse_cfg(args)?;
    let csv = args.get("csv");
    args.finish().map_err(anyhow::Error::msg)?;
    let engine = Engine::new()?;
    let record = Trainer::new(&engine, cfg)?.run()?;
    println!(
        "final: val acc {:.2}%  tail loss {:.4}  {:.1}s train ({:.0} ms/step)",
        record.final_val_acc(),
        record.tail_loss(10),
        record.train_seconds,
        record.train_seconds / record.steps.len().max(1) as f64 * 1e3,
    );
    if let Some(path) = csv {
        record.write_csv(&path)?;
        println!("loss curve written to {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &mut Args) -> Result<()> {
    let base = parse_cfg(args)?;
    let seeds = parse_seeds(&args.str_or("seeds", "1,2,3"))
        .map_err(|e| anyhow::anyhow!("--seeds: {e:#}"))?;
    if let Some(template) = args.get("grid") {
        return cmd_sweep_grid(args, base, &template, &seeds);
    }
    for flag in ["workers", "resume", "no-cache", "store"] {
        if args.get(flag).is_some() {
            bail!("--{flag} applies to grid sweeps — pass a --grid template");
        }
    }
    let mode = args.str_or("mode", "full"); // grad | act | full
    // default: the whole registry (the paper's five plus the literature
    // additions)
    let default_keys = Estimator::keys();
    let estimators = args.list_or("estimators", &default_keys);
    args.finish().map_err(anyhow::Error::msg)?;

    let engine = Engine::new()?;
    let mut table = Table::new(
        &format!(
            "{} on SynthTiny ({} mode, {} seeds)",
            base.model,
            mode,
            seeds.len()
        ),
        &["Method", "Static", "Val. Acc. (%)", "ms/step"],
    );
    for est_name in &estimators {
        let est = Estimator::parse(est_name)?;
        if est.needs_search() && mode == "act" {
            continue; // search estimators apply to gradients only
        }
        let cfg = match mode.as_str() {
            "grad" => base.clone().grad_only(est),
            "act" => base.clone().act_only(est),
            // fully_quantized applies the search-estimator act fallback
            "full" => base.clone().fully_quantized(est),
            other => bail!("unknown --mode '{other}' (grad|act|full)"),
        };
        // labels carry the parseable scheme-clause form (key + suffix)
        let label = est.spec();
        let out = sweep_row(&engine, &cfg, &label, &seeds)?;
        table.row(&[
            label,
            if est.enabled() {
                if est.is_static() {
                    "yes".into()
                } else {
                    "no".into()
                }
            } else {
                "n.a.".into()
            },
            out.cell(),
            format!("{:.0}", out.sec_per_step * 1e3),
        ]);
    }
    table.print();
    Ok(())
}

/// `sweep --grid`: expand the scheme template × seeds into cells, run
/// them on the work-queue executor against the resumable run store, and
/// print one aggregate row per scheme in grid order.
fn cmd_sweep_grid(
    args: &mut Args,
    base: TrainConfig,
    template: &str,
    seeds: &[u64],
) -> Result<()> {
    let workers = args.usize_or("workers", 1).max(1);
    let store_dir = args.str_or("store", "runs");
    // cells are cached by default; --no-cache forces re-execution
    // (completed cells still write through).  --resume is the explicit
    // spelling of the default, kept so scripts can state their intent.
    let resume = args.bool_or("resume", true);
    let no_cache = args.bool_or("no-cache", false);
    args.finish().map_err(anyhow::Error::msg)?;

    let spec = GridSpec::new(template, seeds)?;
    let cells = spec.expand(&base);
    println!(
        "grid: {} scheme(s) x {} seed(s) = {} cells, {workers} worker(s), store {store_dir}/",
        spec.schemes().len(),
        spec.seeds().len(),
        cells.len(),
    );
    let opts = GridOptions {
        workers,
        store: Some(RunStore::open(&store_dir)?),
        use_cache: resume && !no_cache,
        fail_fast: false,
    };
    let runs = run_grid(&cells, &opts);

    let mut table = Table::new(
        &format!("{} scheme grid ({} seeds)", base.model, seeds.len()),
        &["Scheme", "Val. Acc. (%)", "ms/step", "Cells"],
    );
    let rows = grid_rows(&runs);
    for (row, scheme) in rows.iter().zip(spec.schemes()) {
        let canon = scheme.to_string();
        let per_row = runs.iter().filter(|r| r.key.scheme == canon);
        let (mut ran, mut cached, mut failed) = (0, 0, 0);
        for r in per_row {
            match r.outcome {
                CellOutcome::Ran(_) => ran += 1,
                CellOutcome::Cached(_) => cached += 1,
                CellOutcome::Failed(_) => failed += 1,
            }
        }
        table.row(&[
            row.label.clone(),
            if row.runs.is_empty() {
                "failed".into()
            } else {
                row.cell()
            },
            format!("{:.0}", row.sec_per_step * 1e3),
            format!("{ran} ran / {cached} cached / {failed} failed"),
        ]);
    }
    table.print();
    let s = hindsight::coordinator::executor::summarize(&runs);
    println!(
        "grid complete: {} ran, {} cached, {} failed ({} cells in {}/)",
        s.ran,
        s.cached,
        s.failed,
        runs.len(),
        store_dir
    );
    for r in runs.iter().filter(|r| r.outcome.is_failed()) {
        if let CellOutcome::Failed(e) = &r.outcome {
            eprintln!("  cell {} ({}): {e}", r.index, r.label);
        }
    }
    Ok(())
}

fn cmd_estimators(args: &mut Args) -> Result<()> {
    args.finish().map_err(anyhow::Error::msg)?;
    fn yn(b: bool) -> String {
        let s = if b { "yes" } else { "no" };
        s.to_string()
    }
    let mut table = Table::new(
        "Range-estimator registry",
        &["Key", "Method", "Static", "Quantizes", "Needs search", "Calibrates"],
    );
    for est in Estimator::all() {
        table.row(&[
            est.key().to_string(),
            est.name().to_string(),
            yn(est.is_static()),
            yn(est.enabled()),
            yn(est.needs_search()),
            yn(est.stateful()),
        ]);
    }
    table.print();
    println!(
        "granularity: append '@pc' to any key (e.g. 'hindsight@pc') for \
         per-channel ranges — one row per channel group, any estimator."
    );
    println!(
        "schemes: compose per-tensor-class policies with --scheme; \
         per-site overrides use '@<site>:<spec>' clauses.\n{}",
        syntax_help()
    );
    Ok(())
}

fn cmd_mem_report(args: &mut Args) -> Result<()> {
    let network = args.str_or("network", "table5");
    // a scheme sets the per-class datapath widths; the explicit bit
    // flags override individual fields on top.  A scheme also switches
    // on the backward-pass table, where its gradient clause matters.
    let scheme = args.get("scheme").map(|s| QuantScheme::parse(&s)).transpose()?;
    let base = scheme.as_ref().map(BitWidths::from_scheme).unwrap_or_default();
    let b = BitWidths {
        b_w: args.usize_or("bits-w", base.b_w as usize) as u64,
        b_a: args.usize_or("bits-a", base.b_a as usize) as u64,
        b_acc: args.usize_or("bits-acc", base.b_acc as usize) as u64,
    };
    args.finish().map_err(anyhow::Error::msg)?;

    let layers = if network == "table5" {
        traffic::table5_layers()
    } else {
        models::by_name(&network).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown network '{network}' (table5|{})",
                models::names().join("|")
            )
        })?
    };
    let mut table = Table::new(
        &format!("Memory movement, static vs dynamic quantization ({network})"),
        &["Layer", "Kind", "In", "Out", "Shape", "Static", "Dynamic", "Delta"],
    );
    let mut tot_s = 0u64;
    let mut tot_d = 0u64;
    for g in &layers {
        let c = traffic::compare(g, b);
        tot_s += c.static_bits;
        tot_d += c.dynamic_bits;
        table.row(&[
            g.name().to_string(),
            g.kind_str().to_string(),
            g.fan_in().to_string(),
            g.fan_out().to_string(),
            g.spatial(),
            format!("{:.0} KB", c.static_kb()),
            format!("{:.0} KB", c.dynamic_kb()),
            format!("+{:.0}%", c.delta_percent()),
        ]);
    }
    table.row(&[
        "TOTAL".into(),
        "".into(),
        "".into(),
        "".into(),
        "".into(),
        format!("{:.0} KB", tot_s as f64 / 8.0 / 1024.0),
        format!("{:.0} KB", tot_d as f64 / 8.0 / 1024.0),
        format!("+{:.0}%", (tot_d as f64 / tot_s as f64 - 1.0) * 100.0),
    ]);
    table.print();

    // under a scheme, the gradient clause drives the backward pass —
    // report it so `g:<bits>` visibly changes the numbers.  The
    // explicit bit flags already resolved into `b` apply here too, so
    // forward and backward bill the same datapath.
    if let Some(scheme) = &scheme {
        let bb = BwdBits {
            b_g: BwdBits::from_scheme(scheme).b_g,
            b_a: b.b_a,
            b_w: b.b_w,
            b_acc: b.b_acc,
        };
        let mut bt = Table::new(
            &format!("Backward pass under scheme (G at {} bits)", bb.b_g),
            &["Layer", "Static", "Dynamic", "Delta"],
        );
        let mut bs = 0u64;
        let mut bd = 0u64;
        for g in &layers {
            let c = backward::bwd_compare(g, bb);
            bs += c.static_bits;
            bd += c.dynamic_bits;
            bt.row(&[
                g.name().to_string(),
                format!("{:.0} KB", c.static_kb()),
                format!("{:.0} KB", c.dynamic_kb()),
                format!("+{:.0}%", c.delta_percent()),
            ]);
        }
        bt.row(&[
            "TOTAL".into(),
            format!("{:.0} KB", bs as f64 / 8.0 / 1024.0),
            format!("{:.0} KB", bd as f64 / 8.0 / 1024.0),
            format!("+{:.0}%", (bd as f64 / bs as f64 - 1.0) * 100.0),
        ]);
        bt.print();
        let step_ratio = (tot_d + bd) as f64 / (tot_s + bs) as f64;
        println!("training step (fwd + bwd) dynamic/static ratio: {step_ratio:.2}x");
    }
    Ok(())
}

fn cmd_inspect(args: &mut Args) -> Result<()> {
    let model = args.str_or("model", "cnn");
    args.finish().map_err(anyhow::Error::msg)?;
    let engine = Engine::new()?;
    let spec = engine.manifest.model(&model)?;
    println!(
        "model {} — {} params in {} leaves, batch {}, input {:?}, {} classes, pallas={}",
        spec.name,
        spec.n_params,
        spec.params.len(),
        spec.batch_size,
        spec.input_shape,
        spec.n_classes,
        spec.pallas,
    );
    let mut t = Table::new(
        "Quantizer sites (Fig. 1 wiring)",
        &["#", "Site", "Kind", "Feature shape"],
    );
    for s in &spec.sites {
        t.row(&[
            s.index.to_string(),
            s.name.clone(),
            format!("{:?}", s.kind),
            format!("{:?}", s.feature_shape),
        ]);
    }
    t.print();
    let mut g = Table::new("Graphs", &["Graph", "Inputs", "Outputs", "File"]);
    for (name, spec) in &spec.graphs {
        g.row(&[
            name.clone(),
            spec.inputs.len().to_string(),
            spec.outputs.len().to_string(),
            spec.file.clone(),
        ]);
    }
    g.print();
    Ok(())
}

fn cmd_bench_step(args: &mut Args) -> Result<()> {
    let mut cfg = parse_cfg(args)?;
    let iters = args.u64_or("iters", 20);
    cfg.steps = iters;
    cfg.calib_batches = 0;
    args.finish().map_err(anyhow::Error::msg)?;
    let engine = Engine::new()?;
    let mut trainer = Trainer::new(&engine, cfg.clone())?;
    for _ in 0..3 {
        trainer.train_step()?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        trainer.train_step()?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let es = engine.stats();
    println!(
        "{}: {:.1} ms/step over {iters} steps (graph execute {:.1} ms, marshal {:.2} ms per call) \
         [kernel backend: {}]",
        cfg.model,
        dt / iters as f64 * 1e3,
        es.execute_seconds / es.executions as f64 * 1e3,
        es.marshal_seconds / es.executions as f64 * 1e3,
        hindsight::quant::kernel::backend(),
    );
    Ok(())
}

/// One speedup record pulled out of the trajectory file (records
/// without a `kernel`/`speedup` pair — grid-sweep smoke rows — are
/// reporting-only and skipped).
struct BenchRec {
    kernel: String,
    backend: String,
    bits: usize,
    elems: usize,
    speedup: f64,
    autotune: bool,
}

/// `bench-report`: render the kernel-perf trajectory as Markdown
/// speedup tables (per backend, per bit-width, autotune picks) and gate
/// on a speedup floor — the CI regression gate fails the run when a
/// kernel shape's best backend no longer beats scalar by `--floor`.
fn cmd_bench_report(args: &mut Args) -> Result<()> {
    use hindsight::util::json;
    use std::collections::BTreeMap;

    let default_path = std::env::var("HINDSIGHT_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_kernels.json".into());
    let path = args.str_or("json", &default_path);
    let floor: f64 = match args.get("floor") {
        Some(s) => s
            .parse()
            .map_err(|_| anyhow::anyhow!("--floor: not a number: '{s}'"))?,
        None => 1.0,
    };
    // --kernel restricts both the tables and the gate to one kernel
    // name, so CI can hold different record families to different
    // floors (e.g. raw_doc_results at 2x, fused kernels at 0.8x)
    let kernel_filter = args.get("kernel");
    args.finish().map_err(anyhow::Error::msg)?;

    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path}: {e:#}"))?;
    let runs = doc.get("runs").and_then(|v| v.as_array()).unwrap_or(&[]);
    let mut recs: Vec<BenchRec> = Vec::new();
    for r in runs {
        let (Some(kernel), Some(speedup)) = (
            r.get("kernel").and_then(|v| v.as_str()),
            r.get("speedup").and_then(|v| v.as_f64()),
        ) else {
            continue;
        };
        recs.push(BenchRec {
            kernel: kernel.to_string(),
            backend: r
                .get("backend")
                .and_then(|v| v.as_str())
                .unwrap_or("-")
                .to_string(),
            bits: r.get("bits").and_then(|v| v.as_usize()).unwrap_or(0),
            elems: r.get("elems").and_then(|v| v.as_usize()).unwrap_or(0),
            speedup,
            autotune: r.get("autotune").and_then(|v| v.as_bool()).unwrap_or(false),
        });
    }
    if let Some(k) = &kernel_filter {
        recs.retain(|r| r.kernel == *k);
    }
    println!(
        "# Kernel bench report\n\n{} speedup record(s) in `{path}` ({} run entries total)\n",
        recs.len(),
        runs.len()
    );
    if recs.is_empty() {
        println!("no kernel speedup records — nothing to gate");
        return Ok(());
    }

    let stats = |v: &[f64]| {
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let max = v.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        (mean, max)
    };
    // per-backend table: how each backend fares vs scalar, per kernel
    let mut by_backend: BTreeMap<(String, String), Vec<f64>> = BTreeMap::new();
    // per-bitwidth table: speedup by code width, per kernel
    let mut by_bits: BTreeMap<(String, usize), Vec<f64>> = BTreeMap::new();
    for r in &recs {
        by_backend
            .entry((r.kernel.clone(), r.backend.clone()))
            .or_default()
            .push(r.speedup);
        by_bits.entry((r.kernel.clone(), r.bits)).or_default().push(r.speedup);
    }
    println!("## Speedup over scalar, per backend\n");
    let mut t = Table::new("", &["Kernel", "Backend", "Records", "Mean", "Max"]);
    for ((kernel, backend), v) in &by_backend {
        let (mean, max) = stats(v);
        t.row(&[
            kernel.clone(),
            backend.clone(),
            v.len().to_string(),
            format!("{mean:.2}x"),
            format!("{max:.2}x"),
        ]);
    }
    println!("{}\n", t.markdown());
    println!("## Speedup over scalar, per bit-width\n");
    let mut t = Table::new("", &["Kernel", "Bits", "Records", "Mean", "Max"]);
    for ((kernel, bits), v) in &by_bits {
        let (mean, max) = stats(v);
        t.row(&[
            kernel.clone(),
            bits.to_string(),
            v.len().to_string(),
            format!("{mean:.2}x"),
            format!("{max:.2}x"),
        ]);
    }
    println!("{}\n", t.markdown());
    let picks: Vec<&BenchRec> = recs.iter().filter(|r| r.autotune).collect();
    if !picks.is_empty() {
        println!("## Autotune picks (measured per-site winners)\n");
        let mut t = Table::new("", &["Kernel", "Winner", "Elems", "Bits", "Speedup"]);
        for r in &picks {
            t.row(&[
                r.kernel.clone(),
                r.backend.clone(),
                r.elems.to_string(),
                r.bits.to_string(),
                format!("{:.2}x", r.speedup),
            ]);
        }
        println!("{}\n", t.markdown());
    }

    // Regression gate: per (kernel, elems, bits) shape, the BEST backend
    // must clear the floor.  Taking the max across backends keeps the
    // gate robust to one backend being slow on one shape (expected —
    // that's what dispatch is for) while still catching a kernel whose
    // fused path lost to scalar everywhere.
    let mut by_shape: BTreeMap<(String, usize, usize), f64> = BTreeMap::new();
    for r in &recs {
        let e = by_shape.entry((r.kernel.clone(), r.elems, r.bits)).or_insert(f64::NEG_INFINITY);
        *e = e.max(r.speedup);
    }
    let failures: Vec<String> = by_shape
        .iter()
        .filter(|(_, &best)| best < floor)
        .map(|((k, elems, bits), best)| {
            format!("{k} ({elems} elems @ {bits}b): best backend {best:.2}x < floor {floor:.2}x")
        })
        .collect();
    if failures.is_empty() {
        println!(
            "gate: all {} kernel shape(s) clear the {floor:.2}x speedup floor",
            by_shape.len()
        );
        Ok(())
    } else {
        bail!("speedup floor violated:\n  {}", failures.join("\n  "))
    }
}

/// `serve`: the long-running sweep service.  Binds, prints the bound
/// address (scripts parse this line to discover an ephemeral `:0`
/// port), then serves until a drain shutdown completes.
fn cmd_serve(args: &mut Args) -> Result<()> {
    use hindsight::service::{CellRunner, ServeOptions, Server, ShardSpec};
    let addr = args.str_or("addr", "127.0.0.1:8080");
    let workers = args.usize_or("workers", 2).max(1);
    let store_dir = args.str_or("store", "runs");
    let shard = match args.get("shard") {
        Some(s) => ShardSpec::parse(&s).map_err(|e| anyhow::anyhow!("--shard: {e:#}"))?,
        None => ShardSpec::solo(),
    };
    // --synthetic runs deterministic synthetic cells (CI smoke, demos)
    // instead of engine training, so the service is exercisable end to
    // end on machines without compiled artifacts
    let synthetic = args.bool_or("synthetic", false);
    let poll_ms = args.u64_or("poll-ms", 500);
    // --queue-cap bounds the pending-cell queue: submissions that would
    // exceed it get 429 + Retry-After instead of queueing without limit
    let queue_cap = match args.get("queue-cap") {
        Some(s) => s
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--queue-cap: not a count: '{s}'"))?,
        None => usize::MAX,
    };
    args.finish().map_err(anyhow::Error::msg)?;
    let runner = if synthetic {
        CellRunner::Synthetic
    } else {
        CellRunner::Engine
    };
    let server = Server::bind(ServeOptions {
        addr,
        workers,
        store_dir: store_dir.clone().into(),
        shard,
        runner,
        poll_ms,
        queue_cap,
        synthetic_delay_ms: 0,
    })?;
    println!(
        "serving on http://{} (shard {shard}, {workers} worker(s), store {store_dir}/, {} cells)",
        server.local_addr()?,
        if synthetic { "synthetic" } else { "engine" },
    );
    server.run()
}

/// `runs`: inspect the run store.  Lists cached cells; `--gc` prunes
/// version-skewed and key-mismatched files and rebuilds the index;
/// `--verify` re-reads every cell and fails on corrupt ones.
fn cmd_runs(args: &mut Args) -> Result<()> {
    let store_dir = args.str_or("store", "runs");
    let gc = args.bool_or("gc", false);
    let verify = args.bool_or("verify", false);
    args.finish().map_err(anyhow::Error::msg)?;
    let store = RunStore::open(&store_dir)?;
    store.refresh();
    if gc {
        let r = store.gc()?;
        println!(
            "gc: kept {} cell(s), removed {} version-skewed + {} key-mismatched + {} temp file(s), \
             kept {} corrupt (unparseable) file(s)",
            r.kept, r.removed_skewed, r.removed_mismatched, r.removed_tmp, r.corrupt,
        );
    }
    if verify {
        let bad = store.verify();
        if !bad.is_empty() {
            for (file, err) in &bad {
                eprintln!("  corrupt: {file}: {err}");
            }
            bail!("{} corrupt cell(s) in {store_dir}/", bad.len());
        }
        println!("verify: every cell file in {store_dir}/ reads back cleanly");
    }
    let files = store.files();
    let mut table = Table::new(
        &format!("Run store {store_dir}/ ({} cells)", files.len()),
        &["Model", "Scheme", "Seed", "Steps", "Age", "File"],
    );
    let now = std::time::SystemTime::now();
    for file in &files {
        let Ok((key, _record)) = store.read_cell_file(file) else {
            table.row(&[
                "?".into(),
                "(unreadable — see --verify)".into(),
                "".into(),
                "".into(),
                "".into(),
                file.clone(),
            ]);
            continue;
        };
        let age = std::fs::metadata(store.dir().join(file))
            .and_then(|m| m.modified())
            .ok()
            .and_then(|t| now.duration_since(t).ok())
            .map(format_age)
            .unwrap_or_else(|| "?".into());
        table.row(&[
            key.model,
            key.scheme,
            key.seed.to_string(),
            key.steps.to_string(),
            age,
            file.clone(),
        ]);
    }
    table.print();
    println!("{} cell(s) in {store_dir}/", files.len());
    Ok(())
}

/// Compact duration rendering for the `runs` age column.
fn format_age(d: std::time::Duration) -> String {
    let s = d.as_secs();
    if s < 60 {
        format!("{s}s")
    } else if s < 3600 {
        format!("{}m", s / 60)
    } else if s < 86_400 {
        format!("{}h", s / 3600)
    } else {
        format!("{}d", s / 86_400)
    }
}

//! The training driver: marshals batches into the compiled train graph,
//! threads the range state between steps, runs calibration, the periodic
//! search pass for `needs_search` estimators (DSGC, sampled min-max),
//! LR schedules, evaluation and metrics.
//!
//! Everything on the step path is Rust + one compiled XLA executable;
//! the per-step coordinator work is a handful of slice copies and the
//! O(Q) range-state update (paper Sec. 4: "minimal hardware support").

use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::config::{Estimator, TrainConfig};
use crate::coordinator::ranges::RangeManager;
use crate::data::{Batcher, SynthSpec, SynthVision};
use crate::metrics::RunRecord;
use crate::quant::kernel;
use crate::runtime::engine::{Engine, Graph};
use crate::runtime::manifest::ModelSpec;
use crate::runtime::tensor::Tensor;

/// Whether the periodic search pass is due at `step`.  A zero period
/// means "search once, at step 0" (the bootstrap search only) — the
/// naive `step % period` would panic with a divide-by-zero.
fn search_due(step: u64, period: u64) -> bool {
    if period == 0 {
        step == 0
    } else {
        step % period == 0
    }
}

/// Validate a scheme's site-level coupling against a model's quantizer
/// sites — shared by the engine-backed [`Trainer`] and analytic
/// workloads built through
/// [`workload_spec`](crate::simulator::workload_spec):
///
/// * every per-site override must name a real quantizer site (a typo'd
///   key would otherwise be silently inert);
/// * search-based estimators are rejected on activation sites (the
///   dump-graph search pass materializes gradient tensors only);
/// * a per-site override must keep its class's graph mode and enable
///   bit (the train graph has one mode/enable scalar per class).
pub fn validate_scheme_sites(
    model: &ModelSpec,
    scheme: &crate::scheme::QuantScheme,
) -> Result<()> {
    use crate::runtime::manifest::SiteKind;
    for (site, _) in scheme.overrides() {
        if !model.sites.iter().any(|s| s.name == site) {
            let names: Vec<&str> = model.sites.iter().map(|s| s.name.as_str()).collect();
            anyhow::bail!(
                "scheme override '@{site}' matches no quantizer site of model '{}' \
                 (sites: {})",
                model.name,
                names.join(", ")
            );
        }
    }
    for s in &model.sites {
        let class = match s.kind {
            SiteKind::Act => crate::scheme::TensorClass::Activations,
            SiteKind::Grad => crate::scheme::TensorClass::Gradients,
        };
        let spec = scheme.site_spec(class, &s.name);
        // the periodic search pass only materializes gradient
        // tensors, so a search-based estimator on an activation site
        // would freeze at its init row forever — reject it instead
        if spec.estimator.needs_search() && s.kind == SiteKind::Act {
            anyhow::bail!(
                "activation site '{}' uses search-based estimator '{}' — the dump-graph \
                 search pass visits gradient sites only (paper Table 3 runs DSGC-style \
                 estimators on gradients, activations fall back to 'current')",
                s.name,
                spec.estimator.spec()
            );
        }
        // the train graph has ONE mode/enable scalar per class, so a
        // per-site override may refine semantics only within the same
        // graph mode (e.g. hindsight -> tqt/dsgc, all static); a
        // dynamic override under a static class (or vice versa) would
        // silently quantize with the wrong in-graph rule
        let class_est = scheme.spec(class).estimator;
        if spec.estimator.mode() != class_est.mode()
            || spec.estimator.enabled() != class_est.enabled()
        {
            anyhow::bail!(
                "site '{}' override '{}' runs in graph mode {} but its class \
                 estimator '{}' runs in mode {} — per-site overrides must keep \
                 the class's graph mode (static/dynamic) and enable bit",
                s.name,
                spec.estimator.spec(),
                spec.estimator.mode(),
                class_est.spec(),
                class_est.mode()
            );
        }
    }
    Ok(())
}

/// One model + one configuration training session.
pub struct Trainer<'e> {
    engine: &'e Engine,
    pub model: ModelSpec,
    pub cfg: TrainConfig,
    g_train: Graph,
    g_eval: Option<Graph>,
    g_dump: Option<Graph>,
    /// params ++ opt ++ state, in manifest order (graph I/O prefix)
    pub carry: Vec<Tensor>,
    pub ranges: RangeManager,
    data: SynthVision,
    batcher: Batcher,
    // preallocated batch staging
    x_buf: Tensor,
    y_buf: Tensor,
    pub record: RunRecord,
    step: u64,
    /// cumulative search-pass tensor traversals (cost accounting; DSGC
    /// objective evaluations, sampled-min-max subsample passes)
    pub search_evals: u64,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: TrainConfig) -> Result<Self> {
        let model = engine.manifest.model(&cfg.model)?.clone();
        let g_train = engine.graph(&cfg.model, "train")?;
        let g_eval = if model.has_graph("eval") {
            Some(engine.graph(&cfg.model, "eval")?)
        } else {
            None
        };

        // init params on-device from the seed
        let g_init = engine.graph(&cfg.model, "init")?;
        let carry = engine.run(&g_init, &[Tensor::scalar_i32(cfg.seed as i32)])?;

        let ranges = RangeManager::new(&model, &cfg.scheme);
        // the dump graph is needed iff any (possibly overridden) grad
        // site's estimator declares the periodic search pass; name the
        // actual sites in the error so an override-triggered requirement
        // doesn't get blamed on the (search-free) class estimator
        let g_dump = if ranges.needs_search_pass() {
            let searchers: Vec<String> = ranges
                .search_sites()
                .iter()
                .map(|&i| {
                    format!("{}:{}", model.sites[i].name, ranges.site_spec(i).estimator.spec())
                })
                .collect();
            Some(engine.graph(&cfg.model, "dump").with_context(|| {
                format!(
                    "search-based estimator(s) [{}] require the dump graph",
                    searchers.join(", ")
                )
            })?)
        } else {
            None
        };
        // Manifest validation: the artifacts were AOT-compiled at fixed
        // bit-widths, so every enabled class/site of the scheme must
        // match them — mixed-precision schemes run on the simulator
        // path (`simulator::scheme`) until per-bitwidth artifacts exist.
        let m = &engine.manifest;
        let check = |what: &str, want: u32, have: u32| -> Result<()> {
            if want != have {
                anyhow::bail!(
                    "scheme requests {want}-bit {what} but the compiled artifacts are \
                     {have}-bit — engine runs are fixed-bit (W{}/A{}/G{}); run \
                     mixed-precision schemes on the simulator (`mem-report`, \
                     `simulator::scheme`) or rebuild artifacts (python/compile/aot.py)",
                    m.bits_w,
                    m.bits_a,
                    m.bits_g
                );
            }
            Ok(())
        };
        if cfg.scheme.weights.enabled() {
            check("weights", cfg.scheme.weights.bits, m.bits_w)?;
        }
        // site-level coupling (override names, act-search rejection,
        // graph-mode drift) — shared with analytic workloads
        validate_scheme_sites(&model, &cfg.scheme)?;
        for s in &model.sites {
            use crate::runtime::manifest::SiteKind;
            let (class, have, what) = match s.kind {
                SiteKind::Act => (crate::scheme::TensorClass::Activations, m.bits_a, "activations"),
                SiteKind::Grad => (crate::scheme::TensorClass::Gradients, m.bits_g, "gradients"),
            };
            let spec = cfg.scheme.site_spec(class, &s.name);
            if spec.enabled() {
                check(what, spec.bits, have)?;
            }
        }
        // the train graph has a single EMA scalar (graph_eta == the
        // gradient eta): a stateful activation estimator whose in-graph
        // update would want a different eta only sees its own eta during
        // calibration — surface that instead of silently ignoring it
        if cfg.scheme.activations.estimator.enabled()
            && cfg.scheme.activations.estimator.stateful()
            && cfg.scheme.activations.eta != cfg.scheme.graph_eta()
        {
            log::warn!(
                "activation eta {} differs from the graph eta {} — the compiled graph \
                 has one EMA scalar (the gradient eta); per-class activation eta \
                 applies to calibration batches only",
                cfg.scheme.activations.eta,
                cfg.scheme.graph_eta()
            );
        }
        // fail early and readably when the range-row count does not match
        // the compiled graph's ranges input — otherwise a per-channel
        // config surfaces as an opaque marshalling shape error on the
        // first step
        if let Ok(gspec) = model.graph("train") {
            if let Ok(ri) = gspec.input_index("ranges") {
                let want = &gspec.inputs[ri].shape;
                let have = vec![ranges.n_rows(), 2];
                if *want != have {
                    anyhow::bail!(
                        "model '{}' compiled with a {:?} ranges input but the configured \
                         estimators produce a {:?} range state — these artifacts are \
                         per-tensor; per-channel ('@pc') estimators need \
                         per-channel-aware artifacts (re-run python/compile/aot.py)",
                        model.name,
                        want,
                        have
                    );
                }
            }
        }
        let mut spec = SynthSpec::tiny(
            model.n_classes,
            model.input_shape[0],
            cfg.seed ^ 0x5EED_DA7A,
        );
        spec.n_train = cfg.n_train;
        spec.n_val = cfg.n_val;
        let data = SynthVision::new(spec);
        let batcher = Batcher::new(cfg.n_train, model.batch_size, cfg.seed);

        let bs = model.batch_size;
        let img: usize = model.input_shape.iter().product();
        let x_buf = Tensor::from_f32(
            &[bs, model.input_shape[0], model.input_shape[1], model.input_shape[2]],
            vec![0.0; bs * img],
        );
        let y_buf = Tensor::from_i32(&[bs], vec![0; bs]);
        let record = RunRecord::new(&cfg.tag());

        Ok(Self {
            engine,
            model,
            cfg,
            g_train,
            g_eval,
            g_dump,
            carry,
            ranges,
            data,
            batcher,
            x_buf,
            y_buf,
            record,
            step: 0,
            search_evals: 0,
        })
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    fn fill_next_batch(&mut self) {
        let idx = self.batcher.next_batch().to_vec();
        self.data.fill_batch(
            &idx,
            false,
            self.x_buf.as_f32_mut().unwrap(),
            match &mut self.y_buf.data {
                crate::runtime::tensor::Payload::I32(v) => v,
                _ => unreachable!(),
            },
        );
    }

    /// Calibration pass (paper Sec. 5.2): feed batches with lr = 0 and
    /// quantization disabled, absorbing the observed statistics into the
    /// range state.  Params are bit-identical afterwards (lr = 0).
    pub fn calibrate(&mut self) -> Result<()> {
        let n = self.cfg.calib_batches;
        for _ in 0..n {
            self.fill_next_batch();
            let out = self.run_train_graph(0.0, 0.0, true)?;
            let stats = &out[out.len() - 1];
            self.ranges.calibrate(stats); // per-site spec eta
        }
        if n > 0 {
            log::debug!(
                "calibrated {} sites over {n} batches (coverage {:.3})",
                self.ranges.n_sites(),
                self.ranges.coverage()
            );
        }
        // measured-auto backend selection piggybacks on calibration: the
        // sites' shapes are known, training hasn't started, and a few
        // timed passes here are amortized over the whole run
        if kernel::measured_auto_requested() {
            self.autotune_sites();
        }
        Ok(())
    }

    /// Time every candidate kernel backend on each site's actual tensor
    /// shape and cache the measured winner in the range manager's site
    /// table.  If `--kernel-backend auto` asked for a measured pick and
    /// nothing pinned the process-wide backend yet (env overrides win),
    /// adopt the largest site's winner instead of the core-count
    /// heuristic.
    pub fn autotune_sites(&mut self) {
        let bs = self.model.batch_size;
        for i in 0..self.model.sites.len() {
            let site = &self.model.sites[i];
            let elems = bs * site.feature_shape.iter().product::<usize>().max(1);
            let bits = self.ranges.site_spec(i).bits.clamp(1, 8);
            let at = kernel::autotune_minmax_fq(elems, bits);
            log::debug!(
                "autotune {}: {} ({} elems @ {bits}b, {:.2}x over scalar)",
                site.name,
                at.backend.key(),
                at.elems,
                at.speedup()
            );
            self.ranges.set_site_autotune(i, at);
        }
        if kernel::measured_auto_requested() && kernel::resolved_backend().is_none() {
            if let Some(b) = self.ranges.tuned_backend() {
                // a concurrent select_backend can win the race; the
                // measured pick is best-effort, never an error
                let _ = kernel::select_backend(b);
                log::info!("kernel backend '{}' picked by per-site autotuning", b.key());
            }
        }
    }

    /// Assemble inputs and run the train graph.  Returns the raw outputs.
    /// `disable_quant` forces all enables off (calibration).
    fn run_train_graph(&self, lr: f32, wd: f32, disable_quant: bool) -> Result<Vec<Tensor>> {
        let ranges_t = self.ranges.as_tensor();
        let (mode_a, mode_g, wq, aq, gq) = if disable_quant {
            (2.0, 2.0, 0.0, 0.0, 0.0)
        } else {
            // paper Sec. 4.1 initialization: q^0 = minmax(G^0) — when no
            // calibration seeded the state, the very first step runs the
            // stateful estimators in current-min-max mode so their grid is
            // the first batch's statistics, not the neutral init.
            let bootstrap = self.step == 0 && !self.ranges.is_calibrated();
            let boot = |est: Estimator, m: f32| {
                if bootstrap && est.bootstrap_dynamic() {
                    0.0
                } else {
                    m
                }
            };
            (
                boot(self.cfg.scheme.activations.estimator, self.ranges.mode_act()),
                boot(self.cfg.scheme.gradients.estimator, self.ranges.mode_grad()),
                self.cfg.scheme.weights.enabled() as u32 as f32,
                self.ranges.aq_on(),
                self.ranges.gq_on(),
            )
        };
        let scal = [
            Tensor::scalar_f32(mode_a),
            Tensor::scalar_f32(mode_g),
            Tensor::scalar_f32(wq),
            Tensor::scalar_f32(aq),
            Tensor::scalar_f32(gq),
            Tensor::scalar_f32(self.cfg.scheme.graph_eta()),
            Tensor::scalar_f32(lr),
            Tensor::scalar_f32(wd),
            Tensor::scalar_i32((self.cfg.seed as i32) ^ (self.step as i32)),
        ];
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(self.carry.len() + 12);
        inputs.extend(self.carry.iter());
        inputs.push(&self.x_buf);
        inputs.push(&self.y_buf);
        inputs.push(&ranges_t);
        inputs.extend(scal.iter());
        self.engine.run_refs(&self.g_train, &inputs)
    }

    /// One optimization step; returns (loss, train-batch accuracy).
    pub fn train_step(&mut self) -> Result<(f32, f32)> {
        // periodic tensor-level range search for sites that need it
        // (step 0 bootstraps the ranges; period 0 = bootstrap only)
        if self.ranges.needs_search_pass() && search_due(self.step, self.cfg.dsgc_period) {
            self.search_update()?;
        }

        self.fill_next_batch();
        let lr = self
            .cfg
            .schedule
            .lr_at(self.cfg.lr, self.cfg.final_lr, self.step, self.cfg.steps);
        let out = self.run_train_graph(lr, self.cfg.weight_decay, false)?;

        let n_carry = self.carry.len();
        let loss = out[n_carry].item_f32()?;
        let acc = out[n_carry + 1].item_f32()?;
        let new_ranges = &out[n_carry + 2];
        let stats = &out[n_carry + 3];
        self.ranges
            .update(new_ranges, stats, self.step == 0);
        // adopt new params/opt/state
        let mut out = out;
        out.truncate(n_carry);
        self.carry = out;

        if self.cfg.log_every > 0 && self.step % self.cfg.log_every == 0 {
            log::debug!(
                "step {:>5} lr {lr:.4} loss {loss:.4} acc {acc:.3}",
                self.step
            );
        }
        self.record.log_step(self.step, loss, acc);
        self.step += 1;
        Ok((loss, acc))
    }

    /// Periodic range search over dumped gradient tensors: every grad
    /// site whose estimator declares `needs_search` gets handed the raw
    /// tensor (DSGC runs its golden-section search, sampled min-max a
    /// strided subsample pass).
    pub fn search_update(&mut self) -> Result<()> {
        let g_dump = self.g_dump.clone().context("no dump graph")?;
        self.fill_next_batch();
        let ranges_t = self.ranges.as_tensor();
        let scal = [
            Tensor::scalar_f32(2.0), // mode_grad: static while dumping
            Tensor::scalar_f32(self.cfg.scheme.weights.enabled() as u32 as f32),
            Tensor::scalar_f32(self.ranges.aq_on()),
            Tensor::scalar_f32(self.ranges.gq_on()),
            Tensor::scalar_f32(self.cfg.scheme.graph_eta()),
            Tensor::scalar_i32(self.cfg.seed as i32 ^ self.step as i32),
        ];
        let p = self.model.params.len();
        let s = self.model.state.len();
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(p + s + 9);
        // dump ABI: params..., state..., x, y, ranges, scalars
        inputs.extend(self.carry[..p].iter()); // params
        inputs.extend(self.carry[2 * p..2 * p + s].iter()); // state
        inputs.push(&self.x_buf);
        inputs.push(&self.y_buf);
        inputs.push(&ranges_t);
        inputs.extend(scal.iter());
        let grads = self.engine.run_refs(&g_dump, &inputs)?;

        // the dump graph returns one tensor per *gradient site* in site
        // order; with per-site overrides only a subset may need search,
        // so map each search site to its position among the grad sites
        use crate::runtime::manifest::SiteKind;
        let grad_order: Vec<usize> = (0..self.model.sites.len())
            .filter(|&i| self.model.sites[i].kind == SiteKind::Grad)
            .collect();
        assert_eq!(grads.len(), grad_order.len(), "dump arity vs grad sites");
        let sites = self.ranges.search_sites();
        for &site in &sites {
            let pos = grad_order
                .iter()
                .position(|&g| g == site)
                .expect("search site is a grad site");
            let evals =
                self.ranges
                    .search_site(site, grads[pos].as_f32()?, self.cfg.dsgc_iters);
            self.search_evals += evals as u64;
        }
        log::debug!(
            "search update at step {}: {} sites, {} evals total",
            self.step,
            sites.len(),
            self.search_evals
        );
        Ok(())
    }

    /// Full-validation evaluation; returns (loss, accuracy).
    ///
    /// Each validation sample is scored *at most once*: batches take
    /// distinct index windows and the metrics are normalized by the true
    /// scored count.  (The previous wrap-around `i % len` scored the
    /// head of the set twice whenever the count didn't divide the batch
    /// size, biasing both loss and accuracy toward those samples.)  The
    /// trailing partial batch is dropped — the eval graph returns
    /// batch-level sums, so padded slots can't be masked out; the one
    /// exception is a validation set smaller than a single batch, where
    /// wrap-padding is unavoidable and the old normalization applies.
    pub fn evaluate(&mut self) -> Result<(f32, f32)> {
        let g_eval = self.g_eval.clone().context("model has no eval graph")?;
        let bs = self.model.batch_size;
        let n_avail = self.cfg.n_val.min(self.data.len(true)).max(1);
        let (n_batches, wrap) = if n_avail >= bs { (n_avail / bs, false) } else { (1, true) };
        let p = self.model.params.len();
        let s = self.model.state.len();
        let ranges_t = self.ranges.as_tensor();
        // eval act-quant follows the configured estimator: static ranges
        // for hindsight/dsgc, current for the dynamic methods.
        let scal = [
            Tensor::scalar_f32(self.ranges.mode_act()),
            Tensor::scalar_f32(self.cfg.scheme.weights.enabled() as u32 as f32),
            Tensor::scalar_f32(self.ranges.aq_on()),
        ];
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut x = self.x_buf.clone();
        let mut y = self.y_buf.clone();
        for b in 0..n_batches {
            let idx: Vec<usize> = (b * bs..(b + 1) * bs)
                .map(|i| if wrap { i % n_avail } else { i })
                .collect();
            self.data.fill_batch(
                &idx,
                true,
                x.as_f32_mut().unwrap(),
                match &mut y.data {
                    crate::runtime::tensor::Payload::I32(v) => v,
                    _ => unreachable!(),
                },
            );
            let mut inputs: Vec<&Tensor> = Vec::with_capacity(p + s + 6);
            inputs.extend(self.carry[..p].iter());
            inputs.extend(self.carry[2 * p..2 * p + s].iter());
            inputs.push(&x);
            inputs.push(&y);
            inputs.push(&ranges_t);
            inputs.extend(scal.iter());
            let out = self.engine.run_refs(&g_eval, &inputs)?;
            loss_sum += out[0].item_f32()? as f64;
            correct += out[1].item_f32()? as f64;
        }
        let n = (n_batches * bs) as f64;
        let (l, a) = ((loss_sum / n) as f32, (correct / n) as f32);
        self.record.log_eval(self.step, l, a);
        Ok((l, a))
    }

    /// Full schedule: calibrate, train `cfg.steps`, evaluate periodically
    /// and at the end.  Returns the run record.
    pub fn run(mut self) -> Result<RunRecord> {
        // paper Sec. 5.2: stateful estimators (running / hindsight /
        // max-history / tqt) benefit from an initial calibration pass;
        // apply it whenever any site uses one (it also seeds the
        // gradient ranges, subsuming the q^0 = minmax(G^0) bootstrap).
        let any_stateful = self.cfg.scheme.activations.estimator.stateful()
            || self.cfg.scheme.gradients.estimator.stateful()
            || self.cfg.scheme.overrides().any(|(_, s)| s.estimator.stateful());
        if any_stateful && self.cfg.calib_batches > 0 {
            self.calibrate()?;
        }
        let t0 = Instant::now();
        for _ in 0..self.cfg.steps {
            self.train_step()?;
            if self.cfg.eval_every > 0
                && self.step % self.cfg.eval_every == 0
                && self.g_eval.is_some()
            {
                let (l, a) = self.evaluate()?;
                log::info!("eval @ step {}: loss {l:.4} acc {a:.3}", self.step);
            }
        }
        self.record.train_seconds = t0.elapsed().as_secs_f64();
        if self.g_eval.is_some() {
            let (l, a) = self.evaluate()?;
            log::info!(
                "[{}] final eval: loss {l:.4} acc {a:.3} ({:.1}s train)",
                self.record.name,
                self.record.train_seconds
            );
        }
        self.record
            .extra
            .insert("search_evals".into(), self.search_evals as f64);
        self.record
            .extra
            .insert("coverage".into(), self.ranges.coverage());
        Ok(self.record)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn engine() -> Option<Engine> {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::new().unwrap())
    }

    /// Regression: `dsgc_period == 0` used to hit `step % 0` and panic
    /// with a divide-by-zero on the very first train step.  Zero now
    /// means "bootstrap search only" — due at step 0, never again.
    #[test]
    fn zero_dsgc_period_means_bootstrap_search_only() {
        assert!(search_due(0, 0));
        for step in 1..50 {
            assert!(!search_due(step, 0));
        }
        // the periodic semantics are unchanged
        assert!(search_due(0, 10));
        assert!(search_due(10, 10));
        assert!(!search_due(7, 10));
    }

    fn quick_cfg(model: &str) -> TrainConfig {
        let mut c = TrainConfig::new(model);
        c.steps = 12;
        c.n_train = 128;
        c.n_val = 64;
        c.calib_batches = 2;
        c.lr = 0.05;
        c
    }

    #[test]
    fn mlp_trains_and_loss_decreases() {
        let Some(e) = engine() else { return };
        let cfg = quick_cfg("mlp");
        let mut t = Trainer::new(&e, cfg).unwrap();
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for i in 0..30 {
            let (l, _) = t.train_step().unwrap();
            if i == 0 {
                first = l;
            }
            last = l;
        }
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }

    #[test]
    fn calibration_does_not_touch_params() {
        let Some(e) = engine() else { return };
        let mut t = Trainer::new(&e, quick_cfg("mlp")).unwrap();
        let before = t.carry[0].clone();
        t.calibrate().unwrap();
        assert_eq!(t.carry[0], before);
        assert!(t.ranges.is_calibrated());
    }

    #[test]
    fn estimators_update_ranges_differently() {
        let Some(e) = engine() else { return };
        for est in [Estimator::CURRENT, Estimator::RUNNING, Estimator::HINDSIGHT] {
            let cfg = quick_cfg("mlp").fully_quantized(est);
            let mut t = Trainer::new(&e, cfg).unwrap();
            for _ in 0..3 {
                t.train_step().unwrap();
            }
            // ranges must have moved off the neutral init
            assert_ne!(t.ranges.row(0), [-1.0, 1.0], "{est:?}");
        }
    }

    #[test]
    fn search_estimators_run_periodic_search() {
        let Some(e) = engine() else { return };
        for est in [Estimator::DSGC, Estimator::SAMPLED_MINMAX] {
            let mut cfg = quick_cfg("mlp").grad_only(est);
            cfg.dsgc_period = 4;
            cfg.dsgc_iters = 5;
            let mut t = Trainer::new(&e, cfg).unwrap();
            for _ in 0..5 {
                t.train_step().unwrap();
            }
            assert!(t.search_evals > 0, "{}: no search ran", est.key());
        }
    }

    #[test]
    fn zero_period_trains_without_panicking() {
        let Some(e) = engine() else { return };
        let mut cfg = quick_cfg("mlp").grad_only(Estimator::DSGC);
        cfg.dsgc_period = 0;
        cfg.dsgc_iters = 3;
        let mut t = Trainer::new(&e, cfg).unwrap();
        for _ in 0..3 {
            t.train_step().unwrap();
        }
        // exactly one (bootstrap) search ran; no divide-by-zero
        assert!(t.search_evals > 0);
    }

    #[test]
    fn mixed_precision_schemes_are_rejected_by_fixed_bit_artifacts() {
        use crate::scheme::QuantScheme;
        let Some(e) = engine() else { return };
        let mut cfg = quick_cfg("mlp");
        cfg.scheme = QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight:4").unwrap();
        let err = Trainer::new(&e, cfg).err().expect("4-bit grads vs 8-bit artifacts");
        let msg = format!("{err:#}");
        assert!(msg.contains("4-bit"), "{msg}");
        assert!(msg.contains("simulator"), "{msg}");
        // disabled classes are not validated: fp32 grads at odd bits pass
        let mut cfg = quick_cfg("mlp");
        cfg.scheme = QuantScheme::parse("w:current:8 a:hindsight:8 g:fp32:4").unwrap();
        assert!(Trainer::new(&e, cfg).is_ok());
    }

    #[test]
    fn bogus_overrides_and_act_search_schemes_are_rejected() {
        use crate::scheme::QuantScheme;
        let Some(e) = engine() else { return };
        // an override naming no site must not be silently inert
        let mut cfg = quick_cfg("mlp");
        cfg.scheme = QuantScheme::w8a8g8().override_site_str("no_such_site", "tqt:8").unwrap();
        let msg = format!("{:#}", Trainer::new(&e, cfg).err().expect("unknown site"));
        assert!(msg.contains("no_such_site"), "{msg}");
        assert!(msg.contains("sites:"), "{msg}");
        // search-based estimators on activation sites would freeze at init
        let mut cfg = quick_cfg("mlp");
        cfg.scheme = QuantScheme::w8a8g8().act("dsgc").unwrap();
        let msg = format!("{:#}", Trainer::new(&e, cfg).err().expect("act search"));
        assert!(msg.contains("gradient sites only"), "{msg}");
    }

    #[test]
    fn evaluation_returns_sane_numbers() {
        let Some(e) = engine() else { return };
        let mut t = Trainer::new(&e, quick_cfg("mlp")).unwrap();
        let (l, a) = t.evaluate().unwrap();
        assert!(l.is_finite() && l > 0.0);
        assert!((0.0..=1.0).contains(&a));
    }

    #[test]
    fn full_run_produces_record() {
        let Some(e) = engine() else { return };
        let r = Trainer::new(&e, quick_cfg("mlp")).unwrap().run().unwrap();
        assert_eq!(r.steps.len(), 12);
        assert!(!r.evals.is_empty());
        assert!(r.train_seconds > 0.0);
    }
}

//! The resumable run store: completed grid cells persisted as JSON.
//!
//! A week-long ablation grid must survive interruption, so every
//! completed cell lands on disk as one JSON document keyed by
//! `(model, canonical scheme string, seed, steps)` plus a digest of
//! the remaining run-determining knobs (lr, schedule, weight decay,
//! calibration, search cadence, dataset sizes) — together, exactly the
//! inputs that determine a run's outcome on the deterministic training
//! stack.  Re-running the same grid serves cached cells from the store
//! instead of re-training (`--no-cache` forces re-execution); changing
//! *any* knob changes the key, so a cache hit is never a stale result.
//!
//! Layout: one `cell-<fnv64>.json` file per cell under the store
//! directory.  The file name is a 64-bit FNV-1a hash of the key string;
//! the key fields are also stored *inside* the document and verified on
//! read, so a hash collision (or a file copied between stores) degrades
//! to a cache miss, never to a wrong record.  Writes go through a
//! temp-file rename, so an interrupted run never leaves a torn cell
//! behind.
//!
//! An `index.json` sidecar lists every cell file (name -> key id) so
//! resume-time cache lookups answer misses from one in-memory map
//! instead of probing a `cell-*.json` path per cell, and `len()` reads
//! one file instead of scanning the directory.  The sidecar is pure
//! cache: `put` keeps it in sync, a missing or corrupt sidecar degrades
//! to one directory scan (then persists the rebuilt index), and a stale
//! entry can only turn a would-be hit into a re-run — never a wrong
//! record, because the cell document's own key fields stay the source
//! of truth.  Cross-process writers can race the sidecar; delete
//! `index.json` (or just re-open the store) to force a rescan.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::coordinator::config::TrainConfig;
use crate::metrics::RunRecord;
use crate::util::json::{self, Value};

/// Store-document schema version; bump on incompatible layout changes
/// (older documents then read as cache misses, not parse errors).
const STORE_VERSION: f64 = 1.0;

/// The identity of one grid cell: everything that determines the run's
/// outcome.  The scheme is the *canonical* string form, so any two
/// configs that quantize identically share a cache entry regardless of
/// how they were spelled; `config` digests every other outcome-relevant
/// training knob so a changed `--lr` (say) can never serve a stale
/// cached cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellKey {
    pub model: String,
    pub scheme: String,
    pub seed: u64,
    pub steps: u64,
    /// digest of the remaining run-determining config fields (see
    /// [`CellKey::config_digest`])
    pub config: String,
}

impl CellKey {
    /// The key of a training configuration.
    pub fn of(cfg: &TrainConfig) -> Self {
        Self {
            model: cfg.model.clone(),
            scheme: cfg.scheme.to_string(),
            seed: cfg.seed,
            steps: cfg.steps,
            config: Self::config_digest(cfg),
        }
    }

    /// Stable flat form of every outcome-relevant config field outside
    /// the primary key.  `log_every` is deliberately excluded — it only
    /// changes logging, never the record.
    pub fn config_digest(cfg: &TrainConfig) -> String {
        format!(
            "lr={} flr={} sched={:?} wd={} calib={} dsgcp={} dsgci={} ntrain={} nval={} evale={}",
            cfg.lr,
            cfg.final_lr,
            cfg.schedule,
            cfg.weight_decay,
            cfg.calib_batches,
            cfg.dsgc_period,
            cfg.dsgc_iters,
            cfg.n_train,
            cfg.n_val,
            cfg.eval_every
        )
    }

    /// Stable flat form (also the hash input):
    /// `model|scheme|s<seed>|t<steps>|<config>`.
    pub fn id(&self) -> String {
        format!(
            "{}|{}|s{}|t{}|{}",
            self.model, self.scheme, self.seed, self.steps, self.config
        )
    }

    /// Store file name for this key.
    pub fn file_name(&self) -> String {
        format!("cell-{:016x}.json", fnv1a64(self.id().as_bytes()))
    }
}

/// 64-bit FNV-1a (the store needs a stable, dependency-free hash; the
/// key fields inside each document guard against collisions).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The sidecar file listing every cell in the store.
const INDEX_FILE: &str = "index.json";

/// A directory of persisted cell records.
#[derive(Debug)]
pub struct RunStore {
    dir: PathBuf,
    /// lazily-loaded `index.json` entries: cell file name -> key id
    /// (`""` when the entry came from a bare directory-scan rebuild).
    /// `None` until first use; kept in sync by `put`.
    index: Mutex<Option<HashMap<String, String>>>,
}

impl RunStore {
    /// Open (creating if needed) the store at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating run store {}", dir.display()))?;
        Ok(Self { dir, index: Mutex::new(None) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Run `f` over the index entries, loading (or rebuilding from a
    /// directory scan) the sidecar on first use.
    fn with_index<T>(&self, f: impl FnOnce(&mut HashMap<String, String>) -> T) -> T {
        let mut guard = self.index.lock().unwrap_or_else(|e| e.into_inner());
        if guard.is_none() {
            *guard = Some(self.load_or_rebuild_index());
        }
        f(guard.as_mut().expect("just loaded"))
    }

    fn load_or_rebuild_index(&self) -> HashMap<String, String> {
        if let Some(entries) = self.read_index_file() {
            return entries;
        }
        // missing, torn or wrong-version sidecar: one directory scan
        // rebuilds it (ids unknown — advisory-only anyway), then the
        // rebuilt index is persisted so the next open skips the scan
        let mut entries = HashMap::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let name = e.file_name().to_string_lossy().into_owned();
                if name.starts_with("cell-") && name.ends_with(".json") {
                    entries.insert(name, String::new());
                }
            }
        }
        if let Err(e) = self.write_index_file(&entries) {
            log::warn!(
                "run store {}: could not persist rebuilt index: {e:#}",
                self.dir.display()
            );
        }
        entries
    }

    fn read_index_file(&self) -> Option<HashMap<String, String>> {
        let text = std::fs::read_to_string(self.dir.join(INDEX_FILE)).ok()?;
        let doc = json::parse(&text).ok()?;
        if doc.get("version")?.as_f64()? != STORE_VERSION {
            return None;
        }
        match doc.get("cells")? {
            Value::Object(kv) => Some(
                kv.iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                    .collect(),
            ),
            _ => None,
        }
    }

    /// Atomically rewrite the sidecar (sorted, so the bytes are
    /// deterministic for a given cell population).
    fn write_index_file(&self, entries: &HashMap<String, String>) -> Result<()> {
        let mut cells: Vec<(String, Value)> = entries
            .iter()
            .map(|(k, v)| (k.clone(), Value::from(v.clone())))
            .collect();
        cells.sort_by(|a, b| a.0.cmp(&b.0));
        let doc = Value::Object(vec![
            ("version".to_string(), Value::Num(STORE_VERSION)),
            ("cells".to_string(), Value::Object(cells)),
        ]);
        let tmp = self.dir.join(format!(".tmp-{}-{INDEX_FILE}", std::process::id()));
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.dir.join(INDEX_FILE))
            .with_context(|| format!("committing {}", self.dir.join(INDEX_FILE).display()))?;
        Ok(())
    }

    /// Look a cell up; any mismatch (absent, torn, wrong version, key
    /// fields disagreeing with `key`) is a cache miss, never an error.
    /// Misses are answered from the in-memory index — no per-cell file
    /// probe; only an indexed cell's document is actually read.
    pub fn get(&self, key: &CellKey) -> Option<RunRecord> {
        let file = key.file_name();
        // a recorded id must match; "" (scan-rebuilt) defers entirely to
        // the document's verified key fields below
        let known = self.with_index(|idx| {
            idx.get(&file).is_some_and(|id| id.is_empty() || *id == key.id())
        });
        if !known {
            return None;
        }
        let path = self.dir.join(&file);
        let text = std::fs::read_to_string(&path).ok()?;
        let doc = json::parse(&text).ok()?;
        if doc.get("version")?.as_f64()? != STORE_VERSION {
            return None;
        }
        let stored = CellKey {
            model: doc.get("model")?.as_str()?.to_string(),
            scheme: doc.get("scheme")?.as_str()?.to_string(),
            seed: doc.get("seed")?.as_f64()? as u64,
            steps: doc.get("steps")?.as_f64()? as u64,
            config: doc.get("config")?.as_str()?.to_string(),
        };
        if stored != *key {
            log::warn!(
                "run store {}: key mismatch (stored '{}', wanted '{}') — treating as miss",
                path.display(),
                stored.id(),
                key.id()
            );
            return None;
        }
        RunRecord::from_json(doc.get("record")?).ok()
    }

    pub fn contains(&self, key: &CellKey) -> bool {
        self.get(key).is_some()
    }

    /// Persist a completed cell (atomically: temp file + rename;
    /// overwrites any previous record under the same key).
    pub fn put(&self, key: &CellKey, record: &RunRecord) -> Result<PathBuf> {
        let doc = Value::object(vec![
            ("version", Value::Num(STORE_VERSION)),
            ("model", Value::from(key.model.clone())),
            ("scheme", Value::from(key.scheme.clone())),
            ("seed", Value::Num(key.seed as f64)),
            ("steps", Value::Num(key.steps as f64)),
            ("config", Value::from(key.config.clone())),
            ("record", record.to_json()),
        ]);
        let path = self.dir.join(key.file_name());
        let tmp = self
            .dir
            .join(format!(".tmp-{}-{}", std::process::id(), key.file_name()));
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {}", path.display()))?;
        // keep the sidecar in sync; a failed index write only costs the
        // next open a rescan, never the committed cell
        self.with_index(|idx| {
            idx.insert(key.file_name(), key.id());
            if let Err(e) = self.write_index_file(idx) {
                log::warn!("run store index update failed: {e:#}");
            }
        });
        Ok(path)
    }

    /// Number of cell documents in the store (any key) — answered from
    /// the index sidecar (one file) instead of a directory scan.
    pub fn len(&self) -> usize {
        self.with_index(|idx| idx.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> RunStore {
        let dir = std::env::temp_dir().join(format!(
            "hindsight_store_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        RunStore::open(dir).unwrap()
    }

    fn record(name: &str) -> RunRecord {
        let mut r = RunRecord::new(name);
        r.log_step(0, 2.5, 0.1);
        r.log_step(1, 1.0 / 3.0, 0.2);
        r.log_eval(1, 0.9, 0.55);
        r.train_seconds = 1.25;
        r.extra.insert("coverage".into(), 0.75);
        r
    }

    fn key(scheme: &str, seed: u64, steps: u64) -> CellKey {
        CellKey {
            model: "mlp".into(),
            scheme: scheme.into(),
            seed,
            steps,
            config: "lr=0.05".into(),
        }
    }

    #[test]
    fn put_get_round_trip() {
        let store = tmp_store("roundtrip");
        let key = key("w:current:8 a:hindsight:8 g:hindsight:8", 3, 24);
        assert!(store.get(&key).is_none());
        assert!(store.is_empty());
        let rec = record("mlp-run");
        store.put(&key, &rec).unwrap();
        assert_eq!(store.get(&key).unwrap(), rec);
        assert_eq!(store.len(), 1);
        // overwrite under the same key
        let rec2 = record("mlp-run-2");
        store.put(&key, &rec2).unwrap();
        assert_eq!(store.get(&key).unwrap(), rec2);
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn keys_separate_every_axis_including_the_config_digest() {
        let base = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 100);
        let mut variants = vec![base.clone()];
        let mut k = base.clone();
        k.scheme = "w:fp32:8 a:fp32:8 g:current:8".into();
        variants.push(k);
        let mut k = base.clone();
        k.seed = 2;
        variants.push(k);
        let mut k = base.clone();
        k.steps = 200;
        variants.push(k);
        let mut k = base.clone();
        k.model = "cnn".into();
        variants.push(k);
        // a changed training knob (digest) must also miss — a stale
        // cached cell under a new lr would be silently wrong results
        let mut k = base.clone();
        k.config = "lr=0.005".into();
        variants.push(k);
        let mut names: Vec<String> = variants.iter().map(|k| k.file_name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), variants.len(), "every key axis must separate");
    }

    #[test]
    fn config_digest_tracks_every_outcome_relevant_knob() {
        let base = TrainConfig::new("mlp");
        let d0 = CellKey::config_digest(&base);
        let mutations: Vec<Box<dyn Fn(&mut TrainConfig)>> = vec![
            Box::new(|c| c.lr = 0.005),
            Box::new(|c| c.final_lr = 0.9),
            Box::new(|c| c.schedule = crate::coordinator::config::Schedule::Cosine),
            Box::new(|c| c.weight_decay = 0.5),
            Box::new(|c| c.calib_batches = 9),
            Box::new(|c| c.dsgc_period = 7),
            Box::new(|c| c.dsgc_iters = 3),
            Box::new(|c| c.n_train = 64),
            Box::new(|c| c.n_val = 16),
            Box::new(|c| c.eval_every = 5),
        ];
        for (i, m) in mutations.iter().enumerate() {
            let mut cfg = base.clone();
            m(&mut cfg);
            assert_ne!(CellKey::config_digest(&cfg), d0, "mutation {i} must change the digest");
        }
        // log_every is presentation-only: same digest, same cache cell
        let mut cfg = base.clone();
        cfg.log_every = 999;
        assert_eq!(CellKey::config_digest(&cfg), d0);
    }

    #[test]
    fn corrupt_wrong_version_or_mismatched_documents_read_as_misses() {
        let store = tmp_store("corrupt");
        let key = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        let path = store.dir().join(key.file_name());
        // torn write
        std::fs::write(&path, "{\"version\":").unwrap();
        assert!(store.get(&key).is_none());
        // future version
        std::fs::write(&path, "{\"version\":99,\"model\":\"mlp\"}").unwrap();
        assert!(store.get(&key).is_none());
        // right file name, wrong key inside (simulated hash collision)
        let other = CellKey {
            seed: 2,
            ..key.clone()
        };
        let doc = Value::object(vec![
            ("version", Value::Num(STORE_VERSION)),
            ("model", Value::from(other.model.clone())),
            ("scheme", Value::from(other.scheme.clone())),
            ("seed", Value::Num(other.seed as f64)),
            ("steps", Value::Num(other.steps as f64)),
            ("config", Value::from(other.config.clone())),
            ("record", record("x").to_json()),
        ]);
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(store.get(&key).is_none(), "key fields must be verified");
        assert!(store.get(&other).is_none(), "lives under the wrong file name");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn index_sidecar_tracks_puts_and_answers_len() {
        let store = tmp_store("index");
        let k1 = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        let k2 = key("w:fp32:8 a:fp32:8 g:current:8", 1, 10);
        store.put(&k1, &record("a")).unwrap();
        store.put(&k2, &record("b")).unwrap();
        assert_eq!(store.len(), 2);
        let idx_path = store.dir().join(INDEX_FILE);
        assert!(idx_path.exists(), "put must maintain the sidecar");
        let doc = json::parse(&std::fs::read_to_string(&idx_path).unwrap()).unwrap();
        assert_eq!(doc.get("version").unwrap().as_f64(), Some(STORE_VERSION));
        let cells = doc.get("cells").unwrap();
        assert_eq!(
            cells.get(&k1.file_name()).and_then(|v| v.as_str()),
            Some(k1.id().as_str()),
            "sidecar records the key id"
        );
        // a fresh store on the same dir serves hits straight off the
        // sidecar (no rebuild scan needed — but behavior is identical)
        let store2 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store2.len(), 2);
        assert!(store2.get(&k1).is_some());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn missing_or_corrupt_index_degrades_to_a_directory_scan() {
        let store = tmp_store("index_degrade");
        let k = key("w:fp32:8 a:fp32:8 g:hindsight:8", 4, 20);
        let rec = record("cell");
        store.put(&k, &rec).unwrap();
        // missing sidecar: a fresh store must still find the cell
        std::fs::remove_file(store.dir().join(INDEX_FILE)).unwrap();
        let store2 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store2.get(&k).unwrap(), rec, "scan rebuild must find the cell");
        assert_eq!(store2.len(), 1);
        assert!(
            store2.dir().join(INDEX_FILE).exists(),
            "rebuilt index must be persisted"
        );
        // corrupt sidecar: same degradation
        std::fs::write(store.dir().join(INDEX_FILE), "not json at all").unwrap();
        let store3 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store3.get(&k).unwrap(), rec);
        // wrong-version sidecar: treated as stale, rebuilt by scan
        std::fs::write(
            store.dir().join(INDEX_FILE),
            "{\"version\": 99, \"cells\": {}}",
        )
        .unwrap();
        let store4 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store4.len(), 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn unindexed_keys_miss_without_a_file_probe() {
        let store = tmp_store("index_miss");
        let k1 = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        store.put(&k1, &record("a")).unwrap();
        // a key the index has never seen is a miss straight from memory
        let absent = key("w:fp32:8 a:fp32:8 g:tqt:8", 9, 10);
        assert!(store.get(&absent).is_none());
        // an index entry whose file vanished is a plain miss too (the
        // document read fails), never a panic
        std::fs::remove_file(store.dir().join(k1.file_name())).unwrap();
        assert!(store.get(&k1).is_none());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn cell_key_of_config_uses_the_canonical_scheme() {
        use crate::coordinator::config::Estimator;
        let mut cfg = TrainConfig::new("mlp").fully_quantized(Estimator::HINDSIGHT);
        cfg.seed = 7;
        cfg.steps = 50;
        let key = CellKey::of(&cfg);
        assert_eq!(key.scheme, "w:current:8 a:hindsight:8 g:hindsight:8");
        assert_eq!(key.seed, 7);
        assert_eq!(key.steps, 50);
        assert_eq!(key.config, CellKey::config_digest(&cfg));
        assert!(key.config.contains("lr=0.05"), "{}", key.config);
        assert!(key.file_name().starts_with("cell-"));
        assert!(key.file_name().ends_with(".json"));
    }
}

//! The resumable run store: completed grid cells persisted as JSON.
//!
//! A week-long ablation grid must survive interruption, so every
//! completed cell lands on disk as one JSON document keyed by
//! `(model, canonical scheme string, seed, steps)` plus a digest of
//! the remaining run-determining knobs (lr, schedule, weight decay,
//! calibration, search cadence, dataset sizes) — together, exactly the
//! inputs that determine a run's outcome on the deterministic training
//! stack.  Re-running the same grid serves cached cells from the store
//! instead of re-training (`--no-cache` forces re-execution); changing
//! *any* knob changes the key, so a cache hit is never a stale result.
//!
//! Layout: one `cell-<fnv64>.json` file per cell under the store
//! directory.  The file name is a 64-bit FNV-1a hash of the key string;
//! the key fields are also stored *inside* the document and verified on
//! read, so a hash collision (or a file copied between stores) degrades
//! to a cache miss, never to a wrong record.  Writes go through a
//! temp-file rename, so an interrupted run never leaves a torn cell
//! behind.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::coordinator::config::TrainConfig;
use crate::metrics::RunRecord;
use crate::util::json::{self, Value};

/// Store-document schema version; bump on incompatible layout changes
/// (older documents then read as cache misses, not parse errors).
const STORE_VERSION: f64 = 1.0;

/// The identity of one grid cell: everything that determines the run's
/// outcome.  The scheme is the *canonical* string form, so any two
/// configs that quantize identically share a cache entry regardless of
/// how they were spelled; `config` digests every other outcome-relevant
/// training knob so a changed `--lr` (say) can never serve a stale
/// cached cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellKey {
    pub model: String,
    pub scheme: String,
    pub seed: u64,
    pub steps: u64,
    /// digest of the remaining run-determining config fields (see
    /// [`CellKey::config_digest`])
    pub config: String,
}

impl CellKey {
    /// The key of a training configuration.
    pub fn of(cfg: &TrainConfig) -> Self {
        Self {
            model: cfg.model.clone(),
            scheme: cfg.scheme.to_string(),
            seed: cfg.seed,
            steps: cfg.steps,
            config: Self::config_digest(cfg),
        }
    }

    /// Stable flat form of every outcome-relevant config field outside
    /// the primary key.  `log_every` is deliberately excluded — it only
    /// changes logging, never the record.
    pub fn config_digest(cfg: &TrainConfig) -> String {
        format!(
            "lr={} flr={} sched={:?} wd={} calib={} dsgcp={} dsgci={} ntrain={} nval={} evale={}",
            cfg.lr,
            cfg.final_lr,
            cfg.schedule,
            cfg.weight_decay,
            cfg.calib_batches,
            cfg.dsgc_period,
            cfg.dsgc_iters,
            cfg.n_train,
            cfg.n_val,
            cfg.eval_every
        )
    }

    /// Stable flat form (also the hash input):
    /// `model|scheme|s<seed>|t<steps>|<config>`.
    pub fn id(&self) -> String {
        format!(
            "{}|{}|s{}|t{}|{}",
            self.model, self.scheme, self.seed, self.steps, self.config
        )
    }

    /// Store file name for this key.
    pub fn file_name(&self) -> String {
        format!("cell-{:016x}.json", fnv1a64(self.id().as_bytes()))
    }
}

/// 64-bit FNV-1a (the store needs a stable, dependency-free hash; the
/// key fields inside each document guard against collisions).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A directory of persisted cell records.
#[derive(Debug)]
pub struct RunStore {
    dir: PathBuf,
}

impl RunStore {
    /// Open (creating if needed) the store at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating run store {}", dir.display()))?;
        Ok(Self { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Look a cell up; any mismatch (absent, torn, wrong version, key
    /// fields disagreeing with `key`) is a cache miss, never an error.
    pub fn get(&self, key: &CellKey) -> Option<RunRecord> {
        let path = self.dir.join(key.file_name());
        let text = std::fs::read_to_string(&path).ok()?;
        let doc = json::parse(&text).ok()?;
        if doc.get("version")?.as_f64()? != STORE_VERSION {
            return None;
        }
        let stored = CellKey {
            model: doc.get("model")?.as_str()?.to_string(),
            scheme: doc.get("scheme")?.as_str()?.to_string(),
            seed: doc.get("seed")?.as_f64()? as u64,
            steps: doc.get("steps")?.as_f64()? as u64,
            config: doc.get("config")?.as_str()?.to_string(),
        };
        if stored != *key {
            log::warn!(
                "run store {}: key mismatch (stored '{}', wanted '{}') — treating as miss",
                path.display(),
                stored.id(),
                key.id()
            );
            return None;
        }
        RunRecord::from_json(doc.get("record")?).ok()
    }

    pub fn contains(&self, key: &CellKey) -> bool {
        self.get(key).is_some()
    }

    /// Persist a completed cell (atomically: temp file + rename;
    /// overwrites any previous record under the same key).
    pub fn put(&self, key: &CellKey, record: &RunRecord) -> Result<PathBuf> {
        let doc = Value::object(vec![
            ("version", Value::Num(STORE_VERSION)),
            ("model", Value::from(key.model.clone())),
            ("scheme", Value::from(key.scheme.clone())),
            ("seed", Value::Num(key.seed as f64)),
            ("steps", Value::Num(key.steps as f64)),
            ("config", Value::from(key.config.clone())),
            ("record", record.to_json()),
        ]);
        let path = self.dir.join(key.file_name());
        let tmp = self
            .dir
            .join(format!(".tmp-{}-{}", std::process::id(), key.file_name()));
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {}", path.display()))?;
        Ok(path)
    }

    /// Number of cell documents in the store (any key).
    pub fn len(&self) -> usize {
        let Ok(rd) = std::fs::read_dir(&self.dir) else {
            return 0;
        };
        rd.filter_map(|e| e.ok())
            .filter(|e| {
                let name = e.file_name();
                let name = name.to_string_lossy();
                name.starts_with("cell-") && name.ends_with(".json")
            })
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> RunStore {
        let dir = std::env::temp_dir().join(format!(
            "hindsight_store_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        RunStore::open(dir).unwrap()
    }

    fn record(name: &str) -> RunRecord {
        let mut r = RunRecord::new(name);
        r.log_step(0, 2.5, 0.1);
        r.log_step(1, 1.0 / 3.0, 0.2);
        r.log_eval(1, 0.9, 0.55);
        r.train_seconds = 1.25;
        r.extra.insert("coverage".into(), 0.75);
        r
    }

    fn key(scheme: &str, seed: u64, steps: u64) -> CellKey {
        CellKey {
            model: "mlp".into(),
            scheme: scheme.into(),
            seed,
            steps,
            config: "lr=0.05".into(),
        }
    }

    #[test]
    fn put_get_round_trip() {
        let store = tmp_store("roundtrip");
        let key = key("w:current:8 a:hindsight:8 g:hindsight:8", 3, 24);
        assert!(store.get(&key).is_none());
        assert!(store.is_empty());
        let rec = record("mlp-run");
        store.put(&key, &rec).unwrap();
        assert_eq!(store.get(&key).unwrap(), rec);
        assert_eq!(store.len(), 1);
        // overwrite under the same key
        let rec2 = record("mlp-run-2");
        store.put(&key, &rec2).unwrap();
        assert_eq!(store.get(&key).unwrap(), rec2);
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn keys_separate_every_axis_including_the_config_digest() {
        let base = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 100);
        let mut variants = vec![base.clone()];
        let mut k = base.clone();
        k.scheme = "w:fp32:8 a:fp32:8 g:current:8".into();
        variants.push(k);
        let mut k = base.clone();
        k.seed = 2;
        variants.push(k);
        let mut k = base.clone();
        k.steps = 200;
        variants.push(k);
        let mut k = base.clone();
        k.model = "cnn".into();
        variants.push(k);
        // a changed training knob (digest) must also miss — a stale
        // cached cell under a new lr would be silently wrong results
        let mut k = base.clone();
        k.config = "lr=0.005".into();
        variants.push(k);
        let mut names: Vec<String> = variants.iter().map(|k| k.file_name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), variants.len(), "every key axis must separate");
    }

    #[test]
    fn config_digest_tracks_every_outcome_relevant_knob() {
        let base = TrainConfig::new("mlp");
        let d0 = CellKey::config_digest(&base);
        let mutations: Vec<Box<dyn Fn(&mut TrainConfig)>> = vec![
            Box::new(|c| c.lr = 0.005),
            Box::new(|c| c.final_lr = 0.9),
            Box::new(|c| c.schedule = crate::coordinator::config::Schedule::Cosine),
            Box::new(|c| c.weight_decay = 0.5),
            Box::new(|c| c.calib_batches = 9),
            Box::new(|c| c.dsgc_period = 7),
            Box::new(|c| c.dsgc_iters = 3),
            Box::new(|c| c.n_train = 64),
            Box::new(|c| c.n_val = 16),
            Box::new(|c| c.eval_every = 5),
        ];
        for (i, m) in mutations.iter().enumerate() {
            let mut cfg = base.clone();
            m(&mut cfg);
            assert_ne!(CellKey::config_digest(&cfg), d0, "mutation {i} must change the digest");
        }
        // log_every is presentation-only: same digest, same cache cell
        let mut cfg = base.clone();
        cfg.log_every = 999;
        assert_eq!(CellKey::config_digest(&cfg), d0);
    }

    #[test]
    fn corrupt_wrong_version_or_mismatched_documents_read_as_misses() {
        let store = tmp_store("corrupt");
        let key = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        let path = store.dir().join(key.file_name());
        // torn write
        std::fs::write(&path, "{\"version\":").unwrap();
        assert!(store.get(&key).is_none());
        // future version
        std::fs::write(&path, "{\"version\":99,\"model\":\"mlp\"}").unwrap();
        assert!(store.get(&key).is_none());
        // right file name, wrong key inside (simulated hash collision)
        let other = CellKey {
            seed: 2,
            ..key.clone()
        };
        let doc = Value::object(vec![
            ("version", Value::Num(STORE_VERSION)),
            ("model", Value::from(other.model.clone())),
            ("scheme", Value::from(other.scheme.clone())),
            ("seed", Value::Num(other.seed as f64)),
            ("steps", Value::Num(other.steps as f64)),
            ("config", Value::from(other.config.clone())),
            ("record", record("x").to_json()),
        ]);
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(store.get(&key).is_none(), "key fields must be verified");
        assert!(store.get(&other).is_none(), "lives under the wrong file name");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn cell_key_of_config_uses_the_canonical_scheme() {
        use crate::coordinator::config::Estimator;
        let mut cfg = TrainConfig::new("mlp").fully_quantized(Estimator::HINDSIGHT);
        cfg.seed = 7;
        cfg.steps = 50;
        let key = CellKey::of(&cfg);
        assert_eq!(key.scheme, "w:current:8 a:hindsight:8 g:hindsight:8");
        assert_eq!(key.seed, 7);
        assert_eq!(key.steps, 50);
        assert_eq!(key.config, CellKey::config_digest(&cfg));
        assert!(key.config.contains("lr=0.05"), "{}", key.config);
        assert!(key.file_name().starts_with("cell-"));
        assert!(key.file_name().ends_with(".json"));
    }
}

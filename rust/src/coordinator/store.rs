//! The resumable run store: completed grid cells persisted as JSON.
//!
//! A week-long ablation grid must survive interruption, so every
//! completed cell lands on disk as one JSON document keyed by
//! `(model, canonical scheme string, seed, steps)` plus a digest of
//! the remaining run-determining knobs (lr, schedule, weight decay,
//! calibration, search cadence, dataset sizes) — together, exactly the
//! inputs that determine a run's outcome on the deterministic training
//! stack.  Re-running the same grid serves cached cells from the store
//! instead of re-training (`--no-cache` forces re-execution); changing
//! *any* knob changes the key, so a cache hit is never a stale result.
//!
//! Layout: one `cell-<fnv64>.json` file per cell under the store
//! directory.  The file name is a 64-bit FNV-1a hash of the key string;
//! the key fields are also stored *inside* the document and verified on
//! read, so a hash collision (or a file copied between stores) degrades
//! to a cache miss, never to a wrong record.  Writes go through a
//! temp-file rename, so an interrupted run never leaves a torn cell
//! behind.
//!
//! An `index.json` sidecar lists every cell file (name -> key id) so
//! resume-time cache lookups answer misses from one in-memory map
//! instead of probing a `cell-*.json` path per cell, and `len()` reads
//! one file instead of scanning the directory.  The sidecar is pure
//! cache: `put` keeps it in sync, a missing or corrupt sidecar degrades
//! to one directory scan (then persists the rebuilt index), and a stale
//! entry can only turn a would-be hit into a re-run — never a wrong
//! record, because the cell document's own key fields stay the source
//! of truth.
//!
//! Concurrent writers are safe: every sidecar write goes through a
//! per-process temp file + atomic rename, *after* merging the entries
//! currently on disk, so two processes `put`ting into the same store
//! can at worst cost each other one stale entry on the final racing
//! write (served correctly anyway via the in-document key check after
//! a [`RunStore::refresh`] or re-open).  Cell writes themselves are
//! last-writer-wins safe because keys are content-derived: both racers
//! are writing the same record.  [`RunStore::refresh`] unions the
//! on-disk sidecar and a directory scan into the in-memory index so a
//! long-lived process (the sweep service) can observe cells completed
//! by sibling shards.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::SystemTime;

use anyhow::{Context, Result};

use crate::coordinator::config::TrainConfig;
use crate::metrics::RunRecord;
use crate::util::json::{self, JsonView, RawDoc, Value};

/// Store-document schema version; bump on incompatible layout changes
/// (older documents then read as cache misses, not parse errors).
const STORE_VERSION: f64 = 1.0;

/// The identity of one grid cell: everything that determines the run's
/// outcome.  The scheme is the *canonical* string form, so any two
/// configs that quantize identically share a cache entry regardless of
/// how they were spelled; `config` digests every other outcome-relevant
/// training knob so a changed `--lr` (say) can never serve a stale
/// cached cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellKey {
    pub model: String,
    pub scheme: String,
    pub seed: u64,
    pub steps: u64,
    /// digest of the remaining run-determining config fields (see
    /// [`CellKey::config_digest`])
    pub config: String,
}

impl CellKey {
    /// The key of a training configuration.
    pub fn of(cfg: &TrainConfig) -> Self {
        Self {
            model: cfg.model.clone(),
            scheme: cfg.scheme.to_string(),
            seed: cfg.seed,
            steps: cfg.steps,
            config: Self::config_digest(cfg),
        }
    }

    /// Stable flat form of every outcome-relevant config field outside
    /// the primary key.  `log_every` is deliberately excluded — it only
    /// changes logging, never the record.
    pub fn config_digest(cfg: &TrainConfig) -> String {
        format!(
            "lr={} flr={} sched={:?} wd={} calib={} dsgcp={} dsgci={} ntrain={} nval={} evale={}",
            cfg.lr,
            cfg.final_lr,
            cfg.schedule,
            cfg.weight_decay,
            cfg.calib_batches,
            cfg.dsgc_period,
            cfg.dsgc_iters,
            cfg.n_train,
            cfg.n_val,
            cfg.eval_every
        )
    }

    /// Stable flat form (also the hash input):
    /// `model|scheme|s<seed>|t<steps>|<config>`.
    pub fn id(&self) -> String {
        format!(
            "{}|{}|s{}|t{}|{}",
            self.model, self.scheme, self.seed, self.steps, self.config
        )
    }

    /// Store file name for this key.
    pub fn file_name(&self) -> String {
        format!("cell-{:016x}.json", fnv1a64(self.id().as_bytes()))
    }
}

/// 64-bit FNV-1a (the store needs a stable, dependency-free hash; the
/// key fields inside each document guard against collisions).  Shared
/// with the service layer for content-derived job ids.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The sidecar file listing every cell in the store.
const INDEX_FILE: &str = "index.json";

/// A verified cell document, parsed exactly once and shared behind an
/// `Arc` for the serve-many read path.
#[derive(Debug)]
pub struct CellDoc {
    /// The key the document was verified against.
    pub key: CellKey,
    /// The decoded run record.
    pub record: RunRecord,
    /// Canonical serialization of `record` (the exact bytes `{record}`
    /// would print), produced once at load so responses can splice it
    /// without re-walking the tree.
    pub record_json: Arc<str>,
    /// Identity of the file snapshot this was parsed from (file name +
    /// length + mtime); changes whenever the cell file is rewritten.
    pub fingerprint: u64,
}

/// One entry of the document cache: the `(len, mtime)` snapshot the
/// cached parse belongs to.  `doc: None` caches a known-bad file
/// (corrupt / wrong version / key mismatch) so repeated misses don't
/// re-read it either.
#[derive(Debug, Clone)]
struct DocSlot {
    len: u64,
    mtime: SystemTime,
    doc: Option<Arc<CellDoc>>,
}

/// A directory of persisted cell records.
#[derive(Debug)]
pub struct RunStore {
    dir: PathBuf,
    /// lazily-loaded `index.json` entries: cell file name -> key id
    /// (`""` when the entry came from a bare directory-scan rebuild).
    /// `None` until first use; kept in sync by `put`.
    index: Mutex<Option<HashMap<String, String>>>,
    /// parse-once document cache: cell file name -> parsed snapshot,
    /// invalidated by `(len, mtime)` on every lookup (an unchanged
    /// file is never parsed twice in one process lifetime)
    docs: Mutex<HashMap<String, DocSlot>>,
    /// `(len, mtime)` of the sidecar at the last `refresh` re-read, so
    /// an unchanged sidecar is not re-parsed per poll
    index_stat: Mutex<Option<(u64, SystemTime)>>,
    /// cell files parsed (doc-cache misses) since open
    doc_parses: AtomicU64,
    /// doc-cache hits (lookups answered without touching file contents)
    doc_hits: AtomicU64,
}

impl RunStore {
    /// Open (creating if needed) the store at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating run store {}", dir.display()))?;
        Ok(Self {
            dir,
            index: Mutex::new(None),
            docs: Mutex::new(HashMap::new()),
            index_stat: Mutex::new(None),
            doc_parses: AtomicU64::new(0),
            doc_hits: AtomicU64::new(0),
        })
    }

    /// Cell files parsed since open (each unchanged file at most once).
    pub fn doc_parses(&self) -> u64 {
        self.doc_parses.load(Ordering::Relaxed)
    }

    /// Document-cache hits since open.
    pub fn doc_hits(&self) -> u64 {
        self.doc_hits.load(Ordering::Relaxed)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Run `f` over the index entries, loading (or rebuilding from a
    /// directory scan) the sidecar on first use.
    fn with_index<T>(&self, f: impl FnOnce(&mut HashMap<String, String>) -> T) -> T {
        let mut guard = self.index.lock().unwrap_or_else(|e| e.into_inner());
        if guard.is_none() {
            *guard = Some(self.load_or_rebuild_index());
        }
        f(guard.as_mut().expect("just loaded"))
    }

    fn load_or_rebuild_index(&self) -> HashMap<String, String> {
        if let Some(entries) = self.read_index_file() {
            return entries;
        }
        // missing, torn or wrong-version sidecar: one directory scan
        // rebuilds it (ids unknown — advisory-only anyway), then the
        // rebuilt index is persisted so the next open skips the scan
        let mut entries = HashMap::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let name = e.file_name().to_string_lossy().into_owned();
                if name.starts_with("cell-") && name.ends_with(".json") {
                    entries.insert(name, String::new());
                }
            }
        }
        if let Err(e) = self.write_index_file(&mut entries) {
            log::warn!(
                "run store {}: could not persist rebuilt index: {e:#}",
                self.dir.display()
            );
        }
        entries
    }

    fn read_index_file(&self) -> Option<HashMap<String, String>> {
        let text = std::fs::read_to_string(self.dir.join(INDEX_FILE)).ok()?;
        let doc = json::parse(&text).ok()?;
        if doc.get("version")?.as_f64()? != STORE_VERSION {
            return None;
        }
        match doc.get("cells")? {
            Value::Object(kv) => Some(
                kv.iter()
                    .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                    .collect(),
            ),
            _ => None,
        }
    }

    /// Atomically rewrite the sidecar (sorted, so the bytes are
    /// deterministic for a given cell population).  Before writing,
    /// entries already on disk are merged in, so a concurrent writer's
    /// additions survive this write — a lost race can only leave one
    /// *stale* entry (fixed by the next write or a rescan), never drop
    /// a committed cell from the index.
    fn write_index_file(&self, entries: &mut HashMap<String, String>) -> Result<()> {
        if let Some(disk) = self.read_index_file() {
            for (file, id) in disk {
                match entries.get(&file) {
                    // another writer's cell we have never seen
                    None => {
                        entries.insert(file, id);
                    }
                    // we only know it from a bare scan; the disk id is
                    // richer (it answers misses without a file probe)
                    Some(ours) if ours.is_empty() && !id.is_empty() => {
                        entries.insert(file, id);
                    }
                    _ => {}
                }
            }
        }
        let mut cells: Vec<(String, Value)> = entries
            .iter()
            .map(|(k, v)| (k.clone(), Value::from(v.clone())))
            .collect();
        cells.sort_by(|a, b| a.0.cmp(&b.0));
        let doc = Value::Object(vec![
            ("version".to_string(), Value::Num(STORE_VERSION)),
            ("cells".to_string(), Value::Object(cells)),
        ]);
        let tmp = self.dir.join(format!(".tmp-{}-{INDEX_FILE}", std::process::id()));
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, self.dir.join(INDEX_FILE))
            .with_context(|| format!("committing {}", self.dir.join(INDEX_FILE).display()))?;
        Ok(())
    }

    /// Look a cell up; any mismatch (absent, torn, wrong version, key
    /// fields disagreeing with `key`) is a cache miss, never an error.
    /// Misses are answered from the in-memory index — no per-cell file
    /// probe; only an indexed cell's document is actually read.
    ///
    /// Served through the parse-once document cache: an unchanged file
    /// costs one `stat`, never a re-parse.
    pub fn get(&self, key: &CellKey) -> Option<RunRecord> {
        self.get_doc(key).map(|d| d.record.clone())
    }

    /// Like [`RunStore::get`], but returns the shared parsed document
    /// (record + its pre-serialized JSON + file fingerprint).  This is
    /// the serve-many entry point: the first lookup of a cell file
    /// parses it, every later lookup of the unchanged file (same
    /// length + mtime) returns the same `Arc` with zero JSON work.
    pub fn get_doc(&self, key: &CellKey) -> Option<Arc<CellDoc>> {
        let file = key.file_name();
        // a recorded id must match; "" (scan-rebuilt) defers entirely to
        // the document's verified key fields below
        let known = self.with_index(|idx| {
            idx.get(&file).is_some_and(|id| id.is_empty() || *id == key.id())
        });
        if !known {
            return None;
        }
        let path = self.dir.join(&file);
        let meta = std::fs::metadata(&path).ok()?;
        let (len, mtime) = (meta.len(), meta.modified().ok()?);
        {
            let docs = self.docs.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(slot) = docs.get(&file) {
                if slot.len == len && slot.mtime == mtime {
                    self.doc_hits.fetch_add(1, Ordering::Relaxed);
                    // `None` = cached known-bad: still a miss, still no re-read
                    return slot.doc.clone();
                }
            }
        }
        let doc = self.load_cell_doc(key, &path, &file, len, mtime);
        self.docs
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .insert(file, DocSlot { len, mtime, doc: doc.clone() });
        doc
    }

    /// Parse + verify one cell file (the doc-cache miss path).  The
    /// zero-copy parser is primary; if it refuses the bytes the owned
    /// parser gets one chance (defense in depth against a raw-layer
    /// bug), and failing both the file reads as a plain miss.
    fn load_cell_doc(
        &self,
        key: &CellKey,
        path: &Path,
        file: &str,
        len: u64,
        mtime: SystemTime,
    ) -> Option<Arc<CellDoc>> {
        self.doc_parses.fetch_add(1, Ordering::Relaxed);
        let buf: Arc<[u8]> = Arc::from(std::fs::read(path).ok()?);
        let record = match RawDoc::parse_arc(buf.clone()) {
            Ok(raw) => Self::decode_cell(raw.root(), key, path)?,
            Err(_) => {
                let text = std::str::from_utf8(&buf).ok()?;
                let doc = json::parse(text).ok()?;
                Self::decode_cell(&doc, key, path)?
            }
        };
        let record_json: Arc<str> = Arc::from(record.to_json().to_string());
        let nanos = mtime
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let fingerprint = fnv1a64(format!("{file}|{len}|{nanos}").as_bytes());
        Some(Arc::new(CellDoc {
            key: key.clone(),
            record,
            record_json,
            fingerprint,
        }))
    }

    /// Verify version + in-document key fields and decode the record,
    /// from either representation (`RawRef` or `&Value`).
    fn decode_cell<'a, V: JsonView<'a>>(v: V, key: &CellKey, path: &Path) -> Option<RunRecord> {
        if v.get("version")?.as_f64()? != STORE_VERSION {
            return None;
        }
        let stored = CellKey {
            model: v.get("model")?.as_str()?.to_string(),
            scheme: v.get("scheme")?.as_str()?.to_string(),
            // dual-form (`json::u64_value`): seeds ≥ 2^53 persist as
            // decimal strings so the in-document key verifies exactly
            seed: json::lossless_u64(v.get("seed")?)?,
            steps: json::lossless_u64(v.get("steps")?)?,
            config: v.get("config")?.as_str()?.to_string(),
        };
        if stored != *key {
            log::warn!(
                "run store {}: key mismatch (stored '{}', wanted '{}') — treating as miss",
                path.display(),
                stored.id(),
                key.id()
            );
            return None;
        }
        RunRecord::from_view(v.get("record")?).ok()
    }

    pub fn contains(&self, key: &CellKey) -> bool {
        self.get(key).is_some()
    }

    /// Persist a completed cell (atomically: temp file + rename;
    /// overwrites any previous record under the same key).
    pub fn put(&self, key: &CellKey, record: &RunRecord) -> Result<PathBuf> {
        let doc = Value::object(vec![
            ("version", Value::Num(STORE_VERSION)),
            ("model", Value::from(key.model.clone())),
            ("scheme", Value::from(key.scheme.clone())),
            ("seed", json::u64_value(key.seed)),
            ("steps", json::u64_value(key.steps)),
            ("config", Value::from(key.config.clone())),
            ("record", record.to_json()),
        ]);
        let path = self.dir.join(key.file_name());
        let tmp = self
            .dir
            .join(format!(".tmp-{}-{}", std::process::id(), key.file_name()));
        std::fs::write(&tmp, format!("{doc}\n"))
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("committing {}", path.display()))?;
        // keep the sidecar in sync; a failed index write only costs the
        // next open a rescan, never the committed cell
        self.with_index(|idx| {
            idx.insert(key.file_name(), key.id());
            if let Err(e) = self.write_index_file(idx) {
                log::warn!("run store index update failed: {e:#}");
            }
        });
        // drop any cached parse of the replaced file; the next get_doc
        // parses the new contents exactly once.  (Deliberately not
        // seeded from the in-memory record: a NaN/Inf record does not
        // re-parse and must keep reading as a miss.)
        self.docs
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .remove(&key.file_name());
        Ok(path)
    }

    /// Number of cell documents in the store (any key) — answered from
    /// the index sidecar (one file) instead of a directory scan.
    pub fn len(&self) -> usize {
        self.with_index(|idx| idx.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Union the on-disk sidecar and a directory scan into the
    /// in-memory index, making cells written by *other* processes
    /// (sibling shards over a shared store dir) visible to `get`.
    /// Entries discovered only by the scan carry an empty id, so the
    /// document's verified key fields still gate every hit.
    ///
    /// The sidecar is only re-parsed when its `(len, mtime)` changed
    /// since the last refresh — an idle store polls with a stat and a
    /// directory scan, zero JSON parses.
    pub fn refresh(&self) {
        let stat = std::fs::metadata(self.dir.join(INDEX_FILE))
            .ok()
            .and_then(|m| Some((m.len(), m.modified().ok()?)));
        let changed = {
            let mut last = self.index_stat.lock().unwrap_or_else(|e| e.into_inner());
            let changed = *last != stat || stat.is_none();
            *last = stat;
            changed
        };
        let disk = if changed { self.read_index_file() } else { None };
        let mut scanned: Vec<String> = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let name = e.file_name().to_string_lossy().into_owned();
                if name.starts_with("cell-") && name.ends_with(".json") {
                    scanned.push(name);
                }
            }
        }
        self.with_index(|idx| {
            if let Some(disk) = disk {
                for (file, id) in disk {
                    let keep_ours =
                        idx.get(&file).is_some_and(|ours| !ours.is_empty()) && id.is_empty();
                    if !keep_ours {
                        idx.insert(file, id);
                    }
                }
            }
            for file in scanned {
                idx.entry(file).or_default();
            }
        });
    }

    /// Indexed cells as `(file name, key id)` pairs, sorted by file
    /// name (the id is `""` for scan-discovered entries).
    pub fn entries(&self) -> Vec<(String, String)> {
        let mut out: Vec<(String, String)> =
            self.with_index(|idx| idx.iter().map(|(k, v)| (k.clone(), v.clone())).collect());
        out.sort();
        out
    }

    /// Cell file names currently on disk (directory scan, sorted) —
    /// the ground truth `gc`/`verify` reconcile the index against.
    pub fn files(&self) -> Vec<String> {
        let mut out = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let name = e.file_name().to_string_lossy().into_owned();
                if name.starts_with("cell-") && name.ends_with(".json") {
                    out.push(name);
                }
            }
        }
        out.sort();
        out
    }

    /// Read one cell document by file name, returning its stored key
    /// and record.  Errors (instead of the miss-mapping `get`) so
    /// inspection tooling can report *why* a cell is unreadable.
    pub fn read_cell_file(&self, file: &str) -> Result<(CellKey, RunRecord)> {
        let path = self.dir.join(file);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let version = doc
            .get("version")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("{}: missing version", path.display()))?;
        if version != STORE_VERSION {
            anyhow::bail!("{}: store version {version} != {STORE_VERSION}", path.display());
        }
        let field = |name: &str| -> Result<&Value> {
            doc.get(name)
                .ok_or_else(|| anyhow::anyhow!("{}: missing field '{name}'", path.display()))
        };
        let key = CellKey {
            model: field("model")?.as_str().unwrap_or_default().to_string(),
            scheme: field("scheme")?.as_str().unwrap_or_default().to_string(),
            seed: json::lossless_u64(field("seed")?).unwrap_or_default(),
            steps: json::lossless_u64(field("steps")?).unwrap_or_default(),
            config: field("config")?.as_str().unwrap_or_default().to_string(),
        };
        let record = RunRecord::from_json(field("record")?)
            .map_err(|e| anyhow::anyhow!("{}: {e:#}", path.display()))?;
        Ok((key, record))
    }

    /// Prune version-skewed and key-mismatched cell files (plus stale
    /// `.tmp-*` droppings) and rebuild the sidecar with verified key
    /// ids.  Unparseable cell files are *kept* (and counted) — `gc`
    /// removes cells that are provably not servable under this store
    /// version, not data that merely failed to parse.
    pub fn gc(&self) -> Result<GcReport> {
        let mut report = GcReport::default();
        let mut rebuilt: HashMap<String, String> = HashMap::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.filter_map(|e| e.ok()) {
                let name = e.file_name().to_string_lossy().into_owned();
                if name.starts_with(".tmp-") {
                    if std::fs::remove_file(e.path()).is_ok() {
                        report.removed_tmp += 1;
                    }
                    continue;
                }
                if !(name.starts_with("cell-") && name.ends_with(".json")) {
                    continue;
                }
                match self.read_cell_file(&name) {
                    Ok((key, _)) => {
                        if key.file_name() == name {
                            report.kept += 1;
                            rebuilt.insert(name, key.id());
                        } else {
                            // the document's own key hashes elsewhere:
                            // unservable under any lookup, safe to drop
                            std::fs::remove_file(e.path())
                                .with_context(|| format!("removing mismatched {name}"))?;
                            report.removed_mismatched += 1;
                        }
                    }
                    Err(err) => {
                        let msg = format!("{err:#}");
                        if msg.contains("store version") {
                            std::fs::remove_file(e.path())
                                .with_context(|| format!("removing version-skewed {name}"))?;
                            report.removed_skewed += 1;
                        } else {
                            report.corrupt += 1;
                        }
                    }
                }
            }
        }
        self.with_index(|idx| {
            *idx = rebuilt.clone();
            // drop the old sidecar first so the merge-before-write
            // can't resurrect entries for the files just removed
            let _ = std::fs::remove_file(self.dir.join(INDEX_FILE));
            if let Err(e) = self.write_index_file(&mut rebuilt) {
                log::warn!("run store gc: could not persist rebuilt index: {e:#}");
            }
        });
        // cached parses may reference files gc just removed
        self.docs.lock().unwrap_or_else(|e| e.into_inner()).clear();
        Ok(report)
    }

    /// Re-read every cell file on disk and report the unreadable ones
    /// as `(file name, error)` pairs (empty = store fully healthy).
    pub fn verify(&self) -> Vec<(String, String)> {
        self.files()
            .into_iter()
            .filter_map(|file| match self.read_cell_file(&file) {
                Ok(_) => None,
                Err(e) => Some((file, format!("{e:#}"))),
            })
            .collect()
    }
}

/// What [`RunStore::gc`] did: cells kept, files removed per reason,
/// and unparseable cells left in place.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct GcReport {
    pub kept: usize,
    pub removed_skewed: usize,
    pub removed_mismatched: usize,
    pub removed_tmp: usize,
    pub corrupt: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(tag: &str) -> RunStore {
        let dir = std::env::temp_dir().join(format!(
            "hindsight_store_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        RunStore::open(dir).unwrap()
    }

    fn record(name: &str) -> RunRecord {
        let mut r = RunRecord::new(name);
        r.log_step(0, 2.5, 0.1);
        r.log_step(1, 1.0 / 3.0, 0.2);
        r.log_eval(1, 0.9, 0.55);
        r.train_seconds = 1.25;
        r.extra.insert("coverage".into(), 0.75);
        r
    }

    fn key(scheme: &str, seed: u64, steps: u64) -> CellKey {
        CellKey {
            model: "mlp".into(),
            scheme: scheme.into(),
            seed,
            steps,
            config: "lr=0.05".into(),
        }
    }

    #[test]
    fn put_get_round_trip() {
        let store = tmp_store("roundtrip");
        let key = key("w:current:8 a:hindsight:8 g:hindsight:8", 3, 24);
        assert!(store.get(&key).is_none());
        assert!(store.is_empty());
        let rec = record("mlp-run");
        store.put(&key, &rec).unwrap();
        assert_eq!(store.get(&key).unwrap(), rec);
        assert_eq!(store.len(), 1);
        // overwrite under the same key
        let rec2 = record("mlp-run-2");
        store.put(&key, &rec2).unwrap();
        assert_eq!(store.get(&key).unwrap(), rec2);
        assert_eq!(store.len(), 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    /// Regression (satellite bugfix): a cell keyed by a seed ≥ 2^53
    /// used to fail its own in-document key check forever (the stored
    /// `Num(seed as f64)` had rounded) — a permanent cache miss.
    #[test]
    fn huge_seed_cells_round_trip_and_verify() {
        let store = tmp_store("huge_seed");
        let p53 = 1_u64 << 53;
        for (i, seed) in [p53 - 1, p53 + 1, u64::MAX].into_iter().enumerate() {
            let key = key("w:fp32:8 a:fp32:8 g:hindsight:8", seed, 10 + i as u64);
            let rec = record(&format!("run-{i}"));
            store.put(&key, &rec).unwrap();
            assert_eq!(store.get(&key).unwrap(), rec, "seed {seed}");
            // the document's stored key reads back exactly
            let (stored, _) = store.read_cell_file(&key.file_name()).unwrap();
            assert_eq!(stored, key);
        }
        // legacy form: seeds ≤ 2^53 written as plain numbers (every
        // pre-dual-encoding document) must still decode
        let legacy = key("w:fp32:8 a:fp32:8 g:current:8", 7, 10);
        let doc = Value::object(vec![
            ("version", Value::Num(STORE_VERSION)),
            ("model", Value::from(legacy.model.clone())),
            ("scheme", Value::from(legacy.scheme.clone())),
            ("seed", Value::Num(legacy.seed as f64)),
            ("steps", Value::Num(legacy.steps as f64)),
            ("config", Value::from(legacy.config.clone())),
            ("record", record("legacy").to_json()),
        ]);
        std::fs::write(store.dir().join(legacy.file_name()), doc.to_string()).unwrap();
        store.refresh();
        assert_eq!(store.get(&legacy).unwrap(), record("legacy"));
        // and small seeds still *write* the plain number form
        let small = key("w:fp32:8 a:fp32:8 g:hindsight:8", 3, 5);
        store.put(&small, &record("small")).unwrap();
        let text = std::fs::read_to_string(store.dir().join(small.file_name())).unwrap();
        assert!(text.contains("\"seed\":3"), "{text}");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn keys_separate_every_axis_including_the_config_digest() {
        let base = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 100);
        let mut variants = vec![base.clone()];
        let mut k = base.clone();
        k.scheme = "w:fp32:8 a:fp32:8 g:current:8".into();
        variants.push(k);
        let mut k = base.clone();
        k.seed = 2;
        variants.push(k);
        let mut k = base.clone();
        k.steps = 200;
        variants.push(k);
        let mut k = base.clone();
        k.model = "cnn".into();
        variants.push(k);
        // a changed training knob (digest) must also miss — a stale
        // cached cell under a new lr would be silently wrong results
        let mut k = base.clone();
        k.config = "lr=0.005".into();
        variants.push(k);
        let mut names: Vec<String> = variants.iter().map(|k| k.file_name()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), variants.len(), "every key axis must separate");
    }

    #[test]
    fn config_digest_tracks_every_outcome_relevant_knob() {
        let base = TrainConfig::new("mlp");
        let d0 = CellKey::config_digest(&base);
        let mutations: Vec<Box<dyn Fn(&mut TrainConfig)>> = vec![
            Box::new(|c| c.lr = 0.005),
            Box::new(|c| c.final_lr = 0.9),
            Box::new(|c| c.schedule = crate::coordinator::config::Schedule::Cosine),
            Box::new(|c| c.weight_decay = 0.5),
            Box::new(|c| c.calib_batches = 9),
            Box::new(|c| c.dsgc_period = 7),
            Box::new(|c| c.dsgc_iters = 3),
            Box::new(|c| c.n_train = 64),
            Box::new(|c| c.n_val = 16),
            Box::new(|c| c.eval_every = 5),
        ];
        for (i, m) in mutations.iter().enumerate() {
            let mut cfg = base.clone();
            m(&mut cfg);
            assert_ne!(CellKey::config_digest(&cfg), d0, "mutation {i} must change the digest");
        }
        // log_every is presentation-only: same digest, same cache cell
        let mut cfg = base.clone();
        cfg.log_every = 999;
        assert_eq!(CellKey::config_digest(&cfg), d0);
    }

    #[test]
    fn corrupt_wrong_version_or_mismatched_documents_read_as_misses() {
        let store = tmp_store("corrupt");
        let key = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        let path = store.dir().join(key.file_name());
        // torn write
        std::fs::write(&path, "{\"version\":").unwrap();
        assert!(store.get(&key).is_none());
        // future version
        std::fs::write(&path, "{\"version\":99,\"model\":\"mlp\"}").unwrap();
        assert!(store.get(&key).is_none());
        // right file name, wrong key inside (simulated hash collision)
        let other = CellKey {
            seed: 2,
            ..key.clone()
        };
        let doc = Value::object(vec![
            ("version", Value::Num(STORE_VERSION)),
            ("model", Value::from(other.model.clone())),
            ("scheme", Value::from(other.scheme.clone())),
            ("seed", Value::Num(other.seed as f64)),
            ("steps", Value::Num(other.steps as f64)),
            ("config", Value::from(other.config.clone())),
            ("record", record("x").to_json()),
        ]);
        std::fs::write(&path, doc.to_string()).unwrap();
        assert!(store.get(&key).is_none(), "key fields must be verified");
        assert!(store.get(&other).is_none(), "lives under the wrong file name");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn index_sidecar_tracks_puts_and_answers_len() {
        let store = tmp_store("index");
        let k1 = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        let k2 = key("w:fp32:8 a:fp32:8 g:current:8", 1, 10);
        store.put(&k1, &record("a")).unwrap();
        store.put(&k2, &record("b")).unwrap();
        assert_eq!(store.len(), 2);
        let idx_path = store.dir().join(INDEX_FILE);
        assert!(idx_path.exists(), "put must maintain the sidecar");
        let doc = json::parse(&std::fs::read_to_string(&idx_path).unwrap()).unwrap();
        assert_eq!(doc.get("version").unwrap().as_f64(), Some(STORE_VERSION));
        let cells = doc.get("cells").unwrap();
        assert_eq!(
            cells.get(&k1.file_name()).and_then(|v| v.as_str()),
            Some(k1.id().as_str()),
            "sidecar records the key id"
        );
        // a fresh store on the same dir serves hits straight off the
        // sidecar (no rebuild scan needed — but behavior is identical)
        let store2 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store2.len(), 2);
        assert!(store2.get(&k1).is_some());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn missing_or_corrupt_index_degrades_to_a_directory_scan() {
        let store = tmp_store("index_degrade");
        let k = key("w:fp32:8 a:fp32:8 g:hindsight:8", 4, 20);
        let rec = record("cell");
        store.put(&k, &rec).unwrap();
        // missing sidecar: a fresh store must still find the cell
        std::fs::remove_file(store.dir().join(INDEX_FILE)).unwrap();
        let store2 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store2.get(&k).unwrap(), rec, "scan rebuild must find the cell");
        assert_eq!(store2.len(), 1);
        assert!(
            store2.dir().join(INDEX_FILE).exists(),
            "rebuilt index must be persisted"
        );
        // corrupt sidecar: same degradation
        std::fs::write(store.dir().join(INDEX_FILE), "not json at all").unwrap();
        let store3 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store3.get(&k).unwrap(), rec);
        // wrong-version sidecar: treated as stale, rebuilt by scan
        std::fs::write(
            store.dir().join(INDEX_FILE),
            "{\"version\": 99, \"cells\": {}}",
        )
        .unwrap();
        let store4 = RunStore::open(store.dir()).unwrap();
        assert_eq!(store4.len(), 1);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn unindexed_keys_miss_without_a_file_probe() {
        let store = tmp_store("index_miss");
        let k1 = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        store.put(&k1, &record("a")).unwrap();
        // a key the index has never seen is a miss straight from memory
        let absent = key("w:fp32:8 a:fp32:8 g:tqt:8", 9, 10);
        assert!(store.get(&absent).is_none());
        // an index entry whose file vanished is a plain miss too (the
        // document read fails), never a panic
        std::fs::remove_file(store.dir().join(k1.file_name())).unwrap();
        assert!(store.get(&k1).is_none());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn concurrent_writers_merge_instead_of_clobbering_the_sidecar() {
        // two *store handles* on one dir model two processes: each
        // caches its own in-memory index, so without merge-before-write
        // the second handle's put would drop the first handle's entry
        let store_a = tmp_store("two_writers");
        let store_b = RunStore::open(store_a.dir()).unwrap();
        let ka = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        let kb = key("w:fp32:8 a:fp32:8 g:current:8", 1, 10);
        // interleave: A loads its index (empty), B loads its index
        // (empty), A puts, B puts — B's sidecar write races A's
        assert!(store_a.is_empty());
        assert!(store_b.is_empty());
        store_a.put(&ka, &record("a")).unwrap();
        store_b.put(&kb, &record("b")).unwrap();
        // a third, fresh reader sees BOTH cells straight off the sidecar
        let reader = RunStore::open(store_a.dir()).unwrap();
        assert_eq!(reader.len(), 2, "merge-before-write must keep A's entry");
        assert!(reader.get(&ka).is_some());
        assert!(reader.get(&kb).is_some());
        // and the sidecar ids are the real key ids, not scan stubs
        let doc = json::parse(
            &std::fs::read_to_string(store_a.dir().join(INDEX_FILE)).unwrap(),
        )
        .unwrap();
        let cells = doc.get("cells").unwrap();
        assert_eq!(cells.get(&ka.file_name()).and_then(|v| v.as_str()), Some(ka.id().as_str()));
        assert_eq!(cells.get(&kb.file_name()).and_then(|v| v.as_str()), Some(kb.id().as_str()));
        let _ = std::fs::remove_dir_all(store_a.dir());
    }

    #[test]
    fn threaded_writers_stress_the_sidecar_race() {
        let store = tmp_store("threaded_writers");
        let dir = store.dir().to_path_buf();
        let n_per = 8usize;
        let handles: Vec<_> = (0..2u64)
            .map(|t| {
                let dir = dir.clone();
                std::thread::spawn(move || {
                    let s = RunStore::open(dir).unwrap();
                    for i in 0..n_per {
                        let k = key(
                            &format!("w:fp32:8 a:fp32:8 g:hindsight:{}", 2 + i),
                            t,
                            10,
                        );
                        s.put(&k, &record(&format!("t{t}i{i}"))).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // every written cell must be servable from a fresh store; the
        // worst a lost sidecar race may cost is a *stale* (missing)
        // entry — refresh's directory scan recovers exactly those
        let fresh = RunStore::open(&dir).unwrap();
        fresh.refresh();
        for t in 0..2u64 {
            for i in 0..n_per {
                let k = key(&format!("w:fp32:8 a:fp32:8 g:hindsight:{}", 2 + i), t, 10);
                assert!(
                    fresh.get(&k).is_some(),
                    "cell t{t}i{i} lost — sidecar race dropped a committed cell"
                );
            }
        }
        assert_eq!(fresh.len(), 2 * n_per);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_sees_cells_written_by_another_handle() {
        let store = tmp_store("refresh");
        let other = RunStore::open(store.dir()).unwrap();
        let k1 = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        let k2 = key("w:fp32:8 a:fp32:8 g:current:8", 1, 10);
        store.put(&k1, &record("mine")).unwrap();
        assert!(store.get(&k2).is_none(), "not written yet");
        other.put(&k2, &record("theirs")).unwrap();
        // without refresh, `store`'s in-memory index predates k2
        assert!(store.get(&k2).is_none(), "index answer is stale by design");
        store.refresh();
        assert!(store.get(&k2).is_some(), "refresh must surface the sibling's cell");
        assert!(store.get(&k1).is_some(), "refresh must not lose own entries");
        assert_eq!(store.len(), 2);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn gc_prunes_skewed_and_mismatched_keeps_corrupt_and_rebuilds_index() {
        let store = tmp_store("gc");
        let good = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        store.put(&good, &record("good")).unwrap();
        // version-skewed cell file
        std::fs::write(
            store.dir().join("cell-00000000000000aa.json"),
            "{\"version\": 99, \"model\": \"mlp\"}",
        )
        .unwrap();
        // key-mismatched: a valid document copied under the wrong name
        let stray = key("w:fp32:8 a:fp32:8 g:current:8", 2, 10);
        let src = store.put(&stray, &record("stray")).unwrap();
        let wrong_name = store.dir().join("cell-00000000000000bb.json");
        std::fs::copy(&src, &wrong_name).unwrap();
        // corrupt (unparseable) cell file — must be kept, only counted
        let corrupt_name = store.dir().join("cell-00000000000000cc.json");
        std::fs::write(&corrupt_name, "{\"version\":").unwrap();
        // stale temp dropping from an interrupted writer
        std::fs::write(store.dir().join(".tmp-999-cell-x.json"), "{}").unwrap();
        let report = store.gc().unwrap();
        assert_eq!(report.kept, 2, "good + stray-at-its-own-name survive");
        assert_eq!(report.removed_skewed, 1);
        assert_eq!(report.removed_mismatched, 1);
        assert_eq!(report.removed_tmp, 1);
        assert_eq!(report.corrupt, 1);
        assert!(!wrong_name.exists());
        assert!(corrupt_name.exists(), "gc must not delete unparseable data");
        // the rebuilt sidecar lists exactly the kept cells with real ids
        let fresh = RunStore::open(store.dir()).unwrap();
        assert!(fresh.get(&good).is_some());
        assert!(fresh.get(&stray).is_some());
        let entries = fresh.entries();
        assert!(entries.iter().any(|(f, id)| *f == good.file_name() && *id == good.id()));
        // verify reports exactly the kept-but-corrupt file
        let bad = store.verify();
        assert_eq!(bad.len(), 1);
        assert!(bad[0].0.contains("00000000000000cc"));
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn read_cell_file_round_trips_and_entries_lists_sorted() {
        let store = tmp_store("read_cell");
        let k = key("w:fp32:8 a:fp32:8 g:hindsight:8", 5, 30);
        let rec = record("inspect");
        store.put(&k, &rec).unwrap();
        let files = store.files();
        assert_eq!(files, vec![k.file_name()]);
        let (stored_key, stored_rec) = store.read_cell_file(&files[0]).unwrap();
        assert_eq!(stored_key, k);
        assert_eq!(stored_rec, rec);
        assert!(store.read_cell_file("cell-nope.json").is_err());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn doc_cache_parses_once_and_shares_the_parse() {
        let store = tmp_store("doc_cache");
        let k = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        store.put(&k, &record("cell")).unwrap();
        assert_eq!(store.doc_parses(), 0, "put must not read the file back");
        let d1 = store.get_doc(&k).unwrap();
        assert_eq!(store.doc_parses(), 1);
        let d2 = store.get_doc(&k).unwrap();
        let d3 = store.get_doc(&k).unwrap();
        assert!(Arc::ptr_eq(&d1, &d2), "unchanged file must share one parse");
        assert!(Arc::ptr_eq(&d1, &d3));
        assert_eq!(store.doc_parses(), 1, "repeat lookups must not re-parse");
        assert!(store.doc_hits() >= 2);
        // plain get rides the same cache
        assert_eq!(store.get(&k).unwrap(), d1.record);
        assert_eq!(store.doc_parses(), 1);
        // the pre-serialized record bytes are the canonical serialization
        assert_eq!(*d1.record_json, d1.record.to_json().to_string());
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn doc_cache_invalidates_when_the_file_is_rewritten() {
        let store = tmp_store("doc_cache_rewrite");
        let k = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        // different name lengths => different file lengths, so the
        // (len, mtime) check can't be fooled by coarse mtime granularity
        store.put(&k, &record("short")).unwrap();
        let d1 = store.get_doc(&k).unwrap();
        // a sibling handle (another process) rewrites the same cell
        let sibling = RunStore::open(store.dir()).unwrap();
        sibling.put(&k, &record("a-much-longer-name")).unwrap();
        let d2 = store.get_doc(&k).unwrap();
        assert!(!Arc::ptr_eq(&d1, &d2), "rewritten file must re-parse");
        assert_eq!(d2.record.name, "a-much-longer-name");
        assert_ne!(d1.fingerprint, d2.fingerprint);
        assert_eq!(store.doc_parses(), 2);
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn doc_cache_degrades_corrupt_files_to_cached_misses() {
        let store = tmp_store("doc_cache_corrupt");
        let k = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        store.put(&k, &record("good")).unwrap();
        assert!(store.get_doc(&k).is_some());
        // corrupt the file in place (longer than the original, so the
        // snapshot check sees the change regardless of mtime)
        let path = store.dir().join(k.file_name());
        let garbage = format!("{{\"version\": {}", "x".repeat(4096));
        std::fs::write(&path, garbage).unwrap();
        assert!(store.get(&k).is_none(), "corrupt file must miss, not panic");
        let parses = store.doc_parses();
        assert!(store.get(&k).is_none());
        assert!(store.get_doc(&k).is_none());
        assert_eq!(store.doc_parses(), parses, "known-bad file must not re-parse");
        // a valid rewrite heals the slot
        store.put(&k, &record("healed")).unwrap();
        assert_eq!(store.get(&k).unwrap().name, "healed");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn refresh_skips_sidecar_reparse_when_unchanged() {
        let store = tmp_store("refresh_gate");
        let k = key("w:fp32:8 a:fp32:8 g:hindsight:8", 1, 10);
        store.put(&k, &record("cell")).unwrap();
        // json::count is process-global; other tests run concurrently,
        // so assert through behavior instead: repeated refresh on an
        // unchanged store must keep serving the cell and stay cheap
        store.refresh();
        let d1 = store.get_doc(&k).unwrap();
        for _ in 0..5 {
            store.refresh();
        }
        let d2 = store.get_doc(&k).unwrap();
        assert!(Arc::ptr_eq(&d1, &d2), "refresh must not drop cached docs");
        assert_eq!(store.doc_parses(), 1);
        // a sibling's write (sidecar mtime/len change) is still seen
        let sibling = RunStore::open(store.dir()).unwrap();
        let k2 = key("w:fp32:8 a:fp32:8 g:current:8", 1, 10);
        sibling.put(&k2, &record("theirs")).unwrap();
        store.refresh();
        assert!(store.get(&k2).is_some(), "refresh must surface sibling writes");
        let _ = std::fs::remove_dir_all(store.dir());
    }

    #[test]
    fn cell_key_of_config_uses_the_canonical_scheme() {
        use crate::coordinator::config::Estimator;
        let mut cfg = TrainConfig::new("mlp").fully_quantized(Estimator::HINDSIGHT);
        cfg.seed = 7;
        cfg.steps = 50;
        let key = CellKey::of(&cfg);
        assert_eq!(key.scheme, "w:current:8 a:hindsight:8 g:hindsight:8");
        assert_eq!(key.seed, 7);
        assert_eq!(key.steps, 50);
        assert_eq!(key.config, CellKey::config_digest(&cfg));
        assert!(key.config.contains("lr=0.05"), "{}", key.config);
        assert!(key.file_name().starts_with("cell-"));
        assert!(key.file_name().ends_with(".json"));
    }
}

//! The range-estimation state machine (paper Sec. 4, realized).
//!
//! The compiled graph takes the range state as an *input* and returns
//! two tensors of the same shape: `new_ranges` (the state-update each
//! estimator mode prescribes, computed in-graph) and `stats` (the raw
//! accumulator min/max of the step — paper Fig. 3).  This module owns
//! what happens *between* steps — but no longer knows any estimator's
//! semantics: each quantizer site carries a boxed
//! [`RangeEstimator`](crate::estimator::RangeEstimator) instantiated
//! from the registry, and `RangeManager` just routes the graph outputs
//! through the per-site `absorb_step_rows` / `absorb_calibration_rows`
//! hooks and the periodic `search_rows` hook for estimators that
//! declare `needs_search` (DSGC, sampled min-max).
//!
//! **Row layout.**  The graph ABI is one dense f32 tensor of shape
//! `(R, 2)` where `R` is the total number of range rows: each site
//! contributes a contiguous *row group* — one row for per-tensor sites,
//! `n_channels` rows for per-channel sites (channels-last: channel `c`
//! of a site owns row `offset(site) + c`).  A site→row-offset table maps
//! between the two indexings; with every site per-tensor (the paper's
//! setting) `R == Q` and the layout degenerates to the original one row
//! per site, bit-for-bit (golden parity tests below pin this).
//!
//! **Scheme resolution.**  Construction takes a
//! [`QuantScheme`](crate::scheme::QuantScheme) and resolves each site's
//! [`QuantSpec`](crate::scheme::QuantSpec) once (class spec, or a
//! per-site override keyed by site name): the spec's estimator and
//! granularity pick the trait object, its `eta` drives calibration, its
//! `bits` drive the periodic search, and its `symmetric` flag
//! symmetrizes every row the coordinator adopts — no loose knobs are
//! threaded through the call sites anymore.

use crate::coordinator::config::Estimator;
use crate::estimator::{RangeEstimator, StepCtx};
use crate::quant::kernel;
use crate::runtime::manifest::{ModelSpec, SiteKind};
use crate::runtime::tensor::Tensor;
use crate::scheme::{QuantScheme, QuantSpec, TensorClass};

/// The tensor class a quantizer site belongs to.
fn class_of(kind: SiteKind) -> TensorClass {
    match kind {
        SiteKind::Act => TensorClass::Activations,
        SiteKind::Grad => TensorClass::Gradients,
    }
}

/// Force rows onto a zero-symmetric grid: `[-m, m]`, `m = max(|lo|, |hi|)`.
fn symmetrize(rows: &mut [[f32; 2]]) {
    for r in rows {
        let m = (-r[0]).max(r[1]).max(0.0);
        *r = [-m, m];
    }
}

/// Per-quantizer range state + delegated estimator semantics.
#[derive(Debug, Clone)]
pub struct RangeManager {
    /// (R, 2) rows: [qmin, qmax] per channel group, all sites flattened
    ranges: Vec<[f32; 2]>,
    /// site → first row; `offsets[i]..offsets[i+1]` is site i's group
    offsets: Vec<usize>,
    kinds: Vec<SiteKind>,
    /// the configured scheme (class specs + overrides)
    scheme: QuantScheme,
    /// each site's resolved spec (override or class spec)
    site_specs: Vec<QuantSpec>,
    /// one estimator instance per site (owns any per-site state)
    sites: Vec<Box<dyn RangeEstimator>>,
    /// last raw stats observed per row (diagnostics, saturation tracking)
    last_stats: Vec<[f32; 2]>,
    /// per-site measured kernel pick (filled by calibration autotuning)
    tuned: Vec<Option<kernel::Autotune>>,
    calibrated: bool,
}

impl RangeManager {
    pub fn new(model: &ModelSpec, scheme: &QuantScheme) -> Self {
        let kinds: Vec<SiteKind> = model.sites.iter().map(|s| s.kind).collect();
        let mut sites: Vec<Box<dyn RangeEstimator>> = Vec::with_capacity(kinds.len());
        let mut site_specs: Vec<QuantSpec> = Vec::with_capacity(kinds.len());
        let mut offsets = Vec::with_capacity(kinds.len() + 1);
        offsets.push(0usize);
        for s in &model.sites {
            let spec = scheme.site_spec(class_of(s.kind), &s.name);
            let inst = spec.instantiate_site(s.channels());
            offsets.push(offsets.last().unwrap() + inst.n_rows());
            sites.push(inst);
            site_specs.push(spec);
        }
        let mut ranges = Vec::with_capacity(*offsets.last().unwrap());
        for e in &sites {
            for _ in 0..e.n_rows() {
                ranges.push(e.init());
            }
        }
        Self {
            last_stats: vec![[0.0, 0.0]; ranges.len()],
            tuned: vec![None; kinds.len()],
            ranges,
            offsets,
            kinds,
            scheme: scheme.clone(),
            site_specs,
            sites,
            calibrated: false,
        }
    }

    /// Manager over an *analytic* workload: builds the synthetic
    /// [`ModelSpec`] of a [`LayerGeom`](crate::simulator::LayerGeom)
    /// graph (one site per quantizer of each layer's site plan, heads as
    /// the trailing channel axis for attention) and resolves `scheme`
    /// against it — the entry point for end-to-end range estimation on
    /// workloads with no compiled artifacts.
    pub fn for_workload(
        name: &str,
        layers: &[crate::simulator::LayerGeom],
        scheme: &QuantScheme,
    ) -> Self {
        Self::new(&crate::simulator::workload_spec(name, layers), scheme)
    }

    /// The scheme this manager was built from.
    pub fn scheme(&self) -> &QuantScheme {
        &self.scheme
    }

    /// Site `i`'s resolved spec (class spec or per-site override).
    pub fn site_spec(&self, i: usize) -> &QuantSpec {
        &self.site_specs[i]
    }

    /// The activation-class estimator (graph-ABI scalar source).
    pub fn act_est(&self) -> Estimator {
        self.scheme.activations.estimator
    }

    /// The gradient-class estimator (graph-ABI scalar source).
    pub fn grad_est(&self) -> Estimator {
        self.scheme.gradients.estimator
    }

    pub fn n_sites(&self) -> usize {
        self.kinds.len()
    }

    /// Total range rows R across all sites (== n_sites when every site
    /// is per-tensor).
    pub fn n_rows(&self) -> usize {
        self.ranges.len()
    }

    /// First row index of site `i` in the flat (R, 2) layout.
    pub fn row_offset(&self, i: usize) -> usize {
        self.offsets[i]
    }

    /// All of site `i`'s rows (one per channel group).
    pub fn site_rows(&self, i: usize) -> &[[f32; 2]] {
        &self.ranges[self.offsets[i]..self.offsets[i + 1]]
    }

    /// The (R, 2) tensor fed to the graph this step.
    pub fn as_tensor(&self) -> Tensor {
        let mut data = Vec::with_capacity(self.ranges.len() * 2);
        for r in &self.ranges {
            data.extend_from_slice(r);
        }
        Tensor::from_f32(&[self.ranges.len(), 2], data)
    }

    /// Site `i`'s first row (its only row for per-tensor sites).
    pub fn row(&self, i: usize) -> [f32; 2] {
        self.ranges[self.offsets[i]]
    }

    /// Set every row of site `i` to `r` (one row for per-tensor sites).
    pub fn set_row(&mut self, i: usize, r: [f32; 2]) {
        for row in self.offsets[i]..self.offsets[i + 1] {
            self.ranges[row] = r;
        }
    }

    /// Site `i`'s most recent raw stats (first row of its group).
    pub fn last_stats(&self, i: usize) -> [f32; 2] {
        self.last_stats[self.offsets[i]]
    }

    /// Scalar ABI values for the train graph.
    pub fn mode_act(&self) -> f32 {
        self.act_est().mode()
    }

    pub fn mode_grad(&self) -> f32 {
        self.grad_est().mode()
    }

    pub fn aq_on(&self) -> f32 {
        self.act_est().enabled() as u32 as f32
    }

    pub fn gq_on(&self) -> f32 {
        self.grad_est().enabled() as u32 as f32
    }

    /// Absorb one training step's outputs: each site's estimator sees
    /// `{current row, raw stats, in-graph update}` for every row of its
    /// group and returns the rows the next step quantizes with.
    ///
    /// `first_step` lets uncalibrated estimators implement the paper's
    /// initialization `q^0 = minmax(G^0)`.
    pub fn update(&mut self, new_ranges: &Tensor, stats: &Tensor, first_step: bool) {
        let nr = new_ranges.as_f32().expect("new_ranges f32");
        let st = stats.as_f32().expect("stats f32");
        let r = self.ranges.len();
        assert_eq!(nr.len(), 2 * r, "new_ranges has {} values, want 2 x {r} rows", nr.len());
        assert_eq!(st.len(), 2 * r, "stats has {} values, want 2 x {r} rows", st.len());
        let mut ctxs: Vec<StepCtx> = Vec::new();
        for i in 0..self.kinds.len() {
            let (start, end) = (self.offsets[i], self.offsets[i + 1]);
            ctxs.clear();
            for row in start..end {
                self.last_stats[row] = [st[2 * row], st[2 * row + 1]];
                ctxs.push(StepCtx {
                    current: self.ranges[row],
                    stats: self.last_stats[row],
                    new_ranges: [nr[2 * row], nr[2 * row + 1]],
                    first_step,
                    calibrated: self.calibrated,
                });
            }
            let (sites, ranges) = (&mut self.sites, &mut self.ranges);
            sites[i].absorb_step_rows(&ctxs, &mut ranges[start..end]);
            if self.site_specs[i].symmetric {
                symmetrize(&mut ranges[start..end]);
            }
        }
    }

    /// Absorb one *calibration* batch (paper Sec. 5.2: feed a few batches
    /// through the network before training to set activation ranges).
    /// Each site blends with its own spec's `eta`.
    pub fn calibrate(&mut self, stats: &Tensor) {
        let st = stats.as_f32().expect("stats f32");
        let r = self.ranges.len();
        assert_eq!(st.len(), 2 * r, "stats has {} values, want 2 x {r} rows", st.len());
        let mut cur: Vec<[f32; 2]> = Vec::new();
        let mut obs: Vec<[f32; 2]> = Vec::new();
        for i in 0..self.kinds.len() {
            let (start, end) = (self.offsets[i], self.offsets[i + 1]);
            cur.clear();
            obs.clear();
            for row in start..end {
                let s = [st[2 * row], st[2 * row + 1]];
                cur.push(self.ranges[row]);
                obs.push(s);
                self.last_stats[row] = s;
            }
            let first = !self.calibrated;
            let eta = self.site_specs[i].eta;
            let (sites, ranges) = (&mut self.sites, &mut self.ranges);
            sites[i].absorb_calibration_rows(&cur, &obs, eta, first, &mut ranges[start..end]);
            if self.site_specs[i].symmetric {
                symmetrize(&mut ranges[start..end]);
            }
        }
        self.calibrated = true;
    }

    pub fn is_calibrated(&self) -> bool {
        self.calibrated
    }

    /// Record the measured kernel pick for site `i` (calibration-time
    /// autotuning over the site's actual tensor shape).
    pub fn set_site_autotune(&mut self, i: usize, at: kernel::Autotune) {
        self.tuned[i] = Some(at);
    }

    /// Site `i`'s measured kernel pick, if autotuning ran.
    pub fn site_autotune(&self, i: usize) -> Option<kernel::Autotune> {
        self.tuned[i]
    }

    /// The measured backend of the *largest* tuned site — the pick a
    /// process-wide `--kernel-backend auto` adopts (the biggest tensor
    /// dominates traffic, so its winner is the least-bad single choice).
    pub fn tuned_backend(&self) -> Option<kernel::KernelBackend> {
        let mut best: Option<kernel::Autotune> = None;
        for at in self.tuned.iter().flatten() {
            if best.map(|b| at.elems > b.elems).unwrap_or(true) {
                best = Some(*at);
            }
        }
        best.map(|b| b.backend)
    }

    /// Site indices the periodic search pass must visit: gradient sites
    /// whose *own* estimator declares `needs_search` — consulted
    /// per-site, not from the config-level gradient estimator, so mixed
    /// and per-channel site populations resolve correctly.  (The dump
    /// graph only materializes gradient tensors, hence the kind filter.)
    pub fn search_sites(&self) -> Vec<usize> {
        (0..self.kinds.len())
            .filter(|&i| self.kinds[i] == SiteKind::Grad && self.sites[i].needs_search())
            .collect()
    }

    /// Whether any gradient site requires the periodic dump-graph search
    /// pass (allocation-free form of `!search_sites().is_empty()`).
    pub fn needs_search_pass(&self) -> bool {
        self.kinds
            .iter()
            .zip(&self.sites)
            .any(|(k, s)| *k == SiteKind::Grad && s.needs_search())
    }

    /// Run one site's tensor-level search and adopt the resulting rows
    /// (per-channel sites search each channel's strided slice).  The
    /// search runs at the site's own spec bit-width.  Returns the
    /// search's cost in tensor traversals.
    pub fn search_site(&mut self, i: usize, tensor: &[f32], iters: u32) -> u32 {
        let (start, end) = (self.offsets[i], self.offsets[i + 1]);
        let bits = self.site_specs[i].bits;
        let (sites, ranges) = (&mut self.sites, &mut self.ranges);
        let evals = sites[i].search_rows(tensor, bits, iters, &mut ranges[start..end]);
        if self.site_specs[i].symmetric {
            symmetrize(&mut ranges[start..end]);
        }
        evals
    }

    /// Mean saturation headroom diagnostic: how much of the last stats
    /// interval the current ranges cover (1.0 = fully covered).
    pub fn coverage(&self) -> f64 {
        let mut acc = 0.0;
        let mut n = 0;
        for i in 0..self.ranges.len() {
            let w_stats = self.last_stats[i][1] - self.last_stats[i][0];
            if w_stats <= 0.0 {
                continue;
            }
            let lo = self.ranges[i][0].max(self.last_stats[i][0]);
            let hi = self.ranges[i][1].min(self.last_stats[i][1]);
            acc += ((hi - lo).max(0.0) / w_stats) as f64;
            n += 1;
        }
        if n == 0 {
            1.0
        } else {
            acc / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ema_update;
    use crate::runtime::manifest::{LeafSpec, ModelSpec, SiteSpec};
    use crate::util::rng::Pcg32;
    use crate::util::testkit::forall;

    fn model_ch(n_act: usize, n_grad: usize, channels: usize) -> ModelSpec {
        let mut sites = Vec::new();
        for i in 0..n_act + n_grad {
            sites.push(SiteSpec {
                index: i,
                name: format!("s{i}"),
                kind: if i < n_act { SiteKind::Act } else { SiteKind::Grad },
                feature_shape: vec![channels],
            });
        }
        ModelSpec {
            name: "m".into(),
            batch_size: 2,
            input_shape: vec![2, 2, 3],
            n_classes: 4,
            n_params: 10,
            pallas: "none".into(),
            params: vec![LeafSpec { name: "w".into(), shape: vec![2] }],
            state: vec![],
            sites,
            graphs: vec![],
        }
    }

    fn model(n_act: usize, n_grad: usize) -> ModelSpec {
        model_ch(n_act, n_grad, 4)
    }

    fn t(q: usize, vals: &[f32]) -> Tensor {
        Tensor::from_f32(&[q, 2], vals.to_vec())
    }

    /// Scheme with the given per-class estimators at defaults (the old
    /// two-knob constructor, as a scheme).
    fn scheme2(act: Estimator, grad: Estimator) -> QuantScheme {
        QuantScheme::fp32().act_est(act).grad_est(grad)
    }

    fn mgr(m: &ModelSpec, act: Estimator, grad: Estimator) -> RangeManager {
        RangeManager::new(m, &scheme2(act, grad))
    }

    #[test]
    fn first_step_adopts_raw_stats() {
        let m = model(1, 1);
        let mut rm = mgr(&m, Estimator::HINDSIGHT, Estimator::HINDSIGHT);
        let nr = t(2, &[-0.5, 0.5, -0.1, 0.1]);
        let st = t(2, &[-2.0, 3.0, -4.0, 5.0]);
        rm.update(&nr, &st, true);
        assert_eq!(rm.row(0), [-2.0, 3.0]);
        assert_eq!(rm.row(1), [-4.0, 5.0]);
        // subsequent steps adopt the graph's EMA output
        rm.update(&nr, &st, false);
        assert_eq!(rm.row(0), [-0.5, 0.5]);
    }

    #[test]
    fn fp32_rows_frozen() {
        let m = model(1, 1);
        let mut rm = mgr(&m, Estimator::FP32, Estimator::HINDSIGHT);
        let before = rm.row(0);
        rm.update(&t(2, &[9.0, 9.0, -1.0, 1.0]), &t(2, &[0.0, 1.0, 0.0, 1.0]), false);
        assert_eq!(rm.row(0), before); // act site untouched (FP32)
        assert_eq!(rm.row(1), [-1.0, 1.0]); // grad site updated
        assert_eq!(rm.aq_on(), 0.0);
        assert_eq!(rm.gq_on(), 1.0);
    }

    #[test]
    fn dsgc_rows_held_between_searches() {
        let m = model(1, 2);
        let mut rm = mgr(&m, Estimator::CURRENT, Estimator::DSGC);
        rm.set_row(1, [-7.0, 7.0]); // pretend a search happened
        rm.calibrate(&t(3, &[0.0; 6])); // mark calibrated
        rm.set_row(1, [-7.0, 7.0]);
        rm.update(
            &t(3, &[0.0, 1.0, -1.0, 1.0, -1.0, 1.0]),
            &t(3, &[0.0, 2.0, -2.0, 2.0, -2.0, 2.0]),
            false,
        );
        assert_eq!(rm.row(1), [-7.0, 7.0]); // held
        assert_eq!(rm.search_sites(), vec![1, 2]);
        assert!(rm.needs_search_pass());
        // act sites are never search sites
        let rm2 = mgr(&m, Estimator::DSGC, Estimator::CURRENT);
        assert!(rm2.search_sites().is_empty());
        assert!(!rm2.needs_search_pass());
    }

    #[test]
    fn search_site_adopts_the_searched_range() {
        let m = model(0, 1);
        let mut rm = mgr(&m, Estimator::CURRENT, Estimator::SAMPLED_MINMAX);
        assert_eq!(rm.search_sites(), vec![0]);
        let g: Vec<f32> = (0..4096).map(|i| ((i % 513) as f32 / 256.0) - 1.0).collect();
        let evals = rm.search_site(0, &g, 0);
        assert_eq!(evals, 1);
        let r = rm.row(0);
        assert!(r[0] <= -0.9 && r[1] >= 0.9, "{r:?}");
    }

    #[test]
    fn calibration_seeds_then_emas_with_the_spec_eta() {
        let m = model(2, 0);
        let scheme = scheme2(Estimator::HINDSIGHT, Estimator::FP32).eta_all(0.5);
        let mut rm = RangeManager::new(&m, &scheme);
        rm.calibrate(&t(2, &[-1.0, 1.0, -2.0, 2.0]));
        assert_eq!(rm.row(0), [-1.0, 1.0]);
        rm.calibrate(&t(2, &[-3.0, 3.0, -2.0, 2.0]));
        assert_eq!(rm.row(0), [-2.0, 2.0]); // 0.5 blend from the spec eta
        assert!(rm.is_calibrated());
    }

    #[test]
    fn tensor_roundtrip_and_coverage() {
        let m = model(1, 0);
        let mut rm = mgr(&m, Estimator::HINDSIGHT, Estimator::FP32);
        rm.set_row(0, [-1.0, 1.0]);
        let t = rm.as_tensor();
        assert_eq!(t.shape, vec![1, 2]);
        assert_eq!(t.as_f32().unwrap(), &[-1.0, 1.0]);
        // stats wider than range => coverage < 1
        rm.update(
            &Tensor::from_f32(&[1, 2], vec![-1.0, 1.0]),
            &Tensor::from_f32(&[1, 2], vec![-2.0, 2.0]),
            false,
        );
        assert!(rm.coverage() < 1.0);
    }

    #[test]
    fn maxhist_rows_track_the_window_hull() {
        let m = model(1, 1);
        let mut rm = mgr(&m, Estimator::MAX_HISTORY, Estimator::MAX_HISTORY);
        rm.update(&t(2, &[0.0; 4]), &t(2, &[-1.0, 1.0, -2.0, 2.0]), true);
        assert_eq!(rm.row(0), [-1.0, 1.0]);
        rm.update(&t(2, &[0.0; 4]), &t(2, &[-0.5, 3.0, -1.0, 1.0]), false);
        // hull over both observations, not an EMA
        assert_eq!(rm.row(0), [-1.0, 3.0]);
        assert_eq!(rm.row(1), [-2.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "stats has")]
    fn update_rejects_short_stats_tensor() {
        // regression: only new_ranges used to be length-checked, so a
        // short stats tensor died with an unhelpful index panic
        let m = model(1, 1);
        let mut rm = mgr(&m, Estimator::HINDSIGHT, Estimator::HINDSIGHT);
        rm.update(&t(2, &[0.0; 4]), &t(1, &[0.0; 2]), false);
    }

    // ------------------------------------------------------------------
    // Scheme resolution: overrides, symmetry, per-site bits/eta
    // ------------------------------------------------------------------

    #[test]
    fn per_site_overrides_resolve_by_site_name() {
        let m = model(1, 2); // sites s0 (act), s1, s2 (grad)
        let scheme = scheme2(Estimator::HINDSIGHT, Estimator::HINDSIGHT)
            .override_site_str("s2", "dsgc:4")
            .unwrap();
        let rm = RangeManager::new(&m, &scheme);
        assert_eq!(rm.site_spec(1).estimator, Estimator::HINDSIGHT);
        assert_eq!(rm.site_spec(2).estimator, Estimator::DSGC);
        assert_eq!(rm.site_spec(2).bits, 4);
        // only the overridden grad site needs the search pass
        assert_eq!(rm.search_sites(), vec![2]);
        assert!(rm.needs_search_pass());
    }

    #[test]
    fn search_runs_at_the_sites_own_bits() {
        // 3-bit vs 8-bit DSGC searches clip differently on a heavy tail
        let m = model(0, 1);
        let mut g = vec![0.0f32; 4096];
        let mut rng = Pcg32::new(3, 1);
        for v in g.iter_mut() {
            *v = rng.normal() * 0.02;
        }
        g[0] = 1.0; // one outlier the low-bit search should clip away
        let mk = |bits: u32| {
            let mut s = scheme2(Estimator::CURRENT, Estimator::DSGC);
            s.gradients.bits = bits;
            s
        };
        let mut rm3 = RangeManager::new(&m, &mk(3));
        let mut rm8 = RangeManager::new(&m, &mk(8));
        rm3.search_site(0, &g, 8);
        rm8.search_site(0, &g, 8);
        assert!(
            rm3.row(0)[1] < rm8.row(0)[1],
            "3-bit search must clip harder: {:?} vs {:?}",
            rm3.row(0),
            rm8.row(0)
        );
    }

    #[test]
    fn symmetric_specs_clamp_every_adopted_row() {
        let m = model(1, 1);
        let mut scheme = scheme2(Estimator::HINDSIGHT, Estimator::HINDSIGHT);
        scheme.gradients.symmetric = true;
        let mut rm = RangeManager::new(&m, &scheme);
        // calibration: act row keeps the raw stats, grad row symmetrizes
        rm.calibrate(&t(2, &[-1.0, 2.0, -1.0, 3.0]));
        assert_eq!(rm.row(0), [-1.0, 2.0]);
        assert_eq!(rm.row(1), [-3.0, 3.0]);
        // step adoption symmetrizes too
        rm.update(&t(2, &[-0.5, 0.25, -0.5, 0.25]), &t(2, &[0.0; 4]), false);
        assert_eq!(rm.row(0), [-0.5, 0.25]);
        assert_eq!(rm.row(1), [-0.5, 0.5]);
    }

    // ------------------------------------------------------------------
    // Per-channel layout
    // ------------------------------------------------------------------

    #[test]
    fn per_channel_sites_expand_the_row_table() {
        let m = model_ch(1, 1, 3);
        let pc = Estimator::HINDSIGHT.per_channel();
        let rm = mgr(&m, pc, Estimator::HINDSIGHT);
        // act site: 3 rows (per-channel); grad site: 1 (per-tensor)
        assert_eq!(rm.n_sites(), 2);
        assert_eq!(rm.n_rows(), 4);
        assert_eq!(rm.row_offset(0), 0);
        assert_eq!(rm.row_offset(1), 3);
        assert_eq!(rm.site_rows(0).len(), 3);
        assert_eq!(rm.as_tensor().shape, vec![4, 2]);
    }

    #[test]
    fn per_channel_rows_update_independently() {
        let m = model_ch(1, 0, 2);
        let pc = Estimator::MAX_HISTORY.per_channel();
        let mut rm = mgr(&m, pc, Estimator::FP32);
        // R = 2 rows; feed different stats per channel
        rm.update(&t(2, &[0.0; 4]), &t(2, &[-1.0, 1.0, -5.0, 0.5]), true);
        assert_eq!(rm.site_rows(0), &[[-1.0, 1.0], [-5.0, 0.5]]);
        rm.update(&t(2, &[0.0; 4]), &t(2, &[-2.0, 0.5, -1.0, 1.0]), false);
        // each channel hulls only its own history
        assert_eq!(rm.site_rows(0), &[[-2.0, 1.0], [-5.0, 1.0]]);
    }

    #[test]
    fn per_channel_search_sites_and_search() {
        let m = model_ch(0, 1, 2);
        let pc = Estimator::SAMPLED_MINMAX.per_channel();
        let mut rm = mgr(&m, Estimator::CURRENT, pc);
        // search_sites consults the per-site estimator, not the config
        assert_eq!(rm.search_sites(), vec![0]);
        // even channel ~[-1,1], odd channel ~[-4,4]
        let mut rng = Pcg32::new(9, 1);
        let g: Vec<f32> = (0..4096)
            .map(|i| if i % 2 == 0 { rng.range(-1.0, 1.0) } else { rng.range(-4.0, 4.0) })
            .collect();
        let evals = rm.search_site(0, &g, 0);
        assert_eq!(evals, 2);
        let rows = rm.site_rows(0);
        assert!(rows[0][1] < 1.5 && rows[1][1] > 3.0, "{rows:?}");
    }

    /// Satellite acceptance: an `@pc` gradient scheme on the attention
    /// workload yields one range row per *head* on the score-gradient
    /// site — heads are the trailing channel axis of the site plan.
    #[test]
    fn attention_workload_groups_gradient_rows_per_head() {
        use crate::simulator::LayerGeom;
        let layers = [LayerGeom::attention("attn", 16, 32, 4, 8)];
        let scheme = scheme2(Estimator::HINDSIGHT, Estimator::HINDSIGHT.per_channel());
        let mut rm = RangeManager::for_workload("toy-attn", &layers, &scheme);
        // sites: probs (act), ctx (act), scores.gx (grad), gx (grad)
        assert_eq!(rm.n_sites(), 4);
        // per-tensor acts contribute 1 row each; @pc grads group by
        // head (4) on the score site and by feature (32) on gx
        assert_eq!(rm.n_rows(), 1 + 1 + 4 + 32);
        assert_eq!(rm.site_rows(2).len(), 4);
        assert_eq!(rm.site_rows(3).len(), 32);
        assert_eq!(rm.row_offset(2), 2);
        // per-head rows update independently: feed head-varying stats
        let r = rm.n_rows();
        let mut st = vec![0.0f32; 2 * r];
        for h in 0..4 {
            let row = rm.row_offset(2) + h;
            st[2 * row] = -(h as f32 + 1.0);
            st[2 * row + 1] = h as f32 + 1.0;
        }
        let nr = vec![0.0f32; 2 * r];
        rm.update(
            &Tensor::from_f32(&[r, 2], nr),
            &Tensor::from_f32(&[r, 2], st),
            true,
        );
        assert_eq!(
            rm.site_rows(2),
            &[[-1.0, 1.0], [-2.0, 2.0], [-3.0, 3.0], [-4.0, 4.0]]
        );
    }

    /// Tentpole acceptance: every per-channel estimator pinned to one
    /// channel reproduces the per-tensor row sequence bit-for-bit over
    /// random calibration + step sequences.
    #[test]
    fn per_channel_one_group_matches_per_tensor_bit_for_bit() {
        for base in [
            Estimator::FP32,
            Estimator::CURRENT,
            Estimator::RUNNING,
            Estimator::HINDSIGHT,
            Estimator::DSGC,
            Estimator::MAX_HISTORY,
            Estimator::SAMPLED_MINMAX,
            Estimator::TQT,
            Estimator::BANNER,
        ] {
            forall(
                32,
                &format!("pc-golden-{}", base.key()),
                |rng| {
                    let n_act = 1 + rng.below(2);
                    let n_grad = 1 + rng.below(2);
                    let q = n_act + n_grad;
                    let calib: Vec<Vec<f32>> =
                        (0..rng.below(3)).map(|_| rand_rows(rng, q)).collect();
                    let steps: Vec<(Vec<f32>, Vec<f32>)> = (0..1 + rng.below(5))
                        .map(|_| (rand_rows(rng, q), rand_rows(rng, q)))
                        .collect();
                    let eta = rng.range(0.0, 1.0);
                    (n_act, n_grad, calib, steps, eta)
                },
                |(n_act, n_grad, calib, steps, eta)| {
                    let m = model_ch(*n_act, *n_grad, 1);
                    let q = n_act + n_grad;
                    let mut rm_pt = RangeManager::new(&m, &scheme2(base, base).eta_all(*eta));
                    let mut rm_pc = RangeManager::new(
                        &m,
                        &scheme2(base.per_channel(), base.per_channel()).eta_all(*eta),
                    );
                    assert_eq!(rm_pc.n_rows(), q); // 1 channel == 1 row per site
                    for st in calib {
                        rm_pt.calibrate(&t(q, st));
                        rm_pc.calibrate(&t(q, st));
                    }
                    for (step, (nr, st)) in steps.iter().enumerate() {
                        rm_pt.update(&t(q, nr), &t(q, st), step == 0);
                        rm_pc.update(&t(q, nr), &t(q, st), step == 0);
                        for i in 0..q {
                            if rm_pt.row(i) != rm_pc.row(i) {
                                return false;
                            }
                        }
                    }
                    true
                },
            );
        }
    }

    // ------------------------------------------------------------------
    // Golden parity: the trait impls must reproduce the pre-refactor
    // enum-branch semantics of `RangeManager::update` / `calibrate`
    // bit-for-bit for the five legacy estimators.
    // ------------------------------------------------------------------

    /// The seed's `RangeManager::update` match, verbatim.
    fn legacy_step(
        est: Estimator,
        cur: [f32; 2],
        stats: [f32; 2],
        nr: [f32; 2],
        first_step: bool,
        calibrated: bool,
    ) -> [f32; 2] {
        if est == Estimator::FP32 {
            cur
        } else if est == Estimator::DSGC {
            if first_step && !calibrated {
                stats
            } else {
                cur
            }
        } else if first_step && !calibrated {
            stats
        } else {
            nr
        }
    }

    /// The seed's `RangeManager::calibrate` body, verbatim.
    fn legacy_calibrate(cur: [f32; 2], stats: [f32; 2], eta: f32, calibrated: bool) -> [f32; 2] {
        if calibrated {
            ema_update(cur, stats, eta)
        } else {
            stats
        }
    }

    fn rand_rows(rng: &mut Pcg32, q: usize) -> Vec<f32> {
        (0..2 * q).map(|_| rng.range(-20.0, 20.0)).collect()
    }

    #[test]
    fn trait_impls_match_legacy_enum_semantics() {
        for est in [
            Estimator::FP32,
            Estimator::CURRENT,
            Estimator::RUNNING,
            Estimator::HINDSIGHT,
            Estimator::DSGC,
        ] {
            forall(
                48,
                &format!("legacy-parity-{}", est.key()),
                |rng| {
                    let n_act = 1 + rng.below(2);
                    let n_grad = 1 + rng.below(2);
                    let q = n_act + n_grad;
                    let calib: Vec<Vec<f32>> =
                        (0..rng.below(3)).map(|_| rand_rows(rng, q)).collect();
                    let steps: Vec<(Vec<f32>, Vec<f32>)> = (0..1 + rng.below(5))
                        .map(|_| (rand_rows(rng, q), rand_rows(rng, q)))
                        .collect();
                    let eta = rng.range(0.0, 1.0);
                    (n_act, n_grad, calib, steps, eta)
                },
                |(n_act, n_grad, calib, steps, eta)| {
                    let m = model(*n_act, *n_grad);
                    let q = n_act + n_grad;
                    let mut rm = RangeManager::new(&m, &scheme2(est, est).eta_all(*eta));
                    // legacy mirror state
                    let mut rows = vec![[-1.0f32, 1.0]; q];
                    let mut calibrated = false;
                    for st in calib {
                        for (i, row) in rows.iter_mut().enumerate() {
                            *row = legacy_calibrate(
                                *row,
                                [st[2 * i], st[2 * i + 1]],
                                *eta,
                                calibrated,
                            );
                        }
                        calibrated = true;
                        rm.calibrate(&t(q, st));
                    }
                    for (step, (nr, st)) in steps.iter().enumerate() {
                        rm.update(&t(q, nr), &t(q, st), step == 0);
                        for (i, row) in rows.iter_mut().enumerate() {
                            *row = legacy_step(
                                est,
                                *row,
                                [st[2 * i], st[2 * i + 1]],
                                [nr[2 * i], nr[2 * i + 1]],
                                step == 0,
                                calibrated,
                            );
                        }
                        for i in 0..q {
                            if rm.row(i) != rows[i] {
                                return false;
                            }
                        }
                    }
                    true
                },
            );
        }
    }
}

//! The range-estimation state machine (paper Sec. 4, realized).
//!
//! The compiled graph takes the (Q, 2) range state as an *input* and
//! returns two (Q, 2) tensors: `new_ranges` (the state-update each
//! estimator mode prescribes, computed in-graph) and `stats` (the raw
//! accumulator min/max of the step — paper Fig. 3).  This module owns
//! what happens *between* steps:
//!
//! * current / running / hindsight rows adopt `new_ranges` verbatim
//!   (the graph applied exactly eqs. 2-3 / the dynamic rules);
//! * DSGC gradient rows **ignore** the EMA update and hold their last
//!   searched range until the next periodic golden-section search — the
//!   hybrid static scheme of the paper's Sec. 5.1;
//! * FP32 rows keep whatever they had (quantization disabled).

use crate::coordinator::config::Estimator;
use crate::runtime::manifest::{ModelSpec, SiteKind};
use crate::runtime::tensor::Tensor;

/// Per-quantizer range state + estimator semantics.
#[derive(Debug, Clone)]
pub struct RangeManager {
    /// (Q, 2) rows: [qmin, qmax] per site, indexed by site index
    ranges: Vec<[f32; 2]>,
    kinds: Vec<SiteKind>,
    pub act_est: Estimator,
    pub grad_est: Estimator,
    /// last raw stats observed (diagnostics, saturation tracking)
    last_stats: Vec<[f32; 2]>,
    calibrated: bool,
}

impl RangeManager {
    pub fn new(model: &ModelSpec, act_est: Estimator, grad_est: Estimator) -> Self {
        let kinds = model.sites.iter().map(|s| s.kind).collect::<Vec<_>>();
        // neutral init: a generous symmetric range; calibration and/or the
        // first-step stats (paper: q^0 = minmax(G^0)) replace it
        let ranges = vec![[-1.0, 1.0]; kinds.len()];
        Self {
            last_stats: vec![[0.0, 0.0]; kinds.len()],
            ranges,
            kinds,
            act_est,
            grad_est,
            calibrated: false,
        }
    }

    pub fn n_sites(&self) -> usize {
        self.kinds.len()
    }

    pub fn estimator_for(&self, i: usize) -> Estimator {
        match self.kinds[i] {
            SiteKind::Act => self.act_est,
            SiteKind::Grad => self.grad_est,
        }
    }

    /// The (Q, 2) tensor fed to the graph this step.
    pub fn as_tensor(&self) -> Tensor {
        let mut data = Vec::with_capacity(self.ranges.len() * 2);
        for r in &self.ranges {
            data.extend_from_slice(r);
        }
        Tensor::from_f32(&[self.ranges.len(), 2], data)
    }

    pub fn row(&self, i: usize) -> [f32; 2] {
        self.ranges[i]
    }

    pub fn set_row(&mut self, i: usize, r: [f32; 2]) {
        self.ranges[i] = r;
    }

    pub fn last_stats(&self, i: usize) -> [f32; 2] {
        self.last_stats[i]
    }

    /// Scalar ABI values for the train graph.
    pub fn mode_act(&self) -> f32 {
        self.act_est.mode()
    }

    pub fn mode_grad(&self) -> f32 {
        self.grad_est.mode()
    }

    pub fn aq_on(&self) -> f32 {
        self.act_est.enabled() as u32 as f32
    }

    pub fn gq_on(&self) -> f32 {
        self.grad_est.enabled() as u32 as f32
    }

    /// Absorb one training step's outputs.
    ///
    /// `first_step` implements the paper's initialization
    /// `q^0 = minmax(G^0)` for sites that were never calibrated.
    pub fn update(&mut self, new_ranges: &Tensor, stats: &Tensor, first_step: bool) {
        let nr = new_ranges.as_f32().expect("new_ranges f32");
        let st = stats.as_f32().expect("stats f32");
        assert_eq!(nr.len(), self.ranges.len() * 2);
        for i in 0..self.ranges.len() {
            self.last_stats[i] = [st[2 * i], st[2 * i + 1]];
            let est = self.estimator_for(i);
            match est {
                Estimator::Fp32 => {}
                Estimator::Dsgc => {
                    // hold the searched range; but bootstrap from the first
                    // observation so training can start before search #1
                    if first_step && !self.calibrated {
                        self.ranges[i] = self.last_stats[i];
                    }
                }
                _ => {
                    if first_step && !self.calibrated {
                        // q^0 = minmax of the first batch (paper Sec. 4.1)
                        self.ranges[i] = self.last_stats[i];
                    } else {
                        self.ranges[i] = [nr[2 * i], nr[2 * i + 1]];
                    }
                }
            }
        }
    }

    /// Absorb one *calibration* batch (paper Sec. 5.2: feed a few batches
    /// through the network before training to set activation ranges).
    /// First batch seeds the ranges with raw stats, later batches EMA in.
    pub fn calibrate(&mut self, stats: &Tensor, eta: f32) {
        let st = stats.as_f32().expect("stats f32");
        for i in 0..self.ranges.len() {
            let s = [st[2 * i], st[2 * i + 1]];
            self.ranges[i] = if self.calibrated {
                crate::quant::ema_update(self.ranges[i], s, eta)
            } else {
                s
            };
            self.last_stats[i] = s;
        }
        self.calibrated = true;
    }

    pub fn is_calibrated(&self) -> bool {
        self.calibrated
    }

    /// Site indices that DSGC must search (gradient sites, when the grad
    /// estimator is DSGC).
    pub fn dsgc_sites(&self) -> Vec<usize> {
        if self.grad_est != Estimator::Dsgc {
            return vec![];
        }
        (0..self.kinds.len())
            .filter(|&i| self.kinds[i] == SiteKind::Grad)
            .collect()
    }

    /// Mean saturation headroom diagnostic: how much of the last stats
    /// interval the current ranges cover (1.0 = fully covered).
    pub fn coverage(&self) -> f64 {
        let mut acc = 0.0;
        let mut n = 0;
        for i in 0..self.ranges.len() {
            let w_stats = self.last_stats[i][1] - self.last_stats[i][0];
            if w_stats <= 0.0 {
                continue;
            }
            let lo = self.ranges[i][0].max(self.last_stats[i][0]);
            let hi = self.ranges[i][1].min(self.last_stats[i][1]);
            acc += ((hi - lo).max(0.0) / w_stats) as f64;
            n += 1;
        }
        if n == 0 {
            1.0
        } else {
            acc / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{LeafSpec, ModelSpec, SiteSpec};

    fn model(n_act: usize, n_grad: usize) -> ModelSpec {
        let mut sites = Vec::new();
        for i in 0..n_act + n_grad {
            sites.push(SiteSpec {
                index: i,
                name: format!("s{i}"),
                kind: if i < n_act { SiteKind::Act } else { SiteKind::Grad },
                feature_shape: vec![4],
            });
        }
        ModelSpec {
            name: "m".into(),
            batch_size: 2,
            input_shape: vec![2, 2, 3],
            n_classes: 4,
            n_params: 10,
            pallas: "none".into(),
            params: vec![LeafSpec { name: "w".into(), shape: vec![2] }],
            state: vec![],
            sites,
            graphs: vec![],
        }
    }

    fn t(q: usize, vals: &[f32]) -> Tensor {
        Tensor::from_f32(&[q, 2], vals.to_vec())
    }

    #[test]
    fn first_step_adopts_raw_stats() {
        let m = model(1, 1);
        let mut rm = RangeManager::new(&m, Estimator::Hindsight, Estimator::Hindsight);
        let nr = t(2, &[-0.5, 0.5, -0.1, 0.1]);
        let st = t(2, &[-2.0, 3.0, -4.0, 5.0]);
        rm.update(&nr, &st, true);
        assert_eq!(rm.row(0), [-2.0, 3.0]);
        assert_eq!(rm.row(1), [-4.0, 5.0]);
        // subsequent steps adopt the graph's EMA output
        rm.update(&nr, &st, false);
        assert_eq!(rm.row(0), [-0.5, 0.5]);
    }

    #[test]
    fn fp32_rows_frozen() {
        let m = model(1, 1);
        let mut rm = RangeManager::new(&m, Estimator::Fp32, Estimator::Hindsight);
        let before = rm.row(0);
        rm.update(&t(2, &[9.0, 9.0, -1.0, 1.0]), &t(2, &[0.0, 1.0, 0.0, 1.0]), false);
        assert_eq!(rm.row(0), before); // act site untouched (FP32)
        assert_eq!(rm.row(1), [-1.0, 1.0]); // grad site updated
        assert_eq!(rm.aq_on(), 0.0);
        assert_eq!(rm.gq_on(), 1.0);
    }

    #[test]
    fn dsgc_rows_held_between_searches() {
        let m = model(1, 2);
        let mut rm = RangeManager::new(&m, Estimator::Current, Estimator::Dsgc);
        rm.set_row(1, [-7.0, 7.0]); // pretend a search happened
        rm.calibrate(&t(3, &[0.0; 6]), 0.9); // mark calibrated
        rm.set_row(1, [-7.0, 7.0]);
        rm.update(
            &t(3, &[0.0, 1.0, -1.0, 1.0, -1.0, 1.0]),
            &t(3, &[0.0, 2.0, -2.0, 2.0, -2.0, 2.0]),
            false,
        );
        assert_eq!(rm.row(1), [-7.0, 7.0]); // held
        assert_eq!(rm.dsgc_sites(), vec![1, 2]);
        // act sites are not DSGC sites
        let rm2 = RangeManager::new(&m, Estimator::Dsgc, Estimator::Current);
        assert!(rm2.dsgc_sites().is_empty());
    }

    #[test]
    fn calibration_seeds_then_emas() {
        let m = model(2, 0);
        let mut rm = RangeManager::new(&m, Estimator::Hindsight, Estimator::Fp32);
        rm.calibrate(&t(2, &[-1.0, 1.0, -2.0, 2.0]), 0.5);
        assert_eq!(rm.row(0), [-1.0, 1.0]);
        rm.calibrate(&t(2, &[-3.0, 3.0, -2.0, 2.0]), 0.5);
        assert_eq!(rm.row(0), [-2.0, 2.0]); // 0.5 blend
        assert!(rm.is_calibrated());
    }

    #[test]
    fn tensor_roundtrip_and_coverage() {
        let m = model(1, 0);
        let mut rm = RangeManager::new(&m, Estimator::Hindsight, Estimator::Fp32);
        rm.set_row(0, [-1.0, 1.0]);
        let t = rm.as_tensor();
        assert_eq!(t.shape, vec![1, 2]);
        assert_eq!(t.as_f32().unwrap(), &[-1.0, 1.0]);
        // stats wider than range => coverage < 1
        rm.update(
            &Tensor::from_f32(&[1, 2], vec![-1.0, 1.0]),
            &Tensor::from_f32(&[1, 2], vec![-2.0, 2.0]),
            false,
        );
        assert!(rm.coverage() < 1.0);
    }
}

//! The range-estimation state machine (paper Sec. 4, realized).
//!
//! The compiled graph takes the (Q, 2) range state as an *input* and
//! returns two (Q, 2) tensors: `new_ranges` (the state-update each
//! estimator mode prescribes, computed in-graph) and `stats` (the raw
//! accumulator min/max of the step — paper Fig. 3).  This module owns
//! what happens *between* steps — but no longer knows any estimator's
//! semantics: each quantizer site carries a boxed
//! [`RangeEstimator`](crate::estimator::RangeEstimator) instantiated
//! from the registry, and `RangeManager` just routes the graph outputs
//! through the per-site `absorb_step` / `absorb_calibration` hooks and
//! the periodic `search` hook for estimators that declare
//! `needs_search` (DSGC, sampled min-max).  The (Q, 2) tensor ABI to
//! the compiled graph is unchanged.

use crate::coordinator::config::Estimator;
use crate::estimator::{RangeEstimator, StepCtx};
use crate::runtime::manifest::{ModelSpec, SiteKind};
use crate::runtime::tensor::Tensor;

/// Per-quantizer range state + delegated estimator semantics.
#[derive(Debug, Clone)]
pub struct RangeManager {
    /// (Q, 2) rows: [qmin, qmax] per site, indexed by site index
    ranges: Vec<[f32; 2]>,
    kinds: Vec<SiteKind>,
    pub act_est: Estimator,
    pub grad_est: Estimator,
    /// one estimator instance per site (owns any per-site state)
    sites: Vec<Box<dyn RangeEstimator>>,
    /// last raw stats observed (diagnostics, saturation tracking)
    last_stats: Vec<[f32; 2]>,
    calibrated: bool,
}

impl RangeManager {
    pub fn new(model: &ModelSpec, act_est: Estimator, grad_est: Estimator) -> Self {
        let kinds = model.sites.iter().map(|s| s.kind).collect::<Vec<_>>();
        let sites: Vec<Box<dyn RangeEstimator>> = kinds
            .iter()
            .map(|k| match k {
                SiteKind::Act => act_est.instantiate(),
                SiteKind::Grad => grad_est.instantiate(),
            })
            .collect();
        let ranges = sites.iter().map(|e| e.init()).collect();
        Self {
            last_stats: vec![[0.0, 0.0]; kinds.len()],
            ranges,
            kinds,
            act_est,
            grad_est,
            sites,
            calibrated: false,
        }
    }

    pub fn n_sites(&self) -> usize {
        self.kinds.len()
    }

    /// The (Q, 2) tensor fed to the graph this step.
    pub fn as_tensor(&self) -> Tensor {
        let mut data = Vec::with_capacity(self.ranges.len() * 2);
        for r in &self.ranges {
            data.extend_from_slice(r);
        }
        Tensor::from_f32(&[self.ranges.len(), 2], data)
    }

    pub fn row(&self, i: usize) -> [f32; 2] {
        self.ranges[i]
    }

    pub fn set_row(&mut self, i: usize, r: [f32; 2]) {
        self.ranges[i] = r;
    }

    pub fn last_stats(&self, i: usize) -> [f32; 2] {
        self.last_stats[i]
    }

    /// Scalar ABI values for the train graph.
    pub fn mode_act(&self) -> f32 {
        self.act_est.mode()
    }

    pub fn mode_grad(&self) -> f32 {
        self.grad_est.mode()
    }

    pub fn aq_on(&self) -> f32 {
        self.act_est.enabled() as u32 as f32
    }

    pub fn gq_on(&self) -> f32 {
        self.grad_est.enabled() as u32 as f32
    }

    /// Absorb one training step's outputs: each site's estimator sees
    /// `{current row, raw stats, in-graph update}` and returns the row
    /// the next step quantizes with.
    ///
    /// `first_step` lets uncalibrated estimators implement the paper's
    /// initialization `q^0 = minmax(G^0)`.
    pub fn update(&mut self, new_ranges: &Tensor, stats: &Tensor, first_step: bool) {
        let nr = new_ranges.as_f32().expect("new_ranges f32");
        let st = stats.as_f32().expect("stats f32");
        assert_eq!(nr.len(), self.ranges.len() * 2);
        for i in 0..self.ranges.len() {
            self.last_stats[i] = [st[2 * i], st[2 * i + 1]];
            let ctx = StepCtx {
                current: self.ranges[i],
                stats: self.last_stats[i],
                new_ranges: [nr[2 * i], nr[2 * i + 1]],
                first_step,
                calibrated: self.calibrated,
            };
            self.ranges[i] = self.sites[i].absorb_step(ctx);
        }
    }

    /// Absorb one *calibration* batch (paper Sec. 5.2: feed a few batches
    /// through the network before training to set activation ranges).
    pub fn calibrate(&mut self, stats: &Tensor, eta: f32) {
        let st = stats.as_f32().expect("stats f32");
        for i in 0..self.ranges.len() {
            let s = [st[2 * i], st[2 * i + 1]];
            self.ranges[i] =
                self.sites[i].absorb_calibration(self.ranges[i], s, eta, !self.calibrated);
            self.last_stats[i] = s;
        }
        self.calibrated = true;
    }

    pub fn is_calibrated(&self) -> bool {
        self.calibrated
    }

    /// Site indices the periodic search pass must visit: gradient sites
    /// whose estimator declares `needs_search` (DSGC, sampled min-max).
    pub fn search_sites(&self) -> Vec<usize> {
        if !self.grad_est.needs_search() {
            return vec![];
        }
        (0..self.kinds.len())
            .filter(|&i| self.kinds[i] == SiteKind::Grad)
            .collect()
    }

    /// Run one site's tensor-level search and adopt the resulting range.
    /// Returns the search's cost in tensor traversals.
    pub fn search_site(&mut self, i: usize, tensor: &[f32], bits: u32, iters: u32) -> u32 {
        let out = self.sites[i].search(tensor, bits, iters);
        self.ranges[i] = out.range;
        out.evals
    }

    /// Mean saturation headroom diagnostic: how much of the last stats
    /// interval the current ranges cover (1.0 = fully covered).
    pub fn coverage(&self) -> f64 {
        let mut acc = 0.0;
        let mut n = 0;
        for i in 0..self.ranges.len() {
            let w_stats = self.last_stats[i][1] - self.last_stats[i][0];
            if w_stats <= 0.0 {
                continue;
            }
            let lo = self.ranges[i][0].max(self.last_stats[i][0]);
            let hi = self.ranges[i][1].min(self.last_stats[i][1]);
            acc += ((hi - lo).max(0.0) / w_stats) as f64;
            n += 1;
        }
        if n == 0 {
            1.0
        } else {
            acc / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ema_update;
    use crate::runtime::manifest::{LeafSpec, ModelSpec, SiteSpec};
    use crate::util::rng::Pcg32;
    use crate::util::testkit::forall;

    fn model(n_act: usize, n_grad: usize) -> ModelSpec {
        let mut sites = Vec::new();
        for i in 0..n_act + n_grad {
            sites.push(SiteSpec {
                index: i,
                name: format!("s{i}"),
                kind: if i < n_act { SiteKind::Act } else { SiteKind::Grad },
                feature_shape: vec![4],
            });
        }
        ModelSpec {
            name: "m".into(),
            batch_size: 2,
            input_shape: vec![2, 2, 3],
            n_classes: 4,
            n_params: 10,
            pallas: "none".into(),
            params: vec![LeafSpec { name: "w".into(), shape: vec![2] }],
            state: vec![],
            sites,
            graphs: vec![],
        }
    }

    fn t(q: usize, vals: &[f32]) -> Tensor {
        Tensor::from_f32(&[q, 2], vals.to_vec())
    }

    #[test]
    fn first_step_adopts_raw_stats() {
        let m = model(1, 1);
        let mut rm = RangeManager::new(&m, Estimator::HINDSIGHT, Estimator::HINDSIGHT);
        let nr = t(2, &[-0.5, 0.5, -0.1, 0.1]);
        let st = t(2, &[-2.0, 3.0, -4.0, 5.0]);
        rm.update(&nr, &st, true);
        assert_eq!(rm.row(0), [-2.0, 3.0]);
        assert_eq!(rm.row(1), [-4.0, 5.0]);
        // subsequent steps adopt the graph's EMA output
        rm.update(&nr, &st, false);
        assert_eq!(rm.row(0), [-0.5, 0.5]);
    }

    #[test]
    fn fp32_rows_frozen() {
        let m = model(1, 1);
        let mut rm = RangeManager::new(&m, Estimator::FP32, Estimator::HINDSIGHT);
        let before = rm.row(0);
        rm.update(&t(2, &[9.0, 9.0, -1.0, 1.0]), &t(2, &[0.0, 1.0, 0.0, 1.0]), false);
        assert_eq!(rm.row(0), before); // act site untouched (FP32)
        assert_eq!(rm.row(1), [-1.0, 1.0]); // grad site updated
        assert_eq!(rm.aq_on(), 0.0);
        assert_eq!(rm.gq_on(), 1.0);
    }

    #[test]
    fn dsgc_rows_held_between_searches() {
        let m = model(1, 2);
        let mut rm = RangeManager::new(&m, Estimator::CURRENT, Estimator::DSGC);
        rm.set_row(1, [-7.0, 7.0]); // pretend a search happened
        rm.calibrate(&t(3, &[0.0; 6]), 0.9); // mark calibrated
        rm.set_row(1, [-7.0, 7.0]);
        rm.update(
            &t(3, &[0.0, 1.0, -1.0, 1.0, -1.0, 1.0]),
            &t(3, &[0.0, 2.0, -2.0, 2.0, -2.0, 2.0]),
            false,
        );
        assert_eq!(rm.row(1), [-7.0, 7.0]); // held
        assert_eq!(rm.search_sites(), vec![1, 2]);
        // act sites are never search sites
        let rm2 = RangeManager::new(&m, Estimator::DSGC, Estimator::CURRENT);
        assert!(rm2.search_sites().is_empty());
    }

    #[test]
    fn search_site_adopts_the_searched_range() {
        let m = model(0, 1);
        let mut rm = RangeManager::new(&m, Estimator::CURRENT, Estimator::SAMPLED_MINMAX);
        assert_eq!(rm.search_sites(), vec![0]);
        let g: Vec<f32> = (0..4096).map(|i| ((i % 513) as f32 / 256.0) - 1.0).collect();
        let evals = rm.search_site(0, &g, 8, 0);
        assert_eq!(evals, 1);
        let r = rm.row(0);
        assert!(r[0] <= -0.9 && r[1] >= 0.9, "{r:?}");
    }

    #[test]
    fn calibration_seeds_then_emas() {
        let m = model(2, 0);
        let mut rm = RangeManager::new(&m, Estimator::HINDSIGHT, Estimator::FP32);
        rm.calibrate(&t(2, &[-1.0, 1.0, -2.0, 2.0]), 0.5);
        assert_eq!(rm.row(0), [-1.0, 1.0]);
        rm.calibrate(&t(2, &[-3.0, 3.0, -2.0, 2.0]), 0.5);
        assert_eq!(rm.row(0), [-2.0, 2.0]); // 0.5 blend
        assert!(rm.is_calibrated());
    }

    #[test]
    fn tensor_roundtrip_and_coverage() {
        let m = model(1, 0);
        let mut rm = RangeManager::new(&m, Estimator::HINDSIGHT, Estimator::FP32);
        rm.set_row(0, [-1.0, 1.0]);
        let t = rm.as_tensor();
        assert_eq!(t.shape, vec![1, 2]);
        assert_eq!(t.as_f32().unwrap(), &[-1.0, 1.0]);
        // stats wider than range => coverage < 1
        rm.update(
            &Tensor::from_f32(&[1, 2], vec![-1.0, 1.0]),
            &Tensor::from_f32(&[1, 2], vec![-2.0, 2.0]),
            false,
        );
        assert!(rm.coverage() < 1.0);
    }

    #[test]
    fn maxhist_rows_track_the_window_hull() {
        let m = model(1, 1);
        let mut rm = RangeManager::new(&m, Estimator::MAX_HISTORY, Estimator::MAX_HISTORY);
        rm.update(&t(2, &[0.0; 4]), &t(2, &[-1.0, 1.0, -2.0, 2.0]), true);
        assert_eq!(rm.row(0), [-1.0, 1.0]);
        rm.update(&t(2, &[0.0; 4]), &t(2, &[-0.5, 3.0, -1.0, 1.0]), false);
        // hull over both observations, not an EMA
        assert_eq!(rm.row(0), [-1.0, 3.0]);
        assert_eq!(rm.row(1), [-2.0, 2.0]);
    }

    // ------------------------------------------------------------------
    // Golden parity: the trait impls must reproduce the pre-refactor
    // enum-branch semantics of `RangeManager::update` / `calibrate`
    // bit-for-bit for the five legacy estimators.
    // ------------------------------------------------------------------

    /// The seed's `RangeManager::update` match, verbatim.
    fn legacy_step(
        est: Estimator,
        cur: [f32; 2],
        stats: [f32; 2],
        nr: [f32; 2],
        first_step: bool,
        calibrated: bool,
    ) -> [f32; 2] {
        if est == Estimator::FP32 {
            cur
        } else if est == Estimator::DSGC {
            if first_step && !calibrated {
                stats
            } else {
                cur
            }
        } else if first_step && !calibrated {
            stats
        } else {
            nr
        }
    }

    /// The seed's `RangeManager::calibrate` body, verbatim.
    fn legacy_calibrate(cur: [f32; 2], stats: [f32; 2], eta: f32, calibrated: bool) -> [f32; 2] {
        if calibrated {
            ema_update(cur, stats, eta)
        } else {
            stats
        }
    }

    fn rand_rows(rng: &mut Pcg32, q: usize) -> Vec<f32> {
        (0..2 * q).map(|_| rng.range(-20.0, 20.0)).collect()
    }

    #[test]
    fn trait_impls_match_legacy_enum_semantics() {
        for est in [
            Estimator::FP32,
            Estimator::CURRENT,
            Estimator::RUNNING,
            Estimator::HINDSIGHT,
            Estimator::DSGC,
        ] {
            forall(
                48,
                &format!("legacy-parity-{}", est.key()),
                |rng| {
                    let n_act = 1 + rng.below(2);
                    let n_grad = 1 + rng.below(2);
                    let q = n_act + n_grad;
                    let calib: Vec<Vec<f32>> =
                        (0..rng.below(3)).map(|_| rand_rows(rng, q)).collect();
                    let steps: Vec<(Vec<f32>, Vec<f32>)> = (0..1 + rng.below(5))
                        .map(|_| (rand_rows(rng, q), rand_rows(rng, q)))
                        .collect();
                    let eta = rng.range(0.0, 1.0);
                    (n_act, n_grad, calib, steps, eta)
                },
                |(n_act, n_grad, calib, steps, eta)| {
                    let m = model(*n_act, *n_grad);
                    let q = n_act + n_grad;
                    let mut rm = RangeManager::new(&m, est, est);
                    // legacy mirror state
                    let mut rows = vec![[-1.0f32, 1.0]; q];
                    let mut calibrated = false;
                    for st in calib {
                        for (i, row) in rows.iter_mut().enumerate() {
                            *row = legacy_calibrate(
                                *row,
                                [st[2 * i], st[2 * i + 1]],
                                *eta,
                                calibrated,
                            );
                        }
                        calibrated = true;
                        rm.calibrate(&t(q, st), *eta);
                    }
                    for (step, (nr, st)) in steps.iter().enumerate() {
                        rm.update(&t(q, nr), &t(q, st), step == 0);
                        for (i, row) in rows.iter_mut().enumerate() {
                            *row = legacy_step(
                                est,
                                *row,
                                [st[2 * i], st[2 * i + 1]],
                                [nr[2 * i], nr[2 * i + 1]],
                                step == 0,
                                calibrated,
                            );
                        }
                        for i in 0..q {
                            if rm.row(i) != rows[i] {
                                return false;
                            }
                        }
                    }
                    true
                },
            );
        }
    }
}

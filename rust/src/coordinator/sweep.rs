//! Multi-seed sweeps: run one configuration across seeds and aggregate
//! into the paper's "mean ± std" table rows.  A whole estimator sweep
//! shares a single Engine, so each model compiles exactly once.

use anyhow::Result;

use crate::coordinator::config::TrainConfig;
use crate::coordinator::trainer::Trainer;
use crate::metrics::{RunRecord, SeedAggregate};
use crate::runtime::engine::Engine;

/// Aggregated outcome of one table row.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub label: String,
    pub agg: SeedAggregate,
    pub runs: Vec<RunRecord>,
    /// mean seconds per training step (perf reporting)
    pub sec_per_step: f64,
}

impl SweepOutcome {
    pub fn cell(&self) -> String {
        self.agg.cell()
    }
}

/// Run `cfg` across `seeds`, returning the aggregate row.
pub fn sweep_row(
    engine: &Engine,
    base: &TrainConfig,
    label: &str,
    seeds: &[u64],
) -> Result<SweepOutcome> {
    let mut runs = Vec::with_capacity(seeds.len());
    for &seed in seeds {
        let mut cfg = base.clone();
        cfg.seed = seed;
        log::info!("[sweep:{label}] seed {seed} ...");
        let rec = Trainer::new(engine, cfg)?.run()?;
        runs.push(rec);
    }
    let agg = SeedAggregate::from_runs(label, &runs);
    let total_steps: f64 = runs.iter().map(|r| r.steps.len() as f64).sum();
    let total_secs: f64 = runs.iter().map(|r| r.train_seconds).sum();
    Ok(SweepOutcome {
        label: label.to_string(),
        agg,
        runs,
        sec_per_step: total_secs / total_steps.max(1.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Estimator;
    use crate::runtime::manifest::Manifest;

    /// Registry round-trip, no engine needed: every registered name
    /// resolves through config parsing into a sweepable configuration.
    #[test]
    fn registry_names_round_trip_through_configs() {
        for est in Estimator::all() {
            let parsed = Estimator::parse(est.key()).unwrap();
            assert_eq!(parsed, est);
            let full = TrainConfig::new("mlp").fully_quantized(parsed);
            assert_eq!(full.scheme.weights.enabled(), parsed.enabled());
            assert_eq!(full.scheme.gradients.estimator, parsed);
            // the tag carries the scheme's string form (registry keys)
            assert!(full.tag().contains(parsed.key()), "{}", full.tag());
            let _ = TrainConfig::new("mlp").grad_only(parsed);
            let _ = TrainConfig::new("mlp").act_only(parsed);
            // per-site instances are constructible for every name
            let _ = parsed.instantiate();
        }
        assert!(Estimator::parse("not-an-estimator").is_err());
    }

    #[test]
    fn sweep_aggregates_across_seeds() {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::new().unwrap();
        let mut cfg = TrainConfig::new("mlp").fully_quantized(Estimator::HINDSIGHT);
        cfg.steps = 6;
        cfg.n_train = 64;
        cfg.n_val = 32;
        cfg.calib_batches = 1;
        let out = sweep_row(&engine, &cfg, "hindsight", &[1, 2]).unwrap();
        assert_eq!(out.runs.len(), 2);
        assert!(out.agg.accs.iter().all(|a| a.is_finite()));
        assert!(out.sec_per_step > 0.0);
        // one engine, one train-graph compile across both seeds
        assert!(engine.stats().compiles <= 3); // init + train + eval
    }
}

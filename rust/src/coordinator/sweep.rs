//! Multi-seed sweeps: one configuration across seeds, aggregated into
//! the paper's "mean ± std" table rows.
//!
//! Since the grid refactor this is a thin wrapper over the sweep
//! engine: `sweep_row` builds the seed cells with
//! [`grid::seed_cells`](crate::coordinator::grid::seed_cells) and runs
//! them through the executor's serial shared-engine path
//! ([`executor::run_cells_on`](crate::coordinator::executor::run_cells_on)),
//! so an entire estimator sweep compiles each model exactly once and
//! shares cache/store semantics with the parallel `--grid` path.

use anyhow::{bail, Result};

use crate::coordinator::config::TrainConfig;
use crate::coordinator::executor::{run_cells_on, CellOutcome, GridOptions};
use crate::coordinator::grid::seed_cells;
use crate::metrics::{RunRecord, SeedAggregate};
use crate::runtime::engine::Engine;

/// Aggregated outcome of one table row.
#[derive(Debug, Clone)]
pub struct SweepOutcome {
    pub label: String,
    pub agg: SeedAggregate,
    pub runs: Vec<RunRecord>,
    /// mean seconds per training step (perf reporting); 0.0 when no
    /// steps ran — never a masked divide
    pub sec_per_step: f64,
}

impl SweepOutcome {
    /// Aggregate completed runs into a row.  Handles the degenerate
    /// cases explicitly: zero training steps (a `steps = 0` smoke
    /// config, or every cell failed) reports `sec_per_step` of exactly
    /// 0.0 rather than dividing total seconds by a clamped step count.
    pub fn from_runs(label: &str, runs: Vec<RunRecord>) -> Self {
        let agg = SeedAggregate::from_runs(label, &runs);
        let total_steps: f64 = runs.iter().map(|r| r.steps.len() as f64).sum();
        let total_secs: f64 = runs.iter().map(|r| r.train_seconds).sum();
        let sec_per_step = if total_steps > 0.0 {
            total_secs / total_steps
        } else {
            0.0
        };
        Self {
            label: label.to_string(),
            agg,
            runs,
            sec_per_step,
        }
    }

    pub fn cell(&self) -> String {
        self.agg.cell()
    }

    /// JSON form for the sweep service's result endpoint: the row's
    /// aggregate plus per-cell provenance tags.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::object(vec![
            ("scheme", Value::from(self.label.clone())),
            ("acc_mean", Value::Num(self.agg.mean())),
            ("acc_std", Value::Num(self.agg.std())),
            ("n", Value::from(self.runs.len())),
            ("sec_per_step", Value::Num(self.sec_per_step)),
            (
                "cells",
                Value::Array(self.agg.cells.iter().map(|c| Value::from(c.clone())).collect()),
            ),
        ])
    }
}

/// Run `cfg` across `seeds` on one shared engine, returning the
/// aggregate row.  An empty seed list is an error (a degenerate
/// no-seed aggregate would silently print `NaN ± NaN`); any failing
/// seed cell fails the whole row, and the serial path's fail-fast
/// ([`GridOptions::serial`]) stops before training the remaining
/// seeds — partial rows are a grid-engine concern
/// (`executor::grid_rows`), not a table-row one.
pub fn sweep_row(
    engine: &Engine,
    base: &TrainConfig,
    label: &str,
    seeds: &[u64],
) -> Result<SweepOutcome> {
    if seeds.is_empty() {
        bail!("sweep row '{label}': empty seed list — pass at least one seed");
    }
    let cells = seed_cells(base, seeds)?;
    let results = run_cells_on(engine, &cells, &GridOptions::serial());
    let mut runs = Vec::with_capacity(results.len());
    for r in results {
        match r.outcome {
            CellOutcome::Ran(rec) | CellOutcome::Cached(rec) => runs.push(rec),
            CellOutcome::Failed(e) => bail!("sweep row '{label}': cell '{}': {e}", r.label),
        }
    }
    Ok(SweepOutcome::from_runs(label, runs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::Estimator;
    use crate::runtime::manifest::Manifest;

    /// Registry round-trip, no engine needed: every registered name
    /// resolves through config parsing into a sweepable configuration.
    #[test]
    fn registry_names_round_trip_through_configs() {
        for est in Estimator::all() {
            let parsed = Estimator::parse(est.key()).unwrap();
            assert_eq!(parsed, est);
            let full = TrainConfig::new("mlp").fully_quantized(parsed);
            assert_eq!(full.scheme.weights.enabled(), parsed.enabled());
            assert_eq!(full.scheme.gradients.estimator, parsed);
            // the tag carries the scheme's string form (registry keys)
            assert!(full.tag().contains(parsed.key()), "{}", full.tag());
            let _ = TrainConfig::new("mlp").grad_only(parsed);
            let _ = TrainConfig::new("mlp").act_only(parsed);
            // per-site instances are constructible for every name
            let _ = parsed.instantiate();
        }
        assert!(Estimator::parse("not-an-estimator").is_err());
    }

    /// Satellite regression: degenerate aggregates are explicit, not
    /// masked.  Zero completed steps → `sec_per_step` exactly 0.0.
    #[test]
    fn from_runs_reports_zero_sec_per_step_when_no_steps_ran() {
        let out = SweepOutcome::from_runs("empty", Vec::new());
        assert_eq!(out.sec_per_step, 0.0);
        assert!(out.runs.is_empty());
        assert!(out.agg.accs.is_empty());
        // a run that trained zero steps but spent wall-clock time (e.g.
        // a steps=0 smoke config that still compiled/evaluated)
        let mut rec = RunRecord::new("zero-steps");
        rec.train_seconds = 3.5;
        let out = SweepOutcome::from_runs("zero", vec![rec]);
        assert_eq!(out.sec_per_step, 0.0, "no steps ran: report 0.0, not 3.5/1");
        // the normal case still divides by the true step count
        let mut rec = RunRecord::new("two-steps");
        rec.log_step(0, 1.0, 0.5);
        rec.log_step(1, 0.9, 0.5);
        rec.train_seconds = 3.0;
        let out = SweepOutcome::from_runs("ok", vec![rec]);
        assert_eq!(out.sec_per_step, 1.5);
    }

    #[test]
    fn to_json_carries_the_row_aggregate_and_provenance() {
        let runs = vec![
            RunRecord::synthetic("g:hindsight:8#s1", 4),
            RunRecord::synthetic("g:hindsight:8#s2", 4),
        ];
        let out = SweepOutcome::from_runs("g:hindsight:8", runs);
        let v = out.to_json();
        assert_eq!(v.get("scheme").and_then(|s| s.as_str()), Some("g:hindsight:8"));
        assert_eq!(v.get("n").and_then(|n| n.as_usize()), Some(2));
        assert_eq!(v.get("acc_mean").and_then(|m| m.as_f64()), Some(out.agg.mean()));
        assert_eq!(v.get("acc_std").and_then(|s| s.as_f64()), Some(out.agg.std()));
        assert_eq!(v.get("cells").and_then(|c| c.as_array()).map(|c| c.len()), Some(2));
        // serialized form survives a parse round-trip
        let back = crate::util::json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn empty_seed_lists_are_rejected() {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::new().unwrap();
        let cfg = TrainConfig::new("mlp");
        let err = sweep_row(&engine, &cfg, "none", &[]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("empty seed list"), "{msg}");
    }

    #[test]
    fn sweep_aggregates_across_seeds() {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = Engine::new().unwrap();
        let mut cfg = TrainConfig::new("mlp").fully_quantized(Estimator::HINDSIGHT);
        cfg.steps = 6;
        cfg.n_train = 64;
        cfg.n_val = 32;
        cfg.calib_batches = 1;
        let out = sweep_row(&engine, &cfg, "hindsight", &[1, 2]).unwrap();
        assert_eq!(out.runs.len(), 2);
        assert!(out.agg.accs.iter().all(|a| a.is_finite()));
        assert!(out.sec_per_step > 0.0);
        // provenance: one cell tag per seed, in seed order
        assert_eq!(out.agg.cells.len(), 2);
        assert!(out.agg.cells[0].ends_with("-s1"), "{:?}", out.agg.cells);
        // one engine, one train-graph compile across both seeds
        assert!(engine.stats().compiles <= 3); // init + train + eval
    }
}

//! Training configuration, mirroring the paper's Sec. 5 setup.
//!
//! Quantization policy is no longer a flat pair of estimator knobs plus
//! a global `eta`: [`TrainConfig`] carries a typed
//! [`QuantScheme`](crate::scheme::QuantScheme) — one
//! `QuantSpec { estimator, bits, eta, symmetric }` per tensor class
//! (weights / activations / gradients) plus per-site overrides.  (The
//! legacy flat accessors survived exactly one PR as deprecated shims
//! and are gone; read `cfg.scheme` directly.)

use anyhow::{bail, Result};

pub use crate::estimator::Estimator;
pub use crate::scheme::{QuantScheme, QuantSpec, TensorClass};

/// Learning-rate schedule (paper: step decay for ResNet/VGG, cosine for
/// MobileNetV2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// /10 at 1/3 and 2/3 of training (the paper's 90-epoch recipe scaled)
    Step,
    /// cosine annealing to `final_lr`
    Cosine,
    Constant,
}

impl Schedule {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "step" => Self::Step,
            "cosine" => Self::Cosine,
            "constant" => Self::Constant,
            other => bail!("unknown schedule '{other}' (step|cosine|constant)"),
        })
    }

    /// LR at `step` of `total`.
    pub fn lr_at(&self, base: f32, final_lr: f32, step: u64, total: u64) -> f32 {
        let frac = step as f32 / total.max(1) as f32;
        match self {
            Self::Constant => base,
            Self::Step => {
                if frac < 1.0 / 3.0 {
                    base
                } else if frac < 2.0 / 3.0 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
            Self::Cosine => {
                final_lr
                    + 0.5 * (base - final_lr) * (1.0 + (std::f32::consts::PI * frac).cos())
            }
        }
    }
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub steps: u64,
    /// the quantization policy: per-class estimator/bits/eta/symmetry
    /// plus per-site overrides
    pub scheme: QuantScheme,
    pub lr: f32,
    pub final_lr: f32,
    pub schedule: Schedule,
    pub weight_decay: f32,
    /// calibration batches before training (paper Sec. 5.2)
    pub calib_batches: usize,
    /// DSGC update interval in steps (paper: 100).  0 is valid and means
    /// "search once, at step 0" — the bootstrap search only (the trainer
    /// guards the modulo; see `trainer::search_due`).
    pub dsgc_period: u64,
    /// golden-section refinement iterations per DSGC update
    pub dsgc_iters: u32,
    pub seed: u64,
    pub n_train: usize,
    pub n_val: usize,
    pub eval_every: u64,
    pub log_every: u64,
}

impl TrainConfig {
    /// Paper-shaped defaults at testbed scale (see DESIGN.md §3): the
    /// fully quantized W8/A8/G8 in-hindsight scheme.
    pub fn new(model: &str) -> Self {
        Self {
            model: model.to_string(),
            steps: 300,
            scheme: QuantScheme::w8a8g8(),
            lr: 0.05,
            final_lr: 1e-5,
            schedule: Schedule::Step,
            weight_decay: 1e-4,
            calib_batches: 4,
            dsgc_period: 100,
            dsgc_iters: 10,
            seed: 0,
            n_train: 4096,
            n_val: 512,
            eval_every: 0, // 0 => only at the end
            log_every: 10,
        }
    }

    /// Configure the paper's "fully quantized" W8/A8/G8 setting for
    /// `est` (see [`QuantScheme::fully_quantized`] for the search-
    /// estimator activation fallback and the FP32 weight rule).  Only
    /// the class *estimators* are re-pointed: per-class bits/eta/sym
    /// and site overrides already on the config survive, so sweeping
    /// estimators over a user-built base scheme (e.g. `--eta 0.5`)
    /// keeps the user's knobs — matching the legacy field-wise
    /// mutators, which never touched `eta`.
    pub fn fully_quantized(mut self, est: Estimator) -> Self {
        self.scheme = self.scheme.with_fully_quantized(est);
        self
    }

    /// Gradient-quantization-only study (paper Table 1).
    pub fn grad_only(mut self, est: Estimator) -> Self {
        self.scheme = self.scheme.with_grad_only(est);
        self
    }

    /// Activation-quantization-only study (paper Table 2).
    pub fn act_only(mut self, est: Estimator) -> Self {
        self.scheme = self.scheme.with_act_only(est);
        self
    }

    /// Run tag: model + the scheme's one-token form + seed.
    pub fn tag(&self) -> String {
        format!("{}-{}-s{}", self.model, self.scheme.tag(), self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_parse_and_props() {
        assert_eq!(Estimator::parse("hindsight").unwrap(), Estimator::HINDSIGHT);
        assert!(Estimator::parse("bogus").is_err());
        assert!(Estimator::HINDSIGHT.is_static());
        assert!(!Estimator::CURRENT.is_static());
        assert!(Estimator::DSGC.is_static());
        assert!(!Estimator::FP32.enabled());
        assert_eq!(Estimator::CURRENT.mode(), 0.0);
        assert_eq!(Estimator::RUNNING.mode(), 1.0);
        assert_eq!(Estimator::HINDSIGHT.mode(), 2.0);
    }

    #[test]
    fn schedules() {
        let s = Schedule::Step;
        assert_eq!(s.lr_at(0.1, 0.0, 0, 90), 0.1);
        assert!((s.lr_at(0.1, 0.0, 45, 90) - 0.01).abs() < 1e-7);
        assert!((s.lr_at(0.1, 0.0, 89, 90) - 0.001).abs() < 1e-7);
        let c = Schedule::Cosine;
        assert!((c.lr_at(0.1, 1e-5, 0, 100) - 0.1).abs() < 1e-6);
        assert!(c.lr_at(0.1, 1e-5, 99, 100) < 0.001);
        // monotone decreasing
        let mut prev = f32::INFINITY;
        for step in 0..100 {
            let lr = c.lr_at(0.1, 1e-5, step, 100);
            assert!(lr <= prev);
            prev = lr;
        }
    }

    #[test]
    fn config_presets() {
        let c = TrainConfig::new("resnet_tiny").grad_only(Estimator::DSGC);
        assert_eq!(c.scheme.gradients.estimator, Estimator::DSGC);
        assert_eq!(c.scheme.activations.estimator, Estimator::FP32);
        assert!(!c.scheme.weights.enabled());
        let f = TrainConfig::new("cnn").fully_quantized(Estimator::RUNNING);
        assert!(f.scheme.weights.enabled());
        let fp = TrainConfig::new("cnn").fully_quantized(Estimator::FP32);
        assert!(!fp.scheme.weights.enabled());
        // search estimators quantize gradients; acts fall back to current
        let d = TrainConfig::new("cnn").fully_quantized(Estimator::DSGC);
        assert_eq!(d.scheme.gradients.estimator, Estimator::DSGC);
        assert_eq!(d.scheme.activations.estimator, Estimator::CURRENT);
    }

    #[test]
    fn presets_preserve_user_scheme_attrs() {
        // regression: `sweep --eta 0.5 --mode grad` must not silently
        // reset eta/bits/sym/overrides when the sweep re-points the
        // estimators per row
        let mut base = TrainConfig::new("cnn");
        base.scheme = QuantScheme::parse("w:current:8 a:hindsight:4:eta=0.5 g:hindsight:8:sym")
            .unwrap()
            .eta_all(0.5)
            .override_site_str("fc1_g", "tqt:8")
            .unwrap();
        for c in [
            base.clone().fully_quantized(Estimator::DSGC),
            base.clone().grad_only(Estimator::DSGC),
            base.clone().act_only(Estimator::RUNNING),
        ] {
            assert_eq!(c.scheme.gradients.eta, 0.5, "{}", c.scheme);
            assert_eq!(c.scheme.activations.bits, 4, "{}", c.scheme);
            assert!(c.scheme.gradients.symmetric, "{}", c.scheme);
            assert_eq!(c.scheme.overrides().count(), 1, "{}", c.scheme);
        }
        // and the estimator re-pointing itself still applies
        let d = base.clone().fully_quantized(Estimator::DSGC);
        assert_eq!(d.scheme.gradients.estimator, Estimator::DSGC);
        assert_eq!(d.scheme.activations.estimator, Estimator::CURRENT);
        let g = base.grad_only(Estimator::DSGC);
        assert_eq!(g.scheme.activations.estimator, Estimator::FP32);
    }

    #[test]
    fn per_channel_configs_parse_and_tag() {
        let pc = Estimator::parse("hindsight@pc").unwrap();
        let c = TrainConfig::new("cnn").fully_quantized(pc);
        assert!(c.scheme.gradients.is_per_channel());
        assert!(c.scheme.activations.is_per_channel()); // granularity carries over
        assert!(c.tag().contains("@pc"), "{}", c.tag());
        // per-tensor tags are unchanged
        let t = TrainConfig::new("cnn").fully_quantized(Estimator::HINDSIGHT);
        assert!(!t.tag().contains("@pc"), "{}", t.tag());
        // the tag carries the whole scheme, one token per run
        assert!(t.tag().contains("g:hindsight:8"), "{}", t.tag());
        assert!(!t.tag().contains(' '), "{}", t.tag());
    }

    #[test]
    fn config_accepts_string_form_schemes() {
        let mut c = TrainConfig::new("cnn");
        c.scheme = QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight@pc:4").unwrap();
        assert_eq!(c.scheme.gradients.bits, 4);
        assert!(c.scheme.gradients.is_per_channel());
        assert_eq!(c.scheme.activations.bits, 8);
    }
}

//! Training configuration, mirroring the paper's Sec. 5 setup.

use anyhow::{bail, Result};

/// Range-estimation method for a tensor class (paper Sec. 5.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Estimator {
    /// no quantization of this tensor class (FP32 baseline rows)
    Fp32,
    /// current min-max — dynamic, ranges from the current tensor
    Current,
    /// running min-max — dynamic, EMA blended including current stats
    Running,
    /// in-hindsight min-max — static, the paper's method (eqs. 2-3)
    Hindsight,
    /// direction-sensitive gradient clipping — static between periodic
    /// golden-section searches (gradients only in the paper)
    Dsgc,
}

impl Estimator {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "fp32" => Self::Fp32,
            "current" => Self::Current,
            "running" => Self::Running,
            "hindsight" => Self::Hindsight,
            "dsgc" => Self::Dsgc,
            other => bail!(
                "unknown estimator '{other}' \
                 (fp32|current|running|hindsight|dsgc)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Fp32 => "FP32",
            Self::Current => "Current min-max",
            Self::Running => "Running min-max",
            Self::Hindsight => "In-hindsight min-max",
            Self::Dsgc => "DSGC",
        }
    }

    /// Graph `mode` scalar (see `python/compile/quant_ops.py`).
    /// DSGC runs the graph in static (hindsight) mode; the coordinator
    /// owns its range state.  FP32's mode is irrelevant (enable is off) —
    /// static keeps the dead branch cheapest.
    pub fn mode(&self) -> f32 {
        match self {
            Self::Current => 0.0,
            Self::Running => 1.0,
            Self::Fp32 | Self::Hindsight | Self::Dsgc => 2.0,
        }
    }

    /// Whether this estimator quantizes its tensor class at all.
    pub fn enabled(&self) -> bool {
        !matches!(self, Self::Fp32)
    }

    /// Is the step-path quantization static (paper Table 1 "Static" col)?
    pub fn is_static(&self) -> bool {
        matches!(self, Self::Hindsight | Self::Dsgc | Self::Fp32)
    }
}

/// Learning-rate schedule (paper: step decay for ResNet/VGG, cosine for
/// MobileNetV2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// /10 at 1/3 and 2/3 of training (the paper's 90-epoch recipe scaled)
    Step,
    /// cosine annealing to `final_lr`
    Cosine,
    Constant,
}

impl Schedule {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "step" => Self::Step,
            "cosine" => Self::Cosine,
            "constant" => Self::Constant,
            other => bail!("unknown schedule '{other}' (step|cosine|constant)"),
        })
    }

    /// LR at `step` of `total`.
    pub fn lr_at(&self, base: f32, final_lr: f32, step: u64, total: u64) -> f32 {
        let frac = step as f32 / total.max(1) as f32;
        match self {
            Self::Constant => base,
            Self::Step => {
                if frac < 1.0 / 3.0 {
                    base
                } else if frac < 2.0 / 3.0 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
            Self::Cosine => {
                final_lr
                    + 0.5 * (base - final_lr) * (1.0 + (std::f32::consts::PI * frac).cos())
            }
        }
    }
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub steps: u64,
    pub grad_est: Estimator,
    pub act_est: Estimator,
    /// quantize weights (current min-max, per the paper)
    pub quant_weights: bool,
    /// EMA momentum for running/in-hindsight (paper: 0.9)
    pub eta: f32,
    pub lr: f32,
    pub final_lr: f32,
    pub schedule: Schedule,
    pub weight_decay: f32,
    /// calibration batches before training (paper Sec. 5.2)
    pub calib_batches: usize,
    /// DSGC update interval in steps (paper: 100)
    pub dsgc_period: u64,
    /// golden-section refinement iterations per DSGC update
    pub dsgc_iters: u32,
    pub seed: u64,
    pub n_train: usize,
    pub n_val: usize,
    pub eval_every: u64,
    pub log_every: u64,
}

impl TrainConfig {
    /// Paper-shaped defaults at testbed scale (see DESIGN.md §3).
    pub fn new(model: &str) -> Self {
        Self {
            model: model.to_string(),
            steps: 300,
            grad_est: Estimator::Hindsight,
            act_est: Estimator::Hindsight,
            quant_weights: true,
            eta: 0.9,
            lr: 0.05,
            final_lr: 1e-5,
            schedule: Schedule::Step,
            weight_decay: 1e-4,
            calib_batches: 4,
            dsgc_period: 100,
            dsgc_iters: 10,
            seed: 0,
            n_train: 4096,
            n_val: 512,
            eval_every: 0, // 0 => only at the end
            log_every: 10,
        }
    }

    /// Configure the paper's "fully quantized" W8/A8/G8 setting.
    pub fn fully_quantized(mut self, est: Estimator) -> Self {
        self.grad_est = est;
        self.act_est = est;
        self.quant_weights = est.enabled();
        self
    }

    /// Gradient-quantization-only study (paper Table 1).
    pub fn grad_only(mut self, est: Estimator) -> Self {
        self.grad_est = est;
        self.act_est = Estimator::Fp32;
        self.quant_weights = false;
        self
    }

    /// Activation-quantization-only study (paper Table 2).
    pub fn act_only(mut self, est: Estimator) -> Self {
        self.act_est = est;
        self.grad_est = Estimator::Fp32;
        self.quant_weights = false;
        self
    }

    pub fn tag(&self) -> String {
        format!(
            "{}-g:{}-a:{}-w:{}-s{}",
            self.model,
            self.grad_est.name(),
            self.act_est.name(),
            self.quant_weights,
            self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_parse_and_props() {
        assert_eq!(Estimator::parse("hindsight").unwrap(), Estimator::Hindsight);
        assert!(Estimator::parse("bogus").is_err());
        assert!(Estimator::Hindsight.is_static());
        assert!(!Estimator::Current.is_static());
        assert!(Estimator::Dsgc.is_static());
        assert!(!Estimator::Fp32.enabled());
        assert_eq!(Estimator::Current.mode(), 0.0);
        assert_eq!(Estimator::Running.mode(), 1.0);
        assert_eq!(Estimator::Hindsight.mode(), 2.0);
    }

    #[test]
    fn schedules() {
        let s = Schedule::Step;
        assert_eq!(s.lr_at(0.1, 0.0, 0, 90), 0.1);
        assert!((s.lr_at(0.1, 0.0, 45, 90) - 0.01).abs() < 1e-7);
        assert!((s.lr_at(0.1, 0.0, 89, 90) - 0.001).abs() < 1e-7);
        let c = Schedule::Cosine;
        assert!((c.lr_at(0.1, 1e-5, 0, 100) - 0.1).abs() < 1e-6);
        assert!(c.lr_at(0.1, 1e-5, 99, 100) < 0.001);
        // monotone decreasing
        let mut prev = f32::INFINITY;
        for step in 0..100 {
            let lr = c.lr_at(0.1, 1e-5, step, 100);
            assert!(lr <= prev);
            prev = lr;
        }
    }

    #[test]
    fn config_presets() {
        let c = TrainConfig::new("resnet_tiny").grad_only(Estimator::Dsgc);
        assert_eq!(c.grad_est, Estimator::Dsgc);
        assert_eq!(c.act_est, Estimator::Fp32);
        assert!(!c.quant_weights);
        let f = TrainConfig::new("cnn").fully_quantized(Estimator::Running);
        assert!(f.quant_weights);
        let fp = TrainConfig::new("cnn").fully_quantized(Estimator::Fp32);
        assert!(!fp.quant_weights);
    }
}

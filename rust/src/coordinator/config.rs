//! Training configuration, mirroring the paper's Sec. 5 setup.
//!
//! The range-estimation method for a tensor class used to be a closed
//! enum here; it is now the registry-backed [`Estimator`] handle from
//! `crate::estimator` (re-exported for the existing import paths), so a
//! config can name any registered estimator.

use anyhow::{bail, Result};

pub use crate::estimator::Estimator;

/// Learning-rate schedule (paper: step decay for ResNet/VGG, cosine for
/// MobileNetV2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// /10 at 1/3 and 2/3 of training (the paper's 90-epoch recipe scaled)
    Step,
    /// cosine annealing to `final_lr`
    Cosine,
    Constant,
}

impl Schedule {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "step" => Self::Step,
            "cosine" => Self::Cosine,
            "constant" => Self::Constant,
            other => bail!("unknown schedule '{other}' (step|cosine|constant)"),
        })
    }

    /// LR at `step` of `total`.
    pub fn lr_at(&self, base: f32, final_lr: f32, step: u64, total: u64) -> f32 {
        let frac = step as f32 / total.max(1) as f32;
        match self {
            Self::Constant => base,
            Self::Step => {
                if frac < 1.0 / 3.0 {
                    base
                } else if frac < 2.0 / 3.0 {
                    base * 0.1
                } else {
                    base * 0.01
                }
            }
            Self::Cosine => {
                final_lr
                    + 0.5 * (base - final_lr) * (1.0 + (std::f32::consts::PI * frac).cos())
            }
        }
    }
}

/// Full training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub steps: u64,
    pub grad_est: Estimator,
    pub act_est: Estimator,
    /// quantize weights (current min-max, per the paper)
    pub quant_weights: bool,
    /// EMA momentum for running/in-hindsight (paper: 0.9)
    pub eta: f32,
    pub lr: f32,
    pub final_lr: f32,
    pub schedule: Schedule,
    pub weight_decay: f32,
    /// calibration batches before training (paper Sec. 5.2)
    pub calib_batches: usize,
    /// DSGC update interval in steps (paper: 100).  0 is valid and means
    /// "search once, at step 0" — the bootstrap search only (the trainer
    /// guards the modulo; see `trainer::search_due`).
    pub dsgc_period: u64,
    /// golden-section refinement iterations per DSGC update
    pub dsgc_iters: u32,
    pub seed: u64,
    pub n_train: usize,
    pub n_val: usize,
    pub eval_every: u64,
    pub log_every: u64,
}

impl TrainConfig {
    /// Paper-shaped defaults at testbed scale (see DESIGN.md §3).
    pub fn new(model: &str) -> Self {
        Self {
            model: model.to_string(),
            steps: 300,
            grad_est: Estimator::HINDSIGHT,
            act_est: Estimator::HINDSIGHT,
            quant_weights: true,
            eta: 0.9,
            lr: 0.05,
            final_lr: 1e-5,
            schedule: Schedule::Step,
            weight_decay: 1e-4,
            calib_batches: 4,
            dsgc_period: 100,
            dsgc_iters: 10,
            seed: 0,
            n_train: 4096,
            n_val: 512,
            eval_every: 0, // 0 => only at the end
            log_every: 10,
        }
    }

    /// Configure the paper's "fully quantized" W8/A8/G8 setting.
    ///
    /// Search-based estimators (DSGC-style `needs_search`) apply to
    /// gradients only; their activation side falls back to current
    /// min-max (paper Table 3's DSGC row).  Centralized here so sweeps,
    /// benches and examples don't each re-encode the rule.
    pub fn fully_quantized(mut self, est: Estimator) -> Self {
        self.grad_est = est;
        self.act_est = if est.needs_search() { Estimator::CURRENT } else { est };
        self.quant_weights = est.enabled();
        self
    }

    /// Gradient-quantization-only study (paper Table 1).
    pub fn grad_only(mut self, est: Estimator) -> Self {
        self.grad_est = est;
        self.act_est = Estimator::FP32;
        self.quant_weights = false;
        self
    }

    /// Activation-quantization-only study (paper Table 2).
    pub fn act_only(mut self, est: Estimator) -> Self {
        self.act_est = est;
        self.grad_est = Estimator::FP32;
        self.quant_weights = false;
        self
    }

    pub fn tag(&self) -> String {
        format!(
            "{}-g:{}{}-a:{}{}-w:{}-s{}",
            self.model,
            self.grad_est.name(),
            self.grad_est.suffix(),
            self.act_est.name(),
            self.act_est.suffix(),
            self.quant_weights,
            self.seed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn estimator_parse_and_props() {
        assert_eq!(Estimator::parse("hindsight").unwrap(), Estimator::HINDSIGHT);
        assert!(Estimator::parse("bogus").is_err());
        assert!(Estimator::HINDSIGHT.is_static());
        assert!(!Estimator::CURRENT.is_static());
        assert!(Estimator::DSGC.is_static());
        assert!(!Estimator::FP32.enabled());
        assert_eq!(Estimator::CURRENT.mode(), 0.0);
        assert_eq!(Estimator::RUNNING.mode(), 1.0);
        assert_eq!(Estimator::HINDSIGHT.mode(), 2.0);
    }

    #[test]
    fn schedules() {
        let s = Schedule::Step;
        assert_eq!(s.lr_at(0.1, 0.0, 0, 90), 0.1);
        assert!((s.lr_at(0.1, 0.0, 45, 90) - 0.01).abs() < 1e-7);
        assert!((s.lr_at(0.1, 0.0, 89, 90) - 0.001).abs() < 1e-7);
        let c = Schedule::Cosine;
        assert!((c.lr_at(0.1, 1e-5, 0, 100) - 0.1).abs() < 1e-6);
        assert!(c.lr_at(0.1, 1e-5, 99, 100) < 0.001);
        // monotone decreasing
        let mut prev = f32::INFINITY;
        for step in 0..100 {
            let lr = c.lr_at(0.1, 1e-5, step, 100);
            assert!(lr <= prev);
            prev = lr;
        }
    }

    #[test]
    fn config_presets() {
        let c = TrainConfig::new("resnet_tiny").grad_only(Estimator::DSGC);
        assert_eq!(c.grad_est, Estimator::DSGC);
        assert_eq!(c.act_est, Estimator::FP32);
        assert!(!c.quant_weights);
        let f = TrainConfig::new("cnn").fully_quantized(Estimator::RUNNING);
        assert!(f.quant_weights);
        let fp = TrainConfig::new("cnn").fully_quantized(Estimator::FP32);
        assert!(!fp.quant_weights);
        // search estimators quantize gradients; acts fall back to current
        let d = TrainConfig::new("cnn").fully_quantized(Estimator::DSGC);
        assert_eq!(d.grad_est, Estimator::DSGC);
        assert_eq!(d.act_est, Estimator::CURRENT);
    }

    #[test]
    fn per_channel_configs_parse_and_tag() {
        let pc = Estimator::parse("hindsight@pc").unwrap();
        let c = TrainConfig::new("cnn").fully_quantized(pc);
        assert!(c.grad_est.is_per_channel());
        assert!(c.act_est.is_per_channel()); // granularity carries over
        assert!(c.tag().contains("@pc"), "{}", c.tag());
        // per-tensor tags are unchanged
        let t = TrainConfig::new("cnn").fully_quantized(Estimator::HINDSIGHT);
        assert!(!t.tag().contains("@pc"), "{}", t.tag());
    }
}

//! Scheme-grid sweeps: brace expansion over the scheme grammar.
//!
//! The paper's headline results are *grids* — estimator × bit-width ×
//! granularity × eta × seed (Table 3, Fig. 3, the ablations) — so the
//! sweep layer speaks grids natively.  A [`GridSpec`] is a scheme-string
//! template with shell-style alternations plus a seed list:
//!
//! ```text
//!   g:{hindsight,current,tqt}@{pt,pc}:{4,8}     × --seeds 1..5
//! ```
//!
//! Expansion is a deterministic cartesian product (the leftmost brace
//! varies slowest, exactly like shell brace expansion), every expanded
//! string parses through the [`QuantScheme`] grammar, duplicates (after
//! canonicalization) collapse to their first occurrence, and each
//! resulting cell — one `(scheme, seed)` pair — gets a unique label and
//! a dense grid index.  The executor (`coordinator::executor`) runs
//! cells by index and lands results by index, so a grid's output
//! ordering never depends on worker scheduling; the run store
//! (`coordinator::store`) keys cached cells by the canonical scheme
//! string the expansion produced.
//!
//! `@pt` is accepted as the explicit per-tensor granularity suffix so
//! granularity can be a grid axis (`@{pt,pc}`); it canonicalizes to the
//! bare key.

use std::collections::HashSet;

use anyhow::{bail, Context, Result};

use crate::coordinator::config::TrainConfig;
use crate::scheme::QuantScheme;

/// Hard cap on the total seed count a grid may carry.  `parse_seeds`
/// checks it *before* materializing a range (`"0..4000000000"` must
/// fail in O(1), not after a 32 GB allocation) and `validate_seeds`
/// enforces it for explicit lists, so the CLI `--seeds` axis and the
/// service `POST /jobs` body share one bound.
pub const MAX_SEEDS: usize = 65_536;

/// Hard cap on the brace-expansion cartesian product.  Checked from the
/// alternation counts alone before any expansion string is allocated,
/// so a brace bomb (ten 10-way alternations → 10^10 strings) is
/// rejected without allocating.
pub const MAX_EXPANSIONS: usize = 4_096;

/// Hard cap on the total bytes brace expansion may produce
/// (`expansions × template length`, an upper bound on the output).
/// Guards the cap product itself: `MAX_EXPANSIONS` strings of a
/// megabyte template would still be gigabytes.
pub const MAX_EXPANSION_BYTES: usize = 16 * 1024 * 1024;

/// Hard cap on the expanded cell count (`schemes × seeds`).  The other
/// caps bound each axis; this bounds their product, which is what
/// `GridSpec::expand` actually allocates (one `TrainConfig` per cell).
pub const MAX_GRID_CELLS: usize = 65_536;

/// One cell of an expanded grid: a full training configuration plus its
/// dense grid index and unique label.
#[derive(Debug, Clone)]
pub struct GridCell {
    /// dense index in expansion order (scheme-major, seed-minor)
    pub index: usize,
    /// unique label: `<scheme tag>#s<seed>` (single token)
    pub label: String,
    /// the cell's full configuration (scheme and seed applied)
    pub cfg: TrainConfig,
}

/// A scheme-grid template plus the seed axis.  Construction expands and
/// validates eagerly, so a held `GridSpec` is always runnable.
#[derive(Debug, Clone)]
pub struct GridSpec {
    template: String,
    /// expanded schemes, deduplicated by canonical string, in expansion
    /// order (first occurrence wins)
    schemes: Vec<QuantScheme>,
    seeds: Vec<u64>,
}

impl GridSpec {
    /// Expand `template` (scheme grammar + `{a,b,...}` alternations)
    /// against `seeds`.  Errors name the expansion that failed to parse.
    pub fn new(template: &str, seeds: &[u64]) -> Result<Self> {
        let seeds = validate_seeds(seeds)?;
        let expansions = expand_braces(template)?;
        let mut schemes: Vec<QuantScheme> = Vec::with_capacity(expansions.len());
        let mut seen: HashSet<String> = HashSet::with_capacity(expansions.len());
        for exp in &expansions {
            let scheme = QuantScheme::parse(exp)
                .with_context(|| format!("grid expansion '{exp}' of template '{template}'"))?;
            // alternations may canonicalize onto each other (e.g. an
            // explicit `@pt` vs the bare key): keep first occurrence
            if seen.insert(scheme.to_string()) {
                schemes.push(scheme);
            }
        }
        if schemes.is_empty() {
            bail!("grid template '{template}' expanded to no schemes");
        }
        let cells = schemes.len().saturating_mul(seeds.len());
        if cells > MAX_GRID_CELLS {
            bail!(
                "grid expands to {cells} cells ({} schemes x {} seeds), over the \
                 {MAX_GRID_CELLS}-cell cap (MAX_GRID_CELLS)",
                schemes.len(),
                seeds.len()
            );
        }
        Ok(Self {
            template: template.to_string(),
            schemes,
            seeds,
        })
    }

    /// Grid over an explicit scheme list (one alternation): the template
    /// is reconstructed from the canonical strings, so typed-builder
    /// callers (the benches' protocol tables) and string-template
    /// callers share one expansion/label/ordering path.
    pub fn alternation(schemes: &[QuantScheme], seeds: &[u64]) -> Result<Self> {
        if schemes.is_empty() {
            bail!("grid alternation needs at least one scheme");
        }
        let alts: Vec<String> = schemes.iter().map(|s| s.to_string()).collect();
        Self::new(&format!("{{{}}}", alts.join(",")), seeds)
    }

    pub fn template(&self) -> &str {
        &self.template
    }

    /// The expanded schemes, deduplicated, in expansion order.
    pub fn schemes(&self) -> &[QuantScheme] {
        &self.schemes
    }

    pub fn seeds(&self) -> &[u64] {
        &self.seeds
    }

    /// Total cell count (`schemes × seeds`).
    pub fn n_cells(&self) -> usize {
        self.schemes.len() * self.seeds.len()
    }

    /// Expand into ordered, uniquely-labeled cells over `base`
    /// (scheme-major, seed-minor; `base`'s own scheme and seed are
    /// replaced, everything else — model, steps, lr, ... — carries over).
    pub fn expand(&self, base: &TrainConfig) -> Vec<GridCell> {
        let mut cells = Vec::with_capacity(self.n_cells());
        for scheme in &self.schemes {
            for &seed in &self.seeds {
                let mut cfg = base.clone();
                cfg.scheme = scheme.clone();
                cfg.seed = seed;
                cells.push(GridCell {
                    index: cells.len(),
                    label: format!("{}#s{seed}", scheme.tag()),
                    cfg,
                });
            }
        }
        cells
    }
}

/// One-scheme grid helper: the cells `sweep_row` runs — `base`'s own
/// scheme across `seeds`, in seed order.
pub fn seed_cells(base: &TrainConfig, seeds: &[u64]) -> Result<Vec<GridCell>> {
    let seeds = validate_seeds(seeds)?;
    Ok(seeds
        .iter()
        .enumerate()
        .map(|(i, &seed)| {
            let mut cfg = base.clone();
            cfg.seed = seed;
            GridCell {
                index: i,
                label: format!("{}#s{seed}", base.scheme.tag()),
                cfg,
            }
        })
        .collect())
}

/// Parse the CLI seed axis: comma-separated integers and/or inclusive
/// `a..b` ranges (`"1..5"` → 1,2,3,4,5; `"1,2,7..9"` → 1,2,7,8,9).
///
/// Ranges are bounds-checked against [`MAX_SEEDS`] *before* they are
/// materialized: `"0..4000000000"` fails with the cap named, it does
/// not allocate 32 GB first.
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    let mut seeds = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            bail!("empty seed token in '{s}'");
        }
        if let Some((a, b)) = tok.split_once("..") {
            let lo: u64 = a
                .trim()
                .parse()
                .with_context(|| format!("bad seed range start '{a}' in '{s}'"))?;
            let hi: u64 = b
                .trim()
                .parse()
                .with_context(|| format!("bad seed range end '{b}' in '{s}'"))?;
            if lo > hi {
                bail!("seed range '{tok}' is empty (start > end; ranges are inclusive)");
            }
            // span check before +1 so `0..u64::MAX` cannot overflow
            let span = hi - lo;
            if span >= MAX_SEEDS as u64
                || seeds.len() as u64 + span + 1 > MAX_SEEDS as u64
            {
                bail!(
                    "seed range '{tok}' would push the seed count over the \
                     {MAX_SEEDS}-seed cap (MAX_SEEDS)"
                );
            }
            seeds.extend(lo..=hi);
        } else {
            if seeds.len() >= MAX_SEEDS {
                bail!("more than {MAX_SEEDS} seeds in '{s}' (cap MAX_SEEDS)");
            }
            seeds.push(
                tok.parse()
                    .with_context(|| format!("bad seed '{tok}' in '{s}'"))?,
            );
        }
    }
    validate_seeds(&seeds)
}

/// Inverse of [`parse_seeds`]: render a seed list in the same grammar,
/// compressing maximal consecutive ascending runs to `a..b` ranges.
/// Exact for all of `u64` (no float hop), so it is the lossless
/// serialization form for persisted job specs:
/// `parse_seeds(&format_seeds(s)).unwrap() == s` for any valid list.
pub fn format_seeds(seeds: &[u64]) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < seeds.len() {
        let start = seeds[i];
        let mut end = start;
        let mut j = i + 1;
        while j < seeds.len() && end < u64::MAX && seeds[j] == end + 1 {
            end = seeds[j];
            j += 1;
        }
        if !out.is_empty() {
            out.push(',');
        }
        if j - i >= 2 {
            out.push_str(&format!("{start}..{end}"));
        } else {
            out.push_str(&format!("{start}"));
        }
        i = j;
    }
    out
}

/// The seed-list rules every grid surface shares: non-empty,
/// duplicate-free (a duplicated seed would silently double-weight one
/// run in every aggregate), and at most [`MAX_SEEDS`] entries.
fn validate_seeds(seeds: &[u64]) -> Result<Vec<u64>> {
    if seeds.is_empty() {
        bail!("empty seed list — pass at least one seed");
    }
    if seeds.len() > MAX_SEEDS {
        bail!(
            "{} seeds exceed the {MAX_SEEDS}-seed cap (MAX_SEEDS)",
            seeds.len()
        );
    }
    let mut seen = HashSet::with_capacity(seeds.len());
    for s in seeds {
        if !seen.insert(*s) {
            bail!("duplicate seed {s} — each seed may appear once per grid");
        }
    }
    Ok(seeds.to_vec())
}

/// Shell-style brace expansion: every `{a,b,...}` alternation multiplies
/// the result set; the leftmost brace varies slowest.  Braces do not
/// nest; an empty alternative (`{a,}`) is allowed (optional-suffix
/// grids like `hindsight{,@pc}`).
///
/// The template is scanned twice.  The first pass validates structure
/// and multiplies the alternation counts, so both the
/// [`MAX_EXPANSIONS`] product cap and the [`MAX_EXPANSION_BYTES`]
/// output-size cap are enforced *before* any expansion string is
/// allocated — a brace bomb costs one arithmetic pass over the
/// template, nothing more.  The second pass builds the product
/// iteratively (no recursion: a template of thousands of groups must
/// not overflow the stack).
pub fn expand_braces(template: &str) -> Result<Vec<String>> {
    // pass 1: locate groups, validate, and bound the product
    let mut groups: Vec<(usize, usize)> = Vec::new(); // (open, close) offsets
    let mut product = 1usize;
    let mut rest = template;
    let mut base = 0usize;
    loop {
        let Some(open) = rest.find('{') else {
            if rest.contains('}') {
                bail!("unmatched '}}' in '{template}'");
            }
            break;
        };
        if rest[..open].contains('}') {
            bail!("unmatched '}}' in '{template}'");
        }
        let after = &rest[open + 1..];
        let close = after
            .find('}')
            .with_context(|| format!("unmatched '{{' in '{template}'"))?;
        let body = &after[..close];
        if body.contains('{') {
            bail!("nested braces in '{template}' — alternations do not nest");
        }
        if body.is_empty() {
            bail!("empty alternation '{{}}' in '{template}'");
        }
        product = product.saturating_mul(body.split(',').count());
        if product > MAX_EXPANSIONS {
            bail!(
                "template '{template}' expands to more than {MAX_EXPANSIONS} \
                 schemes (cap MAX_EXPANSIONS)"
            );
        }
        groups.push((base + open, base + open + 1 + close));
        let consumed = open + 1 + close + 1;
        base += consumed;
        rest = &rest[consumed..];
    }
    // `product × template length` over-counts (braces are dropped, one
    // alternative replaces the whole group) so it upper-bounds output
    if product.saturating_mul(template.len().max(1)) > MAX_EXPANSION_BYTES {
        bail!(
            "template '{template}' would expand to more than \
             {MAX_EXPANSION_BYTES} bytes (cap MAX_EXPANSION_BYTES)"
        );
    }

    // pass 2: iterative product, leftmost group varying slowest
    let mut out: Vec<String> = vec![String::with_capacity(template.len())];
    let mut pos = 0usize;
    for &(open, close) in &groups {
        let lit = &template[pos..open];
        if !lit.is_empty() {
            for s in out.iter_mut() {
                s.push_str(lit);
            }
        }
        let alts: Vec<&str> = template[open + 1..close].split(',').map(str::trim).collect();
        if alts.len() == 1 {
            for s in out.iter_mut() {
                s.push_str(alts[0]);
            }
        } else {
            let mut next = Vec::with_capacity(out.len() * alts.len());
            for s in &out {
                for alt in &alts {
                    let mut n = String::with_capacity(s.len() + alt.len());
                    n.push_str(s);
                    n.push_str(alt);
                    next.push(n);
                }
            }
            out = next;
        }
        pos = close + 1;
    }
    let tail = &template[pos..];
    if !tail.is_empty() {
        for s in out.iter_mut() {
            s.push_str(tail);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::Estimator;
    use crate::util::testkit::forall;

    #[test]
    fn brace_expansion_is_shell_ordered() {
        assert_eq!(expand_braces("plain").unwrap(), vec!["plain"]);
        assert_eq!(
            expand_braces("x{a,b}y{1,2}").unwrap(),
            vec!["xay1", "xay2", "xby1", "xby2"]
        );
        // empty alternative = optional suffix
        assert_eq!(
            expand_braces("hindsight{,@pc}").unwrap(),
            vec!["hindsight", "hindsight@pc"]
        );
        // whitespace around alternatives is trimmed
        assert_eq!(expand_braces("{a, b}").unwrap(), vec!["a", "b"]);
    }

    #[test]
    fn malformed_templates_are_rejected() {
        assert!(expand_braces("{a,b").is_err()); // unmatched {
        assert!(expand_braces("a}b").is_err()); // unmatched }
        assert!(expand_braces("{a,{b,c}}").is_err()); // nested
        assert!(expand_braces("{}").is_err()); // empty alternation
        assert!(GridSpec::new("g:{bogus,hindsight}:8", &[1]).is_err()); // bad key
        assert!(GridSpec::new("g:hindsight:{1,8}", &[1]).is_err()); // bad bits
        let err = format!(
            "{:#}",
            GridSpec::new("g:{hindsight,nope}:8", &[1]).unwrap_err()
        );
        assert!(err.contains("g:nope:8"), "names the expansion: {err}");
    }

    #[test]
    fn the_issue_grid_expands_deterministically() {
        let template = "g:{hindsight,current,tqt}@{pt,pc}:{4,8}";
        let a = GridSpec::new(template, &[1, 2, 3, 4, 5]).unwrap();
        let b = GridSpec::new(template, &[1, 2, 3, 4, 5]).unwrap();
        // deterministic: two expansions agree exactly
        let canon = |g: &GridSpec| -> Vec<String> {
            g.schemes().iter().map(|s| s.to_string()).collect()
        };
        assert_eq!(canon(&a), canon(&b));
        // 3 estimators × 2 granularities × 2 bit-widths, duplicate-free
        assert_eq!(a.schemes().len(), 12);
        assert_eq!(a.n_cells(), 60);
        let mut seen = canon(&a);
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 12, "expansion must be duplicate-free");
        // `@pt` canonicalizes to the bare key; `@pc` survives
        assert!(canon(&a).contains(&"w:fp32:8 a:fp32:8 g:hindsight:4".to_string()));
        assert!(canon(&a).contains(&"w:fp32:8 a:fp32:8 g:tqt@pc:8".to_string()));
    }

    /// Satellite acceptance: expansion is deterministic, duplicate-free
    /// and label-unique across estimators × granularities × bits.
    #[test]
    fn expansion_exhaustive_over_estimators_granularities_and_bits() {
        let keys = Estimator::keys().join(",");
        let template = format!("g:{{{keys}}}@{{pt,pc}}:{{2,4,8}}");
        let grid = GridSpec::new(&template, &[1, 2]).unwrap();
        let n = Estimator::keys().len() * 2 * 3;
        assert_eq!(grid.schemes().len(), n);
        // expansion order matches the nested-loop order (key slowest,
        // granularity, then bits) and every scheme equals its
        // builder-constructed counterpart
        let mut i = 0;
        for est in Estimator::all() {
            for pc in [false, true] {
                let est = if pc { est.per_channel() } else { est };
                for bits in [2u32, 4, 8] {
                    let mut want = QuantScheme::fp32();
                    want.gradients.estimator = est;
                    let want = want.bits(crate::scheme::TensorClass::Gradients, bits);
                    assert_eq!(grid.schemes()[i], want, "slot {i}");
                    i += 1;
                }
            }
        }
        // labels are unique across the whole cell set
        let cells = grid.expand(&TrainConfig::new("mlp"));
        assert_eq!(cells.len(), n * 2);
        let mut labels: Vec<&str> = cells.iter().map(|c| c.label.as_str()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), n * 2, "cell labels must be unique");
        // indices are dense and in order
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    /// Randomized: any alternation set (with textual duplicates) expands
    /// deterministically into a duplicate-free, label-unique grid.
    #[test]
    fn random_alternation_grids_are_duplicate_free() {
        let keys = Estimator::keys();
        forall(
            64,
            "grid-dedup",
            |rng| {
                // 2-5 alternatives, possibly repeating, over random
                // keys/granularities/bits
                let n = 2 + rng.below(4);
                let alts: Vec<String> = (0..n)
                    .map(|_| {
                        let key = keys[rng.below(keys.len())];
                        let gran = ["", "@pt", "@pc"][rng.below(3)];
                        let bits = [4, 8][rng.below(2)];
                        format!("{key}{gran}:{bits}")
                    })
                    .collect();
                format!("g:{{{}}}", alts.join(","))
            },
            |template| {
                let a = GridSpec::new(template, &[7]).unwrap();
                let b = GridSpec::new(template, &[7]).unwrap();
                let canon: Vec<String> =
                    a.schemes().iter().map(|s| s.to_string()).collect();
                let canon_b: Vec<String> =
                    b.schemes().iter().map(|s| s.to_string()).collect();
                let mut uniq = canon.clone();
                uniq.sort();
                uniq.dedup();
                canon == canon_b && uniq.len() == canon.len()
            },
        );
    }

    #[test]
    fn cells_carry_the_base_config() {
        let mut base = TrainConfig::new("cnn");
        base.steps = 77;
        base.lr = 0.25;
        let grid = GridSpec::new("g:{hindsight,current}:8", &[3, 9]).unwrap();
        let cells = grid.expand(&base);
        assert_eq!(cells.len(), 4);
        // scheme-major, seed-minor
        assert_eq!(cells[0].cfg.seed, 3);
        assert_eq!(cells[1].cfg.seed, 9);
        assert_eq!(cells[0].cfg.scheme, cells[1].cfg.scheme);
        assert_ne!(cells[1].cfg.scheme, cells[2].cfg.scheme);
        for c in &cells {
            assert_eq!(c.cfg.steps, 77);
            assert_eq!(c.cfg.lr, 0.25);
            assert_eq!(c.cfg.model, "cnn");
            assert!(c.label.contains("#s"), "{}", c.label);
            assert!(!c.label.contains(' '), "{}", c.label);
        }
    }

    #[test]
    fn alternation_matches_the_textual_template() {
        let schemes = vec![
            QuantScheme::fully_quantized(Estimator::HINDSIGHT),
            QuantScheme::fully_quantized(Estimator::DSGC),
        ];
        let grid = GridSpec::alternation(&schemes, &[1]).unwrap();
        assert_eq!(grid.schemes(), &schemes[..]);
        // duplicates collapse to first occurrence
        let dup = vec![schemes[0].clone(), schemes[0].clone(), schemes[1].clone()];
        assert_eq!(GridSpec::alternation(&dup, &[1]).unwrap().schemes().len(), 2);
        assert!(GridSpec::alternation(&[], &[1]).is_err());
    }

    #[test]
    fn seed_parsing_ranges_and_lists() {
        assert_eq!(parse_seeds("1..5").unwrap(), vec![1, 2, 3, 4, 5]);
        assert_eq!(parse_seeds("1,2,7..9").unwrap(), vec![1, 2, 7, 8, 9]);
        assert_eq!(parse_seeds("4").unwrap(), vec![4]);
        assert_eq!(parse_seeds(" 1 , 2 ").unwrap(), vec![1, 2]);
        assert!(parse_seeds("").is_err());
        assert!(parse_seeds("5..1").is_err());
        assert!(parse_seeds("x").is_err());
        assert!(parse_seeds("1,1").is_err());
        assert!(parse_seeds("1..3,2").is_err()); // overlapping range
    }

    /// Regression (fuzz finding, DoS): an adversarial seed range must
    /// fail naming the cap without materializing the range.
    #[test]
    fn seed_range_bombs_are_rejected_without_allocating() {
        for s in [
            "0..4000000000",
            "0..18446744073709551615",
            &format!("0..{}", u64::MAX - 1),
            "1..65538",
            "0,1..65536",
        ] {
            let err = format!("{:#}", parse_seeds(s).unwrap_err());
            assert!(err.contains("MAX_SEEDS"), "'{s}' must name the cap: {err}");
        }
        // the cap itself is inclusive: exactly MAX_SEEDS seeds pass
        let seeds = parse_seeds(&format!("0..{}", MAX_SEEDS - 1)).unwrap();
        assert_eq!(seeds.len(), MAX_SEEDS);
        assert!(parse_seeds(&format!("0..{MAX_SEEDS}")).is_err());
    }

    /// Regression (fuzz finding, DoS): a brace bomb must fail from the
    /// alternation counts alone, before any expansion is allocated.
    #[test]
    fn brace_bombs_are_rejected_before_allocation() {
        // ten 10-way alternations → 10^10 expansions
        let bomb = "{0,1,2,3,4,5,6,7,8,9}".repeat(10);
        let err = format!("{:#}", expand_braces(&bomb).unwrap_err());
        assert!(err.contains("MAX_EXPANSIONS"), "{err}");
        // byte cap: few expansions of a huge template
        let wide = format!("{}{{a,b}}", "x".repeat(9 * 1024 * 1024));
        let err = format!("{:#}", expand_braces(&wide).unwrap_err());
        assert!(err.contains("MAX_EXPANSION_BYTES"), "{err}");
        // and the service-facing path surfaces the same failure
        assert!(GridSpec::new(&bomb, &[1]).is_err());
    }

    /// Regression (fuzz finding): thousands of brace groups used to
    /// recurse once per group and overflow the stack.
    #[test]
    fn many_brace_groups_expand_iteratively() {
        let template = "{a}".repeat(10_000);
        let out = expand_braces(&template).unwrap();
        assert_eq!(out, vec!["a".repeat(10_000)]);
        // alternating many groups still respects the product cap
        let alt = "{a,b}".repeat(64);
        let err = format!("{:#}", expand_braces(&alt).unwrap_err());
        assert!(err.contains("MAX_EXPANSIONS"), "{err}");
        // 2^12 == MAX_EXPANSIONS passes exactly
        let edge = "{a,b}".repeat(12);
        assert_eq!(expand_braces(&edge).unwrap().len(), MAX_EXPANSIONS);
    }

    #[test]
    fn unmatched_close_before_a_group_is_rejected() {
        // the old recursive expander silently passed a stray '}' that
        // preceded a valid group; the scanner rejects it uniformly
        assert!(expand_braces("a}b{c,d}").is_err());
        assert!(expand_braces("{c,d}a}b").is_err());
    }

    #[test]
    fn schemes_times_seeds_cell_cap_is_enforced() {
        // 30 schemes × 4096 seeds = 122880 cells > MAX_GRID_CELLS,
        // though each axis alone is under its own cap
        let template = "g:hindsight@{pt,pc}:{2,3,4,5,6,7,8,9,10,11,12,13,14,15,16}";
        let seeds: Vec<u64> = (0..4096).collect();
        let err = format!("{:#}", GridSpec::new(template, &seeds).unwrap_err());
        assert!(err.contains("MAX_GRID_CELLS"), "{err}");
        // under the cap the same template works
        assert!(GridSpec::new(template, &[1, 2]).is_ok());
    }

    #[test]
    fn format_seeds_round_trips_exactly() {
        assert_eq!(format_seeds(&[1, 2, 3, 4, 5]), "1..5");
        assert_eq!(format_seeds(&[1, 2, 7, 8, 9]), "1..2,7..9");
        assert_eq!(format_seeds(&[4]), "4");
        assert_eq!(format_seeds(&[5, 3, 1]), "5,3,1");
        assert_eq!(
            format_seeds(&[9007199254740993, u64::MAX]),
            "9007199254740993,18446744073709551615"
        );
        // u64::MAX terminates a run without overflowing
        assert_eq!(
            format_seeds(&[u64::MAX - 1, u64::MAX]),
            format!("{}..{}", u64::MAX - 1, u64::MAX)
        );
        forall(
            64,
            "format-seeds-roundtrip",
            |rng| {
                let n = 1 + rng.below(20);
                let mut seeds: Vec<u64> = Vec::with_capacity(n);
                let mut next = rng.below(100) as u64;
                for _ in 0..n {
                    // mix of consecutive runs, gaps, and huge values
                    next = match rng.below(4) {
                        0 => next.wrapping_add(1),
                        1 => next.wrapping_add(2 + rng.below(50) as u64),
                        2 => next.wrapping_add(1) | (1u64 << 53),
                        _ => u64::MAX - rng.below(3) as u64,
                    };
                    if !seeds.contains(&next) {
                        seeds.push(next);
                    }
                }
                seeds
            },
            |seeds| parse_seeds(&format_seeds(seeds)).map(|p| &p == seeds).unwrap_or(false),
        );
    }

    #[test]
    fn empty_or_duplicate_seed_axes_are_rejected() {
        assert!(GridSpec::new("g:hindsight:8", &[]).is_err());
        assert!(GridSpec::new("g:hindsight:8", &[1, 1]).is_err());
        assert!(seed_cells(&TrainConfig::new("mlp"), &[]).is_err());
        let cells = seed_cells(&TrainConfig::new("mlp"), &[5, 6]).unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].cfg.seed, 5);
        assert_eq!(cells[1].index, 1);
    }
}

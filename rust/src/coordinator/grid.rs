//! Scheme-grid sweeps: brace expansion over the scheme grammar.
//!
//! The paper's headline results are *grids* — estimator × bit-width ×
//! granularity × eta × seed (Table 3, Fig. 3, the ablations) — so the
//! sweep layer speaks grids natively.  A [`GridSpec`] is a scheme-string
//! template with shell-style alternations plus a seed list:
//!
//! ```text
//!   g:{hindsight,current,tqt}@{pt,pc}:{4,8}     × --seeds 1..5
//! ```
//!
//! Expansion is a deterministic cartesian product (the leftmost brace
//! varies slowest, exactly like shell brace expansion), every expanded
//! string parses through the [`QuantScheme`] grammar, duplicates (after
//! canonicalization) collapse to their first occurrence, and each
//! resulting cell — one `(scheme, seed)` pair — gets a unique label and
//! a dense grid index.  The executor (`coordinator::executor`) runs
//! cells by index and lands results by index, so a grid's output
//! ordering never depends on worker scheduling; the run store
//! (`coordinator::store`) keys cached cells by the canonical scheme
//! string the expansion produced.
//!
//! `@pt` is accepted as the explicit per-tensor granularity suffix so
//! granularity can be a grid axis (`@{pt,pc}`); it canonicalizes to the
//! bare key.

use anyhow::{bail, Context, Result};

use crate::coordinator::config::TrainConfig;
use crate::scheme::QuantScheme;

/// One cell of an expanded grid: a full training configuration plus its
/// dense grid index and unique label.
#[derive(Debug, Clone)]
pub struct GridCell {
    /// dense index in expansion order (scheme-major, seed-minor)
    pub index: usize,
    /// unique label: `<scheme tag>#s<seed>` (single token)
    pub label: String,
    /// the cell's full configuration (scheme and seed applied)
    pub cfg: TrainConfig,
}

/// A scheme-grid template plus the seed axis.  Construction expands and
/// validates eagerly, so a held `GridSpec` is always runnable.
#[derive(Debug, Clone)]
pub struct GridSpec {
    template: String,
    /// expanded schemes, deduplicated by canonical string, in expansion
    /// order (first occurrence wins)
    schemes: Vec<QuantScheme>,
    seeds: Vec<u64>,
}

impl GridSpec {
    /// Expand `template` (scheme grammar + `{a,b,...}` alternations)
    /// against `seeds`.  Errors name the expansion that failed to parse.
    pub fn new(template: &str, seeds: &[u64]) -> Result<Self> {
        let seeds = validate_seeds(seeds)?;
        let expansions = expand_braces(template)?;
        let mut schemes: Vec<QuantScheme> = Vec::with_capacity(expansions.len());
        let mut seen: Vec<String> = Vec::with_capacity(expansions.len());
        for exp in &expansions {
            let scheme = QuantScheme::parse(exp)
                .with_context(|| format!("grid expansion '{exp}' of template '{template}'"))?;
            let canon = scheme.to_string();
            // alternations may canonicalize onto each other (e.g. an
            // explicit `@pt` vs the bare key): keep first occurrence
            if !seen.contains(&canon) {
                seen.push(canon);
                schemes.push(scheme);
            }
        }
        if schemes.is_empty() {
            bail!("grid template '{template}' expanded to no schemes");
        }
        Ok(Self {
            template: template.to_string(),
            schemes,
            seeds,
        })
    }

    /// Grid over an explicit scheme list (one alternation): the template
    /// is reconstructed from the canonical strings, so typed-builder
    /// callers (the benches' protocol tables) and string-template
    /// callers share one expansion/label/ordering path.
    pub fn alternation(schemes: &[QuantScheme], seeds: &[u64]) -> Result<Self> {
        if schemes.is_empty() {
            bail!("grid alternation needs at least one scheme");
        }
        let alts: Vec<String> = schemes.iter().map(|s| s.to_string()).collect();
        Self::new(&format!("{{{}}}", alts.join(",")), seeds)
    }

    pub fn template(&self) -> &str {
        &self.template
    }

    /// The expanded schemes, deduplicated, in expansion order.
    pub fn schemes(&self) -> &[QuantScheme] {
        &self.schemes
    }

    pub fn seeds(&self) -> &[u64] {
        &self.seeds
    }

    /// Total cell count (`schemes × seeds`).
    pub fn n_cells(&self) -> usize {
        self.schemes.len() * self.seeds.len()
    }

    /// Expand into ordered, uniquely-labeled cells over `base`
    /// (scheme-major, seed-minor; `base`'s own scheme and seed are
    /// replaced, everything else — model, steps, lr, ... — carries over).
    pub fn expand(&self, base: &TrainConfig) -> Vec<GridCell> {
        let mut cells = Vec::with_capacity(self.n_cells());
        for scheme in &self.schemes {
            for &seed in &self.seeds {
                let mut cfg = base.clone();
                cfg.scheme = scheme.clone();
                cfg.seed = seed;
                cells.push(GridCell {
                    index: cells.len(),
                    label: format!("{}#s{seed}", scheme.tag()),
                    cfg,
                });
            }
        }
        cells
    }
}

/// One-scheme grid helper: the cells `sweep_row` runs — `base`'s own
/// scheme across `seeds`, in seed order.
pub fn seed_cells(base: &TrainConfig, seeds: &[u64]) -> Result<Vec<GridCell>> {
    let seeds = validate_seeds(seeds)?;
    Ok(seeds
        .iter()
        .enumerate()
        .map(|(i, &seed)| {
            let mut cfg = base.clone();
            cfg.seed = seed;
            GridCell {
                index: i,
                label: format!("{}#s{seed}", base.scheme.tag()),
                cfg,
            }
        })
        .collect())
}

/// Parse the CLI seed axis: comma-separated integers and/or inclusive
/// `a..b` ranges (`"1..5"` → 1,2,3,4,5; `"1,2,7..9"` → 1,2,7,8,9).
pub fn parse_seeds(s: &str) -> Result<Vec<u64>> {
    let mut seeds = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            bail!("empty seed token in '{s}'");
        }
        if let Some((a, b)) = tok.split_once("..") {
            let lo: u64 = a
                .trim()
                .parse()
                .with_context(|| format!("bad seed range start '{a}' in '{s}'"))?;
            let hi: u64 = b
                .trim()
                .parse()
                .with_context(|| format!("bad seed range end '{b}' in '{s}'"))?;
            if lo > hi {
                bail!("seed range '{tok}' is empty (start > end; ranges are inclusive)");
            }
            seeds.extend(lo..=hi);
        } else {
            seeds.push(
                tok.parse()
                    .with_context(|| format!("bad seed '{tok}' in '{s}'"))?,
            );
        }
    }
    validate_seeds(&seeds)
}

/// The one seed-list rule every grid surface shares: non-empty,
/// duplicate-free (a duplicated seed would silently double-weight one
/// run in every aggregate).
fn validate_seeds(seeds: &[u64]) -> Result<Vec<u64>> {
    if seeds.is_empty() {
        bail!("empty seed list — pass at least one seed");
    }
    for (i, s) in seeds.iter().enumerate() {
        if seeds[..i].contains(s) {
            bail!("duplicate seed {s} — each seed may appear once per grid");
        }
    }
    Ok(seeds.to_vec())
}

/// Shell-style brace expansion: every `{a,b,...}` alternation multiplies
/// the result set; the leftmost brace varies slowest.  Braces do not
/// nest; an empty alternative (`{a,}`) is allowed (optional-suffix
/// grids like `hindsight{,@pc}`).
pub fn expand_braces(template: &str) -> Result<Vec<String>> {
    let Some(open) = template.find('{') else {
        if template.contains('}') {
            bail!("unmatched '}}' in '{template}'");
        }
        return Ok(vec![template.to_string()]);
    };
    let rest = &template[open + 1..];
    let close = rest
        .find('}')
        .with_context(|| format!("unmatched '{{' in '{template}'"))?;
    let body = &rest[..close];
    if body.contains('{') {
        bail!("nested braces in '{template}' — alternations do not nest");
    }
    if body.is_empty() {
        bail!("empty alternation '{{}}' in '{template}'");
    }
    let prefix = &template[..open];
    let tails = expand_braces(&rest[close + 1..])?;
    let mut out = Vec::with_capacity(body.split(',').count() * tails.len());
    for alt in body.split(',') {
        let alt = alt.trim();
        for tail in &tails {
            out.push(format!("{prefix}{alt}{tail}"));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::Estimator;
    use crate::util::testkit::forall;

    #[test]
    fn brace_expansion_is_shell_ordered() {
        assert_eq!(expand_braces("plain").unwrap(), vec!["plain"]);
        assert_eq!(
            expand_braces("x{a,b}y{1,2}").unwrap(),
            vec!["xay1", "xay2", "xby1", "xby2"]
        );
        // empty alternative = optional suffix
        assert_eq!(
            expand_braces("hindsight{,@pc}").unwrap(),
            vec!["hindsight", "hindsight@pc"]
        );
        // whitespace around alternatives is trimmed
        assert_eq!(expand_braces("{a, b}").unwrap(), vec!["a", "b"]);
    }

    #[test]
    fn malformed_templates_are_rejected() {
        assert!(expand_braces("{a,b").is_err()); // unmatched {
        assert!(expand_braces("a}b").is_err()); // unmatched }
        assert!(expand_braces("{a,{b,c}}").is_err()); // nested
        assert!(expand_braces("{}").is_err()); // empty alternation
        assert!(GridSpec::new("g:{bogus,hindsight}:8", &[1]).is_err()); // bad key
        assert!(GridSpec::new("g:hindsight:{1,8}", &[1]).is_err()); // bad bits
        let err = format!(
            "{:#}",
            GridSpec::new("g:{hindsight,nope}:8", &[1]).unwrap_err()
        );
        assert!(err.contains("g:nope:8"), "names the expansion: {err}");
    }

    #[test]
    fn the_issue_grid_expands_deterministically() {
        let template = "g:{hindsight,current,tqt}@{pt,pc}:{4,8}";
        let a = GridSpec::new(template, &[1, 2, 3, 4, 5]).unwrap();
        let b = GridSpec::new(template, &[1, 2, 3, 4, 5]).unwrap();
        // deterministic: two expansions agree exactly
        let canon = |g: &GridSpec| -> Vec<String> {
            g.schemes().iter().map(|s| s.to_string()).collect()
        };
        assert_eq!(canon(&a), canon(&b));
        // 3 estimators × 2 granularities × 2 bit-widths, duplicate-free
        assert_eq!(a.schemes().len(), 12);
        assert_eq!(a.n_cells(), 60);
        let mut seen = canon(&a);
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 12, "expansion must be duplicate-free");
        // `@pt` canonicalizes to the bare key; `@pc` survives
        assert!(canon(&a).contains(&"w:fp32:8 a:fp32:8 g:hindsight:4".to_string()));
        assert!(canon(&a).contains(&"w:fp32:8 a:fp32:8 g:tqt@pc:8".to_string()));
    }

    /// Satellite acceptance: expansion is deterministic, duplicate-free
    /// and label-unique across estimators × granularities × bits.
    #[test]
    fn expansion_exhaustive_over_estimators_granularities_and_bits() {
        let keys = Estimator::keys().join(",");
        let template = format!("g:{{{keys}}}@{{pt,pc}}:{{2,4,8}}");
        let grid = GridSpec::new(&template, &[1, 2]).unwrap();
        let n = Estimator::keys().len() * 2 * 3;
        assert_eq!(grid.schemes().len(), n);
        // expansion order matches the nested-loop order (key slowest,
        // granularity, then bits) and every scheme equals its
        // builder-constructed counterpart
        let mut i = 0;
        for est in Estimator::all() {
            for pc in [false, true] {
                let est = if pc { est.per_channel() } else { est };
                for bits in [2u32, 4, 8] {
                    let mut want = QuantScheme::fp32();
                    want.gradients.estimator = est;
                    let want = want.bits(crate::scheme::TensorClass::Gradients, bits);
                    assert_eq!(grid.schemes()[i], want, "slot {i}");
                    i += 1;
                }
            }
        }
        // labels are unique across the whole cell set
        let cells = grid.expand(&TrainConfig::new("mlp"));
        assert_eq!(cells.len(), n * 2);
        let mut labels: Vec<&str> = cells.iter().map(|c| c.label.as_str()).collect();
        labels.sort();
        labels.dedup();
        assert_eq!(labels.len(), n * 2, "cell labels must be unique");
        // indices are dense and in order
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    /// Randomized: any alternation set (with textual duplicates) expands
    /// deterministically into a duplicate-free, label-unique grid.
    #[test]
    fn random_alternation_grids_are_duplicate_free() {
        let keys = Estimator::keys();
        forall(
            64,
            "grid-dedup",
            |rng| {
                // 2-5 alternatives, possibly repeating, over random
                // keys/granularities/bits
                let n = 2 + rng.below(4);
                let alts: Vec<String> = (0..n)
                    .map(|_| {
                        let key = keys[rng.below(keys.len())];
                        let gran = ["", "@pt", "@pc"][rng.below(3)];
                        let bits = [4, 8][rng.below(2)];
                        format!("{key}{gran}:{bits}")
                    })
                    .collect();
                format!("g:{{{}}}", alts.join(","))
            },
            |template| {
                let a = GridSpec::new(template, &[7]).unwrap();
                let b = GridSpec::new(template, &[7]).unwrap();
                let canon: Vec<String> =
                    a.schemes().iter().map(|s| s.to_string()).collect();
                let canon_b: Vec<String> =
                    b.schemes().iter().map(|s| s.to_string()).collect();
                let mut uniq = canon.clone();
                uniq.sort();
                uniq.dedup();
                canon == canon_b && uniq.len() == canon.len()
            },
        );
    }

    #[test]
    fn cells_carry_the_base_config() {
        let mut base = TrainConfig::new("cnn");
        base.steps = 77;
        base.lr = 0.25;
        let grid = GridSpec::new("g:{hindsight,current}:8", &[3, 9]).unwrap();
        let cells = grid.expand(&base);
        assert_eq!(cells.len(), 4);
        // scheme-major, seed-minor
        assert_eq!(cells[0].cfg.seed, 3);
        assert_eq!(cells[1].cfg.seed, 9);
        assert_eq!(cells[0].cfg.scheme, cells[1].cfg.scheme);
        assert_ne!(cells[1].cfg.scheme, cells[2].cfg.scheme);
        for c in &cells {
            assert_eq!(c.cfg.steps, 77);
            assert_eq!(c.cfg.lr, 0.25);
            assert_eq!(c.cfg.model, "cnn");
            assert!(c.label.contains("#s"), "{}", c.label);
            assert!(!c.label.contains(' '), "{}", c.label);
        }
    }

    #[test]
    fn alternation_matches_the_textual_template() {
        let schemes = vec![
            QuantScheme::fully_quantized(Estimator::HINDSIGHT),
            QuantScheme::fully_quantized(Estimator::DSGC),
        ];
        let grid = GridSpec::alternation(&schemes, &[1]).unwrap();
        assert_eq!(grid.schemes(), &schemes[..]);
        // duplicates collapse to first occurrence
        let dup = vec![schemes[0].clone(), schemes[0].clone(), schemes[1].clone()];
        assert_eq!(GridSpec::alternation(&dup, &[1]).unwrap().schemes().len(), 2);
        assert!(GridSpec::alternation(&[], &[1]).is_err());
    }

    #[test]
    fn seed_parsing_ranges_and_lists() {
        assert_eq!(parse_seeds("1..5").unwrap(), vec![1, 2, 3, 4, 5]);
        assert_eq!(parse_seeds("1,2,7..9").unwrap(), vec![1, 2, 7, 8, 9]);
        assert_eq!(parse_seeds("4").unwrap(), vec![4]);
        assert_eq!(parse_seeds(" 1 , 2 ").unwrap(), vec![1, 2]);
        assert!(parse_seeds("").is_err());
        assert!(parse_seeds("5..1").is_err());
        assert!(parse_seeds("x").is_err());
        assert!(parse_seeds("1,1").is_err());
        assert!(parse_seeds("1..3,2").is_err()); // overlapping range
    }

    #[test]
    fn empty_or_duplicate_seed_axes_are_rejected() {
        assert!(GridSpec::new("g:hindsight:8", &[]).is_err());
        assert!(GridSpec::new("g:hindsight:8", &[1, 1]).is_err());
        assert!(seed_cells(&TrainConfig::new("mlp"), &[]).is_err());
        let cells = seed_cells(&TrainConfig::new("mlp"), &[5, 6]).unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].cfg.seed, 5);
        assert_eq!(cells[1].index, 1);
    }
}

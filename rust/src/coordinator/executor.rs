//! Rayon-free parallel grid execution: a `std::thread` work queue with
//! deterministic result ordering, per-worker engine reuse and panic
//! isolation.
//!
//! Design constraints, in order:
//!
//! * **Determinism** — results land by *grid index*, never by completion
//!   order, so a `--workers 8` sweep prints (and aggregates) exactly
//!   what the serial path would.  Each cell's run is itself
//!   deterministic (pinned by `tests/integration.rs`), so parallel and
//!   serial grids are bit-identical.
//! * **Engine reuse** — the PJRT [`Engine`] is single-threaded
//!   (`Rc`/`RefCell` executable cache), so each worker thread builds
//!   one engine lazily and keeps it across all the cells it claims: a
//!   worker compiles each (model, graph) at most once per sweep.
//! * **Panic isolation** — one diverging cell (a shape mismatch, an
//!   assert deep in a kernel) must not kill a week-long grid.  Worker
//!   panics are caught per cell and reported as [`CellOutcome::Failed`];
//!   the worker drops its (possibly inconsistent) engine and re-inits
//!   for the next cell.
//! * **Resumability** — cells found in the [`RunStore`] are served as
//!   [`CellOutcome::Cached`] without occupying a worker; completed
//!   cells are written through so an interrupted grid resumes where it
//!   stopped.
//!
//! The generic core ([`run_indexed`] / [`run_grid_with`]) takes the
//! per-worker context and per-cell runner as closures, so the executor
//! is exercised by tests and the `grid_sweep` bench without compiled
//! artifacts; [`run_grid`] instantiates it with real engines and
//! trainers, and [`run_cells_on`] is the serial shared-engine variant
//! `sweep_row` and the bench tables wrap.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::coordinator::grid::GridCell;
use crate::coordinator::store::{CellKey, RunStore};
use crate::coordinator::sweep::SweepOutcome;
use crate::coordinator::trainer::Trainer;
use crate::metrics::RunRecord;
use crate::runtime::engine::Engine;

/// How a grid executes: worker count, the run store (if any) and
/// whether cached cells may be served from it.
#[derive(Debug)]
pub struct GridOptions {
    /// worker threads (clamped to [1, pending cells])
    pub workers: usize,
    /// resumable run store for cache reads and write-through
    pub store: Option<RunStore>,
    /// serve cells from the store when present (`false` = `--no-cache`:
    /// every cell re-runs; completed cells still write through)
    pub use_cache: bool,
    /// serial path only: after a failed/panicked cell, mark the
    /// remaining cells as skipped instead of running them — the
    /// fail-fast a table row wants (`sweep_row` bails on the first
    /// failure, so training the remaining seeds would be wasted work).
    /// The threaded path ignores this: in-flight workers can't be
    /// cancelled, and a grid wants per-cell isolation anyway.
    pub fail_fast: bool,
}

impl GridOptions {
    /// One worker, no store, fail-fast: the plain in-process sweep.
    pub fn serial() -> Self {
        Self {
            workers: 1,
            store: None,
            use_cache: true,
            fail_fast: true,
        }
    }
}

/// Result of one executed (or cached, or failed) grid cell.
#[derive(Debug)]
pub enum CellOutcome {
    /// the cell was trained this run
    Ran(RunRecord),
    /// the cell was served from the run store
    Cached(RunRecord),
    /// the cell errored or panicked; the rest of the grid is unaffected
    Failed(String),
}

impl CellOutcome {
    pub fn record(&self) -> Option<&RunRecord> {
        match self {
            Self::Ran(r) | Self::Cached(r) => Some(r),
            Self::Failed(_) => None,
        }
    }

    pub fn is_cached(&self) -> bool {
        matches!(self, Self::Cached(_))
    }

    pub fn is_failed(&self) -> bool {
        matches!(self, Self::Failed(_))
    }
}

/// One cell's result, at its grid index.
#[derive(Debug)]
pub struct CellRun {
    pub index: usize,
    pub label: String,
    pub key: CellKey,
    pub outcome: CellOutcome,
}

/// Outcome of one generic job (see [`run_indexed`]).
#[derive(Debug)]
pub enum JobOutcome<R> {
    Done(R),
    Failed(String),
}

/// Run `jobs` on `workers` threads over a shared claim cursor; results
/// land in a vector indexed like `jobs`, regardless of completion
/// order.  `init` builds one context per worker (lazily, so an init
/// failure is reported per claimed job rather than aborting the grid);
/// `run` executes one job against the worker's context.  A panicking
/// job is isolated: it reports as `Failed` and the worker rebuilds its
/// context before the next claim.
pub fn run_indexed<T, R, W, I, F>(jobs: &[T], workers: usize, init: I, run: F) -> Vec<JobOutcome<R>>
where
    T: Sync,
    R: Send,
    I: Fn(usize) -> Result<W> + Sync,
    F: Fn(&mut W, usize, &T) -> Result<R> + Sync,
{
    if jobs.is_empty() {
        return Vec::new();
    }
    let workers = workers.clamp(1, jobs.len());
    // hint the kernel layer: its auto chunked-parallel fan-out divides
    // the hardware budget by our worker count, so worker threads and
    // kernel span threads don't multiply into oversubscription
    let _kernel_hint = crate::quant::kernel::parallel::external_parallelism_guard(workers);
    let cursor = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<JobOutcome<R>>>> =
        jobs.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for w in 0..workers {
            let (cursor, slots, init, run) = (&cursor, &slots, &init, &run);
            scope.spawn(move || {
                let mut ctx: Option<W> = None;
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let outcome = run_one(&mut ctx, w, i, &jobs[i], init, run);
                    *slots[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(outcome);
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .unwrap_or_else(|| {
                    JobOutcome::Failed("job never completed (worker died)".into())
                })
        })
        .collect()
}

fn run_one<T, R, W>(
    ctx: &mut Option<W>,
    worker: usize,
    index: usize,
    job: &T,
    init: &(impl Fn(usize) -> Result<W> + Sync),
    run: &(impl Fn(&mut W, usize, &T) -> Result<R> + Sync),
) -> JobOutcome<R> {
    if ctx.is_none() {
        // init panics (e.g. an unwrap deep in PJRT client construction)
        // must not escape: an uncaught panic in a scoped thread would
        // re-raise at the join and kill the whole grid
        match catch_unwind(AssertUnwindSafe(|| init(worker))) {
            Ok(Ok(c)) => *ctx = Some(c),
            Ok(Err(e)) => return JobOutcome::Failed(format!("worker {worker} init: {e:#}")),
            Err(panic) => {
                return JobOutcome::Failed(format!(
                    "worker {worker} init panicked: {}",
                    panic_message(&panic)
                ))
            }
        }
    }
    let c = ctx.as_mut().expect("context initialized above");
    match catch_unwind(AssertUnwindSafe(|| run(c, index, job))) {
        Ok(Ok(r)) => JobOutcome::Done(r),
        Ok(Err(e)) => JobOutcome::Failed(format!("{e:#}")),
        Err(panic) => {
            // a panicking cell may leave the worker context (engine
            // caches, in-flight state) inconsistent: drop it so the
            // next claimed cell re-inits from scratch
            *ctx = None;
            JobOutcome::Failed(format!("panicked: {}", panic_message(&panic)))
        }
    }
}

pub(crate) fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// The grid pipeline over a pluggable cell runner: serve cached cells
/// from the store, run the pending ones on the worker queue, write
/// completions through, and return every cell's result in grid order.
pub fn run_grid_with<W, I, F>(
    cells: &[GridCell],
    opts: &GridOptions,
    init: I,
    run: F,
) -> Vec<CellRun>
where
    I: Fn(usize) -> Result<W> + Sync,
    F: Fn(&mut W, &GridCell) -> Result<RunRecord> + Sync,
{
    // cache reads are serial and cheap: cached cells never occupy a
    // worker, so `--resume` on a completed grid runs zero trainers
    let mut outcomes: Vec<Option<CellOutcome>> = cells
        .iter()
        .map(|cell| {
            if !opts.use_cache {
                return None;
            }
            let store = opts.store.as_ref()?;
            store
                .get(&CellKey::of(&cell.cfg))
                .map(CellOutcome::Cached)
        })
        .collect();
    let pending: Vec<&GridCell> = cells
        .iter()
        .zip(&outcomes)
        .filter(|(_, o)| o.is_none())
        .map(|(c, _)| c)
        .collect();
    let cached = cells.len() - pending.len();
    if cached > 0 {
        log::info!("grid: {cached} cell(s) served from the run store");
    }
    let results = run_indexed(&pending, opts.workers, init, |w, _i, cell: &&GridCell| run(w, cell));
    let mut results = results.into_iter();
    for (cell, slot) in cells.iter().zip(outcomes.iter_mut()) {
        if slot.is_some() {
            continue;
        }
        let outcome = match results.next().expect("one result per pending cell") {
            JobOutcome::Done(rec) => {
                if let Some(store) = &opts.store {
                    if let Err(e) = store.put(&CellKey::of(&cell.cfg), &rec) {
                        log::warn!("grid cell '{}': store write failed: {e:#}", cell.label);
                    }
                }
                CellOutcome::Ran(rec)
            }
            JobOutcome::Failed(e) => {
                log::warn!("grid cell '{}' failed: {e}", cell.label);
                CellOutcome::Failed(e)
            }
        };
        *slot = Some(outcome);
    }
    cells
        .iter()
        .zip(outcomes)
        .map(|(cell, outcome)| CellRun {
            index: cell.index,
            label: cell.label.clone(),
            key: CellKey::of(&cell.cfg),
            outcome: outcome.expect("every cell resolved"),
        })
        .collect()
}

/// Execute a grid with real engines and trainers: each worker thread
/// builds (and reuses) its own [`Engine`], so an N-worker sweep holds N
/// PJRT clients and compiles each (model, graph) at most N times.
pub fn run_grid(cells: &[GridCell], opts: &GridOptions) -> Vec<CellRun> {
    // the engine constructor defaults XLA_FLAGS via the process
    // environment; do it once before workers race to build clients
    crate::runtime::engine::ensure_default_xla_flags();
    run_grid_with(
        cells,
        opts,
        |worker| {
            log::debug!("grid worker {worker}: building engine");
            Engine::new()
        },
        |engine, cell| {
            log::info!("[grid:{}] running", cell.label);
            Trainer::new(engine, cell.cfg.clone())?.run()
        },
    )
}

/// Serial grid execution over a pluggable cell runner.  Cache reads,
/// store write-through and result ordering match [`run_grid_with`];
/// unlike the threaded path, `opts.fail_fast` is honored: after the
/// first failed or panicked cell the remaining cells are marked
/// skipped instead of executed.
pub fn run_cells_serial_with<F>(
    cells: &[GridCell],
    opts: &GridOptions,
    mut runner: F,
) -> Vec<CellRun>
where
    F: FnMut(&GridCell) -> Result<RunRecord>,
{
    let mut aborted: Option<String> = None;
    let mut out = Vec::with_capacity(cells.len());
    for cell in cells {
        let key = CellKey::of(&cell.cfg);
        let outcome = if let Some(first) = &aborted {
            CellOutcome::Failed(format!("skipped: earlier cell '{first}' failed (fail-fast)"))
        } else {
            let cached = if opts.use_cache {
                opts.store
                    .as_ref()
                    .and_then(|s| s.get(&key))
                    .map(CellOutcome::Cached)
            } else {
                None
            };
            cached.unwrap_or_else(|| {
                log::info!("[grid:{}] running", cell.label);
                match catch_unwind(AssertUnwindSafe(|| runner(cell))) {
                    Ok(Ok(rec)) => {
                        if let Some(store) = &opts.store {
                            if let Err(e) = store.put(&key, &rec) {
                                log::warn!(
                                    "grid cell '{}': store write failed: {e:#}",
                                    cell.label
                                );
                            }
                        }
                        CellOutcome::Ran(rec)
                    }
                    Ok(Err(e)) => {
                        log::warn!("grid cell '{}' failed: {e:#}", cell.label);
                        CellOutcome::Failed(format!("{e:#}"))
                    }
                    Err(p) => {
                        log::warn!("grid cell '{}' panicked", cell.label);
                        CellOutcome::Failed(format!("panicked: {}", panic_message(&p)))
                    }
                }
            })
        };
        if opts.fail_fast && outcome.is_failed() && aborted.is_none() {
            aborted = Some(cell.label.clone());
        }
        out.push(CellRun {
            index: cell.index,
            label: cell.label.clone(),
            key,
            outcome,
        });
    }
    out
}

/// Serial variant sharing one caller-owned engine (the engine is
/// single-threaded, so the in-process path of `sweep_row` and the
/// benches cannot hand it to worker threads).  Cache, store
/// write-through and result ordering match [`run_grid`]; the two
/// deliberate differences are fail-fast (see [`GridOptions::fail_fast`])
/// and panic recovery — a worker thread discards its engine after a
/// panicking cell, while the shared engine here cannot be rebuilt, so
/// with `fail_fast` off later cells reuse it (its executable cache is
/// insert-after-compile, so a caught panic cannot leave a half-built
/// entry behind).
pub fn run_cells_on(engine: &Engine, cells: &[GridCell], opts: &GridOptions) -> Vec<CellRun> {
    run_cells_serial_with(cells, opts, |cell| {
        Trainer::new(engine, cell.cfg.clone())?.run()
    })
}

/// Group a grid's cell results into per-scheme table rows (cells are
/// scheme-major, so grouping is by consecutive runs of the canonical
/// scheme string).  Failed cells are excluded from the aggregate — a
/// row over zero surviving cells reports an empty aggregate rather
/// than poisoning its neighbours.
pub fn grid_rows(runs: &[CellRun]) -> Vec<SweepOutcome> {
    let mut rows = Vec::new();
    let mut i = 0;
    while i < runs.len() {
        let scheme = runs[i].key.scheme.clone();
        let mut recs = Vec::new();
        let mut failed = 0usize;
        while i < runs.len() && runs[i].key.scheme == scheme {
            match &runs[i].outcome {
                CellOutcome::Ran(r) | CellOutcome::Cached(r) => recs.push(r.clone()),
                CellOutcome::Failed(_) => failed += 1,
            }
            i += 1;
        }
        if failed > 0 {
            log::warn!("grid row '{scheme}': {failed} failed cell(s) excluded from the aggregate");
        }
        rows.push(SweepOutcome::from_runs(&scheme, recs));
    }
    rows
}

/// Cell counts of a finished grid, for the CLI summary line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GridSummary {
    pub ran: usize,
    pub cached: usize,
    pub failed: usize,
}

pub fn summarize(runs: &[CellRun]) -> GridSummary {
    let mut s = GridSummary {
        ran: 0,
        cached: 0,
        failed: 0,
    };
    for r in runs {
        match r.outcome {
            CellOutcome::Ran(_) => s.ran += 1,
            CellOutcome::Cached(_) => s.cached += 1,
            CellOutcome::Failed(_) => s.failed += 1,
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::TrainConfig;
    use crate::coordinator::grid::GridSpec;
    use crate::coordinator::store::RunStore;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_land_by_index_not_completion_order() {
        let jobs: Vec<usize> = (0..16).collect();
        let out = run_indexed(
            &jobs,
            4,
            |_| Ok(()),
            |_, i, &job| {
                // later jobs finish first: completion order is roughly
                // reversed, result order must not be
                std::thread::sleep(std::time::Duration::from_millis(((16 - i) % 5) as u64));
                Ok(job * 2)
            },
        );
        assert_eq!(out.len(), 16);
        for (i, o) in out.iter().enumerate() {
            match o {
                JobOutcome::Done(v) => assert_eq!(*v, i * 2, "slot {i}"),
                JobOutcome::Failed(e) => panic!("job {i} failed: {e}"),
            }
        }
    }

    #[test]
    fn a_panicking_job_is_isolated_and_the_worker_reinits() {
        let inits = AtomicUsize::new(0);
        let jobs = [0usize, 1, 2];
        let out = run_indexed(
            &jobs,
            1,
            |_| {
                inits.fetch_add(1, Ordering::SeqCst);
                Ok(())
            },
            |_, _, &job| {
                if job == 1 {
                    panic!("cell diverged");
                }
                Ok(job)
            },
        );
        assert!(matches!(out[0], JobOutcome::Done(0)));
        match &out[1] {
            JobOutcome::Failed(e) => assert!(e.contains("cell diverged"), "{e}"),
            other => panic!("expected failure, got {other:?}"),
        }
        assert!(matches!(out[2], JobOutcome::Done(2)), "grid continued");
        // the single worker re-initialized after the panic
        assert_eq!(inits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn worker_init_failure_fails_jobs_not_the_process() {
        let jobs = [0usize, 1];
        let out = run_indexed(
            &jobs,
            2,
            |w| -> Result<()> { anyhow::bail!("no engine on worker {w}") },
            |_, _, &job| Ok(job),
        );
        for o in &out {
            match o {
                JobOutcome::Failed(e) => assert!(e.contains("init"), "{e}"),
                other => panic!("expected init failure, got {other:?}"),
            }
        }
    }

    // ---- synthetic grid harness (no artifacts needed) -------------------

    fn tmp_store(tag: &str) -> RunStore {
        let dir = std::env::temp_dir().join(format!(
            "hindsight_executor_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        RunStore::open(dir).unwrap()
    }

    /// Deterministic fake training: the record depends only on the
    /// cell's label (as a real run depends only on its config).
    fn synthetic_record(cell: &GridCell) -> RunRecord {
        RunRecord::synthetic(&cell.label, 4)
    }

    fn synthetic_cells() -> Vec<GridCell> {
        GridSpec::new("g:{hindsight,current,running,tqt}:8", &[1, 2])
            .unwrap()
            .expand(&TrainConfig::new("mlp"))
    }

    #[test]
    fn grid_store_round_trip_serves_cached_cells_and_skips_reruns() {
        let cells = synthetic_cells();
        let executions = AtomicUsize::new(0);
        let runner = |_: &mut (), cell: &GridCell| {
            executions.fetch_add(1, Ordering::SeqCst);
            Ok(synthetic_record(cell))
        };
        let opts = GridOptions {
            workers: 2,
            store: Some(tmp_store("cache")),
            use_cache: true,
            fail_fast: false,
        };
        let first = run_grid_with(&cells, &opts, |_| Ok(()), runner);
        assert_eq!(executions.load(Ordering::SeqCst), cells.len());
        assert!(first.iter().all(|r| matches!(r.outcome, CellOutcome::Ran(_))));
        assert_eq!(opts.store.as_ref().unwrap().len(), cells.len());

        // resume: every cell cached, zero runner invocations
        let second = run_grid_with(&cells, &opts, |_| Ok(()), runner);
        assert_eq!(executions.load(Ordering::SeqCst), cells.len());
        assert!(second.iter().all(|r| r.outcome.is_cached()));
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.index, b.index);
            assert_eq!(a.outcome.record(), b.outcome.record(), "{}", a.label);
        }

        // --no-cache forces re-execution despite the store
        let no_cache = GridOptions {
            use_cache: false,
            ..opts
        };
        let third = run_grid_with(&cells, &no_cache, |_| Ok(()), runner);
        assert_eq!(executions.load(Ordering::SeqCst), 2 * cells.len());
        assert!(third.iter().all(|r| matches!(r.outcome, CellOutcome::Ran(_))));

        assert_eq!(
            summarize(&second),
            GridSummary {
                ran: 0,
                cached: cells.len(),
                failed: 0
            }
        );
        let _ = std::fs::remove_dir_all(no_cache.store.unwrap().dir());
    }

    /// Satellite acceptance (engine-free half): a 2-worker grid is
    /// bit-identical — ordering and aggregates — to the serial path,
    /// even when workers finish out of order.
    #[test]
    fn parallel_grid_matches_serial_bit_for_bit() {
        let cells = synthetic_cells();
        let run = |workers: usize| {
            let opts = GridOptions {
                workers,
                store: None,
                use_cache: true,
                fail_fast: false,
            };
            run_grid_with(&cells, &opts, |_| Ok(()), |_: &mut (), cell: &GridCell| {
                // scramble completion order
                std::thread::sleep(std::time::Duration::from_millis(
                    ((cells.len() - cell.index) % 3) as u64,
                ));
                Ok(synthetic_record(cell))
            })
        };
        let serial = run(1);
        let parallel = run(2);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.index, p.index);
            assert_eq!(s.label, p.label);
            assert_eq!(s.outcome.record(), p.outcome.record());
        }
        let rs = grid_rows(&serial);
        let rp = grid_rows(&parallel);
        assert_eq!(rs.len(), 4, "one row per scheme");
        for (a, b) in rs.iter().zip(&rp) {
            assert_eq!(a.label, b.label);
            // bitwise aggregate equality, not tolerance
            let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.agg.accs), bits(&b.agg.accs), "{}", a.label);
            assert_eq!(a.sec_per_step.to_bits(), b.sec_per_step.to_bits());
            assert_eq!(a.agg.cells, b.agg.cells, "provenance matches");
            assert_eq!(a.runs.len(), 2);
        }
    }

    /// Satellite extension of the golden parity test: the *kernel*
    /// work inside grid cells is also backend-invariant.  A 2-worker
    /// grid whose cells run the fused quantization kernels on the
    /// chunked-parallel backend must be bit-identical to a serial
    /// 1-worker run of the same cells on the scalar reference backend
    /// — nested parallelism (worker threads spawning kernel span
    /// threads) included.  The dispatched-global version of this pin
    /// lives in `tests/kernel_conformance.rs`; this one uses the
    /// explicit `_on` entry points so it cannot race other tests.
    #[test]
    fn parallel_backend_grid_matches_serial_scalar_grid_bit_for_bit() {
        use crate::quant::kernel::{self, KernelBackend};

        let tensors: Vec<Vec<f32>> = (0..6)
            .map(|i| {
                let mut rng = crate::util::rng::Pcg32::new(7 + i as u64, 2);
                // long enough that the parallel backend's auto path
                // really fans out inside the worker threads
                let n = 2 * crate::quant::kernel::parallel::PAR_MIN_LEN + 257 * i;
                (0..n).map(|_| rng.normal() * 0.02).collect()
            })
            .collect();
        let run_on = |workers: usize, b: KernelBackend| {
            run_indexed(
                &tensors,
                workers,
                |_| Ok(()),
                move |_, _, xs: &Vec<f32>| {
                    let mut buf = xs.clone();
                    let stats = kernel::minmax_fq_on(b, &mut buf, -0.05, 0.05, 8);
                    let cos = kernel::fq_cosine_on(b, xs, -0.05, 0.05, 8);
                    Ok((buf, stats, cos))
                },
            )
        };
        let serial = run_on(1, KernelBackend::Scalar);
        let parallel = run_on(2, KernelBackend::Parallel);
        for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
            match (s, p) {
                (JobOutcome::Done(a), JobOutcome::Done(b)) => {
                    assert_eq!(a.0, b.0, "cell {i}: quantized tensor");
                    assert_eq!(a.1, b.1, "cell {i}: stats");
                    assert_eq!(a.2.to_bits(), b.2.to_bits(), "cell {i}: objective");
                }
                other => panic!("cell {i}: {other:?}"),
            }
        }
    }

    #[test]
    fn failed_cells_are_excluded_from_rows_but_not_fatal() {
        let cells = synthetic_cells();
        let opts = GridOptions {
            workers: 2,
            store: None,
            use_cache: true,
            fail_fast: false,
        };
        let runs = run_grid_with(&cells, &opts, |_| Ok(()), |_: &mut (), cell: &GridCell| {
            if cell.index == 1 {
                anyhow::bail!("diverged");
            }
            if cell.index == 2 {
                panic!("kernel assert");
            }
            Ok(synthetic_record(cell))
        });
        let s = summarize(&runs);
        assert_eq!(s.failed, 2);
        assert_eq!(s.ran, cells.len() - 2);
        let rows = grid_rows(&runs);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].runs.len(), 1, "seed 2 of row 0 failed");
        assert_eq!(rows[1].runs.len(), 1, "seed 1 of row 1 panicked");
        assert_eq!(rows[2].runs.len(), 2);
    }

    /// Regression (review finding): `sweep_row` relies on the serial
    /// path to stop after the first failure — without fail-fast it
    /// would train every remaining seed and then throw the work away.
    #[test]
    fn serial_fail_fast_skips_cells_after_the_first_failure() {
        let cells = synthetic_cells();
        let mut executed = 0usize;
        let opts = GridOptions::serial(); // fail_fast: true
        let runs = run_cells_serial_with(&cells, &opts, |cell| {
            executed += 1;
            if cell.index == 2 {
                anyhow::bail!("diverged");
            }
            Ok(synthetic_record(cell))
        });
        assert_eq!(executed, 3, "cells after the failure must not run");
        assert!(matches!(runs[0].outcome, CellOutcome::Ran(_)));
        assert!(matches!(runs[1].outcome, CellOutcome::Ran(_)));
        match &runs[2].outcome {
            CellOutcome::Failed(e) => assert!(e.contains("diverged"), "{e}"),
            other => panic!("expected failure, got {other:?}"),
        }
        for r in &runs[3..] {
            match &r.outcome {
                CellOutcome::Failed(e) => {
                    assert!(e.contains("skipped"), "{e}");
                    assert!(e.contains(&cells[2].label), "names the first failure: {e}");
                }
                other => panic!("expected skip, got {other:?}"),
            }
        }
        // with fail_fast off the same runner executes every cell
        let mut executed = 0usize;
        let opts = GridOptions {
            fail_fast: false,
            ..GridOptions::serial()
        };
        let runs = run_cells_serial_with(&cells, &opts, |cell| {
            executed += 1;
            if cell.index == 2 {
                anyhow::bail!("diverged");
            }
            Ok(synthetic_record(cell))
        });
        assert_eq!(executed, cells.len());
        assert_eq!(summarize(&runs).failed, 1);
    }

    /// Engine-gated golden test: with compiled artifacts, a real
    /// 2-worker grid must be bit-identical to the serial shared-engine
    /// path (aggregates and ordering) and a resumed grid must execute
    /// zero trainer runs.
    #[test]
    fn engine_grid_parallel_serial_and_resume_parity() {
        use crate::runtime::manifest::Manifest;
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut base = TrainConfig::new("mlp");
        base.steps = 6;
        base.n_train = 64;
        base.n_val = 32;
        base.calib_batches = 1;
        let cells = GridSpec::new("g:{hindsight,current}:8", &[1, 2])
            .unwrap()
            .expand(&base);

        let engine = Engine::new().unwrap();
        let serial = run_cells_on(&engine, &cells, &GridOptions::serial());
        let store = tmp_store("engine");
        let opts = GridOptions {
            workers: 2,
            store: Some(store),
            use_cache: true,
            fail_fast: false,
        };
        let parallel = run_grid(&cells, &opts);
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.index, p.index);
            let (a, b) = (s.outcome.record().unwrap(), p.outcome.record().unwrap());
            assert_eq!(a.losses, b.losses, "{}", s.label);
            assert_eq!(a.evals, b.evals, "{}", s.label);
        }
        // resume: all four cells come from the store
        let resumed = run_grid(&cells, &opts);
        let s = summarize(&resumed);
        assert_eq!(s.cached, cells.len());
        assert_eq!(s.ran, 0);
        let _ = std::fs::remove_dir_all(opts.store.unwrap().dir());
    }
}

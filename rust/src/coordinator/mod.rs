//! Training coordination — the paper's contribution as runtime logic.
//!
//! * [`ranges`] — the range-estimation state machine: per-quantizer range
//!   state and the graph-ABI scalar encoding; estimator semantics are
//!   delegated to per-site `crate::estimator` trait objects.
//! * [`config`] — training configuration (mirrors the paper's Sec. 5
//!   experimental setup); the quantization policy is a typed
//!   [`QuantScheme`] (per-tensor-class specs + per-site overrides).
//! * [`trainer`] — the step loop: batch marshalling, the compiled train /
//!   eval / dump graphs, calibration, LR schedules, metrics.
//! * [`sweep`] — multi-seed table rows (mean ± std over seeds); a thin
//!   wrapper over the executor's serial path.
//! * [`grid`] — scheme-grid sweeps: brace-expansion templates
//!   (`g:{hindsight,current}@{pt,pc}:{4,8}`) deterministically expanded
//!   into ordered, uniquely-labeled cells.
//! * [`executor`] — the rayon-free `std::thread` work-queue executor:
//!   per-worker engine reuse, panic isolation, results landing by grid
//!   index (bit-identical to the serial path at any worker count).
//! * [`store`] — the resumable run store: completed cells persist as
//!   JSON keyed by `(model, canonical scheme, seed, steps)` so
//!   re-running a grid skips cached cells.

pub mod config;
pub mod executor;
pub mod grid;
pub mod ranges;
pub mod store;
pub mod sweep;
pub mod trainer;

pub use config::{Estimator, QuantScheme, QuantSpec, Schedule, TensorClass, TrainConfig};
pub use executor::{grid_rows, run_cells_on, run_grid, CellOutcome, CellRun, GridOptions};
pub use grid::{format_seeds, parse_seeds, GridCell, GridSpec};
pub use ranges::RangeManager;
pub use store::{CellKey, RunStore};
pub use sweep::{sweep_row, SweepOutcome};
pub use trainer::{validate_scheme_sites, Trainer};

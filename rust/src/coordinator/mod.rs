//! Training coordination — the paper's contribution as runtime logic.
//!
//! * [`ranges`] — the range-estimation state machine: per-quantizer range
//!   state and the graph-ABI scalar encoding; estimator semantics are
//!   delegated to per-site `crate::estimator` trait objects.
//! * [`config`] — training configuration (mirrors the paper's Sec. 5
//!   experimental setup); the quantization policy is a typed
//!   [`QuantScheme`] (per-tensor-class specs + per-site overrides).
//! * [`trainer`] — the step loop: batch marshalling, the compiled train /
//!   eval / dump graphs, calibration, LR schedules, metrics.
//! * [`sweep`] — multi-seed, multi-estimator sweeps producing the paper's
//!   table rows (mean ± std over seeds).

pub mod config;
pub mod ranges;
pub mod sweep;
pub mod trainer;

pub use config::{Estimator, QuantScheme, QuantSpec, Schedule, TensorClass, TrainConfig};
pub use ranges::RangeManager;
pub use sweep::{sweep_row, SweepOutcome};
pub use trainer::Trainer;

//! The string-keyed estimator registry and the [`Estimator`] handle.
//!
//! Every estimator the system knows is one [`EstimatorInfo`] row: its
//! registry key, display name, the graph-ABI metadata (mode scalar,
//! enable bit, static/dynamic classification), the coordinator hooks it
//! needs (periodic search, calibration statefulness, first-step
//! bootstrap mode) and a factory for its per-site trait object.
//! `config`, `sweep`, the CLI and the benches resolve estimators by name
//! through [`Estimator::parse`] / [`Estimator::all`] — nothing outside
//! this file enumerates estimators.
//!
//! [`Estimator`] itself is a `Copy` handle (a reference into the
//! registry) so `TrainConfig` stays cheap to clone and call sites read
//! like the old enum: `Estimator::HINDSIGHT`, `est == Estimator::DSGC`.

use anyhow::{bail, Result};

use super::classic::{Current, Dsgc, Fp32, Hindsight, Running};
use super::literature::{Banner, MaxHistory, SampledMinMax};
use super::perchannel::PerChannel;
use super::trained::TrainedThreshold;
use super::{RangeEstimator, SiteParams};

/// Quantizer granularity of a configured estimator: one range row per
/// site (per-tensor, the paper's setting) or one per channel group.
/// Selected with the registry key suffix `@pc` (`hindsight@pc`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    PerTensor,
    PerChannel,
}

/// One registry row: estimator metadata + per-site factory.
pub struct EstimatorInfo {
    /// stable string id used by the CLI, configs and sweeps
    pub key: &'static str,
    /// display name (the paper's table row labels)
    pub display: &'static str,
    /// graph `mode` scalar (see `python/compile/quant_ops.py`):
    /// 0 = current, 1 = running, 2 = static.  Estimators whose range
    /// state lives coordinator-side run the graph in static mode.
    pub mode: f32,
    /// whether this estimator quantizes its tensor class at all
    pub enabled: bool,
    /// step-path quantization is static (paper Table 1 "Static" column)
    pub is_static: bool,
    /// requires the periodic dump-graph search pass
    pub needs_search: bool,
    /// benefits from the initial calibration pass (paper Sec. 5.2) /
    /// carries range state across steps
    pub stateful: bool,
    /// run an uncalibrated first step in current-min-max mode so the
    /// first grid is the first batch's statistics (paper Sec. 4.1)
    pub bootstrap_dynamic: bool,
    /// per-site trait-object factory; receives the site's resolved
    /// [`SiteParams`] (bits/eta) so adaptive estimators can consume them
    pub make: fn(SiteParams) -> Box<dyn RangeEstimator>,
}

fn make_fp32(_p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(Fp32)
}
fn make_current(_p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(Current)
}
fn make_running(_p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(Running)
}
fn make_hindsight(_p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(Hindsight)
}
fn make_dsgc(_p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(Dsgc)
}
fn make_maxhist(_p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(MaxHistory::default())
}
fn make_sampled(_p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(SampledMinMax::default())
}
fn make_tqt(p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(TrainedThreshold::from_params(p))
}
fn make_banner(p: SiteParams) -> Box<dyn RangeEstimator> {
    Box::new(Banner::new(p.eta))
}

const FP32_INFO: EstimatorInfo = EstimatorInfo {
    key: "fp32",
    display: "FP32",
    mode: 2.0, // enable is off; static keeps the dead branch cheapest
    enabled: false,
    is_static: true,
    needs_search: false,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_fp32,
};

const CURRENT_INFO: EstimatorInfo = EstimatorInfo {
    key: "current",
    display: "Current min-max",
    mode: 0.0,
    enabled: true,
    is_static: false,
    needs_search: false,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_current,
};

const RUNNING_INFO: EstimatorInfo = EstimatorInfo {
    key: "running",
    display: "Running min-max",
    mode: 1.0,
    enabled: true,
    is_static: false,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_running,
};

const HINDSIGHT_INFO: EstimatorInfo = EstimatorInfo {
    key: "hindsight",
    display: "In-hindsight min-max",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_hindsight,
};

const DSGC_INFO: EstimatorInfo = EstimatorInfo {
    key: "dsgc",
    display: "DSGC",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: true,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_dsgc,
};

const MAX_HISTORY_INFO: EstimatorInfo = EstimatorInfo {
    key: "maxhist",
    display: "Max-history min-max",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_maxhist,
};

const SAMPLED_INFO: EstimatorInfo = EstimatorInfo {
    key: "sampled",
    display: "Sampled min-max",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: true,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_sampled,
};

const TQT_INFO: EstimatorInfo = EstimatorInfo {
    key: "tqt",
    display: "Trained threshold (TQT)",
    mode: 2.0, // coordinator-side state: the graph runs static
    enabled: true,
    is_static: true,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_tqt,
};

const BANNER_INFO: EstimatorInfo = EstimatorInfo {
    key: "banner",
    display: "Layer-wise max (Banner et al.)",
    mode: 2.0, // coordinator-side EMA state: the graph runs static
    enabled: true,
    is_static: true,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_banner,
};

/// Every registered estimator, in presentation order (the paper's five,
/// then the literature additions).
pub static REGISTRY: &[&EstimatorInfo] = &[
    &FP32_INFO,
    &CURRENT_INFO,
    &RUNNING_INFO,
    &HINDSIGHT_INFO,
    &DSGC_INFO,
    &MAX_HISTORY_INFO,
    &SAMPLED_INFO,
    &TQT_INFO,
    &BANNER_INFO,
];

/// Cheap `Copy` handle to one registry row plus a granularity tag.
#[derive(Clone, Copy)]
pub struct Estimator {
    info: &'static EstimatorInfo,
    gran: Granularity,
}

const fn per_tensor(info: &'static EstimatorInfo) -> Estimator {
    Estimator { info, gran: Granularity::PerTensor }
}

impl Estimator {
    pub const FP32: Self = per_tensor(&FP32_INFO);
    pub const CURRENT: Self = per_tensor(&CURRENT_INFO);
    pub const RUNNING: Self = per_tensor(&RUNNING_INFO);
    pub const HINDSIGHT: Self = per_tensor(&HINDSIGHT_INFO);
    pub const DSGC: Self = per_tensor(&DSGC_INFO);
    pub const MAX_HISTORY: Self = per_tensor(&MAX_HISTORY_INFO);
    pub const SAMPLED_MINMAX: Self = per_tensor(&SAMPLED_INFO);
    pub const TQT: Self = per_tensor(&TQT_INFO);
    pub const BANNER: Self = per_tensor(&BANNER_INFO);

    /// Resolve a registry key (the CLI / config string form), with an
    /// optional granularity suffix: `hindsight` is per-tensor,
    /// `hindsight@pc` per-channel.  `@pt` is accepted as the explicit
    /// per-tensor spelling (it canonicalizes back to the bare key) so
    /// grid templates can alternate over granularity (`@{pt,pc}`).
    pub fn parse(s: &str) -> Result<Self> {
        let (base, gran) = match s.split_once('@') {
            None => (s, Granularity::PerTensor),
            Some((b, "pc")) => (b, Granularity::PerChannel),
            Some((b, "pt")) => (b, Granularity::PerTensor),
            Some((_, suffix)) => {
                bail!(
                    "unknown granularity suffix '@{suffix}' (use '@pc' for per-channel, \
                     '@pt' for explicit per-tensor)"
                )
            }
        };
        for info in REGISTRY {
            if info.key == base {
                return Ok(Self { info, gran });
            }
        }
        bail!(
            "unknown estimator '{base}' — valid keys: {}; append '@pc' for per-channel \
             granularity; scheme clauses take a ':<bits>' suffix (e.g. 'hindsight@pc:4')",
            Self::keys().join("|")
        )
    }

    /// Iterate every registered estimator, in registry order
    /// (per-tensor granularity; use [`Estimator::per_channel`] to flip).
    pub fn all() -> impl Iterator<Item = Estimator> {
        REGISTRY.iter().copied().map(per_tensor)
    }

    /// Every registry key, in registry order.
    pub fn keys() -> Vec<&'static str> {
        REGISTRY.iter().map(|i| i.key).collect()
    }

    /// The stable base string id (`"hindsight"`, ...), without the
    /// granularity suffix; [`Estimator::spec`] gives the full form.
    pub fn key(&self) -> &'static str {
        self.info.key
    }

    /// Display name (the paper's table row labels).
    pub fn name(&self) -> &'static str {
        self.info.display
    }

    /// Range granularity of this configured estimator.
    pub fn granularity(&self) -> Granularity {
        self.gran
    }

    pub fn is_per_channel(&self) -> bool {
        self.gran == Granularity::PerChannel
    }

    /// The same estimator at per-channel granularity.
    pub fn per_channel(&self) -> Self {
        Self { info: self.info, gran: Granularity::PerChannel }
    }

    /// The granularity suffix of the parseable key form (`""` or `"@pc"`).
    pub fn suffix(&self) -> &'static str {
        match self.gran {
            Granularity::PerTensor => "",
            Granularity::PerChannel => "@pc",
        }
    }

    /// Full parseable key (`"hindsight"` / `"hindsight@pc"`): round-trips
    /// through [`Estimator::parse`].
    pub fn spec(&self) -> String {
        format!("{}{}", self.key(), self.suffix())
    }

    /// Graph `mode` scalar (see `python/compile/quant_ops.py`).
    pub fn mode(&self) -> f32 {
        self.info.mode
    }

    /// Whether this estimator quantizes its tensor class at all.
    pub fn enabled(&self) -> bool {
        self.info.enabled
    }

    /// Is the step-path quantization static (paper Table 1 "Static")?
    pub fn is_static(&self) -> bool {
        self.info.is_static
    }

    /// Requires the periodic dump-graph search pass (DSGC-style).
    pub fn needs_search(&self) -> bool {
        self.info.needs_search
    }

    /// Benefits from the initial calibration pass (paper Sec. 5.2).
    pub fn stateful(&self) -> bool {
        self.info.stateful
    }

    /// Run an uncalibrated first step in current-min-max mode.
    pub fn bootstrap_dynamic(&self) -> bool {
        self.info.bootstrap_dynamic
    }

    /// Build a single-row (per-tensor) trait object with the default
    /// [`SiteParams`] (8 bits, eta 0.9).
    pub fn instantiate(&self) -> Box<dyn RangeEstimator> {
        self.instantiate_with(SiteParams::default())
    }

    /// Build a single-row (per-tensor) trait object with explicit
    /// per-site params.
    pub fn instantiate_with(&self, params: SiteParams) -> Box<dyn RangeEstimator> {
        (self.info.make)(params)
    }

    /// [`Estimator::instantiate_site_with`] with the default params.
    pub fn instantiate_site(&self, n_channels: usize) -> Box<dyn RangeEstimator> {
        self.instantiate_site_with(SiteParams::default(), n_channels)
    }

    /// Build the trait object for a site with `n_channels` channel
    /// groups, honoring this handle's granularity: per-tensor handles
    /// ignore `n_channels`; per-channel handles wrap the estimator in
    /// the channel-replicating [`PerChannel`] adapter (one row per
    /// channel — bit-identical to per-tensor when `n_channels == 1`).
    /// The site's resolved `params` reach every replica's factory.
    pub fn instantiate_site_with(
        &self,
        params: SiteParams,
        n_channels: usize,
    ) -> Box<dyn RangeEstimator> {
        match self.gran {
            Granularity::PerTensor => (self.info.make)(params),
            Granularity::PerChannel => {
                let make = self.info.make;
                Box::new(PerChannel::replicate(move || make(params), n_channels.max(1)))
            }
        }
    }
}

// identity is the registry key + granularity: const-promotion may
// duplicate the underlying &'static EstimatorInfo, so pointer equality
// is not reliable
impl PartialEq for Estimator {
    fn eq(&self, other: &Self) -> bool {
        self.info.key == other.info.key && self.gran == other.gran
    }
}

impl Eq for Estimator {}

impl std::fmt::Debug for Estimator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Estimator({})", self.spec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn unknown_name_errors_and_lists_keys() {
        let err = Estimator::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown estimator 'bogus'"), "{err}");
        for key in Estimator::keys() {
            assert!(err.contains(key), "error must list '{key}': {err}");
        }
    }

    #[test]
    fn every_registered_name_round_trips() {
        for est in Estimator::all() {
            let parsed = Estimator::parse(est.key()).unwrap();
            assert_eq!(parsed, est);
            assert_eq!(parsed.name(), est.name());
            // the factory's instance agrees with the registry row
            let inst = est.instantiate();
            assert_eq!(inst.name(), est.key());
            assert_eq!(inst.needs_search(), est.needs_search());
        }
    }

    #[test]
    fn keys_and_display_names_are_unique() {
        let keys: BTreeSet<_> = Estimator::keys().into_iter().collect();
        assert_eq!(keys.len(), REGISTRY.len());
        let names: BTreeSet<_> = Estimator::all().map(|e| e.name()).collect();
        assert_eq!(names.len(), REGISTRY.len());
    }

    #[test]
    fn legacy_metadata_is_pinned() {
        // the graph ABI the seed shipped with must not drift
        assert_eq!(Estimator::CURRENT.mode(), 0.0);
        assert_eq!(Estimator::RUNNING.mode(), 1.0);
        assert_eq!(Estimator::HINDSIGHT.mode(), 2.0);
        assert_eq!(Estimator::DSGC.mode(), 2.0);
        assert!(!Estimator::FP32.enabled());
        assert!(Estimator::HINDSIGHT.is_static());
        assert!(Estimator::DSGC.is_static());
        assert!(!Estimator::CURRENT.is_static());
        assert!(!Estimator::RUNNING.is_static());
        assert!(Estimator::DSGC.needs_search());
        assert_eq!(Estimator::HINDSIGHT.name(), "In-hindsight min-max");
        assert_eq!(Estimator::DSGC.name(), "DSGC");
    }

    #[test]
    fn new_estimators_are_static_plugins() {
        for est in [
            Estimator::MAX_HISTORY,
            Estimator::SAMPLED_MINMAX,
            Estimator::TQT,
            Estimator::BANNER,
        ] {
            assert!(est.enabled());
            assert!(est.is_static());
            assert_eq!(est.mode(), 2.0);
        }
        assert!(Estimator::SAMPLED_MINMAX.needs_search());
        assert!(!Estimator::MAX_HISTORY.needs_search());
        assert!(Estimator::MAX_HISTORY.stateful());
        // tqt: search-free stateful plugin (ROADMAP "Next" item)
        assert!(!Estimator::TQT.needs_search());
        assert!(Estimator::TQT.stateful());
        assert!(Estimator::TQT.bootstrap_dynamic());
        assert_eq!(Estimator::parse("tqt").unwrap(), Estimator::TQT);
        // banner: search-free stateful EMA-absmax/pow2 plugin
        assert!(!Estimator::BANNER.needs_search());
        assert!(Estimator::BANNER.stateful());
        assert!(Estimator::BANNER.bootstrap_dynamic());
        assert_eq!(Estimator::parse("banner").unwrap(), Estimator::BANNER);
        assert_eq!(Estimator::BANNER.name(), "Layer-wise max (Banner et al.)");
    }

    #[test]
    fn site_params_reach_the_factories() {
        // tqt derives its threshold step from the site's eta
        let mut slow = Estimator::TQT.instantiate_with(SiteParams { bits: 8, eta: 0.99 });
        let mut fast = Estimator::TQT.instantiate_with(SiteParams { bits: 8, eta: 0.5 });
        let ctx = super::super::StepCtx {
            current: [-1.0, 1.0],
            stats: [-2.0, 2.0],
            new_ranges: [0.0, 0.0],
            first_step: false,
            calibrated: true,
        };
        let s = slow.absorb_step(ctx);
        let f = fast.absorb_step(ctx);
        assert!(f[1] > s[1], "faster eta-derived step must move further: {f:?} vs {s:?}");
        // per-channel replication carries the params to every replica
        let pc = Estimator::TQT
            .per_channel()
            .instantiate_site_with(SiteParams { bits: 8, eta: 0.5 }, 3);
        assert_eq!(pc.n_rows(), 3);
    }

    #[test]
    fn equality_is_by_key_not_address() {
        assert_eq!(Estimator::HINDSIGHT, Estimator::parse("hindsight").unwrap());
        assert_ne!(Estimator::HINDSIGHT, Estimator::RUNNING);
    }

    #[test]
    fn granularity_suffix_parses_and_round_trips() {
        for est in Estimator::all() {
            let pc = Estimator::parse(&format!("{}@pc", est.key())).unwrap();
            assert!(pc.is_per_channel());
            assert_eq!(pc, est.per_channel());
            assert_ne!(pc, est, "granularity is part of identity");
            // base metadata is granularity-independent
            assert_eq!(pc.mode(), est.mode());
            assert_eq!(pc.needs_search(), est.needs_search());
            assert_eq!(pc.key(), est.key());
            // spec round-trips through parse
            assert_eq!(Estimator::parse(&pc.spec()).unwrap(), pc);
            assert_eq!(Estimator::parse(&est.spec()).unwrap(), est);
        }
        let err = Estimator::parse("hindsight@bogus").unwrap_err().to_string();
        assert!(err.contains("granularity suffix"), "{err}");
        assert!(Estimator::parse("nope@pc").is_err());
        // '@pt' is the explicit per-tensor spelling (grid granularity
        // axes); it canonicalizes back to the bare key
        let pt = Estimator::parse("hindsight@pt").unwrap();
        assert_eq!(pt, Estimator::HINDSIGHT);
        assert_eq!(pt.spec(), "hindsight");
    }

    #[test]
    fn per_channel_sites_replicate_one_row_per_channel() {
        let pc = Estimator::parse("hindsight@pc").unwrap();
        assert_eq!(pc.instantiate_site(4).n_rows(), 4);
        assert_eq!(pc.instantiate_site(1).n_rows(), 1);
        assert_eq!(pc.instantiate_site(0).n_rows(), 1); // guarded
        // per-tensor handles ignore the channel count
        assert_eq!(Estimator::HINDSIGHT.instantiate_site(4).n_rows(), 1);
    }
}

//! The string-keyed estimator registry and the [`Estimator`] handle.
//!
//! Every estimator the system knows is one [`EstimatorInfo`] row: its
//! registry key, display name, the graph-ABI metadata (mode scalar,
//! enable bit, static/dynamic classification), the coordinator hooks it
//! needs (periodic search, calibration statefulness, first-step
//! bootstrap mode) and a factory for its per-site trait object.
//! `config`, `sweep`, the CLI and the benches resolve estimators by name
//! through [`Estimator::parse`] / [`Estimator::all`] — nothing outside
//! this file enumerates estimators.
//!
//! [`Estimator`] itself is a `Copy` handle (a reference into the
//! registry) so `TrainConfig` stays cheap to clone and call sites read
//! like the old enum: `Estimator::HINDSIGHT`, `est == Estimator::DSGC`.

use anyhow::{bail, Result};

use super::classic::{Current, Dsgc, Fp32, Hindsight, Running};
use super::literature::{MaxHistory, SampledMinMax};
use super::RangeEstimator;

/// One registry row: estimator metadata + per-site factory.
pub struct EstimatorInfo {
    /// stable string id used by the CLI, configs and sweeps
    pub key: &'static str,
    /// display name (the paper's table row labels)
    pub display: &'static str,
    /// graph `mode` scalar (see `python/compile/quant_ops.py`):
    /// 0 = current, 1 = running, 2 = static.  Estimators whose range
    /// state lives coordinator-side run the graph in static mode.
    pub mode: f32,
    /// whether this estimator quantizes its tensor class at all
    pub enabled: bool,
    /// step-path quantization is static (paper Table 1 "Static" column)
    pub is_static: bool,
    /// requires the periodic dump-graph search pass
    pub needs_search: bool,
    /// benefits from the initial calibration pass (paper Sec. 5.2) /
    /// carries range state across steps
    pub stateful: bool,
    /// run an uncalibrated first step in current-min-max mode so the
    /// first grid is the first batch's statistics (paper Sec. 4.1)
    pub bootstrap_dynamic: bool,
    /// per-site trait-object factory
    pub make: fn() -> Box<dyn RangeEstimator>,
}

fn make_fp32() -> Box<dyn RangeEstimator> {
    Box::new(Fp32)
}
fn make_current() -> Box<dyn RangeEstimator> {
    Box::new(Current)
}
fn make_running() -> Box<dyn RangeEstimator> {
    Box::new(Running)
}
fn make_hindsight() -> Box<dyn RangeEstimator> {
    Box::new(Hindsight)
}
fn make_dsgc() -> Box<dyn RangeEstimator> {
    Box::new(Dsgc)
}
fn make_maxhist() -> Box<dyn RangeEstimator> {
    Box::new(MaxHistory::default())
}
fn make_sampled() -> Box<dyn RangeEstimator> {
    Box::new(SampledMinMax::default())
}

const FP32_INFO: EstimatorInfo = EstimatorInfo {
    key: "fp32",
    display: "FP32",
    mode: 2.0, // enable is off; static keeps the dead branch cheapest
    enabled: false,
    is_static: true,
    needs_search: false,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_fp32,
};

const CURRENT_INFO: EstimatorInfo = EstimatorInfo {
    key: "current",
    display: "Current min-max",
    mode: 0.0,
    enabled: true,
    is_static: false,
    needs_search: false,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_current,
};

const RUNNING_INFO: EstimatorInfo = EstimatorInfo {
    key: "running",
    display: "Running min-max",
    mode: 1.0,
    enabled: true,
    is_static: false,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_running,
};

const HINDSIGHT_INFO: EstimatorInfo = EstimatorInfo {
    key: "hindsight",
    display: "In-hindsight min-max",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_hindsight,
};

const DSGC_INFO: EstimatorInfo = EstimatorInfo {
    key: "dsgc",
    display: "DSGC",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: true,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_dsgc,
};

const MAX_HISTORY_INFO: EstimatorInfo = EstimatorInfo {
    key: "maxhist",
    display: "Max-history min-max",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: false,
    stateful: true,
    bootstrap_dynamic: true,
    make: make_maxhist,
};

const SAMPLED_INFO: EstimatorInfo = EstimatorInfo {
    key: "sampled",
    display: "Sampled min-max",
    mode: 2.0,
    enabled: true,
    is_static: true,
    needs_search: true,
    stateful: false,
    bootstrap_dynamic: false,
    make: make_sampled,
};

/// Every registered estimator, in presentation order (the paper's five,
/// then the literature additions).
pub static REGISTRY: &[&EstimatorInfo] = &[
    &FP32_INFO,
    &CURRENT_INFO,
    &RUNNING_INFO,
    &HINDSIGHT_INFO,
    &DSGC_INFO,
    &MAX_HISTORY_INFO,
    &SAMPLED_INFO,
];

/// Cheap `Copy` handle to one registry row.
#[derive(Clone, Copy)]
pub struct Estimator(&'static EstimatorInfo);

impl Estimator {
    pub const FP32: Self = Self(&FP32_INFO);
    pub const CURRENT: Self = Self(&CURRENT_INFO);
    pub const RUNNING: Self = Self(&RUNNING_INFO);
    pub const HINDSIGHT: Self = Self(&HINDSIGHT_INFO);
    pub const DSGC: Self = Self(&DSGC_INFO);
    pub const MAX_HISTORY: Self = Self(&MAX_HISTORY_INFO);
    pub const SAMPLED_MINMAX: Self = Self(&SAMPLED_INFO);

    /// Resolve a registry key (the CLI / config string form).
    pub fn parse(s: &str) -> Result<Self> {
        for info in REGISTRY {
            if info.key == s {
                return Ok(Self(info));
            }
        }
        bail!("unknown estimator '{s}' ({})", Self::keys().join("|"))
    }

    /// Iterate every registered estimator, in registry order.
    pub fn all() -> impl Iterator<Item = Estimator> {
        REGISTRY.iter().copied().map(Estimator)
    }

    /// Every registry key, in registry order.
    pub fn keys() -> Vec<&'static str> {
        REGISTRY.iter().map(|i| i.key).collect()
    }

    /// The stable string id (`"hindsight"`, ...).
    pub fn key(&self) -> &'static str {
        self.0.key
    }

    /// Display name (the paper's table row labels).
    pub fn name(&self) -> &'static str {
        self.0.display
    }

    /// Graph `mode` scalar (see `python/compile/quant_ops.py`).
    pub fn mode(&self) -> f32 {
        self.0.mode
    }

    /// Whether this estimator quantizes its tensor class at all.
    pub fn enabled(&self) -> bool {
        self.0.enabled
    }

    /// Is the step-path quantization static (paper Table 1 "Static")?
    pub fn is_static(&self) -> bool {
        self.0.is_static
    }

    /// Requires the periodic dump-graph search pass (DSGC-style).
    pub fn needs_search(&self) -> bool {
        self.0.needs_search
    }

    /// Benefits from the initial calibration pass (paper Sec. 5.2).
    pub fn stateful(&self) -> bool {
        self.0.stateful
    }

    /// Run an uncalibrated first step in current-min-max mode.
    pub fn bootstrap_dynamic(&self) -> bool {
        self.0.bootstrap_dynamic
    }

    /// Build the per-site trait object.
    pub fn instantiate(&self) -> Box<dyn RangeEstimator> {
        (self.0.make)()
    }
}

// identity is the registry key: const-promotion may duplicate the
// underlying &'static EstimatorInfo, so pointer equality is not reliable
impl PartialEq for Estimator {
    fn eq(&self, other: &Self) -> bool {
        self.0.key == other.0.key
    }
}

impl Eq for Estimator {}

impl std::fmt::Debug for Estimator {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Estimator({})", self.0.key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeSet;

    #[test]
    fn unknown_name_errors_and_lists_keys() {
        let err = Estimator::parse("bogus").unwrap_err().to_string();
        assert!(err.contains("unknown estimator 'bogus'"), "{err}");
        for key in Estimator::keys() {
            assert!(err.contains(key), "error must list '{key}': {err}");
        }
    }

    #[test]
    fn every_registered_name_round_trips() {
        for est in Estimator::all() {
            let parsed = Estimator::parse(est.key()).unwrap();
            assert_eq!(parsed, est);
            assert_eq!(parsed.name(), est.name());
            // the factory's instance agrees with the registry row
            let inst = est.instantiate();
            assert_eq!(inst.name(), est.key());
            assert_eq!(inst.needs_search(), est.needs_search());
        }
    }

    #[test]
    fn keys_and_display_names_are_unique() {
        let keys: BTreeSet<_> = Estimator::keys().into_iter().collect();
        assert_eq!(keys.len(), REGISTRY.len());
        let names: BTreeSet<_> = Estimator::all().map(|e| e.name()).collect();
        assert_eq!(names.len(), REGISTRY.len());
    }

    #[test]
    fn legacy_metadata_is_pinned() {
        // the graph ABI the seed shipped with must not drift
        assert_eq!(Estimator::CURRENT.mode(), 0.0);
        assert_eq!(Estimator::RUNNING.mode(), 1.0);
        assert_eq!(Estimator::HINDSIGHT.mode(), 2.0);
        assert_eq!(Estimator::DSGC.mode(), 2.0);
        assert!(!Estimator::FP32.enabled());
        assert!(Estimator::HINDSIGHT.is_static());
        assert!(Estimator::DSGC.is_static());
        assert!(!Estimator::CURRENT.is_static());
        assert!(!Estimator::RUNNING.is_static());
        assert!(Estimator::DSGC.needs_search());
        assert_eq!(Estimator::HINDSIGHT.name(), "In-hindsight min-max");
        assert_eq!(Estimator::DSGC.name(), "DSGC");
    }

    #[test]
    fn new_estimators_are_static_plugins() {
        for est in [Estimator::MAX_HISTORY, Estimator::SAMPLED_MINMAX] {
            assert!(est.enabled());
            assert!(est.is_static());
            assert_eq!(est.mode(), 2.0);
        }
        assert!(Estimator::SAMPLED_MINMAX.needs_search());
        assert!(!Estimator::MAX_HISTORY.needs_search());
        assert!(Estimator::MAX_HISTORY.stateful());
    }

    #[test]
    fn equality_is_by_key_not_address() {
        assert_eq!(Estimator::HINDSIGHT, Estimator::parse("hindsight").unwrap());
        assert_ne!(Estimator::HINDSIGHT, Estimator::RUNNING);
    }
}

//! Trained-threshold range estimation in the spirit of TQT (Jain et
//! al., "Trained Quantization Thresholds", 1903.08066).
//!
//! TQT learns clipping thresholds by gradient descent on the task loss.
//! The coordinator never sees the loss gradient w.r.t. a threshold, but
//! the *sign* of that gradient is well approximated by a clipping proxy:
//! when the observed statistics exceed the threshold, values are being
//! clipped and the threshold gradient pushes the threshold up; when the
//! statistics fall inside it, grid resolution is being wasted and the
//! gradient pushes it down.  [`TrainedThreshold`] realizes exactly that
//! sign rule, with the multiplicative (log2-domain) update TQT uses:
//!
//! ```text
//!   m_side <- m_side * 2^( step * sgn(|stats_side| - m_side) )
//! ```
//!
//! per side (lo magnitudes and hi magnitudes move independently), where
//! `step` is the log2-domain learning rate.  Like in-hindsight
//! estimation this is *static*: the range used at step `t` was computed
//! from steps `< t` only, so the fused single-store accelerator path
//! applies.  It is a `needs_search`-free stateful plugin — no dump
//! graph, no periodic tensor traversals, O(1) coordinator work per row.
//!
//! The registry key is `tqt`; the spec's `eta` doubles as the
//! adaptation-rate knob (`step = 1 - eta`, clamped to
//! [`MIN_STEP`]..=[`MAX_STEP`]), so `g:tqt:8:eta=0.95` trains its
//! thresholds half as fast as the default.  Golden tests below pin the
//! update rule bit-for-bit.

use super::{RangeEstimator, SiteParams, StepCtx};

/// Smallest log2-domain threshold step (eta very close to 1).
pub const MIN_STEP: f32 = 1.0 / 64.0;
/// Largest log2-domain threshold step (eta far from 1).
pub const MAX_STEP: f32 = 0.25;

/// Trained-threshold (TQT-style) estimator: thresholds nudged by the
/// sign of the clipping-gradient proxy, multiplicatively in log2 domain.
#[derive(Debug, Clone, Copy)]
pub struct TrainedThreshold {
    /// log2-domain learning rate of one threshold update
    step: f32,
}

impl TrainedThreshold {
    pub fn new(step: f32) -> Self {
        assert!(step > 0.0 && step.is_finite(), "threshold step must be positive");
        Self { step }
    }

    /// Registry constructor: derive the threshold step from the site's
    /// range-adaptation momentum (`step = 1 - eta`, clamped).
    pub fn from_params(p: SiteParams) -> Self {
        Self::new((1.0 - p.eta).clamp(MIN_STEP, MAX_STEP))
    }

    pub fn step(&self) -> f32 {
        self.step
    }

    /// One side's update: thresholds move multiplicatively toward the
    /// observed magnitude (`obs` is the magnitude-signed raw side
    /// value).  A dead side (threshold 0) re-seeds from the
    /// observation; a NaN observation holds the threshold (the same
    /// NaN-dropping convention as `quant::minmax` — checked explicitly
    /// because `f32::max` would silently fold NaN to 0 and shrink).
    fn nudge(&self, cur_mag: f32, obs: f32) -> f32 {
        if obs.is_nan() {
            return cur_mag;
        }
        let obs_mag = obs.max(0.0);
        if cur_mag <= 0.0 {
            return obs_mag;
        }
        if obs_mag > cur_mag {
            cur_mag * 2f32.powf(self.step) // clipping: grow
        } else if obs_mag < cur_mag {
            cur_mag * 2f32.powf(-self.step) // headroom: shrink
        } else {
            cur_mag
        }
    }
}

impl RangeEstimator for TrainedThreshold {
    fn name(&self) -> &'static str {
        "tqt"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        if ctx.bootstrap() {
            // paper Sec. 4.1 convention shared by the stateful
            // estimators: the first grid is the first batch's statistics
            return ctx.stats;
        }
        // thresholds are per-side magnitudes around zero (the quantizer
        // grid always contains 0; `QuantParams::from_range` clamps)
        let lo = -self.nudge((-ctx.current[0]).max(0.0), -ctx.stats[0]);
        let hi = self.nudge(ctx.current[1].max(0.0), ctx.stats[1]);
        [lo, hi]
    }

    fn absorb_calibration(
        &mut self,
        current: [f32; 2],
        stats: [f32; 2],
        _eta: f32,
        first_batch: bool,
    ) -> [f32; 2] {
        // threshold training wants a generous starting point it can
        // shrink from, so calibration takes the hull of the observed
        // batches instead of the default EMA blend
        if first_batch {
            stats
        } else {
            [current[0].min(stats[0]), current[1].max(stats[1])]
        }
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(current: [f32; 2], stats: [f32; 2]) -> StepCtx {
        StepCtx {
            current,
            stats,
            new_ranges: [99.0, 99.0], // must be ignored: tqt is coordinator-side
            first_step: false,
            calibrated: true,
        }
    }

    /// Golden pin of the update rule: exact factors, per side, both
    /// directions.
    #[test]
    fn update_rule_is_signed_log2_nudging() {
        let mut e = TrainedThreshold::new(0.0625);
        let up = 2f32.powf(0.0625);
        let down = 2f32.powf(-0.0625);
        // lo clips (|-2| > 1) -> grows; hi has headroom (0.5 < 1) -> shrinks
        assert_eq!(e.absorb_step(ctx([-1.0, 1.0], [-2.0, 0.5])), [-up, down]);
        // both clip -> both grow
        assert_eq!(e.absorb_step(ctx([-1.0, 1.0], [-3.0, 3.0])), [-up, up]);
        // both inside -> both shrink
        assert_eq!(e.absorb_step(ctx([-4.0, 2.0], [-1.0, 1.0])), [-4.0 * down, 2.0 * down]);
        // exact hit -> unchanged
        assert_eq!(e.absorb_step(ctx([-1.0, 2.0], [-1.0, 2.0])), [-1.0, 2.0]);
    }

    #[test]
    fn bootstrap_seeds_from_stats_like_the_paper_init() {
        let mut e = TrainedThreshold::new(0.0625);
        let mut c = ctx([-1.0, 1.0], [-2.0, 3.0]);
        c.first_step = true;
        c.calibrated = false;
        assert_eq!(e.absorb_step(c), [-2.0, 3.0]);
        // calibrated first steps use the trained rule, not the re-seed
        c.calibrated = true;
        assert_ne!(e.absorb_step(c), [-2.0, 3.0]);
    }

    #[test]
    fn dead_sides_reseed_and_nan_observations_hold() {
        let mut e = TrainedThreshold::new(0.0625);
        // a zero side adopts the observation directly
        assert_eq!(e.absorb_step(ctx([0.0, 1.0], [-2.0, 1.0]))[0], -2.0);
        // one-sided tensors keep the dead side at zero
        assert_eq!(e.absorb_step(ctx([0.0, 1.0], [0.5, 1.0]))[0], 0.0);
        // NaN stats leave the thresholds unchanged (minmax NaN policy)
        assert_eq!(e.absorb_step(ctx([-1.0, 2.0], [f32::NAN, f32::NAN])), [-1.0, 2.0]);
    }

    #[test]
    fn repeated_steps_converge_to_the_observed_magnitude() {
        let mut e = TrainedThreshold::new(0.0625);
        let mut row = [-8.0f32, 0.125];
        for _ in 0..200 {
            row = e.absorb_step(ctx(row, [-1.0, 1.0]));
        }
        // within one multiplicative step of the target on both sides
        // (small slack over 2^step: the oscillation bound is exact only
        // in real arithmetic)
        let tol = 2f32.powf(0.0625) * 1.001;
        assert!(-row[0] <= tol && 1.0 / -row[0] <= tol, "{row:?}");
        assert!(row[1] <= tol && 1.0 / row[1] <= tol, "{row:?}");
    }

    #[test]
    fn calibration_takes_the_hull_not_the_ema() {
        let mut e = TrainedThreshold::new(0.0625);
        assert_eq!(e.absorb_calibration([-1.0, 1.0], [-3.0, 0.5], 0.9, true), [-3.0, 0.5]);
        assert_eq!(
            e.absorb_calibration([-3.0, 0.5], [-1.0, 2.0], 0.9, false),
            [-3.0, 2.0]
        );
    }

    #[test]
    fn step_derives_from_eta_with_clamping() {
        assert_eq!(
            TrainedThreshold::from_params(SiteParams { bits: 8, eta: 0.9 }).step(),
            (1.0f32 - 0.9).clamp(MIN_STEP, MAX_STEP)
        );
        // eta ~ 1 clamps to the smallest step, eta 0 to the largest
        assert_eq!(
            TrainedThreshold::from_params(SiteParams { bits: 8, eta: 1.0 }).step(),
            MIN_STEP
        );
        assert_eq!(
            TrainedThreshold::from_params(SiteParams { bits: 8, eta: 0.0 }).step(),
            MAX_STEP
        );
    }
}

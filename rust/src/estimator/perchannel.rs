//! Per-channel granularity as an *adapter*, not a new estimator family.
//!
//! [`PerChannel`] replicates any registered estimator once per channel
//! group and routes each channel's row through its own replica — so
//! `hindsight`, `running`, `maxhist`, DSGC, the sampled searcher, and
//! every future registry entry gain a per-channel variant for free (the
//! registry exposes them via the `@pc` key suffix, e.g. `hindsight@pc`).
//! This is the standard remedy for the inter-channel weight/gradient
//! spread that TQT (Jain et al.) and Banner et al. identify as the main
//! accuracy lever at 8 bits.
//!
//! Channel layout convention (shared with `quant::kernel::minmax_fq_axis`
//! and the simulator's per-channel store path): channels are the
//! trailing, fastest-varying axis — the channel of flat element `i` is
//! `i % n_channels`.
//!
//! With one channel the adapter is a transparent wrapper: every hook
//! forwards to the single replica, so an `@pc` site over a 1-channel
//! feature reproduces the per-tensor row sequence bit-for-bit (pinned by
//! the golden parity tests here and in `coordinator::ranges`).

use super::{RangeEstimator, SearchOutcome, StepCtx};

/// Channel-replicating adapter around any single-row estimator.
#[derive(Debug)]
pub struct PerChannel {
    /// base estimator's registry key (what `name()` reports)
    name: &'static str,
    /// one replica per channel group, each owning its own state
    channels: Vec<Box<dyn RangeEstimator>>,
}

impl PerChannel {
    /// Replicate `make()` across `n_channels` channel groups.
    pub fn replicate(make: impl Fn() -> Box<dyn RangeEstimator>, n_channels: usize) -> Self {
        assert!(n_channels > 0, "PerChannel needs at least one channel");
        let channels: Vec<_> = (0..n_channels).map(|_| make()).collect();
        assert_eq!(
            channels[0].n_rows(),
            1,
            "PerChannel wraps single-row estimators, got '{}' with {} rows",
            channels[0].name(),
            channels[0].n_rows()
        );
        Self { name: channels[0].name(), channels }
    }

    pub fn n_channels(&self) -> usize {
        self.channels.len()
    }
}

impl Clone for PerChannel {
    fn clone(&self) -> Self {
        Self { name: self.name, channels: self.channels.clone() }
    }
}

impl RangeEstimator for PerChannel {
    fn name(&self) -> &'static str {
        self.name
    }

    fn n_rows(&self) -> usize {
        self.channels.len()
    }

    fn init(&self) -> [f32; 2] {
        self.channels[0].init()
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        debug_assert_eq!(
            self.channels.len(),
            1,
            "multi-channel sites absorb via absorb_step_rows"
        );
        self.channels[0].absorb_step(ctx)
    }

    fn absorb_step_rows(&mut self, ctxs: &[StepCtx], out: &mut [[f32; 2]]) {
        assert_eq!(ctxs.len(), self.channels.len(), "ctx rows vs channels");
        assert_eq!(out.len(), self.channels.len(), "out rows vs channels");
        for (c, est) in self.channels.iter_mut().enumerate() {
            out[c] = est.absorb_step(ctxs[c]);
        }
    }

    fn absorb_calibration(
        &mut self,
        current: [f32; 2],
        stats: [f32; 2],
        eta: f32,
        first_batch: bool,
    ) -> [f32; 2] {
        debug_assert_eq!(
            self.channels.len(),
            1,
            "multi-channel sites calibrate via absorb_calibration_rows"
        );
        self.channels[0].absorb_calibration(current, stats, eta, first_batch)
    }

    fn absorb_calibration_rows(
        &mut self,
        currents: &[[f32; 2]],
        stats: &[[f32; 2]],
        eta: f32,
        first_batch: bool,
        out: &mut [[f32; 2]],
    ) {
        assert_eq!(currents.len(), self.channels.len(), "calib rows vs channels");
        for (c, est) in self.channels.iter_mut().enumerate() {
            out[c] = est.absorb_calibration(currents[c], stats[c], eta, first_batch);
        }
    }

    fn needs_search(&self) -> bool {
        self.channels[0].needs_search()
    }

    fn search(&mut self, tensor: &[f32], bits: u32, iters: u32) -> SearchOutcome {
        debug_assert_eq!(self.channels.len(), 1, "multi-channel sites search via search_rows");
        self.channels[0].search(tensor, bits, iters)
    }

    fn search_rows(&mut self, tensor: &[f32], bits: u32, iters: u32, out: &mut [[f32; 2]]) -> u32 {
        let c = self.channels.len();
        assert_eq!(out.len(), c, "out rows vs channels");
        assert_eq!(
            tensor.len() % c,
            0,
            "tensor length {} not divisible by {c} channels",
            tensor.len()
        );
        // one gather pass total: each channel's strided slice is copied
        // once into a scratch buffer sized tensor.len()/c
        let mut chan = Vec::with_capacity(tensor.len() / c);
        let mut evals = 0u32;
        for (ch, est) in self.channels.iter_mut().enumerate() {
            chan.clear();
            chan.extend(tensor.iter().skip(ch).step_by(c).copied());
            let o = est.search(&chan, bits, iters);
            out[ch] = o.range;
            evals += o.evals;
        }
        evals
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::Estimator;
    use crate::util::rng::Pcg32;
    use crate::util::testkit::forall;

    fn ctx(stats: [f32; 2], current: [f32; 2]) -> StepCtx {
        StepCtx {
            current,
            stats,
            new_ranges: [0.6 * stats[0], 0.6 * stats[1]],
            first_step: false,
            calibrated: true,
        }
    }

    #[test]
    fn channels_evolve_independently() {
        let est = Estimator::parse("maxhist").unwrap();
        let mut pc = PerChannel::replicate(|| Estimator::MAX_HISTORY.instantiate(), 2);
        assert_eq!(pc.n_rows(), 2);
        assert_eq!(pc.name(), est.key());
        let ctxs = [ctx([-1.0, 1.0], [-1.0, 1.0]), ctx([-5.0, 0.5], [-1.0, 1.0])];
        let mut out = [[0.0f32; 2]; 2];
        pc.absorb_step_rows(&ctxs, &mut out);
        // each channel's window holds only its own stats
        assert_eq!(out[0], [-1.0, 1.0]);
        assert_eq!(out[1], [-5.0, 0.5]);
    }

    /// Golden parity: a 1-channel adapter reproduces the plain per-tensor
    /// estimator bit-for-bit across random step/calibration sequences,
    /// for every registered estimator.
    #[test]
    fn one_channel_adapter_matches_per_tensor_bit_for_bit() {
        for est in Estimator::all() {
            forall(
                32,
                &format!("pc1-parity-{}", est.key()),
                |rng| {
                    let calib: Vec<[f32; 2]> = (0..rng.below(3))
                        .map(|_| ordered(rng))
                        .collect();
                    let steps: Vec<([f32; 2], [f32; 2])> = (0..1 + rng.below(6))
                        .map(|_| (ordered(rng), ordered(rng)))
                        .collect();
                    (calib, steps, rng.range(0.0, 1.0))
                },
                |(calib, steps, eta)| {
                    let mut plain = est.instantiate();
                    let mut pc = PerChannel::replicate(|| est.instantiate(), 1);
                    let mut row_p = plain.init();
                    let mut row_c = pc.init();
                    if row_p != row_c {
                        return false;
                    }
                    for (i, s) in calib.iter().enumerate() {
                        row_p = plain.absorb_calibration(row_p, *s, *eta, i == 0);
                        let mut out = [[0.0f32; 2]; 1];
                        pc.absorb_calibration_rows(&[row_c], &[*s], *eta, i == 0, &mut out);
                        row_c = out[0];
                        if row_p != row_c {
                            return false;
                        }
                    }
                    for (i, (st, nr)) in steps.iter().enumerate() {
                        let mk = |cur: [f32; 2]| StepCtx {
                            current: cur,
                            stats: *st,
                            new_ranges: *nr,
                            first_step: i == 0,
                            calibrated: !calib.is_empty(),
                        };
                        row_p = plain.absorb_step(mk(row_p));
                        let mut out = [[0.0f32; 2]; 1];
                        pc.absorb_step_rows(&[mk(row_c)], &mut out);
                        row_c = out[0];
                        if row_p != row_c {
                            return false;
                        }
                    }
                    true
                },
            );
        }
    }

    fn ordered(rng: &mut Pcg32) -> [f32; 2] {
        let a = rng.range(-20.0, 20.0);
        let b = rng.range(-20.0, 20.0);
        [a.min(b), a.max(b)]
    }

    #[test]
    fn search_rows_splits_channels_by_stride() {
        // channel 0 = even indices in [-1, 1]; channel 1 = odd in [-4, 4]
        let n = 4096;
        let mut g = vec![0.0f32; n];
        let mut rng = Pcg32::new(5, 1);
        for (i, v) in g.iter_mut().enumerate() {
            *v = if i % 2 == 0 { rng.range(-1.0, 1.0) } else { rng.range(-4.0, 4.0) };
        }
        let mut pc = PerChannel::replicate(|| Estimator::SAMPLED_MINMAX.instantiate(), 2);
        assert!(pc.needs_search());
        let mut rows = [[0.0f32; 2]; 2];
        let evals = pc.search_rows(&g, 8, 0, &mut rows);
        assert_eq!(evals, 2); // one subsample pass per channel
        // channel ranges reflect their own distribution, not the hull
        assert!(rows[0][1] < 1.5, "{rows:?}");
        assert!(rows[1][1] > 3.0, "{rows:?}");
    }

    #[test]
    #[should_panic(expected = "not divisible")]
    fn search_rows_rejects_misaligned_tensors() {
        let mut pc = PerChannel::replicate(|| Estimator::DSGC.instantiate(), 3);
        let mut rows = [[0.0f32; 2]; 3];
        pc.search_rows(&[1.0, 2.0], 8, 1, &mut rows);
    }

    #[test]
    fn clone_preserves_per_channel_state() {
        let mut pc = PerChannel::replicate(|| Estimator::MAX_HISTORY.instantiate(), 2);
        let ctxs = [ctx([-1.0, 1.0], [-1.0, 1.0]), ctx([-2.0, 2.0], [-1.0, 1.0])];
        let mut out = [[0.0f32; 2]; 2];
        pc.absorb_step_rows(&ctxs, &mut out);
        let mut dup = pc.clone_box();
        let mut a = [[0.0f32; 2]; 2];
        let mut b = [[0.0f32; 2]; 2];
        let next = [ctx([-0.5, 0.5], out[0]), ctx([-0.5, 0.5], out[1])];
        pc.absorb_step_rows(&next, &mut a);
        dup.absorb_step_rows(&next, &mut b);
        assert_eq!(a, b);
    }
}

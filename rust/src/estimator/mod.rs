//! The pluggable range-estimator subsystem.
//!
//! The paper's core claim is that in-hindsight range estimation is a
//! *drop-in replacement* for any range estimator.  This module makes that
//! literal: estimator semantics live behind the [`RangeEstimator`] trait
//! (one boxed instance per quantizer site, owning any per-site state),
//! and estimators are resolved from a string-keyed [`registry`] — the
//! coordinator, the CLI, sweeps and benches never branch on a closed
//! enum.  Adding an estimator is: implement the trait, add a registry
//! entry.
//!
//! The split of responsibilities mirrors the paper's Fig. 3 runtime
//! contract:
//!
//! * the compiled graph computes, per step, the raw accumulator
//!   statistics `stats` and the in-graph state update `new_ranges`
//!   (eqs. 2-3 / the dynamic rules) for every site;
//! * between steps, each site's estimator *absorbs* those outputs and
//!   decides the range row the next step quantizes with
//!   ([`RangeEstimator::absorb_step`]);
//! * estimators that cannot be expressed as an O(1) absorb — DSGC's
//!   golden-section search, sample-based estimation — declare
//!   [`RangeEstimator::needs_search`] and get handed the raw gradient
//!   tensors on a period, via the dump graph
//!   ([`RangeEstimator::search`]).
//!
//! Granularity is orthogonal to estimator semantics: a site may
//! quantize per tensor (one range row) or per channel group (one row per
//! channel).  The [`perchannel::PerChannel`] adapter replicates any
//! registered estimator across a site's channels, so every estimator
//! gains a per-channel variant for free — the registry exposes it
//! through the `@pc` key suffix (`hindsight@pc`).  Multi-row sites flow
//! through the `*_rows` hooks below; single-row estimators only ever
//! implement the scalar hooks and inherit the defaults.
//!
//! Submodules: [`classic`] carries the five estimators of the paper's
//! comparison (FP32 / current / running / in-hindsight / DSGC);
//! [`literature`] adds comparison estimators from the wider literature
//! (window max-history, Banner et al.-style sampled min-max, and the
//! Banner et al. layer-wise EMA-absmax/pow2 gradient rule);
//! [`trained`] the TQT-style trained-threshold estimator;
//! [`perchannel`] holds the channel-replicating adapter;
//! [`registry`] owns the name table and the [`Estimator`] handle.

pub mod classic;
pub mod literature;
pub mod perchannel;
pub mod registry;
pub mod trained;

pub use classic::{Current, Dsgc, Fp32, Hindsight, Running};
pub use literature::{Banner, MaxHistory, SampledMinMax};
pub use perchannel::PerChannel;
pub use registry::{Estimator, EstimatorInfo, Granularity, REGISTRY};
pub use trained::TrainedThreshold;

/// Per-site knobs a `QuantSpec` resolves for one quantizer site and
/// hands to the registry factories: estimators that adapt may consume
/// them (TQT derives its threshold step from `eta`); search-based
/// estimators additionally receive `bits` per search call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SiteParams {
    /// quantization bit-width of the site
    pub bits: u32,
    /// EMA momentum / adaptation-rate knob of the site
    pub eta: f32,
}

impl Default for SiteParams {
    /// The paper's defaults (8 bits, eta 0.9).
    fn default() -> Self {
        Self { bits: 8, eta: 0.9 }
    }
}

/// Everything one site's estimator sees from one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepCtx {
    /// the range row the step just quantized with
    pub current: [f32; 2],
    /// raw accumulator min/max of the step (paper Fig. 3)
    pub stats: [f32; 2],
    /// the in-graph state update (eqs. 2-3 / dynamic rules)
    pub new_ranges: [f32; 2],
    /// first training step of the run
    pub first_step: bool,
    /// a calibration pass already seeded the range state
    pub calibrated: bool,
}

impl StepCtx {
    /// Paper Sec. 4.1 initialization `q^0 = minmax(G^0)`: does this step
    /// seed a never-calibrated range state from raw statistics?
    pub fn bootstrap(&self) -> bool {
        self.first_step && !self.calibrated
    }
}

/// Shared absorb rule for search-based (`needs_search`) estimators: hold
/// the last searched range; bootstrap from the first observation so
/// training can start before search #1.
pub(crate) fn hold_between_searches(ctx: StepCtx) -> [f32; 2] {
    if ctx.bootstrap() {
        ctx.stats
    } else {
        ctx.current
    }
}

/// Result of one periodic tensor-level range search.
#[derive(Debug, Clone, Copy)]
pub struct SearchOutcome {
    pub range: [f32; 2],
    /// tensor traversals spent (DSGC: full objective evaluations;
    /// subsampled passes count as one) — cost accounting
    pub evals: u32,
}

/// Per-site range-estimation semantics.
///
/// One boxed instance exists per quantizer site, so implementations may
/// carry per-site state (EMA history, sliding windows, search phase).
/// All hooks are pure coordinator-side math: the dense (R, 2) tensor
/// ABI to the compiled graph — one row group per site — is owned by
/// `RangeManager` and never changes shape mid-run.
pub trait RangeEstimator: std::fmt::Debug + Send {
    /// Registry key (stable string id, e.g. `"hindsight"`).
    fn name(&self) -> &'static str;

    /// Number of range rows this site maintains — 1 for per-tensor
    /// estimators, the channel-group count for per-channel sites.
    fn n_rows(&self) -> usize {
        1
    }

    /// Initial range row before calibration or the first observation.
    fn init(&self) -> [f32; 2] {
        // neutral symmetric range; calibration and/or the first-step
        // stats (paper: q^0 = minmax(G^0)) replace it
        [-1.0, 1.0]
    }

    /// Absorb one training step's graph outputs; returns the next row.
    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2];

    /// Multi-row absorb: one [`StepCtx`] per range row, results written
    /// into `out` (both slices have [`RangeEstimator::n_rows`] entries).
    /// Single-row estimators inherit this forwarding default; the
    /// per-channel adapter overrides it.
    fn absorb_step_rows(&mut self, ctxs: &[StepCtx], out: &mut [[f32; 2]]) {
        debug_assert_eq!(ctxs.len(), 1, "single-row estimator got {} rows", ctxs.len());
        out[0] = self.absorb_step(ctxs[0]);
    }

    /// Absorb one calibration batch (paper Sec. 5.2).  Default: first
    /// batch seeds the row with raw stats, later batches EMA in.
    fn absorb_calibration(
        &mut self,
        current: [f32; 2],
        stats: [f32; 2],
        eta: f32,
        first_batch: bool,
    ) -> [f32; 2] {
        if first_batch {
            stats
        } else {
            crate::quant::ema_update(current, stats, eta)
        }
    }

    /// Multi-row calibration: per-row `current`/`stats`, results written
    /// into `out` (all slices have [`RangeEstimator::n_rows`] entries).
    fn absorb_calibration_rows(
        &mut self,
        currents: &[[f32; 2]],
        stats: &[[f32; 2]],
        eta: f32,
        first_batch: bool,
        out: &mut [[f32; 2]],
    ) {
        debug_assert_eq!(currents.len(), 1, "single-row estimator got {} rows", currents.len());
        out[0] = self.absorb_calibration(currents[0], stats[0], eta, first_batch);
    }

    /// Whether this estimator requires the periodic tensor-level search
    /// pass (the dump graph + [`RangeEstimator::search`]).
    fn needs_search(&self) -> bool {
        false
    }

    /// Periodic tensor-level range search.  Only invoked on sites whose
    /// estimator declares [`RangeEstimator::needs_search`].
    fn search(&mut self, _tensor: &[f32], _bits: u32, _iters: u32) -> SearchOutcome {
        panic!("estimator '{}' has no tensor-level search", self.name())
    }

    /// Multi-row search: ranges written into `out`
    /// ([`RangeEstimator::n_rows`] entries), total tensor-traversal cost
    /// returned.  The default runs one whole-tensor search and broadcasts
    /// its range; the per-channel adapter searches each channel's strided
    /// slice independently.
    fn search_rows(&mut self, tensor: &[f32], bits: u32, iters: u32, out: &mut [[f32; 2]]) -> u32 {
        let o = self.search(tensor, bits, iters);
        for r in out.iter_mut() {
            *r = o.range;
        }
        o.evals
    }

    /// Boxed clone (lets `RangeManager` derive `Clone`).
    fn clone_box(&self) -> Box<dyn RangeEstimator>;
}

impl Clone for Box<dyn RangeEstimator> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_ctx_bootstrap_predicate() {
        let mut ctx = StepCtx {
            current: [-1.0, 1.0],
            stats: [-2.0, 2.0],
            new_ranges: [-0.5, 0.5],
            first_step: true,
            calibrated: false,
        };
        assert!(ctx.bootstrap());
        ctx.calibrated = true;
        assert!(!ctx.bootstrap());
        ctx.calibrated = false;
        ctx.first_step = false;
        assert!(!ctx.bootstrap());
    }

    #[test]
    fn boxed_estimators_clone() {
        let mut a: Box<dyn RangeEstimator> = Box::new(MaxHistory::new(2));
        let ctx = |stats| StepCtx {
            current: [-1.0, 1.0],
            stats,
            new_ranges: [0.0, 0.0],
            first_step: false,
            calibrated: true,
        };
        a.absorb_step(ctx([-3.0, 3.0]));
        let mut b = a.clone();
        // the clone carries the window state: same next result
        assert_eq!(a.absorb_step(ctx([-1.0, 1.0])), b.absorb_step(ctx([-1.0, 1.0])));
    }

    #[test]
    #[should_panic(expected = "no tensor-level search")]
    fn searchless_estimators_reject_search() {
        let mut e: Box<dyn RangeEstimator> = Box::new(Hindsight);
        e.search(&[1.0], 8, 4);
    }

    #[test]
    fn default_row_hooks_forward_to_the_scalar_hooks() {
        let mut e: Box<dyn RangeEstimator> = Box::new(Hindsight);
        assert_eq!(e.n_rows(), 1);
        let ctx = StepCtx {
            current: [-1.0, 1.0],
            stats: [-2.0, 2.0],
            new_ranges: [-0.5, 0.5],
            first_step: false,
            calibrated: true,
        };
        let mut out = [[0.0f32; 2]; 1];
        e.absorb_step_rows(&[ctx], &mut out);
        assert_eq!(out[0], e.absorb_step(ctx));
        let mut out = [[0.0f32; 2]; 1];
        e.absorb_calibration_rows(&[[-1.0, 1.0]], &[[-3.0, 3.0]], 0.5, true, &mut out);
        assert_eq!(out[0], [-3.0, 3.0]);
        // the default search_rows broadcasts the whole-tensor result
        let mut s: Box<dyn RangeEstimator> = Box::new(SampledMinMax::new(4));
        let mut rows = [[0.0f32; 2]; 2];
        let evals = s.search_rows(&[-1.0, 0.5, 2.0, -0.25], 8, 0, &mut rows);
        assert_eq!(evals, 1);
        assert_eq!(rows[0], rows[1]);
        assert!(rows[0][0] <= -1.0 && rows[0][1] >= 2.0);
    }
}

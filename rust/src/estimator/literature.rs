//! Comparison estimators from the wider quantized-training literature,
//! added through the same trait the paper's five estimators use — the
//! "drop-in replacement" claim exercised in the other direction.
//!
//! * [`MaxHistory`] — window max-history: the range is the elementwise
//!   hull (min of mins, max of maxes) of the last `W` steps' statistics.
//!   A static scheme in the paper's sense: the range used at step `t`
//!   was computed from steps `< t` only.  This is the windowed variant
//!   of the max-averaging range trackers used by Jain et al. (TQT) and
//!   Choi et al. as baselines — hindsight's EMA replaced by a hard
//!   window, so one outlier step stops mattering after `W` steps instead
//!   of decaying geometrically.
//! * [`SampledMinMax`] — sample-based range estimation in the spirit of
//!   Banner et al., "Scalable Methods for 8-bit Training of Neural
//!   Networks": statistics are estimated from a small deterministic
//!   subsample of the tensor instead of a full reduction.  Realized
//!   through the `needs_search` hook (like DSGC it periodically sees the
//!   raw gradient tensors and holds its range in between) — but where
//!   DSGC spends `iters + 3` full fake-quant + cosine passes per search,
//!   a sampled search is one pass over ~`budget` elements.
//! * [`Banner`] — the layer-wise gradient range rule of Banner et al.,
//!   "Scalable Methods for 8-bit Training of Neural Networks"
//!   (arXiv:1805.11046): an EMA-smoothed absolute maximum snapped up to
//!   the next power of two — GEMMLOWP-style ranges whose scale is a pure
//!   exponent, so requantization is a shift.  A static scheme like
//!   hindsight (the range at step `t` was computed from steps `< t`),
//!   but symmetric and quantized-to-pow2 rather than a raw min/max hull.

use std::collections::VecDeque;

use super::{hold_between_searches, RangeEstimator, SearchOutcome, StepCtx};

/// Default window length for [`MaxHistory`].
pub const DEFAULT_WINDOW: usize = 8;

/// Window max-history estimator: range = hull of the last `W` stats.
#[derive(Debug, Clone)]
pub struct MaxHistory {
    window: usize,
    hist: VecDeque<[f32; 2]>,
}

impl MaxHistory {
    pub fn new(window: usize) -> Self {
        assert!(window > 0, "MaxHistory window must be positive");
        Self {
            window,
            hist: VecDeque::with_capacity(window),
        }
    }

    fn push(&mut self, stats: [f32; 2]) {
        if self.hist.len() == self.window {
            self.hist.pop_front();
        }
        self.hist.push_back(stats);
    }

    fn hull(&self) -> [f32; 2] {
        // NaN policy: `f32::min`/`max` drop NaN operands, so a NaN stats
        // row never propagates into the hull as long as any finite row
        // is in the window (same dropping convention as `quant::minmax`;
        // pinned by `nan_stats_drop_out_of_the_hull` below)
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for s in &self.hist {
            lo = lo.min(s[0]);
            hi = hi.max(s[1]);
        }
        [lo, hi]
    }
}

impl Default for MaxHistory {
    fn default() -> Self {
        Self::new(DEFAULT_WINDOW)
    }
}

impl RangeEstimator for MaxHistory {
    fn name(&self) -> &'static str {
        "maxhist"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        self.push(ctx.stats);
        // on an uncalibrated first step the window holds exactly the
        // first batch's stats, so the hull *is* q^0 = minmax(G^0)
        self.hull()
    }

    fn absorb_calibration(
        &mut self,
        _current: [f32; 2],
        stats: [f32; 2],
        _eta: f32,
        _first_batch: bool,
    ) -> [f32; 2] {
        // calibration batches enter the same window; the hull replaces
        // the EMA blend (window semantics are the whole point here)
        self.push(stats);
        self.hull()
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(self.clone())
    }
}

/// Default per-search sample budget for [`SampledMinMax`].
pub const DEFAULT_BUDGET: usize = 2048;

/// Sample-based min-max: periodic strided subsample of the gradient
/// tensor, hull widened by a small pad for the unseen tail, held
/// statically between searches.
#[derive(Debug, Clone)]
pub struct SampledMinMax {
    budget: usize,
    /// completed searches; rotates the stride offset so successive
    /// searches see different residue classes of the tensor
    calls: u64,
}

impl SampledMinMax {
    pub fn new(budget: usize) -> Self {
        assert!(budget > 0, "SampledMinMax budget must be positive");
        Self { budget, calls: 0 }
    }
}

impl Default for SampledMinMax {
    fn default() -> Self {
        Self::new(DEFAULT_BUDGET)
    }
}

impl RangeEstimator for SampledMinMax {
    fn name(&self) -> &'static str {
        "sampled"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        hold_between_searches(ctx)
    }

    fn needs_search(&self) -> bool {
        true
    }

    fn search(&mut self, tensor: &[f32], _bits: u32, _iters: u32) -> SearchOutcome {
        if tensor.is_empty() {
            return SearchOutcome {
                range: [0.0, 0.0],
                evals: 0,
            };
        }
        let stride = (tensor.len() / self.budget).max(1);
        let offset = (self.calls as usize) % stride;
        self.calls += 1;
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in tensor.iter().skip(offset).step_by(stride) {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        debug_assert!(lo <= hi, "offset < stride <= len, so the sample is nonempty");
        // widen by 2.5% of the observed span: the sample hull is a biased
        // (under-)estimate of the true extrema; the pad covers the tail
        // the stride skipped (Banner et al. handle this with analytic
        // sub-sampling corrections; a fixed pad keeps it one pass)
        let pad = (hi - lo) * 0.025;
        SearchOutcome {
            range: [lo - pad, hi + pad],
            // one (subsampled) tensor traversal — contrast DSGC's
            // iters + 3 full passes
            evals: 1,
        }
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(self.clone())
    }
}

/// Banner et al. layer-wise gradient ranges: EMA of the absolute
/// maximum, snapped up to the next power of two.
///
/// Update rule (per row):
///
/// ```text
///   a_t = max(|lo_t|, |hi_t|, 0)          observed absmax
///   m_t = eta * m_{t-1} + (1 - eta) * a_t  (adopted raw on bootstrap)
///   range_t = [-2^ceil(log2 m_t), +2^ceil(log2 m_t)]
/// ```
///
/// The pow2 snap makes the quantization scale a pure exponent
/// (GEMMLOWP convention), and also gives the EMA slack: the range only
/// *moves* when the smoothed absmax crosses a power of two, so the grid
/// is stable across steps even while the EMA drifts.
#[derive(Debug, Clone)]
pub struct Banner {
    eta: f32,
    /// EMA state of the absolute maximum (pre-snap)
    absmax: Option<f32>,
}

impl Banner {
    pub fn new(eta: f32) -> Self {
        Self { eta, absmax: None }
    }

    fn absorb(&mut self, stats: [f32; 2], eta: f32, adopt: bool) -> [f32; 2] {
        // NaN policy: `f32::max` drops NaN operands, so a NaN stats side
        // contributes nothing (same convention as the MaxHistory hull)
        let a = (-stats[0]).max(stats[1]).max(0.0);
        let m = match self.absmax {
            Some(m) if !adopt => eta * m + (1.0 - eta) * a,
            _ => a,
        };
        self.absmax = Some(m);
        let p = pow2_ceil(m);
        [-p, p]
    }
}

/// Smallest power of two >= `m` (0 for non-positive or non-finite input;
/// exact powers stay put).
fn pow2_ceil(m: f32) -> f32 {
    if m <= 0.0 || !m.is_finite() {
        0.0
    } else {
        m.log2().ceil().exp2()
    }
}

impl RangeEstimator for Banner {
    fn name(&self) -> &'static str {
        "banner"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        let adopt = ctx.bootstrap() || self.absmax.is_none();
        self.absorb(ctx.stats, self.eta, adopt)
    }

    fn absorb_calibration(
        &mut self,
        _current: [f32; 2],
        stats: [f32; 2],
        eta: f32,
        first_batch: bool,
    ) -> [f32; 2] {
        // calibration blends with the site's eta (the coordinator-side
        // knob), steps with the constructor's; both share the EMA state
        let adopt = first_batch || self.absmax.is_none();
        self.absorb(stats, eta, adopt)
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(stats: [f32; 2]) -> StepCtx {
        StepCtx {
            current: [-9.0, 9.0],
            stats,
            new_ranges: [0.0, 0.0],
            first_step: false,
            calibrated: true,
        }
    }

    #[test]
    fn maxhist_tracks_window_hull() {
        let mut e = MaxHistory::new(2);
        assert_eq!(e.absorb_step(ctx([-1.0, 1.0])), [-1.0, 1.0]);
        assert_eq!(e.absorb_step(ctx([-3.0, 0.5])), [-3.0, 1.0]);
        // the first observation ages out of the 2-window
        assert_eq!(e.absorb_step(ctx([-0.5, 2.0])), [-3.0, 2.0]);
        assert_eq!(e.absorb_step(ctx([-0.5, 0.5])), [-0.5, 2.0]);
    }

    #[test]
    fn maxhist_calibration_enters_the_window() {
        let mut e = MaxHistory::new(4);
        assert_eq!(e.absorb_calibration([-1.0, 1.0], [-2.0, 2.0], 0.9, true), [-2.0, 2.0]);
        // not an EMA: the hull keeps the widest observation
        assert_eq!(e.absorb_calibration([-2.0, 2.0], [-1.0, 1.0], 0.9, false), [-2.0, 2.0]);
    }

    #[test]
    fn nan_stats_drop_out_of_the_hull() {
        let mut e = MaxHistory::new(4);
        assert_eq!(e.absorb_step(ctx([-1.0, 1.0])), [-1.0, 1.0]);
        // a NaN stats row contributes nothing to the hull
        let r = e.absorb_step(ctx([f32::NAN, f32::NAN]));
        assert_eq!(r, [-1.0, 1.0]);
        // one-sided NaN likewise only drops the NaN side
        let r = e.absorb_step(ctx([f32::NAN, 2.0]));
        assert_eq!(r, [-1.0, 2.0]);
        assert!(r[0].is_finite() && r[1].is_finite());
    }

    #[test]
    fn sampled_holds_between_searches_and_bootstraps() {
        let mut e = SampledMinMax::default();
        assert!(e.needs_search());
        let boot = StepCtx {
            first_step: true,
            calibrated: false,
            ..ctx([-2.0, 3.0])
        };
        assert_eq!(e.absorb_step(boot), [-2.0, 3.0]);
        assert_eq!(e.absorb_step(ctx([-2.0, 3.0])), [-9.0, 9.0]); // held
    }

    #[test]
    fn sampled_search_covers_the_bulk() {
        let mut e = SampledMinMax::new(256);
        let g: Vec<f32> = (0..65_536).map(|i| ((i % 1013) as f32 / 506.5) - 1.0).collect();
        let out = e.search(&g, 8, 0);
        assert_eq!(out.evals, 1);
        // the subsample hull (plus pad) must cover most of the true span
        assert!(out.range[0] <= -0.9 && out.range[1] >= 0.9, "{:?}", out.range);
        // successive searches rotate the offset (deterministic but not
        // identical state)
        let out2 = e.search(&g, 8, 0);
        assert_eq!(out2.evals, 1);
    }

    #[test]
    fn banner_ema_absmax_snaps_to_pow2() {
        let mut e = Banner::new(0.5);
        // bootstrap adopts raw: absmax 3 -> 2^ceil(log2 3) = 4
        assert_eq!(e.absorb_step(ctx([-3.0, 2.0])), [-4.0, 4.0]);
        // EMA: 0.5*3 + 0.5*5 = 4 (exact power stays put)
        assert_eq!(e.absorb_step(ctx([-1.0, 5.0])), [-4.0, 4.0]);
        // EMA: 0.5*4 + 0.5*0.2 = 2.1 -> snaps up to 4, not down to 2
        assert_eq!(e.absorb_step(ctx([-0.1, 0.2])), [-4.0, 4.0]);
    }

    #[test]
    fn banner_calibration_shares_the_ema_state() {
        let mut e = Banner::new(0.5);
        // first batch adopts raw (site eta 0.9 unused): absmax 2 -> [-2, 2]
        assert_eq!(e.absorb_calibration([-1.0, 1.0], [-2.0, 2.0], 0.9, true), [-2.0, 2.0]);
        // second batch EMAs with the *site* eta: 0.9*2 + 0.1*12 = 3 -> 4
        assert_eq!(e.absorb_calibration([-2.0, 2.0], [-12.0, 1.0], 0.9, false), [-4.0, 4.0]);
        // a following step EMAs the calibrated state with the ctor eta:
        // 0.5*3 + 0.5*0.5 = 1.75 -> snaps to 2
        assert_eq!(e.absorb_step(ctx([-0.5, 0.5])), [-2.0, 2.0]);
    }

    #[test]
    fn banner_zero_and_nan_guards() {
        let mut e = Banner::new(0.5);
        // all-zero stats: degenerate [0, 0] range, no NaN from log2(0)
        assert_eq!(e.absorb_step(ctx([0.0, 0.0])), [0.0, 0.0]);
        // NaN sides drop out of the absmax (f32::max convention)
        assert_eq!(e.absorb_step(ctx([f32::NAN, f32::NAN])), [0.0, 0.0]);
        let r = e.absorb_step(ctx([f32::NAN, 3.0]));
        assert_eq!(r, [-2.0, 2.0]); // EMA 0.5*0 + 0.5*3 = 1.5 -> 2
        assert!(r[0].is_finite() && r[1].is_finite());
    }

    #[test]
    fn sampled_search_small_and_empty_tensors() {
        let mut e = SampledMinMax::default();
        let out = e.search(&[], 8, 0);
        assert_eq!(out.range, [0.0, 0.0]);
        assert_eq!(out.evals, 0);
        // tensor smaller than the budget: stride 1, exact hull (pad only)
        let out = e.search(&[-1.0, 2.0], 8, 0);
        assert!(out.range[0] <= -1.0 && out.range[1] >= 2.0);
    }
}

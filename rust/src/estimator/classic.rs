//! The five estimators of the paper's comparison (Tables 1-4), as trait
//! impls.  Each reproduces the corresponding branch of the pre-refactor
//! `RangeManager::update` enum `match` bit-for-bit (golden parity tests
//! in `coordinator::ranges` enforce this).

use super::{hold_between_searches, RangeEstimator, SearchOutcome, StepCtx};
use crate::quant::dsgc;

/// Shared absorb rule for the estimators whose state update is computed
/// in-graph: adopt `new_ranges` verbatim, except on an uncalibrated first
/// step, which seeds from raw stats (paper Sec. 4.1, `q^0 = minmax(G^0)`).
fn graph_delegated(ctx: StepCtx) -> [f32; 2] {
    if ctx.bootstrap() {
        ctx.stats
    } else {
        ctx.new_ranges
    }
}

/// No quantization of this tensor class: the row is frozen.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fp32;

impl RangeEstimator for Fp32 {
    fn name(&self) -> &'static str {
        "fp32"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        ctx.current
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(*self)
    }
}

/// Current min-max — dynamic; the graph computes ranges from the current
/// tensor, the coordinator just adopts them.
#[derive(Debug, Clone, Copy, Default)]
pub struct Current;

impl RangeEstimator for Current {
    fn name(&self) -> &'static str {
        "current"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        graph_delegated(ctx)
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(*self)
    }
}

/// Running min-max — dynamic EMA blended *including* the current stats.
#[derive(Debug, Clone, Copy, Default)]
pub struct Running;

impl RangeEstimator for Running {
    fn name(&self) -> &'static str {
        "running"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        graph_delegated(ctx)
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(*self)
    }
}

/// In-hindsight min-max — static; the paper's method (eqs. 2-3).  The
/// EMA update itself runs in-graph; the coordinator adopts its output
/// *after* the step quantized with the previous range.
#[derive(Debug, Clone, Copy, Default)]
pub struct Hindsight;

impl RangeEstimator for Hindsight {
    fn name(&self) -> &'static str {
        "hindsight"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        graph_delegated(ctx)
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(*self)
    }
}

/// Direction-sensitive gradient clipping [Zhu et al. 2019] — static
/// between periodic golden-section searches (paper Sec. 5.1).  The step
/// absorb *holds* the last searched range; the range only moves in
/// [`RangeEstimator::search`].
#[derive(Debug, Clone, Copy, Default)]
pub struct Dsgc;

impl RangeEstimator for Dsgc {
    fn name(&self) -> &'static str {
        "dsgc"
    }

    fn absorb_step(&mut self, ctx: StepCtx) -> [f32; 2] {
        hold_between_searches(ctx)
    }

    fn needs_search(&self) -> bool {
        true
    }

    fn search(&mut self, tensor: &[f32], bits: u32, iters: u32) -> SearchOutcome {
        let r = dsgc::search_range(tensor, bits, iters);
        SearchOutcome {
            range: [r.qmin, r.qmax],
            evals: r.evals,
        }
    }

    fn clone_box(&self) -> Box<dyn RangeEstimator> {
        Box::new(*self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(first_step: bool, calibrated: bool) -> StepCtx {
        StepCtx {
            current: [-7.0, 7.0],
            stats: [-2.0, 3.0],
            new_ranges: [-0.5, 0.5],
            first_step,
            calibrated,
        }
    }

    #[test]
    fn fp32_freezes_rows() {
        let mut e = Fp32;
        assert_eq!(e.absorb_step(ctx(true, false)), [-7.0, 7.0]);
        assert_eq!(e.absorb_step(ctx(false, true)), [-7.0, 7.0]);
    }

    #[test]
    fn graph_delegated_bootstrap_then_adopt() {
        for mut e in [
            Box::new(Current) as Box<dyn RangeEstimator>,
            Box::new(Running),
            Box::new(Hindsight),
        ] {
            assert_eq!(e.absorb_step(ctx(true, false)), [-2.0, 3.0], "{e:?}");
            assert_eq!(e.absorb_step(ctx(true, true)), [-0.5, 0.5], "{e:?}");
            assert_eq!(e.absorb_step(ctx(false, false)), [-0.5, 0.5], "{e:?}");
        }
    }

    #[test]
    fn dsgc_holds_between_searches() {
        let mut e = Dsgc;
        assert!(e.needs_search());
        assert_eq!(e.absorb_step(ctx(true, false)), [-2.0, 3.0]); // bootstrap
        assert_eq!(e.absorb_step(ctx(false, false)), [-7.0, 7.0]); // held
        assert_eq!(e.absorb_step(ctx(true, true)), [-7.0, 7.0]); // held
        // the search delegates to the golden-section module
        let g: Vec<f32> = (0..512).map(|i| (i as f32 / 256.0) - 1.0).collect();
        let out = e.search(&g, 8, 10);
        assert_eq!(out.evals, 13);
        assert!(out.range[0] < 0.0 && out.range[1] > 0.0);
    }

    #[test]
    fn default_calibration_seeds_then_emas() {
        let mut e = Hindsight;
        assert_eq!(e.absorb_calibration([-1.0, 1.0], [-3.0, 3.0], 0.5, true), [-3.0, 3.0]);
        let blended = e.absorb_calibration([-3.0, 3.0], [-1.0, 1.0], 0.5, false);
        assert_eq!(blended, [-2.0, 2.0]);
    }
}

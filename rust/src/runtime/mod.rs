//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! * [`manifest`] — parse `artifacts/manifest.json` (graph ABIs, parameter
//!   layouts, quantizer-site tables produced by `python/compile/aot.py`).
//! * [`tensor`] — host tensors and Literal marshalling.
//! * [`engine`] — PJRT CPU client with an executable cache; one compile
//!   per (model, graph) per process, then pure execution on the step path.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::Engine;
pub use manifest::{GraphSpec, IoSpec, Manifest, ModelSpec, SiteKind, SiteSpec};
pub use tensor::Tensor;

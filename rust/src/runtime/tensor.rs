//! Host tensors and Literal marshalling.

use anyhow::{bail, Result};

/// Element type of a tensor (the manifest only emits these two).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype '{other}'"),
        }
    }
}

/// A host tensor (row-major) with f32 or i32 payload.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Payload,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn zeros(dtype: Dtype, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        let data = match dtype {
            Dtype::F32 => Payload::F32(vec![0.0; n]),
            Dtype::I32 => Payload::I32(vec![0; n]),
        };
        Self {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape: shape.to_vec(),
            data: Payload::F32(data),
        }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self {
            shape: shape.to_vec(),
            data: Payload::I32(data),
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::from_f32(&[], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Self::from_i32(&[], vec![v])
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            Payload::F32(_) => Dtype::F32,
            Payload::I32(_) => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Payload::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Payload::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Payload::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    /// Scalar accessor (any rank-0/1-element tensor).
    pub fn item_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("item_f32 on tensor of {} elements", d.len());
        }
        Ok(d[0])
    }

    /// Convert to an XLA literal (copies).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, bytes): (xla::ElementType, &[u8]) = match &self.data {
            Payload::F32(v) => (xla::ElementType::F32, bytemuck_f32(v)),
            Payload::I32(v) => (xla::ElementType::S32, bytemuck_i32(v)),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty,
            &self.shape,
            bytes,
        )?)
    }

    /// Convert back from an XLA literal (copies).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Self::from_f32(&dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Self::from_i32(&dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported literal type {other:?}"),
        }
    }
}

fn bytemuck_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_accessors() {
        let t = Tensor::zeros(Dtype::F32, &[2, 3]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn scalar_item() {
        assert_eq!(Tensor::scalar_f32(2.5).item_f32().unwrap(), 2.5);
        assert!(Tensor::from_f32(&[2], vec![1.0, 2.0]).item_f32().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::from_i32(&[3], vec![5, -7, 0]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_i32(42);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[42]);
    }
}

//! PJRT execution engine.
//!
//! One [`Engine`] per process: wraps the PJRT CPU client, compiles each
//! (model, graph) artifact at most once (the estimator is a runtime input,
//! so an entire estimator sweep reuses a single executable — the AOT
//! realization of the paper's "drop-in replacement" claim), and executes
//! with positional Literal marshalling per the manifest.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::runtime::manifest::{GraphSpec, Manifest};
use crate::runtime::tensor::Tensor;

/// Executable handle with its ABI.
#[derive(Clone)]
pub struct Graph {
    pub spec: GraphSpec,
    exe: Rc<xla::PjRtLoadedExecutable>,
}

/// PJRT client + executable cache + execution statistics.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Graph>>,
    stats: RefCell<EngineStats>,
}

/// Cumulative engine counters (perf accounting).
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub compiles: u64,
    pub compile_seconds: f64,
    pub executions: u64,
    pub execute_seconds: f64,
    pub marshal_seconds: f64,
}

/// §Perf (EXPERIMENTS.md): xla_extension 0.5.1's CPU backend at its
/// default optimization level compiles the train graphs ~26x slower
/// (388s vs 14.7s for the ResNet train step) AND produces ~1.7x slower
/// code than level 1 on this testbed — set level 1 unless the user
/// overrides XLA_FLAGS themselves.  Engine construction calls this; the
/// sweep executor also calls it *before* spawning workers so the env
/// mutation never races concurrent `Engine::new` calls on worker
/// threads.
pub fn ensure_default_xla_flags() {
    if std::env::var("XLA_FLAGS").is_err() {
        std::env::set_var("XLA_FLAGS", "--xla_backend_optimization_level=1");
    }
}

impl Engine {
    /// Create the engine over the default artifact dir.
    pub fn new() -> Result<Self> {
        Self::with_manifest(Manifest::load_default()?)
    }

    pub fn with_manifest(manifest: Manifest) -> Result<Self> {
        ensure_default_xla_flags();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::debug!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Self {
            manifest,
            client,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(EngineStats::default()),
        })
    }

    pub fn stats(&self) -> EngineStats {
        *self.stats.borrow()
    }

    /// Load (compile-once) a graph of a model.
    pub fn graph(&self, model: &str, graph: &str) -> Result<Graph> {
        let key = format!("{model}/{graph}");
        if let Some(g) = self.cache.borrow().get(&key) {
            return Ok(g.clone());
        }
        let spec = self.manifest.model(model)?.graph(graph)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.borrow_mut();
            s.compiles += 1;
            s.compile_seconds += dt;
        }
        log::info!("compiled {key} in {dt:.2}s");
        let g = Graph {
            spec,
            exe: Rc::new(exe),
        };
        self.cache.borrow_mut().insert(key, g.clone());
        Ok(g)
    }

    /// Execute a graph with host tensors; validates arity/shape against
    /// the manifest ABI and returns outputs in manifest order.
    pub fn run(&self, g: &Graph, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_refs(g, &refs)
    }

    /// Borrowing variant used by the training hot loop (no state clones).
    pub fn run_refs(&self, g: &Graph, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != g.spec.inputs.len() {
            bail!(
                "arity mismatch: graph '{}' wants {} inputs, got {}",
                g.spec.file,
                g.spec.inputs.len(),
                inputs.len()
            );
        }
        let t0 = Instant::now();
        let mut lits = Vec::with_capacity(inputs.len());
        for (t, io) in inputs.iter().zip(&g.spec.inputs) {
            if t.shape != io.shape {
                bail!(
                    "shape mismatch on input '{}': manifest {:?}, got {:?}",
                    io.name,
                    io.shape,
                    t.shape
                );
            }
            lits.push(t.to_literal()?);
        }
        let t1 = Instant::now();
        let result = g.exe.execute::<xla::Literal>(&lits)?;
        let mut tuple = result[0][0].to_literal_sync()?;
        let t2 = Instant::now();
        let parts = tuple.decompose_tuple()?;
        if parts.len() != g.spec.outputs.len() {
            bail!(
                "output arity mismatch: manifest {}, runtime {}",
                g.spec.outputs.len(),
                parts.len()
            );
        }
        let out = parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()?;
        let t3 = Instant::now();
        {
            let mut s = self.stats.borrow_mut();
            s.executions += 1;
            s.execute_seconds += (t2 - t1).as_secs_f64();
            s.marshal_seconds +=
                (t1 - t0).as_secs_f64() + (t3 - t2).as_secs_f64();
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::Dtype;

    fn engine() -> Option<Engine> {
        if !Manifest::default_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(Engine::new().unwrap())
    }

    #[test]
    fn init_graph_produces_params() {
        let Some(e) = engine() else { return };
        let g = e.graph("mlp", "init").unwrap();
        let out = e.run(&g, &[Tensor::scalar_i32(0)]).unwrap();
        let spec = e.manifest.model("mlp").unwrap();
        assert_eq!(out.len(), spec.params.len() * 2 + spec.state.len());
        // he-init weights are non-trivial
        let w = out[0].as_f32().unwrap();
        assert!(w.iter().any(|&x| x != 0.0));
        // momentum buffers are zeros
        let m = out[spec.params.len()].as_f32().unwrap();
        assert!(m.iter().all(|&x| x == 0.0));
        // executable cache: second request hits the cache
        let c0 = e.stats().compiles;
        let _ = e.graph("mlp", "init").unwrap();
        assert_eq!(e.stats().compiles, c0);
    }

    #[test]
    fn arity_and_shape_validation() {
        let Some(e) = engine() else { return };
        let g = e.graph("mlp", "init").unwrap();
        assert!(e.run(&g, &[]).is_err());
        let bad = Tensor::zeros(Dtype::I32, &[2]);
        assert!(e.run(&g, &[bad]).is_err());
    }

    #[test]
    fn init_is_deterministic_per_seed() {
        let Some(e) = engine() else { return };
        let g = e.graph("mlp", "init").unwrap();
        let a = e.run(&g, &[Tensor::scalar_i32(7)]).unwrap();
        let b = e.run(&g, &[Tensor::scalar_i32(7)]).unwrap();
        let c = e.run(&g, &[Tensor::scalar_i32(8)]).unwrap();
        assert_eq!(a[0], b[0]);
        assert_ne!(a[0], c[0]);
    }
}

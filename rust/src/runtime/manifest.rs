//! Artifact manifest: the ABI contract between `python/compile/aot.py`
//! and the Rust runtime.  Marshalling is positional — the manifest's
//! input/output orders *are* the flat argument orders of the HLO graphs.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::Dtype;
use crate::util::json::{self, Value};

/// One graph input/output slot.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

/// One lowered graph (HLO text file + ABI).
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl GraphSpec {
    /// Index of a named input (panics are avoided; marshalling code uses
    /// this for the scalar tail of the argument list).
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|io| io.name == name)
            .ok_or_else(|| anyhow!("graph has no input '{name}'"))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|io| io.name == name)
            .ok_or_else(|| anyhow!("graph has no output '{name}'"))
    }
}

/// Quantizer-site kind (which estimator mode scalar applies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SiteKind {
    Act,
    Grad,
}

/// One quantizer site (row group of the range-state tensor).
#[derive(Debug, Clone)]
pub struct SiteSpec {
    pub index: usize,
    pub name: String,
    pub kind: SiteKind,
    pub feature_shape: Vec<usize>,
}

impl SiteSpec {
    /// Channel-group count for per-channel range estimation: the
    /// trailing (fastest-varying) axis of the site's feature shape —
    /// the channels-last convention the quant kernels and the
    /// per-channel estimator adapter share.  Scalar or empty feature
    /// shapes quantize per tensor (1 group).
    pub fn channels(&self) -> usize {
        self.feature_shape.last().copied().unwrap_or(1).max(1)
    }
}

/// Parameter/state leaf descriptor.
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One model's artifact bundle.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub batch_size: usize,
    pub input_shape: Vec<usize>,
    pub n_classes: usize,
    pub n_params: usize,
    pub pallas: String,
    pub params: Vec<LeafSpec>,
    pub state: Vec<LeafSpec>,
    pub sites: Vec<SiteSpec>,
    pub graphs: Vec<(String, GraphSpec)>,
}

impl ModelSpec {
    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, g)| g)
            .ok_or_else(|| anyhow!("model {} has no graph '{name}'", self.name))
    }

    pub fn has_graph(&self, name: &str) -> bool {
        self.graphs.iter().any(|(n, _)| n == name)
    }

    pub fn n_sites(&self) -> usize {
        self.sites.len()
    }

    pub fn grad_sites(&self) -> Vec<&SiteSpec> {
        self.sites
            .iter()
            .filter(|s| s.kind == SiteKind::Grad)
            .collect()
    }

    pub fn act_sites(&self) -> Vec<&SiteSpec> {
        self.sites
            .iter()
            .filter(|s| s.kind == SiteKind::Act)
            .collect()
    }
}

/// The whole artifact bundle.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub bits_w: u32,
    pub bits_a: u32,
    pub bits_g: u32,
    pub models: Vec<ModelSpec>,
}

impl Manifest {
    /// Default artifact location: `$HINDSIGHT_ARTIFACTS` or `artifacts/`
    /// relative to the current dir (falling back to the crate root, so
    /// tests/benches work from any cwd).
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("HINDSIGHT_ARTIFACTS") {
            return PathBuf::from(p);
        }
        for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).context("parsing manifest.json")?;
        Self::from_value(dir, &root)
    }

    fn from_value(dir: &Path, root: &Value) -> Result<Self> {
        let quant = root.req("quant")?;
        let models_v = root
            .req("models")?
            .as_object()
            .ok_or_else(|| anyhow!("models is not an object"))?;
        let mut models = Vec::new();
        for (name, mv) in models_v {
            models.push(parse_model(name, mv)?);
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            bits_w: req_usize(quant, "bits_w")? as u32,
            bits_a: req_usize(quant, "bits_a")? as u32,
            bits_g: req_usize(quant, "bits_g")? as u32,
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "no model '{name}' in manifest (have: {})",
                    self.models
                        .iter()
                        .map(|m| m.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    pub fn hlo_path(&self, g: &GraphSpec) -> PathBuf {
        self.dir.join(&g.file)
    }
}

fn req_usize(v: &Value, key: &str) -> Result<usize> {
    v.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("'{key}' is not a number"))
}

fn req_str<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    v.req(key)?
        .as_str()
        .ok_or_else(|| anyhow!("'{key}' is not a string"))
}

fn parse_io(v: &Value) -> Result<IoSpec> {
    Ok(IoSpec {
        name: req_str(v, "name")?.to_string(),
        shape: v
            .req("shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("shape is not an array"))?,
        dtype: Dtype::parse(req_str(v, "dtype")?)?,
    })
}

fn parse_leaf(v: &Value) -> Result<LeafSpec> {
    Ok(LeafSpec {
        name: req_str(v, "name")?.to_string(),
        shape: v
            .req("shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("shape is not an array"))?,
    })
}

fn parse_model(name: &str, v: &Value) -> Result<ModelSpec> {
    let arr = |key: &str| -> Result<&[Value]> {
        v.req(key)?
            .as_array()
            .ok_or_else(|| anyhow!("'{key}' is not an array"))
    };
    let params = arr("params")?.iter().map(parse_leaf).collect::<Result<_>>()?;
    let state = arr("state")?.iter().map(parse_leaf).collect::<Result<_>>()?;
    let sites = arr("sites")?
        .iter()
        .map(|s| {
            let kind = match req_str(s, "kind")? {
                "act" => SiteKind::Act,
                "grad" => SiteKind::Grad,
                other => bail!("unknown site kind '{other}'"),
            };
            Ok(SiteSpec {
                index: req_usize(s, "index")?,
                name: req_str(s, "name")?.to_string(),
                kind,
                feature_shape: s
                    .req("feature_shape")?
                    .as_usize_vec()
                    .ok_or_else(|| anyhow!("feature_shape is not an array"))?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let graphs_v = v
        .req("graphs")?
        .as_object()
        .ok_or_else(|| anyhow!("graphs is not an object"))?;
    let mut graphs = Vec::new();
    for (gname, gv) in graphs_v {
        let inputs = gv
            .req("inputs")?
            .as_array()
            .ok_or_else(|| anyhow!("inputs is not an array"))?
            .iter()
            .map(parse_io)
            .collect::<Result<_>>()?;
        let outputs = gv
            .req("outputs")?
            .as_array()
            .ok_or_else(|| anyhow!("outputs is not an array"))?
            .iter()
            .map(parse_io)
            .collect::<Result<_>>()?;
        graphs.push((
            gname.clone(),
            GraphSpec {
                file: req_str(gv, "file")?.to_string(),
                inputs,
                outputs,
            },
        ));
    }
    Ok(ModelSpec {
        name: name.to_string(),
        batch_size: req_usize(v, "batch_size")?,
        input_shape: v
            .req("input_shape")?
            .as_usize_vec()
            .ok_or_else(|| anyhow!("input_shape is not an array"))?,
        n_classes: req_usize(v, "n_classes")?,
        n_params: req_usize(v, "n_params")?,
        pallas: req_str(v, "pallas")?.to_string(),
        params,
        state,
        sites,
        graphs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "quant": {"bits_w": 8, "bits_a": 8, "bits_g": 8},
      "models": {
        "mlp": {
          "batch_size": 32, "input_shape": [8, 8, 3], "n_classes": 10,
          "n_params": 100, "pallas": "all",
          "params": [{"name": "fc1.w", "shape": [192, 64]}],
          "state": [],
          "sites": [
            {"index": 0, "name": "fc1.act", "kind": "act", "feature_shape": [64]},
            {"index": 1, "name": "fc2.grad", "kind": "grad", "feature_shape": [64]}
          ],
          "graphs": {
            "train": {
              "file": "mlp_train.hlo.txt",
              "inputs": [{"name": "param:fc1.w", "shape": [192, 64], "dtype": "f32"},
                         {"name": "seed", "shape": [], "dtype": "i32"}],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
            }
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(Path::new("/tmp"), &v).unwrap();
        assert_eq!(m.bits_g, 8);
        let model = m.model("mlp").unwrap();
        assert_eq!(model.batch_size, 32);
        assert_eq!(model.sites.len(), 2);
        assert_eq!(model.grad_sites().len(), 1);
        // channels-last convention: trailing feature axis is the group count
        assert_eq!(model.sites[0].channels(), 64);
        let scalar_site = SiteSpec {
            index: 9,
            name: "s".into(),
            kind: SiteKind::Act,
            feature_shape: vec![],
        };
        assert_eq!(scalar_site.channels(), 1);
        let g = model.graph("train").unwrap();
        assert_eq!(g.input_index("seed").unwrap(), 1);
        assert!(g.input_index("nope").is_err());
        assert!(model.graph("eval").is_err());
    }

    #[test]
    fn missing_model_error_lists_names() {
        let v = json::parse(SAMPLE).unwrap();
        let m = Manifest::from_value(Path::new("/tmp"), &v).unwrap();
        let err = m.model("resnet").unwrap_err().to_string();
        assert!(err.contains("mlp"), "{err}");
    }

    /// Parses the real manifest when artifacts are built.
    #[test]
    fn parses_real_manifest_if_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model("mlp").is_ok());
        let resnet = m.model("resnet_tiny").unwrap();
        // train graph ABI: params*2 + state + x,y,ranges + 9 scalars
        let g = resnet.graph("train").unwrap();
        let expected =
            resnet.params.len() * 2 + resnet.state.len() + 3 + 9;
        assert_eq!(g.inputs.len(), expected);
        // outputs: params*2 + state + loss, acc, new_ranges, stats
        assert_eq!(
            g.outputs.len(),
            resnet.params.len() * 2 + resnet.state.len() + 4
        );
        // the ranges input is (R, 2); R == Q for per-tensor artifacts
        let ri = g.input_index("ranges").unwrap();
        assert_eq!(g.inputs[ri].shape, vec![resnet.n_sites(), 2]);
    }
}

//! # hindsight
//!
//! Production-grade reproduction of *In-Hindsight Quantization Range
//! Estimation for Quantized Training* (Fournarakis & Nagel, 2021) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The Rust crate is the entire runtime: it loads AOT-compiled XLA
//! artifacts (HLO text produced once by `python/compile/aot.py`), drives
//! quantized training end-to-end, owns the paper's range-estimation state
//! machine, and ships the substrates the paper's evaluation depends on
//! (synthetic datasets, a fixed-point accelerator model, the architecture
//! zoo, metrics and reporting).  Python never runs on the step path.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — hand-rolled substrates: JSON, CLI, PRNG, logging, stats,
//!   a property-test kit and a bench harness (no external deps).
//! * [`quant`] — bit-exact quantization math mirroring the L1 kernels;
//!   the fused single-pass kernels (`quant::kernel`) and DSGC's
//!   golden-section range search live here.
//! * [`estimator`] — the pluggable range-estimator subsystem: the
//!   `RangeEstimator` trait, the string-keyed registry, the paper's five
//!   estimators and the literature additions (max-history, sampled,
//!   TQT-style trained thresholds, the Banner et al. layer-wise
//!   EMA-absmax/pow2 rule).
//! * [`scheme`] — typed per-tensor-class quantization schemes: one
//!   `QuantSpec` (estimator, bits, eta, symmetry) per tensor class plus
//!   per-site overrides, with a builder and a canonical string form
//!   (`w:current:8 a:hindsight:8 g:hindsight@pc:4`).
//! * [`simulator`] — fixed-point accelerator model: the `LayerGeom`
//!   workload graph (conv / linear / attention), MAC-array execution
//!   and the static-vs-dynamic memory-traffic accounting of paper §6.
//! * [`models`] — architecture geometry zoo (full-size ResNet18 / VGG16 /
//!   MobileNetV2 for Table 5 plus the ViT-S/16 and DeiT-T/16
//!   transformers; the reduced training variants live in the manifest).
//! * [`data`] — deterministic synthetic vision datasets (the Tiny
//!   ImageNet stand-in; DESIGN.md §3 documents the substitution).
//! * [`metrics`] — run records, seed aggregation, table emitters.
//! * [`runtime`] — PJRT engine: manifest-driven marshalling, executable
//!   cache, device-resident parameter state.
//! * [`coordinator`] — the paper's contribution as runtime logic: the
//!   range-state machine delegating to the estimator subsystem,
//!   calibration, the training driver, and the sweep-grid engine
//!   (brace-expanded scheme grids, a deterministic parallel executor,
//!   a resumable run store).
//! * [`service`] — the sweep service (`hindsight serve`): a
//!   dependency-free HTTP/1.1 front end over the grid executor and
//!   run store, with cost-prioritized scheduling and deterministic
//!   `index % N` sharding across processes sharing one store.

pub mod coordinator;
pub mod data;
pub mod estimator;
pub mod metrics;
pub mod models;
pub mod quant;
pub mod runtime;
pub mod scheme;
pub mod service;
pub mod simulator;
pub mod util;

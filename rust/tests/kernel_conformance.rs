//! Differential kernel-conformance harness: every kernel backend must
//! be bit-identical to the scalar reference on every input the
//! adversarial generators can produce.
//!
//! The scalar backend (`quant::kernel::scalar`) is the contract; the
//! SIMD and chunked-parallel backends are checked against it — not
//! against each other — across:
//!
//! * empty slices and lengths below / at / straddling the SIMD lane
//!   width (`simd::LANES`), the cache-chunk size (`kernel::CHUNK`) and
//!   multi-chunk spans (where the parallel backend actually fans out);
//! * NaN and ±inf payloads (pinning the documented NaN-*dropping*
//!   statistics policy and the NaN-*saturating* fake-quant policy);
//! * subnormals, all-negative and all-constant tensors, mixed-sign
//!   zeros;
//! * ragged per-channel layouts (checked rejection) and every channel
//!   count in 1..=9 — covering both the lane-mapped fast path
//!   (`c | LANES`) and the wrapped-counter fallback;
//! * explicit parallel span counts {1, 2, 7, 16} (determinism does not
//!   depend on how many workers the tensor was split across);
//! * integer-payload stores (`fq_store_i8`, nibble-packed
//!   `fq_store_i4`, their `_axis` variants and the `dequant_*`
//!   readbacks): pack -> unpack round trips must produce byte-identical
//!   payloads and bit-identical decodes on every backend, including odd
//!   lengths straddling the i4 pack boundary, empty slices and odd
//!   channel counts.
//!
//! Cases are seeded (`HINDSIGHT_PT_SEED`) and shrink on failure, so a
//! falsified property reports a minimal core, not a 3000-element dump.
//!
//! The final test exercises the *dispatched* path end-to-end: it pins
//! the process backend to `parallel` via `select_backend` (this
//! binary's only use of the global — everything else goes through the
//! explicit `_on`/`_with` entry points) and runs a 2-worker sweep-grid
//! workload whose results must be bit-identical to a serial
//! scalar-backend run.

use hindsight::coordinator::executor::{run_indexed, JobOutcome};
use hindsight::quant::kernel::{
    self, parallel, simd, KernelBackend, KernelError, CHUNK,
};
use hindsight::util::rng::Pcg32;
use hindsight::util::testkit::{forall_shrink, gens};

/// Boundary lengths the generators aim at: lane width, cache chunk,
/// and a span long enough that the parallel backend genuinely fans out.
const BOUNDARIES: [usize; 4] = [simd::LANES, CHUNK, 3 * CHUNK, 5 * CHUNK];

/// Explicit span counts for the chunked-parallel determinism pins.
const SPAN_COUNTS: [usize; 4] = [1, 2, 7, 16];

/// Bitwise-up-to-zero-sign equality with NaN == NaN: what "bit-identical"
/// means for f32 results in this repo (the `==` the unit suites use,
/// plus NaN-position equality so a backend can't hide a stray NaN).
fn feq(a: f32, b: f32) -> bool {
    a == b || (a.is_nan() && b.is_nan())
}

fn slices_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(&x, &y)| feq(x, y))
}

fn stats_eq(a: &[(f32, f32)], b: &[(f32, f32)]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| feq(x.0, y.0) && feq(x.1, y.1))
}

#[derive(Debug, Clone)]
struct Case {
    lo: f32,
    hi: f32,
    bits: u32,
    xs: Vec<f32>,
}

fn gen_case(rng: &mut Pcg32) -> Case {
    let (lo, hi) = gens::range(rng);
    Case {
        lo,
        hi,
        bits: gens::bits(rng),
        xs: gens::adversarial(rng, &BOUNDARIES),
    }
}

fn shrink_case(c: &Case) -> Vec<Case> {
    gens::shrink_tensor(&c.xs)
        .into_iter()
        .map(|xs| Case { xs, ..c.clone() })
        .collect()
}

/// The non-scalar variants of `minmax_fq` under test, as (label, run).
fn minmax_fq_variants(c: &Case) -> Vec<(String, Vec<f32>, (f32, f32))> {
    let mut out = Vec::new();
    for b in [KernelBackend::Simd, KernelBackend::Parallel] {
        let mut buf = c.xs.clone();
        let stats = kernel::minmax_fq_on(b, &mut buf, c.lo, c.hi, c.bits);
        out.push((b.key().to_string(), buf, stats));
    }
    for t in SPAN_COUNTS {
        let mut buf = c.xs.clone();
        let stats = parallel::minmax_fq_with(t, &mut buf, c.lo, c.hi, c.bits);
        out.push((format!("parallel[{t}]"), buf, stats));
    }
    out
}

#[test]
fn minmax_fq_backends_match_the_scalar_reference() {
    forall_shrink(128, "conf-minmax_fq", gen_case, shrink_case, |c| {
        let mut expect = c.xs.clone();
        let expect_stats = kernel::minmax_fq_on(
            KernelBackend::Scalar,
            &mut expect,
            c.lo,
            c.hi,
            c.bits,
        );
        minmax_fq_variants(c).into_iter().all(|(_, buf, stats)| {
            slices_eq(&buf, &expect) && feq(stats.0, expect_stats.0) && feq(stats.1, expect_stats.1)
        })
    });
}

#[test]
fn fq_into_backends_match_the_scalar_reference() {
    forall_shrink(128, "conf-fq_into", gen_case, shrink_case, |c| {
        let mut expect = vec![0.0f32; c.xs.len()];
        kernel::fq_into_on(KernelBackend::Scalar, &c.xs, &mut expect, c.lo, c.hi, c.bits);
        let simd_ok = {
            let mut dst = vec![0.0f32; c.xs.len()];
            kernel::fq_into_on(KernelBackend::Simd, &c.xs, &mut dst, c.lo, c.hi, c.bits);
            slices_eq(&dst, &expect)
        };
        simd_ok
            && SPAN_COUNTS.iter().all(|&t| {
                let mut dst = vec![0.0f32; c.xs.len()];
                parallel::fq_into_with(t, &c.xs, &mut dst, c.lo, c.hi, c.bits);
                slices_eq(&dst, &expect)
            })
    });
}

#[test]
fn fq_cosine_backends_match_the_scalar_reference() {
    // the f64 accumulation order is pinned on every backend, so the
    // comparison is exact f32 equality (NaN-aware for inf payloads
    // whose products make the objective NaN on all backends equally)
    forall_shrink(128, "conf-fq_cosine", gen_case, shrink_case, |c| {
        let expect = kernel::fq_cosine_on(KernelBackend::Scalar, &c.xs, c.lo, c.hi, c.bits);
        KernelBackend::ALL
            .iter()
            .all(|&b| feq(kernel::fq_cosine_on(b, &c.xs, c.lo, c.hi, c.bits), expect))
    });
}

// ---------------------------------------------------------------------------
// Per-channel axis kernel
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct AxisCase {
    bits: u32,
    ranges: Vec<[f32; 2]>,
    xs: Vec<f32>,
}

fn gen_axis_case(rng: &mut Pcg32) -> AxisCase {
    // covers all three SIMD layouts: {1, 2, 4, 8} lane-mapped,
    // {16, 24, 64} row-blocked (24 additionally exercises the
    // lcm-aligned parallel spans, since 24 does not divide CHUNK),
    // and the rest the wrapped-counter fallback
    let c = match rng.below(4) {
        0 => [16, 24, 64][rng.below(3)],
        _ => 1 + rng.below(9),
    };
    let ranges: Vec<[f32; 2]> = (0..c)
        .map(|_| {
            let (lo, hi) = gens::range(rng);
            [lo, hi]
        })
        .collect();
    let mut xs = gens::adversarial(rng, &BOUNDARIES);
    xs.truncate(xs.len() - xs.len() % c); // channels-last contract
    AxisCase {
        bits: gens::bits(rng),
        ranges,
        xs,
    }
}

fn shrink_axis_case(a: &AxisCase) -> Vec<AxisCase> {
    let c = a.ranges.len();
    let rows = a.xs.len() / c;
    let mut out = Vec::new();
    // halve the rows (keeps the layout channel-aligned); when only one
    // row is left the "second half" would be the case itself — skip it
    if rows / 2 > 0 {
        out.push(AxisCase {
            xs: a.xs[..(rows / 2) * c].to_vec(),
            ..a.clone()
        });
        out.push(AxisCase {
            xs: a.xs[(rows / 2) * c..].to_vec(),
            ..a.clone()
        });
    }
    // neutralize the first interesting element
    if let Some(i) = a.xs.iter().position(|&x| x != 0.0 || x.is_nan()) {
        let mut xs = a.xs.clone();
        xs[i] = 0.0;
        out.push(AxisCase { xs, ..a.clone() });
    }
    out
}

#[test]
fn minmax_fq_axis_backends_match_the_scalar_reference() {
    forall_shrink(128, "conf-axis", gen_axis_case, shrink_axis_case, |a| {
        let mut expect = a.xs.clone();
        let expect_stats =
            kernel::minmax_fq_axis_on(KernelBackend::Scalar, &mut expect, &a.ranges, a.bits);
        let simd_ok = {
            let mut buf = a.xs.clone();
            let stats =
                kernel::minmax_fq_axis_on(KernelBackend::Simd, &mut buf, &a.ranges, a.bits);
            slices_eq(&buf, &expect) && stats_eq(&stats, &expect_stats)
        };
        simd_ok
            && SPAN_COUNTS.iter().all(|&t| {
                let mut buf = a.xs.clone();
                let stats = parallel::minmax_fq_axis_with(t, &mut buf, &a.ranges, a.bits);
                slices_eq(&buf, &expect) && stats_eq(&stats, &expect_stats)
            })
    });
}

#[test]
fn ragged_axis_layouts_are_rejected_by_every_backend() {
    // the checked contract: a length that wraps mid-row is an error
    // value, never a silent misquantization — on all backends alike
    for b in KernelBackend::ALL {
        for (len, c) in [(3usize, 2usize), (CHUNK + 1, 2), (10, 3), (8 * CHUNK + 4, 8)] {
            let mut xs = vec![1.0f32; len];
            let before = xs.clone();
            let err = kernel::try_minmax_fq_axis_on(b, &mut xs, &vec![[-1.0, 1.0]; c], 8)
                .expect_err("ragged layout must be rejected");
            assert_eq!(err, KernelError::RaggedAxis { len, channels: c });
            assert_eq!(xs, before, "rejected tensor must be untouched");
        }
        let err = kernel::try_minmax_fq_axis_on(b, &mut [1.0, 2.0], &[], 8).unwrap_err();
        assert_eq!(err, KernelError::NoChannels);
    }
}

// ---------------------------------------------------------------------------
// Targeted edge pins (deterministic, not property-driven)
// ---------------------------------------------------------------------------

#[test]
fn empty_slices_on_every_backend_and_entry_point() {
    for b in KernelBackend::ALL {
        assert_eq!(kernel::minmax_fq_on(b, &mut [], -1.0, 1.0, 8), (0.0, 0.0));
        assert_eq!(
            kernel::try_minmax_fq_axis_on(b, &mut [], &[[-1.0, 1.0]; 3], 8).unwrap(),
            vec![(0.0, 0.0); 3]
        );
        kernel::fq_into_on(b, &[], &mut [], -1.0, 1.0, 8);
        assert_eq!(kernel::fq_cosine_on(b, &[], -1.0, 1.0, 8), 1.0);
    }
    // the public `_with` surface shares the dispatcher's empty-slice
    // convention (it is called directly by tests and benches)
    for t in SPAN_COUNTS {
        assert_eq!(parallel::minmax_fq_with(t, &mut [], -1.0, 1.0, 8), (0.0, 0.0));
        assert_eq!(
            parallel::minmax_fq_axis_with(t, &mut [], &[[-1.0, 1.0]; 2], 8),
            vec![(0.0, 0.0); 2]
        );
    }
}

#[test]
fn nan_and_inf_payload_policy_is_identical_across_backends() {
    // NaN drops out of the statistics fold; ±inf propagates into it;
    // the fake-quant side saturates both onto the grid
    let mut payload = vec![0.5f32; 2 * CHUNK + 3];
    payload[0] = f32::NAN;
    payload[CHUNK] = f32::INFINITY;
    payload[CHUNK + 1] = f32::NEG_INFINITY;
    payload[2 * CHUNK + 2] = f32::NAN; // in the scalar tail
    let mut expect = payload.clone();
    let expect_stats =
        kernel::minmax_fq_on(KernelBackend::Scalar, &mut expect, -1.0, 1.0, 8);
    assert_eq!(expect_stats, (f32::NEG_INFINITY, f32::INFINITY));
    assert!(expect.iter().all(|x| x.is_finite()), "fq saturates payloads");
    for b in [KernelBackend::Simd, KernelBackend::Parallel] {
        let mut buf = payload.clone();
        let stats = kernel::minmax_fq_on(b, &mut buf, -1.0, 1.0, 8);
        assert_eq!(stats, expect_stats, "{b}");
        assert!(slices_eq(&buf, &expect), "{b}");
    }
    for t in SPAN_COUNTS {
        let mut buf = payload.clone();
        let stats = parallel::minmax_fq_with(t, &mut buf, -1.0, 1.0, 8);
        assert_eq!(stats, expect_stats, "parallel[{t}]");
        assert!(slices_eq(&buf, &expect), "parallel[{t}]");
    }
}

#[test]
fn subnormal_all_negative_and_all_constant_tensors_conform() {
    let tensors: Vec<Vec<f32>> = vec![
        (0..CHUNK + 7).map(|i| (i as f32 + 1.0) * f32::MIN_POSITIVE * 0.25).collect(),
        (0..3 * CHUNK + 1).map(|i| -1.0 - (i % 17) as f32 * 0.5).collect(),
        vec![-2.75; 2 * CHUNK + 9],
        vec![0.0; simd::LANES - 1],
    ];
    for xs in &tensors {
        for &(lo, hi, bits) in &[(-1.0f32, 1.0f32, 8u32), (0.0, 0.0, 4), (-50.0, 0.0, 2)] {
            let mut expect = xs.clone();
            let es = kernel::minmax_fq_on(KernelBackend::Scalar, &mut expect, lo, hi, bits);
            for b in [KernelBackend::Simd, KernelBackend::Parallel] {
                let mut buf = xs.clone();
                let s = kernel::minmax_fq_on(b, &mut buf, lo, hi, bits);
                assert!(feq(s.0, es.0) && feq(s.1, es.1), "{b} stats");
                assert!(slices_eq(&buf, &expect), "{b} values");
            }
        }
    }
}

/// Satellite pin: the chunked-parallel backend is deterministic in the
/// span count — {1, 2, 7, 16} spans all produce the serial scalar bits
/// on the same tensor, for the per-tensor, per-channel and `fq_into`
/// kernels alike.
#[test]
fn parallel_span_counts_are_bit_equal_to_serial() {
    let mut rng = Pcg32::new(41, 5);
    let xs: Vec<f32> = (0..5 * CHUNK + 13).map(|_| rng.normal()).collect();
    let ranges: Vec<[f32; 2]> = (0..4).map(|c| [-1.0 - c as f32, 1.0 + c as f32]).collect();

    let mut serial = xs.clone();
    let serial_stats =
        kernel::minmax_fq_on(KernelBackend::Scalar, &mut serial, -2.0, 2.0, 8);
    let axis_len = xs.len() - xs.len() % ranges.len();
    let mut serial_axis = xs[..axis_len].to_vec();
    let serial_axis_stats =
        kernel::minmax_fq_axis_on(KernelBackend::Scalar, &mut serial_axis, &ranges, 8);
    let mut serial_into = vec![0.0f32; xs.len()];
    kernel::fq_into_on(KernelBackend::Scalar, &xs, &mut serial_into, -2.0, 2.0, 8);

    for t in SPAN_COUNTS {
        let mut buf = xs.clone();
        assert_eq!(
            parallel::minmax_fq_with(t, &mut buf, -2.0, 2.0, 8),
            serial_stats,
            "stats diverge at {t} spans"
        );
        assert_eq!(buf, serial, "values diverge at {t} spans");

        let mut buf = xs[..axis_len].to_vec();
        assert_eq!(
            parallel::minmax_fq_axis_with(t, &mut buf, &ranges, 8),
            serial_axis_stats,
            "axis stats diverge at {t} spans"
        );
        assert_eq!(buf, serial_axis, "axis values diverge at {t} spans");

        let mut dst = vec![0.0f32; xs.len()];
        parallel::fq_into_with(t, &xs, &mut dst, -2.0, 2.0, 8);
        assert_eq!(dst, serial_into, "fq_into diverges at {t} spans");
    }
}

// ---------------------------------------------------------------------------
// Integer-payload stores: pack -> unpack round trips, bit-identical
// across backends
// ---------------------------------------------------------------------------

fn bytes_eq(a: &[u8], b: &[u8], what: &str) -> bool {
    if a != b {
        eprintln!("{what}: payload bytes diverge");
        return false;
    }
    true
}

/// Scalar-reference i8 round trip: (payload, stats, decoded values).
fn i8_reference(xs: &[f32], lo: f32, hi: f32, bits: u32) -> (Vec<u8>, (f32, f32), Vec<f32>) {
    let mut payload = vec![0u8; xs.len()];
    let stats = kernel::fq_store_i8_on(KernelBackend::Scalar, xs, &mut payload, lo, hi, bits);
    let mut decoded = vec![0.0f32; xs.len()];
    kernel::dequant_i8_on(KernelBackend::Scalar, &payload, &mut decoded, lo, hi, bits);
    (payload, stats, decoded)
}

/// Scalar-reference i4 round trip (nibble-packed payload).
fn i4_reference(xs: &[f32], lo: f32, hi: f32, bits: u32) -> (Vec<u8>, (f32, f32), Vec<f32>) {
    let mut payload = vec![0u8; xs.len().div_ceil(2)];
    let stats = kernel::fq_store_i4_on(KernelBackend::Scalar, xs, &mut payload, lo, hi, bits);
    let mut decoded = vec![0.0f32; xs.len()];
    kernel::dequant_i4_on(KernelBackend::Scalar, &payload, &mut decoded, lo, hi, bits);
    (payload, stats, decoded)
}

#[test]
fn i8_payload_round_trips_match_the_scalar_reference() {
    forall_shrink(128, "conf-i8-payload", gen_case, shrink_case, |c| {
        let (ep, es, ed) = i8_reference(&c.xs, c.lo, c.hi, c.bits);
        let mut ok = true;
        for b in [KernelBackend::Simd, KernelBackend::Parallel] {
            let mut p = vec![0u8; c.xs.len()];
            let s = kernel::fq_store_i8_on(b, &c.xs, &mut p, c.lo, c.hi, c.bits);
            let mut d = vec![0.0f32; c.xs.len()];
            kernel::dequant_i8_on(b, &p, &mut d, c.lo, c.hi, c.bits);
            ok &= bytes_eq(&p, &ep, b.key())
                && feq(s.0, es.0)
                && feq(s.1, es.1)
                && slices_eq(&d, &ed);
        }
        for t in SPAN_COUNTS {
            let mut p = vec![0u8; c.xs.len()];
            let s = parallel::fq_store_i8_with(t, &c.xs, &mut p, c.lo, c.hi, c.bits);
            let mut d = vec![0.0f32; c.xs.len()];
            parallel::dequant_i8_with(t, &p, &mut d, c.lo, c.hi, c.bits);
            ok &= bytes_eq(&p, &ep, &format!("parallel[{t}]"))
                && feq(s.0, es.0)
                && feq(s.1, es.1)
                && slices_eq(&d, &ed);
        }
        ok
    });
}

#[test]
fn i4_payload_round_trips_match_the_scalar_reference() {
    forall_shrink(128, "conf-i4-payload", gen_case, shrink_case, |c| {
        // the adversarial generator draws bits in 2..=8; pack-width codes
        // are 1..=4, so clamp (the range/payload adversaries still apply)
        let bits = c.bits.min(4);
        let (ep, es, ed) = i4_reference(&c.xs, c.lo, c.hi, bits);
        let mut ok = true;
        for b in [KernelBackend::Simd, KernelBackend::Parallel] {
            let mut p = vec![0u8; c.xs.len().div_ceil(2)];
            let s = kernel::fq_store_i4_on(b, &c.xs, &mut p, c.lo, c.hi, bits);
            let mut d = vec![0.0f32; c.xs.len()];
            kernel::dequant_i4_on(b, &p, &mut d, c.lo, c.hi, bits);
            ok &= bytes_eq(&p, &ep, b.key())
                && feq(s.0, es.0)
                && feq(s.1, es.1)
                && slices_eq(&d, &ed);
        }
        for t in SPAN_COUNTS {
            let mut p = vec![0u8; c.xs.len().div_ceil(2)];
            let s = parallel::fq_store_i4_with(t, &c.xs, &mut p, c.lo, c.hi, bits);
            let mut d = vec![0.0f32; c.xs.len()];
            parallel::dequant_i4_with(t, &p, &mut d, c.lo, c.hi, bits);
            ok &= bytes_eq(&p, &ep, &format!("parallel[{t}]"))
                && feq(s.0, es.0)
                && feq(s.1, es.1)
                && slices_eq(&d, &ed);
        }
        ok
    });
}

#[test]
fn axis_payload_round_trips_match_the_scalar_reference() {
    forall_shrink(96, "conf-axis-payload", gen_axis_case, shrink_axis_case, |a| {
        let bits4 = a.bits.min(4);
        // scalar references, both widths
        let mut ep8 = vec![0u8; a.xs.len()];
        let es8 = kernel::try_fq_store_i8_axis_on(
            KernelBackend::Scalar,
            &a.xs,
            &mut ep8,
            &a.ranges,
            a.bits,
        )
        .unwrap();
        let mut ed8 = vec![0.0f32; a.xs.len()];
        kernel::dequant_i8_axis_on(KernelBackend::Scalar, &ep8, &mut ed8, &a.ranges, a.bits);
        let mut ep4 = vec![0u8; a.xs.len().div_ceil(2)];
        let es4 = kernel::try_fq_store_i4_axis_on(
            KernelBackend::Scalar,
            &a.xs,
            &mut ep4,
            &a.ranges,
            bits4,
        )
        .unwrap();
        let mut ed4 = vec![0.0f32; a.xs.len()];
        kernel::dequant_i4_axis_on(KernelBackend::Scalar, &ep4, &mut ed4, &a.ranges, bits4);

        let mut ok = true;
        for b in [KernelBackend::Simd, KernelBackend::Parallel] {
            let mut p = vec![0u8; a.xs.len()];
            let s = kernel::try_fq_store_i8_axis_on(b, &a.xs, &mut p, &a.ranges, a.bits).unwrap();
            let mut d = vec![0.0f32; a.xs.len()];
            kernel::dequant_i8_axis_on(b, &p, &mut d, &a.ranges, a.bits);
            ok &= bytes_eq(&p, &ep8, b.key()) && stats_eq(&s, &es8) && slices_eq(&d, &ed8);

            let mut p = vec![0u8; a.xs.len().div_ceil(2)];
            let s = kernel::try_fq_store_i4_axis_on(b, &a.xs, &mut p, &a.ranges, bits4).unwrap();
            let mut d = vec![0.0f32; a.xs.len()];
            kernel::dequant_i4_axis_on(b, &p, &mut d, &a.ranges, bits4);
            ok &= bytes_eq(&p, &ep4, b.key()) && stats_eq(&s, &es4) && slices_eq(&d, &ed4);
        }
        for t in SPAN_COUNTS {
            let mut p = vec![0u8; a.xs.len()];
            let s = parallel::fq_store_i8_axis_with(t, &a.xs, &mut p, &a.ranges, a.bits);
            ok &= bytes_eq(&p, &ep8, &format!("parallel[{t}] i8 axis")) && stats_eq(&s, &es8);

            let mut p = vec![0u8; a.xs.len().div_ceil(2)];
            let s = parallel::fq_store_i4_axis_with(t, &a.xs, &mut p, &a.ranges, bits4);
            ok &= bytes_eq(&p, &ep4, &format!("parallel[{t}] i4 axis")) && stats_eq(&s, &es4);
        }
        ok
    });
}

/// Satellite pin: every odd length around the nibble-pack boundaries —
/// the final byte's high nibble must be zero on every backend, so odd
/// payloads are byte-comparable (and hashable) across backends.
#[test]
fn i4_odd_lengths_straddling_the_pack_boundary_conform() {
    let mut rng = Pcg32::new(77, 3);
    for base in [1usize, 3, simd::LANES - 1, simd::LANES + 1, CHUNK - 1, CHUNK + 1, 2 * CHUNK + 3]
    {
        let xs: Vec<f32> = (0..base).map(|_| rng.normal()).collect();
        let (ep, es, _) = i4_reference(&xs, -2.0, 2.0, 4);
        if base % 2 == 1 {
            assert_eq!(ep.last().unwrap() >> 4, 0, "odd length {base}: high nibble parked");
        }
        for b in [KernelBackend::Simd, KernelBackend::Parallel] {
            let mut p = vec![0u8; base.div_ceil(2)];
            let s = kernel::fq_store_i4_on(b, &xs, &mut p, -2.0, 2.0, 4);
            assert_eq!(p, ep, "{b} @ len {base}");
            assert_eq!(s, es, "{b} stats @ len {base}");
        }
        for t in SPAN_COUNTS {
            let mut p = vec![0u8; base.div_ceil(2)];
            let s = parallel::fq_store_i4_with(t, &xs, &mut p, -2.0, 2.0, 4);
            assert_eq!(p, ep, "parallel[{t}] @ len {base}");
            assert_eq!(s, es, "parallel[{t}] stats @ len {base}");
        }
    }
}

/// Satellite pin: empty slices on every payload entry point, every
/// backend — no panics, neutral stats, untouched buffers.
#[test]
fn empty_payload_slices_on_every_backend_and_entry_point() {
    for b in KernelBackend::ALL {
        assert_eq!(kernel::fq_store_i8_on(b, &[], &mut [], -1.0, 1.0, 8), (0.0, 0.0));
        assert_eq!(kernel::fq_store_i4_on(b, &[], &mut [], -1.0, 1.0, 4), (0.0, 0.0));
        kernel::dequant_i8_on(b, &[], &mut [], -1.0, 1.0, 8);
        kernel::dequant_i4_on(b, &[], &mut [], -1.0, 1.0, 4);
        let ranges = [[-1.0, 1.0]; 3];
        assert_eq!(
            kernel::try_fq_store_i8_axis_on(b, &[], &mut [], &ranges, 8).unwrap(),
            vec![(0.0, 0.0); 3]
        );
        assert_eq!(
            kernel::try_fq_store_i4_axis_on(b, &[], &mut [], &ranges, 4).unwrap(),
            vec![(0.0, 0.0); 3]
        );
        kernel::dequant_i8_axis_on(b, &[], &mut [], &ranges, 8);
        kernel::dequant_i4_axis_on(b, &[], &mut [], &ranges, 4);
    }
    for t in SPAN_COUNTS {
        assert_eq!(parallel::fq_store_i8_with(t, &[], &mut [], -1.0, 1.0, 8), (0.0, 0.0));
        assert_eq!(parallel::fq_store_i4_with(t, &[], &mut [], -1.0, 1.0, 4), (0.0, 0.0));
        parallel::dequant_i8_with(t, &[], &mut [], -1.0, 1.0, 8);
        parallel::dequant_i4_with(t, &[], &mut [], -1.0, 1.0, 4);
    }
}

/// Satellite pin: per-channel payload stores with an *odd* channel
/// count — channel phase and nibble phase drift apart (lcm(c, 2) = 2c),
/// the hardest alignment case for the packed axis kernels.
#[test]
fn odd_channel_count_axis_payload_stores_conform() {
    let mut rng = Pcg32::new(91, 7);
    for c in [3usize, 5, 7, 9] {
        let rows = (2 * CHUNK) / c + 1; // deliberately not chunk-aligned
        let xs: Vec<f32> = (0..rows * c).map(|_| rng.normal()).collect();
        let ranges: Vec<[f32; 2]> =
            (0..c).map(|i| [-1.0 - i as f32 * 0.3, 1.0 + i as f32 * 0.2]).collect();
        let mut ep = vec![0u8; xs.len().div_ceil(2)];
        let es = kernel::try_fq_store_i4_axis_on(
            KernelBackend::Scalar,
            &xs,
            &mut ep,
            &ranges,
            4,
        )
        .unwrap();
        let mut ed = vec![0.0f32; xs.len()];
        kernel::dequant_i4_axis_on(KernelBackend::Scalar, &ep, &mut ed, &ranges, 4);
        // the decode must round-trip the scalar store exactly
        let mut fq_ref = xs.clone();
        kernel::minmax_fq_axis_on(KernelBackend::Scalar, &mut fq_ref, &ranges, 4);
        assert!(slices_eq(&ed, &fq_ref), "c={c}: dequant(store(x)) != fq(x)");
        for b in [KernelBackend::Simd, KernelBackend::Parallel] {
            let mut p = vec![0u8; xs.len().div_ceil(2)];
            let s = kernel::try_fq_store_i4_axis_on(b, &xs, &mut p, &ranges, 4).unwrap();
            assert_eq!(p, ep, "{b} @ c={c}");
            assert!(stats_eq(&s, &es), "{b} stats @ c={c}");
            let mut d = vec![0.0f32; xs.len()];
            kernel::dequant_i4_axis_on(b, &p, &mut d, &ranges, 4);
            assert!(slices_eq(&d, &ed), "{b} decode @ c={c}");
        }
        for t in SPAN_COUNTS {
            let mut p = vec![0u8; xs.len().div_ceil(2)];
            let s = parallel::fq_store_i4_axis_with(t, &xs, &mut p, &ranges, 4);
            assert_eq!(p, ep, "parallel[{t}] @ c={c}");
            assert!(stats_eq(&s, &es), "parallel[{t}] stats @ c={c}");
        }
    }
}

/// Ragged and short-buffer payload contracts reject on every backend,
/// leaving the destination untouched.
#[test]
fn ragged_axis_payload_layouts_are_rejected_by_every_backend() {
    for b in KernelBackend::ALL {
        let xs = [1.0f32; 7];
        let mut dst = [0u8; 7];
        let err = kernel::try_fq_store_i8_axis_on(b, &xs, &mut dst, &[[-1.0, 1.0]; 2], 8)
            .expect_err("ragged layout must be rejected");
        assert_eq!(err, KernelError::RaggedAxis { len: 7, channels: 2 });
        assert_eq!(dst, [0u8; 7], "rejected payload must be untouched");
        let mut dst4 = [0u8; 4];
        let err = kernel::try_fq_store_i4_axis_on(b, &xs, &mut dst4, &[], 4).unwrap_err();
        assert_eq!(err, KernelError::NoChannels);
    }
}

// ---------------------------------------------------------------------------
// Dispatched end-to-end: the sweep executor under the parallel backend
// ---------------------------------------------------------------------------

/// Satellite pin (executor level): a 2-worker grid whose cells run the
/// *dispatched* kernels under the globally-selected parallel backend is
/// bit-identical to a serial scalar-backend run of the same cells.
/// This is the only test in the binary that touches the global
/// selection, so the pin is race-free.
#[test]
fn two_worker_grid_under_parallel_backend_matches_serial_scalar_run() {
    kernel::select_backend(KernelBackend::Parallel).expect("first selection in this process");
    assert_eq!(kernel::backend(), KernelBackend::Parallel);
    // conflicting re-selection is an error; re-selecting the same
    // backend is a no-op
    assert!(kernel::select_backend(KernelBackend::Scalar).is_err());
    kernel::select_backend(KernelBackend::Parallel).expect("idempotent re-select");

    // eight deterministic "gradient tensors" standing in for grid cells
    let cells: Vec<Vec<f32>> = (0..8)
        .map(|i| {
            let mut rng = Pcg32::new(100 + i as u64, 3);
            let n = 2 * CHUNK + 17 * i;
            (0..n).map(|_| rng.normal() * 0.01).collect()
        })
        .collect();
    let ranges: Vec<[f32; 2]> = (0..2).map(|c| [-0.05 - c as f32 * 0.01, 0.05]).collect();

    // the per-cell workload every quantized-training step runs: a
    // static G_X store (minmax_fq), its per-channel variant, and a
    // DSGC objective probe
    type CellOut = (Vec<f32>, (f32, f32), Vec<(f32, f32)>, f32);
    let work = |xs: &Vec<f32>, b: Option<KernelBackend>| -> CellOut {
        let mut buf = xs.clone();
        let stats = match b {
            Some(b) => kernel::minmax_fq_on(b, &mut buf, -0.04, 0.04, 8),
            None => kernel::minmax_fq(&mut buf, -0.04, 0.04, 8),
        };
        let axis_len = xs.len() - xs.len() % ranges.len();
        let mut axis = xs[..axis_len].to_vec();
        let axis_stats = match b {
            Some(b) => kernel::minmax_fq_axis_on(b, &mut axis, &ranges, 8),
            None => kernel::minmax_fq_axis(&mut axis, &ranges, 8),
        };
        let cos = match b {
            Some(b) => kernel::fq_cosine_on(b, xs, -0.04, 0.04, 8),
            None => kernel::fq_cosine(xs, -0.04, 0.04, 8),
        };
        (buf, stats, axis_stats, cos)
    };

    // serial scalar reference, in grid order
    let expect: Vec<_> = cells
        .iter()
        .map(|xs| work(xs, Some(KernelBackend::Scalar)))
        .collect();

    // 2-worker executor run through the *dispatched* entry points
    let runs = run_indexed(&cells, 2, |_| Ok(()), |_: &mut (), _i, xs: &Vec<f32>| {
        Ok(work(xs, None))
    });
    assert_eq!(runs.len(), expect.len());
    for (i, (run, want)) in runs.iter().zip(&expect).enumerate() {
        match run {
            JobOutcome::Done(got) => {
                assert_eq!(got.0, want.0, "cell {i}: quantized tensor");
                assert_eq!(got.1, want.1, "cell {i}: stats");
                assert_eq!(got.2, want.2, "cell {i}: axis stats");
                assert_eq!(got.3.to_bits(), want.3.to_bits(), "cell {i}: objective");
            }
            JobOutcome::Failed(e) => panic!("cell {i} failed: {e}"),
        }
    }
}

//! End-to-end acceptance for the transformer workload path: an
//! attention layer under `g:hindsight@pc:4` gets one range row per head,
//! the hindsight update adopts per-head ranges one step late (eqs. 2-3),
//! and the 4-bit gradient store bills the nibble-packed integer payload.
//! Engine-free: everything runs on the analytic workload spec.

use hindsight::coordinator::{validate_scheme_sites, RangeManager};
use hindsight::quant::kernel::{self, KernelError};
use hindsight::runtime::{SiteKind, Tensor};
use hindsight::scheme::QuantScheme;
use hindsight::simulator::scheme::store_gradient;
use hindsight::simulator::{workload_spec, LayerGeom};

const T: u64 = 16; // tokens
const D: u64 = 32; // d_model
const H: u64 = 4; // heads
const HD: u64 = 8; // head_dim

fn layers() -> Vec<LayerGeom> {
    vec![LayerGeom::attention("attn", T, D, H, HD)]
}

fn scheme() -> QuantScheme {
    QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight@pc:4").unwrap()
}

#[test]
fn attention_spec_exposes_per_head_sites() {
    let spec = workload_spec("attn-e2e", &layers());
    let names: Vec<&str> = spec.sites.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, ["L00.probs", "L00.ctx", "L00.scores.gx", "L00.gx"]);
    // site names are single tokens — the scheme override grammar keys
    // on them (`@L00.scores.gx:<spec>`)
    for s in &spec.sites {
        assert!(!s.name.contains(char::is_whitespace), "{}", s.name);
    }
    assert_eq!(spec.sites[2].kind, SiteKind::Grad);
    // heads are the trailing channel-group axis: scores are (t, t, h)
    assert_eq!(spec.sites[2].feature_shape, vec![T as usize, T as usize, H as usize]);
    assert_eq!(spec.sites[2].channels(), H as usize);
    assert_eq!(spec.sites[3].channels(), D as usize);

    // a per-site override naming an attention site validates...
    let with_override = QuantScheme::parse(
        "w:current:8 a:hindsight:8 g:hindsight@pc:4 @L00.scores.gx:hindsight@pc:4",
    )
    .unwrap();
    validate_scheme_sites(&spec, &with_override).unwrap();
    // ...and a typo'd site errors, listing the real quantizer sites
    let bogus =
        QuantScheme::parse("w:current:8 a:hindsight:8 g:hindsight@pc:4 @L9.gx:hindsight@pc:4")
            .unwrap();
    let err = validate_scheme_sites(&spec, &bogus).unwrap_err().to_string();
    assert!(err.contains("matches no quantizer site"), "{err}");
    assert!(err.contains("L00.scores.gx"), "{err}");
}

#[test]
fn per_head_hindsight_ranges_drive_the_payload_store() {
    let layers = layers();
    let scheme = scheme();
    let mut rm = RangeManager::for_workload("attn-e2e", &layers, &scheme);
    assert_eq!(rm.n_sites(), 4);
    // per-tensor act sites (1 row each) + per-channel grad sites
    // (one row per head for scores, one per model channel for gx)
    let rows = 1 + 1 + H as usize + D as usize;
    assert_eq!(rm.n_rows(), rows);
    let scores = 2; // site index of L00.scores.gx
    assert_eq!(rm.site_rows(scores).len(), H as usize);

    // step 0, uncalibrated: hindsight seeds each head row from its own
    // first-batch statistics (paper Sec. 4.1, q^0 = minmax(G^0))
    let mut nr = vec![0.0f32; rows * 2];
    let mut st = vec![0.0f32; rows * 2];
    let off = rm.row_offset(scores);
    for h in 0..H as usize {
        st[(off + h) * 2] = -(h as f32 + 1.0);
        st[(off + h) * 2 + 1] = h as f32 + 1.0;
    }
    rm.update(
        &Tensor::from_f32(&[rows, 2], nr.clone()),
        &Tensor::from_f32(&[rows, 2], st.clone()),
        true,
    );
    assert_eq!(rm.site_rows(scores), &[[-1.0, 1.0], [-2.0, 2.0], [-3.0, 3.0], [-4.0, 4.0]]);

    // step 1: the in-graph EMA hands back new per-head ranges; the
    // coordinator adopts them *after* this step quantized with the old
    for h in 0..H as usize {
        nr[(off + h) * 2] = -2.0 * (h as f32 + 1.0);
        nr[(off + h) * 2 + 1] = 2.0 * (h as f32 + 1.0);
    }
    let before = rm.site_rows(scores).to_vec();
    let gx_len = (T * T * H) as usize;
    let mut gx: Vec<f32> = (0..gx_len).map(|i| (i % 7) as f32 * 0.01 - 0.03).collect();
    let (stats, bits_moved) = store_gradient(&scheme, &mut gx, &before);
    // one stats pair per head, and the traffic is the measured 4-bit
    // nibble-packed payload: two codes per byte
    assert_eq!(stats.len(), H as usize);
    assert_eq!(bits_moved, kernel::payload_bytes(gx_len, 4) as u64 * 8);
    assert_eq!(bits_moved, gx_len as u64 * 4);
    rm.update(
        &Tensor::from_f32(&[rows, 2], nr),
        &Tensor::from_f32(&[rows, 2], st),
        false,
    );
    assert_eq!(rm.site_rows(scores), &[[-2.0, 2.0], [-4.0, 4.0], [-6.0, 6.0], [-8.0, 8.0]]);
}

#[test]
fn ragged_head_layout_is_rejected() {
    // a stats tensor whose length the head count doesn't divide must be
    // refused, not silently misquantized against the wrong head's range
    let ranges = vec![[-1.0f32, 1.0]; H as usize];
    let xs = vec![0.1f32; (T * T * H) as usize - 1];
    let mut dst = vec![0u8; kernel::payload_bytes(xs.len(), 4)];
    let err = kernel::try_fq_store_i4_axis(&xs, &mut dst, &ranges, 4).unwrap_err();
    assert_eq!(err, KernelError::RaggedAxis { len: xs.len(), channels: H as usize });
    assert!(err.to_string().contains("not divisible"), "{err}");
}

//! End-to-end sweep-service tests over real TCP.
//!
//! The service is exercised exactly as a client would: bind an
//! ephemeral port, submit grids over a socket, poll status, fetch
//! results — then pin the acceptance invariants: service results are
//! bit-identical to a serial run of the same grid, resubmission serves
//! 100% cached cells, and two shards over one store partition the grid
//! disjointly while their merged results still match the serial run.
//!
//! Everything runs the synthetic cell runner (no artifacts needed);
//! the synthetic record convention is shared with the server
//! (`synthetic_cell_record`), which is what makes bit-identity
//! checkable here.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use hindsight::coordinator::executor::run_cells_serial_with;
use hindsight::coordinator::{grid_rows, GridOptions, GridSpec, TrainConfig};
use hindsight::service::protocol::{read_response, read_response_full};
use hindsight::service::{synthetic_cell_record, CellRunner, ServeOptions, Server, ShardSpec};
use hindsight::util::json::{self, Value};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hindsight_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The warm-path test snapshots the process-global `json::count`
/// counters, which every other test in this binary would disturb from
/// its client side — so the binary's tests run one at a time.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// One HTTP request over a fresh connection; returns (status, JSON).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Value) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("read timeout");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("request write");
    let (status, bytes) = read_response(&mut stream).expect("response read");
    let text = String::from_utf8(bytes).expect("utf8 body");
    let value = json::parse(text.trim()).unwrap_or_else(|e| panic!("bad body '{text}': {e}"));
    (status, value)
}

/// Raw variant of [`http`]: status + headers + unparsed body bytes.
/// The warm-path tests use this so the *client* does not touch the
/// process-global JSON counters they are asserting on.
fn http_raw(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, Vec<(String, String)>, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("read timeout");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("request write");
    read_response_full(&mut stream).expect("response read")
}

/// Bind a server on an ephemeral port and run it on its own thread.
fn spawn_server(
    store: &std::path::Path,
    shard: ShardSpec,
    poll_ms: u64,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    spawn_server_tuned(store, shard, poll_ms, 2, usize::MAX, 0)
}

/// [`spawn_server`] with the backpressure/cancellation knobs exposed.
fn spawn_server_tuned(
    store: &std::path::Path,
    shard: ShardSpec,
    poll_ms: u64,
    workers: usize,
    queue_cap: usize,
    synthetic_delay_ms: u64,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".into(),
        workers,
        store_dir: store.to_path_buf(),
        shard,
        runner: CellRunner::Synthetic,
        poll_ms,
        queue_cap,
        synthetic_delay_ms,
    })
    .expect("bind");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run().expect("server run"));
    (addr, handle)
}

/// Poll a job's status until `complete` (30s deadline).  A 404 is
/// tolerated while polling: a sibling shard may not have discovered
/// the job file yet (its poller runs on a cadence).
fn wait_complete(addr: SocketAddr, job: &str) -> Value {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, doc) = http(addr, "GET", &format!("/jobs/{job}"), "");
        if status == 200 && doc.get("complete").and_then(|c| c.as_bool()) == Some(true) {
            return doc;
        }
        assert!(
            status == 200 || status == 404,
            "status poll failed ({status}): {doc}"
        );
        assert!(
            Instant::now() < deadline,
            "job {job} did not complete in 30s: {doc}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

const GRID: &str = "g:{hindsight,current,tqt}:8";
const SUBMIT: &str =
    r#"{"grid":"g:{hindsight,current,tqt}:8","model":"mlp","seeds":[1,2],"steps":6}"#;

/// The reference: the same grid run serially through the executor with
/// the same synthetic runner, rows aggregated by `grid_rows`.
fn serial_reference() -> (Vec<String>, Vec<String>) {
    let mut base = TrainConfig::new("mlp");
    base.steps = 6;
    let cells = GridSpec::new(GRID, &[1, 2]).expect("grid").expand(&base);
    let runs = run_cells_serial_with(&cells, &GridOptions::serial(), |cell| {
        Ok(synthetic_cell_record(cell))
    });
    let rows = grid_rows(&runs)
        .iter()
        .map(|row| row.to_json().to_string())
        .collect();
    let records = runs
        .iter()
        .map(|run| run.outcome.record().expect("ran").to_json().to_string())
        .collect();
    (rows, records)
}

/// Pull `(rows, records)` out of a `/jobs/<id>/results` document in
/// the serializer's canonical string form for bit-exact comparison.
fn results_strings(doc: &Value) -> (Vec<String>, Vec<String>) {
    let rows = doc
        .get("rows")
        .and_then(|r| r.as_array())
        .expect("rows")
        .iter()
        .map(|r| r.to_string())
        .collect();
    let records = doc
        .get("cells")
        .and_then(|c| c.as_array())
        .expect("cells")
        .iter()
        .map(|c| c.get("record").expect("record").to_string())
        .collect();
    (rows, records)
}

#[test]
fn serve_end_to_end_matches_serial_and_resubmission_is_cached() {
    let _serial = serial();
    let store = tmp_dir("e2e");
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 500);

    let (status, health) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert_eq!(health.get("shard").and_then(|s| s.as_str()), Some("0/1"));

    // submit: 202 on first sight, with the full status document
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();
    assert_eq!(doc.get("total").and_then(|t| t.as_usize()), Some(6));

    let done = wait_complete(addr, &job);
    assert_eq!(done.get("done").and_then(|d| d.as_usize()), Some(6));
    assert_eq!(done.get("failed").and_then(|f| f.as_usize()), Some(0));
    assert_eq!(
        done.get("executed").and_then(|e| e.as_usize()),
        Some(6),
        "all 6 cells must have been executed, none cache-served: {done}"
    );

    // results: bit-identical to the serial executor run of the grid
    let (status, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
    assert_eq!(status, 200, "{results}");
    let (rows, records) = results_strings(&results);
    let (ref_rows, ref_records) = serial_reference();
    assert_eq!(rows, ref_rows, "service rows must match the serial run bit-for-bit");
    assert_eq!(records, ref_records, "per-cell records must match bit-for-bit");

    // resubmission: same id (idempotent), 200, nothing new executed
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 200, "known job resubmission: {doc}");
    assert_eq!(doc.get("job").and_then(|j| j.as_str()), Some(job.as_str()));
    assert_eq!(doc.get("executed").and_then(|e| e.as_usize()), Some(6));

    // the cache surface: /cells lists all six store entries
    let (status, cells) = http(addr, "GET", "/cells", "");
    assert_eq!(status, 200);
    assert_eq!(cells.get("count").and_then(|c| c.as_usize()), Some(6));

    // graceful drain
    let (status, bye) = http(addr, "POST", "/shutdown", "{}");
    assert_eq!(status, 200);
    assert_eq!(bye.get("drain").and_then(|d| d.as_bool()), Some(true));
    handle.join().expect("server thread");

    // a fresh server over the same store serves the whole job from
    // cache: complete immediately, zero cells executed
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 500);
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert!(status == 200 || status == 202, "{doc}");
    let done = wait_complete(addr, &job);
    assert_eq!(done.get("cached").and_then(|c| c.as_usize()), Some(6));
    assert_eq!(
        done.get("executed").and_then(|e| e.as_usize()),
        Some(0),
        "resubmission over a warm store must serve 100% cached cells: {done}"
    );
    let (_, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
    assert_eq!(results_strings(&results), serial_reference());
    let _ = http(addr, "POST", "/shutdown", "{}");
    handle.join().expect("second server thread");
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn two_shards_partition_the_grid_and_merge_bit_identically() {
    let _serial = serial();
    let store = tmp_dir("shards");
    let shard0 = ShardSpec::parse("0/2").unwrap();
    let shard1 = ShardSpec::parse("1/2").unwrap();
    // fast polling so shard 1 discovers the job file promptly
    let (addr0, handle0) = spawn_server(&store, shard0, 50);
    let (addr1, handle1) = spawn_server(&store, shard1, 50);

    // submit to shard 0 ONLY — shard 1 must pick the job up from the
    // shared jobs directory with no further coordination
    let (status, doc) = http(addr0, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();

    // both shards converge: claimed cells ran locally, foreign cells
    // observed through the store
    let done0 = wait_complete(addr0, &job);
    let done1 = wait_complete(addr1, &job);

    // the partition: 6 cells, indices 0,2,4 -> shard 0 and 1,3,5 ->
    // shard 1 (index % 2); each shard executed exactly its claim and
    // observed the other's cells as store completions
    for (doc, shard) in [(&done0, shard0), (&done1, shard1)] {
        let claimed = (0..6).filter(|&i| shard.claims(i)).count();
        assert_eq!(doc.get("claimed").and_then(|c| c.as_usize()), Some(claimed), "{doc}");
        assert_eq!(doc.get("ran").and_then(|r| r.as_usize()), Some(claimed), "{doc}");
        assert_eq!(doc.get("stored").and_then(|s| s.as_usize()), Some(6 - claimed), "{doc}");
        assert_eq!(doc.get("executed").and_then(|e| e.as_usize()), Some(claimed), "{doc}");
        assert_eq!(doc.get("failed").and_then(|f| f.as_usize()), Some(0), "{doc}");
    }
    // disjoint + total: executed counts sum to the whole grid
    let executed: usize = [&done0, &done1]
        .iter()
        .map(|d| d.get("executed").and_then(|e| e.as_usize()).unwrap())
        .sum();
    assert_eq!(executed, 6, "shards must split the grid without overlap");

    // the acceptance pin: merged results from either shard are
    // bit-identical to one serial run of the same grid
    let reference = serial_reference();
    for addr in [addr0, addr1] {
        let (status, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
        assert_eq!(status, 200, "{results}");
        assert_eq!(results_strings(&results), reference);
    }

    for addr in [addr0, addr1] {
        let _ = http(addr, "POST", "/shutdown", "{}");
    }
    handle0.join().expect("shard 0");
    handle1.join().expect("shard 1");
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn protocol_errors_are_clean() {
    let _serial = serial();
    let store = tmp_dir("errors");
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 500);

    let (status, doc) = http(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    assert!(doc.get("error").is_some(), "{doc}");

    let (status, doc) = http(addr, "POST", "/jobs", "{not json");
    assert_eq!(status, 400);
    assert!(doc.get("error").is_some(), "{doc}");

    // a structurally-valid body with a broken grid template
    let (status, doc) = http(addr, "POST", "/jobs", r#"{"grid":"g:{unclosed"}"#);
    assert_eq!(status, 400, "{doc}");

    // results for a submitted-but-incomplete job would be 409; for an
    // unknown job it is a plain 404
    let (status, _) = http(addr, "GET", "/jobs/does-not-exist/results", "");
    assert_eq!(status, 404);

    // abort shutdown: immediate, no drain
    let (status, bye) = http(addr, "POST", "/shutdown", r#"{"drain":false}"#);
    assert_eq!(status, 200);
    assert_eq!(bye.get("drain").and_then(|d| d.as_bool()), Some(false));
    handle.join().expect("server thread");
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn warm_results_reuse_bytes_with_zero_json_work() {
    let _serial = serial();
    let store = tmp_dir("warm");
    // short poll is fine: the poller does no JSON work for job ids it
    // already knows, so it cannot disturb the counter snapshots below
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 100);
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();
    wait_complete(addr, &job);

    // cold GET: assembles the body (cell docs parse once, rows
    // serialize once) and seeds the per-job results cache
    let (status, _, cold) = http_raw(addr, "GET", &format!("/jobs/{job}/results"), "");
    assert_eq!(status, 200);

    // the assembled body is the canonical serialization: re-parsing
    // and re-serializing it reproduces the exact bytes the old
    // tree-building implementation would have sent
    let cold_text = std::str::from_utf8(&cold).expect("utf8 body");
    let reparsed = json::parse(cold_text.trim()).expect("cold body parses");
    assert_eq!(
        format!("{reparsed}\n").as_bytes(),
        &cold[..],
        "spliced body must equal the canonical tree serialization"
    );
    // ... and its rows/records still match the serial reference
    assert_eq!(results_strings(&reparsed), serial_reference());

    // warm GETs: identical bytes, zero JSON parses, zero tree
    // serializations anywhere in the process (the client reads raw)
    let parses = json::count::parses();
    let serializes = json::count::serializes();
    for _ in 0..3 {
        let (status, _, warm) = http_raw(addr, "GET", &format!("/jobs/{job}/results"), "");
        assert_eq!(status, 200);
        assert_eq!(warm, cold, "warm results must be byte-identical to the cold assembly");
    }
    assert_eq!(json::count::parses(), parses, "warm GETs must parse nothing");
    assert_eq!(json::count::serializes(), serializes, "warm GETs must serialize no tree");

    // the instrumented server agrees: one cold assembly, three warm
    // hits, six documents parsed (one per cell file), none re-parsed
    let (status, health) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(health.get("results_cold").and_then(|v| v.as_usize()), Some(1), "{health}");
    assert_eq!(health.get("results_warm").and_then(|v| v.as_usize()), Some(3), "{health}");
    assert_eq!(health.get("doc_parses").and_then(|v| v.as_usize()), Some(6), "{health}");
    assert!(
        health.get("doc_hits").and_then(|v| v.as_usize()).unwrap_or(0) >= 6,
        "warm GETs must be served from the doc cache: {health}"
    );

    let _ = http(addr, "POST", "/shutdown", "{}");
    handle.join().expect("server thread");
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn full_queue_rejects_submissions_with_429_and_retry_after() {
    let _serial = serial();
    let store = tmp_dir("flood");
    // capacity 4 < the 6-cell grid: the submission can never queue
    let (addr, handle) = spawn_server_tuned(&store, ShardSpec::solo(), 100, 2, 4, 0);

    let (status, health) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(health.get("queue_cap").and_then(|v| v.as_usize()), Some(4), "{health}");

    // flood: every oversized submission is refused, never queued
    for _ in 0..5 {
        let (status, headers, body) = http_raw(addr, "POST", "/jobs", SUBMIT);
        assert_eq!(status, 429, "{}", String::from_utf8_lossy(&body));
        assert!(
            headers.iter().any(|(k, _)| k == "retry-after"),
            "429 must carry Retry-After: {headers:?}"
        );
        let doc = json::parse(std::str::from_utf8(&body).unwrap().trim()).unwrap();
        assert!(
            doc.get("error").and_then(|e| e.as_str()).unwrap_or("").contains("queue full"),
            "{doc}"
        );
    }
    // a refused job leaves no trace: not registered, not persisted
    let (status, jobs) = http(addr, "GET", "/jobs", "");
    assert_eq!(status, 200);
    assert_eq!(jobs.get("count").and_then(|c| c.as_usize()), Some(0), "{jobs}");
    let job_files = std::fs::read_dir(store.join("jobs"))
        .map(|rd| rd.filter_map(|e| e.ok()).count())
        .unwrap_or(0);
    assert_eq!(job_files, 0, "refused submissions must not persist job files");

    // a job that fits the bound still sails through
    let small = r#"{"grid":"g:{hindsight,current,tqt}:8","model":"mlp","seeds":[1],"steps":6}"#;
    let (status, doc) = http(addr, "POST", "/jobs", small);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();
    let done = wait_complete(addr, &job);
    assert_eq!(done.get("done").and_then(|d| d.as_usize()), Some(3));

    let _ = http(addr, "POST", "/shutdown", "{}");
    handle.join().expect("server thread");
    let _ = std::fs::remove_dir_all(&store);
}

/// Acceptance pin (fuzz PR): adversarial submissions — a seed-range
/// bomb and a brace bomb — come back as clean 400s naming the cap.
/// The memory bound itself is unit-tested at the cap checks
/// (`grid::tests`): both rejections happen before any expansion
/// allocation, so the server never holds the bomb in memory.
#[test]
fn adversarial_submissions_are_rejected_with_400() {
    let _serial = serial();
    let store = tmp_dir("advsubmit");
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 500);

    // seed-range bomb: 4 billion seeds in one token
    let (status, doc) = http(
        addr,
        "POST",
        "/jobs",
        r#"{"grid":"g:hindsight:8","seeds":"0..4000000000"}"#,
    );
    assert_eq!(status, 400, "{doc}");
    let err = doc.get("error").and_then(|e| e.as_str()).unwrap_or("");
    assert!(err.contains("MAX_SEEDS"), "error must name the cap: {doc}");

    // brace bomb: ten 10-way alternations = 10^10 expansions
    let bomb = format!(
        r#"{{"grid":"g:{}:8"}}"#,
        "{0,1,2,3,4,5,6,7,8,9}".repeat(10)
    );
    let (status, doc) = http(addr, "POST", "/jobs", &bomb);
    assert_eq!(status, 400, "{doc}");
    let err = doc.get("error").and_then(|e| e.as_str()).unwrap_or("");
    assert!(err.contains("MAX_EXPANSIONS"), "error must name the cap: {doc}");

    // numeric seeds past 2^53 are rejected toward the string form,
    // not silently rounded
    let (status, doc) = http(
        addr,
        "POST",
        "/jobs",
        r#"{"grid":"g:hindsight:8","seeds":[9007199254740993]}"#,
    );
    assert_eq!(status, 400, "{doc}");
    let err = doc.get("error").and_then(|e| e.as_str()).unwrap_or("");
    assert!(err.contains("2^53"), "error must explain the precision rule: {doc}");

    // nothing registered, nothing persisted, server still healthy
    let (status, jobs) = http(addr, "GET", "/jobs", "");
    assert_eq!(status, 200);
    assert_eq!(jobs.get("count").and_then(|c| c.as_usize()), Some(0), "{jobs}");
    let (status, health) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(|s| s.as_str()), Some("ok"));

    let _ = http(addr, "POST", "/shutdown", "{}");
    handle.join().expect("server thread");
    let _ = std::fs::remove_dir_all(&store);
}

/// Acceptance pin (fuzz PR): seeds past 2^53 survive the whole
/// cross-shard path exactly — job file persisted by shard 0, picked up
/// by shard 1, both expanding identical cell keys, store files keyed
/// by the exact seed.  The old float-array job serialization rounded
/// these and sibling shards re-expanded *different* grids.
#[test]
fn huge_seeds_cross_shards_exactly() {
    let _serial = serial();
    let store = tmp_dir("hugeseeds");
    let shard0 = ShardSpec::parse("0/2").unwrap();
    let shard1 = ShardSpec::parse("1/2").unwrap();
    let (addr0, handle0) = spawn_server(&store, shard0, 50);
    let (addr1, handle1) = spawn_server(&store, shard1, 50);

    const P53P1: &str = "9007199254740993"; // 2^53 + 1
    const UMAX: &str = "18446744073709551615"; // u64::MAX
    let submit = format!(
        r#"{{"grid":"g:hindsight:8","seeds":"{P53P1},{UMAX}","steps":6}}"#
    );
    // submit to shard 0 only; shard 1 must re-expand from the job file
    let (status, doc) = http(addr0, "POST", "/jobs", &submit);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();
    assert_eq!(doc.get("total").and_then(|t| t.as_usize()), Some(2), "{doc}");

    // the persisted job file carries the seeds losslessly (the exact
    // decimal strings, not a rounded float array)
    let job_file = store.join("jobs").join(format!("job-{job}.json"));
    let text = std::fs::read_to_string(&job_file).expect("job file");
    assert!(text.contains(P53P1) && text.contains(UMAX), "{text}");
    assert!(
        !text.contains("9007199254740992"),
        "rounded 2^53 neighbor must not appear: {text}"
    );

    // both shards converge on the same two cells: one ran locally on
    // each, the other observed through the store
    let done0 = wait_complete(addr0, &job);
    let done1 = wait_complete(addr1, &job);
    for doc in [&done0, &done1] {
        assert_eq!(doc.get("done").and_then(|d| d.as_usize()), Some(2), "{doc}");
        assert_eq!(doc.get("ran").and_then(|r| r.as_usize()), Some(1), "{doc}");
        assert_eq!(doc.get("stored").and_then(|s| s.as_usize()), Some(1), "{doc}");
        assert_eq!(doc.get("failed").and_then(|f| f.as_usize()), Some(0), "{doc}");
    }

    // results are served by both shards with the exact seed labels
    for addr in [addr0, addr1] {
        let (status, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
        assert_eq!(status, 200, "{results}");
        let text = results.to_string();
        assert!(text.contains(P53P1) && text.contains(UMAX), "{text}");
    }

    // the store keys the cells by the exact seeds: each appears in
    // exactly one persisted cell file, in the lossless string form
    let mut hits = (0usize, 0usize);
    for entry in std::fs::read_dir(&store).expect("store dir") {
        let path = entry.expect("entry").path();
        let name = path.file_name().unwrap_or_default().to_string_lossy().into_owned();
        if !(name.starts_with("cell-") && name.ends_with(".json")) {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap_or_default();
        if text.contains(P53P1) {
            hits.0 += 1;
        }
        if text.contains(UMAX) {
            hits.1 += 1;
        }
    }
    assert_eq!(hits, (1, 1), "each huge seed keys exactly one cell file");

    for addr in [addr0, addr1] {
        let _ = http(addr, "POST", "/shutdown", "{}");
    }
    handle0.join().expect("shard 0");
    handle1.join().expect("shard 1");
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn cancel_drops_queued_cells_but_running_cells_finish() {
    let _serial = serial();
    let store = tmp_dir("cancel");
    // one worker, 200ms per synthetic cell: at cancel time one cell is
    // in flight and the rest are still queued
    let (addr, handle) = spawn_server_tuned(&store, ShardSpec::solo(), 100, 1, usize::MAX, 200);

    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();

    let (status, doc) = http(addr, "POST", &format!("/jobs/{job}/cancel"), "");
    assert_eq!(status, 200, "{doc}");
    let cancelled = doc.get("cancelled").and_then(|c| c.as_usize()).expect("cancelled count");
    assert!(cancelled >= 4, "most of the 6 cells must still be queued at cancel: {doc}");

    // running cells finish; the job settles with nothing queued
    let deadline = Instant::now() + Duration::from_secs(30);
    let settled = loop {
        let (status, doc) = http(addr, "GET", &format!("/jobs/{job}"), "");
        assert_eq!(status, 200, "{doc}");
        let queued = doc.get("queued").and_then(|q| q.as_usize()).unwrap_or(9);
        let running = doc.get("running").and_then(|r| r.as_usize()).unwrap_or(9);
        if queued == 0 && running == 0 {
            break doc;
        }
        assert!(Instant::now() < deadline, "cancelled job did not settle: {doc}");
        std::thread::sleep(Duration::from_millis(50));
    };
    let ran = settled.get("ran").and_then(|r| r.as_usize()).unwrap_or(0);
    let cancelled = settled.get("cancelled").and_then(|c| c.as_usize()).unwrap_or(0);
    assert_eq!(ran + cancelled, 6, "every cell ends ran-or-cancelled: {settled}");
    assert!(cancelled >= 4, "{settled}");
    assert_eq!(
        settled.get("complete").and_then(|c| c.as_bool()),
        Some(false),
        "a cancelled job never reaches complete: {settled}"
    );

    // results stay 409 (incomplete), and the job file is gone so
    // neither a restart nor a sibling shard resurrects the work
    let (status, _, _) = http_raw(addr, "GET", &format!("/jobs/{job}/results"), "");
    assert_eq!(status, 409);
    assert!(
        !store.join("jobs").join(format!("job-{job}.json")).exists(),
        "cancel must remove the persisted job file"
    );

    // cancelling an unknown job is a clean 404
    let (status, _) = http(addr, "POST", "/jobs/does-not-exist/cancel", "");
    assert_eq!(status, 404);

    let _ = http(addr, "POST", "/shutdown", "{}");
    handle.join().expect("server thread");
    let _ = std::fs::remove_dir_all(&store);
}

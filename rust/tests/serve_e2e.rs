//! End-to-end sweep-service tests over real TCP.
//!
//! The service is exercised exactly as a client would: bind an
//! ephemeral port, submit grids over a socket, poll status, fetch
//! results — then pin the acceptance invariants: service results are
//! bit-identical to a serial run of the same grid, resubmission serves
//! 100% cached cells, and two shards over one store partition the grid
//! disjointly while their merged results still match the serial run.
//!
//! Everything runs the synthetic cell runner (no artifacts needed);
//! the synthetic record convention is shared with the server
//! (`synthetic_cell_record`), which is what makes bit-identity
//! checkable here.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use hindsight::coordinator::executor::run_cells_serial_with;
use hindsight::coordinator::{grid_rows, GridOptions, GridSpec, TrainConfig};
use hindsight::service::protocol::read_response;
use hindsight::service::{synthetic_cell_record, CellRunner, ServeOptions, Server, ShardSpec};
use hindsight::util::json::{self, Value};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hindsight_serve_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// One HTTP request over a fresh connection; returns (status, JSON).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, Value) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .expect("read timeout");
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .expect("request write");
    let (status, bytes) = read_response(&mut stream).expect("response read");
    let text = String::from_utf8(bytes).expect("utf8 body");
    let value = json::parse(text.trim()).unwrap_or_else(|e| panic!("bad body '{text}': {e}"));
    (status, value)
}

/// Bind a server on an ephemeral port and run it on its own thread.
fn spawn_server(
    store: &std::path::Path,
    shard: ShardSpec,
    poll_ms: u64,
) -> (SocketAddr, std::thread::JoinHandle<()>) {
    let server = Server::bind(ServeOptions {
        addr: "127.0.0.1:0".into(),
        workers: 2,
        store_dir: store.to_path_buf(),
        shard,
        runner: CellRunner::Synthetic,
        poll_ms,
    })
    .expect("bind");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run().expect("server run"));
    (addr, handle)
}

/// Poll a job's status until `complete` (30s deadline).  A 404 is
/// tolerated while polling: a sibling shard may not have discovered
/// the job file yet (its poller runs on a cadence).
fn wait_complete(addr: SocketAddr, job: &str) -> Value {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let (status, doc) = http(addr, "GET", &format!("/jobs/{job}"), "");
        if status == 200 && doc.get("complete").and_then(|c| c.as_bool()) == Some(true) {
            return doc;
        }
        assert!(
            status == 200 || status == 404,
            "status poll failed ({status}): {doc}"
        );
        assert!(
            Instant::now() < deadline,
            "job {job} did not complete in 30s: {doc}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

const GRID: &str = "g:{hindsight,current,tqt}:8";
const SUBMIT: &str =
    r#"{"grid":"g:{hindsight,current,tqt}:8","model":"mlp","seeds":[1,2],"steps":6}"#;

/// The reference: the same grid run serially through the executor with
/// the same synthetic runner, rows aggregated by `grid_rows`.
fn serial_reference() -> (Vec<String>, Vec<String>) {
    let mut base = TrainConfig::new("mlp");
    base.steps = 6;
    let cells = GridSpec::new(GRID, &[1, 2]).expect("grid").expand(&base);
    let runs = run_cells_serial_with(&cells, &GridOptions::serial(), |cell| {
        Ok(synthetic_cell_record(cell))
    });
    let rows = grid_rows(&runs)
        .iter()
        .map(|row| row.to_json().to_string())
        .collect();
    let records = runs
        .iter()
        .map(|run| run.outcome.record().expect("ran").to_json().to_string())
        .collect();
    (rows, records)
}

/// Pull `(rows, records)` out of a `/jobs/<id>/results` document in
/// the serializer's canonical string form for bit-exact comparison.
fn results_strings(doc: &Value) -> (Vec<String>, Vec<String>) {
    let rows = doc
        .get("rows")
        .and_then(|r| r.as_array())
        .expect("rows")
        .iter()
        .map(|r| r.to_string())
        .collect();
    let records = doc
        .get("cells")
        .and_then(|c| c.as_array())
        .expect("cells")
        .iter()
        .map(|c| c.get("record").expect("record").to_string())
        .collect();
    (rows, records)
}

#[test]
fn serve_end_to_end_matches_serial_and_resubmission_is_cached() {
    let store = tmp_dir("e2e");
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 500);

    let (status, health) = http(addr, "GET", "/healthz", "");
    assert_eq!(status, 200);
    assert_eq!(health.get("status").and_then(|s| s.as_str()), Some("ok"));
    assert_eq!(health.get("shard").and_then(|s| s.as_str()), Some("0/1"));

    // submit: 202 on first sight, with the full status document
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();
    assert_eq!(doc.get("total").and_then(|t| t.as_usize()), Some(6));

    let done = wait_complete(addr, &job);
    assert_eq!(done.get("done").and_then(|d| d.as_usize()), Some(6));
    assert_eq!(done.get("failed").and_then(|f| f.as_usize()), Some(0));
    assert_eq!(
        done.get("executed").and_then(|e| e.as_usize()),
        Some(6),
        "all 6 cells must have been executed, none cache-served: {done}"
    );

    // results: bit-identical to the serial executor run of the grid
    let (status, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
    assert_eq!(status, 200, "{results}");
    let (rows, records) = results_strings(&results);
    let (ref_rows, ref_records) = serial_reference();
    assert_eq!(rows, ref_rows, "service rows must match the serial run bit-for-bit");
    assert_eq!(records, ref_records, "per-cell records must match bit-for-bit");

    // resubmission: same id (idempotent), 200, nothing new executed
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 200, "known job resubmission: {doc}");
    assert_eq!(doc.get("job").and_then(|j| j.as_str()), Some(job.as_str()));
    assert_eq!(doc.get("executed").and_then(|e| e.as_usize()), Some(6));

    // the cache surface: /cells lists all six store entries
    let (status, cells) = http(addr, "GET", "/cells", "");
    assert_eq!(status, 200);
    assert_eq!(cells.get("count").and_then(|c| c.as_usize()), Some(6));

    // graceful drain
    let (status, bye) = http(addr, "POST", "/shutdown", "{}");
    assert_eq!(status, 200);
    assert_eq!(bye.get("drain").and_then(|d| d.as_bool()), Some(true));
    handle.join().expect("server thread");

    // a fresh server over the same store serves the whole job from
    // cache: complete immediately, zero cells executed
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 500);
    let (status, doc) = http(addr, "POST", "/jobs", SUBMIT);
    assert!(status == 200 || status == 202, "{doc}");
    let done = wait_complete(addr, &job);
    assert_eq!(done.get("cached").and_then(|c| c.as_usize()), Some(6));
    assert_eq!(
        done.get("executed").and_then(|e| e.as_usize()),
        Some(0),
        "resubmission over a warm store must serve 100% cached cells: {done}"
    );
    let (_, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
    assert_eq!(results_strings(&results), serial_reference());
    let _ = http(addr, "POST", "/shutdown", "{}");
    handle.join().expect("second server thread");
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn two_shards_partition_the_grid_and_merge_bit_identically() {
    let store = tmp_dir("shards");
    let shard0 = ShardSpec::parse("0/2").unwrap();
    let shard1 = ShardSpec::parse("1/2").unwrap();
    // fast polling so shard 1 discovers the job file promptly
    let (addr0, handle0) = spawn_server(&store, shard0, 50);
    let (addr1, handle1) = spawn_server(&store, shard1, 50);

    // submit to shard 0 ONLY — shard 1 must pick the job up from the
    // shared jobs directory with no further coordination
    let (status, doc) = http(addr0, "POST", "/jobs", SUBMIT);
    assert_eq!(status, 202, "{doc}");
    let job = doc.get("job").and_then(|j| j.as_str()).expect("job id").to_string();

    // both shards converge: claimed cells ran locally, foreign cells
    // observed through the store
    let done0 = wait_complete(addr0, &job);
    let done1 = wait_complete(addr1, &job);

    // the partition: 6 cells, indices 0,2,4 -> shard 0 and 1,3,5 ->
    // shard 1 (index % 2); each shard executed exactly its claim and
    // observed the other's cells as store completions
    for (doc, shard) in [(&done0, shard0), (&done1, shard1)] {
        let claimed = (0..6).filter(|&i| shard.claims(i)).count();
        assert_eq!(doc.get("claimed").and_then(|c| c.as_usize()), Some(claimed), "{doc}");
        assert_eq!(doc.get("ran").and_then(|r| r.as_usize()), Some(claimed), "{doc}");
        assert_eq!(doc.get("stored").and_then(|s| s.as_usize()), Some(6 - claimed), "{doc}");
        assert_eq!(doc.get("executed").and_then(|e| e.as_usize()), Some(claimed), "{doc}");
        assert_eq!(doc.get("failed").and_then(|f| f.as_usize()), Some(0), "{doc}");
    }
    // disjoint + total: executed counts sum to the whole grid
    let executed: usize = [&done0, &done1]
        .iter()
        .map(|d| d.get("executed").and_then(|e| e.as_usize()).unwrap())
        .sum();
    assert_eq!(executed, 6, "shards must split the grid without overlap");

    // the acceptance pin: merged results from either shard are
    // bit-identical to one serial run of the same grid
    let reference = serial_reference();
    for addr in [addr0, addr1] {
        let (status, results) = http(addr, "GET", &format!("/jobs/{job}/results"), "");
        assert_eq!(status, 200, "{results}");
        assert_eq!(results_strings(&results), reference);
    }

    for addr in [addr0, addr1] {
        let _ = http(addr, "POST", "/shutdown", "{}");
    }
    handle0.join().expect("shard 0");
    handle1.join().expect("shard 1");
    let _ = std::fs::remove_dir_all(&store);
}

#[test]
fn protocol_errors_are_clean() {
    let store = tmp_dir("errors");
    let (addr, handle) = spawn_server(&store, ShardSpec::solo(), 500);

    let (status, doc) = http(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    assert!(doc.get("error").is_some(), "{doc}");

    let (status, doc) = http(addr, "POST", "/jobs", "{not json");
    assert_eq!(status, 400);
    assert!(doc.get("error").is_some(), "{doc}");

    // a structurally-valid body with a broken grid template
    let (status, doc) = http(addr, "POST", "/jobs", r#"{"grid":"g:{unclosed"}"#);
    assert_eq!(status, 400, "{doc}");

    // results for a submitted-but-incomplete job would be 409; for an
    // unknown job it is a plain 404
    let (status, _) = http(addr, "GET", "/jobs/does-not-exist/results", "");
    assert_eq!(status, 404);

    // abort shutdown: immediate, no drain
    let (status, bye) = http(addr, "POST", "/shutdown", r#"{"drain":false}"#);
    assert_eq!(status, 200);
    assert_eq!(bye.get("drain").and_then(|d| d.as_bool()), Some(false));
    handle.join().expect("server thread");
    let _ = std::fs::remove_dir_all(&store);
}

//! Stable-toolchain replay of the fuzz surfaces.
//!
//! The cargo-fuzz targets in `fuzz/` are one-line wrappers around
//! `util::fuzzing::check_*`; this test replays the checked-in corpus
//! through the same bodies and runs bounded property loops over the
//! grammar-shaped generators, so tier-1 CI exercises every harness
//! without nightly or libFuzzer.  A crash cargo-fuzz shrinks becomes a
//! permanent regression by dropping its input into
//! `fuzz/corpus/<target>/` — this test picks it up automatically.

use std::fs;
use std::path::PathBuf;

use hindsight::util::fuzzing::{
    check_grid_expansion, check_json_differential, check_scheme_roundtrip,
    check_service_request, gen,
};
use hindsight::util::testkit::{default_cases, forall};

fn corpus_dir(target: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("fuzz/corpus")
        .join(target)
}

/// Every file under `fuzz/corpus/<target>/`, with the acceptance floor
/// of three seeds per target enforced.
fn corpus(target: &str) -> Vec<(String, Vec<u8>)> {
    let dir = corpus_dir(target);
    let mut files: Vec<(String, Vec<u8>)> = fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {} unreadable: {e}", dir.display()))
        .filter_map(|entry| {
            let entry = entry.ok()?;
            if !entry.file_type().ok()?.is_file() {
                return None;
            }
            let name = entry.file_name().to_string_lossy().into_owned();
            Some((name, fs::read(entry.path()).ok()?))
        })
        .collect();
    files.sort_by(|a, b| a.0.cmp(&b.0));
    assert!(
        files.len() >= 3,
        "target '{target}' needs at least 3 corpus seeds, found {}",
        files.len()
    );
    files
}

#[test]
fn corpus_replays_clean_through_every_harness() {
    for (target, check) in [
        ("fuzz_scheme", check_scheme_roundtrip as fn(&[u8])),
        ("fuzz_grid", check_grid_expansion),
        ("fuzz_json", check_json_differential),
        ("fuzz_service", check_service_request),
    ] {
        for (name, bytes) in corpus(target) {
            // a panic here names the corpus file that regressed
            let caught = std::panic::catch_unwind(|| check(&bytes));
            assert!(caught.is_ok(), "corpus file {target}/{name} regressed");
        }
    }
}

/// The shrunk originals of the fixed bugs, pinned inline so the history
/// survives even if the corpus is re-seeded.
#[test]
fn shrunk_crash_inputs_stay_fixed() {
    // DoS: unbounded seed-range materialization (grid + service)
    check_grid_expansion(b"g:hindsight:8\n0..4000000000");
    check_grid_expansion(b"g:hindsight:8\n0..18446744073709551615");
    // DoS: brace-bomb cartesian product
    let bomb = format!("{}\n1", "{0,1,2,3,4,5,6,7,8,9}".repeat(10));
    check_grid_expansion(bomb.as_bytes());
    // stack overflow: thousands of brace groups in the old recursive
    // expander
    let deep = format!("{}\n1", "{a}".repeat(10_000));
    check_grid_expansion(deep.as_bytes());
    // divergence: "1e999" parsed to inf, serialized to "inf", and the
    // serialize -> reparse property broke
    check_json_differential(b"[1e999]");
    check_json_differential(b"{\"n\":2e400}");
    // overflow: a Content-Length past usize
    check_service_request(
        b"POST / HTTP/1.1\r\nContent-Length: 99999999999999999999999999\r\n\r\n",
    );
    // precision loss: numeric seeds past 2^53 silently rounded through
    // f64 in the job path
    check_service_request(
        b"POST /jobs HTTP/1.1\r\nContent-Length: 55\r\n\r\n\
          {\"grid\":\"g:hindsight:8\",\"seeds\":[18446744073709551615]}",
    );
}

#[test]
fn scheme_property_loop() {
    forall(
        default_cases(),
        "fuzz-scheme",
        |rng| gen::scheme_string(rng),
        |s| {
            check_scheme_roundtrip(s.as_bytes());
            true
        },
    );
}

#[test]
fn grid_property_loop() {
    forall(
        default_cases(),
        "fuzz-grid",
        |rng| gen::grid_input(rng),
        |s| {
            check_grid_expansion(s.as_bytes());
            true
        },
    );
}

#[test]
fn json_property_loop() {
    forall(
        default_cases(),
        "fuzz-json",
        |rng| gen::json_text(rng),
        |s| {
            check_json_differential(s.as_bytes());
            true
        },
    );
}

#[test]
fn service_property_loop() {
    forall(
        default_cases(),
        "fuzz-service",
        |rng| gen::http_request(rng),
        |req| {
            check_service_request(req);
            true
        },
    );
}

//! Differential conformance: the bytes-backed lazy parser
//! (`json::RawDoc`) against the owned tree parser (`json::parse`).
//!
//! The serve-many read path trusts `RawDoc` to be bit-compatible with
//! the owned parser — same accepted grammar, same rejections (message
//! and byte position), same decoded values, and source spans that
//! re-parse to the exact subtree.  These properties pin that contract
//! over adversarially generated documents and garbage.

use hindsight::metrics::RunRecord;
use hindsight::util::json::{self, RawDoc, RawRef, Value, MAX_DEPTH};
use hindsight::util::rng::Pcg32;
use hindsight::util::testkit::{default_cases, forall};

/// Strings biased toward the serializer's escape set (backslashes,
/// quotes, control bytes) plus multi-byte UTF-8, so both the borrowed
/// and the copy-on-escape string paths are exercised.
fn gen_string(rng: &mut Pcg32) -> String {
    const PIECES: &[&str] = &[
        "a", "cell", "0", " ", "β", "𝕏", "❤", "\"", "\\", "\n", "\t", "\r", "\u{1}", "\u{1f}",
        "\u{7f}", "e+", "-", "ñ",
    ];
    let n = rng.below(8);
    (0..n).map(|_| PIECES[rng.below(PIECES.len())]).collect()
}

/// Finite numbers across the serializer's regimes: integral shortening
/// (|x| < 1e15), float `Display`, negative zero, subnormals, and the
/// integer-accessor boundaries (2^53, 2^63 neighborhood).
fn gen_num(rng: &mut Pcg32) -> f64 {
    match rng.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => rng.below(100_000) as f64,
        3 => -(rng.below(1000) as f64) - 0.5,
        4 => rng.below(1000) as f64 / 7.0,
        5 => 1e15 + rng.below(100) as f64,
        6 => 9_007_199_254_740_992.0 + rng.below(4) as f64, // 2^53..
        _ => (rng.below(1_000_000) as f64) * 1e-300,        // subnormal-ish
    }
}

fn gen_value(rng: &mut Pcg32, depth: usize) -> Value {
    let pick = if depth == 0 { rng.below(4) } else { rng.below(6) };
    match pick {
        0 => Value::Null,
        1 => Value::Bool(rng.below(2) == 0),
        2 => Value::Num(gen_num(rng)),
        3 => Value::Str(gen_string(rng)),
        4 => Value::Array((0..rng.below(5)).map(|_| gen_value(rng, depth - 1)).collect()),
        _ => Value::Object(
            (0..rng.below(5))
                .map(|i| (format!("{}k{i}", gen_string(rng)), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// Walk both representations in lockstep: every accessor answer and
/// every span's re-parse must agree with the owned subtree.
fn agrees(raw: RawRef<'_>, owned: &Value) -> bool {
    if raw.as_str() != owned.as_str()
        || raw.as_bool() != owned.as_bool()
        || raw.as_i64() != owned.as_i64()
        || raw.as_usize() != owned.as_usize()
    {
        return false;
    }
    match (raw.as_f64(), owned.as_f64()) {
        (Some(a), Some(b)) => {
            if a.to_bits() != b.to_bits() {
                return false;
            }
        }
        (None, None) => {}
        _ => return false,
    }
    // the span must cover a standalone re-parseable form of the node
    let span_text = std::str::from_utf8(raw.raw_bytes()).expect("spans sit on char boundaries");
    match json::parse(span_text) {
        Ok(back) if back == *owned => {}
        _ => return false,
    }
    match owned {
        Value::Array(items) => {
            let raw_items = match raw.items() {
                Some(v) => v,
                None => return false,
            };
            raw_items.len() == items.len()
                && raw_items.iter().zip(items).all(|(r, o)| agrees(*r, o))
        }
        Value::Object(entries) => {
            let raw_entries = match raw.entries() {
                Some(v) => v,
                None => return false,
            };
            raw_entries.len() == entries.len()
                && raw_entries
                    .iter()
                    .zip(entries)
                    .all(|((rk, rv), (ok, ov))| rk == ok && agrees(*rv, ov))
        }
        _ => raw.items().is_none() && raw.entries().is_none(),
    }
}

#[test]
fn prop_raw_doc_matches_owned_parser_on_generated_documents() {
    forall(
        default_cases(),
        "raw_conformance_valid",
        |rng| gen_value(rng, 4),
        |tree| {
            let text = tree.to_string();
            let owned = json::parse(&text).expect("serializer output must re-parse");
            let raw = RawDoc::parse(&text).expect("raw parser must accept the same text");
            owned == *tree && raw.to_value() == owned && agrees(raw.root(), &owned)
        },
    );
}

#[test]
fn prop_raw_doc_rejects_exactly_what_the_owned_parser_rejects() {
    const CHARSET: &[u8] = b"{}[]\",:0123456789.eE+-\\ truefalsn\n\tu00\x7f";
    forall(
        default_cases(),
        "raw_conformance_garbage",
        |rng| {
            let len = rng.below(256);
            (0..len)
                .map(|_| CHARSET[rng.below(CHARSET.len())] as char)
                .collect::<String>()
        },
        |s| match (json::parse(s), RawDoc::parse(s)) {
            (Ok(owned), Ok(raw)) => raw.to_value() == owned,
            // the raw parser mirrors the owned one line for line: the
            // rejection itself must be byte-identical too
            (Err(a), Err(b)) => a.pos == b.pos && a.msg == b.msg,
            _ => false,
        },
    );
}

#[test]
fn copy_on_escape_borrows_plain_strings_only() {
    let doc = RawDoc::parse(r#"{"plain":"cell-abc123","escaped":"a\nbA\\"}"#).unwrap();
    let root = doc.root();
    let plain = root.get("plain").unwrap();
    assert!(plain.is_borrowed_str(), "escape-free strings must borrow from the buffer");
    let s = plain.as_str().unwrap();
    let base = doc.buf().as_ptr() as usize;
    let addr = s.as_ptr() as usize;
    assert!(
        (base..base + doc.buf().len()).contains(&addr),
        "borrowed strings must point into the shared buffer"
    );
    let escaped = root.get("escaped").unwrap();
    assert!(!escaped.is_borrowed_str(), "escapes force materialization");
    assert_eq!(escaped.as_str(), Some("a\nbA\\"));
}

#[test]
fn depth_and_size_budgets_match_the_owned_parser() {
    let nested = |d: usize| format!("{}1{}", "[".repeat(d), "]".repeat(d));
    for d in [MAX_DEPTH - 1, MAX_DEPTH, MAX_DEPTH + 1, 2 * MAX_DEPTH] {
        let text = nested(d);
        assert_eq!(
            json::parse(&text).is_ok(),
            RawDoc::parse(&text).is_ok(),
            "depth {d}: both parsers must agree on the budget"
        );
        assert_eq!(RawDoc::parse(&text).is_ok(), d <= MAX_DEPTH);
    }
}

#[test]
fn run_records_decode_identically_through_both_views() {
    forall(
        default_cases(),
        "raw_conformance_records",
        |rng| {
            let name = format!("mlp-s{}-hindsight-w8a8g8", rng.below(1000));
            RunRecord::synthetic(&name, 1 + rng.below(40) as u64)
        },
        |record| {
            let text = record.to_json().to_string();
            let owned = RunRecord::from_json(&json::parse(&text).unwrap()).unwrap();
            let doc = RawDoc::parse(&text).unwrap();
            let raw = RunRecord::from_raw(doc.root()).unwrap();
            owned == *record && raw == *record
        },
    );
}

#[test]
fn shared_buffer_documents_reuse_the_allocation() {
    let record = RunRecord::synthetic("mlp-s1-hindsight-w8a8g8", 12);
    let text = record.to_json().to_string();
    let buf: std::sync::Arc<[u8]> = std::sync::Arc::from(text.as_bytes());
    let doc = RawDoc::parse_arc(buf.clone()).unwrap();
    assert!(std::sync::Arc::ptr_eq(doc.buf(), &buf), "parse_arc must not copy the input");
    assert_eq!(RunRecord::from_raw(doc.root()).unwrap(), record);
}

//! Integration tests across runtime + coordinator + quant + artifacts.
//!
//! All tests skip gracefully when `artifacts/` has not been built (run
//! `make artifacts` first); CI always builds artifacts before testing.

use hindsight::coordinator::{Estimator, TrainConfig, Trainer};
use hindsight::quant;
use hindsight::runtime::manifest::Manifest;
use hindsight::runtime::{Engine, Tensor};

fn engine() -> Option<Engine> {
    if !Manifest::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::new().unwrap())
}

fn quick(model: &str) -> TrainConfig {
    let mut c = TrainConfig::new(model);
    c.steps = 10;
    c.n_train = 128;
    c.n_val = 64;
    c.calib_batches = 2;
    c
}

/// Cross-layer numeric check: the train graph's per-site `stats` output
/// must equal the min/max of the raw gradient tensors the dump graph
/// returns for the *same* params, batch and seed — i.e. the L2 graph's
/// "accumulator statistics" agree with an independent extraction path,
/// computed in Rust by the L3 quant module.
#[test]
fn train_stats_match_dump_gradients() {
    let Some(e) = engine() else { return };
    let model = e.manifest.model("mlp").unwrap().clone();
    let g_init = e.graph("mlp", "init").unwrap();
    let g_train = e.graph("mlp", "train").unwrap();
    let g_dump = e.graph("mlp", "dump").unwrap();

    let carry = e.run(&g_init, &[Tensor::scalar_i32(3)]).unwrap();
    let p = model.params.len();
    let s = model.state.len();
    let q = model.n_sites();
    let bs = model.batch_size;

    // a fixed batch
    let img: usize = model.input_shape.iter().product();
    let x = Tensor::from_f32(
        &[bs, model.input_shape[0], model.input_shape[1], model.input_shape[2]],
        (0..bs * img).map(|i| ((i % 97) as f32 / 48.5) - 1.0).collect(),
    );
    let y = Tensor::from_i32(&[bs], (0..bs as i32).map(|i| i % 10).collect());
    let ranges = Tensor::from_f32(&[q, 2], vec![-1.0, 1.0].repeat(q));
    let seed = Tensor::scalar_i32(42);

    // train step, hindsight mode, all quant on, lr=0 so params stay put
    let mut inputs: Vec<&Tensor> = carry.iter().collect();
    let scal = [
        Tensor::scalar_f32(2.0), // mode_act
        Tensor::scalar_f32(2.0), // mode_grad
        Tensor::scalar_f32(1.0), // wq
        Tensor::scalar_f32(1.0), // aq
        Tensor::scalar_f32(1.0), // gq
        Tensor::scalar_f32(0.9), // eta
        Tensor::scalar_f32(0.0), // lr
        Tensor::scalar_f32(0.0), // wd
    ];
    inputs.push(&x);
    inputs.push(&y);
    inputs.push(&ranges);
    for t in &scal {
        inputs.push(t);
    }
    inputs.push(&seed);
    let out = e.run_refs(&g_train, &inputs).unwrap();
    let stats = out.last().unwrap().as_f32().unwrap().to_vec();

    // dump graph with the same state/batch/ranges/seed
    let mut dinputs: Vec<&Tensor> = Vec::new();
    dinputs.extend(carry[..p].iter());
    dinputs.extend(carry[2 * p..2 * p + s].iter());
    let dscal = [
        Tensor::scalar_f32(2.0), // mode_grad
        Tensor::scalar_f32(1.0), // wq
        Tensor::scalar_f32(1.0), // aq
        Tensor::scalar_f32(1.0), // gq
        Tensor::scalar_f32(0.9), // eta
    ];
    dinputs.push(&x);
    dinputs.push(&y);
    dinputs.push(&ranges);
    for t in &dscal {
        dinputs.push(t);
    }
    dinputs.push(&seed);
    let grads = e.run_refs(&g_dump, &dinputs).unwrap();

    // per grad site: minmax (computed by the Rust quant module) == stats
    for (gi, site) in model.grad_sites().iter().enumerate() {
        let (lo, hi) = quant::minmax(grads[gi].as_f32().unwrap());
        let (slo, shi) = (stats[2 * site.index], stats[2 * site.index + 1]);
        let tol = 1e-5 * (1.0 + hi.abs().max(lo.abs()));
        assert!(
            (lo - slo).abs() < tol && (hi - shi).abs() < tol,
            "site {} ({}): dump minmax [{lo}, {hi}] vs train stats [{slo}, {shi}]",
            site.index,
            site.name
        );
    }
}

/// Same configuration + same seed => bitwise-identical runs (the whole
/// stack is deterministic: data gen, batching, stochastic rounding).
#[test]
fn training_is_deterministic() {
    let Some(e) = engine() else { return };
    let run = |seed: u64| {
        let mut cfg = quick("mlp").fully_quantized(Estimator::HINDSIGHT);
        cfg.seed = seed;
        Trainer::new(&e, cfg).unwrap().run().unwrap()
    };
    let a = run(5);
    let b = run(5);
    let c = run(6);
    assert_eq!(a.losses, b.losses, "same seed must replay exactly");
    assert_ne!(a.losses, c.losses, "different seed must differ");
}

/// The paper's core claim at micro scale: in-hindsight (static) training
/// reaches an accuracy comparable to dynamic estimators on the same
/// budget.  With a tiny budget we assert a weak form: quantized training
/// works (loss decreases) for every estimator and final accuracies are
/// finite.
#[test]
fn all_estimators_train() {
    let Some(e) = engine() else { return };
    for est in Estimator::all().filter(|e| e.enabled()) {
        let mut cfg = quick("mlp").fully_quantized(est);
        if est.needs_search() {
            cfg.dsgc_period = 5;
        }
        cfg.steps = 40;
        let rec = Trainer::new(&e, cfg).unwrap().run().unwrap();
        assert!(
            rec.loss_decreased(),
            "{}: loss failed to decrease: {:?}",
            est.name(),
            &rec.losses[..5.min(rec.losses.len())]
        );
        assert!(rec.final_val_acc().is_finite());
    }
}

/// FP32 vs quantized: with 8-bit quantization the two runs should differ
/// (quantization is on) but stay in the same loss regime — the
/// within-a-few-percent shape of the paper's tables.
#[test]
fn quantization_perturbs_but_does_not_break() {
    let Some(e) = engine() else { return };
    let mut base = quick("mlp");
    base.steps = 60;
    let fp = Trainer::new(&e, base.clone().fully_quantized(Estimator::FP32))
        .unwrap()
        .run()
        .unwrap();
    let qt = Trainer::new(&e, base.fully_quantized(Estimator::HINDSIGHT))
        .unwrap()
        .run()
        .unwrap();
    assert_ne!(fp.losses, qt.losses, "quantization must change the math");
    let (fl, ql) = (fp.tail_loss(10), qt.tail_loss(10));
    assert!(
        (ql - fl).abs() < 1.0,
        "quantized tail loss {ql:.3} too far from fp32 {fl:.3}"
    );
}

/// Estimator mode is a runtime input: switching estimators must not
/// trigger a recompile (one executable per model/graph per process).
#[test]
fn estimator_sweep_reuses_executables() {
    let Some(e) = engine() else { return };
    for est in [Estimator::CURRENT, Estimator::RUNNING, Estimator::HINDSIGHT] {
        let mut cfg = quick("mlp").fully_quantized(est);
        cfg.steps = 2;
        cfg.calib_batches = 0;
        let _ = Trainer::new(&e, cfg).unwrap().run().unwrap();
    }
    // init + train + eval compiled once each
    assert_eq!(e.stats().compiles, 3, "{:?}", e.stats());
}

/// The pallas-lowered resnet variant loads and trains (kernel-at-scale).
#[test]
fn resnet_pallas_variant_steps() {
    let Some(e) = engine() else { return };
    if e.manifest.model("resnet_pallas").is_err() {
        return;
    }
    let mut cfg = quick("resnet_pallas");
    cfg.calib_batches = 0;
    cfg.steps = 2;
    let mut t = Trainer::new(&e, cfg).unwrap();
    for _ in 0..2 {
        let (loss, _) = t.train_step().unwrap();
        assert!(loss.is_finite());
    }
}

/// Ranges persist and evolve: in-hindsight ranges after training differ
/// from the neutral init and cover the last observed statistics.
#[test]
fn hindsight_ranges_track_statistics() {
    let Some(e) = engine() else { return };
    let mut cfg = quick("mlp").fully_quantized(Estimator::HINDSIGHT);
    cfg.steps = 30;
    let mut t = Trainer::new(&e, cfg).unwrap();
    t.calibrate().unwrap();
    for _ in 0..30 {
        t.train_step().unwrap();
    }
    assert!(
        t.ranges.coverage() > 0.5,
        "EMA ranges lost track of the statistics: coverage {}",
        t.ranges.coverage()
    );
}

//! Grid surface: brace templates and seed strings never panic and
//! never expand past the MAX_EXPANSIONS / MAX_SEEDS / MAX_GRID_CELLS
//! caps, no matter the input.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    hindsight::util::fuzzing::check_grid_expansion(data);
});

//! Service request path: HTTP framing -> JSON body -> JobSpec ->
//! grid expansion on arbitrary bytes, bounded end to end.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    hindsight::util::fuzzing::check_service_request(data);
});

//! Scheme grammar: parse -> canonicalize -> reparse is a fixpoint.
//! The harness body lives in the main crate so `cargo test` replays
//! the corpus through the exact same code on stable.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    hindsight::util::fuzzing::check_scheme_roundtrip(data);
});

//! JSON differential: the owned parser and the bytes-backed RawDoc
//! must agree on accept/reject, trees, and error position + message;
//! accepted documents survive serialize -> reparse.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    hindsight::util::fuzzing::check_json_differential(data);
});

//! Paper Table 5: memory-movement cost of static vs dynamic quantization
//! (eqs. 4 & 5) for the five highlighted layers — an exact analytic
//! reproduction, cross-checked against the MAC-array machine.
//!
//!   cargo bench --bench table5_memory_transfer

use hindsight::simulator::machine::MacArray;
use hindsight::simulator::traffic::{self, BitWidths};
use hindsight::util::bench::Table;

fn main() {
    let b = BitWidths::default();
    let mac = MacArray::default();
    // paper cells: (static KB, dynamic KB, delta). Row 4 marked *: the
    // paper's printed absolutes for the 96ch DW layer are inconsistent
    // with its own eq. (4) by a 3/8 factor; its delta (+400%) matches.
    let paper = [
        ("428 KB", "1996 KB", "+366%"),
        ("674 KB", "1066 KB", "+58%"),
        ("1374 KB", "10782 KB", "+685%"),
        ("882 KB*", "4410 KB*", "+400%"),
        ("100 KB", "468 KB", "+366%"),
    ];
    let mut t = Table::new(
        "Table 5 — memory movement, static vs dynamic (b_w=b_a=8, b_acc=32)",
        &[
            "Layer", "Cin", "Cout", "WxH", "Static", "Dynamic", "Delta",
            "paper static", "paper dynamic", "paper delta",
        ],
    );
    for (g, (ps, pd, pdelta)) in traffic::table5_layers().iter().zip(paper) {
        let c = traffic::compare(g, b);
        // machine-level cross-check: byte-for-byte agreement with eqs. 4/5
        assert_eq!(mac.conv_traffic(g, true).total() * 8, c.static_bits);
        assert_eq!(mac.conv_traffic(g, false).total() * 8, c.dynamic_bits);
        t.row(&[
            g.name.to_string(),
            g.cin.to_string(),
            g.cout.to_string(),
            format!("{}x{}", g.w, g.h),
            format!("{:.0} KB", c.static_kb()),
            format!("{:.0} KB", c.dynamic_kb()),
            format!("+{:.0}%", c.delta_percent()),
            ps.into(),
            pd.into(),
            pdelta.into(),
        ]);
    }
    t.print();
    let worst = traffic::table5_layers()
        .iter()
        .map(|g| traffic::compare(g, b).ratio())
        .fold(0.0, f64::max);
    println!(
        "paper headline: dynamic quantization costs up to 8x more memory \
         movement — measured max ratio {worst:.2}x (pointwise conv).\n\
         (*) paper's printed absolutes for row 4 are inconsistent with its \
         own eq. (4); the scale-invariant delta matches exactly."
    );
    assert!(worst > 7.5 && worst < 8.1);
}

//! Paper Table 5: memory-movement cost of static vs dynamic quantization
//! (eqs. 4 & 5) for the five highlighted layers — an exact analytic
//! reproduction, cross-checked against the MAC-array machine — plus the
//! same accounting over a transformer workload (ViT-S/16), whose rows
//! land in the bench JSON for the CI smoke gate.
//!
//!   cargo bench --bench table5_memory_transfer

use hindsight::models;
use hindsight::simulator::machine::MacArray;
use hindsight::simulator::traffic::{self, BitWidths};
use hindsight::simulator::LayerGeom;
use hindsight::util::bench::{append_bench_record, Table};
use hindsight::util::json::Value;

fn kind_label(g: &LayerGeom) -> &'static str {
    match g {
        LayerGeom::Conv2d(c) if c.depthwise => "dw-conv",
        LayerGeom::Conv2d(_) => "conv",
        LayerGeom::Linear(_) => "linear",
        LayerGeom::Attention(_) => "attention",
    }
}

fn main() {
    let b = BitWidths::default();
    let mac = MacArray::default();
    // paper cells: (static KB, dynamic KB, delta). Row 4 marked *: the
    // paper's printed absolutes for the 96ch DW layer are inconsistent
    // with its own eq. (4) by a 3/8 factor; its delta (+400%) matches.
    let paper = [
        ("428 KB", "1996 KB", "+366%"),
        ("674 KB", "1066 KB", "+58%"),
        ("1374 KB", "10782 KB", "+685%"),
        ("882 KB*", "4410 KB*", "+400%"),
        ("100 KB", "468 KB", "+366%"),
    ];
    let mut t = Table::new(
        "Table 5 — memory movement, static vs dynamic (b_w=b_a=8, b_acc=32)",
        &[
            "Layer", "In", "Out", "Shape", "Static", "Dynamic", "Delta",
            "paper static", "paper dynamic", "paper delta",
        ],
    );
    for (g, (ps, pd, pdelta)) in traffic::table5_layers().iter().zip(paper) {
        let c = traffic::compare(g, b);
        // machine-level cross-check: byte-for-byte agreement with eqs. 4/5
        assert_eq!(mac.layer_phases(g, true).total() * 8, c.static_bits);
        assert_eq!(mac.layer_phases(g, false).total() * 8, c.dynamic_bits);
        t.row(&[
            g.name().to_string(),
            g.fan_in().to_string(),
            g.fan_out().to_string(),
            g.spatial(),
            format!("{:.0} KB", c.static_kb()),
            format!("{:.0} KB", c.dynamic_kb()),
            format!("+{:.0}%", c.delta_percent()),
            ps.into(),
            pd.into(),
            pdelta.into(),
        ]);
    }
    t.print();
    let worst = traffic::table5_layers()
        .iter()
        .map(|g| traffic::compare(g, b).ratio())
        .fold(0.0, f64::max);
    println!(
        "paper headline: dynamic quantization costs up to 8x more memory \
         movement — measured max ratio {worst:.2}x (pointwise conv).\n\
         (*) paper's printed absolutes for row 4 are inconsistent with its \
         own eq. (4); the scale-invariant delta matches exactly."
    );
    assert!(worst > 7.5 && worst < 8.1);

    // transformer leg: the same eqs. 4/5 on ViT-S/16 — every layer
    // (conv patch embed, attention, MLP linears) cross-checked against
    // the machine's phase totals
    let layers = models::vit_s16();
    let (mut tot_s, mut tot_d) = (0u64, 0u64);
    for g in &layers {
        let c = traffic::compare(g, b);
        assert_eq!(mac.layer_phases(g, true).total() * 8, c.static_bits);
        assert_eq!(mac.layer_phases(g, false).total() * 8, c.dynamic_bits);
        tot_s += c.static_bits;
        tot_d += c.dynamic_bits;
    }
    let mut t2 = Table::new(
        "ViT-S/16 under the same accounting (patch embed + block 0 shown)",
        &["Layer", "Kind", "Static", "Dynamic", "Delta"],
    );
    for g in layers.iter().take(4) {
        let c = traffic::compare(g, b);
        t2.row(&[
            g.name().to_string(),
            kind_label(g).to_string(),
            format!("{:.0} KB", c.static_kb()),
            format!("{:.0} KB", c.dynamic_kb()),
            format!("+{:.0}%", c.delta_percent()),
        ]);
    }
    t2.row(&[
        "TOTAL (38 layers)".into(),
        "".into(),
        format!("{:.0} KB", tot_s as f64 / 8.0 / 1024.0),
        format!("{:.0} KB", tot_d as f64 / 8.0 / 1024.0),
        format!("+{:.0}%", (tot_d as f64 / tot_s as f64 - 1.0) * 100.0),
    ]);
    t2.print();
    println!(
        "network ratio (dynamic/static): {:.2}x over the full ViT-S/16",
        tot_d as f64 / tot_s as f64
    );
    assert!(tot_d > tot_s, "dynamic must move strictly more than static");

    // drop the transformer rows into the bench trajectory: one record
    // for the first attention layer, one for the network total (no
    // kernel/speedup pair, so the bench-report gate skips them)
    let attn = layers
        .iter()
        .find(|g| matches!(g, LayerGeom::Attention(_)))
        .expect("ViT-S/16 has attention layers");
    let c = traffic::compare(attn, b);
    let path = append_bench_record(Value::object(vec![
        ("bench", "table5_memory_transfer".into()),
        ("workload", "vit_s16".into()),
        ("layer_kind", "attention".into()),
        ("layer", attn.name().into()),
        ("static_kb", c.static_kb().into()),
        ("dynamic_kb", c.dynamic_kb().into()),
        ("ratio", c.ratio().into()),
    ]))
    .expect("bench record");
    append_bench_record(Value::object(vec![
        ("bench", "table5_memory_transfer".into()),
        ("workload", "vit_s16".into()),
        ("layer_kind", "network".into()),
        ("layer", "TOTAL".into()),
        ("static_kb", (tot_s as f64 / 8.0 / 1024.0).into()),
        ("dynamic_kb", (tot_d as f64 / 8.0 / 1024.0).into()),
        ("ratio", (tot_d as f64 / tot_s as f64).into()),
    ]))
    .expect("bench record");
    println!("transformer records appended to {}", path.display());
}

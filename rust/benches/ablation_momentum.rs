//! Ablation: sensitivity of in-hindsight min-max to the EMA momentum η
//! (paper Sec. 5.2: "we observe little sensitivity to that parameter").
//!
//!   cargo bench --bench ablation_momentum

mod common;

use hindsight::coordinator::{sweep_row, Estimator, QuantScheme};
use hindsight::runtime::Engine;
use hindsight::util::bench::Table;

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");
    let s = common::scale();
    let mut table = Table::new(
        "Ablation — in-hindsight momentum η (cnn, fully quantized)",
        &["η", "Val. Acc. (%)", "ms/step"],
    );
    let mut accs = Vec::new();
    for eta in [0.0f32, 0.5, 0.9, 0.99] {
        let mut cfg = common::base_cfg("cnn", &s);
        cfg.scheme = QuantScheme::fully_quantized(Estimator::HINDSIGHT).eta_all(eta);
        let out = sweep_row(&engine, &cfg, &format!("eta={eta}"), &s.seeds).unwrap();
        accs.push(out.agg.mean());
        table.row(&[
            format!("{eta}"),
            out.cell(),
            format!("{:.0}", out.sec_per_step * 1e3),
        ]);
    }
    table.print();
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "accuracy spread across η ∈ [0, 0.99]: {spread:.2} points \
         (paper: little sensitivity). η=0 degenerates to one-step-delayed \
         current min-max; η→1 freezes the calibrated range."
    );
}

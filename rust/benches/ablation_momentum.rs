//! Ablation: sensitivity of in-hindsight min-max to the EMA momentum η
//! (paper Sec. 5.2: "we observe little sensitivity to that parameter").
//! The η axis is a scheme grid — one fully quantized scheme per η via
//! the typed builder, expanded and run through the grid engine.
//!
//!   cargo bench --bench ablation_momentum

mod common;

use hindsight::coordinator::{
    grid_rows, run_cells_on, Estimator, GridOptions, GridSpec, QuantScheme,
};
use hindsight::runtime::Engine;
use hindsight::util::bench::Table;

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");
    let s = common::scale();
    let mut table = Table::new(
        "Ablation — in-hindsight momentum η (cnn, fully quantized)",
        &["η", "Val. Acc. (%)", "ms/step"],
    );
    let etas = [0.0f32, 0.5, 0.9, 0.99];
    let schemes: Vec<QuantScheme> = etas
        .iter()
        .map(|&eta| QuantScheme::fully_quantized(Estimator::HINDSIGHT).eta_all(eta))
        .collect();
    let grid = GridSpec::alternation(&schemes, &s.seeds).expect("eta grid");
    let cells = grid.expand(&common::base_cfg("cnn", &s));
    let rows = grid_rows(&run_cells_on(&engine, &cells, &GridOptions::serial()));
    let mut accs = Vec::new();
    for (eta, row) in etas.iter().zip(&rows) {
        assert!(!row.runs.is_empty(), "eta={eta}: every cell failed");
        accs.push(row.agg.mean());
        table.row(&[
            format!("{eta}"),
            row.cell(),
            format!("{:.0}", row.sec_per_step * 1e3),
        ]);
    }
    table.print();
    let spread = accs.iter().cloned().fold(f64::MIN, f64::max)
        - accs.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "accuracy spread across η ∈ [0, 0.99]: {spread:.2} points \
         (paper: little sensitivity). η=0 degenerates to one-step-delayed \
         current min-max; η→1 freezes the calibrated range."
    );
}

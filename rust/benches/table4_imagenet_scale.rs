//! Paper Table 4: fully quantized ResNet18 on full ImageNet — reproduced
//! at "scale-up" relative to Table 3: the SynthLarge workload (4x the
//! classes and samples, longer schedule) on the same architecture family,
//! comparing the three min-max estimators end to end.
//!
//!   cargo bench --bench table4_imagenet_scale

mod common;

use hindsight::coordinator::{sweep_row, Estimator};
use hindsight::runtime::Engine;
use hindsight::util::bench::{env_usize, quick, Table};

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");
    let s = common::scale();
    // SynthLarge: more data + longer schedule than the Table 3 runs
    let steps = if quick() { 32 } else { env_usize("HINDSIGHT_BENCH_STEPS", 120) as u64 * 2 };
    let paper = [
        ("FP32", "69.75"),
        ("Current min-max", "69.21 ± 0.06"),
        ("Running min-max", "69.35 ± 0.16"),
        ("In-hindsight min-max", "69.37 ± 0.11"),
    ];
    let mut table = Table::new(
        "Table 4 — fully quantized W8/A8/G8 at ImageNet-scale workload \
         (ResNet-tiny / SynthLarge)",
        &["Method", "Static", "Val. Acc. (%)", "paper (ImageNet)", "ms/step"],
    );
    for est in [
        Estimator::FP32,
        Estimator::CURRENT,
        Estimator::RUNNING,
        Estimator::HINDSIGHT,
    ] {
        let mut cfg = common::base_cfg("resnet_tiny", &s).fully_quantized(est);
        cfg.steps = steps;
        cfg.n_train = s.n_train * 4;
        cfg.n_val = s.n_val * 2;
        let out = sweep_row(&engine, &cfg, est.name(), &s.seeds).expect("row");
        let paper_cell = paper
            .iter()
            .find(|(n, _)| *n == est.name())
            .map(|(_, c)| c.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(&[
            est.name().to_string(),
            common::static_cell(est),
            out.cell(),
            paper_cell,
            format!("{:.0}", out.sec_per_step * 1e3),
        ]);
    }
    table.print();
    println!(
        "shape check: paper finds in-hindsight ≈ running > current, all \
         within 0.5% of FP32, with the static method matching the dynamic ones."
    );
    common::assert_rows_close_to_fp32(&table, 25.0);

    // scale context: the memory-analysis workload zoo the mem-report /
    // traffic benches run at full ImageNet scale (convs + transformers)
    println!("\nworkload zoo GMACs (mem-report networks):");
    for name in hindsight::models::names() {
        let layers = hindsight::models::by_name(name).expect("zoo name");
        let gmacs = layers.iter().map(|g| g.macs()).sum::<u64>() as f64 / 1e9;
        println!("  {name:>12}: {gmacs:.2} GMACs over {} layers", layers.len());
    }
}

//! Paper Table 2: activation-quantization range-estimator comparison.
//! Weights and backward pass FP32; activations quantized to 8 bits.
//!
//!   cargo bench --bench table2_act_estimators

mod common;

use common::{estimator_table, Mode};

fn main() {
    hindsight::util::logging::init();
    let paper = [
        ("FP32", "58.97 ± 0.13"),
        ("Current min-max", "59.00 ± 0.31"),
        ("Running min-max", "59.28 ± 0.25"),
        ("In-hindsight min-max", "59.30 ± 0.19"),
    ];
    let table = estimator_table(
        "Table 2 — activation quantization range estimators \
         (ResNet-tiny / SynthTiny, A8, bwd FP32)",
        "resnet_tiny",
        Mode::ActOnly,
        &paper,
    );
    table.print();
    println!(
        "shape check: paper finds in-hindsight ≈ running ≥ current, all within \
         ~0.5% of FP32."
    );
    common::assert_rows_close_to_fp32(&table, 20.0);
}

//! Ablation: the initial calibration pass for activation quantizers
//! (paper Sec. 5.2: "both methods benefit from an initial calibration
//! step when used for activation quantization").
//!
//!   cargo bench --bench ablation_calibration

mod common;

use hindsight::coordinator::{sweep_row, Estimator};
use hindsight::runtime::Engine;
use hindsight::util::bench::Table;

fn main() {
    hindsight::util::logging::init();
    let engine = Engine::new().expect("engine");
    let s = common::scale();
    let mut table = Table::new(
        "Ablation — activation-quantizer calibration (cnn, A8 only)",
        &["Method", "Calib batches", "Val. Acc. (%)"],
    );
    for est in [Estimator::RUNNING, Estimator::HINDSIGHT] {
        for calib in [0usize, 4] {
            let mut cfg = common::base_cfg("cnn", &s).act_only(est);
            cfg.calib_batches = calib;
            let out = sweep_row(
                &engine,
                &cfg,
                &format!("{}-c{calib}", est.name()),
                &s.seeds,
            )
            .unwrap();
            table.row(&[
                est.name().into(),
                calib.to_string(),
                out.cell(),
            ]);
        }
    }
    table.print();
    println!(
        "paper: running and in-hindsight activation quantizers both benefit \
         from feeding a few batches through the network before training; \
         without it the first steps quantize with a cold range state."
    );
}
